"""Fault-tolerant checkpointing: atomic, sharded, resumable.

Layout: <dir>/step_<N>/
    manifest.json        tree structure + shapes/dtypes + save metadata
    shard_<proc>.npz     flat arrays owned by this host process

Writes go to a temp directory then an atomic rename — a preempted save never
corrupts the latest checkpoint. `restore_latest` + the train loop's
auto-resume give restartability; `keep` bounds disk usage. (Single-process
here; the per-process sharding hook is the `process_index` suffix.)
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    if hasattr(jax.tree, "flatten_with_path"):
        flat, treedef = jax.tree.flatten_with_path(tree)
    else:  # jax <= 0.4.x
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
             for p, _ in flat]
    return paths, [v for _, v in flat], treedef


def save(ckpt_dir: str, step: int, tree, keep: int = 3, extra: dict | None = None):
    proc = jax.process_index()
    paths, leaves, _ = _flatten_with_paths(tree)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + f".tmp{proc}"
    os.makedirs(tmp, exist_ok=True)
    raw = [np.asarray(jax.device_get(v)) for v in leaves]
    dtypes = [str(a.dtype) for a in raw]
    # numpy's savez cannot serialize ml_dtypes (bfloat16, fp8): store a raw
    # byte view and re-view on restore via the manifest dtype.
    arrays = {
        f"a{i}": (a if a.dtype.kind in "fiub?" and a.dtype.name != "bfloat16"
                  else a.view(np.uint8))
        for i, a in enumerate(raw)
    }
    np.savez(os.path.join(tmp, f"shard_{proc}.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": dtypes,
        "shapes": [list(a.shape) for a in raw],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp0")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and "." not in d
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like, strict: bool = True):
    """Restore into the structure of tree_like (shape-checked).

    strict=False matches leaves by manifest *path* instead of flat order:
    paths missing from the checkpoint keep tree_like's current value (so a
    state_dict that grew new fields — e.g. the scheduler's backend adaptive
    skip-control state, which is APPENDED to `FusedState` precisely so the
    positional paths of old snapshots still line up — still restores from
    old checkpoints), checkpoint paths absent from tree_like are ignored,
    and a matched path whose stored shape no longer fits tree_like keeps
    the current value too (with a warning) instead of failing the restore.
    """
    proc = jax.process_index()
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, f"shard_{proc}.npz"))
    import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)

    leaves = []
    for i, (dt, shp) in enumerate(zip(manifest["dtypes"], manifest["shapes"])):
        a = data[f"a{i}"]
        if a.dtype == np.uint8 and dt != "uint8":
            a = a.view(np.dtype(dt)).reshape(shp)
        leaves.append(a)
    if strict:
        ref_leaves, treedef = jax.tree.flatten(tree_like)
        assert len(leaves) == len(ref_leaves), "checkpoint/tree mismatch"
        pairs = zip(leaves, ref_leaves)
    else:
        by_path = dict(zip(manifest["paths"], leaves))
        ref_paths, ref_leaves, treedef = _flatten_with_paths(tree_like)
        pairs = [(by_path.get(p, ref), ref)
                 for p, ref in zip(ref_paths, ref_leaves)]
    out = []
    for got, ref in pairs:
        if got is not ref:
            got = np.asarray(jax.device_get(got))
        if got is ref or tuple(got.shape) != tuple(ref.shape):
            if got is not ref:
                if strict:
                    raise AssertionError((got.shape, ref.shape))
                import warnings

                warnings.warn(
                    f"checkpoint leaf shape {got.shape} does not fit "
                    f"{tuple(ref.shape)}; keeping the current value",
                    stacklevel=2,
                )
            # Keep the reference leaf AS IS — no host round-trip, and its
            # device placement/sharding survives.
            out.append(ref)
            continue
        out.append(jnp.asarray(got, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, out), manifest["extra"]


def restore_latest(ckpt_dir: str, tree_like, strict: bool = True):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None, None
    tree, extra = restore(ckpt_dir, step, tree_like, strict=strict)
    return tree, step, extra
