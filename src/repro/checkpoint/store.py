"""Fault-tolerant checkpointing: atomic, sharded, resumable.

Two on-disk formats share one directory layout, <dir>/step_<N>/:

  legacy (single-process, `sharded=False`):
    manifest.json        tree structure + shapes/dtypes + save metadata
    shard_0.npz          ALL flat arrays, gathered to the host

  sharded-v1 (`sharded=True`; the default on multi-process meshes):
    manifest.json        tree structure + GLOBAL shapes/dtypes + process
                         topology + per-shard checksums (embedded from the
                         shard_<proc>.json done-markers)
    shard_<proc>.npz     each process's ADDRESSABLE slab of every leaf —
                         written from `jax.Array.addressable_shards`, so no
                         host ever materializes (or device_get's) a
                         non-addressable global array
    shard_<proc>.json    per-process done-marker: crc32 per array + slab
                         offsets/shapes (embedded into the manifest by
                         process 0, then deleted from view by the rename)

Commit protocol: every process writes into the shared `step_<N>.tmp`
directory; its shard_<proc>.json is the done-marker. Process 0 waits for
all markers, embeds them into manifest.json, and atomically renames the
temp dir over the final one — a preempted save never corrupts the latest
checkpoint, and a step directory WITHOUT a manifest.json is by definition
a partially-renamed/partially-written step. Non-zero processes wait for
the final directory to appear (save returns only once the checkpoint is
durable on every host).

Integrity: restore verifies each array against the manifest's per-shard
crc32 and raises `CheckpointCorruptError` on any damage — truncated or
bit-flipped npz, unreadable manifest, missing shard file. `restore_latest`
catches it, warns, and falls back to the previous step instead of
crashing. (On a multi-process mesh all processes see the same manifest, so
a damaged manifest falls back consistently; per-host npz damage is
host-local — a driver that needs fleet agreement on the restored step
should broadcast process 0's step.)

Restore of a sharded-v1 checkpoint reassembles each leaf from the local
slab via `jax.make_array_from_process_local_data` against the reference
tree's sharding — committed sharded arrays come back without any global
gather. `strict=False` path-matching compat with old snapshots (and old
single-file layouts) is preserved.

Topology resharding: a sharded-v1 checkpoint saved by N processes can be
restored onto a DIFFERENT process count (elastic shrink/grow, or a
single-process post-mortem of a fleet checkpoint). When the running
topology differs from the saving one, every running process reads all
saved shard files (crc-verified) and re-slices each leaf along the
recorded global offsets to exactly its own addressable box under the
reference sharding — the data path is offsets-driven, so it needs no
agreement between the old and new shard boundaries beyond both tiling
the same global shapes. The fast path (same topology: each process reads
only its own shard file) is unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import warnings
import zlib

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A checkpoint step directory is damaged: unreadable/missing manifest
    (partially-renamed step), truncated/corrupt shard npz, or a checksum
    mismatch. `restore_latest` treats it as 'skip this step and fall back
    to the previous one'."""


def _flatten_with_paths(tree):
    if hasattr(jax.tree, "flatten_with_path"):
        flat, treedef = jax.tree.flatten_with_path(tree)
    else:  # jax <= 0.4.x
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
             for p, _ in flat]
    return paths, [v for _, v in flat], treedef


def _storable(a: np.ndarray) -> np.ndarray:
    # numpy's savez cannot serialize ml_dtypes (bfloat16, fp8): store a raw
    # byte view and re-view on restore via the manifest dtype.
    if a.dtype.kind in "fiub?" and a.dtype.name != "bfloat16":
        return a
    return a.view(np.uint8)


def _local_slab(v):
    """This process's contiguous slab of a leaf + its global offsets.

    Returns (array, offsets): offsets is None when the slab IS the whole
    (global) array — non-jax leaves, fully-addressable arrays, and
    fully-replicated arrays (one addressable copy suffices) — else the
    per-axis global start indices of the slab. Never touches a
    non-addressable shard and never calls `jax.device_get`, so saving can
    run under a no-global-gather guard."""
    if not isinstance(v, jax.Array):
        return np.asarray(v), None
    if v.is_fully_replicated:
        return np.asarray(v.addressable_shards[0].data), None
    if v.is_fully_addressable:
        return np.asarray(v), None
    shards = v.addressable_shards
    ndim = v.ndim
    lo = list(v.shape)
    hi = [0] * ndim
    uniq = {}
    for s in shards:
        key = tuple(
            (sl.start or 0, v.shape[i] if sl.stop is None else sl.stop)
            for i, sl in enumerate(s.index))
        if key in uniq:  # one entry per distinct index (replica devices)
            continue
        uniq[key] = s
        for i, (a, b) in enumerate(key):
            lo[i] = min(lo[i], a)
            hi[i] = max(hi[i], b)
    box = np.empty([h - l for l, h in zip(lo, hi)], dtype=v.dtype)
    filled = 0
    for key, s in uniq.items():
        idx = tuple(slice(a - l, b - l) for (a, b), l in zip(key, lo))
        box[idx] = np.asarray(s.data)
        filled += int(np.prod([b - a for a, b in key], dtype=np.int64))
    if filled != box.size:
        raise ValueError(
            f"addressable shards of a {v.shape} array do not tile a "
            "contiguous slab; the sharded checkpoint path needs the "
            "contiguous host-slice layout")
    return box, [int(l) for l in lo]


def _write_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f)


def _poll(predicate, what: str, timeout: float = 120.0):
    t0 = time.monotonic()
    while True:
        got = predicate()
        if got is not None:
            return got
        if time.monotonic() - t0 > timeout:
            raise TimeoutError(f"timed out waiting for {what}")
        time.sleep(0.05)


def save(ckpt_dir: str, step: int, tree, keep: int = 3,
         extra: dict | None = None, sharded: bool | None = None):
    """Write one atomic checkpoint step. `sharded=None` auto-selects: the
    per-host sharded-v1 format on a multi-process mesh, the legacy
    single-file format otherwise (exact old layout — old readers keep
    working). `sharded=True` forces the new format on one process too."""
    proc = jax.process_index()
    n_procs = jax.process_count()
    if sharded is None:
        sharded = n_procs > 1
    if not sharded and n_procs > 1:
        raise ValueError(
            "sharded=False cannot represent a multi-process mesh: a host "
            "cannot serialize the non-addressable shards of its peers")
    paths, leaves, _ = _flatten_with_paths(tree)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")

    if not sharded:
        tmp = final + f".tmp{proc}"
        os.makedirs(tmp, exist_ok=True)
        raw = [np.asarray(jax.device_get(v)) for v in leaves]
        stored = [_storable(a) for a in raw]
        np.savez(os.path.join(tmp, f"shard_{proc}.npz"),
                 **{f"a{i}": a for i, a in enumerate(stored)})
        manifest = {
            "step": step,
            "paths": paths,
            "dtypes": [str(a.dtype) for a in raw],
            "shapes": [list(a.shape) for a in raw],
            "crcs": [zlib.crc32(a.tobytes()) for a in stored],
            "extra": extra or {},
        }
        _write_json(os.path.join(tmp, "manifest.json"), manifest)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)
        return final

    # -- sharded-v1: shared temp dir, per-process slabs ------------------
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    slabs = [_local_slab(v) for v in leaves]
    stored = [_storable(a) for a, _ in slabs]
    np.savez(os.path.join(tmp, f"shard_{proc}.npz"),
             **{f"a{i}": a for i, a in enumerate(stored)})
    # The done-marker: written only after the npz is fully on disk.
    _write_json(os.path.join(tmp, f"shard_{proc}.json"), {
        "proc": proc,
        "crcs": [zlib.crc32(a.tobytes()) for a in stored],
        "offsets": [off for _, off in slabs],
        "local_shapes": [list(a.shape) for a, _ in slabs],
    })

    if proc == 0:
        def _read_marker(p):
            def attempt():
                try:
                    with open(os.path.join(tmp, f"shard_{p}.json")) as f:
                        return json.load(f)
                except (OSError, ValueError):
                    return None  # not written / mid-write yet
            return attempt

        shards_meta = {
            str(p): _poll(_read_marker(p), f"shard_{p}.json in {tmp}")
            for p in range(n_procs)
        }
        manifest = {
            "format": "sharded-v1",
            "step": step,
            "paths": paths,
            "dtypes": [str(v.dtype) if isinstance(v, jax.Array)
                       else str(np.asarray(v).dtype) for v in leaves],
            "shapes": [list(v.shape) if isinstance(v, jax.Array)
                       else list(np.asarray(v).shape) for v in leaves],
            "topology": {"n_procs": n_procs,
                         "n_devices": jax.device_count()},
            "shards": shards_meta,
            "extra": extra or {},
        }
        _write_json(os.path.join(tmp, "manifest.json"), manifest)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)
    else:
        # The rename is the commit: returning early would let this host
        # act on a checkpoint that does not exist yet.
        _poll(lambda: True if os.path.isdir(final) else None,
              f"process 0 to commit {final}")
    return final


def _gc(ckpt_dir: str, keep: int):
    # "." filters BOTH legacy ".tmp<proc>" dirs (any proc, not just 0) and
    # the shared sharded ".tmp" dir — never collect an in-flight save.
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and "." not in d
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def _step_dirs(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and "." not in d
    )


def latest_step(ckpt_dir: str) -> int | None:
    steps = _step_dirs(ckpt_dir)
    return steps[-1] if steps else None


class _Slab:
    """A process-local contiguous slab of a sharded leaf, pending
    reassembly against the reference tree's sharding."""

    __slots__ = ("local", "offsets", "shape")

    def __init__(self, local, offsets, shape):
        self.local = local
        self.offsets = offsets
        self.shape = tuple(shape)


class _MultiSlab:
    """ALL saved processes' slabs of one sharded leaf (the
    topology-resharding restore path): `pieces` of (local array, global
    offsets), disjoint and jointly tiling the global `shape`. Re-sliced to
    the running topology's addressable boxes in `_pair_and_rebuild`."""

    __slots__ = ("pieces", "shape")

    def __init__(self, pieces, shape):
        self.pieces = pieces
        self.shape = tuple(shape)


def _assemble_box(ref: jax.Array, slab: _MultiSlab) -> np.ndarray:
    """Fill this process's addressable box of `ref` from the recorded
    slabs of a different saving topology. Offsets-driven: each saved piece
    contributes its overlap with the box, and full coverage is verified —
    a gap means the recorded slabs do not tile the global shape
    (`CheckpointCorruptError`), never a silently half-initialized leaf."""
    if ref.is_fully_addressable:
        lo = [0] * ref.ndim
        box_shape = tuple(ref.shape)
    else:
        lo = list(ref.shape)
        hi = [0] * ref.ndim
        for s in ref.addressable_shards:
            for i, sl in enumerate(s.index):
                a = sl.start or 0
                b = ref.shape[i] if sl.stop is None else sl.stop
                lo[i] = min(lo[i], a)
                hi[i] = max(hi[i], b)
        box_shape = tuple(h - l for l, h in zip(lo, hi))
    box = np.empty(box_shape, ref.dtype)
    filled = 0
    for a, off in slab.pieces:
        src, dst = [], []
        for i in range(ref.ndim):
            s0 = max(off[i], lo[i])
            s1 = min(off[i] + a.shape[i], lo[i] + box_shape[i])
            if s1 <= s0:
                break
            src.append(slice(s0 - off[i], s1 - off[i]))
            dst.append(slice(s0 - lo[i], s1 - lo[i]))
        else:
            box[tuple(dst)] = a[tuple(src)].astype(ref.dtype, copy=False)
            filled += int(np.prod([s.stop - s.start for s in dst],
                                  dtype=np.int64))
    if filled != box.size:
        raise CheckpointCorruptError(
            f"recorded shard slabs cover {filled} of {box.size} elements "
            f"of this process's box of a {slab.shape} leaf; the saved "
            "slabs do not tile the global shape")
    return box


def restore(ckpt_dir: str, step: int, tree_like, strict: bool = True):
    """Restore into the structure of tree_like (shape-checked).

    strict=False matches leaves by manifest *path* instead of flat order:
    paths missing from the checkpoint keep tree_like's current value (so a
    state_dict that grew new fields — e.g. the scheduler's backend adaptive
    skip-control state, which is APPENDED to `FusedState` precisely so the
    positional paths of old snapshots still line up — still restores from
    old checkpoints), checkpoint paths absent from tree_like are ignored,
    and a matched path whose stored shape no longer fits tree_like keeps
    the current value too (with a warning) instead of failing the restore.

    Integrity: a missing/unreadable manifest (a partially-renamed step
    dir), a truncated or corrupt shard npz, and any crc mismatch raise
    `CheckpointCorruptError`. Sharded-v1 checkpoints reassemble each
    sharded leaf from this process's slab via
    `jax.make_array_from_process_local_data` — no global gather. When the
    running process count differs from the saving one, restore re-slices
    the saved slabs along their recorded global offsets to the running
    topology's addressable boxes (module docstring "Topology resharding")
    — each running process then reads every saved shard file."""
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"{d} has no readable manifest.json (partially renamed or "
            f"damaged step): {e}") from e
    if manifest.get("format") == "sharded-v1":
        leaves = _load_sharded_leaves(d, manifest)
    else:
        leaves = _load_legacy_leaves(d, manifest)
    return _pair_and_rebuild(leaves, manifest, tree_like, strict)


def _load_npz(d: str, proc: int, n: int) -> list[np.ndarray]:
    path = os.path.join(d, f"shard_{proc}.npz")
    try:
        data = np.load(path)
        return [data[f"a{i}"] for i in range(n)]
    except Exception as e:  # missing file, truncated/corrupt zip, bad member
        raise CheckpointCorruptError(
            f"shard_{proc}.npz in {d} is missing or unreadable: {e}") from e


def _verify_crcs(arrays, crcs, d: str, proc: int) -> None:
    if crcs is None:  # pre-checksum legacy snapshot
        return
    for i, (a, want) in enumerate(zip(arrays, crcs)):
        got = zlib.crc32(a.tobytes())
        if got != want:
            raise CheckpointCorruptError(
                f"checksum mismatch on array a{i} of shard_{proc}.npz in "
                f"{d} (crc32 {got} != recorded {want})")


def _load_legacy_leaves(d: str, manifest):
    proc = jax.process_index()
    arrays = _load_npz(d, proc, len(manifest["paths"]))
    _verify_crcs(arrays, manifest.get("crcs"), d, proc)
    import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)

    leaves = []
    for a, dt, shp in zip(arrays, manifest["dtypes"], manifest["shapes"]):
        if a.dtype == np.uint8 and dt != "uint8":
            a = a.view(np.dtype(dt)).reshape(shp)
        leaves.append(a)
    return leaves


def _load_sharded_leaves(d: str, manifest):
    proc = jax.process_index()
    n_procs = jax.process_count()
    topo = manifest.get("topology", {})
    if topo.get("n_procs") != n_procs:
        # Elastic restore: re-slice the saved slabs to the running topology
        # along the recorded global offsets.
        return _load_resharded_leaves(d, manifest)
    try:
        smeta = manifest["shards"][str(proc)]
    except KeyError as e:
        raise CheckpointCorruptError(
            f"manifest in {d} has no shard metadata for process "
            f"{proc}") from e
    arrays = _load_npz(d, proc, len(manifest["paths"]))
    _verify_crcs(arrays, smeta.get("crcs"), d, proc)
    import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)

    leaves = []
    for i, (dt, gshp) in enumerate(zip(manifest["dtypes"],
                                       manifest["shapes"])):
        a = arrays[i]
        if a.dtype == np.uint8 and dt != "uint8":
            a = a.view(np.dtype(dt)).reshape(smeta["local_shapes"][i])
        off = smeta["offsets"][i]
        leaves.append(a if off is None else _Slab(a, off, gshp))
    return leaves


def _load_resharded_leaves(d: str, manifest):
    """Load a sharded-v1 checkpoint saved by a DIFFERENT process count:
    every running process reads all saved shard files and carries each
    sharded leaf as a `_MultiSlab` of (slab, global offsets) pieces, which
    `_pair_and_rebuild` re-slices to this process's addressable boxes.
    Replicated/global leaves (offsets None — identical in every saved
    shard file by construction) restore from the first saved process."""
    topo = manifest.get("topology", {})
    saved_procs = sorted(int(p) for p in manifest.get("shards", {}))
    if saved_procs != list(range(topo.get("n_procs", -1))):
        raise CheckpointCorruptError(
            f"manifest in {d} records topology {topo} but shard metadata "
            f"for processes {saved_procs}")
    n = len(manifest["paths"])
    per_proc = []
    for p in saved_procs:
        smeta = manifest["shards"][str(p)]
        arrays = _load_npz(d, p, n)
        _verify_crcs(arrays, smeta.get("crcs"), d, p)
        per_proc.append((smeta, arrays))
    import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)

    leaves = []
    for i, (dt, gshp) in enumerate(zip(manifest["dtypes"],
                                       manifest["shapes"])):
        def view(p_idx):
            smeta, arrays = per_proc[p_idx]
            a = arrays[i]
            if a.dtype == np.uint8 and dt != "uint8":
                a = a.view(np.dtype(dt)).reshape(smeta["local_shapes"][i])
            return a
        # `_local_slab` classifies a leaf identically on every process
        # (sharded vs replicated/global is a property of the array, not
        # the host), so process 0's offsets decide for all.
        if per_proc[0][0]["offsets"][i] is None:
            leaves.append(view(0))
        else:
            leaves.append(_MultiSlab(
                [(view(p), per_proc[p][0]["offsets"][i])
                 for p in range(len(per_proc))], gshp))
    return leaves


def _pair_and_rebuild(leaves, manifest, tree_like, strict: bool):
    if strict:
        ref_leaves, treedef = jax.tree.flatten(tree_like)
        assert len(leaves) == len(ref_leaves), "checkpoint/tree mismatch"
        pairs = zip(leaves, ref_leaves)
    else:
        by_path = dict(zip(manifest["paths"], leaves))
        ref_paths, ref_leaves, treedef = _flatten_with_paths(tree_like)
        pairs = [(by_path.get(p, ref), ref)
                 for p, ref in zip(ref_paths, ref_leaves)]

    def keep_ref(got, ref, out):
        if got is not ref:
            if strict:
                raise AssertionError(
                    (got.shape, getattr(ref, "shape", None)))
            warnings.warn(
                f"checkpoint leaf shape {tuple(got.shape)} does not fit "
                f"{tuple(np.shape(ref))}; keeping the current value",
                stacklevel=3,
            )
        # Keep the reference leaf AS IS — no host round-trip, and its
        # device placement/sharding survives.
        out.append(ref)

    out = []
    for got, ref in pairs:
        if got is ref:
            out.append(ref)
            continue
        if isinstance(got, _Slab):
            # Reassemble the committed sharded leaf from this process's
            # slab — every process contributes its own, nobody gathers.
            if (isinstance(ref, jax.Array)
                    and got.shape == tuple(ref.shape)):
                local = got.local.astype(ref.dtype, copy=False)
                out.append(jax.make_array_from_process_local_data(
                    ref.sharding, local))
            else:
                keep_ref(got, ref, out)
            continue
        if isinstance(got, _MultiSlab):
            # Topology resharding: re-slice the saved slabs to THIS
            # process's addressable box under the reference sharding.
            if (isinstance(ref, jax.Array)
                    and got.shape == tuple(ref.shape)):
                box = _assemble_box(ref, got)
                if ref.is_fully_addressable:
                    out.append(jax.device_put(box, ref.sharding))
                else:
                    out.append(jax.make_array_from_process_local_data(
                        ref.sharding, box))
            else:
                keep_ref(got, ref, out)
            continue
        got = np.asarray(got)
        if tuple(got.shape) != tuple(np.shape(ref)):
            keep_ref(got, ref, out)
            continue
        out.append(jnp.asarray(got, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, out), manifest["extra"]


def restore_latest(ckpt_dir: str, tree_like, strict: bool = True):
    """Restore the newest intact step: a step that raises
    `CheckpointCorruptError` (partially-renamed dir, truncated npz, crc
    mismatch) is skipped with a warning and the previous step is tried —
    a damaged latest checkpoint degrades to the one before it, it does not
    take the service down. Returns (None, None, None) when no intact step
    exists."""
    for step in reversed(_step_dirs(ckpt_dir)):
        try:
            tree, extra = restore(ckpt_dir, step, tree_like, strict=strict)
        except CheckpointCorruptError as e:
            warnings.warn(
                f"checkpoint step {step} is damaged ({e}); falling back "
                "to the previous step", stacklevel=2)
            continue
        return tree, step, extra
    return None, None, None
