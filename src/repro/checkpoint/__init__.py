from repro.checkpoint.store import (
    CheckpointCorruptError,
    latest_step,
    restore,
    restore_latest,
    save,
)

__all__ = [k for k in dir() if not k.startswith("_")]
