"""Typed scheduler errors: host-local vs fleet-fatal.

A multi-process deployment needs to know, when one host's driver catches an
exception, whether the rest of the fleet is still healthy. Bare ValueErrors
cannot carry that distinction, so the service raises these instead. Every
class subclasses the builtin it replaced (ValueError / TypeError), so
existing `except ValueError` handlers and tests keep working.

The contract is the `fleet_fatal` class attribute:

  * `fleet_fatal=False` (host-local, recoverable): the error was raised
    during host-side validation/conversion, BEFORE this host dispatched any
    device work or entered any collective. No peer host is affected — the
    driver may fix the offending batch (reshape it, cast it, drop bad rows)
    and retry on this host alone.

  * `fleet_fatal=True` (must abort the fleet): the condition violates a
    cross-host static contract (capacity caps are compiled shapes all hosts
    agree on). Peer hosts whose data fit the contract have already entered
    the round and are waiting at its collectives; they will never complete.
    The driver must tear down / restart the whole fleet (restore from the
    per-host shard checkpoints — see README "Fault tolerance & recovery").

Hierarchy:

    SchedulerError
    ├── FeedValidationError(ValueError)    host-local: bad feed/update shape
    │   └── FeedDtypeError(TypeError)      host-local: non-integer CIS feed
    └── CapacityExceeded(ValueError)       FLEET-FATAL: cap contract broken
"""
from __future__ import annotations


class SchedulerError(Exception):
    """Base of the scheduler's typed errors.

    `fleet_fatal` tells a multi-host driver whether peers are affected:
    False = raised before any device work on this host, fix-and-retry
    locally; True = a cross-host contract is broken, tear down the fleet.
    """

    fleet_fatal = False


class FeedValidationError(SchedulerError, ValueError):
    """A CIS feed / refresh batch failed host-side validation (shape,
    width, page-id range). Host-local and recoverable: raised before any
    device work, so the driver can fix the batch and retry — no peer host
    saw anything."""

    fleet_fatal = False


class FeedDtypeError(FeedValidationError, TypeError):
    """A CIS feed carried a non-integer dtype (would promote the donated
    int32 n_cis state). Host-local and recoverable, like its parent; also a
    TypeError because the legacy dtype checks raised TypeError."""

    fleet_fatal = False


class CapacityExceeded(SchedulerError, ValueError):
    """A per-host capacity contract (`feed_cap` / `update_cap`) cannot be
    satisfied: either a batch exceeds the pinned cap, or a multi-process
    mesh was driven without an explicit cap. FLEET-FATAL: caps are compiled
    static shapes all hosts agree on — peer hosts whose data fit are
    already waiting at the round's collectives and will never complete.
    Tear the fleet down and restore from the per-host shard checkpoints.

    (The one exception the service handles itself: an over-`update_cap`
    refresh batch is chunked host-side in `update_pages` — the local-range
    repack is collective-free, so hosts need not agree on chunk count.
    This error therefore only escapes for feed batches and for missing
    caps on multi-process meshes.)"""

    fleet_fatal = True
