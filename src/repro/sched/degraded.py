"""Degraded-mode host-side machinery: outcome-echo dedup and bounded retry.

The on-device half of degraded mode (the per-block staleness watchdog, bound
inflation for silent blocks, expected-missed-CIS compensation, and estimator
quarantine) lives in `sched/backends.py` behind `FusedBackend(degraded=True)`.
This module is the host-side half: the outcome-echo path from a crawler fleet
is a distributed feed in its own right, and under faults it delivers batches
late, twice, or out of order. Scattering a duplicate batch into the streaming
estimator double-counts observations (`StreamStats` has no idempotence), so
delivery must be gated *before* `run_rounds`.

`OutcomeGate` dedupes against a small sliding sequence window — O(window)
memory, no unbounded seen-set — and `retry_with_backoff` wraps flaky delivery
callables with bounded exponential backoff (sleep injectable for tests).
"""
from __future__ import annotations

from typing import Callable, Optional, TypeVar

T = TypeVar("T")


class OutcomeGate:
    """Sliding-window sequence gate for outcome-echo batches.

    `offer(seq, batch)` returns the batch when it should be ingested and
    None when it must be discarded:

    - duplicates of a sequence number already accepted inside the window
      are dropped (the double-scatter bug this exists to prevent);
    - batches older than the window tail are dropped — they raced a
      restart or were retried past their usefulness, and accepting them
      could alias a recycled sequence number;
    - otherwise the batch is accepted (out-of-order within the window is
      fine: `ingest_outcomes` keeps per-page *last-write* semantics, and a
      slightly stale estimate update is still a valid observation).

    The window is a set of accepted sequence numbers pruned to the last
    `window` values below the high-water mark, so memory is O(window) no
    matter how long the stream runs.
    """

    def __init__(self, window: int = 64):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = int(window)
        self._seen: set = set()
        self._high = -1
        self.accepted = 0
        self.dropped_dup = 0
        self.dropped_stale = 0

    def offer(self, seq: int, batch: Optional[T]) -> Optional[T]:
        seq = int(seq)
        if seq < 0:
            raise ValueError("sequence numbers must be >= 0")
        if seq <= self._high - self.window:
            self.dropped_stale += 1
            return None
        if seq in self._seen:
            self.dropped_dup += 1
            return None
        self._seen.add(seq)
        if seq > self._high:
            self._high = seq
            floor = self._high - self.window
            self._seen = {s for s in self._seen if s > floor}
        self.accepted += 1
        return batch

    def state_dict(self) -> dict:
        return {
            "window": self.window,
            "high": self._high,
            "seen": sorted(self._seen),
        }

    @classmethod
    def from_state_dict(cls, sd: dict) -> "OutcomeGate":
        gate = cls(window=int(sd["window"]))
        gate._high = int(sd["high"])
        gate._seen = set(int(s) for s in sd["seen"])
        return gate


def retry_with_backoff(
    fn: Callable[[], T],
    *,
    max_attempts: int = 4,
    base_delay: float = 0.05,
    max_delay: float = 2.0,
    retry_on: tuple = (OSError, TimeoutError),
    sleep: Callable[[float], None] = None,
) -> T:
    """Call `fn` with bounded exponential backoff on transient errors.

    Retries only exceptions in `retry_on` (validation errors from
    `sched.errors` are not transient and propagate immediately); the final
    attempt's exception propagates. `sleep` is injectable so tests assert
    the backoff sequence without wall-clock time.
    """
    if max_attempts < 1:
        raise ValueError("max_attempts must be >= 1")
    if sleep is None:
        import time

        sleep = time.sleep
    delay = float(base_delay)
    for attempt in range(max_attempts):
        try:
            return fn()
        except retry_on:
            if attempt == max_attempts - 1:
                raise
            sleep(delay)
            delay = min(delay * 2.0, float(max_delay))
    raise AssertionError("unreachable")
