"""Sharded crawl scheduling over a device mesh (paper Section 5.2).

Pages are sharded across *all* mesh axes (a pure data decomposition — the
paper's state per page is O(1) and independent across pages). One scheduling
round is:

    local values (VPU / Pallas kernel)  ->  local top-k  ->
    all_gather of k candidates per shard (tiny)  ->  global top-k  ->
    local reset of winners.

Only the candidate exchange touches the interconnect: k * n_shards * 8 bytes
per round, independent of the page count — this is the paper's "only the
comparison between the pages with the top crawl values matters" made concrete.

Local value evaluation has four strategies, in increasing production-grade
order:

  * dense jnp series (`use_kernel=False`, no table) — oracle-grade;
  * exposure-table lookup (`table=...`) — App. G tier tables;
  * dense Pallas kernel (`use_kernel=True`) — values written to HBM, full
    `top_k` second pass;
  * **fused select** (`env_planes=...` from `kernels.layout.pack_shard`) —
    single pass, in-register values, per-block candidate buffers, the
    m-element value vector never materialized; exact (provably identical to
    dense top-k) via the candidate-overflow fallback in `kernels.select`.
    `thresh` (previous round's k-th value) and `bounds` (per-block optimistic
    bounds, e.g. `layout.asym_block_bounds` or `tiered.BlockBounds`) enable
    the App. G block skip. The fused path requires block-aligned shards:
    state length == n_blocks * block_rows * 128 with n_blocks divisible by
    the shard count.

The same step is used by the multi-pod dry-run at 2^30 pages on 512 devices.

NOTE: `sharded_crawl_step`'s flag-dispatched signature is the *legacy* entry
point, kept for the dry-run tooling and existing callers. New code should go
through `sched.backends`: a `SelectionBackend` object + the donated, jitted
`crawl_round` over a functional `RoundState` (which also carries per-shard
warm-start thresholds — the `thresh=` scalar here is single-shard-sound
only; see `backends.FusedBackend`).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import tables
from repro.core.state import PageState
from repro.core.values import DerivedEnv, Env, derive


def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map (new API) with a jax.experimental fallback (<= 0.4.x)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


class ShardedSchedState(NamedTuple):
    tau_elap: jax.Array   # (m,) f32, sharded over all mesh axes
    n_cis: jax.Array      # (m,) i32
    crawl_clock: jax.Array  # scalar step counter


def make_sharded_env(env: Env, mesh: Mesh, mu_total) -> DerivedEnv:
    """Derive env with a *global* importance normalizer so that per-shard
    normalization agrees across shards."""
    return derive(env, mu_total=mu_total)


def _local_values(tau_elap, n_cis, d: DerivedEnv, table: tables.ValueTable | None,
                  n_terms: int, use_kernel: bool):
    if table is not None:
        return tables.lookup_state(table, d, tau_elap, n_cis)
    if use_kernel:
        # Legacy dense-kernel path: packs the env per round (ops.crawl_value
        # is a one-shot API). Hot paths should pass env_planes instead —
        # the fused path packs once per parameter refresh.
        from repro.kernels import ops as kops

        return kops.crawl_value(tau_elap, n_cis, d, n_terms=n_terms)
    from repro.core.values import tau_eff, value_ncis

    return value_ncis(tau_eff(tau_elap, n_cis, d), d, n_terms=n_terms,
                      method="series")


def _axis_size(ax):
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(ax)
    return jax.lax.psum(1, ax)  # jax <= 0.4.x


def _shard_linear_index(axes):
    shard_lin = jnp.int32(0)
    mul = 1
    for ax in reversed(axes):
        shard_lin = shard_lin + jax.lax.axis_index(ax) * mul
        mul = mul * _axis_size(ax)
    return shard_lin


def host_shard_range(mesh: Mesh) -> tuple[int, int]:
    """The contiguous [s0, s1) range of linear shard indices whose devices
    are addressable from this process — the basis of the scheduler's
    `host_slice` (multi-host data path: each process touches only the pages
    of its own shards). The linearization is row-major over the mesh axes,
    matching `_shard_linear_index`, so shard s owns pages
    [s * m_shard, (s+1) * m_shard) of the flat padded page space.

    On a single-process mesh this is (0, mesh.size). Raises if this
    process's devices are not contiguous in the linearization — the
    host-local data path needs one contiguous page range per host (the
    default `jax.distributed` device assignment satisfies this)."""
    devs = mesh.devices.reshape(-1)
    pid = jax.process_index()
    mine = [i for i, d in enumerate(devs) if d.process_index == pid]
    if not mine:
        raise ValueError(
            f"process {pid} owns no devices of this mesh; every "
            "participating process must contribute devices")
    s0, s1 = mine[0], mine[-1] + 1
    if mine != list(range(s0, s1)):
        raise ValueError(
            f"process {pid}'s mesh devices occupy non-contiguous linear "
            f"shard slots {mine}; the host-local data path needs one "
            "contiguous page range per host — reorder the mesh devices")
    return s0, s1


def host_local_array(local, mesh: Mesh, spec: P) -> jax.Array:
    """Build a (possibly multi-process) global array from this process's
    local data. `local` holds exactly this process's addressable slice of
    the global array (for a single-process mesh, the whole array).

    This is THE device-put of the host-local data path: on a multi-process
    mesh each host materializes only its own shards
    (`jax.make_array_from_process_local_data`), so a feed or refresh batch
    never leaves the host that produced it; single-process meshes take the
    plain sharded `device_put`."""
    sharding = NamedSharding(mesh, spec)
    if jax.process_count() == 1:
        return jax.device_put(local, sharding)
    return jax.make_array_from_process_local_data(sharding, local)


def _global_winners(loc_v, loc_i, axes, m_local, k, k_dyn=None):
    """Candidate exchange + global top-k (shared by the dense and fused
    paths). loc_i are shard-local page indices. Returns (global_ids, values,
    local_idx) where local_idx holds each winner's shard-local index, or the
    out-of-bounds sentinel m_local for winners living on other shards — made
    for `.at[local_idx].set(..., mode="drop")` updates, so callers touching
    only the k winners (the macro-round scan) never materialize an m-sized
    mask.

    k_dyn: optional traced int32 budget under the static cap k. Winner slots
    >= k_dyn come back masked (id -1, value -inf); their local_idx resolves
    below local_start and lands on the m_local sentinel, so masked slots are
    dropped by the same `.at[...].set(mode="drop")` path as remote winners.
    Shard-local candidates already arrive masked at the *local* clamp
    (`kernels.select` k_dyn), but remasking here is what bounds the number
    of *global* winners: with per-shard clamps alone, S shards could jointly
    contribute more than k_dyn live candidates."""
    shard_lin = _shard_linear_index(axes)
    gids = loc_i.astype(jnp.int32) + shard_lin * m_local
    if k_dyn is not None:
        # Masked local slots carry id -1 from the select masking; keep them
        # -1 rather than shifting into another shard's id range.
        gids = jnp.where(loc_i < 0, -1, gids)
    # Tiny candidate exchange: (n_shards * k_loc) values + ids.
    all_v = loc_v
    all_g = gids
    for ax in axes:
        all_v = jax.lax.all_gather(all_v, ax, tiled=True)
        all_g = jax.lax.all_gather(all_g, ax, tiled=True)
    top_v, top_j = jax.lax.top_k(all_v, k)
    top_g = all_g[top_j]
    if k_dyn is not None:
        live = jnp.arange(k, dtype=jnp.int32) < k_dyn
        top_g = jnp.where(live, top_g, -1)
        top_v = jnp.where(live, top_v, -jnp.inf)
    local_start = shard_lin * m_local
    rel = top_g - local_start
    here = (rel >= 0) & (rel < m_local) & (top_g >= 0)
    idx = jnp.where(here, rel, m_local)
    return top_g, top_v, idx


def _global_topk(loc_v, loc_i, axes, m_local, k):
    """`_global_winners` + the per-shard crawl mask for the winners that
    live here (out-of-bounds indices are dropped, so non-local winners are
    no-ops)."""
    top_g, top_v, idx = _global_winners(loc_v, loc_i, axes, m_local, k)
    mask = jnp.zeros((m_local,), bool).at[idx].set(True, mode="drop")
    return top_g, top_v, mask


def sharded_select(
    state: ShardedSchedState,
    d: DerivedEnv | None,
    table: tables.ValueTable | None,
    mesh: Mesh,
    k: int,
    n_terms: int = 8,
    use_kernel: bool = False,
    k_local: int | None = None,
    env_planes: jax.Array | None = None,
    thresh: jax.Array | None = None,
    bounds: jax.Array | None = None,
):
    """Global top-k page selection. Returns (global_page_ids, values) replicated
    and a per-page crawl mask (sharded like the state).

    k_local: candidates contributed per shard. Default k (exact). With S
    shards, E[winners per shard] = k/S; k_local = c*k/S for small c is exact
    with overwhelming probability and cuts the candidate exchange by S/c —
    see EXPERIMENTS.md §Perf (the final top-k result is unchanged whenever no
    shard holds more than k_local winners).

    env_planes/thresh/bounds: fused-select path (module docstring). The local
    selection it produces is *exactly* `top_k(values, k_local)` — the
    overflow fallback in `kernels.select` guarantees it — so the global
    result is identical to the dense paths. NOTE: `thresh` here is a single
    replicated scalar compared against each shard's *local* k-th candidate;
    feeding the global k-th on a multi-shard mesh stays exact but drives
    low-value shards into the dense fallback every round. The per-shard
    threshold exchange lives in `sched.backends.FusedBackend` — use that for
    warm-started multi-shard rounds; pass None here.
    """
    axes = tuple(mesh.axis_names)
    pspec = P(axes)
    m = state.tau_elap.shape[0]
    n_shards = 1
    for ax_size in mesh.devices.shape:
        n_shards *= ax_size
    # A shard can contribute at most its own page count (large budgets on
    # small shards: local top_k over more entries than the shard holds
    # would be an error; padding pages score -inf and are harmless).
    k_loc = min(k_local or k, k, m // n_shards)

    if env_planes is not None:
        from repro.kernels import select as ksel

        n_blocks, _, block_rows, lanes = env_planes.shape
        assert m == n_blocks * block_rows * lanes, (
            "fused path needs block-aligned padded state "
            f"(m={m}, planes={env_planes.shape})"
        )
        assert n_blocks % n_shards == 0, (
            "fused path needs n_blocks divisible by the shard count"
        )
        # ... and at most its candidate-buffer capacity — the one shared
        # clamp rule (`select.shard_budget`).
        k_loc, _ = ksel.shard_budget(k, m // n_shards, n_blocks // n_shards,
                                     n_shards, k_local)
        if thresh is None:
            thresh = jnp.float32(-jnp.inf)
        if bounds is None:
            bounds = jnp.full((n_blocks,), jnp.inf, jnp.float32)
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"

        def shard_fn(tau_elap, n_cis, env_shard, bounds_shard, thresh_r):
            sel = ksel.fused_select_local(
                tau_elap, n_cis, env_shard, k_loc, thresh_r, bounds_shard,
                n_terms=n_terms, impl=impl, interpret=impl != "pallas",
            )
            m_local = tau_elap.shape[0]
            return _global_topk(sel.values, sel.ids, axes, m_local, k)

        fn = _shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(pspec, pspec, P(axes, None, None, None), P(axes), P()),
            out_specs=(P(), P(), pspec),
        )
        return fn(state.tau_elap, state.n_cis, env_planes, bounds,
                  jnp.asarray(thresh, jnp.float32))

    def shard_fn(tau_elap, n_cis, d_shard, table_shard):
        vals = _local_values(tau_elap, n_cis, d_shard, table_shard, n_terms,
                             use_kernel)
        m_local = tau_elap.shape[0]
        loc_v, loc_i = jax.lax.top_k(vals, k_loc)
        return _global_topk(loc_v, loc_i, axes, m_local, k)

    table_specs = tables.ValueTable(vals=P(axes, None), u_max=P()) if table is not None else None
    d_specs = DerivedEnv(*([pspec] * len(d)))
    fn = _shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(pspec, pspec, d_specs, table_specs),
        out_specs=(P(), P(), pspec),
    )
    return fn(state.tau_elap, state.n_cis, d, table)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "k", "n_terms", "use_kernel", "dt", "k_local"),
)
def sharded_crawl_step(
    state: ShardedSchedState,
    new_cis: jax.Array,
    d: DerivedEnv | None,
    table: tables.ValueTable | None,
    mesh: Mesh,
    k: int,
    dt: float,
    n_terms: int = 8,
    use_kernel: bool = False,
    k_local: int | None = None,
    env_planes: jax.Array | None = None,
    thresh: jax.Array | None = None,
    bounds: jax.Array | None = None,
):
    """One full scheduling round: select k pages globally, reset them, advance
    time, ingest externally-fed CIS counts. Returns (new_state, page_ids).

    With env_planes (fused path) the caller threads `thresh` across rounds:
    feed the previous round's k-th returned value (relaxed by a hysteresis
    factor) to skip provably-losing blocks; exactness is preserved for any
    thresh by the fallback."""
    top_g, top_v, mask = sharded_select(
        state, d, table, mesh, k, n_terms, use_kernel, k_local,
        env_planes, thresh, bounds,
    )
    tau = jnp.where(mask, 0.0, state.tau_elap) + dt
    n = jnp.where(mask, 0, state.n_cis) + new_cis
    new_state = ShardedSchedState(
        tau_elap=tau, n_cis=n, crawl_clock=state.crawl_clock + 1
    )
    return new_state, (top_g, top_v)


def sched_input_specs(m: int, mesh: Mesh, table_grid: int | None = None):
    """ShapeDtypeStructs + shardings for the dry-run scheduler step."""
    axes = tuple(mesh.axis_names)
    sh = NamedSharding(mesh, P(axes))
    sh_t = NamedSharding(mesh, P(axes, None))
    rep = NamedSharding(mesh, P())
    f = lambda shape, dt, s: jax.ShapeDtypeStruct(shape, dt, sharding=s)
    state = ShardedSchedState(
        tau_elap=f((m,), jnp.float32, sh),
        n_cis=f((m,), jnp.int32, sh),
        crawl_clock=f((), jnp.int32, rep),
    )
    new_cis = f((m,), jnp.int32, sh)
    d = DerivedEnv(*[f((m,), jnp.float32, sh) for _ in range(8)])
    table = None
    if table_grid:
        table = tables.ValueTable(
            vals=f((m, table_grid), jnp.float32, sh_t),
            u_max=f((), jnp.float32, rep),
        )
    return state, new_cis, d, table
