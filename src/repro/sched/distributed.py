"""Sharded crawl scheduling over a device mesh (paper Section 5.2).

Pages are sharded across *all* mesh axes (a pure data decomposition — the
paper's state per page is O(1) and independent across pages). One scheduling
round is:

    local values (VPU / Pallas kernel)  ->  local top-k  ->
    all_gather of k candidates per shard (tiny)  ->  global top-k  ->
    local reset of winners.

Only the candidate exchange touches the interconnect: k * n_shards * 8 bytes
per round, independent of the page count — this is the paper's "only the
comparison between the pages with the top crawl values matters" made concrete.

The same step is used by the multi-pod dry-run at 2^30 pages on 512 devices.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import tables
from repro.core.state import PageState
from repro.core.values import DerivedEnv, Env, derive


class ShardedSchedState(NamedTuple):
    tau_elap: jax.Array   # (m,) f32, sharded over all mesh axes
    n_cis: jax.Array      # (m,) i32
    crawl_clock: jax.Array  # scalar step counter


def make_sharded_env(env: Env, mesh: Mesh, mu_total) -> DerivedEnv:
    """Derive env with a *global* importance normalizer so that per-shard
    normalization agrees across shards."""
    return derive(env, mu_total=mu_total)


def _local_values(tau_elap, n_cis, d: DerivedEnv, table: tables.ValueTable | None,
                  n_terms: int, use_kernel: bool):
    if table is not None:
        return tables.lookup_state(table, d, tau_elap, n_cis)
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.crawl_value(tau_elap, n_cis, d, n_terms=n_terms)
    from repro.core.values import tau_eff, value_ncis

    return value_ncis(tau_eff(tau_elap, n_cis, d), d, n_terms=n_terms,
                      method="series")


def sharded_select(
    state: ShardedSchedState,
    d: DerivedEnv,
    table: tables.ValueTable | None,
    mesh: Mesh,
    k: int,
    n_terms: int = 8,
    use_kernel: bool = False,
    k_local: int | None = None,
):
    """Global top-k page selection. Returns (global_page_ids, values) replicated
    and a per-page crawl mask (sharded like the state).

    k_local: candidates contributed per shard. Default k (exact). With S
    shards, E[winners per shard] = k/S; k_local = c*k/S for small c is exact
    with overwhelming probability and cuts the candidate exchange by S/c —
    see EXPERIMENTS.md §Perf (the final top-k result is unchanged whenever no
    shard holds more than k_local winners).
    """
    axes = tuple(mesh.axis_names)
    pspec = P(axes)
    k_loc = min(k_local or k, k)

    def shard_fn(tau_elap, n_cis, d_shard, table_shard):
        vals = _local_values(tau_elap, n_cis, d_shard, table_shard, n_terms,
                             use_kernel)
        m_local = tau_elap.shape[0]
        loc_v, loc_i = jax.lax.top_k(vals, k_loc)
        # Global ids: shard offset + local index.
        shard_lin = jnp.int32(0)
        mul = 1
        for ax in reversed(axes):
            shard_lin = shard_lin + jax.lax.axis_index(ax) * mul
            mul = mul * jax.lax.axis_size(ax)
        gids = loc_i.astype(jnp.int32) + shard_lin * m_local
        # Tiny candidate exchange: (n_shards * k) values + ids.
        all_v = loc_v
        all_g = gids
        for ax in axes:
            all_v = jax.lax.all_gather(all_v, ax, tiled=True)
            all_g = jax.lax.all_gather(all_g, ax, tiled=True)
        top_v, top_j = jax.lax.top_k(all_v, k)
        top_g = all_g[top_j]
        # Per-shard crawl mask for the winners that live here.
        local_start = shard_lin * m_local
        rel = top_g - local_start
        here = (rel >= 0) & (rel < m_local)
        # Out-of-bounds indices are dropped, so non-local winners are no-ops.
        idx = jnp.where(here, rel, m_local)
        mask = jnp.zeros((m_local,), bool).at[idx].set(True, mode="drop")
        return top_g, top_v, mask

    table_specs = tables.ValueTable(vals=P(axes, None), u_max=P()) if table is not None else None
    d_specs = DerivedEnv(*([pspec] * len(d)))
    fn = jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(pspec, pspec, d_specs, table_specs),
        out_specs=(P(), P(), pspec),
        check_vma=False,
    )
    return fn(state.tau_elap, state.n_cis, d, table)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "k", "n_terms", "use_kernel", "dt", "k_local"),
)
def sharded_crawl_step(
    state: ShardedSchedState,
    new_cis: jax.Array,
    d: DerivedEnv,
    table: tables.ValueTable | None,
    mesh: Mesh,
    k: int,
    dt: float,
    n_terms: int = 8,
    use_kernel: bool = False,
    k_local: int | None = None,
):
    """One full scheduling round: select k pages globally, reset them, advance
    time, ingest externally-fed CIS counts. Returns (new_state, page_ids)."""
    top_g, top_v, mask = sharded_select(
        state, d, table, mesh, k, n_terms, use_kernel, k_local
    )
    tau = jnp.where(mask, 0.0, state.tau_elap) + dt
    n = jnp.where(mask, 0, state.n_cis) + new_cis
    new_state = ShardedSchedState(
        tau_elap=tau, n_cis=n, crawl_clock=state.crawl_clock + 1
    )
    return new_state, (top_g, top_v)


def sched_input_specs(m: int, mesh: Mesh, table_grid: int | None = None):
    """ShapeDtypeStructs + shardings for the dry-run scheduler step."""
    axes = tuple(mesh.axis_names)
    sh = NamedSharding(mesh, P(axes))
    sh_t = NamedSharding(mesh, P(axes, None))
    rep = NamedSharding(mesh, P())
    f = lambda shape, dt, s: jax.ShapeDtypeStruct(shape, dt, sharding=s)
    state = ShardedSchedState(
        tau_elap=f((m,), jnp.float32, sh),
        n_cis=f((m,), jnp.int32, sh),
        crawl_clock=f((), jnp.int32, rep),
    )
    new_cis = f((m,), jnp.int32, sh)
    d = DerivedEnv(*[f((m,), jnp.float32, sh) for _ in range(8)])
    table = None
    if table_grid:
        table = tables.ValueTable(
            vals=f((m, table_grid), jnp.float32, sh_t),
            u_max=f((), jnp.float32, rep),
        )
    return state, new_cis, d, table
