"""CrawlScheduler — the deployable service wrapper.

Holds the sharded page state, executes budgeted scheduling rounds, ingests CIS
feeds, and exposes the two production properties the paper highlights:

  * **elastic bandwidth** (App. D): `set_bandwidth` changes the per-round
    budget k (or round period) with *zero* recomputation — the greedy rule is
    self-adapting;
  * **decentralized parameter refresh**: per-page (Delta, mu, lam, nu) updates
    touch only the owning shard (value tables are rebuilt shard-locally).

Fault tolerance: the entire scheduler state is two arrays; `state_dict()` /
`load_state_dict()` plug into repro.checkpoint for atomic, sharded, resumable
snapshots. Loss of a shard loses only the staleness clocks of its pages (they
re-initialize as "just crawled" — conservative under-crawling that self-heals)
while the budget re-normalizes to the surviving shard count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import tables
from repro.core.values import Env, derive
from repro.sched.distributed import ShardedSchedState, sharded_crawl_step


class CrawlScheduler:
    def __init__(
        self,
        env: Env,
        mesh: Mesh,
        bandwidth: float,
        round_period: float = 1.0,
        n_terms: int = 8,
        table_grid: int | None = 128,
        use_kernel: bool = False,
    ):
        self.mesh = mesh
        self.axes = tuple(mesh.axis_names)
        self.round_period = float(round_period)
        self.bandwidth = float(bandwidth)
        self.n_terms = n_terms
        self.use_kernel = use_kernel
        sh = NamedSharding(mesh, P(self.axes))
        self.m = env.m
        env = jax.device_put(env, sh)
        self.d = derive(env)
        self.table = (
            tables.build_ncis_table(self.d, n_terms=n_terms, n_grid=table_grid)
            if table_grid
            else None
        )
        self.state = ShardedSchedState(
            tau_elap=jax.device_put(jnp.zeros((self.m,), jnp.float32), sh),
            n_cis=jax.device_put(jnp.zeros((self.m,), jnp.int32), sh),
            crawl_clock=jnp.int32(0),
        )

    @property
    def k_per_round(self) -> int:
        return max(1, int(round(self.bandwidth * self.round_period)))

    def set_bandwidth(self, bandwidth: float) -> None:
        """App. D: adapting to a new budget is just a new k — no re-solve."""
        self.bandwidth = float(bandwidth)

    def ingest_and_schedule(self, new_cis: jax.Array):
        """One round: ingest the CIS feed counts, pick k pages to crawl."""
        self.state, (page_ids, values) = sharded_crawl_step(
            self.state,
            new_cis,
            self.d,
            self.table,
            self.mesh,
            self.k_per_round,
            self.round_period,
            self.n_terms,
            self.use_kernel,
        )
        return page_ids, values

    def state_dict(self):
        return {
            "tau_elap": self.state.tau_elap,
            "n_cis": self.state.n_cis,
            "crawl_clock": self.state.crawl_clock,
        }

    def load_state_dict(self, sd) -> None:
        sh = NamedSharding(self.mesh, P(self.axes))
        self.state = ShardedSchedState(
            tau_elap=jax.device_put(sd["tau_elap"], sh),
            n_cis=jax.device_put(sd["n_cis"], sh),
            crawl_clock=jnp.asarray(sd["crawl_clock"]),
        )
