"""CrawlScheduler — the deployable service wrapper.

Holds one functional `RoundState` (page state + selection-backend state,
see `sched.backends`), executes budgeted scheduling rounds, ingests CIS
feeds and crawl results, and exposes the production properties the paper
highlights:

  * **elastic bandwidth** (App. D): `set_bandwidth` changes the per-round
    budget k (or round period) with *zero* recomputation — the greedy rule is
    self-adapting;
  * **decentralized parameter refresh** (§5.2): `update_pages` scatters new
    per-page (Delta, mu, lam, nu) into the backend state touching only the
    updated rows — for the fused backend, a block-granular repack of the
    touched `PageShard` plane columns + bounds, never a full `pack_shard`;
  * **closed estimation loop** (App. E): `ingest_crawl_results` fits the
    CIS-quality MLE (`core.estimation.fit_mle_pages`) on crawl logs and
    feeds the refreshed parameters straight back through `update_pages`;
  * **host-local data path** (§5.2's decentralization, multi-process): the
    scheduler's `host_slice` (the page range whose shards live on this
    process) threads through feed conversion, parameter refresh, and
    crawl-log ingestion — each host converts only its local feed rows
    (per-shard `SparseFeeds` + the `feed_cap` capacity contract, so a hot
    shard re-jits no one), repacks only its local plane columns
    (collective-free shard_map; `update_cap`), and estimates only its own
    crawl logs. See README "Multi-host deployment";
  * **adaptive skip control** (App. G): with
    `FusedBackend(adaptive_bounds=True)` the per-block bounds refresh from
    each round's block maxima and the warm-start hysteresis adapts per
    shard, all inside the jitted round (`sched.backends`); the scheduler
    additionally shrinks the candidate-buffer depth host-side from the
    realized winner concentration (`adaptive_cand`).

Selection strategies are `SelectionBackend` objects (`sched.backends`):
`DenseBackend`, `TableBackend` (default), `KernelBackend`, `FusedBackend`
(packed planes + single-pass candidate select with per-shard threshold
warm-start — enabled on any mesh size; selection stays provably identical
to dense top-k). The legacy `use_kernel=`/`use_fused=`/`table_grid=` kwargs
are deprecation shims that map onto those backends.

Fault tolerance: the entire scheduler state is one pytree; `state_dict()` /
`load_state_dict()` plug into repro.checkpoint for atomic, sharded,
resumable snapshots, and now include the backend state (per-shard
thresholds, block bounds) so a restart resumes warm — the first post-restore
round skips cold blocks instead of paying a full dense pass. NOTE: rounds
donate the live buffers; `jax.device_get` a `state_dict()` before running
further rounds if you intend to keep it.
"""
from __future__ import annotations

import math
import warnings

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import estimation
from repro.core.values import DerivedEnv, Env, derive
from repro.sched import backends as be
from repro.sched.degraded import OutcomeGate
from repro.sched.distributed import (
    ShardedSchedState,
    host_local_array,
    host_shard_range,
)
from repro.sched.errors import (
    CapacityExceeded,
    FeedDtypeError,
    FeedValidationError,
)

# Legacy constant, re-exported for back-compat (now lives per backend:
# `FusedBackend.hysteresis`).
THRESH_HYSTERESIS = be.DEFAULT_HYSTERESIS


def _legacy_backend(n_terms, table_grid, use_kernel, use_fused, block_rows):
    """Map the pre-backend flag soup onto a SelectionBackend."""
    if use_fused:
        return be.FusedBackend(n_terms=n_terms, block_rows=block_rows)
    if use_kernel:
        return be.KernelBackend(n_terms=n_terms)
    if table_grid:
        return be.TableBackend(n_terms=n_terms, table_grid=table_grid)
    return be.DenseBackend(n_terms=n_terms)


class CrawlScheduler:
    def __init__(
        self,
        env: Env,
        mesh: Mesh,
        bandwidth: float,
        round_period: float = 1.0,
        n_terms: int = 8,
        table_grid: int | None = 128,
        use_kernel: bool = False,
        use_fused: bool = False,
        block_rows: int | None = None,
        backend: be.SelectionBackend | None = None,
        feed_cap: int | None = None,
        update_cap: int | None = None,
        outcome_cap: int | None = None,
        k_max: int | None = None,
        emission: str = "fixed",
        importance: bool = False,
        importance_prior=None,
        importance_decay: float = 0.9,
        request_cap: int | None = None,
    ):
        if backend is None:
            if use_kernel or use_fused:
                warnings.warn(
                    "use_kernel=/use_fused= are deprecated; pass "
                    "backend=KernelBackend(...)/FusedBackend(...) instead",
                    DeprecationWarning, stacklevel=2,
                )
            backend = _legacy_backend(n_terms, table_grid, use_kernel,
                                      use_fused, block_rows)
        self.backend = backend
        self.mesh = mesh
        self.axes = tuple(mesh.axis_names)
        self.round_period = float(round_period)
        self.bandwidth = float(bandwidth)
        self.m = env.m
        # Per-host capacity contracts (multi-host data path): feed_cap is
        # the static COO width per (round, shard) cell of a SparseFeeds
        # batch, update_cap the static per-shard width of an update_pages
        # batch. Fixing them makes every compiled shape independent of feed
        # / refresh content, so a hot shard on one host can never force a
        # re-jit — on any host. None = derive a pow2 bucket per batch
        # (single-process convenience; multi-process meshes require
        # explicit caps, since all hosts must agree on the static shapes).
        # outcome_cap plays the same role for the crawl-outcome batches of
        # the streaming-estimation loop (`run_rounds(feeds, outcomes=...)`).
        self.feed_cap = feed_cap
        self.update_cap = update_cap
        self.outcome_cap = outcome_cap
        # Bandwidth-axis capacity contract (elastic bandwidth; fused macro
        # path): k_max pins the static selection width so per-round budgets
        # and mid-flight `set_bandwidth` changes are pure data — same
        # pattern as feed_cap. emission: "fixed" (legacy integer k every
        # round) | "smooth" (token-bucket spike-free emission at the exact
        # fractional rate bandwidth * round_period).
        self._init_bandwidth_axis(k_max, emission)
        # Host-side mirror of the device round counter
        # (`RoundState.crawl_clock`), maintained without any device sync so
        # drivers can date crawls (e.g. to reconstruct per-crawl interval
        # lengths for the streaming-estimation outcome echo): a
        # `run_rounds` batch covers rounds [rounds_completed,
        # rounds_completed + R) as counted BEFORE the call.
        self.rounds_completed = 0
        self.round, binit = be.init_round(backend, env, mesh)
        self.m_state = binit.m_state
        # Request-driven importance (sched.importance): the serve front's
        # EWMA plane + raw-delta/prior columns ride FusedState.req.
        # request_cap is the serve/log batches' capacity contract (same
        # role as feed_cap). Attached BEFORE the donation-commit below so
        # the first run_rounds signature is already the request-carrying
        # one.
        self._init_request_axis(importance, importance_decay, request_cap)
        # Process-local shard/page range (the `host_slice` view): on a
        # multi-process mesh this process's devices own the contiguous
        # shard range [s0, s1) and therefore pages
        # [s0 * m_shard, s1 * m_shard) of the flat padded page space.
        self._host_shards = host_shard_range(mesh)
        if importance:
            self._attach_request_plane(env.delta, importance_prior)
        # Host-side conveniences: the derived (padded) env oracle and the
        # frozen importance normalizer (see backends module docstring). For
        # dense/table backends `d`/`table` read through to the live backend
        # state; the fused oracle copy is maintained by update_pages.
        self.mu_total = jnp.sum(jnp.asarray(env.mu))
        self._d_oracle = binit.d if isinstance(self.round.backend,
                                               be.FusedState) else None
        self._d_pending = []  # (ids, d_new) updates not yet folded into it
        # Donation-normalize the freshly built state (commit the clock,
        # canonicalize every leaf's sharding) so the first run_rounds
        # call's compilation is the only one — see `backends.commit_state`.
        self.round = be.commit_state(self.round)

    @classmethod
    def from_local_env(
        cls,
        env_local: Env,
        mesh: Mesh,
        bandwidth: float,
        *,
        m: int,
        round_period: float = 1.0,
        backend: be.SelectionBackend | None = None,
        feed_cap: int | None = None,
        update_cap: int | None = None,
        outcome_cap: int | None = None,
        k_max: int | None = None,
        emission: str = "fixed",
        importance: bool = False,
        importance_prior=None,
        importance_decay: float = 0.9,
        request_cap: int | None = None,
    ) -> "CrawlScheduler":
        """Host-local construction (the elastic-lifecycle cold start): each
        process supplies ONLY its `host_slice` of the raw env — the raw
        pages [s0 * m_shard, min(s1 * m_shard, m)) its devices will own —
        plus the corpus size `m`. No host ever materializes the global env;
        the one global quantity construction needs is the frozen importance
        normalizer mu_total = sum(mu), computed here from per-shard partial
        sums via a single psum-shaped reduction over the assembled sharded
        vector (fully replicated result, readable on every host).

        Fused backend only (the production path). The resulting scheduler
        is state-identical to `__init__` shard by shard, except that
        mu_total may differ from the global summation order in the last ulp
        — greedy selection is scale-invariant in mu_total, so selections
        match regardless — and the dense `.d` oracle does not exist (its
        accessor raises). Restore a checkpoint on top with
        `load_state_dict` to rejoin a running fleet (README "Fault
        tolerance & recovery")."""
        from repro.kernels import layout

        backend = backend if backend is not None else be.FusedBackend()
        if not isinstance(backend, be.FusedBackend):
            raise ValueError(
                "from_local_env supports FusedBackend only: host-local "
                "construction needs the packed-plane state layout"
            )
        self = cls.__new__(cls)
        self.backend = backend
        self.mesh = mesh
        self.axes = tuple(mesh.axis_names)
        self.round_period = float(round_period)
        self.bandwidth = float(bandwidth)
        self.m = int(m)
        self.feed_cap = feed_cap
        self.update_cap = update_cap
        self.outcome_cap = outcome_cap
        self._init_bandwidth_axis(k_max, emission)
        self._init_request_axis(importance, importance_decay, request_cap)
        self.rounds_completed = 0
        self._host_shards = host_shard_range(mesh)
        block_rows = backend.block_rows or layout.DEFAULT_BLOCK_ROWS
        m_state = layout.padded_size(m, block_rows, n_shards=mesh.size)
        m_shard = m_state // mesh.size
        s0, s1 = self._host_shards
        lo, hi = s0 * m_shard, s1 * m_shard
        expect = max(0, min(hi, self.m) - lo)
        if env_local.m != expect:
            raise ValueError(
                f"env_local must cover exactly this host's raw page range "
                f"[{lo}, {min(hi, self.m)}) = {expect} pages; got "
                f"{env_local.m}"
            )
        # THE one collective of construction: per-shard partial mu sums,
        # assembled into a sharded (n_shards,) vector whose global sum is
        # fully replicated — every host reads the same scalar without ever
        # holding the global mu.
        mu_pad = np.zeros((hi - lo,), np.float32)
        mu_pad[:expect] = np.asarray(env_local.mu, np.float32)[:expect]
        per_shard = mu_pad.reshape(s1 - s0, m_shard).sum(
            axis=1, dtype=np.float32)
        total = host_local_array(per_shard, mesh, P(self.axes))
        self.mu_total = jnp.float32(np.asarray(jnp.sum(total)))
        self.m_state, bstate = backend.init_local(
            env_local, mesh, m=self.m, host_shards=(s0, s1),
            mu_total=self.mu_total)
        self.round = be.RoundState(
            tau_elap=host_local_array(
                np.zeros((hi - lo,), np.float32), mesh, P(self.axes)),
            n_cis=host_local_array(
                np.zeros((hi - lo,), np.int32), mesh, P(self.axes)),
            crawl_clock=jnp.int32(0),
            backend=bstate,
        )
        if importance:
            # Host-local attach: env_local's columns ARE this host's range.
            self._attach_request_plane(
                env_local.delta, importance_prior, local=True)
        # No dense oracle under host-local construction (`.d` raises).
        self._d_oracle = None
        self._d_pending = []
        # Same donation-commit as `__init__`: the first run_rounds call's
        # compilation must be the only one.
        self.round = be.commit_state(self.round)
        return self

    # -- legacy views ------------------------------------------------------
    @property
    def d(self) -> DerivedEnv:
        """Derived-env oracle view. For the fused backend (whose state holds
        packed planes, not a DerivedEnv) pending `update_pages` scatters are
        folded in lazily here, so production refresh loops that never read
        `.d` pay nothing for it."""
        b = self.round.backend
        if hasattr(b, "d"):
            return b.d
        if self._d_oracle is None:
            raise RuntimeError(
                "the dense derived-env oracle is unavailable under "
                "host-local construction (from_local_env) and after an "
                "importance fold (fold_importance rewrites the device mu): "
                "read the packed planes instead"
            )
        for ids, d_new in self._d_pending:
            self._d_oracle = DerivedEnv(
                *[f.at[ids].set(n.astype(f.dtype))
                  for f, n in zip(self._d_oracle, d_new)]
            )
        self._d_pending.clear()
        return self._d_oracle

    @property
    def table(self):
        b = self.round.backend
        return b.table if isinstance(b, be.TableState) else None

    @property
    def state(self) -> ShardedSchedState:
        """Page state as the legacy ShardedSchedState view."""
        return ShardedSchedState(
            tau_elap=self.round.tau_elap,
            n_cis=self.round.n_cis,
            crawl_clock=self.round.crawl_clock,
        )

    # -- the host-local view (multi-host data path) ------------------------
    @property
    def n_shards(self) -> int:
        return self.mesh.size

    @property
    def m_shard(self) -> int:
        """Pages per shard of the flat padded page space."""
        return self.m_state // self.n_shards

    @property
    def host_slice(self) -> slice:
        """The process-local page range [lo, hi) in the padded page space.

        Single-process meshes see the whole corpus (`slice(0, m_state)`).
        On a multi-process mesh this is the contiguous range of pages whose
        state shards live on this process's devices; the data path —
        `_sparse_feed_batch`, `update_pages`, `ingest_crawl_results` — is
        threaded through it, so each host converts/applies only its own
        range and no feed or refresh bytes ever cross hosts."""
        s0, s1 = self._host_shards
        return slice(s0 * self.m_shard, s1 * self.m_shard)

    @property
    def is_multiprocess(self) -> bool:
        s0, s1 = self._host_shards
        return (s1 - s0) != self.n_shards

    # -- bandwidth ---------------------------------------------------------
    def _init_bandwidth_axis(self, k_max: int | None, emission: str) -> None:
        if emission not in ("fixed", "smooth"):
            raise ValueError(
                f"emission must be 'fixed' or 'smooth', got {emission!r}")
        if k_max is not None and k_max < 1:
            raise ValueError(f"k_max must be >= 1, got {k_max}")
        self.k_max = k_max
        self.emission = emission

    @property
    def k_per_round(self) -> int:
        # A budget above the shard size just means "crawl everything".
        # Fixed-emission rounding: the fractional part of
        # bandwidth * round_period is LOST here (2.5 crawls/round emits 2
        # forever) — emission="smooth" folds it into the token bucket
        # instead.
        k = max(1, int(round(self.bandwidth * self.round_period)))
        return min(k, self.m)

    @property
    def k_cap(self) -> int:
        """The static selection width of the elastic-bandwidth paths — the
        k_max cap contract (`run_rounds` budgets= / emission="smooth"):
        every compiled round selects at this width and masks down to the
        round's dynamic budget, so budget values and rate changes are pure
        data. With an explicit k_max the cap (and thus every compiled
        shape) is bandwidth-independent; without one it follows the current
        bandwidth — ceil of the rate under smoothing, since the bucket
        emits up to ceil(rate) on overflow rounds — and a `set_bandwidth`
        past the implied cap re-jits once, exactly like an over-`feed_cap`
        batch would."""
        if self.k_max is not None:
            return min(self.k_max, self.m)
        if self.emission == "smooth":
            return min(max(1, math.ceil(self.bandwidth * self.round_period)),
                       self.m)
        return self.k_per_round

    @property
    def _smooth_rate(self) -> float:
        """Crawls per round of the smooth emission mode, checked against
        the cap contract (a rate whose ceil exceeds k_cap cannot be
        realized — the bucket would grow without bound)."""
        rate = self.bandwidth * self.round_period
        if math.ceil(rate) > self.k_cap:
            if self.k_cap < self.m:
                raise CapacityExceeded(
                    f"bandwidth * round_period = {rate:g} crawls/round is "
                    f"over the k_max contract ({self.k_cap}); raise k_max "
                    "(one re-jit) or lower the bandwidth"
                )
            # Cap == corpus: a higher rate just means "crawl everything".
            rate = float(self.k_cap)
        return rate

    def _ensure_emit_residue(self) -> None:
        """Attach the token-bucket residue plane (`FusedState.emit_res`,
        one f32 per shard, identical replicated copies) the first time the
        smooth emission path runs. Lazy so fixed-emission schedulers —
        and every checkpoint they ever wrote — keep a byte-identical
        state tree; `None` is an empty pytree, so off-path jit signatures
        don't change either (same trick as the `est` leaf)."""
        bst = self.round.backend
        if bst.emit_res is not None:
            return
        s0, s1 = host_shard_range(self.mesh)
        res = host_local_array(
            np.zeros(s1 - s0, np.float32), self.mesh, P(self.axes))
        self.round = dataclasses.replace(
            self.round, backend=bst._replace(emit_res=res))

    def _ensure_stale_plane(self) -> None:
        """Attach the degraded-mode staleness plane (`FusedState.stale`,
        one i32 rounds-since-last-CIS counter per block) to a scheduler
        constructed without `degraded=True` — needed when restoring a
        degraded-mode checkpoint into it. Same lazy-attach trick as
        `_ensure_emit_residue`: `None` is an empty pytree, so schedulers
        that never go near degraded mode keep byte-identical state trees
        and jit signatures."""
        bst = self.round.backend
        if bst.stale is not None:
            return
        s0, s1 = host_shard_range(self.mesh)
        nb_shard = bst.env_planes.shape[0] // self.n_shards
        stale = host_local_array(
            np.zeros((s1 - s0) * nb_shard, np.int32), self.mesh,
            P(self.axes))
        self.round = dataclasses.replace(
            self.round, backend=bst._replace(stale=stale))

    # -- request-driven importance (sched.importance) ----------------------
    def _init_request_axis(self, importance: bool, decay: float,
                           request_cap: int | None) -> None:
        if not (0.0 < decay <= 1.0):
            raise ValueError(
                f"importance_decay must be in (0, 1], got {decay}")
        if request_cap is not None and request_cap < 1:
            raise ValueError(
                f"request_cap must be >= 1, got {request_cap}")
        if importance and not isinstance(self.backend, be.FusedBackend):
            raise ValueError(
                "importance=True requires FusedBackend: the request plane "
                "rides the packed-plane state (FusedState.req)"
            )
        self.importance_decay = float(decay)
        self.request_cap = request_cap

    def _attach_request_plane(self, delta, prior, *, local=False) -> None:
        """Attach the request-importance planes (`FusedState.req`) at
        construction: the EWMA column zeroed, the raw per-page change rate
        and link prior stashed host-locally (pad fills matching
        `importance.init_req`: delta 1.0, prior 0.0; prior=None is the
        uniform 1.0 prior). `local=True` takes `delta`/`prior` as this
        host's raw range (the `from_local_env` contract); otherwise they
        are the global raw columns and each host slices its own range —
        either way no env bytes cross hosts."""
        from repro.sched import importance as imp

        bst = self.round.backend
        lo, hi = self.host_slice.start, self.host_slice.stop
        delta = np.asarray(delta, np.float32).reshape(-1)
        if prior is not None:
            prior = np.asarray(prior, np.float32).reshape(-1)
        if not local:
            delta = delta[lo:min(hi, self.m)]
            prior = None if prior is None else prior[lo:min(hi, self.m)]
        if prior is None:
            prior = np.ones(delta.shape, np.float32)
        width = hi - lo

        def col(raw, fill):
            out = np.full((width,), fill, np.float32)
            out[:raw.shape[0]] = raw
            return host_local_array(out, self.mesh, P(self.axes))

        req = imp.ReqState(
            ewma=col(np.zeros(0, np.float32), 0.0),
            delta=col(delta, 1.0),
            prior=col(prior, 0.0),
            valid=col(np.ones(delta.shape, np.float32), 0.0),
        )
        self.round = dataclasses.replace(
            self.round, backend=bst._replace(req=req))

    def _ensure_request_plane(self) -> None:
        """Attach an all-default request plane (zero EWMA, unit delta, zero
        prior) to a scheduler constructed without `importance=True` — the
        restore-alignment hook for request-plane checkpoints, same lazy
        trick as `_ensure_emit_residue`/`_ensure_stale_plane`. The leaf
        VALUES only matter for their shape/dtype/sharding here: the restore
        path overwrites them from the snapshot."""
        bst = self.round.backend
        if bst.req is not None:
            return
        from repro.sched import importance as imp

        s0, s1 = host_shard_range(self.mesh)
        width = (s1 - s0) * self.m_shard

        def col(fill):
            return host_local_array(
                np.full((width,), fill, np.float32), self.mesh,
                P(self.axes))

        req = imp.ReqState(ewma=col(0.0), delta=col(1.0), prior=col(0.0),
                           valid=col(0.0))
        self.round = dataclasses.replace(
            self.round, backend=bst._replace(req=req))

    def _req_state(self):
        bst = self.round.backend
        if not isinstance(bst, be.FusedState) or bst.req is None:
            raise RuntimeError(
                "the request-importance plane is absent — construct the "
                "scheduler with importance=True (FusedBackend) or restore "
                "a request-plane checkpoint"
            )
        return bst

    def _route_requests(self, page_ids, counts):
        """Route a host's raw request batch to per-shard COO rows — the
        request-path twin of `_sparse_feed_batch`: occurrence-wise (NO
        dedup: duplicate ids are legitimate repeat traffic, and the
        scatter-add in `importance.log_batch` accumulates them; keeping the
        row order also preserves the permutation the serve path needs to
        reassemble per-request answers). Returns device (n_shards, cap)
        global-id/count arrays with the -1 padding sentinel, plus the
        (local_mask, local_shard, pos) routing map. Rows for pages outside
        this host's range are dropped from the device arrays (their
        `local_mask` is False): a host logs and answers for its own pages;
        cross-host requests are the upstream router's job (see README).
        Capacity: `request_cap` pins the static batch width per shard
        (same contract as feed_cap)."""
        ids = np.asarray(page_ids).reshape(-1)
        if not np.issubdtype(ids.dtype, np.integer):
            raise FeedValidationError(
                f"page ids must be integers, got dtype {ids.dtype}")
        if ids.size and (ids.min() < 0 or ids.max() >= self.m):
            raise FeedValidationError(
                f"request ids must lie in [0, {self.m}); got range "
                f"[{ids.min()}, {ids.max()}]")
        if counts is None:
            cnt = np.ones(ids.shape, np.float32)
        else:
            cnt = np.asarray(counts, np.float32).reshape(-1)
            if cnt.shape != ids.shape:
                raise FeedValidationError(
                    f"counts shape {cnt.shape} != ids shape {ids.shape}")
        s0, s1 = self._host_shards
        n_loc = s1 - s0
        shard = ids // self.m_shard
        local_mask = (shard >= s0) & (shard < s1)
        ids_l = ids[local_mask]
        cnt_l = cnt[local_mask]
        shard_l = (shard[local_mask] - s0).astype(np.int64)
        per_shard = np.bincount(shard_l, minlength=n_loc)
        need = int(per_shard.max()) if ids_l.size else 0
        cap = self._resolve_cap(
            max(1, need), self.request_cap, "request_cap",
            "a request batch routes {need} rows to one shard")
        # Occurrence index of each row within its shard bucket (stable, so
        # a page's repeat requests keep their arrival order).
        order = np.argsort(shard_l, kind="stable")
        offsets = np.concatenate(
            [[0], np.cumsum(per_shard)[:-1]]).astype(np.int64)
        pos = np.empty(ids_l.shape, np.int64)
        pos[order] = np.arange(ids_l.size, dtype=np.int64) \
            - offsets[shard_l[order]]
        ids_arr = np.full((n_loc, cap), -1, np.int32)
        cnt_arr = np.zeros((n_loc, cap), np.float32)
        ids_arr[shard_l, pos] = ids_l.astype(np.int32)
        cnt_arr[shard_l, pos] = cnt_l
        return (
            host_local_array(ids_arr, self.mesh, P(self.axes, None)),
            host_local_array(cnt_arr, self.mesh, P(self.axes, None)),
            (local_mask, shard_l, pos),
        )

    def log_requests(self, page_ids, counts=None) -> None:
        """Log one batch of user requests into the EWMA importance plane:
        route host-locally, then one collective-free donated dispatch
        (`importance.log_batch` — every page decays once, requested pages
        gain their counts). Hosts log at independent cadences; totals only
        meet at `fold_importance`. No host sync, no device readback."""
        from repro.sched import importance as imp

        bst = self._req_state()
        ids_dev, cnt_dev, _ = self._route_requests(page_ids, counts)
        req = imp.log_batch(bst.req, ids_dev, cnt_dev,
                            mesh=self.mesh, decay=self.importance_decay)
        # Re-commit: the shard_map output shardings differ from the
        # canonical post-round objects as Python objects, and the jit cache
        # keys on objects — without this, the next run_rounds would compile
        # a second (bit-identical) signature. See `backends.commit_state`.
        self.round = be.commit_state(dataclasses.replace(
            self.round, backend=bst._replace(req=req)))

    def serve_requests(self, page_ids, counts=None, *, log=True,
                       sync=True):
        """Answer a request batch with the model-posterior freshness
        probability per page, P(no change since last crawl | tau, n CIS)
        = exp(-alpha * tau_eff) — the exact belief the value kernel crawls
        by. With `log` (the default) the serve IS a request: the same
        device dispatch applies the EWMA step, so serving and logging stay
        one program. `sync=True` returns a host float32 array aligned with
        `page_ids` (NaN for pages outside this host's range — the router's
        job); `sync=False` returns the raw device (n_shards, cap) answers
        plus the routing map, deferring any transfer (the bench's
        zero-host-sync mode, and what `serve.requests.RequestFront`
        batches on)."""
        from repro.sched import importance as imp

        bst = self._req_state()
        ids_dev, cnt_dev, route = self._route_requests(page_ids, counts)
        req, p = imp.serve_batch(
            bst.req, self.round.tau_elap, self.round.n_cis,
            bst.env_planes, ids_dev, cnt_dev,
            mesh=self.mesh, decay=self.importance_decay, log=log)
        # Re-commit so the next round reuses its compiled signature (see
        # log_requests).
        self.round = be.commit_state(dataclasses.replace(
            self.round, backend=bst._replace(req=req)))
        if not sync:
            return p, route
        local_mask, shard_l, pos = route
        out = np.full(local_mask.shape, np.nan, np.float32)
        s0, _ = self._host_shards
        if not self.is_multiprocess:
            out[local_mask] = np.asarray(p)[shard_l, pos]
            return out
        # Multi-process: read only this host's addressable shard rows.
        p_loc = np.concatenate(
            [np.asarray(sh.data) for sh in sorted(
                p.addressable_shards,
                key=lambda sh: sh.index[0].start or 0)], axis=0)
        out[local_mask] = p_loc[shard_l, pos]
        return out

    def fold_importance(self, source=None):
        """Fold the live request planes into the packed `MU_T` plane and
        re-anchor the frozen normalizer (`importance.fold_into_planes`):
        after this, selection crawls by the blended request-driven mu. The
        new mu_total arrives as a fully replicated device scalar and is
        assigned WITHOUT a readback (later `update_pages` derivations
        consume it as a traced operand). All hosts must fold together —
        the fold's psum is its one collective, like `run_rounds`. The
        dense `.d` oracle (when it exists) is dropped: it describes the
        construction-time mu, not the blend. Returns the new mu_total."""
        from repro.sched import importance as imp

        if source is None:
            source = imp.REQUEST_EWMA
        bst = self._req_state()
        bst2, mu_total = imp.fold_into_planes(
            bst, mesh=self.mesh, source=source)
        # Re-commit so the next round reuses its compiled signature (see
        # log_requests): without it a fold would cost one (bit-identical)
        # recompile of the macro round.
        self.round = be.commit_state(
            dataclasses.replace(self.round, backend=bst2))
        self.mu_total = mu_total
        self._d_oracle = None
        self._d_pending = []
        return mu_total

    def set_bandwidth(self, bandwidth: float) -> None:
        """App. D: adapting to a new budget is just a new k — no re-solve.
        Under the elastic paths (emission="smooth" or explicit budget
        vectors at a pinned k_max) this is a pure DATA update: the new rate
        rides the already-compiled macro-round as a traced operand, with
        zero recompiles (the adaptive candidate-depth machinery keeps its
        floor at k_cap, which does not move). Legacy fixed-emission rounds
        re-jit on a changed k_per_round, as before."""
        self.bandwidth = float(bandwidth)

    # -- the round ---------------------------------------------------------
    def _feed_widths(self) -> tuple[int, ...]:
        """Accepted per-round feed widths: the full corpus (m), pre-padded
        (m_state), or — on a multi-process mesh — this host's local page
        range (the host-local feed contract)."""
        lo, hi = self.host_slice.start, self.host_slice.stop
        if self.is_multiprocess:
            return (self.m, self.m_state, hi - lo)
        return (self.m, self.m_state)

    def _pad_feed(self, new_cis: jax.Array) -> jax.Array:
        """Validate + zero-pad a per-page feed to the packed state size (the
        one shared padding path). A feed must cover exactly the corpus
        (length m), be pre-padded (length m_state), or — multi-process —
        cover exactly this host's local range; anything else is an error —
        a longer feed would silently credit its tail counts to padding
        pages, a shorter one would starve real pages. CIS counts are
        integral by definition, and the round ADDS the feed to the donated
        int32 n_cis state: a float feed would silently promote it to f32
        and break the donated-buffer dtype contract on the next round, so
        non-integer dtypes are rejected (bool counts are cast).

        On a multi-process mesh the returned array is built from this
        host's slice only (`distributed.host_local_array`): a full-width
        feed is sliced to the local range first, so no feed bytes cross
        hosts either way."""
        from repro.kernels import layout

        new_cis = jnp.asarray(new_cis)
        if not (jnp.issubdtype(new_cis.dtype, jnp.integer)
                or new_cis.dtype == jnp.bool_):
            raise FeedDtypeError(
                f"new_cis must have an integer dtype, got {new_cis.dtype}: "
                "CIS counts are integral, and a float feed would promote "
                "the donated int32 n_cis state to f32"
            )
        n = new_cis.shape[0]
        if n not in self._feed_widths():
            raise FeedValidationError(
                f"new_cis has {n} entries but the scheduler holds {self.m} "
                f"pages ({self.m_state} padded); feed one count per page"
            )
        if not self.is_multiprocess:
            return layout.pad_to(new_cis, self.m_state, 0, dtype=jnp.int32)
        lo, hi = self.host_slice.start, self.host_slice.stop
        if n in (self.m, self.m_state) and n != hi - lo:
            new_cis = new_cis[lo:min(hi, n)]
        local = layout.pad_to(new_cis, hi - lo, 0, dtype=jnp.int32)
        return host_local_array(np.asarray(local), self.mesh, P(self.axes))

    def ingest_and_schedule(self, new_cis: jax.Array):
        """One round: ingest the CIS feed counts, pick k pages to crawl."""
        new_cis = self._pad_feed(new_cis)
        self._ensure_cand_coverage()
        self.round, (page_ids, values) = be.crawl_round(
            self.backend, self.round, new_cis,
            mesh=self.mesh, k=self.k_per_round, dt=self.round_period,
        )
        self.rounds_completed += 1
        self._maybe_adapt_cand_depth()
        return page_ids, values

    def _check_feed_batch(self, feeds):
        """Shared (R, m) feed-batch validation (dtype/shape contract of
        `_pad_feed`, row-wise)."""
        if feeds.ndim != 2:
            raise FeedValidationError(
                f"feed batch must be (n_rounds, pages), got {feeds.shape}"
            )
        if not (jnp.issubdtype(feeds.dtype, jnp.integer)
                or feeds.dtype == jnp.bool_):
            raise FeedDtypeError(
                f"feeds must have an integer dtype, got {feeds.dtype}: "
                "CIS counts are integral, and a float feed would promote "
                "the donated int32 n_cis state to f32"
            )
        n = feeds.shape[1]
        if n not in self._feed_widths():
            raise FeedValidationError(
                f"feed rows have {n} entries but the scheduler holds "
                f"{self.m} pages ({self.m_state} padded); feed one count "
                "per page"
            )

    def _resolve_cap(self, need: int, cap: int | None, name: str,
                     what: str) -> int:
        """THE per-host capacity rule, shared by the feed conversion and
        the update-batch packer: a pinned contract cap (over-cap raises),
        or a pow2 bucket of the observed per-shard need (single-process
        only — all hosts of a multi-process mesh must agree on static
        shapes, which local data alone cannot guarantee).

        NOTE (multi-process): `need` is computed from THIS host's rows, so
        the over-cap error is raised host-locally — but peer hosts whose
        rows fit the contract will enter the round and wait at its
        collectives, which is why `CapacityExceeded.fleet_fatal` is True: a
        multi-host driver must treat it as fatal fleet-wide (it is a
        configuration/contract violation, not a per-host condition to
        swallow). The one caller that recovers instead is `update_pages`,
        which chunks an over-cap refresh batch before this rule ever sees
        an oversized need."""
        if cap is not None:
            if need > cap:
                raise CapacityExceeded(
                    f"{what.format(need=need)}, over the {name} contract "
                    f"({cap}); raise {name} (one re-jit) or split the "
                    "batch — on a multi-process mesh, treat this as fatal "
                    "fleet-wide: hosts under the cap are already waiting"
                )
            return cap
        if self.is_multiprocess:
            raise CapacityExceeded(
                f"multi-process meshes require an explicit {name}: the "
                "per-host conversion cannot derive a capacity bucket all "
                "hosts agree on from local data alone"
            )
        return int(max(1, 1 << max(0, (need - 1).bit_length())))

    def _local_feed_rows(self, feeds_np: np.ndarray) -> np.ndarray:
        """This host's (R, hi - lo) slice of a validated dense feed batch:
        full-width batches are sliced to the local range (and the padded
        tail zero-filled), local-width batches pass through."""
        lo, hi = self.host_slice.start, self.host_slice.stop
        n = feeds_np.shape[1]
        if n in (self.m, self.m_state) and n != hi - lo:
            feeds_np = feeds_np[:, lo:min(hi, n)]
        if feeds_np.shape[1] != hi - lo:
            feeds_np = np.concatenate(
                [feeds_np,
                 np.zeros((feeds_np.shape[0],
                           (hi - lo) - feeds_np.shape[1]), np.int32)],
                axis=1)
        return feeds_np

    def _pad_feeds(self, feeds) -> jax.Array:
        """Validate + pad a (R, m) feed batch to (R, m_state), sharded like
        the page state along the page axis (replicated over rounds). On a
        multi-process mesh each host contributes only its local rows
        (`host_local_array`); single-process keeps device-resident batches
        on device (no host round trip)."""
        if not self.is_multiprocess:
            feeds = jnp.asarray(feeds)
            self._check_feed_batch(feeds)
            feeds = feeds.astype(jnp.int32)
            if feeds.shape[1] != self.m_state:
                feeds = jnp.concatenate(
                    [feeds, jnp.zeros((feeds.shape[0],
                                       self.m_state - feeds.shape[1]),
                                      jnp.int32)], axis=1)
            return jax.device_put(
                feeds, NamedSharding(self.mesh, P(None, self.axes)))
        feeds = np.asarray(feeds)
        self._check_feed_batch(feeds)
        local = self._local_feed_rows(feeds.astype(np.int32, copy=False))
        return host_local_array(local, self.mesh, P(None, self.axes))

    def _sparse_feed_batch(self, feeds) -> be.SparseFeeds:
        """Convert a dense CIS feed batch to the per-SHARD COO form the
        fused macro scan consumes (`backends.SparseFeeds`, (R, n_shards,
        cap)): one host pass over this host's local page range only — on a
        multi-process mesh each host converts its own range and
        materializes its own shards' rows, so a feed batch never crosses
        hosts.

        Capacity: the `feed_cap` contract when set (a fixed static shape —
        feed content can never change a compiled signature, so a hot shard
        triggers zero recompiles on any host; a cell over the contract
        raises). Without a contract the per-(round, shard) capacity is
        rounded up to a power of two so repeated batch shapes reuse one
        compiled macro-round (single-process only: multi-process meshes
        must pin feed_cap, since all hosts must agree on static shapes).

        The conversion is memoized on the batch's object identity (the
        cache retains the batch, so its id cannot be recycled while
        cached) — production drivers that re-send one mutated-in-place
        buffer should pass a fresh array per batch; the cache only
        short-circuits the exact same immutable batch object (e.g.
        benchmark reps)."""
        cached = getattr(self, "_sparse_feed_cache", None)
        if (cached is not None and cached[0] is feeds
                and cached[1] == self.feed_cap):
            return cached[2]
        feeds_np = np.asarray(feeds)
        self._check_feed_batch(feeds_np)
        local = self._local_feed_rows(feeds_np.astype(np.int32, copy=False))
        lo = self.host_slice.start
        ms = self.m_shard
        s0, s1 = self._host_shards
        n_loc = s1 - s0
        n_rounds = local.shape[0]
        loc3 = local.reshape(n_rounds, n_loc, ms)
        rr, ss, cc = np.nonzero(loc3)
        nnz = np.zeros((n_rounds, n_loc), np.int64)
        np.add.at(nnz, (rr, ss), 1)
        need = int(nnz.max()) if rr.size else 0
        cap = self._resolve_cap(need, self.feed_cap, "feed_cap",
                                "a feed round carries {need} signalled "
                                "pages on one shard")
        ids = np.full((n_rounds, n_loc, cap), -1, np.int32)
        cnt = np.zeros((n_rounds, n_loc, cap), np.int32)
        if rr.size:
            # np.nonzero is row-major, so entries of one (round, shard)
            # cell are consecutive; their within-cell positions:
            col = np.concatenate([np.arange(x) for x in nnz.reshape(-1)])
            ids[rr, ss, col] = lo + ss * ms + cc
            cnt[rr, ss, col] = loc3[rr, ss, cc]
        spec = P(None, self.axes, None)
        sf = be.SparseFeeds(ids=host_local_array(ids, self.mesh, spec),
                            counts=host_local_array(cnt, self.mesh, spec))
        # Keyed on (batch identity, cap contract): a feed_cap change must
        # re-validate and re-shape even for the exact same batch object.
        self._sparse_feed_cache = (feeds, self.feed_cap, sf)
        return sf

    def _empty_outcome_batch(self, n_rounds: int):
        """An all-padding SparseOutcomes batch (no outcomes arrived this
        macro-round) at the contract cap, so `online_est=True` drivers that
        have nothing to report keep one compiled macro-round signature."""
        from repro.sched.online_est import SparseOutcomes

        cap = self.outcome_cap or 1
        s0, s1 = self._host_shards
        ids = np.full((n_rounds, s1 - s0, cap), -1, np.int32)
        spec = P(None, self.axes, None)
        return SparseOutcomes(
            ids=host_local_array(ids, self.mesh, spec),
            changed=host_local_array(np.zeros_like(ids), self.mesh, spec),
            tau=host_local_array(np.full(ids.shape, -1.0, np.float32),
                                 self.mesh, spec),
            n_cis=host_local_array(np.zeros_like(ids), self.mesh, spec))

    def _sparse_outcome_batch(self, out_ids, out_changed, out_tau, out_n,
                              n_rounds: int):
        """Convert a crawl-outcome batch to the per-SHARD COO form the
        streaming-estimation scan consumes (`online_est.SparseOutcomes`,
        (R, n_shards, cap)) — the outcome-side twin of `_sparse_feed_batch`,
        under the `outcome_cap` capacity contract.

        out_ids/out_changed/out_tau/out_n: (R, w) host arrays — for
        macro-round r, the global page ids whose crawl outcome arrives
        before round r runs, whether that crawl found a change, and the
        covariates of the crawled window (interval length tau and CIS count
        — the caller echoes them from its own crawl-order and feed streams,
        see `online_est.SparseOutcomes`); id = -1 rows are padding (a
        scheduler's own `run_rounds` winner output, with unresolved slots
        set to -1, is the natural input). Rows outside this host's
        `host_slice` are dropped host-locally, so outcome bytes never cross
        hosts."""
        ids_np = np.asarray(out_ids)
        chg_np = np.asarray(out_changed)
        tau_np = np.asarray(out_tau, np.float32)
        n_np = np.asarray(out_n)
        if (ids_np.shape != chg_np.shape or tau_np.shape != ids_np.shape
                or n_np.shape != ids_np.shape or ids_np.ndim != 2):
            raise FeedValidationError(
                f"outcome batch must be matching (n_rounds, w) arrays, got "
                f"ids {ids_np.shape} / changed {chg_np.shape} / tau "
                f"{tau_np.shape} / n_cis {n_np.shape}"
            )
        if not jnp.issubdtype(n_np.dtype, jnp.integer):
            raise FeedDtypeError(
                f"outcome CIS counts must be integers, got {n_np.dtype}")
        if ids_np.shape[0] != n_rounds:
            raise FeedValidationError(
                f"outcome batch has {ids_np.shape[0]} rounds but the feed "
                f"batch has {n_rounds}; supply one outcome row per round "
                "(all-padding rows for rounds without outcomes)"
            )
        if not jnp.issubdtype(ids_np.dtype, jnp.integer):
            raise FeedDtypeError(
                f"outcome page ids must be integers, got {ids_np.dtype}")
        if ids_np.size and ids_np.max() >= self.m:
            raise FeedValidationError(
                f"outcome page ids must be in [-1, {self.m}); got "
                f"max {ids_np.max()}"
            )
        lo, hi = self.host_slice.start, self.host_slice.stop
        ms = self.m_shard
        s0, s1 = self._host_shards
        n_loc = s1 - s0
        rr, ww = np.nonzero((ids_np >= lo) & (ids_np < hi))
        gid = ids_np[rr, ww].astype(np.int64)
        if gid.size:
            # Keep-LAST dedupe per (round, page): `SparseOutcomes` cells
            # must be id-unique — a page id repeated inside one round's
            # outcome row would take two streaming-estimator steps off the
            # same gathered statistics row and the second scatter would
            # silently drop the first (double-count, then lose one). The
            # echo path legitimately repeats ids under at-least-once
            # delivery, so the latest entry wins (matching the estimator's
            # last-write semantics) rather than raising.
            key = rr.astype(np.int64) * np.int64(self.m) + gid
            _, last_rev = np.unique(key[::-1], return_index=True)
            keep = np.sort(key.size - 1 - last_rev)
            rr, ww, gid = rr[keep], ww[keep], gid[keep]
        ss = (gid - lo) // ms
        cell = rr * n_loc + ss
        counts = np.bincount(cell, minlength=n_rounds * n_loc)
        need = int(counts.max()) if gid.size else 0
        cap = self._resolve_cap(need, self.outcome_cap, "outcome_cap",
                                "an outcome round resolves {need} crawls "
                                "on one shard")
        out_i = np.full((n_rounds, n_loc, cap), -1, np.int32)
        out_c = np.zeros((n_rounds, n_loc, cap), np.int32)
        out_t = np.full((n_rounds, n_loc, cap), -1.0, np.float32)
        out_n = np.zeros((n_rounds, n_loc, cap), np.int32)
        if gid.size:
            order = np.argsort(cell, kind="stable")
            col = np.concatenate([np.arange(c) for c in counts])
            out_i[rr[order], ss[order], col] = gid[order]
            out_c[rr[order], ss[order], col] = (
                chg_np[rr, ww][order] != 0).astype(np.int32)
            out_t[rr[order], ss[order], col] = tau_np[rr, ww][order]
            out_n[rr[order], ss[order], col] = n_np[rr, ww][order]
        from repro.sched.online_est import SparseOutcomes

        spec = P(None, self.axes, None)
        return SparseOutcomes(
            ids=host_local_array(out_i, self.mesh, spec),
            changed=host_local_array(out_c, self.mesh, spec),
            tau=host_local_array(out_t, self.mesh, spec),
            n_cis=host_local_array(out_n, self.mesh, spec))

    def run_rounds(self, feeds, outcomes=None, budgets=None,
                   outcome_seq=None):
        """A macro-round: R = len(feeds) rounds under one jitted `lax.scan`
        (`backends.crawl_rounds`) — one dispatch, no mid-loop host sync, and
        for the fused backend O(active + k) instead of O(m) state work per
        round. Returns (page_ids (R, k), values (R, k)), stacked and equal
        to R sequential `ingest_and_schedule` calls page-id-for-page-id.

        Per-round skip-control diagnostics accumulate on device and land in
        `self.macro_diagnostics` (a `backends.RoundDiagnostics`); host-side
        candidate-depth adaptation runs once at the macro-round boundary
        (reading the device-resident watermark) instead of syncing mid-loop.
        R is a static shape — drive a deployment with one batch size to
        avoid re-jits. For the fused backend the dense batch never reaches
        the device: it converts once host-side to the COO `SparseFeeds`
        form (CIS feeds are overwhelmingly sparse in production), so feed
        ingest inside the scan is O(nnz) per round.

        outcomes (streaming estimation, `FusedBackend(online_est=True)`):
        an optional `(page_ids (R, w), changed (R, w), tau (R, w),
        n_cis (R, w))` tuple of host arrays — for round r, the pages whose
        crawl OUTCOME arrives before round r runs, whether the crawl found
        a change (-1 ids = padding), and the crawled window's covariates
        (interval length and CIS count), which the caller echoes from its
        own crawl-order and feed streams so each observation is
        self-contained and pairing is exact even for pages re-crawled
        while their outcome was in flight (`online_est.SparseOutcomes`).
        Converted host-locally to `online_est.SparseOutcomes` under the
        `outcome_cap` contract and consumed inside the scan
        (`online_est.ingest_outcomes`): each resolved outcome takes one
        streaming estimator step on device, and at the macro-round boundary
        the touched pages' packed env planes re-derive from the updated
        estimates — zero per-round host transfers. With `online_est=True`
        and no outcomes, an all-padding batch keeps the compiled signature
        stable; passing outcomes to a non-estimating backend raises.

        budgets (elastic bandwidth, fused backend only): an optional
        (n_rounds,) integer vector of per-round crawl budgets, consumed
        INSIDE the already-compiled scan as a traced operand under the
        k_max cap contract: the compiled round selects at the static width
        `k_cap` and masks down to each round's budget, so any budget
        sequence with values in [0, k_cap] reuses one compiled executable
        — zero recompiles across budget values. Rows past a round's budget
        come back as id -1 / value -inf; a zero-budget round crawls
        nothing but still advances tau for every page. A budget above
        `k_cap` raises CapacityExceeded (raise k_max — one re-jit — or
        split the round). Constant budget vectors equal to k are
        bit-identical to the fixed-k path. With emission="smooth" and no
        explicit budgets, the scheduler instead derives each round's
        budget on device from a token bucket at bandwidth * round_period
        crawls/round (fractional residue rides `FusedState.emit_res`
        across macro-rounds), so realized crawls over any window of W
        rounds stay within +-1 of bandwidth * W * round_period and
        `set_bandwidth` is a pure data update."""
        if outcome_seq is not None:
            # Degraded-mode echo gating (`sched.degraded.OutcomeGate`):
            # under a faulty delivery path the outcome echo arrives late,
            # twice, or out of order, and a replayed batch would
            # double-count every observation in the streaming estimator.
            # Callers that stamp each batch with a monotone sequence number
            # get host-side dedup against a sliding window — a gated-out
            # batch degrades to the all-padding batch (signature-stable),
            # it does not raise.
            if outcomes is None:
                raise FeedValidationError(
                    "run_rounds(outcome_seq=...) requires an outcomes "
                    "batch to gate")
            if not hasattr(self, "outcome_gate"):
                self.outcome_gate = OutcomeGate()
            outcomes = self.outcome_gate.offer(int(outcome_seq), outcomes)
        est_on = (isinstance(self.backend, be.FusedBackend)
                  and self.backend.online_est)
        fused = isinstance(self.backend, be.FusedBackend)
        smooth = self.emission == "smooth" and budgets is None
        if (budgets is not None or smooth) and not fused:
            raise FeedValidationError(
                "elastic bandwidth (run_rounds(budgets=...) or "
                "emission='smooth') requires the fused backend: only the "
                "fused macro-round threads a dynamic per-round k"
            )
        if outcomes is not None and not est_on:
            raise FeedValidationError(
                "run_rounds(outcomes=...) requires "
                "FusedBackend(online_est=True): the non-estimating macro "
                "round has no streaming-estimator planes to ingest into"
            )
        if isinstance(self.backend, be.FusedBackend):
            n_rounds = int(feeds.shape[0]) if hasattr(feeds, "shape") else (
                len(feeds))
            feeds = self._sparse_feed_batch(feeds)
            if est_on:
                if outcomes is None:
                    outcomes = self._empty_outcome_batch(n_rounds)
                else:
                    if len(outcomes) != 4:
                        raise FeedValidationError(
                            "outcomes must be a (page_ids, changed, tau, "
                            "n_cis) tuple of (n_rounds, w) host arrays — "
                            f"got {len(outcomes)} elements"
                        )
                    outcomes = self._sparse_outcome_batch(
                        outcomes[0], outcomes[1], outcomes[2], outcomes[3],
                        n_rounds)
        else:
            feeds = self._pad_feeds(feeds)
        rate = None
        if budgets is not None:
            bud = np.asarray(budgets)
            if bud.ndim != 1 or bud.shape[0] != n_rounds:
                raise FeedValidationError(
                    f"budgets must be a 1-D length-{n_rounds} vector (one "
                    f"entry per round), got shape {bud.shape}"
                )
            if not np.issubdtype(bud.dtype, np.integer):
                raise FeedValidationError(
                    f"budgets must be integers (crawls per round), got "
                    f"dtype {bud.dtype}"
                )
            if bud.size and int(bud.min()) < 0:
                raise FeedValidationError("budgets must be >= 0")
            cap = self.k_cap
            if bud.size and int(bud.max()) > cap:
                raise CapacityExceeded(
                    f"budget {int(bud.max())} exceeds the k_max contract "
                    f"({cap}); raise k_max (one re-jit) or split the round"
                )
            budgets = bud.astype(np.int32)
        elif smooth:
            rate = self._smooth_rate
            self._ensure_emit_residue()
        k_static = self.k_cap if (budgets is not None or smooth) else (
            self.k_per_round)
        self._ensure_cand_coverage()
        self.round, (page_ids, values), diag = be.crawl_rounds(
            self.backend, self.round, feeds,
            mesh=self.mesh, k=k_static, dt=self.round_period,
            outcomes=outcomes, budgets=budgets, rate=rate,
        )
        self.macro_diagnostics = diag
        self.rounds_completed += int(page_ids.shape[0])
        self._maybe_adapt_cand_depth(rounds=page_ids.shape[0])
        return page_ids, values

    # -- adaptive candidate-buffer depth (ROADMAP "candidate-buffer sizing
    # -- from observed concentration") --------------------------------------
    CAND_ADAPT_INTERVAL = 16  # rounds between host-side depth decisions
    CAND_ADAPT_MARGIN = 2     # retained slack above the observed watermark
    # A window is "persistently saturated" when more than this fraction of
    # its rounds hit the retained depth (`FusedState.depth_hot`); rarer
    # saturation is treated as a lone hot round the dense fallback already
    # absorbed, and the watermark spike is NOT chased (ROADMAP macro
    # depth-cadence item: one hot round in a large-R macro-round must not
    # pin the depth high for the whole batch).
    CAND_HOT_FRAC = 1 / 8

    def _cand_floor(self, k: int) -> int:
        """Smallest candidate depth whose per-shard buffer capacity still
        covers the shard-local budget — below it, `select.shard_budget`'s
        capacity clamp would cut k_loc under the global top-k requirement
        (a mid-round ValueError on one shard, or a silently short
        contribution on a winner-heavy shard of a multi-shard mesh), so the
        depth adaptation must never go there. The budget comes from
        `shard_budget` itself (auto depth, whose capacity never binds) so
        this can't drift from the clamp rule the round applies."""
        from repro.kernels import select as ksel

        bst = self.round.backend
        nb_local = bst.env_planes.shape[0] // self.mesh.size
        lanes = bst.env_planes.shape[3]
        k_loc, _ = ksel.shard_budget(
            k, self.m_state // self.mesh.size, nb_local, self.mesh.size,
            self.backend.k_local,
        )
        return -(-k_loc // (nb_local * lanes))

    def _ensure_cand_coverage(self) -> None:
        """Re-grow an adapted candidate depth that a later bandwidth raise
        (`set_bandwidth` between depth decisions) has made too small to
        cover the budget — cheap host-side arithmetic, runs every round.
        The floor is computed against `k_cap`, not the current round's k:
        under elastic bandwidth a budget vector may ramp to the cap inside
        one compiled batch, so coverage must hold at the cap even when the
        bandwidth (and thus this round's typical budget) is low."""
        b = self.backend
        if not (isinstance(b, be.FusedBackend) and b.adaptive_cand
                and b.cand_per_lane is not None):
            return
        floor = self._cand_floor(self.k_cap)
        if b.cand_per_lane < floor:
            self.backend = dataclasses.replace(b, cand_per_lane=floor)

    def _maybe_adapt_cand_depth(self, rounds: int = 1) -> None:
        """Shrink (or re-grow) the fused candidate-buffer depth from the
        realized per-lane-column winner counts the round tracks in
        `FusedState.col_winners`. `auto_cand_per_lane` sizes for the worst
        case — all k winners in one block; on well-mixed shards the realized
        depth is far smaller, and every retained slot is one more
        max/select extraction pass per active block per round. Host-side by
        necessity (the depth is a static buffer shape), so a change re-jits
        the round: decisions are taken every CAND_ADAPT_INTERVAL rounds and
        only when the watermark actually moved. Exactness is never at
        stake — an undersized buffer triggers the dense fallback, which
        both restores the selection and (through the watermark) grows the
        depth back.

        rounds: how many rounds just ran — a macro-round credits its whole
        batch, so the blocking `device_get` of the watermark happens at most
        once per macro-round boundary, never inside the scan.

        Cadence (the ROADMAP macro depth-cadence item): the watermark is a
        running max, so with large R one hot round would pin it — and the
        depth — high for the whole batch. The bounded in-scan saturation
        counter (`FusedState.depth_hot`, surfaced per round in
        `RoundDiagnostics`) disambiguates: if at most CAND_HOT_FRAC of the
        window's rounds saturated the retained depth, the spike was
        exceptional — the dense fallback already restored exactness for
        those rounds — and the current depth is kept; only persistent
        saturation (or a clean window) re-targets the depth from the
        watermark."""
        b = self.backend
        if not (isinstance(b, be.FusedBackend) and b.adaptive_cand):
            return
        self._rounds_since_cand_adapt = getattr(
            self, "_rounds_since_cand_adapt", 0) + rounds
        window = self._rounds_since_cand_adapt
        if window < self.CAND_ADAPT_INTERVAL:
            return
        self._rounds_since_cand_adapt = 0
        from repro.kernels import select as ksel

        bst = self.round.backend
        # Against the cap, not this round's k: a dynamic budget vector may
        # jump to k_cap inside the next compiled batch, and an undersized
        # depth would price a dense fallback on every such round.
        k = self.k_cap
        # The same clamp rule the round itself applies, with the depth left
        # to auto-size: its cand output IS the worst-case auto depth.
        _, auto = ksel.shard_budget(
            k, self.m_state // self.mesh.size,
            bst.env_planes.shape[0] // self.mesh.size, self.mesh.size,
            b.k_local,
        )
        cur = b.cand_per_lane or auto
        # Global (not host-local) maxima: jnp reductions of a sharded array
        # produce a fully-replicated result every host can read — a
        # device_get of the raw watermark would fail on a multi-process
        # mesh (non-addressable shards), and host-local maxima would let
        # hosts take DIFFERENT depth decisions (different static buffer
        # shapes → collective mismatch). One global max keeps the fleet's
        # compiled shapes in lockstep.
        obs = int(np.asarray(jnp.max(bst.col_winners)))
        hot = int(np.asarray(jnp.max(bst.depth_hot)))
        if 0 < hot <= max(1, int(window * self.CAND_HOT_FRAC)):
            # A lone hot round: hold the steady-state depth instead of
            # chasing the watermark spike.
            target = min(max(cur, self._cand_floor(k)), auto)
        else:
            target = min(max(obs + self.CAND_ADAPT_MARGIN, 2,
                             self._cand_floor(k)), auto)
        if target != cur:
            self.backend = dataclasses.replace(b, cand_per_lane=target)
        # Fresh observation window either way.
        self.round = dataclasses.replace(
            self.round,
            backend=bst._replace(
                col_winners=jnp.zeros_like(bst.col_winners),
                depth_hot=jnp.zeros_like(bst.depth_hot)),
        )

    # -- decentralized parameter refresh (§5.2 / App. E) -------------------
    # Benign DerivedEnv fill values for the sentinel rows of a per-shard
    # update batch: every packed plane derived from them is finite, and the
    # sentinel ids drop the rows from every scatter anyway.
    _D_FILL = dict(delta=1.0, mu_t=0.0, lam=0.0, nu=0.0, gamma=1.0,
                   alpha=1.0, b=0.0, beta=0.0)

    def _shard_update_batches(self, ids_np: np.ndarray, d_new: DerivedEnv):
        """Pack a flat host-local update batch into the per-shard padded
        form the fused local-range repack consumes: shard-relative page ids
        (n_local_shards, u_cap) with sentinel = m_shard, the matching
        DerivedEnv columns, and the per-shard touched-block ids
        (n_local_shards, b_cap) with sentinel = blocks-per-shard. Each host
        builds only its own shards' rows; `host_local_array` materializes
        them in place, so refresh jobs never ship cross-host indices."""
        ms = self.m_shard
        s0, s1 = self._host_shards
        n_loc = s1 - s0
        bst = self.round.backend
        bp = bst.env_planes.shape[2] * bst.env_planes.shape[3]
        nb_local = bst.env_planes.shape[0] // self.n_shards
        lo = self.host_slice.start
        rel = ids_np - lo
        shard_row = rel // ms            # local shard row in [0, n_loc)
        rel_in_shard = rel - shard_row * ms
        counts = np.bincount(shard_row, minlength=n_loc) if rel.size else (
            np.zeros((n_loc,), np.int64))
        need = int(counts.max()) if rel.size else 0
        u_cap = self._resolve_cap(need, self.update_cap, "update_cap",
                                  "a refresh batch touches {need} pages "
                                  "on one shard")
        b_cap = min(u_cap, nb_local)
        ids_arr = np.full((n_loc, u_cap), ms, np.int32)       # sentinel
        d_cols = [np.full((n_loc, u_cap), self._D_FILL[f], np.float32)
                  for f in DerivedEnv._fields]
        blk_arr = np.full((n_loc, b_cap), nb_local, np.int32)  # sentinel
        if rel.size:
            order = np.argsort(shard_row, kind="stable")
            col = np.concatenate([np.arange(c) for c in counts])
            rows = shard_row[order]
            ids_arr[rows, col] = rel_in_shard[order]
            for dst, field in zip(d_cols, d_new):
                dst[rows, col] = np.asarray(field, np.float32)[order]
            blk = np.unique(
                np.stack([shard_row, rel_in_shard // bp], axis=1), axis=0)
            bcnt = np.bincount(blk[:, 0], minlength=n_loc)
            bcol = np.concatenate([np.arange(c) for c in bcnt])
            blk_arr[blk[:, 0], bcol] = blk[:, 1]
        row_spec = P(self.axes, None)
        return (
            host_local_array(ids_arr, self.mesh, row_spec),
            DerivedEnv(*[host_local_array(c, self.mesh, row_spec)
                         for c in d_cols]),
            host_local_array(blk_arr, self.mesh, row_spec),
        )

    def _update_chunks(self, ids_np: np.ndarray, d_new: DerivedEnv):
        """Split a host-local refresh batch whose per-shard row count
        exceeds `update_cap` into a sequence of under-cap chunks (ROADMAP
        item iii: an oversized batch used to raise). Chunking is legal
        precisely because the fused local-range repack is collective-free:
        hosts apply their own chunk sequences independently and need not
        agree on chunk count — a host with no over-cap shard applies one
        chunk while its peer applies three. Within a shard the original row
        order is preserved across chunks, so duplicate-id batches keep
        their last-write-wins semantics. No-cap and under-cap batches pass
        through untouched (the exact legacy packing)."""
        cap = self.update_cap
        if cap is None or not ids_np.size:
            return [(ids_np, d_new)]
        ms = self.m_shard
        lo = self.host_slice.start
        shard_row = (ids_np - lo) // ms
        counts = np.bincount(shard_row)
        if int(counts.max()) <= cap:
            return [(ids_np, d_new)]
        order = np.argsort(shard_row, kind="stable")
        # Within-shard position of each (sorted) row; rows land in chunk
        # position // cap, so each chunk holds at most cap rows per shard.
        col = np.concatenate([np.arange(c) for c in counts])
        chunk_of = col // cap
        d_np = DerivedEnv(*[np.asarray(f) for f in d_new])
        return [
            (ids_np[take], DerivedEnv(*[f[take] for f in d_np]))
            for c in range(int(chunk_of.max()) + 1)
            for take in (order[chunk_of == c],)
        ]

    def _local_update_rows(self, page_ids, env_updates: Env):
        """Validate a refresh batch and keep only this host's local rows
        (the `host_slice` filter of the multi-host data path; single-process
        meshes keep everything)."""
        ids_np = np.asarray(page_ids).astype(np.int64, copy=False).reshape(-1)
        if ids_np.size and (ids_np.min() < 0 or ids_np.max() >= self.m):
            raise FeedValidationError(
                f"page ids must be in [0, {self.m}); got "
                f"[{ids_np.min()}, {ids_np.max()}]"
            )
        env_np = Env(*[np.asarray(f) for f in env_updates])
        if self.is_multiprocess:
            lo, hi = self.host_slice.start, self.host_slice.stop
            keep = (ids_np >= lo) & (ids_np < hi)
            if not keep.all():
                ids_np = ids_np[keep]
                env_np = Env(*[f[keep] for f in env_np])
        return ids_np, env_np

    def update_pages(self, page_ids, env_updates: Env):
        """Refresh the environment parameters of `page_ids` in place.

        env_updates: raw Env fields of shape (n_upd,) (new delta/mu/lam/nu
        per updated page). Shard-local and block-granular: only the touched
        rows of the backend state are rewritten — for the fused backend via
        the local-range repack (`FusedBackend.update_pages`): per-shard
        padded batches inside a collective-free shard_map, so each mesh
        shard scatters only its own plane columns and touched-block bounds.
        On a multi-process mesh the batch is first filtered to this host's
        `host_slice` (hosts outside the range contribute nothing), each
        host materializes only its own shards' rows, and — since the repack
        contains no collectives — hosts may apply refresh batches
        asynchronously. The state buffer is donated so nothing else is
        copied. Normalization uses the frozen construction-time mu_total —
        greedy selection is scale-invariant, so no global renormalization
        pass is ever needed.
        """
        ids_np, env_np = self._local_update_rows(page_ids, env_updates)
        d_new = derive(env_np, mu_total=self.mu_total)
        if isinstance(self.round.backend, be.FusedState):
            # The host-side dense oracle syncs lazily on `.d` access (no
            # oracle exists under host-local construction — see
            # `from_local_env`).
            if self._d_oracle is not None:
                self._d_pending.append(
                    (jnp.asarray(ids_np, jnp.int32), d_new))
            # Donation-safe chunk loop: refresh_pages donates the backend
            # state, so each chunk rebinds self.round before the next one
            # packs against it. Over-`update_cap` batches are split
            # host-side (`_update_chunks`) instead of raising.
            for c_ids, c_d in self._update_chunks(ids_np, d_new):
                ids, d_shard, block_ids = self._shard_update_batches(c_ids,
                                                                     c_d)
                new_bstate = be.refresh_pages(
                    self.backend, self.round.backend, ids, d_shard,
                    block_ids, mesh=self.mesh)
                self.round = dataclasses.replace(self.round,
                                                 backend=new_bstate)
            return
        new_bstate = be.refresh_pages(self.backend, self.round.backend,
                                      jnp.asarray(ids_np, jnp.int32),
                                      d_new, None, mesh=self.mesh)
        self.round = dataclasses.replace(self.round, backend=new_bstate)

    def ingest_crawl_results(self, page_ids, tau, n_cis, fresh):
        """Close the crawl -> estimate -> refresh -> re-select loop (App. E).

        tau/n_cis/fresh: (n_pages, n_intervals) crawl-log arrays for
        `page_ids` — interval lengths, CIS counts, and whether the crawl
        found the page unchanged. Fits the CIS-quality MLE per page
        (`core.estimation.fit_mle_pages`), maps it back to raw env
        parameters (importance mu is unchanged — it comes from request logs,
        not crawl logs), and applies `update_pages`. Returns the fitted
        `CISQuality` for observability (of the rows this host processed).

        Host-local: on a multi-process mesh the crawl-log rows are first
        filtered to this host's `host_slice` — each host estimates and
        refreshes only its own pages, so neither the MLE input nor the
        refresh scatter ever crosses hosts.
        """
        ids_np = np.asarray(page_ids).reshape(-1)
        tau, n_cis, fresh = (np.asarray(x) for x in (tau, n_cis, fresh))
        if self.is_multiprocess:
            lo, hi = self.host_slice.start, self.host_slice.stop
            keep = (ids_np >= lo) & (ids_np < hi)
            ids_np = ids_np[keep]
            tau, n_cis, fresh = tau[keep], n_cis[keep], fresh[keep]
        q = estimation.fit_mle_pages(tau, n_cis, fresh)
        ids = jnp.asarray(ids_np, jnp.int32)
        mu = self._gather_mu_t(ids) * self.mu_total
        self.update_pages(ids_np, estimation.quality_to_env(q, mu))
        return q

    def _gather_mu_t(self, ids: jax.Array) -> jax.Array:
        """Normalized importance of `ids` (host-local by contract), read
        from the live backend state.

        For the fused backend this gathers the MU_T plane columns of the
        packed tensor directly — an O(n_upd) gather. Going through the `.d`
        oracle instead would force the lazy pending-update fold (one
        full-plane scatter per queued `update_pages` batch — pathologically
        slow on CPU for large scatter windows) just to read a handful of mu
        values; the packed planes are always current because `update_pages`
        writes them eagerly. On a multi-process mesh the gather walks this
        host's addressable plane shards (ids outside the host range are not
        supported there — the data-path contract filters them first), so it
        ships no cross-host indices either."""
        from repro.kernels import layout

        b = self.round.backend
        if not isinstance(b, be.FusedState):
            return self.d.mu_t[ids]
        bp = b.env_planes.shape[2] * b.env_planes.shape[3]
        if not self.is_multiprocess:
            return layout.gather_plane(b.env_planes, ids, layout.MU_T)
        # Per-addressable-shard gather: each id lives in a block whose
        # plane shard is local to this host (the host_slice contract).
        ids_np = np.asarray(ids)
        out = np.zeros(ids_np.shape, np.float32)
        for shard in b.env_planes.addressable_shards:
            blk0 = shard.index[0].start or 0
            blk1 = blk0 + shard.data.shape[0]
            sel = (ids_np // bp >= blk0) & (ids_np // bp < blk1)
            if not sel.any():
                continue
            rel = ids_np[sel] - blk0 * bp
            out[sel] = np.asarray(
                shard.data[rel // bp, layout.MU_T,
                           (rel % bp) // layout.LANES, rel % layout.LANES])
        return jnp.asarray(out)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self):
        """Full scheduler state incl. backend warm-start state (per-shard
        thresholds, block bounds, packed planes) AND the host-side
        adaptation counters (`adapt` key): the adapted candidate-buffer
        depth and the rounds elapsed in the current observation window.
        Without them a restore silently reverts to the auto depth and
        restarts the window — the first post-restore rounds re-jit with a
        surprise buffer shape. Snapshot with jax.device_get before running
        further (donating) rounds."""
        b = self.backend
        cand = b.cand_per_lane if isinstance(b, be.FusedBackend) else None
        return {
            "tau_elap": self.round.tau_elap,
            "n_cis": self.round.n_cis,
            "crawl_clock": self.round.crawl_clock,
            "backend": self.round.backend,
            "adapt": {
                # -1 encodes "auto" (cand_per_lane=None) for the array-only
                # checkpoint store.
                "cand_per_lane": jnp.int32(-1 if cand is None else cand),
                "rounds_since_cand_adapt": jnp.int32(
                    getattr(self, "_rounds_since_cand_adapt", 0)),
            },
        }

    def load_state_dict(self, sd) -> None:
        sh = NamedSharding(self.mesh, P(self.axes))
        backend_state = self.round.backend
        # jnp.copy decouples from caller-held arrays: subsequent rounds
        # donate the state, which must never invalidate the caller's sd.
        own = lambda v, dt=None: jnp.copy(jnp.asarray(v, dt))
        if sd.get("backend") is not None:
            snap = sd["backend"]
            # Align the lazy emit_res leaf before the structural tree.map:
            # a smooth-emission snapshot restored into a scheduler that
            # hasn't smoothed yet (or vice versa) would otherwise fail the
            # pytree structure match (None is an empty subtree).
            if (isinstance(backend_state, be.FusedState)
                    and isinstance(snap, be.FusedState)):
                if (snap.emit_res is not None
                        and backend_state.emit_res is None):
                    self._ensure_emit_residue()
                    backend_state = self.round.backend
                elif (snap.emit_res is None
                        and backend_state.emit_res is not None):
                    # Pre-smoothing snapshot: restore with a clean bucket.
                    snap = snap._replace(emit_res=np.zeros(
                        backend_state.emit_res.shape, np.float32))
                # Same two-way alignment for the degraded-mode staleness
                # plane (`FusedState.stale` — lazy like emit_res): restore
                # a degraded checkpoint into a healthy scheduler by
                # attaching the plane, and a pre-degraded checkpoint into
                # a degraded scheduler with fresh (all-zero) counters.
                snap_stale = getattr(snap, "stale", None)
                if snap_stale is not None and backend_state.stale is None:
                    self._ensure_stale_plane()
                    backend_state = self.round.backend
                elif snap_stale is None and backend_state.stale is not None:
                    snap = snap._replace(stale=np.zeros(
                        backend_state.stale.shape, np.int32))
                # And for the request-importance planes (`FusedState.req`):
                # a request-plane checkpoint restores into a plain
                # scheduler by attaching the plane (shape/sharding
                # template; values come from the snapshot), and a
                # pre-plane snapshot into an importance scheduler keeps
                # the live delta/prior columns with a zeroed EWMA (the
                # snapshot predates request logging; strict=False
                # checkpoint loads hand exactly this shape through).
                snap_req = getattr(snap, "req", None)
                if snap_req is not None and backend_state.req is None:
                    self._ensure_request_plane()
                    backend_state = self.round.backend
                elif snap_req is None and backend_state.req is not None:
                    live = backend_state.req
                    snap = snap._replace(req=live._replace(
                        ewma=np.zeros(live.ewma.shape, np.float32)))
            # Re-shard each restored leaf like the corresponding live leaf
            # (old checkpoints without backend state keep the cold init).
            backend_state = jax.tree.map(
                lambda ref, val: jax.device_put(own(val, ref.dtype),
                                                ref.sharding),
                backend_state, snap,
            )
        if sd.get("adapt") is not None and isinstance(self.backend,
                                                      be.FusedBackend):
            # Resume the adapted buffer shape + observation window so a
            # restored scheduler keeps its steady-state depth (no surprise
            # re-jit, no cold re-observation) — old snapshots without the
            # key keep the configured depth.
            self._rounds_since_cand_adapt = int(
                sd["adapt"]["rounds_since_cand_adapt"])
            cand = int(sd["adapt"]["cand_per_lane"])
            cand = None if cand < 0 else cand
            if cand != self.backend.cand_per_lane:
                self.backend = dataclasses.replace(self.backend,
                                                   cand_per_lane=cand)
        self.round = be.RoundState(
            tau_elap=jax.device_put(own(sd["tau_elap"]), sh),
            n_cis=jax.device_put(own(sd["n_cis"]), sh),
            crawl_clock=own(sd["crawl_clock"]),
            backend=backend_state,
        )
        self.rounds_completed = int(np.asarray(sd["crawl_clock"]))
        # Donation-normalize the restored state (commit the clock, map the
        # device_put shardings onto the canonical post-round objects) so
        # the first post-restore round reuses the warm jit cache instead of
        # recompiling once — see `backends.commit_state`.
        self.round = be.commit_state(self.round)
