"""CrawlScheduler — the deployable service wrapper.

Holds one functional `RoundState` (page state + selection-backend state,
see `sched.backends`), executes budgeted scheduling rounds, ingests CIS
feeds and crawl results, and exposes the production properties the paper
highlights:

  * **elastic bandwidth** (App. D): `set_bandwidth` changes the per-round
    budget k (or round period) with *zero* recomputation — the greedy rule is
    self-adapting;
  * **decentralized parameter refresh** (§5.2): `update_pages` scatters new
    per-page (Delta, mu, lam, nu) into the backend state touching only the
    updated rows — for the fused backend, a block-granular repack of the
    touched `PageShard` plane columns + bounds, never a full `pack_shard`;
  * **closed estimation loop** (App. E): `ingest_crawl_results` fits the
    CIS-quality MLE (`core.estimation.fit_mle_pages`) on crawl logs and
    feeds the refreshed parameters straight back through `update_pages`;
  * **adaptive skip control** (App. G): with
    `FusedBackend(adaptive_bounds=True)` the per-block bounds refresh from
    each round's block maxima and the warm-start hysteresis adapts per
    shard, all inside the jitted round (`sched.backends`); the scheduler
    additionally shrinks the candidate-buffer depth host-side from the
    realized winner concentration (`adaptive_cand`).

Selection strategies are `SelectionBackend` objects (`sched.backends`):
`DenseBackend`, `TableBackend` (default), `KernelBackend`, `FusedBackend`
(packed planes + single-pass candidate select with per-shard threshold
warm-start — enabled on any mesh size; selection stays provably identical
to dense top-k). The legacy `use_kernel=`/`use_fused=`/`table_grid=` kwargs
are deprecation shims that map onto those backends.

Fault tolerance: the entire scheduler state is one pytree; `state_dict()` /
`load_state_dict()` plug into repro.checkpoint for atomic, sharded,
resumable snapshots, and now include the backend state (per-shard
thresholds, block bounds) so a restart resumes warm — the first post-restore
round skips cold blocks instead of paying a full dense pass. NOTE: rounds
donate the live buffers; `jax.device_get` a `state_dict()` before running
further rounds if you intend to keep it.
"""
from __future__ import annotations

import warnings

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import estimation
from repro.core.values import DerivedEnv, Env, derive
from repro.sched import backends as be
from repro.sched.distributed import ShardedSchedState

# Legacy constant, re-exported for back-compat (now lives per backend:
# `FusedBackend.hysteresis`).
THRESH_HYSTERESIS = be.DEFAULT_HYSTERESIS


def _legacy_backend(n_terms, table_grid, use_kernel, use_fused, block_rows):
    """Map the pre-backend flag soup onto a SelectionBackend."""
    if use_fused:
        return be.FusedBackend(n_terms=n_terms, block_rows=block_rows)
    if use_kernel:
        return be.KernelBackend(n_terms=n_terms)
    if table_grid:
        return be.TableBackend(n_terms=n_terms, table_grid=table_grid)
    return be.DenseBackend(n_terms=n_terms)


class CrawlScheduler:
    def __init__(
        self,
        env: Env,
        mesh: Mesh,
        bandwidth: float,
        round_period: float = 1.0,
        n_terms: int = 8,
        table_grid: int | None = 128,
        use_kernel: bool = False,
        use_fused: bool = False,
        block_rows: int | None = None,
        backend: be.SelectionBackend | None = None,
    ):
        if backend is None:
            if use_kernel or use_fused:
                warnings.warn(
                    "use_kernel=/use_fused= are deprecated; pass "
                    "backend=KernelBackend(...)/FusedBackend(...) instead",
                    DeprecationWarning, stacklevel=2,
                )
            backend = _legacy_backend(n_terms, table_grid, use_kernel,
                                      use_fused, block_rows)
        self.backend = backend
        self.mesh = mesh
        self.axes = tuple(mesh.axis_names)
        self.round_period = float(round_period)
        self.bandwidth = float(bandwidth)
        self.m = env.m
        self.round, binit = be.init_round(backend, env, mesh)
        self.m_state = binit.m_state
        # Host-side conveniences: the derived (padded) env oracle and the
        # frozen importance normalizer (see backends module docstring). For
        # dense/table backends `d`/`table` read through to the live backend
        # state; the fused oracle copy is maintained by update_pages.
        self.mu_total = jnp.sum(jnp.asarray(env.mu))
        self._d_oracle = binit.d if isinstance(self.round.backend,
                                               be.FusedState) else None
        self._d_pending = []  # (ids, d_new) updates not yet folded into it

    # -- legacy views ------------------------------------------------------
    @property
    def d(self) -> DerivedEnv:
        """Derived-env oracle view. For the fused backend (whose state holds
        packed planes, not a DerivedEnv) pending `update_pages` scatters are
        folded in lazily here, so production refresh loops that never read
        `.d` pay nothing for it."""
        b = self.round.backend
        if hasattr(b, "d"):
            return b.d
        for ids, d_new in self._d_pending:
            self._d_oracle = DerivedEnv(
                *[f.at[ids].set(n.astype(f.dtype))
                  for f, n in zip(self._d_oracle, d_new)]
            )
        self._d_pending.clear()
        return self._d_oracle

    @property
    def table(self):
        b = self.round.backend
        return b.table if isinstance(b, be.TableState) else None

    @property
    def state(self) -> ShardedSchedState:
        """Page state as the legacy ShardedSchedState view."""
        return ShardedSchedState(
            tau_elap=self.round.tau_elap,
            n_cis=self.round.n_cis,
            crawl_clock=self.round.crawl_clock,
        )

    # -- bandwidth ---------------------------------------------------------
    @property
    def k_per_round(self) -> int:
        # A budget above the shard size just means "crawl everything".
        k = max(1, int(round(self.bandwidth * self.round_period)))
        return min(k, self.m)

    def set_bandwidth(self, bandwidth: float) -> None:
        """App. D: adapting to a new budget is just a new k — no re-solve."""
        self.bandwidth = float(bandwidth)

    # -- the round ---------------------------------------------------------
    def _pad_feed(self, new_cis: jax.Array) -> jax.Array:
        """Validate + zero-pad a per-page feed to the packed state size (the
        one shared padding path). A feed must cover exactly the corpus
        (length m) or be pre-padded (length m_state); anything else is an
        error — a longer feed would silently credit its tail counts to
        padding pages, a shorter one would starve real pages. CIS counts
        are integral by definition, and the round ADDS the feed to the
        donated int32 n_cis state: a float feed would silently promote it
        to f32 and break the donated-buffer dtype contract on the next
        round, so non-integer dtypes are rejected (bool counts are cast)."""
        from repro.kernels import layout

        new_cis = jnp.asarray(new_cis)
        if not (jnp.issubdtype(new_cis.dtype, jnp.integer)
                or new_cis.dtype == jnp.bool_):
            raise TypeError(
                f"new_cis must have an integer dtype, got {new_cis.dtype}: "
                "CIS counts are integral, and a float feed would promote "
                "the donated int32 n_cis state to f32"
            )
        n = new_cis.shape[0]
        if n not in (self.m, self.m_state):
            raise ValueError(
                f"new_cis has {n} entries but the scheduler holds {self.m} "
                f"pages ({self.m_state} padded); feed one count per page"
            )
        return layout.pad_to(new_cis, self.m_state, 0, dtype=jnp.int32)

    def ingest_and_schedule(self, new_cis: jax.Array):
        """One round: ingest the CIS feed counts, pick k pages to crawl."""
        new_cis = self._pad_feed(new_cis)
        self._ensure_cand_coverage()
        self.round, (page_ids, values) = be.crawl_round(
            self.backend, self.round, new_cis,
            mesh=self.mesh, k=self.k_per_round, dt=self.round_period,
        )
        self._maybe_adapt_cand_depth()
        return page_ids, values

    def _check_feed_batch(self, feeds):
        """Shared (R, m) feed-batch validation (dtype/shape contract of
        `_pad_feed`, row-wise)."""
        if feeds.ndim != 2:
            raise ValueError(
                f"feed batch must be (n_rounds, pages), got {feeds.shape}"
            )
        if not (jnp.issubdtype(feeds.dtype, jnp.integer)
                or feeds.dtype == jnp.bool_):
            raise TypeError(
                f"feeds must have an integer dtype, got {feeds.dtype}: "
                "CIS counts are integral, and a float feed would promote "
                "the donated int32 n_cis state to f32"
            )
        n = feeds.shape[1]
        if n not in (self.m, self.m_state):
            raise ValueError(
                f"feed rows have {n} entries but the scheduler holds "
                f"{self.m} pages ({self.m_state} padded); feed one count "
                "per page"
            )

    def _pad_feeds(self, feeds) -> jax.Array:
        """Validate + pad a (R, m) feed batch to (R, m_state), sharded like
        the page state along the page axis (replicated over rounds)."""
        feeds = jnp.asarray(feeds)
        self._check_feed_batch(feeds)
        feeds = feeds.astype(jnp.int32)
        if feeds.shape[1] != self.m_state:
            feeds = jnp.concatenate(
                [feeds, jnp.zeros((feeds.shape[0],
                                   self.m_state - feeds.shape[1]),
                                  jnp.int32)], axis=1)
        return jax.device_put(
            feeds, NamedSharding(self.mesh, P(None, self.axes)))

    def _sparse_feed_batch(self, feeds) -> be.SparseFeeds:
        """Convert a dense (R, m) feed batch to the per-round COO form the
        fused macro scan consumes (`backends.SparseFeeds`): one host pass
        over the batch, with the column capacity rounded up to a power of
        two so repeated batch shapes reuse one compiled macro-round. The
        conversion is memoized on the batch's object identity (the cache
        retains the batch, so its id cannot be recycled while cached) —
        production drivers that re-send one mutated-in-place buffer should
        pass a fresh array per batch; the cache only short-circuits the
        exact same immutable batch object (e.g. benchmark reps)."""
        cached = getattr(self, "_sparse_feed_cache", None)
        if cached is not None and cached[0] is feeds:
            return cached[1]
        feeds_np = np.asarray(feeds)
        self._check_feed_batch(feeds_np)
        feeds_np = feeds_np.astype(np.int32, copy=False)
        rr, cc = np.nonzero(feeds_np)
        n_rounds = feeds_np.shape[0]
        nnz = np.bincount(rr, minlength=n_rounds)
        cap = int(max(1, 1 << (int(nnz.max()) - 1).bit_length()
                      if nnz.max() else 1))
        ids = np.full((n_rounds, cap), -1, np.int32)
        cnt = np.zeros((n_rounds, cap), np.int32)
        col = np.concatenate([np.arange(x) for x in nnz]) if rr.size else rr
        ids[rr, col] = cc
        cnt[rr, col] = feeds_np[rr, cc]
        sf = be.SparseFeeds(ids=jnp.asarray(ids), counts=jnp.asarray(cnt))
        self._sparse_feed_cache = (feeds, sf)
        return sf

    def run_rounds(self, feeds):
        """A macro-round: R = len(feeds) rounds under one jitted `lax.scan`
        (`backends.crawl_rounds`) — one dispatch, no mid-loop host sync, and
        for the fused backend O(active + k) instead of O(m) state work per
        round. Returns (page_ids (R, k), values (R, k)), stacked and equal
        to R sequential `ingest_and_schedule` calls page-id-for-page-id.

        Per-round skip-control diagnostics accumulate on device and land in
        `self.macro_diagnostics` (a `backends.RoundDiagnostics`); host-side
        candidate-depth adaptation runs once at the macro-round boundary
        (reading the device-resident watermark) instead of syncing mid-loop.
        R is a static shape — drive a deployment with one batch size to
        avoid re-jits. For the fused backend the dense batch never reaches
        the device: it converts once host-side to the COO `SparseFeeds`
        form (CIS feeds are overwhelmingly sparse in production), so feed
        ingest inside the scan is O(nnz) per round."""
        if isinstance(self.backend, be.FusedBackend):
            feeds = self._sparse_feed_batch(feeds)
        else:
            feeds = self._pad_feeds(feeds)
        self._ensure_cand_coverage()
        self.round, (page_ids, values), diag = be.crawl_rounds(
            self.backend, self.round, feeds,
            mesh=self.mesh, k=self.k_per_round, dt=self.round_period,
        )
        self.macro_diagnostics = diag
        self._maybe_adapt_cand_depth(rounds=page_ids.shape[0])
        return page_ids, values

    # -- adaptive candidate-buffer depth (ROADMAP "candidate-buffer sizing
    # -- from observed concentration") --------------------------------------
    CAND_ADAPT_INTERVAL = 16  # rounds between host-side depth decisions
    CAND_ADAPT_MARGIN = 2     # retained slack above the observed watermark

    def _cand_floor(self, k: int) -> int:
        """Smallest candidate depth whose per-shard buffer capacity still
        covers the shard-local budget — below it, `select.shard_budget`'s
        capacity clamp would cut k_loc under the global top-k requirement
        (a mid-round ValueError on one shard, or a silently short
        contribution on a winner-heavy shard of a multi-shard mesh), so the
        depth adaptation must never go there. The budget comes from
        `shard_budget` itself (auto depth, whose capacity never binds) so
        this can't drift from the clamp rule the round applies."""
        from repro.kernels import select as ksel

        bst = self.round.backend
        nb_local = bst.env_planes.shape[0] // self.mesh.size
        lanes = bst.env_planes.shape[3]
        k_loc, _ = ksel.shard_budget(
            k, self.m_state // self.mesh.size, nb_local, self.mesh.size,
            self.backend.k_local,
        )
        return -(-k_loc // (nb_local * lanes))

    def _ensure_cand_coverage(self) -> None:
        """Re-grow an adapted candidate depth that a later bandwidth raise
        (`set_bandwidth` between depth decisions) has made too small to
        cover the budget — cheap host-side arithmetic, runs every round."""
        b = self.backend
        if not (isinstance(b, be.FusedBackend) and b.adaptive_cand
                and b.cand_per_lane is not None):
            return
        floor = self._cand_floor(self.k_per_round)
        if b.cand_per_lane < floor:
            self.backend = dataclasses.replace(b, cand_per_lane=floor)

    def _maybe_adapt_cand_depth(self, rounds: int = 1) -> None:
        """Shrink (or re-grow) the fused candidate-buffer depth from the
        realized per-lane-column winner counts the round tracks in
        `FusedState.col_winners`. `auto_cand_per_lane` sizes for the worst
        case — all k winners in one block; on well-mixed shards the realized
        depth is far smaller, and every retained slot is one more
        max/select extraction pass per active block per round. Host-side by
        necessity (the depth is a static buffer shape), so a change re-jits
        the round: decisions are taken every CAND_ADAPT_INTERVAL rounds and
        only when the watermark actually moved. Exactness is never at
        stake — an undersized buffer triggers the dense fallback, which
        both restores the selection and (through the watermark) grows the
        depth back.

        rounds: how many rounds just ran — a macro-round credits its whole
        batch, so the blocking `device_get` of the watermark happens at most
        once per macro-round boundary, never inside the scan."""
        b = self.backend
        if not (isinstance(b, be.FusedBackend) and b.adaptive_cand):
            return
        self._rounds_since_cand_adapt = getattr(
            self, "_rounds_since_cand_adapt", 0) + rounds
        if self._rounds_since_cand_adapt < self.CAND_ADAPT_INTERVAL:
            return
        self._rounds_since_cand_adapt = 0
        from repro.kernels import select as ksel

        bst = self.round.backend
        k = self.k_per_round
        # The same clamp rule the round itself applies, with the depth left
        # to auto-size: its cand output IS the worst-case auto depth.
        _, auto = ksel.shard_budget(
            k, self.m_state // self.mesh.size,
            bst.env_planes.shape[0] // self.mesh.size, self.mesh.size,
            b.k_local,
        )
        cur = b.cand_per_lane or auto
        obs = int(np.asarray(jax.device_get(bst.col_winners)).max())
        target = min(max(obs + self.CAND_ADAPT_MARGIN, 2,
                         self._cand_floor(k)), auto)
        if target != cur:
            self.backend = dataclasses.replace(b, cand_per_lane=target)
        # Fresh observation window either way.
        self.round = dataclasses.replace(
            self.round,
            backend=bst._replace(col_winners=jnp.zeros_like(bst.col_winners)),
        )

    # -- decentralized parameter refresh (§5.2 / App. E) -------------------
    def update_pages(self, page_ids, env_updates: Env):
        """Refresh the environment parameters of `page_ids` in place.

        env_updates: raw Env fields of shape (n_upd,) (new delta/mu/lam/nu
        per updated page). Shard-local and block-granular: only the touched
        rows of the backend state are rewritten (fused: the touched plane
        columns + the touched blocks' bounds), with the state buffer donated
        so nothing else is copied. Normalization uses the frozen
        construction-time mu_total — greedy selection is scale-invariant, so
        no global renormalization pass is ever needed.
        """
        ids_np = np.asarray(page_ids)
        if ids_np.size and (ids_np.min() < 0 or ids_np.max() >= self.m):
            raise ValueError(
                f"page ids must be in [0, {self.m}); got "
                f"[{ids_np.min()}, {ids_np.max()}]"
            )
        ids = jnp.asarray(ids_np, jnp.int32)
        d_new = derive(env_updates, mu_total=self.mu_total)
        block_ids = None
        if isinstance(self.round.backend, be.FusedState):
            bp = (self.round.backend.env_planes.shape[2] *
                  self.round.backend.env_planes.shape[3])
            block_ids = jnp.asarray(np.unique(ids_np // bp), jnp.int32)
            # The host-side dense oracle syncs lazily on `.d` access.
            self._d_pending.append((ids, d_new))
        new_bstate = be.refresh_pages(self.backend, self.round.backend, ids,
                                      d_new, block_ids)
        self.round = dataclasses.replace(self.round, backend=new_bstate)

    def ingest_crawl_results(self, page_ids, tau, n_cis, fresh):
        """Close the crawl -> estimate -> refresh -> re-select loop (App. E).

        tau/n_cis/fresh: (n_pages, n_intervals) crawl-log arrays for
        `page_ids` — interval lengths, CIS counts, and whether the crawl
        found the page unchanged. Fits the CIS-quality MLE per page
        (`core.estimation.fit_mle_pages`), maps it back to raw env
        parameters (importance mu is unchanged — it comes from request logs,
        not crawl logs), and applies `update_pages`. Returns the fitted
        `CISQuality` for observability.
        """
        q = estimation.fit_mle_pages(tau, n_cis, fresh)
        ids = jnp.asarray(np.asarray(page_ids), jnp.int32)
        mu = self._gather_mu_t(ids) * self.mu_total
        self.update_pages(page_ids, estimation.quality_to_env(q, mu))
        return q

    def _gather_mu_t(self, ids: jax.Array) -> jax.Array:
        """Normalized importance of `ids`, read from the live backend state.

        For the fused backend this gathers the MU_T plane columns of the
        packed tensor directly — an O(n_upd) gather. Going through the `.d`
        oracle instead would force the lazy pending-update fold (one
        full-plane scatter per queued `update_pages` batch — pathologically
        slow on CPU for large scatter windows) just to read a handful of mu
        values; the packed planes are always current because `update_pages`
        writes them eagerly."""
        from repro.kernels import layout

        b = self.round.backend
        if not isinstance(b, be.FusedState):
            return self.d.mu_t[ids]
        bp = b.env_planes.shape[2] * b.env_planes.shape[3]
        return b.env_planes[ids // bp, layout.MU_T,
                            (ids % bp) // layout.LANES, ids % layout.LANES]

    # -- checkpointing -----------------------------------------------------
    def state_dict(self):
        """Full scheduler state incl. backend warm-start state (per-shard
        thresholds, block bounds, packed planes) AND the host-side
        adaptation counters (`adapt` key): the adapted candidate-buffer
        depth and the rounds elapsed in the current observation window.
        Without them a restore silently reverts to the auto depth and
        restarts the window — the first post-restore rounds re-jit with a
        surprise buffer shape. Snapshot with jax.device_get before running
        further (donating) rounds."""
        b = self.backend
        cand = b.cand_per_lane if isinstance(b, be.FusedBackend) else None
        return {
            "tau_elap": self.round.tau_elap,
            "n_cis": self.round.n_cis,
            "crawl_clock": self.round.crawl_clock,
            "backend": self.round.backend,
            "adapt": {
                # -1 encodes "auto" (cand_per_lane=None) for the array-only
                # checkpoint store.
                "cand_per_lane": jnp.int32(-1 if cand is None else cand),
                "rounds_since_cand_adapt": jnp.int32(
                    getattr(self, "_rounds_since_cand_adapt", 0)),
            },
        }

    def load_state_dict(self, sd) -> None:
        sh = NamedSharding(self.mesh, P(self.axes))
        backend_state = self.round.backend
        # jnp.copy decouples from caller-held arrays: subsequent rounds
        # donate the state, which must never invalidate the caller's sd.
        own = lambda v, dt=None: jnp.copy(jnp.asarray(v, dt))
        if sd.get("backend") is not None:
            # Re-shard each restored leaf like the corresponding live leaf
            # (old checkpoints without backend state keep the cold init).
            backend_state = jax.tree.map(
                lambda ref, val: jax.device_put(own(val, ref.dtype),
                                                ref.sharding),
                backend_state, sd["backend"],
            )
        if sd.get("adapt") is not None and isinstance(self.backend,
                                                      be.FusedBackend):
            # Resume the adapted buffer shape + observation window so a
            # restored scheduler keeps its steady-state depth (no surprise
            # re-jit, no cold re-observation) — old snapshots without the
            # key keep the configured depth.
            self._rounds_since_cand_adapt = int(
                sd["adapt"]["rounds_since_cand_adapt"])
            cand = int(sd["adapt"]["cand_per_lane"])
            cand = None if cand < 0 else cand
            if cand != self.backend.cand_per_lane:
                self.backend = dataclasses.replace(self.backend,
                                                   cand_per_lane=cand)
        self.round = be.RoundState(
            tau_elap=jax.device_put(own(sd["tau_elap"]), sh),
            n_cis=jax.device_put(own(sd["n_cis"]), sh),
            crawl_clock=own(sd["crawl_clock"]),
            backend=backend_state,
        )
