"""CrawlScheduler — the deployable service wrapper.

Holds the sharded page state, executes budgeted scheduling rounds, ingests CIS
feeds, and exposes the two production properties the paper highlights:

  * **elastic bandwidth** (App. D): `set_bandwidth` changes the per-round
    budget k (or round period) with *zero* recomputation — the greedy rule is
    self-adapting;
  * **decentralized parameter refresh**: per-page (Delta, mu, lam, nu) updates
    touch only the owning shard (value tables are rebuilt shard-locally).

Selection backends: exposure-table lookup (default), the dense Pallas kernel
(`use_kernel=True`), or the fused select pipeline (`use_fused=True`): the env
is packed once at construction / parameter refresh (`kernels.layout`), pages
are padded to block alignment (padding scores -inf, never selected), and the
previous round's k-th value warm-starts the selection threshold so blocks
whose static asymptote bound can't reach it are skipped. Selection stays
provably identical to dense top-k (see `kernels.select`).

Fault tolerance: the entire scheduler state is two arrays; `state_dict()` /
`load_state_dict()` plug into repro.checkpoint for atomic, sharded, resumable
snapshots. Loss of a shard loses only the staleness clocks of its pages (they
re-initialize as "just crawled" — conservative under-crawling that self-heals)
while the budget re-normalizes to the surviving shard count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import tables
from repro.core.values import Env, derive
from repro.sched.distributed import ShardedSchedState, sharded_crawl_step

# Threshold warm-start relaxation: the next round's k-th value can sit below
# the current one (winners reset to ~0 value), so the carried threshold is
# relaxed; too-aggressive thresholds only cost a dense fallback, never
# exactness.
THRESH_HYSTERESIS = 0.9


class CrawlScheduler:
    def __init__(
        self,
        env: Env,
        mesh: Mesh,
        bandwidth: float,
        round_period: float = 1.0,
        n_terms: int = 8,
        table_grid: int | None = 128,
        use_kernel: bool = False,
        use_fused: bool = False,
        block_rows: int | None = None,
    ):
        self.mesh = mesh
        self.axes = tuple(mesh.axis_names)
        self.round_period = float(round_period)
        self.bandwidth = float(bandwidth)
        self.n_terms = n_terms
        self.use_kernel = use_kernel
        self.use_fused = use_fused
        sh = NamedSharding(mesh, P(self.axes))
        self.m = env.m
        self._shard = None
        self._thresh = None
        self._bounds = None
        if use_fused:
            from repro.kernels import layout

            block_rows = block_rows or layout.DEFAULT_BLOCK_ROWS
            m_state = layout.padded_size(self.m, block_rows,
                                         n_shards=mesh.size)
            # Pad the raw env so derived state/env sizes agree; padding pages
            # (mu = 0) normalize away and score -inf in the fused kernel.
            pad = m_state - self.m
            if pad:
                env = Env(
                    delta=jnp.concatenate([env.delta, jnp.ones((pad,))]),
                    mu=jnp.concatenate([env.mu, jnp.zeros((pad,))]),
                    lam=jnp.concatenate([env.lam, jnp.zeros((pad,))]),
                    nu=jnp.concatenate([env.nu, jnp.zeros((pad,))]),
                )
            env = jax.device_put(env, sh)
            self.d = derive(env, mu_total=jnp.sum(env.mu))
            self._shard = layout.pack_shard(
                self.d, n_terms=n_terms, block_rows=block_rows
            )
            self._bounds = layout.asym_block_bounds(self._shard.env)
            # Threshold warm-start is sound per shard only against that
            # shard's own k-th value; carrying the *global* k-th would push
            # low-value shards into the dense fallback every round (exact but
            # slow). Until per-shard thresholds are threaded through the
            # candidate exchange (see ROADMAP), skip-by-threshold is enabled
            # on single-shard meshes only.
            self._warm_thresh = mesh.size == 1
            self._thresh = jnp.float32(-jnp.inf)
            self.table = None
        else:
            m_state = self.m
            env = jax.device_put(env, sh)
            self.d = derive(env)
            self.table = (
                tables.build_ncis_table(self.d, n_terms=n_terms,
                                        n_grid=table_grid)
                if table_grid
                else None
            )
        self.m_state = m_state
        self.state = ShardedSchedState(
            tau_elap=jax.device_put(jnp.zeros((m_state,), jnp.float32), sh),
            n_cis=jax.device_put(jnp.zeros((m_state,), jnp.int32), sh),
            crawl_clock=jnp.int32(0),
        )

    @property
    def k_per_round(self) -> int:
        # A budget above the shard size just means "crawl everything".
        k = max(1, int(round(self.bandwidth * self.round_period)))
        return min(k, self.m)

    def set_bandwidth(self, bandwidth: float) -> None:
        """App. D: adapting to a new budget is just a new k — no re-solve."""
        self.bandwidth = float(bandwidth)

    def ingest_and_schedule(self, new_cis: jax.Array):
        """One round: ingest the CIS feed counts, pick k pages to crawl."""
        if new_cis.shape[0] < self.m_state:
            new_cis = jnp.concatenate([
                new_cis,
                jnp.zeros((self.m_state - new_cis.shape[0],), new_cis.dtype),
            ])
        k = self.k_per_round
        self.state, (page_ids, values) = sharded_crawl_step(
            self.state,
            new_cis,
            self.d if self._shard is None else None,
            self.table,
            self.mesh,
            k,
            self.round_period,
            self.n_terms,
            self.use_kernel,
            env_planes=self._shard.env if self._shard is not None else None,
            thresh=self._thresh,
            bounds=self._bounds,
        )
        if self._shard is not None and self._warm_thresh:
            self._thresh = values[k - 1] * THRESH_HYSTERESIS
        return page_ids, values

    def state_dict(self):
        return {
            "tau_elap": self.state.tau_elap,
            "n_cis": self.state.n_cis,
            "crawl_clock": self.state.crawl_clock,
        }

    def load_state_dict(self, sd) -> None:
        sh = NamedSharding(self.mesh, P(self.axes))
        self.state = ShardedSchedState(
            tau_elap=jax.device_put(sd["tau_elap"], sh),
            n_cis=jax.device_put(sd["n_cis"], sh),
            crawl_clock=jnp.asarray(sd["crawl_clock"]),
        )
