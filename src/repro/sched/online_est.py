"""Streaming on-device change-rate estimation — the in-scan learning loop.

The batch estimation path (`CrawlScheduler.ingest_crawl_results` ->
`core.estimation.fit_mle_pages` -> `update_pages`) is a host round trip: crawl
outcomes leave the device, a full MLE runs over retained logs, and the refresh
ships back. This module closes the loop *inside* the macro-round scan
(`sched.backends._fused_macro_rounds`), in the online-estimation spirit of
Avrachenkov–Patil–Thoppe ("Online Algorithms for Estimating Change Rates of
Web Pages") but with the closed-form conditional-moment estimator of
`core.estimation` (`StreamStats`) and the source paper's App. E mapping:

  * Per-page streaming-estimator planes (`estimation.StreamStats`) appended
    to `FusedState` (`FusedBackend(online_est=True)`): device-resident,
    sharded alongside the pages, checkpointed by field name like every other
    `FusedState` plane.

  * Per round, `ingest_outcomes` folds that round's slice of the crawl
    OUTCOME batch (`CrawlScheduler.run_rounds(feeds, outcomes=...)` ->
    `SparseOutcomes`) into the statistics — O(cap) gathers + scatters, zero
    host transfers inside the scan.

  * Once per macro batch, `apply_estimates` re-derives the packed env planes
    for the touched pages ON DEVICE: `stream_quality` -> App. E `Env`
    mapping -> `core.values.derive` -> `layout.repack_pages`, then refreshes
    every env-dependent bound row of the touched blocks with exactly the
    semantics of `tiered.refresh_block_params` (asym/slope recomputed, anchor
    dropped, CIS-mass rows reset). The estimate -> policy loop never leaves
    the device.

Outcome observations are SELF-CONTAINED: each `SparseOutcomes` row carries
the freshness bit AND the covariates of the crawl it resolves — the interval
length tau and CIS count n_cis the scheduler selected on. The caller already
owns both (the crawl-order stream run_rounds returns dates every crawl, and
the caller is the source of the CIS feed stream), so echoing them costs no
new device reads — and it makes pairing trivial and exact. The alternative
(latching covariates on device at selection, joining by page id when the
outcome returns) silently MISPAIRS whenever a page is re-crawled while its
outcome is in flight — routine under macro batching, where outcomes for
batch j can enter no earlier than batch j+1 — and that mispairing
decorrelates the freshness bit from n_cis, destroying the CIS-precision
estimate for exactly the hot pages that dominate the crawl budget.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import estimation
from repro.core.values import Env, derive
from repro.kernels import layout
from repro.sched import tiered

# Estimated delta is floored before repacking: the packed V_INF plane is
# mu_t / delta, so a transient near-zero delta estimate would explode a
# block's asymptote bound (costing skip efficiency, never exactness).
# Matches the floor of `sim.instances.uniform_instance` within a decade.
DELTA_FLOOR = 1e-4


class SparseOutcomes(NamedTuple):
    """A crawl-outcome batch in the same per-shard COO form as
    `backends.SparseFeeds`: for round r and shard s, the self-contained
    crawl observations arriving that round — global page id, whether the
    crawl found a change, and the covariates of the crawled window (tau,
    n_cis — see module docstring). Padded to a static `cap` with id = -1 /
    tau = -1 rows (dropped); ids must be unique within a (round, shard)
    cell. Built host-locally by `CrawlScheduler._sparse_outcome_batch`
    under the `outcome_cap` capacity contract, spec P(None, axes, None)."""

    ids: jax.Array      # (R, n_shards, cap) i32 global page ids, -1 pad
    changed: jax.Array  # (R, n_shards, cap) i32 1 = crawl found a change
    tau: jax.Array      # (R, n_shards, cap) f32 crawled interval, -1 pad
    n_cis: jax.Array    # (R, n_shards, cap) i32 CIS count of the interval


def init_est(m_state: int) -> estimation.StreamStats:
    """Fresh (all-zero) streaming-estimator planes, (m_state,) each. The
    estimation prior enters at read time
    (`apply_estimates(prior_a, prior_b, prior_w)`), not here — zero
    statistics under shrinkage ARE the prior."""
    return estimation.stream_init((m_state,))


def ingest_outcomes(stats: estimation.StreamStats, oidx: jax.Array,
                    changed: jax.Array, tau: jax.Array,
                    n_cis: jax.Array,
                    quarantine: jax.Array | None = None
                    ) -> estimation.StreamStats:
    """Fold one round's outcome slice into the streaming statistics.

    oidx: (cap,) shard-LOCAL page indices with the out-of-bounds sentinel
    for padding / other shards' rows; changed: (cap,) 0/1; tau/n_cis:
    (cap,) the crawled window's covariates (tau < 0 = padding row). O(cap)
    gathers + scatters; a page id may appear at most once per call (COO
    cells are id-unique per round).

    quarantine: optional (cap,) bool — rows flagged True are discarded
    without touching the statistics. The degraded-mode watchdog
    (`FusedBackend(degraded=True)`) flags outcomes of pages whose signal
    channel is silent: their crawled window's n_cis is censored (signals
    fired but never arrived), and folding it in would bias the streaming
    gamma/alpha estimates toward zero. None skips the mask entirely, so
    healthy callers trace no extra operation.
    """
    m_local = stats.n_obs.shape[0]
    tau = jnp.asarray(tau, jnp.float32)
    live = (oidx >= 0) & (oidx < m_local) & (tau >= 0.0)
    if quarantine is not None:
        live = live & ~quarantine
    idx = jnp.where(live, oidx, m_local)
    row = estimation.StreamStats(
        *(p.at[oidx].get(mode="clip") for p in stats))
    z = 1.0 - jnp.clip(changed.astype(jnp.float32), 0.0, 1.0)
    upd = estimation.stream_update(row, jnp.maximum(tau, 0.0),
                                   n_cis.astype(jnp.float32), z)
    return estimation.StreamStats(
        *(p.at[idx].set(u, mode="drop") for p, u in zip(stats, upd)))


def apply_estimates(stats: estimation.StreamStats, env_shard: jax.Array,
                    touched: jax.Array, bb: tiered.BlockBounds,
                    beta_max: jax.Array, cis_mass: jax.Array, *,
                    min_obs: float, prior_a: float = 0.0,
                    prior_b: float = 0.0, prior_w: float = 0.0):
    """Device-side estimate -> policy refresh for one shard, once per macro
    batch: repack the packed env planes of the touched pages from their
    current streaming estimates and refresh every env-dependent bound row of
    the touched blocks (mirroring `tiered.refresh_block_params` +
    `FusedBackend.update_pages` exactly: asym/slope/beta_max recomputed,
    anchor dropped to the never-evaluated sentinel, CIS mass reset — the
    touched blocks re-evaluate exactly next round).

    touched: (T,) shard-LOCAL page ids with the out-of-bounds sentinel
    (duplicates fine — every duplicate writes the same derived row). Pages
    with fewer than `min_obs` resolved observations keep their current
    packed parameters (the never/rarely-crawled page holds its prior);
    prior_a/prior_b/prior_w shrink small-sample estimates toward the prior
    (`estimation.stream_quality` — the closed-loop explore/exploit guard).
    Returns (env_planes, BlockBounds, beta_max, cis_mass).

    Cost: O(T) for the repack + one O(m_local) pass for the block-row
    reductions — per macro batch, not per round, so amortized over R rounds
    it is a fraction of one selection pass.
    """
    m_local = stats.n_obs.shape[0]
    nb, _, block_rows, lanes = env_shard.shape
    bp = block_rows * lanes
    n_obs = stats.n_obs.at[touched].get(mode="fill", fill_value=0.0)
    ok = (touched >= 0) & (touched < m_local) & (n_obs >= min_obs)
    ids = jnp.where(ok, touched, m_local)
    row = estimation.StreamStats(
        *(p.at[touched].get(mode="clip") for p in stats))
    q = estimation.stream_quality(row, prior_a=prior_a, prior_b=prior_b,
                                  prior_w=prior_w)
    # App. E mapping (quality_to_env) on device; importance is not estimated
    # here — each page keeps its packed normalized mu_t, so the repack needs
    # no global renormalization (mu_total folds to 1 on the packed plane).
    mu_t = layout.gather_plane(env_shard, jnp.minimum(touched, m_local - 1),
                               layout.MU_T)
    env_rows = Env(
        delta=jnp.maximum(q.delta, DELTA_FLOOR),
        mu=mu_t,
        lam=jnp.clip(q.recall, 0.0, 1.0),
        nu=jnp.maximum(q.gamma * (1.0 - q.precision), 0.0),
    )
    d_rows = derive(env_rows, mu_total=1.0)
    env2 = layout.repack_pages(env_shard, ids, d_rows)
    blk = jnp.zeros((nb,), bool).at[ids // bp].set(True, mode="drop")
    # Full block-row reductions merged under the touched mask: at macro-batch
    # cadence one O(m_local) pass beats gathering whole blocks per id.
    bb2 = tiered.BlockBounds(
        asym=jnp.where(blk, layout.asym_block_bounds(env2), bb.asym),
        slope=jnp.where(blk, tiered._block_slope(layout.block_mu_max(env2)),
                        bb.slope),
        blk_max=jnp.where(blk, 0.0, bb.blk_max),
        last_eval=jnp.where(blk, jnp.int32(-1), bb.last_eval),
    )
    beta2 = jnp.where(blk, layout.block_beta_max(env2), beta_max)
    mass2 = jnp.where(blk, 0.0, cis_mass)
    return env2, bb2, beta2, mass2
