"""Tiered (lazy) value recomputation — paper Appendix G, TPU-adapted.

Production insight: most pages' crawl values are nowhere near the selection
threshold most of the time, so recomputing them every round is wasted work.
The paper's system buckets URLs into tiers and recomputes high tiers more
often. Vector-hardware adaptation: pages are grouped in fixed *blocks*; each
round we maintain a per-block optimistic *bound* on the max value in the block
and evaluate exact values only for blocks whose bound reaches the current
selection threshold (the k-th best value of the previous round, relaxed by a
hysteresis factor).

The bound uses monotonicity of V in the exposure u: a block's values can only
have grown since last evaluated by at most
    dV <= mu_t_max * (e^{-u_min_blk}) * dpsi  ~  block_slope * elapsed,
and we additionally cap by the per-block static asymptote max(mu_t/delta).
Selection is *approximate* (staleness-bounded, like the paper's production
tiering); `benchmarks/sched_scale.py` measures the agreement vs exact
selection and the fraction of block evaluations saved.

Like the paper's production system, tiering pays off when pages are grouped
into blocks by value scale (the paper's "tiers": URLs classified by crawl
value) — under value-correlated blocks most low-tier blocks sit below the
selection threshold and are skipped; randomly-mixed blocks each contain a
near-threshold page and legitimately evaluate every round.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tables
from repro.core.values import DerivedEnv


def _block_slope(mu_blk: jax.Array) -> jax.Array:
    """Max value-growth-rate bound per block from its max normalized
    importance: dV/dt = mu_t * alpha * e^{-alpha iota} * psi' is bounded by
    mu_t * e^{-1} with a 2x safety margin (shared by TierState, BlockBounds,
    and the post-repack refresh so the bound math never diverges)."""
    return mu_blk * jnp.exp(-1.0) * 2.0


class BlockBounds(NamedTuple):
    """Per-block optimistic bounds for the *fused* select pipeline
    (`kernels.select.fused_select`).

    Same *approximate* bound construction as `TierState` — growth capped by
    `slope * elapsed` and by the static asymptote — but tracking only the
    per-block maxima the fused kernel already emits (candidate slot 0), never
    an m-element cached value vector, so it composes with the
    never-materialize-values guarantee.

    Exactness caveat (same as the paper's production tiering): the slope term
    bounds only *time-driven* value growth. An ingested CIS jumps a page's
    exposure by beta instantly, which this bound ignores, so a skipped block
    that received signals can transiently hide a winner (the select-time
    fallback protects against over-aggressive thresholds and candidate
    overflow, not unsound bounds). Blocks that received fresh CIS must
    therefore account for the jump, one of two ways (both keep selection
    exactly equal to dense top-k; `backends.FusedBackend.cis_rule`):

      * "mass" (default): accrue the worst-case clock displacement
        beta_max * n_cis into a per-block accumulator added to the elapsed
        term (`accumulate_cis_mass` / `current_block_bounds`) — the bound
        stays finite and lightly-fed blocks stay skipped;
      * "remark": drop the anchor — mark the block never-evaluated
        (`last_eval = -1` -> +inf bound -> exact re-evaluation next round),
        the blunt rule the mass accumulator refines.

    The static `layout.asym_block_bounds` alone (the default) is a
    true upper bound with no re-evaluation rule needed.

    Sentinel convention: `last_eval = -1` means "never evaluated" (+inf
    bound). Round indices are valid from 0 up — `crawl_clock` starts at 0,
    so 0 must mean "evaluated on the first round", not "never".
"""

    asym: jax.Array       # (n_blocks,) static bound max(mu_t/delta)
    slope: jax.Array      # (n_blocks,) max value growth rate bound
    blk_max: jax.Array    # (n_blocks,) block max at last exact evaluation
    last_eval: jax.Array  # (n_blocks,) round index of last exact evaluation


def init_block_bounds(env_planes: jax.Array) -> BlockBounds:
    """Build bounds from packed env planes (`kernels.layout.pack_shard`)."""
    from repro.kernels import layout

    asym = layout.asym_block_bounds(env_planes)
    nb = env_planes.shape[0]
    return BlockBounds(
        asym=asym,
        slope=_block_slope(layout.block_mu_max(env_planes)),
        blk_max=jnp.zeros((nb,), jnp.float32),
        last_eval=jnp.full((nb,), -1, jnp.int32),
    )


def current_block_bounds(
    bb: BlockBounds,
    round_idx: jax.Array,
    dt: float,
    cis_mass: jax.Array | None = None,
) -> jax.Array:
    """Optimistic per-block bound for this round. Values only shrink on crawl
    and grow at most `slope` per unit time since the last exact evaluation,
    capped by the static asymptote; never-evaluated blocks (`last_eval = -1`,
    NOT 0 — round 0 is a valid evaluation round) get +inf.

    cis_mass (the CIS-mass re-evaluation rule, `accumulate_cis_mass`):
    accumulated exposure-clock displacement from signals the block received
    since its last exact evaluation, in the same time units as `elapsed` —
    an ingested CIS advances a page's effective clock iota = tau + beta * n
    by beta instantly, which the elapsed term (d iota / dt = 1) cannot see.
    Adding the mass to the elapsed displacement keeps the slope bound a true
    upper bound under signal jumps WITHOUT dropping the anchor to +inf the
    way the blanket re-mark does, so lightly-fed blocks stay skipped."""
    elapsed = (round_idx - bb.last_eval).astype(jnp.float32) * dt
    if cis_mass is not None:
        elapsed = elapsed + cis_mass
    bound = jnp.minimum(bb.blk_max + bb.slope * elapsed, bb.asym)
    return jnp.where(bb.last_eval < 0, jnp.inf, bound)


def accumulate_cis_mass(
    cis_mass: jax.Array,
    beta_max: jax.Array,
    blk_cis: jax.Array,
    evaluated: jax.Array,
) -> jax.Array:
    """Fold one round's CIS feed into the per-block mass accumulators.

    blk_cis: (n_blocks,) integer CIS counts received by each block's pages
    this round. Evaluated blocks reset first (their fresh anchor reflects
    values *before* this round's feed was ingested, so this round's mass
    still applies to them), then every block accrues beta_max * n — the
    worst-case exposure-clock displacement of its best page. The mass is
    consumed by `current_block_bounds` and resolves the ROADMAP
    "adaptive-bounds steady-state tuning" item: a single weak signal now
    bumps the bound by one beta-slope step instead of forcing a whole-block
    re-evaluation, while heavy feeds still grow the bound past the threshold
    (or to +inf via the BIG-guarded beta) and re-evaluate exactly."""
    mass = jnp.where(evaluated, 0.0, cis_mass)
    return mass + beta_max * blk_cis.astype(jnp.float32)


def update_block_bounds(
    bb: BlockBounds,
    blk_max: jax.Array,
    evaluated: jax.Array,
    round_idx: jax.Array,
) -> BlockBounds:
    """Fold the fused kernel's per-block maxima (slot-0 candidates) back into
    the bounds; skipped blocks keep their stale anchor."""
    return BlockBounds(
        asym=bb.asym,
        slope=bb.slope,
        blk_max=jnp.where(evaluated, blk_max, bb.blk_max),
        last_eval=jnp.where(evaluated, round_idx, bb.last_eval),
    )


def refresh_block_params(
    bb: BlockBounds, env_planes: jax.Array, block_ids: jax.Array
) -> BlockBounds:
    """Re-derive the env-dependent rows of the touched blocks after a
    parameter repack (`kernels.layout.repack_pages` /
    `CrawlScheduler.update_pages`): the static asymptote and slope change
    with the new (Delta, mu) and the stale block max is no longer an anchor,
    so last_eval resets to the never-evaluated sentinel -1 — the next
    round's bound is +inf and the block re-evaluates exactly.
    Block-granular: untouched rows are not rewritten. Out-of-range sentinel
    block ids (the shard-local repack pads each shard's touched-block batch
    to a static width with id = n_blocks_local) are dropped by every
    scatter, so padding rows touch nothing."""
    from repro.kernels import layout

    mu_new = layout.block_mu_max(env_planes, block_ids)
    return BlockBounds(
        asym=layout.refresh_block_bounds(env_planes, bb.asym, block_ids),
        slope=bb.slope.at[block_ids].set(_block_slope(mu_new), mode="drop"),
        blk_max=bb.blk_max.at[block_ids].set(0.0, mode="drop"),
        last_eval=bb.last_eval.at[block_ids].set(-1, mode="drop"),
    )


class TierState(NamedTuple):
    cached_vals: jax.Array    # (m,) last computed value per page
    blk_asym: jax.Array       # (n_blocks,) static bound max(mu_t/delta)
    blk_slope: jax.Array      # (n_blocks,) max value growth rate bound
    last_eval: jax.Array      # (n_blocks,) round index of last exact eval


def init_tiers(d: DerivedEnv, block: int) -> TierState:
    m = d.delta.shape[0]
    nb = m // block
    asym = (d.mu_t / jnp.maximum(d.delta, 1e-12)).reshape(nb, block).max(axis=1)
    # dV/dt = mu_t * alpha * e^{-alpha iota} * psi <= mu_t * (alpha iota e^{-alpha iota} <= e^{-1}) ...
    # conservative: mu_t * max(alpha * psi) bounded by mu_t (psi <= iota).
    mu_blk = d.mu_t.reshape(nb, block).max(axis=1)
    slope = _block_slope(mu_blk)
    return TierState(
        cached_vals=jnp.zeros((m,), jnp.float32),
        blk_asym=asym,
        blk_slope=slope,
        last_eval=jnp.full((nb,), -1, jnp.int32),
    )


def tiered_select(
    state_tau: jax.Array,
    state_ncis: jax.Array,
    d: DerivedEnv,
    table: tables.ValueTable,
    tiers: TierState,
    round_idx: jax.Array,
    dt: float,
    k: int,
    hysteresis: float = 0.8,
):
    """Approximate top-k with per-block lazy evaluation.

    Returns (top_values, top_ids, new_tiers, evaluated_blocks_fraction).
    """
    m = state_tau.shape[0]
    nb = tiers.last_eval.shape[0]
    block = m // nb

    # Current optimistic bound per block.
    elapsed = (round_idx - tiers.last_eval).astype(jnp.float32) * dt
    cached_blk_max = tiers.cached_vals.reshape(nb, block).max(axis=1)
    bound = jnp.minimum(cached_blk_max + tiers.blk_slope * elapsed, tiers.blk_asym)

    # Threshold: k-th best cached value, relaxed. Never-evaluated blocks
    # (last_eval = -1; 0 means "evaluated at round 0") always evaluate.
    thresh = jax.lax.top_k(tiers.cached_vals, k)[0][-1] * hysteresis
    evaluate = (bound >= thresh) | (tiers.last_eval < 0)

    # Exact values for selected blocks only (masked compute: on TPU the Pallas
    # kernel skips non-selected blocks entirely via pl.when; here we compute
    # under a mask so the semantics match).
    u = tables.exposure(state_tau, state_ncis, d)
    fresh_vals = tables.lookup(table, u)
    keep = jnp.repeat(evaluate, block)
    vals = jnp.where(keep, fresh_vals, tiers.cached_vals)

    top_v, top_i = jax.lax.top_k(vals, k)
    # Selected pages are about to be crawled: their cached value drops to ~0,
    # letting their block's bound decay instead of pinning it at the stale max.
    vals = vals.at[top_i].set(0.0)
    new_tiers = TierState(
        cached_vals=vals,
        blk_asym=tiers.blk_asym,
        blk_slope=tiers.blk_slope,
        last_eval=jnp.where(evaluate, round_idx, tiers.last_eval),
    )
    return top_v, top_i, new_tiers, jnp.mean(evaluate.astype(jnp.float32))
