"""Request-driven importance: the on-device layer behind the serving front.

The paper's objective is freshness *at request time*, so at production scale
the importance vector `mu` is not a config input — it is estimated from the
live stream of user requests. This module owns that estimate as device
state riding `FusedState` (the `req` field, None when the layer is off so
off-path jit signatures and old checkpoints stay byte-identical — the same
lazy-optional pattern as `est`/`emit_res`/`stale`):

  * `ReqState.ewma` — the per-page decayed request count. Every logged
    batch applies one decay step `ewma <- decay * ewma + counts`, so after
    T batches the plane holds the closed form
    sum_t decay^(T-1-t) * counts_t (property-tested): recent traffic
    dominates, dead pages decay toward zero.
  * `ReqState.delta` — the page's raw change rate, captured at attach time.
    The packed planes store only mu-products (MU_T, V_INF = mu_t / delta);
    re-deriving V_INF after a mu refold needs delta back, and stashing the
    raw column here keeps the refold bit-identical to a from-scratch
    `layout.pack_shard` (`V_INF = mu_t / max(delta, eps)`, the exact
    `_page_planes` expression) without growing the packed tensor.
  * `ReqState.prior` — a static per-page link-score prior (PageRank-ish),
    1.0 when not supplied. One of the pluggable importance sources below.

Importance sources are linear blends (SNIPPETS.md snippet 1 / Scrapy's
multi-signal queue strategies, ported as data): an `ImportanceSource`
weights {request EWMA, link prior, uniform} plus an additive floor that
keeps never-requested pages crawlable. `REQUEST_EWMA`, `LINK_PRIOR`, and
`UNIFORM` are the preset ablation points (`sim.driver.
run_importance_ablation` replays all of them over one realized trace).

`fold_into_planes` is the periodic MU_T refold — the point where drifting
request mass re-anchors the frozen normalizer. The contract: greedy
selection is scale-invariant in mu_total, so the fold may pick ANY positive
normalizer without changing selections *at a fixed mu vector*; what it must
guarantee is (a) every shard normalizes by the SAME total (else cross-shard
ranking breaks) and (b) every host computes that total bitwise-identically
(else multi-host selection diverges). Both hold by construction: each shard
reduces its own mu column in a fixed order and a single psum combines the
per-shard partials — the same one-collective shape as
`CrawlScheduler.from_local_env`'s mu sum. The new replicated total is
returned so the scheduler can re-anchor its host-side `mu_total` (consumed
by later `update_pages` derivations) without a device readback.

Everything here is jitted with donated state and runs shard-locally (the
fold's psum is the only collective; logging and serving are collective-free
like the sparse feed path), so the serve front never syncs the host.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.values import BIG
from repro.core.values import _EPS as _MU_EPS
from repro.kernels import layout
from repro.sched.distributed import _shard_linear_index, _shard_map


class ReqState(NamedTuple):
    """Per-page request-importance planes, sharded like tau_elap."""

    ewma: jax.Array    # (m_state,) f32 decayed request counts
    delta: jax.Array   # (m_state,) f32 raw change rate (pad fill 1.0,
    #                    matching `layout.pack_shard`)
    prior: jax.Array   # (m_state,) f32 link-score prior (pad fill 0.0)
    valid: jax.Array   # (m_state,) f32 1.0 real page / 0.0 padding. NOT
    #                    the packed VALID plane: the fused init packs a
    #                    pre-padded env, so that plane is 1.0 everywhere
    #                    and padding is excluded via mu = 0 — which is
    #                    exactly what the fold must reproduce (an additive
    #                    floor would otherwise make padding crawlable).


class ImportanceSource(NamedTuple):
    """A pluggable mu source: mu = valid * (w_request * ewma
    + w_prior * prior + w_uniform + floor). Static per fold call —
    weights are blend *strategies*, not per-round data."""

    w_request: float = 0.0
    w_prior: float = 0.0
    w_uniform: float = 0.0
    floor: float = 0.0


# The ablation presets. REQUEST_EWMA keeps a small uniform floor so pages
# nobody has asked for yet still get crawled (explore term).
REQUEST_EWMA = ImportanceSource(w_request=1.0, floor=1e-3)
LINK_PRIOR = ImportanceSource(w_prior=1.0, floor=1e-3)
UNIFORM = ImportanceSource(w_uniform=1.0)


def init_req(delta, prior, m_state: int) -> ReqState:
    """Host-side build of the request planes (pad like `pack_shard`: delta
    1.0 so derived planes stay finite, prior 0.0 so padding mass is zero).
    `delta`/`prior` cover the raw pages of the caller's range; prior=None
    means the uniform 1.0 prior."""
    delta = jnp.asarray(delta, jnp.float32)
    if prior is None:
        prior = jnp.ones(delta.shape, jnp.float32)
    return ReqState(
        ewma=jnp.zeros((m_state,), jnp.float32),
        delta=layout.pad_to(delta, m_state, 1.0),
        prior=layout.pad_to(jnp.asarray(prior, jnp.float32), m_state, 0.0),
        valid=layout.pad_to(jnp.ones(delta.shape, jnp.float32),
                            m_state, 0.0),
    )


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "decay"),
    donate_argnames=("req",),
)
def log_batch(req: ReqState, ids: jax.Array, counts: jax.Array, *,
              mesh: Mesh, decay: float) -> ReqState:
    """One logged request batch: decay every page once, scatter-add the
    batch's counts. `ids`/`counts` are the per-shard routed COO rows
    (n_shards, cap) — global page ids with the -1 padding sentinel, built
    host-locally by `CrawlScheduler._route_requests` exactly like the
    sparse feed batches. Collective-free: each shard touches only its own
    rows, so hosts may log at independent cadences (their traffic is
    theirs; the fold is where totals meet)."""
    axes = tuple(mesh.axis_names)

    def shard_fn(ewma, ids_s, cnt_s):
        m_local = ewma.shape[0]
        ids_s = ids_s.reshape(-1)
        cnt_s = cnt_s.reshape(-1)
        local_start = _shard_linear_index(axes) * m_local
        rel = ids_s - local_start
        idx = jnp.where((rel >= 0) & (rel < m_local), rel, m_local)
        return (ewma * jnp.float32(decay)).at[idx].add(
            cnt_s.astype(jnp.float32), mode="drop")

    fn = _shard_map(shard_fn, mesh=mesh,
                    in_specs=(P(axes), P(axes, None), P(axes, None)),
                    out_specs=P(axes))
    return req._replace(ewma=fn(req.ewma, ids, counts))


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "decay", "log"),
    donate_argnames=("req",),
)
def serve_batch(req: ReqState, tau_elap: jax.Array, n_cis: jax.Array,
                env_planes: jax.Array, ids: jax.Array, counts: jax.Array, *,
                mesh: Mesh, decay: float, log: bool = True):
    """Answer one routed serve batch: per requested page, the model-posterior
    probability the cached copy is still fresh,

        p_fresh = P(no change since last crawl | tau, n CIS)
                = exp(-alpha * (tau + beta * n)) = exp(-alpha * tau_eff)

    (each observed CIS is false with probability nu/gamma = e^{-b}, and
    beta = b / alpha — the same tau_eff the value kernel scores with, so
    serving reads the exact belief the scheduler crawls by). Rows a shard
    does not own answer -1.0; the front reassembles per-request answers
    from its host's shard rows (no collective — a host answers for its own
    pages, remote ids are the router's job).

    With `log` (the production default) the serve IS a request: the same
    call applies one EWMA decay+add step, so serving and logging stay one
    device dispatch. Returns (req, p_fresh (n_shards, cap))."""
    axes = tuple(mesh.axis_names)

    def shard_fn(ewma, tau, n, env_shard, ids_s, cnt_s):
        m_local = ewma.shape[0]
        ids_s = ids_s.reshape(-1)
        cnt_s = cnt_s.reshape(-1)
        local_start = _shard_linear_index(axes) * m_local
        rel = ids_s - local_start
        here = (rel >= 0) & (rel < m_local)
        safe = jnp.clip(rel, 0, m_local - 1)
        alpha = layout.gather_plane(env_shard, safe, layout.ALPHA)
        beta = layout.gather_plane(env_shard, safe, layout.BETA)
        t_eff = jnp.minimum(
            tau[safe] + jnp.minimum(beta * n[safe].astype(jnp.float32), BIG),
            BIG)
        p = jnp.where(here, jnp.exp(-alpha * t_eff), -1.0)
        if log:
            idx = jnp.where(here, rel, m_local)
            ewma = (ewma * jnp.float32(decay)).at[idx].add(
                cnt_s.astype(jnp.float32), mode="drop")
        return ewma, p.reshape(1, -1)

    fn = _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axes), P(axes), P(axes), P(axes, None, None, None),
                  P(axes, None), P(axes, None)),
        out_specs=(P(axes), P(axes, None)))
    ewma, p = fn(req.ewma, tau_elap, n_cis, env_planes, ids, counts)
    return req._replace(ewma=ewma), p


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "source"),
    donate_argnames=("bstate",),
)
def fold_into_planes(bstate, *, mesh: Mesh, source: ImportanceSource):
    """The periodic MU_T refold: blend the request planes into a new mu
    vector, re-anchor the normalizer, and rewrite every mu-derived row of
    the packed state — the device-side analogue of rebuilding the scheduler
    with `Env(mu=blend)`.

    Per shard: mu = req.valid * (blend + floor) (padding stays exactly
    zero — `ReqState.valid`, not the packed VALID plane, which is 1.0
    even on padding),
    one psum re-anchors mu_total, MU_T and V_INF are rewritten via
    `layout.refold_mu` (bit-identical to `_page_planes` at the new mu_t),
    and the block-bound rows are re-anchored exactly as a fresh
    `tiered.init_block_bounds` would build them — asym/slope recomputed,
    blk_max dropped to 0, last_eval to the never-evaluated sentinel,
    beta_max recomputed (unchanged in value: beta is mu-free), CIS mass
    reset — so every block re-evaluates under the new importance next
    round. A fold therefore equals a from-scratch construction at the
    blended mu for every env-derived row (property-tested), while the
    selection-loop rows (thresh/hyst/col_winners/depth_hot) and the page
    clocks ride through untouched.

    Returns (bstate, mu_total) with mu_total fully replicated — assign it
    to the host-side normalizer without a device readback. All hosts must
    call folds together (the psum is a collective), like `run_rounds`."""
    from repro.sched import tiered

    if bstate.req is None:
        raise ValueError(
            "fold_into_planes needs the request-importance planes "
            "(FusedState.req) — construct the scheduler with "
            "importance=True (or restore a request-plane checkpoint)")
    axes = tuple(mesh.axis_names)
    pspec = P(axes)

    def shard_fn(env_shard, ewma, delta, prior, valid):
        nb_local = env_shard.shape[0]
        blend = (jnp.float32(source.w_request) * ewma
                 + jnp.float32(source.w_prior) * prior
                 + jnp.float32(source.w_uniform)
                 + jnp.float32(source.floor))
        mu = valid * blend
        total = jax.lax.psum(jnp.sum(mu), axes)
        # The exact `derive` normalization expression, at the new anchor.
        # The barrier stops XLA from fusing the two divisions (mu / total
        # / delta) into a reassociated form: materializing mu_t first
        # keeps the fold bit-identical to the eager
        # `derive` + `pack_shard` sequence of a fresh construction.
        mu_t = jax.lax.optimization_barrier(
            mu / jnp.maximum(total, _MU_EPS))
        env2 = layout.refold_mu(env_shard, mu_t, delta)
        return (env2,
                layout.asym_block_bounds(env2),
                tiered._block_slope(layout.block_mu_max(env2)),
                jnp.zeros((nb_local,), jnp.float32),
                jnp.full((nb_local,), -1, jnp.int32),
                layout.block_beta_max(env2),
                jnp.zeros((nb_local,), jnp.float32),
                total)

    fn = _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axes, None, None, None), pspec, pspec, pspec, pspec),
        out_specs=(P(axes, None, None, None), pspec, pspec, pspec, pspec,
                   pspec, pspec, P()))
    (env2, asym, slope, blk_max, last_eval, beta_max, cis_mass,
     mu_total) = fn(bstate.env_planes, bstate.req.ewma, bstate.req.delta,
                    bstate.req.prior, bstate.req.valid)
    return bstate._replace(
        env_planes=env2, bounds=asym, slope=slope, blk_max=blk_max,
        last_eval=last_eval, beta_max=beta_max, cis_mass=cis_mass,
    ), mu_total
