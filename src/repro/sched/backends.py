"""SelectionBackend protocol + the functional scheduling round.

The scheduler API (paper Section 5.2) is organized around two pieces:

  * a **backend** — a frozen, hashable config object implementing the
    `SelectionBackend` protocol. It owns the selection strategy (how values
    are evaluated and the top-k extracted) and builds/updates its own state:

        DenseBackend   dense jnp series values (oracle-grade)
        TableBackend   exposure-table lookup (App. G tier tables)
        KernelBackend  dense Pallas value kernel + full top_k
        FusedBackend   packed PageShard planes + single-pass candidate
                       select (`kernels.select`), per-shard threshold
                       warm-start, per-block bounds — the production path

  * a **`RoundState`** — one functional, sharded pytree holding everything
    that changes round to round: the page state (tau^ELAP, n_CIS, clock) and
    the backend state (derived env / value table / packed env planes,
    per-shard warm-start thresholds, per-block bounds). Because it is a plain
    pytree it checkpoints, donates, and moves through jit/shard_map
    boundaries as-is.

One jitted `crawl_round(backend, state, new_cis, ...)` replaces the old
flag-dispatched `sharded_crawl_step` (which remains as a legacy shim). The
round **donates** the state: tau/n_CIS and the fused threshold/bound planes
are updated in place, and the packed env planes — unchanged within a round —
alias straight through, so no state plane is copied at production sizes.

Per-shard threshold warm-start (resolves the ROADMAP "sharded
bound/threshold exchange" item): `FusedState.thresh` holds one threshold per
shard, sharded alongside the pages, and each shard compares *its own*
previous k-th candidate value against its local block bounds. Carrying a
single global k-th value would force low-value shards into the dense
fallback every round (their local k-th sits far below the global one);
per-shard thresholds make warm-start sound — and cheap — on any mesh, while
selection stays provably identical to dense top-k via the exact-recovery
fallback in `kernels.select`.

The adaptive skip-control loop (ROADMAP "adaptive BlockBounds" / "adaptive
hysteresis") closes entirely inside the jitted, donated round: `FusedState`
additionally carries the refreshing per-block bound rows (slope / blk_max /
last_eval — the `tiered.BlockBounds` construction), the per-shard
hysteresis scalar, the realized candidate-depth watermark, and the CIS-mass
accumulator rows (beta_max / cis_mass). Each `crawl_round` folds the
kernel's block maxima back into the anchors, accounts for every fed block's
signals (the `cis_rule` that keeps refreshing bounds sound under signal
jumps: accrue `beta_max * n_cis` bound growth by default, or re-mark the
block stale), and tightens/relaxes the warm-start threshold from the
fallback diagnostic — no host round-trip, no extra pass over the pages.
See `FusedBackend` for the flags.

Macro-rounds (`crawl_rounds`): a batch of R rounds runs inside ONE jitted,
donated `lax.scan` — stacked `(page_ids, values)` out, `RoundDiagnostics`
accumulated on device, selection bit-identical to R sequential
`crawl_round` calls. The fused backend consumes the feed batch in sparse
COO form (`SparseFeeds`) so a skip-heavy round costs O(active + k + nnz)
instead of O(m); `CrawlScheduler.run_rounds` is the service surface.

Parameter refresh (the paper's decentralized per-page refresh) is
`refresh_pages(backend, bstate, page_ids, env_new, ...)`: each backend
scatter-updates only the touched rows of its state (fused: plane columns +
touched-block bounds via `layout.repack_pages`), again with the state buffer
donated. The global importance normalizer mu_total is frozen at construction
— greedy selection is invariant to a common scale factor, so per-page
updates never need a global renormalization pass (Section 5.2's
decentralization argument).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import tables
from repro.core.values import DerivedEnv, Env, derive
from repro.sched.distributed import (
    ShardedSchedState,
    _global_topk,
    _global_winners,
    _shard_linear_index,
    _shard_map,
    host_local_array,
    sharded_select,
)

# Threshold warm-start relaxation: the next round's k-th value can sit below
# the current one (winners reset to ~0 value), so the carried threshold is
# relaxed; a too-aggressive threshold only costs a dense fallback, never
# exactness. This is only the *initial* factor — the hysteresis loop is
# closed in-jit per shard (FusedState.hyst): tighten toward HYSTERESIS_MAX
# while no fallback fires, relax on fallback.
DEFAULT_HYSTERESIS = 0.9
HYSTERESIS_MIN = 0.5
HYSTERESIS_MAX = 0.98
HYSTERESIS_TIGHTEN = 0.01   # additive step per clean round
HYSTERESIS_RELAX = 0.1      # additive step back per fallback round

# Saturation cap of the in-scan depth-saturation counter
# (`FusedState.depth_hot`): the counter only ever needs to distinguish "a
# few hot rounds" from "most rounds hot" within one observation window, so
# it saturates instead of growing without bound across a very long run
# between boundary decisions.
DEPTH_HOT_CAP = 1 << 20


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoundState:
    """Everything that changes round to round, as one sharded pytree.

    tau_elap/n_cis are sharded over all mesh axes; `backend` is the
    backend-owned state pytree (see each backend's `init`). Treat values as
    immutable: `crawl_round` donates the whole tree, so the previous
    RoundState's buffers are invalid once the next round runs.
    """

    tau_elap: jax.Array     # (m_state,) f32
    n_cis: jax.Array        # (m_state,) i32
    crawl_clock: jax.Array  # () i32 round counter
    backend: Any


class BackendInit(NamedTuple):
    """What a backend hands back from `init`: the (padded) state size, its
    state pytree, and host-side conveniences (derived env, value table)."""

    m_state: int
    state: Any
    d: DerivedEnv
    table: tables.ValueTable | None


class DenseState(NamedTuple):
    d: DerivedEnv


class TableState(NamedTuple):
    d: DerivedEnv
    table: tables.ValueTable


class FusedState(NamedTuple):
    """All array state of the fused backend. NOTE: a NamedTuple checkpoints
    under its *field names* (backend/.thresh, ...), so growing the state is
    append-only in spirit: never rename or repurpose an existing field —
    `checkpoint.restore(strict=False)` then loads pre-adaptive snapshots
    into the grown state by name (the new planes keep their init values)."""

    env_planes: jax.Array   # (n_blocks, n_planes, block_rows, LANES) f32
    thresh: jax.Array       # (n_shards,) per-SHARD warm-start threshold
    bounds: jax.Array       # (n_blocks,) static asymptote bound (cap of the
    #                         refreshing bound; the bound used directly when
    #                         adaptive_bounds is off)
    frac_active: jax.Array  # (n_shards,) diagnostics: blocks evaluated
    fell_back: jax.Array    # (n_shards,) diagnostics: dense recovery taken
    # --- adaptive skip-control planes (appended; see class docstring) ---
    slope: jax.Array        # (n_blocks,) max value-growth-rate bound
    blk_max: jax.Array      # (n_blocks,) block max at last exact evaluation
    last_eval: jax.Array    # (n_blocks,) i32 round of last exact evaluation
    #                         (-1 = never: +inf bound, must evaluate)
    hyst: jax.Array         # (n_shards,) adaptive hysteresis scalar
    col_winners: jax.Array  # (n_shards,) i32 running max winners observed
    #                         per lane column (candidate-depth sizing)
    # --- CIS-mass re-evaluation planes (appended; `FusedBackend.cis_rule`) -
    beta_max: jax.Array     # (n_blocks,) max time-equivalent of one CIS
    cis_mass: jax.Array     # (n_blocks,) f32 accumulated worst-case clock
    #                         displacement from CIS since last exact eval
    # --- depth-cadence plane (appended; macro depth adaptation) -----------
    depth_hot: jax.Array    # (n_shards,) i32 saturating count of rounds in
    #                         the current observation window whose realized
    #                         candidate depth reached the configured buffer
    #                         depth — lets the boundary decision distinguish
    #                         "one hot round" from "every round saturated"
    #                         (a lone spike must not pin the depth high for
    #                         a whole large-R macro-round)
    # --- streaming-estimation planes (appended; FusedBackend.online_est) --
    est: Any = None         # `core.estimation.StreamStats` of (m_state,) f32
    #                         planes when online_est is on, None otherwise
    #                         (None = empty pytree: the off path's state
    #                         tree, jit signatures, and checkpoints are
    #                         byte-identical to pre-estimation builds)
    # --- spike-free emission residue (appended; elastic bandwidth) --------
    emit_res: Any = None    # (n_shards,) f32 token-bucket fractional-rate
    #                         residue of the smooth emission mode (identical
    #                         replicated-per-shard copies — the rate operand
    #                         is replicated, so every shard integrates the
    #                         same bucket), None while smoothing has never
    #                         been engaged (same empty-pytree trick as
    #                         `est`: fixed-k paths keep byte-identical jit
    #                         signatures and checkpoints)
    # --- degraded-mode staleness watchdog (appended; FusedBackend.degraded)
    stale: Any = None       # (n_blocks,) i32 rounds since the block last
    #                         received ANY CIS — the on-device outage
    #                         watchdog. Rides the macro-round carry; a block
    #                         at stale >= FusedBackend.stale_limit is
    #                         flagged silent: its bound is inflated to the
    #                         static asymptote (skips can't hide changes
    #                         behind a dead channel), selection sees the
    #                         expected-missed-CIS compensation, and its
    #                         pages' outcome ingestion is quarantined. None
    #                         when degraded is off (same empty-pytree trick
    #                         as `est`/`emit_res`)
    # --- request-driven importance plane (appended; sched.importance) -----
    req: Any = None         # `sched.importance.ReqState` of (m_state,) f32
    #                         planes when the request-importance layer is
    #                         attached: the per-page decayed request-count
    #                         EWMA plus the raw-delta / link-prior columns
    #                         the periodic MU_T refold needs
    #                         (`importance.fold_into_planes`). The macro
    #                         round never reads it — it rides the donated
    #                         state so serve-front logging, checkpointing,
    #                         and the fold share one state tree. None when
    #                         the layer is off (same empty-pytree trick as
    #                         `est`/`emit_res`/`stale`)


def _pspec(mesh: Mesh) -> P:
    return P(tuple(mesh.axis_names))


def _put(x, mesh: Mesh, spec: P):
    return jax.device_put(x, NamedSharding(mesh, spec))


def _own(env: Env) -> Env:
    """Defensive copy of caller-owned env arrays. derive() may alias its
    inputs, and round donation would otherwise invalidate the caller's
    arrays the first time the state is donated."""
    return Env(*(jnp.copy(jnp.asarray(f)) for f in env))


def _scatter_derived(d: DerivedEnv, ids: jax.Array, d_new: DerivedEnv) -> DerivedEnv:
    return DerivedEnv(*[f.at[ids].set(n.astype(f.dtype)) for f, n in zip(d, d_new)])


@runtime_checkable
class SelectionBackend(Protocol):
    """Frozen config + strategy object. Implementations must be hashable
    (they are static jit arguments) and keep all array state in the pytree
    returned by `init` — the protocol is purely functional."""

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        """Build the backend state for a raw environment on a mesh."""
        ...

    def select(self, state: RoundState, mesh: Mesh, k: int, *,
               dt: float = 0.0, new_cis: jax.Array | None = None):
        """Global top-k. Returns (page_ids (k,) replicated, values (k,)
        replicated, crawl mask (m_state,) sharded, new backend state).

        dt/new_cis thread the round context through for backends whose
        state update depends on it: the fused adaptive bounds need the
        round period to decay block bounds, and the CIS feed so any block
        that received signals this round is re-marked stale (a CIS jump is
        instant value growth the slope bound cannot see — re-evaluating
        keeps a skipped block from hiding a signal-jumped winner).
        Stateless backends ignore both."""
        ...

    def update_pages(self, bstate, page_ids: jax.Array, d_new: DerivedEnv,
                     block_ids: jax.Array | None, *, mesh: Mesh | None = None):
        """Scatter the refreshed derived parameters of `page_ids` into the
        backend state (shard-local / block-granular where the layout allows).
        Dense/table backends take flat global ids; the fused backend takes
        per-shard padded batches (relative ids + touched `block_ids`) and
        repacks inside a collective-free shard_map over `mesh`, so on a
        multi-process mesh no cross-host index is ever shipped (see
        `FusedBackend.update_pages` / `CrawlScheduler.update_pages`)."""
        ...


@dataclasses.dataclass(frozen=True)
class DenseBackend:
    """Dense jnp series values — oracle-grade reference selection."""

    n_terms: int = 8
    k_local: int | None = None
    use_kernel: bool = False  # route values through the dense Pallas kernel

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        env = _put(_own(env), mesh, _pspec(mesh))
        d = derive(env, mu_total=jnp.sum(env.mu))
        return BackendInit(env.m, DenseState(d=d), d, None)

    def select(self, state: RoundState, mesh: Mesh, k: int, *,
               dt: float = 0.0, new_cis: jax.Array | None = None):
        st = ShardedSchedState(state.tau_elap, state.n_cis, state.crawl_clock)
        top_g, top_v, mask = sharded_select(
            st, state.backend.d, None, mesh, k, self.n_terms,
            self.use_kernel, self.k_local,
        )
        return top_g, top_v, mask, state.backend

    def update_pages(self, bstate, page_ids, d_new, block_ids=None, *,
                     mesh=None):
        return bstate._replace(d=_scatter_derived(bstate.d, page_ids, d_new))


@dataclasses.dataclass(frozen=True)
class KernelBackend(DenseBackend):
    """Dense Pallas value kernel (values to HBM) + full top_k second pass."""

    use_kernel: bool = True


@dataclasses.dataclass(frozen=True)
class TableBackend:
    """Exposure-table lookup (App. G tier tables): V_NCIS(u) interpolated
    from a per-page grid built once per parameter refresh."""

    n_terms: int = 8
    table_grid: int = 128
    u_max: float = 40.0
    k_local: int | None = None

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        env = _put(_own(env), mesh, _pspec(mesh))
        d = derive(env, mu_total=jnp.sum(env.mu))
        table = tables.build_ncis_table(d, n_terms=self.n_terms,
                                        n_grid=self.table_grid,
                                        u_max=self.u_max)
        return BackendInit(env.m, TableState(d=d, table=table), d, table)

    def select(self, state: RoundState, mesh: Mesh, k: int, *,
               dt: float = 0.0, new_cis: jax.Array | None = None):
        st = ShardedSchedState(state.tau_elap, state.n_cis, state.crawl_clock)
        top_g, top_v, mask = sharded_select(
            st, state.backend.d, state.backend.table, mesh, k, self.n_terms,
            False, self.k_local,
        )
        return top_g, top_v, mask, state.backend

    def update_pages(self, bstate, page_ids, d_new, block_ids=None, *,
                     mesh=None):
        d = _scatter_derived(bstate.d, page_ids, d_new)
        rows = tables.build_ncis_table(
            d_new, n_terms=self.n_terms, n_grid=bstate.table.vals.shape[-1],
            u_max=self.u_max,
        )
        table = bstate.table._replace(
            vals=bstate.table.vals.at[page_ids].set(rows.vals)
        )
        return bstate._replace(d=d, table=table)


class _FusedShardCtx(NamedTuple):
    """Shard-local skip-control state entering one fused round: the bound
    rows ((nb_local,) each) + the scalar threshold (already warm_start-
    resolved), hysteresis, column watermark, and round clock."""

    asym: jax.Array
    slope: jax.Array
    blkmax: jax.Array
    last_ev: jax.Array
    betam: jax.Array
    cmass: jax.Array
    thresh: jax.Array
    hyst: jax.Array
    colw: jax.Array
    dhot: jax.Array
    clock: jax.Array


class _FusedShardUpd(NamedTuple):
    """What one fused round writes back (scalars + bound rows)."""

    thresh: jax.Array
    hyst: jax.Array
    colw: jax.Array
    dhot: jax.Array
    blkmax: jax.Array
    last_ev: jax.Array
    cmass: jax.Array
    stale: Any = None  # (nb_local,) i32 watchdog rows (degraded mode only)


def _fused_shard_round(backend, state_fn, dense_state, env_shard, ctx, blk_cis,
                       k_loc, cand, impl, dt, k_loc_dyn=None, stale=None):
    """One shard-local fused selection + skip-control update — THE shared
    body of the sequential `FusedBackend.select` and every round of the
    macro scan (`crawl_rounds`), so the two paths are bit-identical by
    construction.

    state_fn/dense_state: per-block state access (`kernels.select
    .fused_select_from`) — the macro scan passes an anchored-n state_fn.
    blk_cis: (nb_local,) per-block CIS counts of this round's feed (None
    when adaptive_bounds is off; counts are non-negative by the feed
    contract).
    k_loc_dyn: optional traced per-round shard budget under the static
    k_loc cap (elastic bandwidth). The selection masks candidates past it
    (`kernels.select` k_dyn); the warm-start threshold is seeded from the
    *dynamic* k-th value — and carried unchanged through zero-budget
    rounds, where no k-th value exists (sound for any carried threshold:
    an over-tight one only prices a dense fallback, never exactness).

    stale: (nb_local,) i32 watchdog rows when the backend is degraded-mode
    (None otherwise; requires blk_cis). Blocks silent for stale_limit
    rounds get (a) their bound inflated to the static asymptote — the
    slope-decayed anchor assumed value growth the dead channel can no
    longer report, and compensated values can jump discontinuously above
    it, so only the unconditional V <= V_INF cap stays sound — and (b)
    expected-missed-CIS compensation: selection sees
    n_eff = n + gamma_page * min(stale * dt, tau_elap), the conditional
    expectation of the CIS censored by the dead channel (GAMMA is the
    observed-signal rate lam*delta + nu). The window is capped per page at
    its own elapsed time since last crawl: a page crawled one round ago
    inside a long-dark block has missed at most one round of signals —
    uncapped block-level compensation would hand freshly-crawled dark
    pages the whole block's phantom signal mass and funnel the crawl
    budget into the outage. Healthy blocks add exactly 0.0 (min(0, tau)
    is 0 for tau >= 0), and n + 0.0 is the IEEE identity for n >= 0, so
    an all-healthy degraded round stays bit-identical to the non-degraded
    path."""
    from repro.kernels import layout
    from repro.kernels import select as ksel
    from repro.sched import tiered

    bb = tiered.BlockBounds(asym=ctx.asym, slope=ctx.slope,
                            blk_max=ctx.blkmax, last_eval=ctx.last_ev)
    if backend.adaptive_bounds:
        bound = tiered.current_block_bounds(
            bb, ctx.clock, dt,
            cis_mass=ctx.cmass if backend.cis_rule == "mass" else None,
        )
    else:
        bound = ctx.asym
    new_stale = None
    if stale is not None:
        assert blk_cis is not None, "degraded mode needs per-block CIS counts"
        # Watchdog tick: any delivered signal proves the channel alive.
        new_stale = jnp.where(blk_cis > 0, jnp.int32(0),
                              stale + jnp.int32(1))
        silent = new_stale >= jnp.int32(backend.stale_limit)
        comp_blk = jnp.where(
            silent, new_stale.astype(jnp.float32) * jnp.float32(dt), 0.0)
        inner_fn = state_fn

        def state_fn(i):  # compensated view of the same page state
            tau_b, n_b = inner_fn(i)
            env_b = jax.lax.dynamic_index_in_dim(env_shard, i, 0,
                                                 keepdims=False)
            win = jnp.minimum(comp_blk[i], tau_b)
            return tau_b, n_b + win * env_b[layout.GAMMA]

        if dense_state is not None:
            tau_d, n_d = dense_state
            bp = env_shard.shape[2] * env_shard.shape[3]
            gamma_flat = env_shard[:, layout.GAMMA].reshape(-1)
            comp_page = jnp.minimum(jnp.repeat(comp_blk, bp), tau_d)
            dense_state = (tau_d,
                           n_d.astype(jnp.float32) + comp_page * gamma_flat)
        bound = jnp.where(silent, ctx.asym, bound)
    sel = ksel.fused_select_from(
        state_fn, env_shard, k_loc, ctx.thresh, bound,
        n_terms=backend.n_terms, cand_per_lane=cand, impl=impl,
        interpret=impl != "pallas", dense_state=dense_state,
        k_dyn=k_loc_dyn,
    )
    # Hysteresis loop: tighten while the threshold proved safe, relax when
    # it (or candidate overflow) forced a dense pass.
    if backend.adaptive_hysteresis:
        h = jnp.where(
            sel.fell_back,
            jnp.maximum(ctx.hyst - backend.hyst_relax, backend.hyst_min),
            jnp.minimum(ctx.hyst + backend.hyst_tighten, backend.hyst_max),
        )
    else:
        h = jnp.float32(backend.hysteresis)
    if k_loc_dyn is None:
        new_thresh = sel.values[k_loc - 1] * h
    else:
        # The masked selection holds its live entries in positions
        # [0, k_loc_dyn), so the dynamic k-th value is the last live slot;
        # k = 0 rounds observe no value and carry the threshold through.
        kq = jnp.maximum(k_loc_dyn, 1)
        new_thresh = jnp.where(
            k_loc_dyn > 0, sel.values[kq - 1] * h, ctx.thresh)
    if backend.adaptive_bounds:
        # Fold the round's block maxima back into the bound anchors. On
        # fallback rounds the dense pass evaluated every block (blk_max is
        # recomputed from the dense values in kernels.select).
        evaluated = (bound >= ctx.thresh) | sel.fell_back
        bb = tiered.update_block_bounds(bb, sel.blk_max, evaluated,
                                        ctx.clock)
        if backend.cis_rule == "mass":
            # CIS-mass rule: fed blocks accrue the worst-case clock
            # displacement beta_max * n into the bound's elapsed term
            # instead of losing their anchor — light feeds stay skipped.
            new_cmass = tiered.accumulate_cis_mass(ctx.cmass, ctx.betam,
                                                   blk_cis, evaluated)
            new_last = bb.last_eval
        else:
            # Blanket re-mark: a CIS jumps exposure instantly, which the
            # slope bound cannot see — blocks that received signals this
            # round lose their anchor (+inf bound next round), so a
            # skipped block can never hide a signal-jumped winner.
            new_last = jnp.where(blk_cis > 0, jnp.int32(-1), bb.last_eval)
            new_cmass = ctx.cmass
        new_blkmax = bb.blk_max
    else:
        # Static bound: the anchors are never read — alias them through
        # untouched (no per-round plane writes, no O(m) CIS reduction on
        # the default path).
        new_blkmax, new_last, new_cmass = ctx.blkmax, ctx.last_ev, ctx.cmass
    # Running max of realized per-column winner depth: the host-side
    # candidate-depth adaptation reads (and resets) this window.
    colw = jnp.maximum(ctx.colw, sel.col_winners)
    # Depth-saturation counter (bounded, in-scan): one tick per round whose
    # realized depth reached the retained buffer depth. The watermark alone
    # cannot tell a lone hot round (absorbed by the dense fallback, depth
    # should stay put) from persistent saturation (the buffer really is too
    # small) once R rounds share one boundary decision.
    dhot = jnp.minimum(
        ctx.dhot + (sel.col_winners >= cand).astype(jnp.int32),
        DEPTH_HOT_CAP)
    return sel, _FusedShardUpd(thresh=new_thresh, hyst=h, colw=colw,
                               dhot=dhot, blkmax=new_blkmax,
                               last_ev=new_last, cmass=new_cmass,
                               stale=new_stale)


@dataclasses.dataclass(frozen=True)
class FusedBackend:
    """Packed planes + single-pass candidate select — the production path.

    warm_start enables the per-shard threshold skip (sound on any mesh size:
    each shard's threshold is its own previous k-th candidate value, relaxed
    by the hysteresis scalar). Selection remains exactly dense top-k
    regardless — the candidate-overflow / over-aggressive-threshold fallback
    in `kernels.select` guarantees it.

    Adaptive skip control (the App. G tiering loop, closed in-jit):

      * adaptive_bounds (opt-in): each round's per-block maxima fold back
        into the refreshing `tiered.BlockBounds` carried in `FusedState`
        (slope-decayed anchor, capped by the static asymptote), replacing
        the static asymptote-only bound. Soundness under CIS is governed by
        cis_rule: "mass" (default) accrues the worst-case exposure-clock
        displacement beta_max * n_cis of every fed block into a per-block
        accumulator added to the bound's elapsed term
        (`tiered.accumulate_cis_mass`) — a weak signal bumps the bound one
        beta-slope step and the block stays skipped under light feeds;
        "remark" is the blunt rule it refines: any block receiving
        `new_cis > 0` is re-marked never-evaluated (+inf bound). Either
        way a skipped block can never hide a signal-jumped winner —
        selection stays exactly dense top-k.
      * adaptive_hysteresis (default on): the per-shard warm-start
        threshold factor is carried in `FusedState.hyst` and adapted from
        the fallback diagnostic — tightened toward `hyst_max` while no
        fallback fires (more skipping), relaxed toward `hyst_min` on
        fallback (fewer dense passes).
      * cand_per_lane (None = auto-size for the worst case): candidate
        buffer depth. `FusedState.col_winners` tracks the realized
        per-lane-column winner counts so `CrawlScheduler` (adaptive_cand)
        can shrink the depth on well-mixed shards — fewer extraction
        passes per active block.

    Streaming estimation (`sched.online_est`, opt-in):

      * online_est: carry per-page streaming (Delta, lambda, nu) estimator
        planes (`FusedState.est`) and close the learning loop inside the
        macro-round scan — self-contained crawl outcomes
        (`crawl_rounds(..., outcomes=SparseOutcomes)`: freshness bit +
        echoed covariates) ingested as
        O(outcomes)/round closed-form moment-statistic updates
        (`estimation.stream_update`), and the packed env planes of the
        touched pages re-derived ON DEVICE once per macro batch
        (`online_est.apply_estimates`). Zero host transfers; with an empty
        outcome batch the selection is bit-identical to online_est=False.
        Estimation only advances through the macro path (`crawl_rounds`
        with SparseFeeds); sequential `crawl_round`s carry the planes
        untouched. Pages with fewer than est_min_obs resolved outcomes keep
        their current packed parameters; est_prior_a/est_prior_b/est_prior_w
        shrink small-sample (alpha, alpha*beta) estimates toward the prior
        with est_prior_w pseudo-observations' weight per statistic group
        (the closed-loop explore/exploit guard — see
        `estimation.stream_quality`).

    Degraded mode (`sched.degraded`, opt-in):

      * degraded: carry a per-block rounds-since-last-CIS watchdog plane
        (`FusedState.stale`) through the scan. A block silent for
        stale_limit rounds is treated as suffering a signal-channel outage:
        its skip bound inflates to the static asymptote (a dead channel
        must not let skips hide changes), selection values see the
        expected-missed-CIS compensation
        n + gamma * min(stale * dt, tau_elap) (per-page window, capped at
        time since that page's own last crawl), and —
        with online_est — outcome ingestion for its pages is quarantined
        so censored windows cannot drive the streaming (alpha, b, gamma)
        estimates toward zero. With every channel healthy the degraded
        path selects bit-identically to degraded=False (compensation is
        exactly 0.0 and bound inflation a no-op); with degraded=False no
        new operand is traced at all, so legacy jit signatures and
        checkpoints stay byte-identical.
    """

    n_terms: int = 8
    block_rows: int | None = None
    k_local: int | None = None
    hysteresis: float = DEFAULT_HYSTERESIS
    warm_start: bool = True
    adaptive_bounds: bool = False
    adaptive_hysteresis: bool = True
    adaptive_cand: bool = False
    cis_rule: str = "mass"  # "mass" | "remark" (see class docstring)
    cand_per_lane: int | None = None
    hyst_min: float = HYSTERESIS_MIN
    hyst_max: float = HYSTERESIS_MAX
    hyst_tighten: float = HYSTERESIS_TIGHTEN
    hyst_relax: float = HYSTERESIS_RELAX
    online_est: bool = False
    est_min_obs: int = 2
    est_prior_a: float = 0.5
    est_prior_b: float = 1.0
    est_prior_w: float = 8.0
    degraded: bool = False
    stale_limit: int = 8

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        from repro.kernels import layout
        from repro.sched import tiered

        assert self.cis_rule in ("mass", "remark"), self.cis_rule
        block_rows = self.block_rows or layout.DEFAULT_BLOCK_ROWS
        m = env.m
        m_state = layout.padded_size(m, block_rows, n_shards=mesh.size)
        # Pad the raw env so derived state/env sizes agree; padding pages
        # (mu = 0) normalize away and score -inf in the fused kernel.
        if m_state != m:
            env = Env(
                delta=layout.pad_to(env.delta, m_state, 1.0),
                mu=layout.pad_to(env.mu, m_state, 0.0),
                lam=layout.pad_to(env.lam, m_state, 0.0),
                nu=layout.pad_to(env.nu, m_state, 0.0),
            )
        env = _put(env, mesh, _pspec(mesh))
        d = derive(env, mu_total=jnp.sum(env.mu))
        shard = layout.pack_shard(d, n_terms=self.n_terms,
                                  block_rows=block_rows)
        n_shards = mesh.size
        pspec = _pspec(mesh)
        neg_inf = jnp.full((n_shards,), -jnp.inf, jnp.float32)
        bb = tiered.init_block_bounds(shard.env)
        bstate = FusedState(
            env_planes=_put(shard.env, mesh, P(tuple(mesh.axis_names),
                                               None, None, None)),
            thresh=_put(neg_inf, mesh, pspec),
            bounds=_put(bb.asym, mesh, pspec),
            frac_active=_put(jnp.ones((n_shards,), jnp.float32), mesh, pspec),
            fell_back=_put(jnp.zeros((n_shards,), bool), mesh, pspec),
            slope=_put(bb.slope, mesh, pspec),
            blk_max=_put(bb.blk_max, mesh, pspec),
            last_eval=_put(bb.last_eval, mesh, pspec),
            hyst=_put(jnp.full((n_shards,), self.hysteresis, jnp.float32),
                      mesh, pspec),
            col_winners=_put(jnp.zeros((n_shards,), jnp.int32), mesh, pspec),
            beta_max=_put(layout.block_beta_max(shard.env), mesh, pspec),
            cis_mass=_put(jnp.zeros(bb.asym.shape, jnp.float32), mesh, pspec),
            depth_hot=_put(jnp.zeros((n_shards,), jnp.int32), mesh, pspec),
            est=self._init_est(m_state, lambda x: _put(x, mesh, pspec)),
            stale=self._init_stale(bb.asym.shape,
                                   lambda x: _put(x, mesh, pspec)),
        )
        return BackendInit(m_state, bstate, d, None)

    def _init_est(self, m_state: int, put):
        """The streaming-estimator planes (None when online_est is off);
        `put` places one (m_state,) plane with the page-state sharding."""
        if not self.online_est:
            return None
        from repro.sched import online_est as oest

        return jax.tree.map(put, oest.init_est(m_state))

    def _init_stale(self, nb_shape, put):
        """The per-block watchdog rows (None when degraded is off); `put`
        places one (n_blocks,) row with the block-row sharding. Zero =
        'heard from just now', so a fresh state starts every channel
        presumed healthy."""
        if not self.degraded:
            return None
        if self.stale_limit < 1:
            raise ValueError("stale_limit must be >= 1")
        return put(jnp.zeros(nb_shape, jnp.int32))

    def init_local(self, env_local: Env, mesh: Mesh, *, m: int,
                   host_shards: tuple[int, int],
                   mu_total) -> tuple[int, "FusedState"]:
        """Host-local `init`: build THIS process's rows of the fused state
        from its local raw-env slice alone — no host ever materializes the
        global env. `env_local` covers exactly the raw pages
        [s0 * m_shard, min(s1 * m_shard, m)) of host_shards = (s0, s1);
        `mu_total` is the frozen global importance normalizer (the caller's
        one mu psum — see `CrawlScheduler.from_local_env`).

        Bit-compatible with the global path: `derive` is elementwise given
        an explicit mu_total, a host's local page range is always
        block-aligned (`layout.padded_size` makes blocks divisible by the
        shard count), and every per-block row (`tiered.init_block_bounds`,
        `layout.block_beta_max`) is a block-local reduction — so each
        assembled shard equals the same shard of `init` bit-for-bit.
        Returns (m_state, state); there is no `BackendInit.d` — host-local
        construction has no dense oracle by design."""
        from repro.kernels import layout
        from repro.sched import tiered

        assert self.cis_rule in ("mass", "remark"), self.cis_rule
        block_rows = self.block_rows or layout.DEFAULT_BLOCK_ROWS
        m_state = layout.padded_size(m, block_rows, n_shards=mesh.size)
        m_shard = m_state // mesh.size
        s0, s1 = host_shards
        local_len = (s1 - s0) * m_shard
        # Pad the local slice exactly like `init` pads the global tail
        # (only the last host has a tail): padding pages (mu = 0)
        # normalize away and score -inf in the fused kernel.
        env_l = Env(
            delta=layout.pad_to(env_local.delta, local_len, 1.0),
            mu=layout.pad_to(env_local.mu, local_len, 0.0),
            lam=layout.pad_to(env_local.lam, local_len, 0.0),
            nu=layout.pad_to(env_local.nu, local_len, 0.0),
        )
        d_l = derive(env_l, mu_total=mu_total)
        # local_len is block-aligned, so pack_shard adds no extra padding
        # and its valid plane is all-ones — identical to the global path,
        # which pads before packing.
        shard = layout.pack_shard(d_l, n_terms=self.n_terms,
                                  block_rows=block_rows)
        bb = tiered.init_block_bounds(shard.env)
        n_loc = s1 - s0
        axes = tuple(mesh.axis_names)
        row = P(axes)
        hla = lambda x, spec: host_local_array(np.asarray(x), mesh, spec)
        bstate = FusedState(
            env_planes=hla(shard.env, P(axes, None, None, None)),
            thresh=hla(jnp.full((n_loc,), -jnp.inf, jnp.float32), row),
            bounds=hla(bb.asym, row),
            frac_active=hla(jnp.ones((n_loc,), jnp.float32), row),
            fell_back=hla(jnp.zeros((n_loc,), bool), row),
            slope=hla(bb.slope, row),
            blk_max=hla(bb.blk_max, row),
            last_eval=hla(bb.last_eval, row),
            hyst=hla(jnp.full((n_loc,), self.hysteresis, jnp.float32), row),
            col_winners=hla(jnp.zeros((n_loc,), jnp.int32), row),
            beta_max=hla(layout.block_beta_max(shard.env), row),
            cis_mass=hla(jnp.zeros(bb.asym.shape, jnp.float32), row),
            depth_hot=hla(jnp.zeros((n_loc,), jnp.int32), row),
            est=self._init_est(local_len, lambda x: hla(x, row)),
            stale=self._init_stale(bb.asym.shape, lambda x: hla(x, row)),
        )
        return m_state, bstate

    def select(self, state: RoundState, mesh: Mesh, k: int, *,
               dt: float = 0.0, new_cis: jax.Array | None = None):
        from repro.kernels import select as ksel
        from repro.sched import tiered

        axes = tuple(mesh.axis_names)
        pspec = P(axes)
        bst: FusedState = state.backend
        n_blocks, _, block_rows, lanes = bst.env_planes.shape
        m = state.tau_elap.shape[0]
        n_shards = mesh.size
        assert m == n_blocks * block_rows * lanes, (
            "fused path needs block-aligned padded state "
            f"(m={m}, planes={bst.env_planes.shape})"
        )
        assert n_blocks % n_shards == 0, (
            "fused path needs n_blocks divisible by the shard count"
        )
        # Shard-local budget + candidate depth, clamped by the one shared
        # rule (`select.shard_budget`): exactness survives the clamp — a
        # shard can contribute at most its page count, and the capacity
        # clamp only binds with an explicitly undersized cand_per_lane,
        # where the overflow fallback already restores dense selection.
        k_loc, cand = ksel.shard_budget(
            k, m // n_shards, n_blocks // n_shards, n_shards,
            self.k_local, self.cand_per_lane,
        )
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
        if new_cis is None:
            new_cis = jnp.zeros_like(state.n_cis)

        degr = self.degraded
        if degr and bst.stale is None:
            raise ValueError(
                "degraded backend with no watchdog plane in FusedState — "
                "the state was built by a non-degraded backend config; "
                "rebuild the scheduler (or restore into a degraded one)")

        def shard_fn(tau_elap, n_cis, cis_feed, env_shard, asym, slope,
                     blkmax, last_ev, betam, cmass, thresh_shard, hyst_shard,
                     colw_shard, dhot_shard, clock, *extra):
            # thresh_shard is this shard's OWN slice: the local k-th candidate
            # value of the previous round — sound to compare against local
            # block bounds (the ROADMAP per-shard threshold exchange).
            stale = extra[0] if degr else None
            thresh = (thresh_shard[0] if self.warm_start
                      else jnp.float32(-jnp.inf))
            blk_cis = (cis_feed.reshape(asym.shape[0], -1).sum(axis=1)
                       if (self.adaptive_bounds or degr) else None)
            n_f = n_cis.astype(jnp.float32)
            sel, upd = _fused_shard_round(
                self, ksel.block_state_fn(tau_elap, n_f, env_shard.shape[2]),
                (tau_elap, n_f), env_shard,
                _FusedShardCtx(asym=asym, slope=slope, blkmax=blkmax,
                               last_ev=last_ev, betam=betam, cmass=cmass,
                               thresh=thresh, hyst=hyst_shard[0],
                               colw=colw_shard[0], dhot=dhot_shard[0],
                               clock=clock),
                blk_cis, k_loc, cand, impl, dt, stale=stale,
            )
            m_local = tau_elap.shape[0]
            top_g, top_v, mask = _global_topk(sel.values, sel.ids, axes,
                                              m_local, k)
            out = (top_g, top_v, mask, upd.thresh.reshape(1),
                   sel.frac_active.reshape(1), sel.fell_back.reshape(1),
                   upd.blkmax, upd.last_ev, upd.cmass, upd.hyst.reshape(1),
                   upd.colw.reshape(1), upd.dhot.reshape(1))
            if degr:
                out = out + (upd.stale,)
            return out

        extra_in = (pspec,) if degr else ()
        extra_out = (pspec,) if degr else ()
        extra_args = (bst.stale,) if degr else ()
        fn = _shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(pspec, pspec, pspec, P(axes, None, None, None),
                      pspec, pspec, pspec, pspec, pspec, pspec, pspec, pspec,
                      pspec, pspec, P()) + extra_in,
            out_specs=(P(), P(), pspec, pspec, pspec, pspec,
                       pspec, pspec, pspec, pspec, pspec, pspec) + extra_out,
        )
        res = fn(
            state.tau_elap, state.n_cis, new_cis, bst.env_planes, bst.bounds,
            bst.slope, bst.blk_max, bst.last_eval, bst.beta_max, bst.cis_mass,
            bst.thresh, bst.hyst, bst.col_winners, bst.depth_hot,
            state.crawl_clock, *extra_args,
        )
        (top_g, top_v, mask, thresh, frac, fb, blkmax, last_ev, cmass, hyst,
         colw, dhot) = res[:12]
        repl = dict(thresh=thresh, frac_active=frac, fell_back=fb,
                    blk_max=blkmax, last_eval=last_ev, cis_mass=cmass,
                    hyst=hyst, col_winners=colw, depth_hot=dhot)
        if degr:
            repl["stale"] = res[12]
        new_bst = bst._replace(**repl)
        return top_g, top_v, mask, new_bst

    def update_pages(self, bstate, page_ids, d_new, block_ids=None, *,
                     mesh=None):
        """Shard-local ("local-range") repack: the multi-host refresh path.

        page_ids: (n_shards, u_cap) i32 shard-RELATIVE page ids, one padded
        row per shard (sentinel = shard page count, dropped by every
        scatter); d_new: DerivedEnv of (n_shards, u_cap) fields;
        block_ids: (n_shards, b_cap) i32 shard-relative touched-block ids
        (sentinel = blocks per shard). `CrawlScheduler.update_pages` builds
        these from its `host_slice`, so on a multi-process mesh each host
        materializes only its own shards' rows and the repack below — a
        shard_map with NO collectives — never ships a cross-host index:
        hosts can even apply refresh batches asynchronously.
        """
        from repro.kernels import layout
        from repro.sched import tiered

        assert block_ids is not None, (
            "fused update_pages needs the touched block ids "
            "(per-shard relative, padded; see CrawlScheduler.update_pages)"
        )
        assert mesh is not None, "fused update_pages needs the mesh"
        axes = tuple(mesh.axis_names)
        pspec = P(axes)

        def shard_fn(env_s, asym, slope, blkmax, last_ev, betam, cmass,
                     ids_s, blk_s, d_n):
            ids = ids_s[0]
            blks = blk_s[0]
            d_loc = DerivedEnv(*[f[0] for f in d_n])
            env_s = layout.repack_pages(env_s, ids, d_loc)
            # Refresh every env-dependent bound row of the touched blocks
            # (asymptote AND slope), and drop their anchors: the repacked
            # pages' values are unrelated to the recorded block max, so the
            # blocks re-evaluate exactly next round (last_eval = -1 ->
            # +inf bound).
            bb = tiered.refresh_block_params(
                tiered.BlockBounds(asym=asym, slope=slope, blk_max=blkmax,
                                   last_eval=last_ev),
                env_s, blks)
            # The CIS-mass rows are env-dependent too: beta changed with
            # the new (delta, lam, nu), and the accumulated mass described
            # the old parameters (the dropped anchor re-evaluates the block
            # exactly regardless).
            betam = betam.at[blks].set(
                layout.block_beta_max(env_s, blks), mode="drop")
            cmass = cmass.at[blks].set(0.0, mode="drop")
            return (env_s, bb.asym, bb.slope, bb.blk_max, bb.last_eval,
                    betam, cmass)

        plane_spec = P(axes, None, None, None)
        row_spec = P(axes, None)
        d_specs = DerivedEnv(*([row_spec] * len(d_new)))
        fn = _shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(plane_spec, pspec, pspec, pspec, pspec, pspec, pspec,
                      row_spec, row_spec, d_specs),
            out_specs=(plane_spec, pspec, pspec, pspec, pspec, pspec, pspec),
        )
        (env_planes, asym, slope, blk_max, last_eval, beta_max, cis_mass
         ) = fn(bstate.env_planes, bstate.bounds, bstate.slope,
                bstate.blk_max, bstate.last_eval, bstate.beta_max,
                bstate.cis_mass, page_ids, block_ids, d_new)
        return bstate._replace(env_planes=env_planes, bounds=asym,
                               slope=slope, blk_max=blk_max,
                               last_eval=last_eval, beta_max=beta_max,
                               cis_mass=cis_mass)


def init_round(backend: SelectionBackend, env: Env, mesh: Mesh):
    """Build the initial RoundState (pages 'just crawled') for a backend.

    Returns (round_state, BackendInit) — the init carries the padded state
    size and host conveniences (derived env, table)."""
    binit = backend.init(env, mesh)
    pspec = _pspec(mesh)
    return RoundState(
        tau_elap=_put(jnp.zeros((binit.m_state,), jnp.float32), mesh, pspec),
        n_cis=_put(jnp.zeros((binit.m_state,), jnp.int32), mesh, pspec),
        crawl_clock=jnp.int32(0),
        backend=binit.state,
    ), binit


@functools.partial(jax.jit, donate_argnums=0)
def commit_state(state):
    """Donation-normalize a freshly built (or freshly restored) round state.

    Host-built states don't match what the compiled rounds hand back: the
    scalar `crawl_clock` is an uncommitted single-device array, and leaves
    that pass through a donated jit untouched (e.g. `env_planes` off the
    estimation path) come back with the GSPMD-canonicalized form of their
    PartitionSpec. Either mismatch flips the C++ jit cache key, so the
    2nd-ever `crawl_rounds` call used to recompile once against the
    "donated" signature. Pushing the state through this donated barrier at
    construction produces exactly the committed, canonical shardings the
    round outputs carry — the first call's compilation is the only one.

    `optimization_barrier` is a bitwise identity (unlike `x + 0`, which
    rewrites -0.0), so committed state is byte-for-byte the built state.
    """
    return jax.lax.optimization_barrier(state)


def _round_body(backend, state, new_cis, mesh, k, dt):
    """The one scheduling round, un-jitted: select k pages globally, reset
    them, advance time, ingest the externally-fed CIS counts. Shared by
    `crawl_round` (one jitted dispatch per round) and the generic macro scan
    in `crawl_rounds`, so the two paths are identical by construction."""
    top_g, top_v, mask, new_b = backend.select(state, mesh, k, dt=dt,
                                               new_cis=new_cis)
    tau = jnp.where(mask, 0.0, state.tau_elap) + dt
    n = jnp.where(mask, 0, state.n_cis) + new_cis
    new_state = RoundState(
        tau_elap=tau, n_cis=n, crawl_clock=state.crawl_clock + 1,
        backend=new_b,
    )
    return new_state, (top_g, top_v)


@functools.partial(
    jax.jit,
    static_argnames=("backend", "mesh", "k", "dt"),
    donate_argnames=("state",),
)
def crawl_round(
    backend: SelectionBackend,
    state: RoundState,
    new_cis: jax.Array,
    *,
    mesh: Mesh,
    k: int,
    dt: float,
):
    """One full scheduling round: select k pages globally, reset them,
    advance time, ingest the externally-fed CIS counts.

    Returns (new_round_state, (page_ids, values)). `state` is DONATED: its
    tau/n_CIS (and fused threshold/bound/anchor) buffers are updated in
    place and the packed env planes alias through untouched — no state plane
    is copied. Do not reuse the argument after the call; `new_cis` is not
    donated (feed buffers may be reused by the caller).

    The CIS feed and round period thread into `select` so stateful backends
    can close their skip-control loop in the same jitted round: the fused
    adaptive bounds decay by `dt` and account for every block's received
    signals (the CIS-mass / re-mark rules — see `FusedBackend`).
    """
    return _round_body(backend, state, new_cis, mesh, k, dt)


class RoundDiagnostics(NamedTuple):
    """Per-round skip-control diagnostics of a macro-round, accumulated on
    device as (R, n_shards) stacks and fetched once per macro-round — the
    mid-loop `jax.device_get` sync the per-round loop paid for host-side
    adaptation disappears. Row r holds the post-round-r values of the
    matching `FusedState` fields (placeholders for stateless backends)."""

    frac_active: jax.Array  # (R, n_shards) f32 blocks evaluated
    fell_back: jax.Array    # (R, n_shards) bool dense recovery taken
    hyst: jax.Array         # (R, n_shards) f32 hysteresis after the round
    col_winners: jax.Array  # (R, n_shards) i32 running candidate watermark
    depth_hot: jax.Array    # (R, n_shards) i32 bounded in-scan counter of
    #                         depth-saturated rounds (FusedState.depth_hot
    #                         after each round) — lets the boundary depth
    #                         decision tell "one hot round" from "every
    #                         round saturated" at large R


def _diag_rows(bstate, n_shards: int) -> RoundDiagnostics:
    if isinstance(bstate, FusedState):
        return RoundDiagnostics(bstate.frac_active, bstate.fell_back,
                                bstate.hyst, bstate.col_winners,
                                bstate.depth_hot)
    return RoundDiagnostics(
        frac_active=jnp.ones((n_shards,), jnp.float32),
        fell_back=jnp.zeros((n_shards,), bool),
        hyst=jnp.zeros((n_shards,), jnp.float32),
        col_winners=jnp.zeros((n_shards,), jnp.int32),
        depth_hot=jnp.zeros((n_shards,), jnp.int32),
    )


class SparseFeeds(NamedTuple):
    """A CIS feed batch in per-SHARD, per-round COO form: for every round
    and every shard, the page ids of that shard's local range that received
    signals and their counts, padded to a static width `cap` with id = -1
    rows (dropped). `CrawlScheduler.run_rounds` converts a dense batch once
    on the host — CIS feeds are overwhelmingly sparse in production, so
    inside the macro scan the feed ingest becomes an O(nnz) scatter-add
    instead of an O(m) pass per round, and the batch never materializes
    densely on device.

    The shard axis is the multi-host data-path contract (sharded alongside
    the pages, spec P(None, axes, None)): each process converts only its
    OWN page range and materializes only its own shards' rows
    (`distributed.host_local_array`), so feed bytes never cross hosts. With
    the scheduler's `feed_cap` capacity contract, `cap` is a fixed static
    shape: a hot shard on one host changes no compiled signature and
    therefore triggers zero recompiles on any host.

    ids are global (padded-flat) page ids — each shard's slice holds only
    ids inside that shard's local range; counts are non-negative; ids are
    unique within a (round, shard) cell (guaranteed by a dense->COO
    conversion)."""

    ids: jax.Array     # (R, n_shards, cap) i32 global page ids, -1 pad
    counts: jax.Array  # (R, n_shards, cap) i32


@functools.partial(
    jax.jit,
    static_argnames=("backend", "mesh", "k", "dt"),
    donate_argnames=("state",),
)
def crawl_rounds(
    backend: SelectionBackend,
    state: RoundState,
    feeds: jax.Array | SparseFeeds,
    *,
    mesh: Mesh,
    k: int,
    dt: float,
    outcomes: "SparseOutcomes | None" = None,
    budgets: jax.Array | None = None,
    rate: jax.Array | None = None,
):
    """A macro-round: R full scheduling rounds inside ONE jitted, donated
    `lax.scan` — one host->device dispatch for the whole batch instead of
    R, with every diagnostic accumulated on device.

    feeds: a dense (R, m_state) int32 batch (one pre-padded row per round),
    or a per-shard `SparseFeeds` COO batch for the fused backend (the
    production path; `CrawlScheduler.run_rounds` converts, host-locally on
    multi-process meshes). Returns
    (new_round_state, (page_ids (R, k), values (R, k)), `RoundDiagnostics`).
    The stacked selection equals R sequential `crawl_round` calls
    page-id-for-page-id (property-tested):

      * dense feeds scan the exact `_round_body` (any backend);
      * the fused backend with `SparseFeeds` runs a dedicated
        scan-inside-shard_map that also eliminates the per-round O(m) state
        traffic: feed ingest is an O(nnz) scatter-add, winner resets touch
        only the k crawled pages, block state is fetched per *active* block,
        and the per-block CIS reductions ride the same sparse scatter — the
        only remaining O(m) work per round is the tau clock advance. Every
        arithmetic expression matches the sequential round's, so selection
        is bit-identical, not just set-equal.

    `state` is DONATED (as in `crawl_round`); `feeds` is not. R (and the
    sparse cap) are static shapes — drive a deployment with one batch size
    to avoid re-jits.

    outcomes: a `sched.online_est.SparseOutcomes` crawl-outcome batch for a
    `FusedBackend(online_est=True)` backend (required there, possibly
    empty — `CrawlScheduler.run_rounds` builds it host-locally); must be
    None otherwise. Outcome ingest, the streaming estimator steps, and the
    macro-boundary env-plane re-derivation all run inside the same
    shard_map as the rounds themselves — zero extra host transfers.

    Elastic bandwidth (fused SparseFeeds path only; the static k becomes
    the k_max cap — `CrawlScheduler.run_rounds` is the service surface):

      * budgets: traced (R,) int32 per-round crawl budgets in [0, k].
        Every round still emits (k,)-shaped rows; positions >= budgets[r]
        carry (id = -1, value = -inf). A constant budgets == k vector is
        bit-identical to the fixed-k path.
      * rate: traced f32 scalar crawls-per-round of the spike-free
        emission mode — a token bucket carried in `FusedState.emit_res`
        derives each round's k in-scan (floor of the accumulated bucket,
        clipped to [0, k]), so over any window of W rounds realized crawls
        stay within +/-1 of rate * W and fractional rates are never lost.

    Both are data operands: sweeping budget values or the rate never
    re-traces. Mutually exclusive.
    """
    if budgets is not None and rate is not None:
        raise ValueError(
            "pass either a per-round budget vector or a smoothing rate, "
            "not both")
    if isinstance(feeds, SparseFeeds):
        if not isinstance(backend, FusedBackend):
            raise ValueError(
                "SparseFeeds macro-rounds require the fused backend; dense "
                "oracle backends take the (R, m_state) batch")
        return _fused_macro_rounds(backend, state, feeds, mesh, k, dt,
                                   outcomes, budgets=budgets, rate=rate)
    if budgets is not None or rate is not None:
        raise ValueError(
            "dynamic per-round budgets require the fused SparseFeeds macro "
            "path (FusedBackend + CrawlScheduler.run_rounds); dense oracle "
            "backends take a fixed static k")
    if outcomes is not None:
        raise ValueError(
            "crawl outcomes require the fused SparseFeeds macro path "
            "(FusedBackend(online_est=True) + CrawlScheduler.run_rounds)")

    def step(st, feed):
        st, (top_g, top_v) = _round_body(backend, st, feed, mesh, k, dt)
        return st, (top_g, top_v, _diag_rows(st.backend, mesh.size))

    state, (ids, vals, diag) = jax.lax.scan(step, state, feeds)
    return state, (ids, vals), diag


def _fused_macro_rounds(backend: FusedBackend, state: RoundState,
                        feeds: SparseFeeds, mesh: Mesh, k: int, dt: float,
                        outcomes=None, budgets=None, rate=None):
    """The fused macro-round scan (see `crawl_rounds`): one shard_map whose
    body scans R rounds, reusing `_fused_shard_round` for the per-round
    math so each round is bit-identical to the sequential path.

    With `backend.online_est`, the same scan additionally threads the
    streaming-estimator planes (`FusedState.est`) through the carry — each
    round ingests its slice of the `outcomes` batch (O(cap) scatters) — and
    after the scan, still
    inside the shard_map, `online_est.apply_estimates` re-derives the
    packed env planes + bound rows of the touched pages on device. The
    off path's trace is built from the exact same expressions with no est
    operands, so it stays bit-identical to pre-estimation builds."""
    from repro.kernels import select as ksel
    from repro.sched import online_est as oest
    from repro.sched import tiered

    axes = tuple(mesh.axis_names)
    pspec = P(axes)
    bst: FusedState = state.backend
    R = feeds.ids.shape[0]
    n_blocks, _, block_rows, lanes = bst.env_planes.shape
    bp = block_rows * lanes
    m = state.tau_elap.shape[0]
    n_shards = mesh.size
    assert m == n_blocks * bp, (
        "fused path needs block-aligned padded state "
        f"(m={m}, planes={bst.env_planes.shape})"
    )
    assert n_blocks % n_shards == 0, (
        "fused path needs n_blocks divisible by the shard count"
    )
    assert feeds.counts.shape == feeds.ids.shape, feeds
    assert feeds.ids.ndim == 3 and feeds.ids.shape[1] == n_shards, (
        f"SparseFeeds must be per-shard (R, n_shards={n_shards}, cap); got "
        f"{feeds.ids.shape} — see CrawlScheduler._sparse_feed_batch"
    )
    est_on = backend.online_est
    if est_on:
        if bst.est is None:
            raise ValueError(
                "online_est backend with no estimator planes in FusedState "
                "— the state was built by a non-estimating backend config; "
                "rebuild the scheduler (or restore into an online_est one)")
        if outcomes is None:
            raise ValueError(
                "online_est macro-rounds need a SparseOutcomes batch "
                "(possibly empty) — CrawlScheduler.run_rounds builds it")
        assert outcomes.changed.shape == outcomes.ids.shape, outcomes
        assert outcomes.tau.shape == outcomes.ids.shape, outcomes
        assert outcomes.n_cis.shape == outcomes.ids.shape, outcomes
        assert (outcomes.ids.ndim == 3 and outcomes.ids.shape[0] == R
                and outcomes.ids.shape[1] == n_shards), (
            f"SparseOutcomes must be per-shard (R={R}, n_shards={n_shards}, "
            f"cap); got {outcomes.ids.shape} — see "
            "CrawlScheduler._sparse_outcome_batch"
        )
    elif outcomes is not None:
        raise ValueError(
            "crawl outcomes passed to a backend without online_est — "
            "construct FusedBackend(online_est=True)")
    nb_local = n_blocks // n_shards
    k_loc, cand = ksel.shard_budget(
        k, m // n_shards, nb_local, n_shards,
        backend.k_local, backend.cand_per_lane,
    )
    impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    dyn = budgets is not None or rate is not None
    if budgets is not None:
        assert budgets.ndim == 1 and budgets.shape[0] == R, (
            f"budgets must be (R={R},); got shape {budgets.shape}")
    if rate is not None and bst.emit_res is None:
        raise ValueError(
            "smooth emission needs the token-bucket residue plane "
            "(FusedState.emit_res) — CrawlScheduler(emission='smooth') "
            "attaches it; or pass an explicit budgets vector")
    degr = backend.degraded
    if degr and bst.stale is None:
        raise ValueError(
            "degraded backend with no watchdog plane in FusedState — the "
            "state was built by a non-degraded backend config; rebuild the "
            "scheduler (or restore into a degraded one)")
    # Scan-carry layout past the 10 base slots (python-level indices — the
    # conditional operands keep every legacy trace byte-identical).
    res_ix = 10 if rate is not None else None
    est_ix = 10 + (1 if rate is not None else 0)
    stale_ix = 10 + (1 if rate is not None else 0) + (1 if est_on else 0)

    def shard_fn(tau0, n0, fid, fcnt, env_shard, asym, slope, blkmax0, last0,
                 betam, cmass0, thresh0, hyst0, colw0, dhot0, clock0,
                 *extra):
        ex = list(extra)
        bud = ex.pop(0) if budgets is not None else None       # (R,) repl.
        rate_s = ex.pop(0) if rate is not None else None       # () repl.
        res0 = ex.pop(0) if rate is not None else None         # (1,) local
        m_local = tau0.shape[0]
        shard_lin = _shard_linear_index(axes)
        local_start = shard_lin * m_local
        # This shard's feed rows: (R, 1, cap) -> (R, cap).
        fid = fid.reshape(R, -1)
        fcnt = fcnt.reshape(R, -1)
        if est_on:
            oid, ochg, otau, ocis, est0 = ex[:5]
            ex = ex[5:]
            oid = oid.reshape(R, -1)
            ochg = ochg.reshape(R, -1)
            otau = otau.reshape(R, -1)
            ocis = ocis.reshape(R, -1)
        stale0 = ex.pop(0) if degr else None
        o0 = 3 if budgets is not None else 2  # outcome slices' xs offset

        def step(carry, xs):
            (tau, n, thresh_s, hyst_s, colw_s, dhot_s, blkmax, last_ev,
             cmass, clock) = carry[:10]
            fid_r, fcnt_r = xs[0], xs[1]
            # This shard's slice of the round's sparse feed: local indices
            # with the out-of-bounds drop sentinel for other shards' pages
            # and the -1 padding rows.
            rel = fid_r - local_start
            here = (rel >= 0) & (rel < m_local)
            fidx = jnp.where(here, rel, m_local)
            thresh = (thresh_s if backend.warm_start
                      else jnp.float32(-jnp.inf))
            # Per-round dynamic budget: an explicit row of the budget
            # vector, or the token bucket integrating the fractional rate
            # (residue stays in [0, 1), so any W-round window realizes
            # within +/-1 of rate * W). Replicated across shards.
            res = None
            if budgets is not None:
                k_r = xs[2]
            elif rate is not None:
                bucket = carry[res_ix] + rate_s
                k_r = jnp.clip(jnp.floor(bucket), 0, k).astype(jnp.int32)
                res = bucket - k_r.astype(jnp.float32)
            k_loc_dyn = (jnp.minimum(k_r, jnp.int32(k_loc)) if dyn
                         else None)
            if backend.adaptive_bounds or degr:
                # Per-block CIS counts via the same sparse scatter (exact:
                # integer sums in any order equal the dense reduction).
                blk_cis = jnp.zeros((nb_local,), jnp.int32).at[
                    jnp.where(here, rel // bp, nb_local)].add(
                        fcnt_r, mode="drop")
            else:
                blk_cis = None
            # The Pallas grid streams dense f32 state; the jnp path only
            # ever touches active blocks, so don't even trace the O(m) cast
            # there.
            dense_state = ((tau, n.astype(jnp.float32))
                           if impl == "pallas" else None)
            sel, upd = _fused_shard_round(
                backend, ksel.block_state_fn(tau, n, block_rows),
                dense_state, env_shard,
                _FusedShardCtx(asym=asym, slope=slope, blkmax=blkmax,
                               last_ev=last_ev, betam=betam, cmass=cmass,
                               thresh=thresh, hyst=hyst_s, colw=colw_s,
                               dhot=dhot_s, clock=clock),
                blk_cis, k_loc, cand, impl, dt, k_loc_dyn=k_loc_dyn,
                stale=carry[stale_ix] if degr else None,
            )
            top_g, top_v, idx = _global_winners(
                sel.values, sel.ids, axes, m_local, k,
                k_dyn=k_r if dyn else None)
            if est_on:
                # Fold this round's self-contained outcome slice (freshness
                # bit + echoed covariates — see `online_est.SparseOutcomes`)
                # into the streaming statistics: O(cap) scatters.
                orel = xs[o0] - local_start
                oidx = jnp.where((orel >= 0) & (orel < m_local), orel,
                                 m_local)
                quar = None
                if degr:
                    # Estimator quarantine: a crawl window that overlapped
                    # a flagged-silent channel is censored evidence — its
                    # n_cis understates the signals that actually fired,
                    # and ingesting it would drive gamma/alpha toward zero.
                    silent_b = upd.stale >= jnp.int32(backend.stale_limit)
                    quar = silent_b.at[oidx // bp].get(mode="clip")
                est = oest.ingest_outcomes(carry[est_ix], oidx, xs[o0 + 1],
                                           xs[o0 + 2], xs[o0 + 3],
                                           quarantine=quar)
            # Winner resets touch only the k crawled pages and the feed
            # ingest only the nnz fed pages (no O(m) mask / dense add):
            # tau drops to one round period and n to 0-then-feed — both
            # bit-equal to the sequential `where(mask, ...) + feed` forms.
            # Masked winner slots (id -1 past the dynamic budget) resolve
            # to the m_local sentinel and drop, so zero-budget rounds reset
            # nothing while tau/n still advance.
            tau = (tau + dt).at[idx].set(jnp.float32(dt), mode="drop")
            n = n.at[idx].set(0, mode="drop").at[fidx].add(fcnt_r,
                                                           mode="drop")
            carry = (tau, n, upd.thresh, upd.hyst, upd.colw, upd.dhot,
                     upd.blkmax, upd.last_ev, upd.cmass, clock + 1)
            if rate is not None:
                carry = carry + (res,)
            if est_on:
                carry = carry + (est,)
            if degr:
                carry = carry + (upd.stale,)
            ys = (top_g, top_v, sel.frac_active, sel.fell_back, upd.hyst,
                  upd.colw, upd.dhot)
            return carry, ys

        carry0 = (tau0, n0, thresh0[0], hyst0[0], colw0[0], dhot0[0],
                  blkmax0, last0, cmass0, clock0)
        if rate is not None:
            carry0 = carry0 + (res0[0],)
        if est_on:
            carry0 = carry0 + (est0,)
        if degr:
            carry0 = carry0 + (stale0,)
        xs = (fid, fcnt)
        if budgets is not None:
            xs = xs + (bud,)
        if est_on:
            xs = xs + (oid, ochg, otau, ocis)
        carry, ys = jax.lax.scan(step, carry0, xs)
        (tau, n, thresh_s, hyst_s, colw_s, dhot_s, blkmax, last_ev, cmass,
         _clock) = carry[:10]
        top_g, top_v, frac, fb, hyst_r, colw_r, dhot_r = ys
        if est_on:
            # Macro-boundary device-side refresh: repack the packed planes
            # of every page whose outcome landed this batch and re-derive
            # the touched blocks' bound rows (post-scan anchors).
            est = carry[est_ix]
            orel_all = oid.reshape(-1) - local_start
            touched = jnp.where(
                (orel_all >= 0) & (orel_all < m_local), orel_all, m_local)
            env2, bb2, betam2, cmass2 = oest.apply_estimates(
                est, env_shard, touched,
                tiered.BlockBounds(asym=asym, slope=slope, blk_max=blkmax,
                                   last_eval=last_ev),
                betam, cmass, min_obs=float(backend.est_min_obs),
                prior_a=backend.est_prior_a, prior_b=backend.est_prior_b,
                prior_w=backend.est_prior_w)
            blkmax, last_ev, cmass = bb2.blk_max, bb2.last_eval, cmass2
        out = (tau, n, thresh_s.reshape(1), hyst_s.reshape(1),
               colw_s.reshape(1), dhot_s.reshape(1), blkmax, last_ev,
               cmass, top_g, top_v,
               frac.reshape(R, 1), fb.reshape(R, 1), hyst_r.reshape(R, 1),
               colw_r.reshape(R, 1), dhot_r.reshape(R, 1))
        if rate is not None:
            out = out + (carry[res_ix].reshape(1),)
        if est_on:
            out = out + (env2, bb2.asym, bb2.slope, betam2, est)
        if degr:
            out = out + (carry[stale_ix],)
        return out

    base_in = (pspec, pspec, P(None, axes, None), P(None, axes, None),
               P(axes, None, None, None),
               pspec, pspec, pspec, pspec, pspec, pspec, pspec, pspec,
               pspec, pspec, P())
    base_out = (pspec, pspec, pspec, pspec, pspec, pspec, pspec, pspec,
                pspec, P(), P(), P(None, axes), P(None, axes),
                P(None, axes), P(None, axes), P(None, axes))
    base_args = (state.tau_elap, state.n_cis, feeds.ids, feeds.counts,
                 bst.env_planes, bst.bounds, bst.slope, bst.blk_max,
                 bst.last_eval, bst.beta_max, bst.cis_mass, bst.thresh,
                 bst.hyst, bst.col_winners, bst.depth_hot, state.crawl_clock)
    extra_in: tuple = ()
    extra_out: tuple = ()
    extra_args: tuple = ()
    if budgets is not None:
        extra_in += (P(None),)
        extra_args += (budgets.astype(jnp.int32),)
    if rate is not None:
        extra_in += (P(), pspec)
        extra_out += (pspec,)
        extra_args += (jnp.asarray(rate, jnp.float32), bst.emit_res)
    if est_on:
        est_spec = jax.tree.map(lambda _: pspec, bst.est)
        extra_in += (P(None, axes, None), P(None, axes, None),
                     P(None, axes, None), P(None, axes, None), est_spec)
        extra_out += (P(axes, None, None, None), pspec, pspec, pspec,
                      est_spec)
        extra_args += (outcomes.ids, outcomes.changed, outcomes.tau,
                       outcomes.n_cis, bst.est)
    if degr:
        extra_in += (pspec,)
        extra_out += (pspec,)
        extra_args += (bst.stale,)
    fn = _shard_map(shard_fn, mesh=mesh, in_specs=base_in + extra_in,
                    out_specs=base_out + extra_out)
    res_all = fn(*base_args, *extra_args)
    (tau, n, thresh, hyst, colw, dhot, blkmax, last_ev, cmass, ids, vals,
     frac, fb, hyst_r, colw_r, dhot_r) = res_all[:16]
    rest = list(res_all[16:])
    repl = dict(thresh=thresh, frac_active=frac[-1], fell_back=fb[-1],
                blk_max=blkmax, last_eval=last_ev, cis_mass=cmass, hyst=hyst,
                col_winners=colw, depth_hot=dhot)
    if rate is not None:
        repl["emit_res"] = rest.pop(0)
    if est_on:
        env_planes, asym, slope, betam, est = rest[:5]
        rest = rest[5:]
        repl.update(env_planes=env_planes, bounds=asym, slope=slope,
                    beta_max=betam, est=est)
    if degr:
        repl["stale"] = rest.pop(0)
    new_bst = bst._replace(**repl)
    new_state = RoundState(
        tau_elap=tau, n_cis=n, crawl_clock=state.crawl_clock + R,
        backend=new_bst,
    )
    return new_state, (ids, vals), RoundDiagnostics(
        frac_active=frac, fell_back=fb, hyst=hyst_r, col_winners=colw_r,
        depth_hot=dhot_r)


@functools.partial(
    jax.jit,
    static_argnames=("backend", "mesh"),
    donate_argnames=("bstate",),
)
def refresh_pages(
    backend: SelectionBackend,
    bstate,
    page_ids: jax.Array,
    d_new: DerivedEnv,
    block_ids: jax.Array | None = None,
    *,
    mesh: Mesh | None = None,
):
    """Jitted decentralized parameter refresh: scatter `d_new` (derived with
    the frozen construction-time mu_total) into the donated backend state.
    Fused backends repack only the touched plane columns + block bounds,
    shard-locally (per-shard batches inside a collective-free shard_map over
    `mesh` — required for the fused backend, ignored by the rest)."""
    return backend.update_pages(bstate, page_ids, d_new, block_ids,
                                mesh=mesh)
