"""SelectionBackend protocol + the functional scheduling round.

The scheduler API (paper Section 5.2) is organized around two pieces:

  * a **backend** — a frozen, hashable config object implementing the
    `SelectionBackend` protocol. It owns the selection strategy (how values
    are evaluated and the top-k extracted) and builds/updates its own state:

        DenseBackend   dense jnp series values (oracle-grade)
        TableBackend   exposure-table lookup (App. G tier tables)
        KernelBackend  dense Pallas value kernel + full top_k
        FusedBackend   packed PageShard planes + single-pass candidate
                       select (`kernels.select`), per-shard threshold
                       warm-start, per-block bounds — the production path

  * a **`RoundState`** — one functional, sharded pytree holding everything
    that changes round to round: the page state (tau^ELAP, n_CIS, clock) and
    the backend state (derived env / value table / packed env planes,
    per-shard warm-start thresholds, per-block bounds). Because it is a plain
    pytree it checkpoints, donates, and moves through jit/shard_map
    boundaries as-is.

One jitted `crawl_round(backend, state, new_cis, ...)` replaces the old
flag-dispatched `sharded_crawl_step` (which remains as a legacy shim). The
round **donates** the state: tau/n_CIS and the fused threshold/bound planes
are updated in place, and the packed env planes — unchanged within a round —
alias straight through, so no state plane is copied at production sizes.

Per-shard threshold warm-start (resolves the ROADMAP "sharded
bound/threshold exchange" item): `FusedState.thresh` holds one threshold per
shard, sharded alongside the pages, and each shard compares *its own*
previous k-th candidate value against its local block bounds. Carrying a
single global k-th value would force low-value shards into the dense
fallback every round (their local k-th sits far below the global one);
per-shard thresholds make warm-start sound — and cheap — on any mesh, while
selection stays provably identical to dense top-k via the exact-recovery
fallback in `kernels.select`.

Parameter refresh (the paper's decentralized per-page refresh) is
`refresh_pages(backend, bstate, page_ids, env_new, ...)`: each backend
scatter-updates only the touched rows of its state (fused: plane columns +
touched-block bounds via `layout.repack_pages`), again with the state buffer
donated. The global importance normalizer mu_total is frozen at construction
— greedy selection is invariant to a common scale factor, so per-page
updates never need a global renormalization pass (Section 5.2's
decentralization argument).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import tables
from repro.core.values import DerivedEnv, Env, derive
from repro.sched.distributed import (
    ShardedSchedState,
    _global_topk,
    _shard_map,
    sharded_select,
)

# Threshold warm-start relaxation: the next round's k-th value can sit below
# the current one (winners reset to ~0 value), so the carried threshold is
# relaxed; a too-aggressive threshold only costs a dense fallback, never
# exactness.
DEFAULT_HYSTERESIS = 0.9


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoundState:
    """Everything that changes round to round, as one sharded pytree.

    tau_elap/n_cis are sharded over all mesh axes; `backend` is the
    backend-owned state pytree (see each backend's `init`). Treat values as
    immutable: `crawl_round` donates the whole tree, so the previous
    RoundState's buffers are invalid once the next round runs.
    """

    tau_elap: jax.Array     # (m_state,) f32
    n_cis: jax.Array        # (m_state,) i32
    crawl_clock: jax.Array  # () i32 round counter
    backend: Any


class BackendInit(NamedTuple):
    """What a backend hands back from `init`: the (padded) state size, its
    state pytree, and host-side conveniences (derived env, value table)."""

    m_state: int
    state: Any
    d: DerivedEnv
    table: tables.ValueTable | None


class DenseState(NamedTuple):
    d: DerivedEnv


class TableState(NamedTuple):
    d: DerivedEnv
    table: tables.ValueTable


class FusedState(NamedTuple):
    env_planes: jax.Array   # (n_blocks, n_planes, block_rows, LANES) f32
    thresh: jax.Array       # (n_shards,) per-SHARD warm-start threshold
    bounds: jax.Array       # (n_blocks,) optimistic per-block bounds
    frac_active: jax.Array  # (n_shards,) diagnostics: blocks evaluated
    fell_back: jax.Array    # (n_shards,) diagnostics: dense recovery taken


def _pspec(mesh: Mesh) -> P:
    return P(tuple(mesh.axis_names))


def _put(x, mesh: Mesh, spec: P):
    return jax.device_put(x, NamedSharding(mesh, spec))


def _own(env: Env) -> Env:
    """Defensive copy of caller-owned env arrays. derive() may alias its
    inputs, and round donation would otherwise invalidate the caller's
    arrays the first time the state is donated."""
    return Env(*(jnp.copy(jnp.asarray(f)) for f in env))


def _scatter_derived(d: DerivedEnv, ids: jax.Array, d_new: DerivedEnv) -> DerivedEnv:
    return DerivedEnv(*[f.at[ids].set(n.astype(f.dtype)) for f, n in zip(d, d_new)])


@runtime_checkable
class SelectionBackend(Protocol):
    """Frozen config + strategy object. Implementations must be hashable
    (they are static jit arguments) and keep all array state in the pytree
    returned by `init` — the protocol is purely functional."""

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        """Build the backend state for a raw environment on a mesh."""
        ...

    def select(self, state: RoundState, mesh: Mesh, k: int):
        """Global top-k. Returns (page_ids (k,) replicated, values (k,)
        replicated, crawl mask (m_state,) sharded, new backend state)."""
        ...

    def update_pages(self, bstate, page_ids: jax.Array, d_new: DerivedEnv,
                     block_ids: jax.Array | None):
        """Scatter the refreshed derived parameters of `page_ids` into the
        backend state (shard-local / block-granular where the layout allows);
        `block_ids` are the touched blocks (fused layout only)."""
        ...


@dataclasses.dataclass(frozen=True)
class DenseBackend:
    """Dense jnp series values — oracle-grade reference selection."""

    n_terms: int = 8
    k_local: int | None = None
    use_kernel: bool = False  # route values through the dense Pallas kernel

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        env = _put(_own(env), mesh, _pspec(mesh))
        d = derive(env, mu_total=jnp.sum(env.mu))
        return BackendInit(env.m, DenseState(d=d), d, None)

    def select(self, state: RoundState, mesh: Mesh, k: int):
        st = ShardedSchedState(state.tau_elap, state.n_cis, state.crawl_clock)
        top_g, top_v, mask = sharded_select(
            st, state.backend.d, None, mesh, k, self.n_terms,
            self.use_kernel, self.k_local,
        )
        return top_g, top_v, mask, state.backend

    def update_pages(self, bstate, page_ids, d_new, block_ids=None):
        return bstate._replace(d=_scatter_derived(bstate.d, page_ids, d_new))


@dataclasses.dataclass(frozen=True)
class KernelBackend(DenseBackend):
    """Dense Pallas value kernel (values to HBM) + full top_k second pass."""

    use_kernel: bool = True


@dataclasses.dataclass(frozen=True)
class TableBackend:
    """Exposure-table lookup (App. G tier tables): V_NCIS(u) interpolated
    from a per-page grid built once per parameter refresh."""

    n_terms: int = 8
    table_grid: int = 128
    u_max: float = 40.0
    k_local: int | None = None

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        env = _put(_own(env), mesh, _pspec(mesh))
        d = derive(env, mu_total=jnp.sum(env.mu))
        table = tables.build_ncis_table(d, n_terms=self.n_terms,
                                        n_grid=self.table_grid,
                                        u_max=self.u_max)
        return BackendInit(env.m, TableState(d=d, table=table), d, table)

    def select(self, state: RoundState, mesh: Mesh, k: int):
        st = ShardedSchedState(state.tau_elap, state.n_cis, state.crawl_clock)
        top_g, top_v, mask = sharded_select(
            st, state.backend.d, state.backend.table, mesh, k, self.n_terms,
            False, self.k_local,
        )
        return top_g, top_v, mask, state.backend

    def update_pages(self, bstate, page_ids, d_new, block_ids=None):
        d = _scatter_derived(bstate.d, page_ids, d_new)
        rows = tables.build_ncis_table(
            d_new, n_terms=self.n_terms, n_grid=bstate.table.vals.shape[-1],
            u_max=self.u_max,
        )
        table = bstate.table._replace(
            vals=bstate.table.vals.at[page_ids].set(rows.vals)
        )
        return bstate._replace(d=d, table=table)


@dataclasses.dataclass(frozen=True)
class FusedBackend:
    """Packed planes + single-pass candidate select — the production path.

    warm_start enables the per-shard threshold skip (sound on any mesh size:
    each shard's threshold is its own previous k-th candidate value, relaxed
    by `hysteresis`). Selection remains exactly dense top-k regardless — the
    candidate-overflow / over-aggressive-threshold fallback in
    `kernels.select` guarantees it.
    """

    n_terms: int = 8
    block_rows: int | None = None
    k_local: int | None = None
    hysteresis: float = DEFAULT_HYSTERESIS
    warm_start: bool = True

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        from repro.kernels import layout

        block_rows = self.block_rows or layout.DEFAULT_BLOCK_ROWS
        m = env.m
        m_state = layout.padded_size(m, block_rows, n_shards=mesh.size)
        # Pad the raw env so derived state/env sizes agree; padding pages
        # (mu = 0) normalize away and score -inf in the fused kernel.
        if m_state != m:
            env = Env(
                delta=layout.pad_to(env.delta, m_state, 1.0),
                mu=layout.pad_to(env.mu, m_state, 0.0),
                lam=layout.pad_to(env.lam, m_state, 0.0),
                nu=layout.pad_to(env.nu, m_state, 0.0),
            )
        env = _put(env, mesh, _pspec(mesh))
        d = derive(env, mu_total=jnp.sum(env.mu))
        shard = layout.pack_shard(d, n_terms=self.n_terms,
                                  block_rows=block_rows)
        n_shards = mesh.size
        pspec = _pspec(mesh)
        neg_inf = jnp.full((n_shards,), -jnp.inf, jnp.float32)
        bstate = FusedState(
            env_planes=_put(shard.env, mesh, P(tuple(mesh.axis_names),
                                               None, None, None)),
            thresh=_put(neg_inf, mesh, pspec),
            bounds=_put(layout.asym_block_bounds(shard.env), mesh, pspec),
            frac_active=_put(jnp.ones((n_shards,), jnp.float32), mesh, pspec),
            fell_back=_put(jnp.zeros((n_shards,), bool), mesh, pspec),
        )
        return BackendInit(m_state, bstate, d, None)

    def select(self, state: RoundState, mesh: Mesh, k: int):
        from repro.kernels import select as ksel

        axes = tuple(mesh.axis_names)
        pspec = P(axes)
        bst: FusedState = state.backend
        n_blocks, _, block_rows, lanes = bst.env_planes.shape
        m = state.tau_elap.shape[0]
        n_shards = mesh.size
        assert m == n_blocks * block_rows * lanes, (
            "fused path needs block-aligned padded state "
            f"(m={m}, planes={bst.env_planes.shape})"
        )
        assert n_blocks % n_shards == 0, (
            "fused path needs n_blocks divisible by the shard count"
        )
        k_loc = min(self.k_local or k, k)
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
        hyst = jnp.float32(self.hysteresis)

        def shard_fn(tau_elap, n_cis, env_shard, bounds_shard, thresh_shard):
            # thresh_shard is this shard's OWN slice: the local k-th candidate
            # value of the previous round — sound to compare against local
            # block bounds (the ROADMAP per-shard threshold exchange).
            thresh = (thresh_shard[0] if self.warm_start
                      else jnp.float32(-jnp.inf))
            sel = ksel.fused_select_local(
                tau_elap, n_cis, env_shard, k_loc, thresh, bounds_shard,
                n_terms=self.n_terms, impl=impl, interpret=impl != "pallas",
            )
            m_local = tau_elap.shape[0]
            top_g, top_v, mask = _global_topk(sel.values, sel.ids, axes,
                                              m_local, k)
            new_thresh = (sel.values[k_loc - 1] * hyst).reshape(1)
            return (top_g, top_v, mask, new_thresh,
                    sel.frac_active.reshape(1), sel.fell_back.reshape(1))

        fn = _shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(pspec, pspec, P(axes, None, None, None), pspec, pspec),
            out_specs=(P(), P(), pspec, pspec, pspec, pspec),
        )
        top_g, top_v, mask, thresh, frac, fb = fn(
            state.tau_elap, state.n_cis, bst.env_planes, bst.bounds,
            bst.thresh,
        )
        new_bst = bst._replace(thresh=thresh, frac_active=frac, fell_back=fb)
        return top_g, top_v, mask, new_bst

    def update_pages(self, bstate, page_ids, d_new, block_ids=None):
        from repro.kernels import layout

        env_planes = layout.repack_pages(bstate.env_planes, page_ids, d_new)
        assert block_ids is not None, (
            "fused update_pages needs the touched block ids "
            "(page_ids // block_pages, deduplicated)"
        )
        bounds = layout.refresh_block_bounds(env_planes, bstate.bounds,
                                             block_ids)
        return bstate._replace(env_planes=env_planes, bounds=bounds)


def init_round(backend: SelectionBackend, env: Env, mesh: Mesh):
    """Build the initial RoundState (pages 'just crawled') for a backend.

    Returns (round_state, BackendInit) — the init carries the padded state
    size and host conveniences (derived env, table)."""
    binit = backend.init(env, mesh)
    pspec = _pspec(mesh)
    return RoundState(
        tau_elap=_put(jnp.zeros((binit.m_state,), jnp.float32), mesh, pspec),
        n_cis=_put(jnp.zeros((binit.m_state,), jnp.int32), mesh, pspec),
        crawl_clock=jnp.int32(0),
        backend=binit.state,
    ), binit


@functools.partial(
    jax.jit,
    static_argnames=("backend", "mesh", "k", "dt"),
    donate_argnames=("state",),
)
def crawl_round(
    backend: SelectionBackend,
    state: RoundState,
    new_cis: jax.Array,
    *,
    mesh: Mesh,
    k: int,
    dt: float,
):
    """One full scheduling round: select k pages globally, reset them,
    advance time, ingest the externally-fed CIS counts.

    Returns (new_round_state, (page_ids, values)). `state` is DONATED: its
    tau/n_CIS (and fused threshold/bound) buffers are updated in place and
    the packed env planes alias through untouched — no state plane is copied.
    Do not reuse the argument after the call; `new_cis` is not donated (feed
    buffers may be reused by the caller).
    """
    top_g, top_v, mask, new_b = backend.select(state, mesh, k)
    tau = jnp.where(mask, 0.0, state.tau_elap) + dt
    n = jnp.where(mask, 0, state.n_cis) + new_cis
    new_state = RoundState(
        tau_elap=tau, n_cis=n, crawl_clock=state.crawl_clock + 1,
        backend=new_b,
    )
    return new_state, (top_g, top_v)


@functools.partial(
    jax.jit,
    static_argnames=("backend",),
    donate_argnames=("bstate",),
)
def refresh_pages(
    backend: SelectionBackend,
    bstate,
    page_ids: jax.Array,
    d_new: DerivedEnv,
    block_ids: jax.Array | None = None,
):
    """Jitted decentralized parameter refresh: scatter `d_new` (derived with
    the frozen construction-time mu_total) into the donated backend state.
    Fused backends repack only the touched plane columns + block bounds."""
    return backend.update_pages(bstate, page_ids, d_new, block_ids)
