"""SelectionBackend protocol + the functional scheduling round.

The scheduler API (paper Section 5.2) is organized around two pieces:

  * a **backend** — a frozen, hashable config object implementing the
    `SelectionBackend` protocol. It owns the selection strategy (how values
    are evaluated and the top-k extracted) and builds/updates its own state:

        DenseBackend   dense jnp series values (oracle-grade)
        TableBackend   exposure-table lookup (App. G tier tables)
        KernelBackend  dense Pallas value kernel + full top_k
        FusedBackend   packed PageShard planes + single-pass candidate
                       select (`kernels.select`), per-shard threshold
                       warm-start, per-block bounds — the production path

  * a **`RoundState`** — one functional, sharded pytree holding everything
    that changes round to round: the page state (tau^ELAP, n_CIS, clock) and
    the backend state (derived env / value table / packed env planes,
    per-shard warm-start thresholds, per-block bounds). Because it is a plain
    pytree it checkpoints, donates, and moves through jit/shard_map
    boundaries as-is.

One jitted `crawl_round(backend, state, new_cis, ...)` replaces the old
flag-dispatched `sharded_crawl_step` (which remains as a legacy shim). The
round **donates** the state: tau/n_CIS and the fused threshold/bound planes
are updated in place, and the packed env planes — unchanged within a round —
alias straight through, so no state plane is copied at production sizes.

Per-shard threshold warm-start (resolves the ROADMAP "sharded
bound/threshold exchange" item): `FusedState.thresh` holds one threshold per
shard, sharded alongside the pages, and each shard compares *its own*
previous k-th candidate value against its local block bounds. Carrying a
single global k-th value would force low-value shards into the dense
fallback every round (their local k-th sits far below the global one);
per-shard thresholds make warm-start sound — and cheap — on any mesh, while
selection stays provably identical to dense top-k via the exact-recovery
fallback in `kernels.select`.

The adaptive skip-control loop (ROADMAP "adaptive BlockBounds" / "adaptive
hysteresis") closes entirely inside the jitted, donated round: `FusedState`
additionally carries the refreshing per-block bound rows (slope / blk_max /
last_eval — the `tiered.BlockBounds` construction), the per-shard
hysteresis scalar, and the realized candidate-depth watermark. Each
`crawl_round` folds the kernel's block maxima back into the anchors,
re-marks CIS-receiving blocks stale (the re-evaluation rule that keeps
refreshing bounds sound under signal jumps), and tightens/relaxes the
warm-start threshold from the fallback diagnostic — no host round-trip, no
extra pass over the pages. See `FusedBackend` for the flags.

Parameter refresh (the paper's decentralized per-page refresh) is
`refresh_pages(backend, bstate, page_ids, env_new, ...)`: each backend
scatter-updates only the touched rows of its state (fused: plane columns +
touched-block bounds via `layout.repack_pages`), again with the state buffer
donated. The global importance normalizer mu_total is frozen at construction
— greedy selection is invariant to a common scale factor, so per-page
updates never need a global renormalization pass (Section 5.2's
decentralization argument).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import tables
from repro.core.values import DerivedEnv, Env, derive
from repro.sched.distributed import (
    ShardedSchedState,
    _global_topk,
    _shard_map,
    sharded_select,
)

# Threshold warm-start relaxation: the next round's k-th value can sit below
# the current one (winners reset to ~0 value), so the carried threshold is
# relaxed; a too-aggressive threshold only costs a dense fallback, never
# exactness. This is only the *initial* factor — the hysteresis loop is
# closed in-jit per shard (FusedState.hyst): tighten toward HYSTERESIS_MAX
# while no fallback fires, relax on fallback.
DEFAULT_HYSTERESIS = 0.9
HYSTERESIS_MIN = 0.5
HYSTERESIS_MAX = 0.98
HYSTERESIS_TIGHTEN = 0.01   # additive step per clean round
HYSTERESIS_RELAX = 0.1      # additive step back per fallback round


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoundState:
    """Everything that changes round to round, as one sharded pytree.

    tau_elap/n_cis are sharded over all mesh axes; `backend` is the
    backend-owned state pytree (see each backend's `init`). Treat values as
    immutable: `crawl_round` donates the whole tree, so the previous
    RoundState's buffers are invalid once the next round runs.
    """

    tau_elap: jax.Array     # (m_state,) f32
    n_cis: jax.Array        # (m_state,) i32
    crawl_clock: jax.Array  # () i32 round counter
    backend: Any


class BackendInit(NamedTuple):
    """What a backend hands back from `init`: the (padded) state size, its
    state pytree, and host-side conveniences (derived env, value table)."""

    m_state: int
    state: Any
    d: DerivedEnv
    table: tables.ValueTable | None


class DenseState(NamedTuple):
    d: DerivedEnv


class TableState(NamedTuple):
    d: DerivedEnv
    table: tables.ValueTable


class FusedState(NamedTuple):
    """All array state of the fused backend. NOTE: a NamedTuple checkpoints
    under its *field names* (backend/.thresh, ...), so growing the state is
    append-only in spirit: never rename or repurpose an existing field —
    `checkpoint.restore(strict=False)` then loads pre-adaptive snapshots
    into the grown state by name (the new planes keep their init values)."""

    env_planes: jax.Array   # (n_blocks, n_planes, block_rows, LANES) f32
    thresh: jax.Array       # (n_shards,) per-SHARD warm-start threshold
    bounds: jax.Array       # (n_blocks,) static asymptote bound (cap of the
    #                         refreshing bound; the bound used directly when
    #                         adaptive_bounds is off)
    frac_active: jax.Array  # (n_shards,) diagnostics: blocks evaluated
    fell_back: jax.Array    # (n_shards,) diagnostics: dense recovery taken
    # --- adaptive skip-control planes (appended; see class docstring) ---
    slope: jax.Array        # (n_blocks,) max value-growth-rate bound
    blk_max: jax.Array      # (n_blocks,) block max at last exact evaluation
    last_eval: jax.Array    # (n_blocks,) i32 round of last exact evaluation
    #                         (-1 = never: +inf bound, must evaluate)
    hyst: jax.Array         # (n_shards,) adaptive hysteresis scalar
    col_winners: jax.Array  # (n_shards,) i32 running max winners observed
    #                         per lane column (candidate-depth sizing)


def _pspec(mesh: Mesh) -> P:
    return P(tuple(mesh.axis_names))


def _put(x, mesh: Mesh, spec: P):
    return jax.device_put(x, NamedSharding(mesh, spec))


def _own(env: Env) -> Env:
    """Defensive copy of caller-owned env arrays. derive() may alias its
    inputs, and round donation would otherwise invalidate the caller's
    arrays the first time the state is donated."""
    return Env(*(jnp.copy(jnp.asarray(f)) for f in env))


def _scatter_derived(d: DerivedEnv, ids: jax.Array, d_new: DerivedEnv) -> DerivedEnv:
    return DerivedEnv(*[f.at[ids].set(n.astype(f.dtype)) for f, n in zip(d, d_new)])


@runtime_checkable
class SelectionBackend(Protocol):
    """Frozen config + strategy object. Implementations must be hashable
    (they are static jit arguments) and keep all array state in the pytree
    returned by `init` — the protocol is purely functional."""

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        """Build the backend state for a raw environment on a mesh."""
        ...

    def select(self, state: RoundState, mesh: Mesh, k: int, *,
               dt: float = 0.0, new_cis: jax.Array | None = None):
        """Global top-k. Returns (page_ids (k,) replicated, values (k,)
        replicated, crawl mask (m_state,) sharded, new backend state).

        dt/new_cis thread the round context through for backends whose
        state update depends on it: the fused adaptive bounds need the
        round period to decay block bounds, and the CIS feed so any block
        that received signals this round is re-marked stale (a CIS jump is
        instant value growth the slope bound cannot see — re-evaluating
        keeps a skipped block from hiding a signal-jumped winner).
        Stateless backends ignore both."""
        ...

    def update_pages(self, bstate, page_ids: jax.Array, d_new: DerivedEnv,
                     block_ids: jax.Array | None):
        """Scatter the refreshed derived parameters of `page_ids` into the
        backend state (shard-local / block-granular where the layout allows);
        `block_ids` are the touched blocks (fused layout only)."""
        ...


@dataclasses.dataclass(frozen=True)
class DenseBackend:
    """Dense jnp series values — oracle-grade reference selection."""

    n_terms: int = 8
    k_local: int | None = None
    use_kernel: bool = False  # route values through the dense Pallas kernel

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        env = _put(_own(env), mesh, _pspec(mesh))
        d = derive(env, mu_total=jnp.sum(env.mu))
        return BackendInit(env.m, DenseState(d=d), d, None)

    def select(self, state: RoundState, mesh: Mesh, k: int, *,
               dt: float = 0.0, new_cis: jax.Array | None = None):
        st = ShardedSchedState(state.tau_elap, state.n_cis, state.crawl_clock)
        top_g, top_v, mask = sharded_select(
            st, state.backend.d, None, mesh, k, self.n_terms,
            self.use_kernel, self.k_local,
        )
        return top_g, top_v, mask, state.backend

    def update_pages(self, bstate, page_ids, d_new, block_ids=None):
        return bstate._replace(d=_scatter_derived(bstate.d, page_ids, d_new))


@dataclasses.dataclass(frozen=True)
class KernelBackend(DenseBackend):
    """Dense Pallas value kernel (values to HBM) + full top_k second pass."""

    use_kernel: bool = True


@dataclasses.dataclass(frozen=True)
class TableBackend:
    """Exposure-table lookup (App. G tier tables): V_NCIS(u) interpolated
    from a per-page grid built once per parameter refresh."""

    n_terms: int = 8
    table_grid: int = 128
    u_max: float = 40.0
    k_local: int | None = None

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        env = _put(_own(env), mesh, _pspec(mesh))
        d = derive(env, mu_total=jnp.sum(env.mu))
        table = tables.build_ncis_table(d, n_terms=self.n_terms,
                                        n_grid=self.table_grid,
                                        u_max=self.u_max)
        return BackendInit(env.m, TableState(d=d, table=table), d, table)

    def select(self, state: RoundState, mesh: Mesh, k: int, *,
               dt: float = 0.0, new_cis: jax.Array | None = None):
        st = ShardedSchedState(state.tau_elap, state.n_cis, state.crawl_clock)
        top_g, top_v, mask = sharded_select(
            st, state.backend.d, state.backend.table, mesh, k, self.n_terms,
            False, self.k_local,
        )
        return top_g, top_v, mask, state.backend

    def update_pages(self, bstate, page_ids, d_new, block_ids=None):
        d = _scatter_derived(bstate.d, page_ids, d_new)
        rows = tables.build_ncis_table(
            d_new, n_terms=self.n_terms, n_grid=bstate.table.vals.shape[-1],
            u_max=self.u_max,
        )
        table = bstate.table._replace(
            vals=bstate.table.vals.at[page_ids].set(rows.vals)
        )
        return bstate._replace(d=d, table=table)


@dataclasses.dataclass(frozen=True)
class FusedBackend:
    """Packed planes + single-pass candidate select — the production path.

    warm_start enables the per-shard threshold skip (sound on any mesh size:
    each shard's threshold is its own previous k-th candidate value, relaxed
    by the hysteresis scalar). Selection remains exactly dense top-k
    regardless — the candidate-overflow / over-aggressive-threshold fallback
    in `kernels.select` guarantees it.

    Adaptive skip control (the App. G tiering loop, closed in-jit):

      * adaptive_bounds (opt-in): each round's per-block maxima fold back
        into the refreshing `tiered.BlockBounds` carried in `FusedState`
        (slope-decayed anchor, capped by the static asymptote), replacing
        the static asymptote-only bound. Soundness under CIS: any block
        whose pages received `new_cis > 0` this round is re-marked
        never-evaluated (+inf bound), so a skipped block can never hide a
        signal-jumped winner — selection stays exactly dense top-k.
      * adaptive_hysteresis (default on): the per-shard warm-start
        threshold factor is carried in `FusedState.hyst` and adapted from
        the fallback diagnostic — tightened toward `hyst_max` while no
        fallback fires (more skipping), relaxed toward `hyst_min` on
        fallback (fewer dense passes).
      * cand_per_lane (None = auto-size for the worst case): candidate
        buffer depth. `FusedState.col_winners` tracks the realized
        per-lane-column winner counts so `CrawlScheduler` (adaptive_cand)
        can shrink the depth on well-mixed shards — fewer extraction
        passes per active block.
    """

    n_terms: int = 8
    block_rows: int | None = None
    k_local: int | None = None
    hysteresis: float = DEFAULT_HYSTERESIS
    warm_start: bool = True
    adaptive_bounds: bool = False
    adaptive_hysteresis: bool = True
    adaptive_cand: bool = False
    cand_per_lane: int | None = None
    hyst_min: float = HYSTERESIS_MIN
    hyst_max: float = HYSTERESIS_MAX
    hyst_tighten: float = HYSTERESIS_TIGHTEN
    hyst_relax: float = HYSTERESIS_RELAX

    def init(self, env: Env, mesh: Mesh) -> BackendInit:
        from repro.kernels import layout
        from repro.sched import tiered

        block_rows = self.block_rows or layout.DEFAULT_BLOCK_ROWS
        m = env.m
        m_state = layout.padded_size(m, block_rows, n_shards=mesh.size)
        # Pad the raw env so derived state/env sizes agree; padding pages
        # (mu = 0) normalize away and score -inf in the fused kernel.
        if m_state != m:
            env = Env(
                delta=layout.pad_to(env.delta, m_state, 1.0),
                mu=layout.pad_to(env.mu, m_state, 0.0),
                lam=layout.pad_to(env.lam, m_state, 0.0),
                nu=layout.pad_to(env.nu, m_state, 0.0),
            )
        env = _put(env, mesh, _pspec(mesh))
        d = derive(env, mu_total=jnp.sum(env.mu))
        shard = layout.pack_shard(d, n_terms=self.n_terms,
                                  block_rows=block_rows)
        n_shards = mesh.size
        pspec = _pspec(mesh)
        neg_inf = jnp.full((n_shards,), -jnp.inf, jnp.float32)
        bb = tiered.init_block_bounds(shard.env)
        bstate = FusedState(
            env_planes=_put(shard.env, mesh, P(tuple(mesh.axis_names),
                                               None, None, None)),
            thresh=_put(neg_inf, mesh, pspec),
            bounds=_put(bb.asym, mesh, pspec),
            frac_active=_put(jnp.ones((n_shards,), jnp.float32), mesh, pspec),
            fell_back=_put(jnp.zeros((n_shards,), bool), mesh, pspec),
            slope=_put(bb.slope, mesh, pspec),
            blk_max=_put(bb.blk_max, mesh, pspec),
            last_eval=_put(bb.last_eval, mesh, pspec),
            hyst=_put(jnp.full((n_shards,), self.hysteresis, jnp.float32),
                      mesh, pspec),
            col_winners=_put(jnp.zeros((n_shards,), jnp.int32), mesh, pspec),
        )
        return BackendInit(m_state, bstate, d, None)

    def select(self, state: RoundState, mesh: Mesh, k: int, *,
               dt: float = 0.0, new_cis: jax.Array | None = None):
        from repro.kernels import select as ksel
        from repro.sched import tiered

        axes = tuple(mesh.axis_names)
        pspec = P(axes)
        bst: FusedState = state.backend
        n_blocks, _, block_rows, lanes = bst.env_planes.shape
        m = state.tau_elap.shape[0]
        n_shards = mesh.size
        assert m == n_blocks * block_rows * lanes, (
            "fused path needs block-aligned padded state "
            f"(m={m}, planes={bst.env_planes.shape})"
        )
        assert n_blocks % n_shards == 0, (
            "fused path needs n_blocks divisible by the shard count"
        )
        # Shard-local budget + candidate depth, clamped by the one shared
        # rule (`select.shard_budget`): exactness survives the clamp — a
        # shard can contribute at most its page count, and the capacity
        # clamp only binds with an explicitly undersized cand_per_lane,
        # where the overflow fallback already restores dense selection.
        k_loc, cand = ksel.shard_budget(
            k, m // n_shards, n_blocks // n_shards, n_shards,
            self.k_local, self.cand_per_lane,
        )
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
        if new_cis is None:
            new_cis = jnp.zeros_like(state.n_cis)

        def shard_fn(tau_elap, n_cis, cis_feed, env_shard, asym, slope,
                     blkmax, last_ev, thresh_shard, hyst_shard, colw_shard,
                     clock):
            # thresh_shard is this shard's OWN slice: the local k-th candidate
            # value of the previous round — sound to compare against local
            # block bounds (the ROADMAP per-shard threshold exchange).
            bb = tiered.BlockBounds(asym=asym, slope=slope, blk_max=blkmax,
                                    last_eval=last_ev)
            bound = (tiered.current_block_bounds(bb, clock, dt)
                     if self.adaptive_bounds else asym)
            thresh = (thresh_shard[0] if self.warm_start
                      else jnp.float32(-jnp.inf))
            sel = ksel.fused_select_local(
                tau_elap, n_cis, env_shard, k_loc, thresh, bound,
                n_terms=self.n_terms, cand_per_lane=cand, impl=impl,
                interpret=impl != "pallas",
            )
            m_local = tau_elap.shape[0]
            top_g, top_v, mask = _global_topk(sel.values, sel.ids, axes,
                                              m_local, k)
            # Hysteresis loop: tighten while the threshold proved safe,
            # relax when it (or candidate overflow) forced a dense pass.
            if self.adaptive_hysteresis:
                h = jnp.where(
                    sel.fell_back,
                    jnp.maximum(hyst_shard[0] - self.hyst_relax,
                                self.hyst_min),
                    jnp.minimum(hyst_shard[0] + self.hyst_tighten,
                                self.hyst_max),
                )
            else:
                h = jnp.float32(self.hysteresis)
            new_thresh = (sel.values[k_loc - 1] * h).reshape(1)
            if self.adaptive_bounds:
                # Fold the round's block maxima back into the bound anchors.
                # On fallback rounds the dense pass evaluated every block
                # (blk_max is recomputed from the dense values in
                # kernels.select).
                evaluated = (bound >= thresh) | sel.fell_back
                bb = tiered.update_block_bounds(bb, sel.blk_max, evaluated,
                                                clock)
                # CIS-seen re-evaluation rule: a CIS jumps exposure
                # instantly, which the slope bound cannot see — blocks that
                # received signals this round lose their anchor (+inf bound
                # next round), so a skipped block can never hide a
                # signal-jumped winner.
                cis_seen = (cis_feed.reshape(asym.shape[0], -1) > 0) \
                    .any(axis=1)
                new_blkmax = bb.blk_max
                new_last = jnp.where(cis_seen, jnp.int32(-1), bb.last_eval)
            else:
                # Static bound: the anchors are never read — alias them
                # through untouched (no per-round plane writes, no O(m)
                # CIS reduction on the default path).
                new_blkmax, new_last = blkmax, last_ev
            # Running max of realized per-column winner depth: the host-side
            # candidate-depth adaptation reads (and resets) this window.
            colw = jnp.maximum(colw_shard[0], sel.col_winners)
            return (top_g, top_v, mask, new_thresh,
                    sel.frac_active.reshape(1), sel.fell_back.reshape(1),
                    new_blkmax, new_last, h.reshape(1), colw.reshape(1))

        fn = _shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(pspec, pspec, pspec, P(axes, None, None, None),
                      pspec, pspec, pspec, pspec, pspec, pspec, pspec, P()),
            out_specs=(P(), P(), pspec, pspec, pspec, pspec,
                       pspec, pspec, pspec, pspec),
        )
        top_g, top_v, mask, thresh, frac, fb, blkmax, last_ev, hyst, colw = fn(
            state.tau_elap, state.n_cis, new_cis, bst.env_planes, bst.bounds,
            bst.slope, bst.blk_max, bst.last_eval, bst.thresh, bst.hyst,
            bst.col_winners, state.crawl_clock,
        )
        new_bst = bst._replace(thresh=thresh, frac_active=frac, fell_back=fb,
                               blk_max=blkmax, last_eval=last_ev, hyst=hyst,
                               col_winners=colw)
        return top_g, top_v, mask, new_bst

    def update_pages(self, bstate, page_ids, d_new, block_ids=None):
        from repro.kernels import layout
        from repro.sched import tiered

        env_planes = layout.repack_pages(bstate.env_planes, page_ids, d_new)
        assert block_ids is not None, (
            "fused update_pages needs the touched block ids "
            "(page_ids // block_pages, deduplicated)"
        )
        # Refresh every env-dependent bound row of the touched blocks
        # (asymptote AND slope), and drop their anchors: the repacked pages'
        # values are unrelated to the recorded block max, so the blocks
        # re-evaluate exactly next round (last_eval = -1 -> +inf bound).
        bb = tiered.refresh_block_params(
            tiered.BlockBounds(asym=bstate.bounds, slope=bstate.slope,
                               blk_max=bstate.blk_max,
                               last_eval=bstate.last_eval),
            env_planes, block_ids)
        return bstate._replace(env_planes=env_planes, bounds=bb.asym,
                               slope=bb.slope, blk_max=bb.blk_max,
                               last_eval=bb.last_eval)


def init_round(backend: SelectionBackend, env: Env, mesh: Mesh):
    """Build the initial RoundState (pages 'just crawled') for a backend.

    Returns (round_state, BackendInit) — the init carries the padded state
    size and host conveniences (derived env, table)."""
    binit = backend.init(env, mesh)
    pspec = _pspec(mesh)
    return RoundState(
        tau_elap=_put(jnp.zeros((binit.m_state,), jnp.float32), mesh, pspec),
        n_cis=_put(jnp.zeros((binit.m_state,), jnp.int32), mesh, pspec),
        crawl_clock=jnp.int32(0),
        backend=binit.state,
    ), binit


@functools.partial(
    jax.jit,
    static_argnames=("backend", "mesh", "k", "dt"),
    donate_argnames=("state",),
)
def crawl_round(
    backend: SelectionBackend,
    state: RoundState,
    new_cis: jax.Array,
    *,
    mesh: Mesh,
    k: int,
    dt: float,
):
    """One full scheduling round: select k pages globally, reset them,
    advance time, ingest the externally-fed CIS counts.

    Returns (new_round_state, (page_ids, values)). `state` is DONATED: its
    tau/n_CIS (and fused threshold/bound/anchor) buffers are updated in
    place and the packed env planes alias through untouched — no state plane
    is copied. Do not reuse the argument after the call; `new_cis` is not
    donated (feed buffers may be reused by the caller).

    The CIS feed and round period thread into `select` so stateful backends
    can close their skip-control loop in the same jitted round: the fused
    adaptive bounds decay by `dt` and re-mark any block receiving
    `new_cis > 0` as stale (see `FusedBackend`).
    """
    top_g, top_v, mask, new_b = backend.select(state, mesh, k, dt=dt,
                                               new_cis=new_cis)
    tau = jnp.where(mask, 0.0, state.tau_elap) + dt
    n = jnp.where(mask, 0, state.n_cis) + new_cis
    new_state = RoundState(
        tau_elap=tau, n_cis=n, crawl_clock=state.crawl_clock + 1,
        backend=new_b,
    )
    return new_state, (top_g, top_v)


@functools.partial(
    jax.jit,
    static_argnames=("backend",),
    donate_argnames=("bstate",),
)
def refresh_pages(
    backend: SelectionBackend,
    bstate,
    page_ids: jax.Array,
    d_new: DerivedEnv,
    block_ids: jax.Array | None = None,
):
    """Jitted decentralized parameter refresh: scatter `d_new` (derived with
    the frozen construction-time mu_total) into the donated backend state.
    Fused backends repack only the touched plane columns + block bounds."""
    return backend.update_pages(bstate, page_ids, d_new, block_ids)
