"""Production scheduler: pluggable selection backends over one functional
sharded RoundState (dense / table / kernel / fused), tiering, elastic
service, decentralized parameter refresh."""
from repro.sched.backends import (
    BackendInit,
    DenseBackend,
    FusedBackend,
    FusedState,
    KernelBackend,
    RoundDiagnostics,
    RoundState,
    SelectionBackend,
    SparseFeeds,
    TableBackend,
    crawl_round,
    crawl_rounds,
    init_round,
    refresh_pages,
)
from repro.sched.errors import (
    CapacityExceeded,
    FeedDtypeError,
    FeedValidationError,
    SchedulerError,
)
from repro.sched.distributed import (
    ShardedSchedState,
    host_local_array,
    host_shard_range,
    make_sharded_env,
    sharded_crawl_step,
    sharded_select,
)
from repro.sched.online_est import (
    SparseOutcomes,
    apply_estimates,
    ingest_outcomes,
    init_est,
)
from repro.sched.service import CrawlScheduler
from repro.sched.tiered import (
    BlockBounds,
    TierState,
    accumulate_cis_mass,
    current_block_bounds,
    init_block_bounds,
    refresh_block_params,
    tiered_select,
    update_block_bounds,
)

__all__ = [k for k in dir() if not k.startswith("_")]
