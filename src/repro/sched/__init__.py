"""Production scheduler: sharded selection (dense / table / fused), tiering,
elastic service."""
from repro.sched.distributed import (
    ShardedSchedState,
    make_sharded_env,
    sharded_crawl_step,
    sharded_select,
)
from repro.sched.service import CrawlScheduler
from repro.sched.tiered import (
    BlockBounds,
    TierState,
    current_block_bounds,
    init_block_bounds,
    tiered_select,
    update_block_bounds,
)

__all__ = [k for k in dir() if not k.startswith("_")]
