"""Production scheduler: sharded selection, tiering, elastic service."""
from repro.sched.distributed import (
    ShardedSchedState,
    make_sharded_env,
    sharded_crawl_step,
    sharded_select,
)
from repro.sched.service import CrawlScheduler
from repro.sched.tiered import TierState, tiered_select

__all__ = [k for k in dir() if not k.startswith("_")]
