"""Training step: loss, grads, optimizer update — pjit-ready.

The cross-entropy keeps logits tensor-sharded over the vocab dim ("tensor" ->
model axis); the log-sum-exp and label gather run on the sharded layout and
XLA inserts the small model-axis reductions — the (B, S, V) f32 logits tensor
never materializes unsharded (it would be ~13 GB/chip for granite-8b at 4k).

Microbatching: optional gradient accumulation over n_micro slices of the
per-step batch via lax.scan (memory ~ 1/n_micro activations at the cost of
re-running the forward; used by long-sequence cells).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.common import constrain
from repro.optim import Optimizer


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def _chunked_xent(cfg, embed_params, hidden, labels, mesh, chunk=512):
    """Sequence-chunked cross entropy from final hidden states.

    The (B, S, V) f32 logits tensor never exists: each chunk's logits are
    (re)computed inside a jax.checkpoint'd scan body (forward AND backward),
    keeping live logits at (B, chunk, V/|model|).
    """
    from repro.models.common import apply_norm, softcap

    b, s, d = hidden.shape
    while s % chunk:
        chunk //= 2
    n_chunks = s // chunk
    head = (embed_params["lm_head"] if "lm_head" in embed_params
            else embed_params["tok"].T)

    hid_c = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lab_c = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        nll_sum, z_sum, cnt = carry
        h, lab = xs
        h = apply_norm(cfg, embed_params["ln_f"], h)
        logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype))
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
        logits = constrain(logits, mesh, "batch", None, "tensor")
        mask = (lab >= 0).astype(jnp.float32)
        lab_cl = jnp.maximum(lab, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab_cl[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + jnp.sum((logz - ll) * mask)
        z_sum = z_sum + jnp.sum((logz * mask) ** 2)
        cnt = cnt + mask.sum()
        return (nll_sum, z_sum, cnt), None

    zero = jnp.float32(0.0)
    (nll_sum, z_sum, cnt), _ = jax.lax.scan(body, (zero, zero, zero),
                                            (hid_c, lab_c))
    denom = jnp.maximum(cnt, 1.0)
    return nll_sum / denom, 1e-4 * z_sum / denom


def loss_fn(cfg: ModelConfig, params, batch, mesh=None, impl="triangle"):
    hidden, aux = M.forward_hidden(cfg, params, batch, mesh, impl)
    xent, zloss = _chunked_xent(cfg, params["embed"], hidden,
                                batch["labels"], mesh)
    return xent + zloss + aux, {"xent": xent, "aux": aux, "zloss": zloss}


def _micro_split(batch, n_micro):
    return jax.tree.map(
        lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
        batch,
    )


def train_step(cfg: ModelConfig, optimizer: Optimizer, state: TrainState,
               batch, mesh=None, impl="triangle", n_micro: int = 1):
    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b, mesh, impl), has_aux=True
    )
    if n_micro == 1:
        (loss, parts), grads = grad_fn(state.params, batch)
    else:
        micro = _micro_split(batch, n_micro)

        def acc(carry, mb):
            g_acc, l_acc = carry
            (l, _), g = grad_fn(state.params, mb)
            return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params
        )
        (grads, loss), _ = jax.lax.scan(acc, (g0, jnp.float32(0.0)), micro)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        loss = loss / n_micro
        parts = {"xent": loss, "aux": jnp.float32(0), "zloss": jnp.float32(0)}

    params, opt_state, om = optimizer.update(
        grads, state.opt_state, state.params, state.step
    )
    metrics = {"loss": loss, **parts, **om}
    return TrainState(params=params, opt_state=opt_state,
                      step=state.step + 1), metrics


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, mesh=None,
                    impl="triangle", n_micro: int = 1, donate: bool = True):
    """jit-wrapped train step (donates state buffers)."""
    fn = functools.partial(train_step, cfg, optimizer, mesh=mesh, impl=impl,
                           n_micro=n_micro)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())
