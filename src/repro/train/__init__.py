from repro.train.step import TrainState, loss_fn, make_train_step, train_step

__all__ = [k for k in dir() if not k.startswith("_")]
