"""Normalized Taylor residuals of exp — the paper's R^i_exp.

R^i(x) = (exp(x) - sum_{j<=i} x^j/j!) / exp(x) = 1 - e^{-x} sum_{j<=i} x^j/j!

Identity used here (numerically superior to the literal form, which suffers
catastrophic cancellation near 0): the truncated Poisson tail equals the
regularized lower incomplete gamma function,

    R^i(x) = P(i+1, x) = gammainc(i+1, x).

Property (paper Eq. (3)):  d/dx R^i(x) = R^{i-1}(x) - R^i(x) = x^i e^{-x} / i!.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import gammainc


def residual(i: jax.Array | int, x: jax.Array) -> jax.Array:
    """R^i_exp(x) for i >= 0 (broadcasts); defined as 0 for x <= 0."""
    i = jnp.asarray(i, dtype=x.dtype if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else jnp.float32)
    x = jnp.asarray(x)
    xc = jnp.maximum(x, 0.0)
    return jnp.where(x > 0, gammainc(i + 1.0, xc), 0.0)


def residual_naive(i: int, x: jax.Array) -> jax.Array:
    """Literal textbook form, for oracle cross-checks only."""
    x = jnp.asarray(x, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    s = jnp.zeros_like(x)
    term = jnp.ones_like(x)
    for j in range(i + 1):
        if j > 0:
            term = term * x / j
        s = s + term
    return jnp.where(x > 0, 1.0 - jnp.exp(-x) * s, 0.0)


def residual_ladder(x: jax.Array) -> jax.Array:
    """R^i(x[..., i]) for i = 0..K-1, term index along the last axis, computed
    by the truncated Taylor series (exp + K^2/2 fused multiply-adds).

    This is the kernel-friendly evaluation: no iterative special functions, so
    it maps directly onto the TPU VPU (and is ~100x faster than igamma on CPU).
    For i = 0 the stable form -expm1(-x) is used; for i >= 1 the absolute error
    of the cancellation is < 1e-7 in f32, negligible for value ordering.
    """
    k = x.shape[-1]
    outs = []
    for i in range(k):
        # Saturation clamp: for x >= i + 10*sqrt(i+1) + 20 the residual is 1
        # within ~1e-11 (Poisson tail, Chernoff), and clamping keeps the
        # largest series term x^i/i! finite in f32 (no inf * 0 = nan).
        cut = i + 10.0 * (i + 1.0) ** 0.5 + 20.0
        xi = jnp.minimum(x[..., i], cut)
        if i == 0:
            outs.append(-jnp.expm1(-xi))
        else:
            s = jnp.ones_like(xi)
            term = jnp.ones_like(xi)
            for j in range(1, i + 1):
                term = term * (xi / j)
                s = s + term
            cancel = 1.0 - jnp.exp(-xi) * s
            # Small x: 1 - e^{-x} s cancels catastrophically (error ~eps,
            # relative blow-up when R^i ~ x^{i+1}); use the complementary
            # tail e^{-x} sum_{j>i} x^j/j! (4 terms: rel err < x^4 < 4e-3
            # of an already-tiny value, abs err < 1e-12).
            t = term * (xi / (i + 1))
            tail = t
            for j in range(i + 2, i + 5):
                t = t * (xi / j)
                tail = tail + t
            small = jnp.exp(-xi) * tail
            outs.append(jnp.where(xi < 0.5, small, cancel))
    r = jnp.stack(outs, axis=-1)
    return jnp.where(x > 0, r, 0.0)


def residual_derivative(i: jax.Array | int, x: jax.Array) -> jax.Array:
    """d/dx R^i(x) = x^i e^{-x} / i!  (Poisson pmf at i)."""
    i = jnp.asarray(i, jnp.float32)
    x = jnp.asarray(x)
    xc = jnp.maximum(x, 1e-30)
    logp = i * jnp.log(xc) - xc - jax.lax.lgamma(i + 1.0)
    return jnp.where(x >= 0, jnp.exp(logp), 0.0)
