"""Appendix E: estimating CIS-quality parameters from crawl logs.

Observed per crawl interval k: (tau_k = interval length, n_k = #CIS received,
z_k = 1 iff the crawl found NO change, i.e. the page was still fresh).
Model: z_k ~ Ber(exp(-(alpha * tau_k + b * n_k))), b = alpha*beta.

We provide (i) the naive statistical estimator of precision/recall (biased —
paper Fig. 10) and (ii) the MLE for (alpha, b), from which
    precision = 1 - e^{-b},   Delta = alpha + gamma(1 - e^{-b}),
    recall    = gamma (1 - e^{-b}) / Delta,
with gamma estimated from the raw CIS frequency.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.values import Env

_EPS = 1e-12


class CISQuality(NamedTuple):
    alpha: jax.Array
    b: jax.Array          # alpha * beta
    gamma: jax.Array
    precision: jax.Array
    recall: jax.Array
    delta: jax.Array


def naive_precision_recall(n_cis: jax.Array, changed: jax.Array):
    """Interval-counting estimator (paper's 'statistical approach'). Biased:
    an interval can contain several changes/signals, and long intervals are
    over-represented in per-interval statistics."""
    has_cis = n_cis > 0
    has_change = changed > 0
    both = jnp.sum(has_cis & has_change, axis=-1).astype(jnp.float32)
    precision = both / jnp.maximum(jnp.sum(has_cis, axis=-1), 1)
    recall = both / jnp.maximum(jnp.sum(has_change, axis=-1), 1)
    return precision, recall


def _nll(params: jax.Array, tau: jax.Array, n: jax.Array, fresh: jax.Array,
         weights: jax.Array) -> jax.Array:
    # Softplus keeps alpha, b >= 0 without projections.
    a = jax.nn.softplus(params[0])
    b = jax.nn.softplus(params[1])
    logit = a * tau + b * n  # = -log p_fresh
    logit = jnp.clip(logit, 1e-6, 60.0)
    logp = -logit
    log1mp = jnp.log(-jnp.expm1(-logit))
    ll = jnp.where(fresh > 0, logp, log1mp)
    return -jnp.sum(weights * ll)


def fit_mle(
    tau: jax.Array,
    n_cis: jax.Array,
    fresh: jax.Array,
    gamma_hat: jax.Array,
    weights: jax.Array | None = None,
    steps: int = 500,
    lr: float = 0.05,
) -> CISQuality:
    """MLE for (alpha, alpha*beta) by full-batch Adam on the Bernoulli NLL.

    tau/n_cis/fresh: (intervals,) arrays for one page (vmap for many pages).
    gamma_hat: observed CIS rate (count/time), estimated outside.
    """
    if weights is None:
        weights = jnp.ones_like(tau)
    tau = tau.astype(jnp.float32)
    n = n_cis.astype(jnp.float32)
    fresh = fresh.astype(jnp.float32)

    grad_fn = jax.grad(_nll)
    p0 = jnp.array([-1.0, -1.0], jnp.float32)  # softplus^-1 starting point
    m0 = jnp.zeros_like(p0)
    v0 = jnp.zeros_like(p0)

    def body(i, carry):
        p, m, v = carry
        g = grad_fn(p, tau, n, fresh, weights)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** (i + 1.0))
        vh = v / (1 - 0.999 ** (i + 1.0))
        p = p - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return p, m, v

    p, _, _ = jax.lax.fori_loop(0, steps, body, (p0, m0, v0))
    a = jax.nn.softplus(p[0])
    b = jax.nn.softplus(p[1])
    precision = -jnp.expm1(-b)
    signaled = gamma_hat * precision           # lam * Delta
    delta = a + signaled
    recall = signaled / jnp.maximum(delta, 1e-12)
    return CISQuality(alpha=a, b=b, gamma=gamma_hat, precision=precision,
                      recall=recall, delta=delta)


@functools.partial(jax.jit, static_argnames=("steps",))
def fit_mle_pages(
    tau: jax.Array,
    n_cis: jax.Array,
    fresh: jax.Array,
    steps: int = 500,
    lr: float = 0.05,
) -> CISQuality:
    """Batched crawl-log estimation: `fit_mle` vmapped over pages.

    tau/n_cis/fresh: (n_pages, n_intervals) crawl-log arrays. The observed
    CIS rate gamma_hat is estimated per page from the raw logs
    (total signals / total observed time), exactly as a production pipeline
    would. Returns a CISQuality of (n_pages,) arrays — feed it to
    `quality_to_env` + `CrawlScheduler.update_pages` to close the paper's
    crawl -> estimate -> refresh loop.
    """
    tau = jnp.atleast_2d(tau)
    n_cis = jnp.atleast_2d(n_cis)
    fresh = jnp.atleast_2d(fresh)
    gamma_hat = n_cis.astype(jnp.float32).sum(-1) / jnp.maximum(
        tau.astype(jnp.float32).sum(-1), _EPS)
    fit = lambda t, n, f, g: fit_mle(t, n, f, g, steps=steps, lr=lr)
    return jax.vmap(fit)(tau, n_cis, fresh, gamma_hat)


def quality_to_env(q: CISQuality, mu: jax.Array) -> Env:
    """Map estimated CIS quality back to the raw Env parameterization.

    recall = lam (the fraction of changes that signal), and the false CIS
    rate is the unexplained part of the observed signal rate:
    nu = gamma * (1 - precision). Importance mu is supplied by the caller —
    it comes from request logs, not crawl logs.
    """
    delta = jnp.maximum(q.delta, _EPS)
    lam = jnp.clip(q.recall, 0.0, 1.0)
    nu = jnp.maximum(q.gamma * (1.0 - q.precision), 0.0)
    return Env(delta=delta, mu=jnp.asarray(mu), lam=lam, nu=nu)


# --------------------------------------------------------------------------
# Streaming (per-observation) estimation.
#
# The batch MLE above needs the full crawl log on the host. The streaming
# variant below consumes one observation (tau, n, z) at a time and keeps only
# O(1) sufficient statistics per page — the stochastic-approximation framing
# of Avrachenkov-Patil-Thoppe ("Online Algorithms for Estimating Change Rates
# of Web Pages") specialized to the source paper's CIS model, sharing App. E's
# quality mapping with `fit_mle` so both paths estimate the same (alpha, b).
#
# The estimator is CLOSED-FORM conditional-moment matching, not SGD (an
# AdaGrad-on-NLL variant was tried and rejected: its O(lr) early steps and
# tail-average inertia left it far from the MLE at the 10-200 observations
# per page a real crawl loop produces). Split the observed intervals by the
# CIS count of the window:
#
#   * n = 0 windows: no signal arrived, so freshness is driven by the
#     unsignalled-change process alone — P(z=1 | n=0) = exp(-alpha tau),
#     exactly (unsignalled changes are independent of the CIS channel).
#     The smoothed group fresh-rate identifies alpha in closed form.
#   * n = 1 windows: P(z=1 | n=1) = exp(-(alpha tau + b)) — one log and a
#     subtraction identify b, with no Jensen bias over the signal count
#     (n >= 2 windows would need E[e^{-bn}] corrections; they are skipped
#     for (alpha, b) and still feed the gamma ratio).
#
# Group rates use (fresh + 1/2) / (count + 1) (Anscombe smoothing), so every
# estimate is finite from the first observation. Averaging exp(-alpha tau)
# over varying tau under-estimates alpha by the second-order Jensen term
# alpha^2 Var(tau) / (2 tau-bar); the group's tau second moment is tracked
# and the one-step de-bias applied. gamma is the running (CIS count /
# exposure time) ratio over ALL windows, exactly like `fit_mle_pages`.
#
# `stream_update`/`stream_quality` are pure elementwise ops on StreamStats of
# any shape — the scheduler scatters them over (m,) state planes, tests
# fori_loop them over a single page's trace.
# --------------------------------------------------------------------------


class StreamStats(NamedTuple):
    """Per-page streaming-estimator sufficient statistics (all float32, any
    common shape). Group 0 = windows with no CIS (n0/f0/t0/q0: count, fresh
    count, sum tau, sum tau^2); group 1 = windows with exactly one CIS
    (n1/f1/t1); n_obs/t_obs/c_obs: total observations, exposure time, and
    CIS counts (the running gamma_hat numerator/denominator)."""

    n0: jax.Array
    f0: jax.Array
    t0: jax.Array
    q0: jax.Array
    n1: jax.Array
    f1: jax.Array
    t1: jax.Array
    n_obs: jax.Array
    t_obs: jax.Array
    c_obs: jax.Array


def stream_init(shape) -> StreamStats:
    """Fresh (all-zero) streaming statistics. The estimation prior enters at
    READ time (`stream_quality(prior_a, prior_b, prior_w)`), not state time:
    zero statistics plus a prior weight reproduce the prior exactly, and the
    prior can be re-tuned on a live state without touching the planes.

    Each field is a DISTINCT zero array: the macro-round scan donates the
    whole FusedState, and one buffer aliased into several donated leaves is
    an XLA error (`donate(a), donate(a)`)."""
    return StreamStats(*(jnp.zeros(shape, jnp.float32)
                         for _ in StreamStats._fields))


def stream_update(s: StreamStats, tau: jax.Array, n: jax.Array,
                  z: jax.Array) -> StreamStats:
    """Fold one observation per element into the sufficient statistics.

    tau/n/z: the observation (interval length, CIS count, 1 iff the crawl
    found the page still fresh). Pure accumulation — O(1), no step size,
    safe on garbage rows (the caller masks by scattering to a dropped
    index): every intermediate is finite for any finite input.
    """
    tau = tau.astype(jnp.float32)
    n = n.astype(jnp.float32)
    z = jnp.clip(z.astype(jnp.float32), 0.0, 1.0)
    no = (n < 0.5).astype(jnp.float32)
    one = ((n >= 0.5) & (n < 1.5)).astype(jnp.float32)
    return StreamStats(
        n0=s.n0 + no, f0=s.f0 + no * z, t0=s.t0 + no * tau,
        q0=s.q0 + no * tau * tau,
        n1=s.n1 + one, f1=s.f1 + one * z, t1=s.t1 + one * tau,
        n_obs=s.n_obs + 1.0, t_obs=s.t_obs + tau, c_obs=s.c_obs + n,
    )


def stream_quality(s: StreamStats, prior_a: float = 0.0,
                   prior_b: float = 0.0, prior_w: float = 0.0) -> CISQuality:
    """Closed-form (alpha, b) from the group statistics + App. E quality
    mapping — `fit_mle`'s tail verbatim. Elementwise and finite everywhere:
    an empty group contributes its prior (or 0 without one).

    prior_w > 0 shrinks each coordinate toward (prior_a, prior_b) with
    `prior_w` pseudo-observations' weight against ITS OWN group count — the
    small-sample regularizer of the closed estimation loop. Unshrunk, two
    lucky fresh crawls report delta ~ 0, the greedy policy stops crawling
    the page, and the error can never correct (an explore/exploit trap the
    batch-MLE loop avoids by refitting whole windows). The weight decays as
    n_group / (n_group + prior_w), so long-trace convergence is unaffected.
    prior_w also acts as pseudo-exposure-time (prior rate 0) on the raw
    signal-rate ratio: a page's first windows can be arbitrarily short, and
    the unsmoothed c_obs / t_obs ratio then reports an arbitrarily large
    gamma — which the App. E mapping turns into an unbounded delta.
    """
    # alpha from the no-CIS group: P(fresh | n=0) = exp(-alpha tau).
    r0 = (s.f0 + 0.5) / (s.n0 + 1.0)
    mt0 = jnp.maximum(s.t0 / jnp.maximum(s.n0, 1.0), _EPS)
    a_raw = jnp.where(s.n0 > 0.0, -jnp.log(r0) / mt0, 0.0)
    # Second-order Jensen de-bias for varying tau within the group.
    var0 = jnp.maximum(s.q0 / jnp.maximum(s.n0, 1.0) - mt0 * mt0, 0.0)
    a_raw = a_raw * (1.0 + a_raw * var0 / (2.0 * mt0))
    if prior_w:
        a = (s.n0 * a_raw + prior_w * prior_a) / (s.n0 + prior_w)
    else:
        a = a_raw
    # b from the one-CIS group: P(fresh | n=1) = exp(-(alpha tau + b)).
    r1 = (s.f1 + 0.5) / (s.n1 + 1.0)
    mt1 = s.t1 / jnp.maximum(s.n1, 1.0)
    b_raw = jnp.where(s.n1 > 0.0,
                      jnp.maximum(-jnp.log(r1) - a * mt1, 0.0), 0.0)
    if prior_w:
        b = (s.n1 * b_raw + prior_w * prior_b) / (s.n1 + prior_w)
    else:
        b = b_raw
    gamma_hat = s.c_obs / jnp.maximum(s.t_obs + prior_w, _EPS)
    precision = -jnp.expm1(-b)
    signaled = gamma_hat * precision           # lam * Delta
    delta = a + signaled
    recall = signaled / jnp.maximum(delta, 1e-12)
    return CISQuality(alpha=a, b=b, gamma=gamma_hat, precision=precision,
                      recall=recall, delta=delta)
