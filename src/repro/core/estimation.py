"""Appendix E: estimating CIS-quality parameters from crawl logs.

Observed per crawl interval k: (tau_k = interval length, n_k = #CIS received,
z_k = 1 iff the crawl found NO change, i.e. the page was still fresh).
Model: z_k ~ Ber(exp(-(alpha * tau_k + b * n_k))), b = alpha*beta.

We provide (i) the naive statistical estimator of precision/recall (biased —
paper Fig. 10) and (ii) the MLE for (alpha, b), from which
    precision = 1 - e^{-b},   Delta = alpha + gamma(1 - e^{-b}),
    recall    = gamma (1 - e^{-b}) / Delta,
with gamma estimated from the raw CIS frequency.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.values import Env

_EPS = 1e-12


class CISQuality(NamedTuple):
    alpha: jax.Array
    b: jax.Array          # alpha * beta
    gamma: jax.Array
    precision: jax.Array
    recall: jax.Array
    delta: jax.Array


def naive_precision_recall(n_cis: jax.Array, changed: jax.Array):
    """Interval-counting estimator (paper's 'statistical approach'). Biased:
    an interval can contain several changes/signals, and long intervals are
    over-represented in per-interval statistics."""
    has_cis = n_cis > 0
    has_change = changed > 0
    both = jnp.sum(has_cis & has_change, axis=-1).astype(jnp.float32)
    precision = both / jnp.maximum(jnp.sum(has_cis, axis=-1), 1)
    recall = both / jnp.maximum(jnp.sum(has_change, axis=-1), 1)
    return precision, recall


def _nll(params: jax.Array, tau: jax.Array, n: jax.Array, fresh: jax.Array,
         weights: jax.Array) -> jax.Array:
    # Softplus keeps alpha, b >= 0 without projections.
    a = jax.nn.softplus(params[0])
    b = jax.nn.softplus(params[1])
    logit = a * tau + b * n  # = -log p_fresh
    logit = jnp.clip(logit, 1e-6, 60.0)
    logp = -logit
    log1mp = jnp.log(-jnp.expm1(-logit))
    ll = jnp.where(fresh > 0, logp, log1mp)
    return -jnp.sum(weights * ll)


def fit_mle(
    tau: jax.Array,
    n_cis: jax.Array,
    fresh: jax.Array,
    gamma_hat: jax.Array,
    weights: jax.Array | None = None,
    steps: int = 500,
    lr: float = 0.05,
) -> CISQuality:
    """MLE for (alpha, alpha*beta) by full-batch Adam on the Bernoulli NLL.

    tau/n_cis/fresh: (intervals,) arrays for one page (vmap for many pages).
    gamma_hat: observed CIS rate (count/time), estimated outside.
    """
    if weights is None:
        weights = jnp.ones_like(tau)
    tau = tau.astype(jnp.float32)
    n = n_cis.astype(jnp.float32)
    fresh = fresh.astype(jnp.float32)

    grad_fn = jax.grad(_nll)
    p0 = jnp.array([-1.0, -1.0], jnp.float32)  # softplus^-1 starting point
    m0 = jnp.zeros_like(p0)
    v0 = jnp.zeros_like(p0)

    def body(i, carry):
        p, m, v = carry
        g = grad_fn(p, tau, n, fresh, weights)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** (i + 1.0))
        vh = v / (1 - 0.999 ** (i + 1.0))
        p = p - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return p, m, v

    p, _, _ = jax.lax.fori_loop(0, steps, body, (p0, m0, v0))
    a = jax.nn.softplus(p[0])
    b = jax.nn.softplus(p[1])
    precision = -jnp.expm1(-b)
    signaled = gamma_hat * precision           # lam * Delta
    delta = a + signaled
    recall = signaled / jnp.maximum(delta, 1e-12)
    return CISQuality(alpha=a, b=b, gamma=gamma_hat, precision=precision,
                      recall=recall, delta=delta)


@functools.partial(jax.jit, static_argnames=("steps",))
def fit_mle_pages(
    tau: jax.Array,
    n_cis: jax.Array,
    fresh: jax.Array,
    steps: int = 500,
    lr: float = 0.05,
) -> CISQuality:
    """Batched crawl-log estimation: `fit_mle` vmapped over pages.

    tau/n_cis/fresh: (n_pages, n_intervals) crawl-log arrays. The observed
    CIS rate gamma_hat is estimated per page from the raw logs
    (total signals / total observed time), exactly as a production pipeline
    would. Returns a CISQuality of (n_pages,) arrays — feed it to
    `quality_to_env` + `CrawlScheduler.update_pages` to close the paper's
    crawl -> estimate -> refresh loop.
    """
    tau = jnp.atleast_2d(tau)
    n_cis = jnp.atleast_2d(n_cis)
    fresh = jnp.atleast_2d(fresh)
    gamma_hat = n_cis.astype(jnp.float32).sum(-1) / jnp.maximum(
        tau.astype(jnp.float32).sum(-1), _EPS)
    fit = lambda t, n, f, g: fit_mle(t, n, f, g, steps=steps, lr=lr)
    return jax.vmap(fit)(tau, n_cis, fresh, gamma_hat)


def quality_to_env(q: CISQuality, mu: jax.Array) -> Env:
    """Map estimated CIS quality back to the raw Env parameterization.

    recall = lam (the fraction of changes that signal), and the false CIS
    rate is the unexplained part of the observed signal rate:
    nu = gamma * (1 - precision). Importance mu is supplied by the caller —
    it comes from request logs, not crawl logs.
    """
    delta = jnp.maximum(q.delta, _EPS)
    lam = jnp.clip(q.recall, 0.0, 1.0)
    nu = jnp.maximum(q.gamma * (1.0 - q.precision), 0.0)
    return Env(delta=delta, mu=jnp.asarray(mu), lam=lam, nu=nu)
