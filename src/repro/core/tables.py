"""Per-page crawl-value interpolation tables.

Production systems cannot afford to re-evaluate the full K-term NCIS value for
every page at every tick (paper App. G uses value tiers + lazy recompute). Our
vector-hardware adaptation: V_NCIS is a smooth monotone function of the scalar
*exposure* u = alpha * tau^ELAP + b * n_CIS (so P[fresh] = e^{-u}), so we
precompute V on a quadratically-spaced u-grid per page once per parameter
refresh and evaluate with a gather + lerp per tick (~10 flops/page instead of
~2 K^2 flops + 2K exps). dV/du = mu_t * e^{-u} * psi(u/alpha) decays like
u e^{-u}, so the table is exact to ~1e-7 beyond u_max = 40 and the
interpolation error on the quadratic grid is < 1e-6 relative (tested).

Edge cases fall out of the u-parameterization automatically:
  * nu = 0 (noiseless): b = BIG, any signal => u >= u_max => asymptote mu_t/delta;
  * lam = 1 (alpha = 0): u = b*n, no signal => u = 0 => V = 0 (never crawl).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.values import BIG, DerivedEnv, value_ncis

_EPS = 1e-12


class ValueTable(NamedTuple):
    vals: jax.Array    # (m, n_grid) value at u_j = u_max * (j/(n-1))^2
    u_max: jax.Array   # scalar


def build_ncis_table(
    d: DerivedEnv,
    n_terms: int = 8,
    n_grid: int = 128,
    u_max: float = 40.0,
    method: str = "series",
) -> ValueTable:
    """Tabulate V_NCIS(u) per page. Cost: m * n_grid * n_terms, paid once."""
    j = jnp.arange(n_grid, dtype=jnp.float32)
    u = u_max * (j / (n_grid - 1)) ** 2                       # (J,)
    alpha = d.alpha[..., None]
    # iota = u / alpha; alpha == 0 pages only ever query u in {0, BIG}:
    # u = 0 -> V = 0 (exact: first grid point evaluates V(iota=0) = 0).
    iota = jnp.where(alpha > 1e-20, u / jnp.maximum(alpha, 1e-20), BIG)
    iota = jnp.where(u == 0.0, 0.0, iota)
    d_e = DerivedEnv(*[x[..., None] for x in d])
    vals = value_ncis(iota, d_e, n_terms=n_terms, method=method)  # (m, J)
    return ValueTable(vals=vals, u_max=jnp.float32(u_max))


def exposure(tau_elap: jax.Array, n_cis: jax.Array, d: DerivedEnv) -> jax.Array:
    """u = alpha * tau^ELAP + b * n_CIS = -log P[fresh] (no beta division)."""
    u = d.alpha * tau_elap + jnp.minimum(d.b * n_cis.astype(tau_elap.dtype), BIG)
    return jnp.minimum(u, BIG)


def lookup(table: ValueTable, u: jax.Array) -> jax.Array:
    """Piecewise-linear interpolation of V at exposure u (per page)."""
    n_grid = table.vals.shape[-1]
    pos = jnp.sqrt(jnp.clip(u, 0.0, table.u_max) / table.u_max) * (n_grid - 1)
    lo = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, n_grid - 2)
    frac = pos - lo.astype(pos.dtype)
    v_lo = jnp.take_along_axis(table.vals, lo[..., None], axis=-1)[..., 0]
    v_hi = jnp.take_along_axis(table.vals, (lo + 1)[..., None], axis=-1)[..., 0]
    return v_lo + frac * (v_hi - v_lo)


def lookup_state(table: ValueTable, d: DerivedEnv,
                 tau_elap: jax.Array, n_cis: jax.Array) -> jax.Array:
    return lookup(table, exposure(tau_elap, n_cis, d))
