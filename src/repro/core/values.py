"""Crawl value functions V, expected interval length psi, cumulative freshness w,
and crawl frequency f — Theorem 1 / Section 5.1 of the paper.

All functions are vectorized over pages and branch-free (fixed K-term masked
sums), so the same code runs on CPU hosts, inside `shard_map` shards, and as
the oracle for the Pallas kernel. The K-term truncation *is* the paper's
APPROX-K policy (Appendix A.1); K >= ceil(iota/beta) recovers the exact value.

Environment parameterization (per page):
    delta: true change rate             mu: raw importance (request rate)
    lam:   P[change emits a CIS]        nu: false-positive CIS rate
derived:
    gamma = lam*delta + nu        (observed CIS rate)
    alpha = (1-lam)*delta         (unsignalled change rate)
    b     = -log(nu/gamma) >= 0   (log information content of one CIS)
    beta  = b/alpha               (time-equivalent of one CIS)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.residuals import residual, residual_ladder

# A value representing "practically infinite" threshold/time. Chosen so that
# i*BIG for i < 64 does not overflow f32.
BIG = 1e30
_EPS = 1e-12


class Env(NamedTuple):
    """Raw per-page environment parameters (arrays of identical shape)."""

    delta: jax.Array  # change rate
    mu: jax.Array     # importance / request rate (unnormalized)
    lam: jax.Array    # CIS recall in [0, 1]
    nu: jax.Array     # false CIS rate >= 0

    @property
    def m(self) -> int:
        return self.delta.shape[-1]


class DerivedEnv(NamedTuple):
    """Derived quantities consumed by the value functions."""

    delta: jax.Array
    mu_t: jax.Array    # normalized importance mu / sum(mu)
    lam: jax.Array
    nu: jax.Array
    gamma: jax.Array   # observed CIS rate
    alpha: jax.Array   # unsignalled change rate
    b: jax.Array       # alpha * beta = -log(nu/gamma)
    beta: jax.Array    # time value of one CIS (BIG when nu == 0)


def derive(env: Env, mu_total: jax.Array | float | None = None) -> DerivedEnv:
    """Compute derived parameters with all the edge-case guards.

    mu_total lets distributed callers pass the *global* importance sum so that
    per-shard normalization is consistent across shards.
    """
    delta = jnp.asarray(env.delta)
    mu = jnp.asarray(env.mu)
    lam = jnp.clip(jnp.asarray(env.lam), 0.0, 1.0)
    nu = jnp.maximum(jnp.asarray(env.nu), 0.0)
    if mu_total is None:
        mu_total = jnp.sum(mu)
    mu_t = mu / jnp.maximum(mu_total, _EPS)

    gamma = lam * delta + nu
    alpha = (1.0 - lam) * delta
    # b = -log(nu/gamma); nu == 0 (but gamma > 0)  => b = inf -> BIG (noiseless)
    #                     gamma == 0               => no CIS at all; b unused -> 0
    ratio = jnp.where(gamma > 0, nu / jnp.maximum(gamma, _EPS), 1.0)
    b = jnp.where(
        (gamma > 0) & (nu > 0),
        -jnp.log(jnp.clip(ratio, _EPS, 1.0)),
        jnp.where(gamma > 0, BIG, 0.0),
    )
    b = jnp.minimum(b, BIG)
    beta = jnp.where(alpha > 0, b / jnp.maximum(alpha, _EPS), BIG)
    beta = jnp.minimum(beta, BIG)
    # gamma == 0: signals never arrive; beta irrelevant but must be finite-safe.
    beta = jnp.where(gamma > 0, beta, BIG)
    return DerivedEnv(delta=delta, mu_t=mu_t, lam=lam, nu=nu, gamma=gamma,
                      alpha=alpha, b=b, beta=beta)


def tau_eff(tau_elap: jax.Array, n_cis: jax.Array, d: DerivedEnv) -> jax.Array:
    """Effective elapsed time tau^EFF = tau^ELAP + beta * n_CIS (clipped to BIG)."""
    t = tau_elap + jnp.minimum(d.beta * n_cis.astype(tau_elap.dtype), BIG)
    return jnp.minimum(t, BIG)


def _masked_terms(iota: jax.Array, d: DerivedEnv, n_terms: int):
    """Shared machinery: per-term (i < K) masked arguments.

    Returns (mask, x_psi, x_w, i) with shapes (..., K): term i is active iff
    i*beta <= iota; x_psi = gamma*(iota-i*beta), x_w = (alpha+gamma)*(iota-i*beta).
    """
    i = jnp.arange(n_terms, dtype=iota.dtype)
    shape = iota.shape + (n_terms,)
    iota_e = iota[..., None]
    beta_e = jnp.broadcast_to(d.beta[..., None], shape)
    # i * beta with beta possibly BIG: i=0 must give exactly 0.
    ib = jnp.where(i == 0, 0.0, i * jnp.minimum(beta_e, BIG))
    rem = jnp.maximum(iota_e - ib, 0.0)           # (iota - i*beta)_+
    mask = ib <= iota_e                            # i <= floor(iota/beta)
    x_psi = d.gamma[..., None] * rem
    x_w = (d.alpha + d.gamma)[..., None] * rem
    return mask, x_psi, x_w, i, rem


def _residual_terms(x: jax.Array, method: str) -> jax.Array:
    """R^i(x[..., i]) via igamma ("gamma", exact) or Taylor series ("series",
    kernel-friendly; used by the simulator and the Pallas kernel)."""
    if method == "series":
        return residual_ladder(x)
    i = jnp.arange(x.shape[-1], dtype=x.dtype)
    return residual(i, x)


def psi(iota: jax.Array, d: DerivedEnv, n_terms: int = 8,
        method: str = "gamma") -> jax.Array:
    """Expected interval length between crawls under threshold iota (Lemma 4)."""
    mask, x_psi, _, i, rem = _masked_terms(iota, d, n_terms)
    g = d.gamma[..., None]
    # term_i = R^i(gamma * rem) / gamma, with the gamma -> 0 limit:
    #   i = 0: (1 - e^{-g r})/g -> r ;  i >= 1: -> 0.
    r_i = _residual_terms(x_psi, method)
    small = g < 1e-8
    t0 = jnp.where(small, rem, -jnp.expm1(-x_psi) / jnp.maximum(g, _EPS))
    ti = jnp.where(small, 0.0, r_i / jnp.maximum(g, _EPS))
    terms = jnp.where(i == 0, t0, ti)
    return jnp.sum(jnp.where(mask, terms, 0.0), axis=-1)


def w(iota: jax.Array, d: DerivedEnv, n_terms: int = 8,
      method: str = "gamma") -> jax.Array:
    """Expected cumulative freshness of one crawl interval (Lemma 4)."""
    mask, _, x_w, i, rem = _masked_terms(iota, d, n_terms)
    dn = (d.delta + d.nu)[..., None]
    nu = d.nu[..., None]
    ag = (d.alpha + d.gamma)[..., None]
    # coeff_i = nu^i / (delta+nu)^{i+1}; log-space for stability at larger i.
    log_nu = jnp.log(jnp.maximum(nu, _EPS))
    log_dn = jnp.log(jnp.maximum(dn, _EPS))
    coeff = jnp.where(
        (nu <= 0.0) & (i > 0), 0.0, jnp.exp(i * log_nu - (i + 1.0) * log_dn)
    )
    coeff = jnp.where(i == 0, 1.0 / jnp.maximum(dn, _EPS), coeff)
    r_i = _residual_terms(x_w, method)
    # delta + nu == 0 would mean the page never changes and never signals;
    # then freshness is 1 and w(iota) = iota (handled via the i=0 limit below).
    small = ag < 1e-8
    t0 = jnp.where(small, rem, coeff * r_i)
    terms = jnp.where(i == 0, t0, coeff * r_i)
    return jnp.sum(jnp.where(mask, terms, 0.0), axis=-1)


def freq(iota: jax.Array, d: DerivedEnv, n_terms: int = 8,
         method: str = "gamma") -> jax.Array:
    """Crawl frequency f(iota) = 1/psi(iota)."""
    return 1.0 / jnp.maximum(psi(iota, d, n_terms, method), _EPS)


def value_ncis(iota: jax.Array, d: DerivedEnv, n_terms: int = 8,
               method: str = "gamma") -> jax.Array:
    """General crawl value V_GREEDY_NCIS (Theorem 1):

        V(iota) = mu_t * (w(iota) - exp(-alpha*iota) * psi(iota)).

    n_terms = j gives the paper's V_G_NCIS_APPROX_j; n_terms >= max floor(i/b)
    gives the exact value. iota >= BIG returns the asymptote mu_t/delta.
    """
    p = psi(iota, d, n_terms, method)
    ww = w(iota, d, n_terms, method)
    decay = jnp.exp(-jnp.minimum(d.alpha * iota, 80.0))
    v = d.mu_t * (ww - decay * p)
    v_inf = d.mu_t / jnp.maximum(d.delta, _EPS)
    return jnp.where(iota >= BIG, v_inf, v)


def value_greedy(tau_elap: jax.Array, d: DerivedEnv) -> jax.Array:
    """V_GREEDY: no CIS knowledge. V = (mu_t/delta) * R^1(delta * tau)."""
    return d.mu_t / jnp.maximum(d.delta, _EPS) * residual(1, d.delta * tau_elap)


def value_cis(tau_elap: jax.Array, n_cis: jax.Array, d: DerivedEnv) -> jax.Array:
    """V_GREEDY_CIS: believes signals are noiseless (nu = 0).

    Under that belief alpha_b = (1-lam)*delta, gamma_b = lam*delta; a received
    CIS means the page is certainly stale -> value jumps to the asymptote
    mu_t/delta. Otherwise
        V = mu_t * ( R^0((a+g) t)/(a+g) - R^0(g t) / (g e^{a t}) ).
    The gamma_b -> 0 limit recovers V_GREEDY.
    """
    a = (1.0 - d.lam) * d.delta
    g = d.lam * d.delta
    t = tau_elap
    ag = a + g
    small_ag = ag < 1e-8
    term1 = jnp.where(small_ag, t, -jnp.expm1(-ag * t) / jnp.maximum(ag, _EPS))
    small_g = g < 1e-8
    r0_over_g = jnp.where(small_g, t, -jnp.expm1(-g * t) / jnp.maximum(g, _EPS))
    decay = jnp.exp(-jnp.minimum(a * t, 80.0))
    v = d.mu_t * (term1 - r0_over_g * decay)
    v_signaled = d.mu_t / jnp.maximum(d.delta, _EPS)
    return jnp.where(n_cis > 0, v_signaled, v)


def value_asymptote(d: DerivedEnv) -> jax.Array:
    """V(iota -> inf) = mu_t / delta — the per-page value upper bound."""
    return d.mu_t / jnp.maximum(d.delta, _EPS)


def accuracy_of_thresholds(iota: jax.Array, d: DerivedEnv, n_terms: int = 8) -> jax.Array:
    """Expected objective O = sum_i mu_t * w(iota_i) * f(iota_i) of a threshold
    policy (the continuous optimum's accuracy when fed iota*)."""
    o = d.mu_t * w(iota, d, n_terms) * freq(iota, d, n_terms)
    o = jnp.where(iota >= BIG, 0.0, o)  # never-crawled pages serve stale copies
    return jnp.sum(o, axis=-1)


def G(xi: jax.Array, mu_t: jax.Array, delta: jax.Array) -> jax.Array:
    """No-CIS objective per page at crawl rate xi (Eq. (5)):
    G(xi) = (mu_t/delta) * xi * (1 - exp(-delta/xi))."""
    safe_xi = jnp.maximum(xi, _EPS)
    val = mu_t / jnp.maximum(delta, _EPS) * safe_xi * -jnp.expm1(-delta / safe_xi)
    return jnp.where(xi > 0, val, 0.0)
