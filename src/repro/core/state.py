"""Per-page scheduler state and transitions.

The paper's key scalability property: the full scheduler state per page is the
pair (tau^ELAP, n_CIS) — O(1) memory, updated locally, checkpointable as two
flat arrays. All transitions here are pure and shard-local.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PageState(NamedTuple):
    tau_elap: jax.Array  # f32: time since last crawl
    n_cis: jax.Array     # i32: CIS received since last crawl


def init_state(m: int, dtype=jnp.float32) -> PageState:
    return PageState(tau_elap=jnp.zeros((m,), dtype), n_cis=jnp.zeros((m,), jnp.int32))


def advance(state: PageState, dt: jax.Array | float, new_cis: jax.Array) -> PageState:
    """Advance time by dt and register newly arrived CIS counts."""
    return PageState(
        tau_elap=state.tau_elap + dt,
        n_cis=state.n_cis + new_cis.astype(jnp.int32),
    )


def advance_with_delay_filter(
    state: PageState,
    dt: jax.Array | float,
    new_cis: jax.Array,
    t_delay: jax.Array | float,
) -> PageState:
    """Appendix C heuristic: discard CIS that arrive within t_delay of the last
    crawl (they most likely describe a change already captured by that crawl).
    A signal arriving during this tick is kept iff tau_elap (at tick start)
    >= t_delay."""
    keep = state.tau_elap >= t_delay
    kept = jnp.where(keep, new_cis.astype(jnp.int32), 0)
    return PageState(tau_elap=state.tau_elap + dt, n_cis=state.n_cis + kept)


def crawl_reset(state: PageState, crawled: jax.Array) -> PageState:
    """Reset the pages selected for crawling (boolean mask)."""
    z = jnp.zeros_like(state.tau_elap)
    return PageState(
        tau_elap=jnp.where(crawled, z, state.tau_elap),
        n_cis=jnp.where(crawled, 0, state.n_cis),
    )
