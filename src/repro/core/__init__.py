"""Core library: the paper's contribution — crawl values, policies, solver."""
from repro.core.residuals import residual, residual_derivative, residual_naive
from repro.core.values import (
    BIG,
    DerivedEnv,
    Env,
    G,
    accuracy_of_thresholds,
    derive,
    freq,
    psi,
    tau_eff,
    value_asymptote,
    value_cis,
    value_greedy,
    value_ncis,
    w,
)
from repro.core.solver import (
    ContinuousSolution,
    iota_for_lambda,
    solve_continuous,
    solve_continuous_nocis,
    total_rate,
)
from repro.core.state import (
    PageState,
    advance,
    advance_with_delay_filter,
    crawl_reset,
    init_state,
)
from repro.core.policies import (
    ALL_VALUE_POLICIES,
    G_NCIS_APPROX_1,
    G_NCIS_APPROX_2,
    GREEDY,
    GREEDY_CIS,
    GREEDY_CIS_PLUS,
    GREEDY_NCIS,
    LDS,
    crawl_values,
    make_policy,
    quality_mask_from_env,
)
from repro.core.estimation import (
    CISQuality,
    fit_mle,
    naive_precision_recall,
)

__all__ = [k for k in dir() if not k.startswith("_")]
