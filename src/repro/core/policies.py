"""Discrete crawl policies (Algorithm 1 with the Section 5.1 value functions).

A policy is a pure function mapping scheduler state -> per-page crawl values;
the scheduler crawls the arg-top-k. Each policy may hold *beliefs* about the
environment that differ from the truth (e.g. GREEDY ignores CIS; GREEDY_CIS
assumes noiseless CIS) — that is exactly how the paper's experiments stress
robustness.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.state import PageState
from repro.core.values import (
    DerivedEnv,
    Env,
    derive,
    tau_eff,
    value_cis,
    value_greedy,
    value_ncis,
)

PolicyFn = Callable[[PageState, DerivedEnv], jax.Array]

GREEDY = "greedy"
GREEDY_CIS = "greedy_cis"
GREEDY_NCIS = "greedy_ncis"
G_NCIS_APPROX_1 = "g_ncis_approx_1"
G_NCIS_APPROX_2 = "g_ncis_approx_2"
GREEDY_CIS_PLUS = "greedy_cis_plus"
LDS = "lds"  # handled by the simulator's deadline path, not a value function

ALL_VALUE_POLICIES = (
    GREEDY,
    GREEDY_CIS,
    GREEDY_NCIS,
    G_NCIS_APPROX_1,
    G_NCIS_APPROX_2,
    GREEDY_CIS_PLUS,
)


def crawl_values(
    kind: str,
    state: PageState,
    d: DerivedEnv,
    n_terms: int = 8,
    quality_mask: jax.Array | None = None,
) -> jax.Array:
    """Per-page crawl value under the given policy's beliefs.

    quality_mask (bool, per page) is only used by GREEDY_CIS_PLUS: True marks
    "high quality" CIS pages (paper Section 6.7: precision > 0.7, recall > 0.6).
    """
    if kind == GREEDY:
        # Believes there are no signals: alpha = delta, ignores n_cis.
        return value_greedy(state.tau_elap, d)
    if kind == GREEDY_CIS:
        return value_cis(state.tau_elap, state.n_cis, d)
    if kind == GREEDY_NCIS:
        t = tau_eff(state.tau_elap, state.n_cis, d)
        return value_ncis(t, d, n_terms=n_terms)
    if kind == G_NCIS_APPROX_1:
        t = tau_eff(state.tau_elap, state.n_cis, d)
        return value_ncis(t, d, n_terms=1)
    if kind == G_NCIS_APPROX_2:
        t = tau_eff(state.tau_elap, state.n_cis, d)
        return value_ncis(t, d, n_terms=2)
    if kind == GREEDY_CIS_PLUS:
        if quality_mask is None:
            raise ValueError("GREEDY_CIS_PLUS requires a quality_mask")
        v_cis = value_cis(state.tau_elap, state.n_cis, d)
        v_greedy = value_greedy(state.tau_elap, d)
        return jnp.where(quality_mask, v_cis, v_greedy)
    raise ValueError(f"unknown policy kind: {kind!r}")


def make_policy(kind: str, n_terms: int = 8,
                quality_mask: jax.Array | None = None) -> PolicyFn:
    return functools.partial(
        crawl_values, kind, n_terms=n_terms, quality_mask=quality_mask
    )


def quality_mask_from_env(env: Env, precision_thresh: float = 0.7,
                          recall_thresh: float = 0.6) -> jax.Array:
    """Section 6.7's high-quality page selector for GREEDY_CIS_PLUS."""
    d = derive(env)
    precision = jnp.where(
        d.gamma > 0, env.lam * env.delta / jnp.maximum(d.gamma, 1e-12), 0.0
    )
    return (precision > precision_thresh) & (env.lam > recall_thresh)
