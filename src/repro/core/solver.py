"""Continuous-policy solver (Theorem 1): find the Lagrange multiplier Lambda and
per-page thresholds iota* with V(iota_i*) = Lambda and sum_i f(iota_i*) = R.

Both levels are monotone (Lemma 2: V increasing in iota, f decreasing in iota,
hence total rate decreasing in Lambda), so nested bisection converges
geometrically. Everything is vectorized over pages and jit-compatible
(fixed-iteration lax.fori_loop), and runs in f64 when enabled.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.values import (
    BIG,
    DerivedEnv,
    Env,
    derive,
    freq,
    psi,
    value_asymptote,
    value_ncis,
    w,
)

_IOTA_LO = 1e-7


class ContinuousSolution(NamedTuple):
    iota: jax.Array        # per-page optimal threshold (BIG => never crawl)
    rate: jax.Array        # per-page crawl frequency f(iota*)
    lam_mult: jax.Array    # the Lagrange multiplier Lambda
    objective: jax.Array   # optimal expected accuracy sum mu_t * w * f


def iota_for_lambda(
    lam_mult: jax.Array,
    d: DerivedEnv,
    n_terms: int = 8,
    iters: int = 60,
    iota_max: float = 1e7,
) -> jax.Array:
    """Per-page bisection: smallest iota with V(iota) >= Lambda.

    Pages whose asymptotic value stays below Lambda get iota = BIG (never
    crawled, Theorem 1's second branch).
    """
    v_hi = value_ncis(jnp.full_like(d.delta, iota_max), d, n_terms)

    def body(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        v = value_ncis(mid, d, n_terms)
        go_right = v < lam_mult
        return jnp.where(go_right, mid, lo), jnp.where(go_right, hi, mid)

    lo0 = jnp.full_like(d.delta, _IOTA_LO)
    hi0 = jnp.full_like(d.delta, iota_max)
    lo, hi = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
    iota = 0.5 * (lo + hi)
    return jnp.where(v_hi < lam_mult, BIG, iota)


def total_rate(
    lam_mult: jax.Array, d: DerivedEnv, n_terms: int = 8, iters: int = 60
) -> jax.Array:
    iota = iota_for_lambda(lam_mult, d, n_terms, iters)
    f = jnp.where(iota >= BIG, 0.0, freq(iota, d, n_terms))
    return jnp.sum(f)


def solve_continuous(
    env: Env,
    bandwidth: float,
    n_terms: int = 8,
    outer_iters: int = 60,
    inner_iters: int = 60,
) -> ContinuousSolution:
    """Nested bisection for the optimal continuous policy under budget R."""
    d = derive(env)
    v_max = jnp.max(value_asymptote(d))

    def body(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        rate = total_rate(mid, d, n_terms, inner_iters)
        # rate decreasing in Lambda: rate > R -> need larger Lambda.
        too_fast = rate > bandwidth
        return jnp.where(too_fast, mid, lo), jnp.where(too_fast, hi, mid)

    lo, hi = jax.lax.fori_loop(
        0, outer_iters, body, (jnp.zeros_like(v_max) + 1e-12, v_max)
    )
    lam_mult = 0.5 * (lo + hi)
    iota = iota_for_lambda(lam_mult, d, n_terms, inner_iters)
    f = jnp.where(iota >= BIG, 0.0, freq(iota, d, n_terms))
    o = jnp.where(iota >= BIG, 0.0, d.mu_t * w(iota, d, n_terms) * f)
    return ContinuousSolution(iota=iota, rate=f, lam_mult=lam_mult,
                              objective=jnp.sum(o))


def solve_continuous_nocis(env: Env, bandwidth: float, **kw) -> ContinuousSolution:
    """Baseline of Eq. (5): the Azar/Cho setting — identical machinery with the
    CIS channel disabled (lam = nu = 0)."""
    blind = Env(delta=env.delta, mu=env.mu, lam=jnp.zeros_like(env.lam),
                nu=jnp.zeros_like(env.nu))
    return solve_continuous(blind, bandwidth, **kw)
