"""zamba2-2.7b [arXiv:2411.15242]: Mamba-2 backbone + a *shared* attention+MLP
block applied every 6 layers with per-site LoRA adapters; ssm_state=64."""
from repro.configs.base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm=SSMCfg(kind="mamba2", d_state=64, head_dim=64, expand=2, chunk=256),
    attn_every=6,
    lora_rank=64,
    subquadratic=True,            # decode state is SSM + sparse shared-attn KV
    tie_embeddings=True,
    optimizer="adamw",
)
