"""gemma2-2b [arXiv:2408.00118]: alternating local(4096)/global attention,
attention + final logit softcaps, GeGLU, sandwich (pre+post) RMSNorm, GQA kv=4."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab=256000,
    head_dim=256,
    attn_softcap=50.0,
    final_softcap=30.0,
    window=4096,
    local_global=True,
    sandwich_norm=True,
    act="gelu",
    tie_embeddings=True,
    optimizer="adamw",
)
