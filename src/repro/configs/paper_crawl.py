"""The paper's own 'architecture': the production crawl-scheduler workload —
page-sharded value evaluation + global top-k on the full mesh (Section 5.2)."""
PAGES_PER_CHIP = 2 ** 21          # 2M pages/chip -> 1B pages on 512 chips
TABLE_GRID = 64
SCHED_K = 8192                    # crawls per scheduling round
