"""Architecture registry: one module per assigned architecture."""
from repro.configs.base import SHAPES, ModelConfig, ShapeCfg, get_shape, reduced

_MODULES = {
    "whisper-large-v3": "whisper_large_v3",
    "gemma2-2b": "gemma2_2b",
    "smollm-135m": "smollm_135m",
    "granite-8b": "granite_8b",
    "qwen2.5-3b": "qwen2_5_3b",
    "xlstm-350m": "xlstm_350m",
    "internvl2-76b": "internvl2_76b",
    "zamba2-2.7b": "zamba2_2_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "grok-1-314b": "grok_1_314b",
}

ARCH_NAMES = tuple(_MODULES)


def get(name: str) -> ModelConfig:
    import importlib

    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}").CONFIG
