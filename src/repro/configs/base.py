"""Architecture configuration schema + the assigned input-shape sets.

Every assigned architecture gets one `<id>.py` in this package exporting
`CONFIG`; `repro.configs.get(name)` resolves them. `reduced()` derives the
small smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoECfg:
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared: int = 0            # shared (always-on) experts
    shared_gate: bool = False    # qwen2-moe sigmoid gate on shared output
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2


@dataclass(frozen=True)
class SSMCfg:
    kind: str = ""               # "mamba2" | "xlstm"
    d_state: int = 64
    head_dim: int = 64           # mamba2 P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    # xlstm: layers per group pattern, e.g. 3 mLSTM then 1 sLSTM
    mlstm_per_group: int = 3
    slstm_head_dim: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | encdec | moe | ssm | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    # --- attention flavor ---
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    window: int = 0              # sliding-window size for local layers
    local_global: bool = False   # gemma2 alternating local/global
    sandwich_norm: bool = False  # gemma2 pre+post block norms
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU) | gelu_mlp
    pos: str = "rope"            # rope | learned | sinusoid | none
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    enc_seq: int = 1500          # whisper audio frames after conv stub
    # --- frontend stubs ---
    frontend: str = "none"       # none | audio_frames | vision_patches
    n_prefix: int = 0            # vision prefix token count
    # --- mixtures / ssm ---
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    attn_every: int = 0          # hybrid: shared attn block every k ssm layers
    lora_rank: int = 0           # zamba2 per-site adapters on the shared block
    # --- numerics / training ---
    dtype: str = "bfloat16"
    optimizer: str = "adamw"     # adamw | adafactor
    remat: str = "block"         # none | block
    train_n_micro: int = 1       # gradient-accumulation microbatches (train_4k)
    # long-context capability (sub-quadratic decode) — decides long_500k
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeCfg, ...] = (
    ShapeCfg("train_4k", "train", 4_096, 256),
    ShapeCfg("prefill_32k", "prefill", 32_768, 32),
    ShapeCfg("decode_32k", "decode", 32_768, 128),
    ShapeCfg("long_500k", "decode", 524_288, 1),
)


def get_shape(name: str) -> ShapeCfg:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.attn_every == 0 else 2 * max(cfg.attn_every, 1)),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2 if cfg.n_kv_heads < cfg.n_heads else 4)),
        d_ff=256,
        vocab=512,
        head_dim=32,
        enc_seq=24,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_prefix=min(cfg.n_prefix, 8),
        window=min(cfg.window, 16) if cfg.window else 0,
        dtype="float32",
    )
    if cfg.moe:
        kw["moe"] = replace(cfg.moe, n_experts=min(cfg.moe.n_experts, 8),
                            top_k=min(cfg.moe.top_k, 2), expert_d_ff=64,
                            n_shared=min(cfg.moe.n_shared, 1))
    if cfg.ssm:
        kw["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk=16,
                            slstm_head_dim=32)
    if cfg.attn_every:
        kw["attn_every"] = 2
        kw["n_layers"] = 4
    if cfg.lora_rank:
        kw["lora_rank"] = 4
    return replace(cfg, **kw)
