"""grok-1-314b [hf:xai-org/grok-1]: 314B MoE, 8 experts top-2, GQA kv=8,
attention logit softcap 30 (tanh), 64 layers."""
from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    attn_softcap=30.0,
    final_softcap=30.0,
    moe=MoECfg(n_experts=8, top_k=2, expert_d_ff=32768, n_shared=0),
    tie_embeddings=True,
    train_n_micro=4,
    optimizer="adafactor",        # 314B: factored second moment
)
