"""xlstm-350m [arXiv:2405.04517]: sLSTM + mLSTM blocks (3 mLSTM : 1 sLSTM per
group), matrix-memory recurrence => O(1)-state decode (sub-quadratic)."""
from repro.configs.base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                       # xLSTM blocks carry their own up-projection
    vocab=50304,
    ssm=SSMCfg(kind="xlstm", mlstm_per_group=3, slstm_head_dim=256, chunk=256),
    subquadratic=True,
    tie_embeddings=True,
    optimizer="adamw",
)
