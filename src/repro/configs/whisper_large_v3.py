"""whisper-large-v3 [arXiv:2212.04356]: enc-dec audio; conv frontend is a stub
(input_specs supplies precomputed 1500-frame embeddings). 32 encoder + 32
decoder layers, MHA (kv=20 == heads), GELU MLP, LayerNorm, learned positions."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    qkv_bias=True,
    norm="layernorm",
    act="gelu_mlp",
    pos="learned",
    frontend="audio_frames",
    enc_seq=1500,
    tie_embeddings=True,
    optimizer="adamw",
)
