"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 60 routed experts top-4 +
4 shared experts (gated), fine-grained expert d_ff=1408."""
from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                    # per-expert width (routed)
    vocab=151936,
    qkv_bias=True,
    moe=MoECfg(n_experts=60, top_k=4, expert_d_ff=1408, n_shared=4,
               shared_gate=True),
    tie_embeddings=True,
    train_n_micro=4,
    optimizer="adamw",
)
