"""internvl2-76b [arXiv:2404.16821]: InternViT frontend (stub: precomputed
patch embeddings) + InternLM2/llama-70B-class backbone, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    frontend="vision_patches",
    n_prefix=256,
    tie_embeddings=False,
    train_n_micro=2,
    optimizer="adafactor",        # 76B: bound per-chip optimizer state
)
