"""Fused single-pass value/top-k selection — the m-element value vector never
touches HBM.

The paper's tractability argument (Section 5.2; also "Learning to Crawl",
Upadhyay et al. 2019) is that only the comparison among the top-valued pages
matters per round. The seed pipeline still materialized all m values to HBM
and ran `jax.lax.top_k` over them as a second full pass. Here a single kernel
pass computes values in-register from the packed PageShard planes
(`kernels.layout`) and emits, per block, only

  * the block's per-lane maxima (candidate slot 0), and
  * a candidate buffer: the top `cand_per_lane` (value, page-id) pairs of
    each of the 128 lane columns,

so global top-k runs over n_blocks * cand_per_lane * 128 = O(n_blocks * c)
candidates instead of m, and HBM write traffic per round is
~8 * c * 128 * n_blocks bytes ~ 0 bytes/page. Blocks whose optimistic bound
is below the running selection threshold (seeded from the previous round's
k-th value; see `sched.tiered.BlockBounds`) skip all compute via `pl.when`.

Exact-recovery guarantee
------------------------
Let kth be the k-th best candidate value. The candidate selection equals dense
`jax.lax.top_k` over all pages (including tie order: ties break toward lower
page id in both) unless

  * some lane column's last retained candidate is >= kth (that column may
    have dropped a page that belongs in the top-k), or
  * thresh > kth (a skipped block's bound — an upper bound on its best page —
    could exceed kth, i.e. a winner may be hiding in a skipped block), or
  * a value tie straddles the k-th boundary (more candidates >= kth than k):
    the candidate ranking top_ks by value with ties broken by buffer
    position, then re-ranks only the k selected pairs by (value desc, id
    asc) — exactly dense tie order whenever the selected set is forced,
    which a boundary tie is the only way to break.

All conditions are detected from the candidate buffers alone; when any
fires, the round falls back to a full dense pass (`crawl_value.pallas` body
as pure jnp + `jax.lax.top_k`) inside `lax.cond`, so selection is *provably
identical* to dense top-k on every round, with the fallback priced only when
it actually triggers. `auto_cand_per_lane` sizes c so the fallback stays rare
even when all k winners concentrate in a single block (value-tiered shards).

Two implementations share the exact same math (`value_from_planes` and
`_lane_topc`): a Pallas kernel (TPU deployment; validated in interpret mode)
and a `lax.scan`-over-blocks mirror whose `lax.cond` reproduces the kernel's
`pl.when` block skip at jnp level — the CPU benchmark path, following the
convention established in `sched.tiered`.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import layout
from repro.kernels.crawl_value import value_from_planes
from repro.kernels.layout import LANES

# Floor on candidates retained per lane column (c = 2 collides on most
# rounds already at k = 256). See `auto_cand_per_lane` for the sizing rule.
DEFAULT_CAND_PER_LANE = 4


def auto_cand_per_lane(k: int) -> int:
    """Candidate-buffer depth for budget k.

    Worst case (value-tiered shards): all k winners land in ONE block, i.e.
    mean lam = ceil(k/128) winners per lane column. Winners per column is
    ~Poisson(lam); retaining 2*lam + 6 per column puts the per-round fallback
    probability well under 1% even then, at a few extra max/select passes per
    active block — cheap next to the K-term value ladder."""
    lam = -(-k // LANES)
    return max(DEFAULT_CAND_PER_LANE, 2 * lam + 6)


def shard_budget(
    k: int,
    m_local: int,
    nb_local: int,
    n_shards: int = 1,
    k_local: int | None = None,
    cand_per_lane: int | None = None,
) -> tuple[int, int]:
    """THE shard-local budget clamp, shared by every fused caller
    (`sched.backends.FusedBackend`, `sched.distributed.sharded_select`, the
    scheduler's candidate-depth adaptation) so the k_loc invariant can never
    diverge between them.

    Returns (k_loc, cand_per_lane): the per-shard candidate count clamped to
    (a) the requested k_local/k, (b) the shard's padded page count (a large
    budget on a small shard would otherwise ask local top-k for more entries
    than the shard holds — the real/unpadded tail shard holds even fewer,
    but padding scores -inf and is harmless to contribute), and (c) the
    shard's candidate-buffer capacity (binds only for an explicitly
    undersized cand_per_lane, where the overflow fallback already restores
    the dense selection). Raises if the clamped shards cannot jointly cover
    the global budget."""
    k_loc = min(k_local or k, k, m_local)
    c = cand_per_lane or auto_cand_per_lane(k_loc)
    k_loc = min(k_loc, nb_local * c * LANES)
    if n_shards * k_loc < k:
        raise ValueError(
            f"global budget k={k} exceeds the {n_shards * k_loc} candidates "
            "the shards can contribute; raise cand_per_lane"
        )
    return k_loc, c


class FusedSelection(NamedTuple):
    values: jax.Array       # (k,) selected values, descending
    ids: jax.Array          # (k,) int32 page ids (padded-flat id space)
    blk_max: jax.Array      # (n_blocks,) block maxima (-inf for skipped;
    #                         recomputed from the dense values on fallback
    #                         rounds so it stays a sound bound anchor)
    fell_back: jax.Array    # () bool — dense exact-recovery pass taken
    frac_active: jax.Array  # () f32 — fraction of blocks evaluated
    #                         (1.0 on fallback rounds: the dense pass
    #                         evaluates everything)
    col_winners: jax.Array  # () i32 — max per-lane-column count of values
    #                         >= the k-th: the realized candidate depth this
    #                         round, feeding the adaptive cand_per_lane
    #                         shrink (`sched.service`)


def _col_depth(vals: jax.Array, kth: jax.Array) -> jax.Array:
    """Realized candidate depth: the max over (block, lane) columns of
    entries strictly above the k-th value, plus one boundary slot. Counting
    strictly (not >=) keeps degenerate mass-tie rounds — e.g. the cold
    first round where every value is 0 — from pinning the watermark at the
    full column height; ties at the k-th are covered by the fallback, not
    the buffer depth. vals: (n_blocks, depth, LANES)."""
    return (jnp.sum(vals > kth, axis=1).max() + 1).astype(jnp.int32)


def _lane_topc(v: jax.Array, row0, c: int):
    """Top-c (value, page-id) per lane column of a (R, LANES) value tile.

    Iterative max-extraction: c rounds of (lane max, lowest achieving row,
    mask) — pure VPU select/max work, no sort, no scatter. Ties break toward
    the lower row, matching `jax.lax.top_k`'s lower-index-first order.
    row0: first global row of this tile (page id = (row0 + r) * LANES + lane).
    """
    rows_n, _ = v.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    vals, ids = [], []
    vv = v
    for _ in range(c):
        mx = jnp.max(vv, axis=0, keepdims=True)                    # (1, L)
        r = jnp.min(jnp.where(vv == mx, rows, rows_n), axis=0,
                    keepdims=True)                                  # (1, L)
        vals.append(mx)
        ids.append((row0 + r) * LANES + lanes)
        vv = jnp.where(rows == r, -jnp.inf, vv)
    return jnp.concatenate(vals, axis=0), jnp.concatenate(ids, axis=0)


def fused_select_kernel(
    thresh_ref,
    bound_ref,
    row0_ref,
    tau_ref,
    n_ref,
    env_ref,
    cand_v_ref,
    cand_i_ref,
    *,
    n_terms: int,
    cand_per_lane: int,
):
    bound = bound_ref[0, 0]
    thresh = thresh_ref[0, 0]

    @pl.when(bound >= thresh)
    def _compute():
        v = value_from_planes(tau_ref[...], n_ref[...], env_ref[0], n_terms)
        cv, ci = _lane_topc(v, row0_ref[0, 0], cand_per_lane)
        cand_v_ref[...] = cv
        cand_i_ref[...] = ci

    @pl.when(bound < thresh)
    def _skip():
        cand_v_ref[...] = jnp.full(cand_v_ref.shape, -jnp.inf, jnp.float32)
        cand_i_ref[...] = jnp.zeros(cand_i_ref.shape, jnp.int32)


def _candidates_pallas(tau_pad, n_pad, env, bounds, thresh, n_terms,
                       cand_per_lane, interpret):
    n_blocks, np_, block_rows, _ = env.shape
    rows = n_blocks * block_rows
    page_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    bound_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    env_spec = pl.BlockSpec((1, np_, block_rows, LANES),
                            lambda i: (i, 0, 0, 0))
    cand_spec = pl.BlockSpec((cand_per_lane, LANES), lambda i: (i, 0))
    row0s = (jnp.arange(n_blocks, dtype=jnp.int32) * block_rows).reshape(-1, 1)
    kernel = functools.partial(
        fused_select_kernel, n_terms=n_terms, cand_per_lane=cand_per_lane
    )
    cand_v, cand_i = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[scalar_spec, bound_spec, bound_spec, page_spec, page_spec,
                  env_spec],
        out_specs=[cand_spec, cand_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks * cand_per_lane, LANES),
                                 jnp.float32),
            jax.ShapeDtypeStruct((n_blocks * cand_per_lane, LANES),
                                 jnp.int32),
        ],
        interpret=interpret,
    )(
        thresh.reshape(1, 1).astype(jnp.float32),
        bounds.reshape(-1, 1).astype(jnp.float32),
        row0s,
        tau_pad.reshape(rows, LANES),
        n_pad.reshape(rows, LANES),
        env,
    )
    return (
        cand_v.reshape(n_blocks, cand_per_lane, LANES),
        cand_i.reshape(n_blocks, cand_per_lane, LANES),
    )


def block_state_fn(tau_pad, n_pad, block_rows: int):
    """Default per-block state fetch: index the free (n_blocks, rows, LANES)
    views of the flat padded state. The fetch happens *inside* the compute
    branch of the block skip, so skipped blocks never touch the state (or
    env) arrays at all — previously the scan-over-blocks carried every block
    through its xs, paying a full copy of the packed planes per round even
    when almost everything was skipped.

    Callers with a different state representation (the macro-round scan in
    `sched.backends` reconstructs n_CIS from a crawl anchor + a prefix-summed
    feed batch) pass their own `state_fn(i) -> (tau_b, n_b)` returning f32
    (block_rows, LANES) tiles; the value math downstream is identical, so
    selection stays bit-equal whenever the reconstructed state is."""
    tau3, n3 = layout.state_blocks(tau_pad, n_pad, block_rows)

    def state_fn(i):
        return (
            jax.lax.dynamic_index_in_dim(tau3, i, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(n3, i, 0, keepdims=False)
            .astype(jnp.float32),
        )

    return state_fn


def _candidates_jnp_from(state_fn, env, bounds, thresh, n_terms,
                         cand_per_lane):
    """scan-over-block-indices mirror of the kernel grid; `lax.cond` ==
    `pl.when`, so skipped blocks cost no FLOPs here either — and because the
    state/env block fetch lives inside the compute branch, they cost no
    memory traffic either."""
    n_blocks, _, block_rows, _ = env.shape

    def body(_, xs):
        i, bound_b = xs

        def compute(_):
            tau_b, n_b = state_fn(i)
            env_b = jax.lax.dynamic_index_in_dim(env, i, 0, keepdims=False)
            v = value_from_planes(tau_b, n_b, env_b, n_terms)
            return _lane_topc(v, i * block_rows, cand_per_lane)

        def skip(_):
            return (
                jnp.full((cand_per_lane, LANES), -jnp.inf, jnp.float32),
                jnp.zeros((cand_per_lane, LANES), jnp.int32),
            )

        return None, jax.lax.cond(bound_b >= thresh, compute, skip, None)

    _, (cand_v, cand_i) = jax.lax.scan(
        body, None, (jnp.arange(n_blocks, dtype=jnp.int32),
                     bounds.astype(jnp.float32))
    )
    return cand_v, cand_i


def _candidates_jnp(tau_pad, n_pad, env, bounds, thresh, n_terms,
                    cand_per_lane):
    """Dense-state convenience wrapper around `_candidates_jnp_from`."""
    return _candidates_jnp_from(
        block_state_fn(tau_pad, n_pad, env.shape[2]), env, bounds, thresh,
        n_terms, cand_per_lane,
    )


def _dense_values_from(state_fn, env, n_terms):
    """All block values via the per-block state fetch (the exact-recovery
    fallback for state_fn-based callers). Same elementwise math as the
    vectorized dense pass."""
    n_blocks = env.shape[0]

    def one(i):
        tau_b, n_b = state_fn(i)
        env_b = jax.lax.dynamic_index_in_dim(env, i, 0, keepdims=False)
        return value_from_planes(tau_b, n_b, env_b, n_terms)

    return jax.lax.map(one, jnp.arange(n_blocks, dtype=jnp.int32))


def fused_select_from(
    state_fn,
    env: jax.Array,
    k: int,
    thresh: jax.Array,
    bounds: jax.Array,
    n_terms: int = 8,
    cand_per_lane: int | None = None,
    impl: str = "jnp",
    interpret: bool = True,
    dense_state: tuple[jax.Array, jax.Array] | None = None,
    k_dyn: jax.Array | None = None,
) -> FusedSelection:
    """Un-jitted core over a per-block state fetch (safe inside shard_map,
    scan-invariant: shapes and branch structure are static, so the whole
    selection scans across rounds under one `lax.scan`). See `fused_select`.

    state_fn(i) -> (tau_b, n_b) f32 (block_rows, LANES) tiles, consulted only
    for evaluated blocks (jnp impl). The Pallas impl streams dense state
    (`dense_state`, required) since a Pallas grid reads arrays, not
    callbacks.

    k_dyn: optional traced int32 scalar — the dynamic budget under the
    static cap `k` (the k_max cap contract). Every shape stays sized at the
    static k; positions >= k_dyn of the returned selection are masked
    (values -inf, ids -1), the k-th value / tie-overflow / column-overflow
    exact-recovery checks evaluate against the *dynamic* k-th candidate
    (k_dyn = 0 selects nothing and never falls back), and when
    k_dyn == k every masking expression is the identity, so constant-budget
    callers stay bit-identical to the static path.
    """
    if cand_per_lane is None:
        cand_per_lane = auto_cand_per_lane(k)
    n_blocks, _, block_rows, _ = env.shape
    n_cand = n_blocks * cand_per_lane * LANES
    assert k <= n_cand, (
        f"k={k} exceeds candidate capacity {n_cand}; raise cand_per_lane"
    )
    thresh = jnp.asarray(thresh, jnp.float32)
    if impl == "pallas":
        assert dense_state is not None, "pallas impl streams dense state"
        tau_pad, n_pad = dense_state
        cand_v, cand_i = _candidates_pallas(
            tau_pad, n_pad, env, bounds, thresh, n_terms, cand_per_lane,
            interpret,
        )

        def dense_values():
            tau3, n3 = layout.state_blocks(tau_pad, n_pad, block_rows)
            return value_from_planes(tau3, n3, env, n_terms)
    else:
        cand_v, cand_i = _candidates_jnp_from(
            state_fn, env, bounds, thresh, n_terms, cand_per_lane
        )
        if dense_state is not None:
            # One vectorized pass over every block beats the sequential
            # per-block lax.map whenever the caller holds dense state (the
            # per-round path) — elementwise-identical math, so exactness
            # and the bit-equality with state_fn-only callers (the macro
            # scan) are unaffected.
            tau_pad, n_pad = dense_state

            def dense_values():
                tau3, n3 = layout.state_blocks(
                    tau_pad, n_pad.astype(jnp.float32), block_rows)
                return value_from_planes(tau3, n3, env, n_terms)
        else:

            def dense_values():
                return _dense_values_from(state_fn, env, n_terms)

    flat_v = cand_v.reshape(-1)
    flat_i = cand_i.reshape(-1)
    # Top-k among the candidates. A full (value desc, id asc) lexsort over
    # the candidate buffer reproduces dense tie order directly but costs a
    # 2-key sort of n_cand elements every round (~40% of a warm round's time
    # at production sizes); instead: top_k by value (ties broken by flat
    # buffer position — NOT page id), then re-rank just the k selected pairs
    # by (value desc, id asc). Whenever no value tie straddles the k-th
    # boundary, the selected SET is forced (all candidates >= kth, counted
    # exactly k) and the re-rank reproduces jax.lax.top_k's dense tie order
    # bit-for-bit. Boundary ties (more candidates >= kth than k — e.g. the
    # degenerate all-equal cold round) are detected below and routed to the
    # dense fallback, which was already the behavior for saturated columns.
    sel_v, pos = jax.lax.top_k(flat_v, k)
    sel_i = flat_i[pos]
    # The optimization_barrier keeps XLA-CPU's TopK rewriter applicable: the
    # rewriter only fires while the underlying sort's sole consumers are the
    # slice-to-k outputs, and slicing kth straight out of `sel_v` would fold
    # into the sort and silently degrade top_k into a full stable sort of
    # the candidate buffer (~30x slower at production sizes). The barrier
    # wraps only the sliced values — never the (values, ids) pair — so the
    # sort's users stay plain get-tuple-elements; a tuple-level barrier user
    # crashes XLA's sort simplifier under sharded lowering.
    sel_vb = jax.lax.optimization_barrier(sel_v)
    if k_dyn is None:
        kth = sel_vb[k - 1]
        k_eff = k
        live = None
    else:
        # Dynamic budget under the static cap: the k-th value is the
        # k_dyn-th best candidate (+inf when k_dyn = 0 — nothing is
        # selected, so no threshold, column, or tie condition can fire and
        # zero-budget rounds never pay the dense fallback). Positions
        # >= k_dyn are masked to (-inf, INT32_MAX) *before* the re-rank so
        # live entries — whose ids are always below INT32_MAX — sort ahead
        # of masked ones even on -inf value ties.
        k_eff = jnp.clip(jnp.asarray(k_dyn, jnp.int32), 0, k)
        kth = jnp.where(
            k_eff > 0, sel_vb[jnp.maximum(k_eff, 1) - 1], jnp.float32(jnp.inf)
        )
        live = jnp.arange(k, dtype=jnp.int32) < k_eff
        sel_v = jnp.where(live, sel_v, -jnp.inf)
        sel_i = jnp.where(live, sel_i, jnp.int32(2**31 - 1))
    order = jnp.lexsort((sel_i, -sel_v))  # k elements — cheap
    top_v = sel_v[order]
    top_i = sel_i[order]
    if k_dyn is not None:
        top_i = jnp.where(live, top_i, -1)

    # Exact-recovery check (module docstring): any lane column whose last
    # retained candidate could still beat (or tie) the k-th value may have
    # dropped a winner; a threshold above kth may have skipped one; a value
    # tie straddling the k-th boundary makes the positional top_k ambiguous.
    col_last = cand_v[:, cand_per_lane - 1, :]
    tie_overflow = jnp.sum(flat_v >= kth) > k_eff
    fell_back = (thresh > kth) | jnp.any(col_last >= kth) | tie_overflow

    def dense(_):
        # Fallback diagnostics must describe the pass that actually ran:
        # every block was evaluated (frac_active = 1.0) and the block maxima
        # come from the dense values — the candidate buffers hold -inf for
        # skipped blocks and truncated columns, so reusing them would poison
        # the bound anchors (`sched.tiered.update_block_bounds`).
        vals = dense_values()
        dv, di = jax.lax.top_k(vals.reshape(-1), k)
        di = di.astype(jnp.int32)
        if k_dyn is None:
            kth_d = dv[k - 1]
        else:
            kth_d = jnp.where(
                k_eff > 0, dv[jnp.maximum(k_eff, 1) - 1],
                jnp.float32(jnp.inf),
            )
            dv = jnp.where(live, dv, -jnp.inf)
            di = jnp.where(live, di, -1)
        colw = _col_depth(vals, kth_d)
        return (dv, di, vals.max(axis=(1, 2)), jnp.float32(1.0), colw)

    def keep(_):
        return (top_v, top_i, cand_v[:, 0, :].max(axis=-1),
                jnp.mean((bounds >= thresh).astype(jnp.float32)),
                _col_depth(cand_v, kth))

    top_v, top_i, blk_max, frac_active, col_winners = jax.lax.cond(
        fell_back, dense, keep, None
    )
    return FusedSelection(
        values=top_v,
        ids=top_i,
        blk_max=blk_max,
        fell_back=fell_back,
        frac_active=frac_active,
        col_winners=col_winners,
    )


def fused_select_local(
    tau_pad: jax.Array,
    n_pad: jax.Array,
    env: jax.Array,
    k: int,
    thresh: jax.Array,
    bounds: jax.Array,
    n_terms: int = 8,
    cand_per_lane: int | None = None,
    impl: str = "jnp",
    interpret: bool = True,
    k_dyn: jax.Array | None = None,
) -> FusedSelection:
    """Un-jitted core over flat padded state (safe inside shard_map). See
    `fused_select`; thin wrapper over `fused_select_from`."""
    n_pad = n_pad.astype(jnp.float32)  # accept the scheduler's int32 counts
    return fused_select_from(
        block_state_fn(tau_pad, n_pad, env.shape[2]), env, k, thresh, bounds,
        n_terms=n_terms, cand_per_lane=cand_per_lane, impl=impl,
        interpret=interpret, dense_state=(tau_pad, n_pad), k_dyn=k_dyn,
    )


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_terms", "cand_per_lane", "impl", "interpret"),
)
def _fused_select_jit(tau_pad, n_pad, env, k, thresh, bounds, n_terms,
                      cand_per_lane, impl, interpret):
    return fused_select_local(
        tau_pad, n_pad, env, k, thresh, bounds, n_terms, cand_per_lane,
        impl, interpret,
    )


def fused_select(
    tau_pad: jax.Array,
    n_cis_pad: jax.Array,
    shard: layout.PageShard | jax.Array,
    k: int,
    thresh: jax.Array | float | None = None,
    bounds: jax.Array | None = None,
    cand_per_lane: int | None = None,
    n_terms: int | None = None,
    impl: str | None = None,
    interpret: bool | None = None,
) -> FusedSelection:
    """Fused single-pass top-k selection over a packed shard.

    tau_pad/n_cis_pad: (m_pad,) padded flat state (`layout.pad_state`).
    shard: a `layout.PageShard` (or its raw env planes; n_terms then
    required). thresh: running selection threshold (previous round's k-th
    value; None = -inf, no skipping). bounds: (n_blocks,) optimistic
    per-block bounds (None = +inf, all blocks evaluated;
    `layout.asym_block_bounds` gives the static asymptote bound,
    `sched.tiered.BlockBounds` the refreshing one).

    Selection is exactly dense `jax.lax.top_k` on every round — overflow /
    over-aggressive-threshold rounds transparently fall back to a dense pass.
    """
    if isinstance(shard, layout.PageShard):
        env = shard.env
        n_terms = shard.n_terms if n_terms is None else n_terms
    else:
        env = shard
        assert n_terms is not None, "raw env planes require n_terms"
    if cand_per_lane is None:
        cand_per_lane = auto_cand_per_lane(k)
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_blocks = env.shape[0]
    if thresh is None:
        thresh = -jnp.inf
    if bounds is None:
        bounds = jnp.full((n_blocks,), jnp.inf, jnp.float32)
    return _fused_select_jit(
        tau_pad, n_cis_pad, env, k,
        jnp.asarray(thresh, jnp.float32), bounds,
        n_terms, cand_per_lane, impl, interpret,
    )
