"""jit'd public wrappers around the Pallas kernels: padding, 2-D page tiling,
bound plumbing, and the interpret-mode switch (CPU validates the kernel body;
TPU is the deployment target)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.values import DerivedEnv
from repro.kernels.crawl_value import (
    DEFAULT_BLOCK_ROWS,
    LANES,
    crawl_value_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, size: int, fill) -> jax.Array:
    pad = size - x.shape[0]
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])


@functools.partial(
    jax.jit, static_argnames=("n_terms", "block_rows", "interpret")
)
def crawl_value(
    tau_elap: jax.Array,
    n_cis: jax.Array,
    d: DerivedEnv,
    n_terms: int = 8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused V_GREEDY_NCIS for a flat page shard (no tiering: all blocks on)."""
    if interpret is None:
        interpret = not _on_tpu()
    m = tau_elap.shape[0]
    block_pages = block_rows * LANES
    m_pad = -(-m // block_pages) * block_pages
    n_blocks = m_pad // block_pages

    # Padding pages: delta=1, mu=0 -> value 0, never selected.
    tau2d = _pad_to(tau_elap.astype(jnp.float32), m_pad, 0.0).reshape(-1, LANES)
    n2d = _pad_to(n_cis.astype(jnp.float32), m_pad, 0.0).reshape(-1, LANES)
    fields = tuple(
        _pad_to(x.astype(jnp.float32), m_pad, fill).reshape(-1, LANES)
        for x, fill in (
            (d.delta, 1.0),
            (d.mu_t, 0.0),
            (d.nu, 0.0),
            (d.gamma, 0.0),
            (d.alpha, 1.0),
            (d.b, 0.0),
        )
    )
    bounds = jnp.ones((n_blocks, 1), jnp.float32)
    thresh = jnp.zeros((1, 1), jnp.float32)
    vals, _ = crawl_value_pallas(
        tau2d, n2d, fields, bounds, thresh, n_terms, block_rows, interpret
    )
    return vals.reshape(-1)[:m]


@functools.partial(
    jax.jit, static_argnames=("n_terms", "block_rows", "interpret")
)
def crawl_value_tiered(
    tau_elap: jax.Array,
    n_cis: jax.Array,
    d: DerivedEnv,
    bounds: jax.Array,
    thresh: jax.Array,
    n_terms: int = 8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
):
    """Tiered variant (paper App. G): per-block bounds + selection threshold;
    returns (values with -inf for skipped blocks, per-block maxima)."""
    if interpret is None:
        interpret = not _on_tpu()
    m = tau_elap.shape[0]
    block_pages = block_rows * LANES
    assert m % block_pages == 0, "tiered path expects block-aligned shards"
    tau2d = tau_elap.astype(jnp.float32).reshape(-1, LANES)
    n2d = n_cis.astype(jnp.float32).reshape(-1, LANES)
    fields = tuple(
        x.astype(jnp.float32).reshape(-1, LANES)
        for x in (d.delta, d.mu_t, d.nu, d.gamma, d.alpha, d.b)
    )
    vals, blkmax = crawl_value_pallas(
        tau2d,
        n2d,
        fields,
        bounds.reshape(-1, 1).astype(jnp.float32),
        thresh.reshape(1, 1).astype(jnp.float32),
        n_terms,
        block_rows,
        interpret,
    )
    return vals.reshape(-1), blkmax.max(axis=-1)
