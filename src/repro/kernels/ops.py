"""jit'd public wrappers around the Pallas kernels.

The hot path packs the environment once per parameter refresh
(`layout.pack_shard`) and re-uses the packed planes every round — see
`kernels.select.fused_select` for the production selection pipeline. The
one-shot APIs here (`crawl_value`, `crawl_value_tiered`) keep the historical
(tau, n, DerivedEnv) signature for tests/oracles and pack internally per call;
`crawl_value_packed` is the refresh-amortized entry point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.values import DerivedEnv
from repro.kernels import layout
from repro.kernels.crawl_value import crawl_value_pallas
from repro.kernels.layout import DEFAULT_BLOCK_ROWS, LANES  # noqa: F401


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("n_terms", "interpret")
)
def crawl_value_packed(
    tau_pad: jax.Array,
    n_pad: jax.Array,
    env: jax.Array,
    bounds: jax.Array | None = None,
    thresh: jax.Array | None = None,
    n_terms: int = 8,
    interpret: bool | None = None,
):
    """Dense values over a packed shard (env from `layout.pack_shard`).

    Returns (vals (m_pad,) with -inf for skipped blocks and padding,
    per-block lane maxima (n_blocks, LANES))."""
    if interpret is None:
        interpret = not _on_tpu()
    n_blocks = env.shape[0]
    if bounds is None:
        bounds = jnp.ones((n_blocks,), jnp.float32)
    if thresh is None:
        thresh = jnp.zeros((), jnp.float32)
    return crawl_value_pallas(
        tau_pad,
        n_pad,
        env,
        bounds.reshape(-1, 1).astype(jnp.float32),
        thresh.reshape(1, 1).astype(jnp.float32),
        n_terms,
        interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("n_terms", "block_rows", "interpret")
)
def crawl_value(
    tau_elap: jax.Array,
    n_cis: jax.Array,
    d: DerivedEnv,
    n_terms: int = 8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused V_GREEDY_NCIS for a flat page shard (no tiering: all blocks on).

    One-shot API: packs per call. Hot paths should pack once per parameter
    refresh and call `crawl_value_packed` / `select.fused_select`."""
    if interpret is None:
        interpret = not _on_tpu()
    m = tau_elap.shape[0]
    shard = layout.pack_shard(d, n_terms=n_terms, block_rows=block_rows)
    tau_pad, n_pad = layout.pad_state(tau_elap, n_cis, shard.m_pad)
    vals, _ = crawl_value_packed(
        tau_pad, n_pad, shard.env, n_terms=n_terms, interpret=interpret
    )
    return vals[:m]


@functools.partial(
    jax.jit, static_argnames=("n_terms", "block_rows", "interpret")
)
def crawl_value_tiered(
    tau_elap: jax.Array,
    n_cis: jax.Array,
    d: DerivedEnv,
    bounds: jax.Array,
    thresh: jax.Array,
    n_terms: int = 8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
):
    """Tiered variant (paper App. G): per-block bounds + selection threshold;
    returns (values with -inf for skipped blocks, per-block maxima)."""
    if interpret is None:
        interpret = not _on_tpu()
    m = tau_elap.shape[0]
    block_pages = block_rows * LANES
    assert m % block_pages == 0, "tiered path expects block-aligned shards"
    shard = layout.pack_shard(d, n_terms=n_terms, block_rows=block_rows)
    tau_pad, n_pad = layout.pad_state(tau_elap, n_cis, shard.m_pad)
    vals, blkmax = crawl_value_packed(
        tau_pad, n_pad, shard.env, bounds, thresh,
        n_terms=n_terms, interpret=interpret,
    )
    return vals, blkmax.max(axis=-1)
