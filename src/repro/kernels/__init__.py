"""Compute hot-spot kernels for the per-round crawl-value pipeline.

  layout       PageShard packed page-shard layout (pack once per refresh)
  crawl_value  dense fused value kernel (Pallas; value vector to HBM)
  select       fused single-pass value/top-k selection (values stay
               in-register; exact via candidate-overflow fallback)
  ops          jit'd public wrappers
  ref          pure-jnp oracles
"""
from repro.kernels import layout, ops, ref, select  # noqa: F401
