"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.values import DerivedEnv, tau_eff, value_ncis


def crawl_value_ref(
    tau: jax.Array,
    n_cis: jax.Array,
    d: DerivedEnv,
    n_terms: int = 8,
    method: str = "gamma",
) -> jax.Array:
    """Reference: V_GREEDY_NCIS(tau^EFF) per page, any shape."""
    t = tau_eff(tau, n_cis.astype(tau.dtype), d)
    return value_ncis(t, d, n_terms=n_terms, method=method)


def tiered_crawl_value_ref(
    tau: jax.Array,
    n_cis: jax.Array,
    d: DerivedEnv,
    bounds: jax.Array,
    thresh: jax.Array,
    block_pages: int,
    n_terms: int = 8,
) -> jax.Array:
    """Reference including the block-skip semantics: blocks with
    bound < thresh yield -inf for every page."""
    v = crawl_value_ref(tau, n_cis, d, n_terms)
    keep = jnp.repeat(bounds.reshape(-1) >= thresh.reshape(()), block_pages)
    return jnp.where(keep, v, -jnp.inf)
