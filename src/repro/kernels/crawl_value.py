"""Pallas TPU kernel: fused crawl-value evaluation over the packed PageShard
layout, with tiered block skip.

This is the per-tick hot spot of the paper's production deployment: evaluating
V_GREEDY_NCIS for ~10^9 pages per shard per scheduling round. The kernel fuses

    tau^EFF = tau^ELAP + beta * n_CIS
    V       = mu_t * ( w(tau^EFF) - e^{-alpha tau^EFF} psi(tau^EFF) )

with the K-term Taylor-residual ladder (Section 5.1 / App. A.1) evaluated
in-register — exp + K^2/2 FMAs per page, no special functions, pure VPU work.
All env-derived constants (beta, 1/gamma, 1/(delta+nu), the coefficient
ladder nu^i/(delta+nu)^{i+1}) arrive precomputed in the packed env planes
(see `kernels.layout`), so the kernel body contains zero divisions and zero
per-round derivation, and reads one contiguous (n_planes, BLOCK_ROWS, 128)
stream per block. Production features:

  * per-block *tiered skip* (paper App. G): each grid block carries an
    optimistic value bound; blocks whose bound is below the current selection
    threshold skip all compute and emit -inf (`pl.when`), saving ~the tier
    fraction of the round's FLOPs and HBM stream;
  * fused per-block lane-maxima output, feeding the scheduler's top-k.

This module holds the *dense* kernel (full m-element value output — used by
the one-shot `ops.crawl_value` API and as the exact-recovery fallback). The
fused *selection* kernel that never materializes the value vector lives in
`kernels.select`.

Memory layout: pages are tiled (BLOCK_ROWS, 128). With BLOCK_ROWS = 256 and
K = 8 a block's working set is (2 state + 16 env + 1 out) * 256 * 128 * 4 B
= 2.4 MiB, comfortably inside VMEM with double buffering; see
`layout.bytes_per_page` for the per-page byte budget. All tile dims are
(8,128)-aligned for the VPU; there is no MXU work here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import layout
from repro.kernels.layout import DEFAULT_BLOCK_ROWS, LANES  # noqa: F401  (re-export)

BIG = 1e30
_BIG_CUT = 1e29  # iota beyond this => asymptote branch


def value_from_planes(tau, n, env, n_terms: int):
    """V_GREEDY_NCIS from packed planes — the shared kernel body.

    tau, n: (..., R, LANES) state tiles; env: (..., n_planes, R, LANES) packed
    planes (`kernels.layout` ordering). Works identically inside a Pallas
    block (R = BLOCK_ROWS, no leading dims) and as a dense jnp evaluation over
    all blocks at once — the jnp path is bit-identical to the kernel body, so
    the exact-recovery fallback and the CPU mirror share one definition.

    Pure FMA + exp work: every division the seed kernel performed per page per
    round (beta = b/alpha, 1/gamma, 1/(delta+nu)) is a precomputed plane.
    """
    mu_t = env[..., layout.MU_T, :, :]
    alpha = env[..., layout.ALPHA, :, :]
    beta = env[..., layout.BETA, :, :]
    gamma = env[..., layout.GAMMA, :, :]
    ag = env[..., layout.AG, :, :]
    inv_g = env[..., layout.INV_G, :, :]

    iota = jnp.minimum(tau + jnp.minimum(beta * n, BIG), BIG)
    small_g = gamma < 1e-8
    small_ag = ag < 1e-8

    psi = jnp.zeros_like(tau)
    ww = jnp.zeros_like(tau)
    for i in range(n_terms):
        coeff = env[..., layout.COEFF0 + i, :, :]
        ib = 0.0 if i == 0 else jnp.minimum(i * beta, BIG)
        rem = jnp.maximum(iota - ib, 0.0)
        active = (ib <= iota) & (rem > 0.0)
        # Saturation clamp (see core.residuals.residual_ladder): beyond cut_i
        # the residual is 1 to ~1e-11 and the clamp prevents f32 overflow of
        # the series terms.
        cut = i + 10.0 * (i + 1.0) ** 0.5 + 20.0
        x_psi = jnp.minimum(gamma * rem, cut)
        x_w = jnp.minimum(ag * rem, cut)
        # --- R^i ladder, inline (series form; i static) ---
        if i == 0:
            r_psi = -jnp.expm1(-x_psi)
            r_w = -jnp.expm1(-x_w)
        else:
            s_p = jnp.ones_like(x_psi)
            t_p = jnp.ones_like(x_psi)
            s_w = jnp.ones_like(x_w)
            t_w = jnp.ones_like(x_w)
            for j in range(1, i + 1):
                inv_j = 1.0 / j
                t_p = t_p * (x_psi * inv_j)
                s_p = s_p + t_p
                t_w = t_w * (x_w * inv_j)
                s_w = s_w + t_w
            r_psi = 1.0 - jnp.exp(-x_psi) * s_p
            r_w = 1.0 - jnp.exp(-x_w) * s_w
            # small-x: complementary tail series (no cancellation) —
            # see core.residuals.residual_ladder.
            tp_t = t_p * (x_psi / (i + 1))
            tw_t = t_w * (x_w / (i + 1))
            tail_p, tail_w = tp_t, tw_t
            for j in range(i + 2, i + 5):
                tp_t = tp_t * (x_psi / j)
                tw_t = tw_t * (x_w / j)
                tail_p = tail_p + tp_t
                tail_w = tail_w + tw_t
            r_psi = jnp.where(x_psi < 0.5, jnp.exp(-x_psi) * tail_p, r_psi)
            r_w = jnp.where(x_w < 0.5, jnp.exp(-x_w) * tail_w, r_w)
        # psi term with gamma->0 limit (only i = 0 survives).
        if i == 0:
            p_term = jnp.where(small_g, rem, r_psi * inv_g)
            w_term = jnp.where(small_ag, rem, coeff * r_w)
        else:
            p_term = jnp.where(small_g, 0.0, r_psi * inv_g)
            w_term = coeff * r_w
        psi = psi + jnp.where(active, p_term, 0.0)
        ww = ww + jnp.where(active, w_term, 0.0)

    decay = jnp.exp(-jnp.minimum(alpha * iota, 80.0))
    v = mu_t * (ww - decay * psi)
    v = jnp.where(iota >= _BIG_CUT, env[..., layout.V_INF, :, :], v)
    # Padding pages score -inf: they can never enter any selection.
    return jnp.where(env[..., layout.VALID, :, :] > 0.0, v, -jnp.inf)


def crawl_value_kernel(
    thresh_ref,
    bound_ref,
    tau_ref,
    n_ref,
    env_ref,
    vals_ref,
    blkmax_ref,
    *,
    n_terms: int,
):
    bound = bound_ref[0, 0]
    thresh = thresh_ref[0, 0]

    @pl.when(bound >= thresh)
    def _compute():
        v = value_from_planes(tau_ref[...], n_ref[...], env_ref[0], n_terms)
        vals_ref[...] = v
        blkmax_ref[...] = jnp.max(v, axis=0, keepdims=True)

    @pl.when(bound < thresh)
    def _skip():
        vals_ref[...] = jnp.full(vals_ref.shape, -jnp.inf, vals_ref.dtype)
        blkmax_ref[...] = jnp.full(blkmax_ref.shape, -jnp.inf, blkmax_ref.dtype)


def crawl_value_pallas(
    tau_pad: jax.Array,
    n_pad: jax.Array,
    env: jax.Array,
    bounds: jax.Array,
    thresh: jax.Array,
    n_terms: int = 8,
    interpret: bool = True,
):
    """Launch the dense value kernel over a packed shard.

    tau_pad/n_pad: (m_pad,) f32 padded state; env: (n_blocks, n_planes,
    block_rows, LANES) packed planes; bounds: (n_blocks, 1) per-block value
    bounds; thresh: (1, 1). Returns (vals (m_pad,), block_lane_max
    (n_blocks, LANES)).
    """
    n_blocks, np_, block_rows, lanes = env.shape
    assert lanes == LANES and np_ == layout.n_planes(n_terms), env.shape
    rows = n_blocks * block_rows
    tau2d = tau_pad.reshape(rows, LANES)
    n2d = n_pad.reshape(rows, LANES)

    page_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    bound_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    env_spec = pl.BlockSpec(
        (1, np_, block_rows, LANES), lambda i: (i, 0, 0, 0)
    )
    blkmax_spec = pl.BlockSpec((1, LANES), lambda i: (i, 0))

    kernel = functools.partial(crawl_value_kernel, n_terms=n_terms)
    vals, blkmax = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[scalar_spec, bound_spec, page_spec, page_spec, env_spec],
        out_specs=[page_spec, blkmax_spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(thresh, bounds, tau2d, n2d, env)
    return vals.reshape(-1), blkmax
