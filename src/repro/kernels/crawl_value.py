"""Pallas TPU kernel: fused crawl-value evaluation with tiered block skip.

This is the per-tick hot spot of the paper's production deployment: evaluating
V_GREEDY_NCIS for ~10^9 pages per shard per scheduling round. The kernel fuses

    tau^EFF = tau^ELAP + beta * n_CIS
    V       = mu_t * ( w(tau^EFF) - e^{-alpha tau^EFF} psi(tau^EFF) )

with the K-term Taylor-residual ladder (Section 5.1 / App. A.1) evaluated
in-register — exp + K^2/2 FMAs per page, no special functions, pure VPU work —
plus two production features:

  * per-block *tiered skip* (paper App. G): each grid block carries an
    optimistic value bound; blocks whose bound is below the current selection
    threshold skip all compute and emit -inf (`pl.when`), saving ~the tier
    fraction of the round's FLOPs;
  * fused per-block lane-maxima output, feeding the scheduler's top-k without
    a second pass over HBM.

Memory layout: pages are tiled (BLOCK_ROWS, 128) — 8 f32 input fields + 1
output per page; with BLOCK_ROWS = 256 a block's working set is
9 * 256 * 128 * 4 B = 1.2 MiB, comfortably inside VMEM with double buffering.
All tile dims are (8,128)-aligned for the VPU; there is no MXU work here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30
_BIG_CUT = 1e29  # iota beyond this => asymptote branch
DEFAULT_BLOCK_ROWS = 256
LANES = 128


def _ladder_sum(x, k_max):
    """R^i(x[i]) for the unrolled i = 0..k_max-1 ladder; x is a list of tiles."""
    outs = []
    for i in range(k_max):
        xi = x[i]
        if i == 0:
            outs.append(-jnp.expm1(-xi))
        else:
            s = jnp.ones_like(xi)
            term = jnp.ones_like(xi)
            for j in range(1, i + 1):
                term = term * (xi * (1.0 / j))
                s = s + term
            outs.append(1.0 - jnp.exp(-xi) * s)
    return outs


def crawl_value_kernel(
    thresh_ref,
    bound_ref,
    tau_ref,
    n_ref,
    delta_ref,
    mu_ref,
    nu_ref,
    gamma_ref,
    alpha_ref,
    b_ref,
    vals_ref,
    blkmax_ref,
    *,
    n_terms: int,
):
    bound = bound_ref[0, 0]
    thresh = thresh_ref[0, 0]

    @pl.when(bound >= thresh)
    def _compute():
        tau = tau_ref[...]
        n = n_ref[...]
        delta = delta_ref[...]
        mu_t = mu_ref[...]
        nu = nu_ref[...]
        gamma = gamma_ref[...]
        alpha = alpha_ref[...]
        b = b_ref[...]

        eps = 1e-12
        beta = jnp.where(alpha > 1e-20, b / jnp.maximum(alpha, 1e-20), BIG)
        beta = jnp.minimum(beta, BIG)
        # gamma == 0: signals never arrive; mirror derive()'s beta -> BIG so a
        # (physically unreachable) n_cis > 0 maps to the asymptote branch.
        beta = jnp.where(gamma > 0.0, beta, BIG)
        iota = jnp.minimum(tau + jnp.minimum(beta * n, BIG), BIG)

        ag = alpha + gamma
        inv_g = 1.0 / jnp.maximum(gamma, eps)
        inv_dn = 1.0 / jnp.maximum(delta + nu, eps)
        small_g = gamma < 1e-8

        psi = jnp.zeros_like(tau)
        ww = jnp.zeros_like(tau)
        # coeff_i = nu^i / (delta+nu)^{i+1}, built incrementally.
        coeff = inv_dn
        nu_ratio = nu * inv_dn
        for i in range(n_terms):
            ib = 0.0 if i == 0 else jnp.minimum(i * beta, BIG)
            rem = jnp.maximum(iota - ib, 0.0)
            active = (ib <= iota) & (rem > 0.0)
            # Saturation clamp (see core.residuals.residual_ladder): beyond
            # cut_i the residual is 1 to ~1e-11 and the clamp prevents f32
            # overflow of the series terms.
            cut = i + 10.0 * (i + 1.0) ** 0.5 + 20.0
            x_psi = jnp.minimum(gamma * rem, cut)
            x_w = jnp.minimum(ag * rem, cut)
            # --- R^i ladder, inline (series form; i static) ---
            if i == 0:
                r_psi = -jnp.expm1(-x_psi)
                r_w = -jnp.expm1(-x_w)
            else:
                s_p = jnp.ones_like(x_psi)
                t_p = jnp.ones_like(x_psi)
                s_w = jnp.ones_like(x_w)
                t_w = jnp.ones_like(x_w)
                for j in range(1, i + 1):
                    inv_j = 1.0 / j
                    t_p = t_p * (x_psi * inv_j)
                    s_p = s_p + t_p
                    t_w = t_w * (x_w * inv_j)
                    s_w = s_w + t_w
                r_psi = 1.0 - jnp.exp(-x_psi) * s_p
                r_w = 1.0 - jnp.exp(-x_w) * s_w
                # small-x: complementary tail series (no cancellation) —
                # see core.residuals.residual_ladder.
                tp_t = t_p * (x_psi / (i + 1))
                tw_t = t_w * (x_w / (i + 1))
                tail_p, tail_w = tp_t, tw_t
                for j in range(i + 2, i + 5):
                    tp_t = tp_t * (x_psi / j)
                    tw_t = tw_t * (x_w / j)
                    tail_p = tail_p + tp_t
                    tail_w = tail_w + tw_t
                r_psi = jnp.where(x_psi < 0.5, jnp.exp(-x_psi) * tail_p, r_psi)
                r_w = jnp.where(x_w < 0.5, jnp.exp(-x_w) * tail_w, r_w)
            # psi term with gamma->0 limit (only i = 0 survives).
            if i == 0:
                p_term = jnp.where(small_g, rem, r_psi * inv_g)
                w_term = coeff * r_w
                w_term = jnp.where(ag < 1e-8, rem, w_term)
            else:
                p_term = jnp.where(small_g, 0.0, r_psi * inv_g)
                w_term = coeff * r_w
            psi = psi + jnp.where(active, p_term, 0.0)
            ww = ww + jnp.where(active, w_term, 0.0)
            coeff = coeff * nu_ratio

        decay = jnp.exp(-jnp.minimum(alpha * iota, 80.0))
        v = mu_t * (ww - decay * psi)
        v_inf = mu_t / jnp.maximum(delta, eps)
        v = jnp.where(iota >= _BIG_CUT, v_inf, v)
        vals_ref[...] = v
        blkmax_ref[...] = jnp.max(v, axis=0, keepdims=True)

    @pl.when(bound < thresh)
    def _skip():
        vals_ref[...] = jnp.full(vals_ref.shape, -jnp.inf, vals_ref.dtype)
        blkmax_ref[...] = jnp.full(blkmax_ref.shape, -jnp.inf, blkmax_ref.dtype)


def crawl_value_pallas(
    tau2d: jax.Array,
    n2d: jax.Array,
    fields2d: tuple,
    bounds: jax.Array,
    thresh: jax.Array,
    n_terms: int = 8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Launch the kernel over a (rows, 128) page tiling.

    tau2d/n2d/fields2d: (rows, 128) f32; fields2d = (delta, mu_t, nu, gamma,
    alpha, b). bounds: (n_blocks, 1) per-block value bounds; thresh: (1, 1).
    Returns (vals (rows,128), block_lane_max (n_blocks, 128)).
    """
    rows = tau2d.shape[0]
    assert rows % block_rows == 0, (rows, block_rows)
    n_blocks = rows // block_rows
    grid = (n_blocks,)

    page_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    bound_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    blkmax_spec = pl.BlockSpec((1, LANES), lambda i: (i, 0))

    kernel = functools.partial(crawl_value_kernel, n_terms=n_terms)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[scalar_spec, bound_spec] + [page_spec] * 8,
        out_specs=[page_spec, blkmax_spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(thresh, bounds, tau2d, n2d, *fields2d)
