"""PageShard — the packed page-shard layout feeding the fused select pipeline.

Production shards hold ~10^9 pages and are re-scored every scheduling round
(paper Section 5.2 / App. G). The seed hot path re-padded and re-streamed 8
separate f32 field arrays through HBM per round and re-derived env-only
constants (beta, 1/gamma, 1/(delta+nu), the nu^i/(delta+nu)^{i+1} coefficient
ladder) inside the kernel. All of that is a function of the *environment
parameters only*, which change once per parameter refresh (hours), not once
per round (seconds).

This module packs everything the value kernel needs into one block-tiled SoA
tensor, built once per parameter refresh:

    env planes: (n_blocks, N_ENV + K, BLOCK_ROWS, 128) f32

Per-page planes (axis 1):

    MU_T    normalized importance                       mu / sum(mu)
    ALPHA   unsignalled change rate                     (1 - lam) * delta
    BETA    time-equivalent of one CIS                  b / alpha (BIG-guarded)
    GAMMA   observed CIS rate                           lam * delta + nu
    AG      alpha + gamma                               (x_w rate)
    INV_G   1 / max(gamma, eps)                         (psi normalizer)
    V_INF   asymptote mu_t / delta                      (iota -> inf branch)
    VALID   1.0 real page / 0.0 padding                 (padding scores -inf)
    COEFF0 + i, i < K:  nu^i / (delta + nu)^{i+1}       (w-series ladder)

so the kernel reads ONE contiguous stream per block and does zero per-round
derivation — no divisions, no logs, pure FMA + exp work. Precomputing the
first-K coefficient ladder costs 4*K B/page of extra stream but removes the
serial coeff_{i+1} = coeff_i * nu_ratio dependency chain from the term loop,
so all K terms issue as independent FMAs on the VPU.

Byte budget per page per round (K = 8):

    state stream (tau, n_cis)            2 * 4 =  8 B
    env stream   (8 + K planes)         16 * 4 = 64 B
    fused-select output                 ~(2 * 8 * n_blocks * 128) / m ~= 0 B
    ------------------------------------------------------------------
    total                                        72 B * (active fraction)

versus the seed pipeline's 8 * 4 read + 4 write + 4 re-read for top-k = 44 B
on EVERY page every round. With value-tiered shards the fused pipeline touches
only the blocks whose optimistic bound clears the selection threshold (the
paper's App. G tiering), so the effective bytes/page is 72 * f_active, with
f_active ~ 0.1 in steady state. `bytes_per_page()` reports the analytic number
used by the benchmarks' derived column.

State (tau^ELAP, n_CIS) stays in flat (m_pad,) arrays owned by the scheduler —
it changes every round, so packing it with the env planes would force a full
rewrite of the packed tensor per round. The flat padded arrays reshape to
(n_blocks, BLOCK_ROWS, 128) views for free; page p lives at block
p // block_pages, row (p % block_pages) // 128, lane p % 128 — i.e. flat
padded index == page id, padding at the tail.

Parameter refresh is incremental: `repack_pages` scatters the refreshed
pages' plane columns (the paper's decentralized per-page refresh — with the
tensor donated the scatter is in place) and `refresh_block_bounds`
recomputes the static bound for the touched blocks only. A full
`pack_shard` is only ever paid at construction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.values import BIG, DerivedEnv

LANES = 128
DEFAULT_BLOCK_ROWS = 256
_EPS = 1e-12

# Env-plane indices (axis 1 of PageShard.env).
MU_T = 0
ALPHA = 1
BETA = 2
GAMMA = 3
AG = 4
INV_G = 5
V_INF = 6
VALID = 7
COEFF0 = 8
N_ENV = 8  # planes before the coefficient ladder
N_STATE = 2  # tau, n_cis — streamed separately (see module docstring)


def n_planes(n_terms: int) -> int:
    return N_ENV + n_terms


def bytes_per_page(n_terms: int) -> int:
    """HBM bytes streamed per *active* page per round by the fused kernel."""
    return 4 * (N_STATE + n_planes(n_terms))


def bytes_per_update(n_terms: int) -> int:
    """HBM bytes written per updated page by `repack_pages` (one plane column
    scatter). Block-granular bound refresh adds O(block) reads per touched
    block on top."""
    return 4 * n_planes(n_terms)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PageShard:
    """Packed env planes + static layout metadata. The env tensor is the only
    array leaf, so a PageShard moves through jit/shard_map boundaries as a
    single (n_blocks, n_planes, block_rows, LANES) f32 array."""

    env: jax.Array
    m: int = dataclasses.field(metadata=dict(static=True))
    n_terms: int = dataclasses.field(metadata=dict(static=True))
    block_rows: int = dataclasses.field(metadata=dict(static=True))

    @property
    def block_pages(self) -> int:
        return self.block_rows * LANES

    @property
    def n_blocks(self) -> int:
        return self.env.shape[0]

    @property
    def m_pad(self) -> int:
        return self.n_blocks * self.block_pages


def pad_to(
    x: jax.Array, m_pad: int, fill: float = 0.0, dtype=jnp.float32
) -> jax.Array:
    """Pad a flat per-page array to the packed size. THE padding helper: every
    feed/state/env pad in the scheduler routes through here (dtype=None keeps
    the input dtype). Rejects inputs longer than the padded size."""
    if dtype is not None:
        x = x.astype(dtype)
    pad = m_pad - x.shape[0]
    if pad < 0:
        raise ValueError(
            f"per-page array of length {x.shape[0]} exceeds the packed size "
            f"{m_pad}; refusing to truncate"
        )
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])


_pad = pad_to


def padded_size(
    m: int, block_rows: int = DEFAULT_BLOCK_ROWS, n_shards: int = 1
) -> int:
    """Pages after padding: a whole number of blocks, and (for sharded use)
    a block count divisible by the shard count so every shard owns the same
    number of whole blocks."""
    bp = block_rows * LANES
    n_blocks = -(-m // bp)
    n_blocks = -(-n_blocks // n_shards) * n_shards
    return n_blocks * bp


def _page_planes(delta, mu_t, nu, gamma, alpha, beta, valid, n_terms: int):
    """The per-page plane math, shared by the full pack and the incremental
    repack so updated pages are bit-identical to a from-scratch pack.

    All inputs f32 of one shape; returns the n_planes(n_terms) plane list.
    """
    dn = jnp.maximum(delta + nu, _EPS)
    # coeff_i = nu^i / (delta+nu)^{i+1} in log space (stable at larger i),
    # mirroring core.values.w exactly so packed values match the oracle.
    log_nu = jnp.log(jnp.maximum(nu, _EPS))
    log_dn = jnp.log(dn)
    ladder = []
    for i in range(n_terms):
        if i == 0:
            ladder.append(1.0 / dn)
        else:
            coeff = jnp.exp(i * log_nu - (i + 1.0) * log_dn)
            ladder.append(jnp.where(nu <= 0.0, 0.0, coeff))

    return [
        mu_t,                                   # MU_T
        alpha,                                  # ALPHA
        jnp.minimum(beta, BIG),                 # BETA
        gamma,                                  # GAMMA
        alpha + gamma,                          # AG
        1.0 / jnp.maximum(gamma, _EPS),         # INV_G
        mu_t / jnp.maximum(delta, _EPS),        # V_INF
        valid,                                  # VALID
    ] + ladder


def pack_shard(
    d: DerivedEnv,
    n_terms: int = 8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> PageShard:
    """Build the packed env planes from a derived environment.

    Pay once per *full* parameter refresh; per-page refreshes should go
    through `repack_pages`, which touches only the updated plane columns.
    Padding pages (mu_t = 0, VALID = 0) score -inf in the fused kernel and
    can never be selected.
    """
    m = d.delta.shape[0]
    m_pad = padded_size(m, block_rows)

    # Padded raw fields; fills chosen so every derived plane is finite.
    planes = _page_planes(
        delta=pad_to(d.delta, m_pad, 1.0),
        mu_t=pad_to(d.mu_t, m_pad, 0.0),
        nu=pad_to(d.nu, m_pad, 0.0),
        gamma=pad_to(d.gamma, m_pad, 0.0),
        alpha=pad_to(d.alpha, m_pad, 1.0),
        beta=pad_to(d.beta, m_pad, 0.0),
        valid=pad_to(jnp.ones((m,), jnp.float32), m_pad, 0.0),
        n_terms=n_terms,
    )
    n_blocks = m_pad // (block_rows * LANES)
    env = jnp.stack(
        [p.reshape(n_blocks, block_rows, LANES) for p in planes], axis=1
    )
    return PageShard(env=env, m=m, n_terms=n_terms, block_rows=block_rows)


def repack_pages(
    env: jax.Array, page_ids: jax.Array, d_new: DerivedEnv
) -> jax.Array:
    """Scatter-update the packed planes of `page_ids` from their refreshed
    derived parameters — the paper's decentralized parameter refresh.

    d_new: DerivedEnv whose fields have shape (n_upd,) (derive the raw
    updates with the *construction-time* mu_total so normalization stays
    consistent with the untouched pages). Only the updated pages' plane
    columns are written; with the env buffer donated (`backends.crawl_round`
    / `backends.refresh_pages`) the scatter is in-place — O(n_upd * n_planes)
    writes instead of the O(m * n_planes) of a full `pack_shard`.

    Out-of-range ids are DROPPED (scatter mode="drop"): the shard-local
    repack (`sched.backends.FusedBackend.update_pages`) pads each shard's
    update batch to a common static width with a sentinel id one past the
    shard's page count, so padding rows write nothing.
    """
    n_blocks, np_, block_rows, lanes = env.shape
    n_terms = np_ - N_ENV
    ids = jnp.asarray(page_ids, jnp.int32)
    f = lambda x: jnp.asarray(x, jnp.float32)
    planes = _page_planes(
        delta=f(d_new.delta), mu_t=f(d_new.mu_t), nu=f(d_new.nu),
        gamma=f(d_new.gamma), alpha=f(d_new.alpha), beta=f(d_new.beta),
        valid=jnp.ones(ids.shape, jnp.float32), n_terms=n_terms,
    )
    cols = jnp.stack(planes, axis=-1)            # (n_upd, n_planes)
    bp = block_rows * lanes
    blk = ids // bp
    row = (ids % bp) // lanes
    lane = ids % lanes
    return env.at[blk, :, row, lane].set(cols, mode="drop")


def refold_mu(env: jax.Array, mu_t: jax.Array, delta: jax.Array) -> jax.Array:
    """Rewrite the mu-derived planes for EVERY page of a packed shard — the
    request-importance refold (`sched.importance.fold_into_planes`). A new
    importance vector re-anchors the global normalizer, so unlike the
    per-page `repack_pages` scatter this touches the whole MU_T plane; but
    mu enters only two planes (MU_T itself and the V_INF asymptote), so the
    refold writes 2 of n_planes columns instead of re-deriving everything
    `pack_shard` does.

    mu_t/delta: flat (m_pad_local,) f32 — the new normalized importance and
    the raw change-rate column (stashed at attach time,
    `sched.importance.ReqState.delta`, padding fill 1.0). V_INF uses the
    exact `_page_planes` expression, so a refold is bit-identical to
    packing from scratch with the new mu."""
    nb, _, block_rows, lanes = env.shape
    vinf = mu_t / jnp.maximum(delta, _EPS)
    env = env.at[:, MU_T].set(mu_t.reshape(nb, block_rows, lanes))
    return env.at[:, V_INF].set(vinf.reshape(nb, block_rows, lanes))


def gather_plane(env: jax.Array, page_ids: jax.Array, plane: int) -> jax.Array:
    """Gather one packed plane's value per flat (padded) page id — the
    read-side companion of `repack_pages`' flat-id addressing (page p lives
    at block p // bp, row (p % bp) // LANES, lane p % LANES). Out-of-range
    ids clamp to the last page (pair with a dropped scatter for sentinel
    rows); ids must be non-negative."""
    nb, _, block_rows, lanes = env.shape
    bp = block_rows * lanes
    ids = jnp.minimum(jnp.asarray(page_ids, jnp.int32), nb * bp - 1)
    return env[ids // bp, plane, (ids % bp) // lanes, ids % lanes]


def refresh_block_bounds(
    env: jax.Array, bounds: jax.Array, block_ids: jax.Array
) -> jax.Array:
    """Recompute the static asymptote bound for the touched blocks only
    (block-granular: O(touched * block_pages) reads, everything else keeps
    its bound). Companion to `repack_pages`; like it, out-of-range sentinel
    ids are dropped (the gather clamps, the scatter drops) so per-shard
    padded block batches pass through unchanged."""
    new = env[block_ids, V_INF].max(axis=(1, 2))
    return bounds.at[block_ids].set(new, mode="drop")


def pad_state(
    tau_elap: jax.Array, n_cis: jax.Array, m_pad: int
) -> tuple[jax.Array, jax.Array]:
    """Pad flat scheduler state to the packed size (padding: tau = 0, n = 0 —
    VALID masks them to -inf regardless)."""
    return _pad(tau_elap, m_pad, 0.0), _pad(n_cis, m_pad, 0.0)


def state_blocks(
    tau_pad: jax.Array, n_pad: jax.Array, block_rows: int
) -> tuple[jax.Array, jax.Array]:
    """Free reshape of padded flat state to (n_blocks, block_rows, LANES)."""
    return (
        tau_pad.reshape(-1, block_rows, LANES),
        n_pad.reshape(-1, block_rows, LANES),
    )


def asym_block_bounds(env: jax.Array) -> jax.Array:
    """Static per-block value bound max(mu_t / delta): V can never exceed its
    asymptote, so this bound needs no staleness refresh — blocks whose best
    page can never reach the selection threshold are skipped forever."""
    return env[:, V_INF].max(axis=(1, 2))


def block_mu_max(env: jax.Array, block_ids: jax.Array | None = None) -> jax.Array:
    """Per-block max normalized importance, feeding the slope row of the
    refreshing bounds (`sched.tiered.BlockBounds`). Like
    `refresh_block_bounds`, passing `block_ids` reads only the touched blocks
    so the post-repack slope refresh stays block-granular — and computes the
    same plane reduction as a from-scratch `init_block_bounds`."""
    sel = env if block_ids is None else env[block_ids]
    return sel[:, MU_T].max(axis=(1, 2))


def block_beta_max(env: jax.Array, block_ids: jax.Array | None = None) -> jax.Array:
    """Per-block max time-equivalent of one CIS (the BETA plane), feeding the
    CIS-mass re-evaluation rule (`sched.tiered.accumulate_cis_mass`): a block
    that received n signals since its last exact evaluation has advanced its
    best page's exposure clock by at most beta_max * n. Padding pages pack
    beta = 0 and never contribute. Block-granular like `block_mu_max`."""
    sel = env if block_ids is None else env[block_ids]
    return sel[:, BETA].max(axis=(1, 2))
