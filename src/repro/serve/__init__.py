from repro.serve.engine import GenerationResult, generate, sample_token

__all__ = [k for k in dir() if not k.startswith("_")]
