from repro.serve.engine import GenerationResult, generate, sample_token
from repro.serve.requests import RequestFront, ServeStats

__all__ = [k for k in dir() if not k.startswith("_")]
