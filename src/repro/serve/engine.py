"""Batched generation engine: prefill + jit decode loop with sampling.

Serving path used by examples/serve_lm.py and the decode dry-run cells. The
decode step is a single compiled program reused every token; the KV cache is
donated so decoding is allocation-free after warmup.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


class GenerationResult(NamedTuple):
    tokens: jax.Array      # (B, prompt + max_new)
    logprobs: jax.Array    # (B, max_new)


def sample_token(key, logits, temperature=1.0, top_k=0):
    """logits: (B, V) f32 -> (B,) i32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cut = vals[..., -1:]
        logits = jnp.where(logits < cut, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("cfg", "temperature", "top_k"),
    donate_argnums=(4,),
)
def _decode_jit(cfg, params, token, pos, cache, key, temperature, top_k):
    logits, cache = M.decode_step(cfg, params, token, pos, cache)
    nxt = sample_token(key, logits, temperature, top_k)
    lp = jax.nn.log_softmax(logits)[jnp.arange(logits.shape[0]), nxt]
    return nxt, lp, cache


def generate(cfg: ModelConfig, params, batch, max_new: int, key=None,
             temperature: float = 0.0, top_k: int = 0, s_max: int = 0):
    """Greedy/temperature generation. batch["tokens"]: (B, S_prompt)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    prompt = batch["tokens"]
    b, s = prompt.shape
    s_max = s_max or s + max_new
    logits, cache = M.prefill(cfg, params, batch, s_max=s_max)
    toks = [prompt]
    lps = []
    tok = sample_token(key, logits, temperature, top_k)
    for i in range(max_new):
        toks.append(tok[:, None])
        key = jax.random.fold_in(key, i)
        pos = jnp.int32(s + i)
        nxt, lp, cache = _decode_jit(
            cfg, params, tok[:, None], pos, cache, key,
            float(temperature), int(top_k)
        )
        lps.append(lp)
        tok = nxt
    return GenerationResult(
        tokens=jnp.concatenate(toks, axis=1),
        logprobs=jnp.stack(lps, axis=1) if lps else jnp.zeros((b, 0)),
    )
