"""The request front: live traffic in, freshness answers out, `mu` learned.

This is the serving-side half of request-driven importance
(`sched.importance`). A `RequestFront` wraps a `CrawlScheduler`
constructed with `importance=True` and exposes the two-call production
API:

  * `serve_pages(ids) -> p_fresh` — per requested page, the model
    posterior P(cached copy still fresh | tau, observed CIS)
    = exp(-alpha * tau_eff), the exact belief the value kernel crawls by
    (`fresh(ids) -> bool` thresholds it). Serving *is* logging: the same
    device dispatch applies the request-EWMA step, so the importance
    estimate is a free by-product of answering traffic.
  * `log_requests(ids)` — traffic that needs no answer (e.g. a replicated
    access log) still teaches the scheduler what matters.

Design mirrors `serve.engine`'s decode loop: one compiled program reused
for every batch (the per-shard `request_cap` pins the static batch shape,
same capacity contract as the scheduler's `feed_cap`), state donated so
serving is allocation-free after warmup, and nothing in the hot path reads
a device value back — `serve_pages(sync=False)` leaves the answers on
device (the bench's zero-host-sync mode), `sync=True` pays one transfer to
reassemble per-request answers host-side. Scheduling rounds interleave
freely between batches: the front holds no copy of the scheduler state, it
drives the live donated pytree.

Periodically (`fold_every` served/logged batches, or an explicit
`fold()`), the accumulated EWMA folds into the packed `MU_T` plane and the
crawler starts optimizing freshness *weighted by what users actually
ask for*. On a multi-process mesh every host must fold at the same batch
count (the fold has one psum; `fold_every` makes that cadence implicit as
long as hosts serve the same number of batches — otherwise call `fold()`
explicitly at a barrier of your choosing) while logging/serving between
folds stays collective-free and per-host independent.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import numpy as np

from repro.sched import importance as imp


class ServeStats(NamedTuple):
    """Host-side counters of the front (plain ints, never device reads)."""

    batches: int        # serve/log batches dispatched
    requests: int       # raw request rows routed (incl. remote drops)
    folds: int          # MU_T refolds performed


class RequestFront:
    """Batched serve/log front over a request-importance scheduler.

    `source` picks the importance blend used at fold time
    (`importance.REQUEST_EWMA` by default; `LINK_PRIOR` / `UNIFORM` are
    the ablation arms). `fold_every=0` disables automatic folds (call
    `fold()` yourself). `fresh_threshold` is the posterior cut for the
    boolean `fresh` view."""

    def __init__(self, sched, *, source: imp.ImportanceSource | None = None,
                 fold_every: int = 0, fresh_threshold: float = 0.5):
        # Validates the plane exists up front (fail at build, not first
        # request).
        sched._req_state()
        self.sched = sched
        self.source = source if source is not None else imp.REQUEST_EWMA
        self.fold_every = int(fold_every)
        self.fresh_threshold = float(fresh_threshold)
        self._batches = 0
        self._requests = 0
        self._folds = 0

    # -- the serving API ---------------------------------------------------
    def serve_pages(self, page_ids, counts=None, *, sync: bool = True):
        """Answer a request batch with per-page freshness posteriors.

        sync=True: float32 array aligned with `page_ids` (NaN for pages
        this host does not own — the upstream router's rows). sync=False:
        the raw device (n_shards, cap) answers + routing map, no host
        transfer (zero-sync mode). Either way the batch's request counts
        are logged into the EWMA plane in the same dispatch."""
        out = self.sched.serve_requests(page_ids, counts, log=True,
                                        sync=sync)
        self._after_batch(page_ids)
        return out

    def fresh(self, page_ids, *, sync: bool = True):
        """`serve_pages` thresholded to the boolean "is it fresh?" view."""
        p = self.serve_pages(page_ids, sync=sync)
        if not sync:
            return p
        return p >= self.fresh_threshold

    def log_requests(self, page_ids, counts=None) -> None:
        """Log traffic that needs no freshness answer."""
        self.sched.log_requests(page_ids, counts)
        self._after_batch(page_ids)

    def fold(self):
        """Fold the EWMA plane into `MU_T` now (see
        `CrawlScheduler.fold_importance`). Returns the re-anchored
        mu_total (replicated device scalar)."""
        self._folds += 1
        return self.sched.fold_importance(self.source)

    # -- bookkeeping -------------------------------------------------------
    def _after_batch(self, page_ids) -> None:
        self._batches += 1
        self._requests += int(np.asarray(page_ids).size)
        if self.fold_every and self._batches % self.fold_every == 0:
            self.fold()

    @property
    def stats(self) -> ServeStats:
        return ServeStats(batches=self._batches, requests=self._requests,
                          folds=self._folds)
