"""Crawl-refreshed training corpus — the paper's technique as the freshness
layer of the data pipeline.

A corpus of m documents lives on a simulated "web": each document changes via
its Poisson process (rate Delta_i), emits noisy change-indicating signals
(recall lam_i, false-positive rate nu_i), and is requested by the trainer with
importance mu_i. The crawler holds a *cached* copy per document and a refresh
budget of k documents per training step; the paper's GREEDY_NCIS policy
chooses which caches to refresh from (tau^ELAP, n_CIS) alone.

Each training batch samples documents ~ mu and tokenizes the *cached* version;
`stats()` reports the importance-weighted cache freshness — the paper's
objective — so the benefit of better crawl policies is directly visible as
fresher training data under the same bandwidth.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import derive, tables
from repro.core.policies import GREEDY_NCIS, crawl_values
from repro.core.state import PageState
from repro.core.values import Env


class CrawlRefreshedCorpus:
    def __init__(self, m: int, vocab: int, seq_len: int, global_batch: int,
                 refresh_per_step: int = 8, policy: str = GREEDY_NCIS,
                 dt: float = 0.05, seed: int = 0):
        self.rng = np.random.Generator(np.random.Philox(seed))
        self.m, self.vocab, self.seq_len = m, vocab, seq_len
        self.batch = global_batch
        self.k = refresh_per_step
        self.dt = dt
        self.policy = policy
        delta = self.rng.uniform(0.05, 1.0, m)
        mu = self.rng.uniform(0.05, 1.0, m)
        lam = self.rng.beta(0.25, 0.25, m)
        nu = self.rng.uniform(0.1, 0.6, m)
        self.env = Env(*map(jnp.asarray, (delta, mu, lam, nu)))
        self.d = derive(self.env)
        self.table = tables.build_ncis_table(self.d)
        self._delta = delta
        self._mu = mu / mu.sum()
        self._lam = lam
        self._nu = nu
        self.web_version = np.zeros(m, np.int64)     # truth
        self.cache_version = np.zeros(m, np.int64)   # what we crawled
        self.tau = np.zeros(m, np.float32)
        self.n_cis = np.zeros(m, np.int32)
        self._refreshes = 0

    # ----- environment tick -----
    def _tick(self):
        changes = self.rng.poisson(self._delta * self.dt)
        signaled = self.rng.binomial(changes, self._lam)
        false = self.rng.poisson(self._nu * self.dt)
        self.web_version += changes
        self.n_cis += (signaled + false).astype(np.int32)
        self.tau += self.dt

    # ----- the paper's scheduler -----
    def _refresh(self):
        vals = tables.lookup_state(
            self.table, self.d, jnp.asarray(self.tau), jnp.asarray(self.n_cis)
        )
        top = np.asarray(jax.lax.top_k(vals, self.k)[1])
        self.cache_version[top] = self.web_version[top]
        self.tau[top] = 0.0
        self.n_cis[top] = 0
        self._refreshes += len(top)
        return top

    # ----- training API -----
    def batch_at(self, step: int):
        self._tick()
        self._refresh()
        docs = self.rng.choice(self.m, size=self.batch, p=self._mu)
        toks = np.empty((self.batch, self.seq_len + 1), np.int32)
        for i, doc in enumerate(docs):
            gen = np.random.Generator(
                np.random.Philox(key=int(doc),
                                 counter=[int(self.cache_version[doc]), 0, 0, 0])
            )
            toks[i] = gen.integers(0, self.vocab, self.seq_len + 1)
        fresh = (self.cache_version[docs] == self.web_version[docs])
        return (
            {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:])},
            {"batch_fresh_frac": float(fresh.mean())},
        )

    def stats(self):
        fresh = (self.cache_version == self.web_version).astype(np.float64)
        return {
            "weighted_freshness": float((self._mu * fresh).sum()),
            "refreshes": self._refreshes,
        }
