from repro.data.synthetic import SyntheticLMData
from repro.data.refresh import CrawlRefreshedCorpus

__all__ = [k for k in dir() if not k.startswith("_")]
