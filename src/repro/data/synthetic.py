"""Deterministic synthetic LM data (host-side pipeline).

Zipf-distributed unigrams mixed with short deterministic motifs so that a
~100M model shows a real, reproducible loss curve within a few hundred steps.
Each (seed, step, host) triple maps to a unique batch — restart-safe and
shardable across data-loader hosts without coordination.
"""
from __future__ import annotations

import numpy as np


class SyntheticLMData:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_hosts: int = 1, host_id: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = global_batch // n_hosts
        self.seed = seed
        self.host_id = host_id
        ranks = np.arange(1, vocab + 1)
        p = 1.0 / ranks ** 1.1
        self.p = p / p.sum()

    def batch_at(self, step: int):
        rng = np.random.Generator(
            np.random.Philox(key=self.seed, counter=[step, self.host_id, 0, 0])
        )
        b, s = self.batch, self.seq_len
        toks = rng.choice(self.vocab, size=(b, s + 1), p=self.p).astype(np.int32)
        # motif structure: periodic copy of a short window -> learnable signal
        motif = toks[:, : s // 8]
        reps = int(np.ceil((s + 1) / motif.shape[1]))
        pattern = np.tile(motif, (1, reps))[:, : s + 1]
        mix = rng.random((b, 1)) < 0.5
        toks = np.where(mix, pattern, toks)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
