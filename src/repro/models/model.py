"""Model registry: parameter definitions, train forward, prefill and decode for
every assigned architecture family.

API (all pure functions of (cfg, params, ...)):
    param_defs(cfg, max_seq)            -> ParamDef tree
    init(key, cfg, max_seq)             -> params
    forward_train(cfg, params, batch)   -> (logits f32, aux loss)
    init_cache(cfg, batch, s_max)       -> cache pytree (decode state)
    prefill(cfg, params, batch, s_max)  -> (last logits, cache, pos)
    decode_step(cfg, params, token, pos, cache) -> (logits, cache)

batch: {"tokens": (B,S) i32, "labels": (B,S) i32,
        "frames": (B,enc_seq,d) [audio], "patches": (B,n_prefix,d) [vlm]}
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xl
from repro.models.common import (
    ParamDef,
    apply_norm,
    constrain,
    init_params as _init,
    norm_defs,
    param_specs as _specs,
    sinusoid_pos,
    stack,
)
from repro.models import transformer as tfm

# ---------------------------------------------------------------------------
# parameter definitions


def param_defs(cfg: ModelConfig, max_seq: int):
    d = {"embed": tfm.embed_defs(cfg, max_seq)}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        d["blocks"] = tfm.dense_stack_defs(cfg)
    elif fam == "encdec":
        d["blocks"] = stack(cfg.n_layers, tfm.block_defs(cfg, cross=True))
        d["enc"] = {
            "blocks": stack(cfg.n_enc_layers, tfm.block_defs(cfg)),
            "ln_post": norm_defs(cfg, cfg.d_model),
        }
    elif fam == "ssm":  # xLSTM
        per = cfg.ssm.mlstm_per_group
        n_groups = cfg.n_layers // (per + 1)
        d["groups"] = stack(
            n_groups,
            {
                "m": stack(per, {"ln": norm_defs(cfg, cfg.d_model),
                                 "cell": xl.mlstm_defs(cfg)}),
                "s": {
                    "ln": norm_defs(cfg, cfg.d_model),
                    "cell": xl.slstm_defs(cfg),
                    "ln2": norm_defs(cfg, cfg.d_model),
                },
            },
        )
    elif fam == "hybrid":  # zamba2
        n_groups = cfg.n_layers // cfg.attn_every
        d["groups"] = stack(
            n_groups,
            {"m": stack(cfg.attn_every, {"ln": norm_defs(cfg, cfg.d_model),
                                         "mix": ssm_mod.mamba_defs(cfg)})},
        )
        d["shared"] = tfm.block_defs(cfg)
        d["shared_in"] = ParamDef((2 * cfg.d_model, cfg.d_model),
                                  ("fsdp", "tensor"))
        if cfg.lora_rank:
            d["lora"] = stack(n_groups, _lora_only_defs(cfg))
    else:
        raise ValueError(fam)
    return d


def _lora_only_defs(cfg):
    full = tfm.block_defs(cfg, lora_rank=cfg.lora_rank)
    return {"attn": {k: v for k, v in full["attn"].items() if "lora" in k},
            "mlp": {k: v for k, v in full["mlp"].items() if "lora" in k}}


def init(key, cfg: ModelConfig, max_seq: int, dtype=jnp.float32):
    return _init(key, param_defs(cfg, max_seq), dtype)


def specs(cfg: ModelConfig, max_seq: int, mesh):
    return _specs(param_defs(cfg, max_seq), mesh)


def _merge_lora(shared, lora_site):
    return {
        **shared,
        "attn": {**shared["attn"], **lora_site["attn"]},
        "mlp": {**shared["mlp"], **lora_site["mlp"]},
    }


# ---------------------------------------------------------------------------
# train forward


def _embed_in(cfg, params, batch, dtype):
    tokens = batch["tokens"]
    x = tfm.embed_apply(cfg, params["embed"], tokens, dtype)
    if cfg.family == "vlm":
        x = jax.lax.dynamic_update_slice(
            x, batch["patches"].astype(dtype), (0, 0, 0)
        )
    if cfg.pos == "learned":
        s = tokens.shape[1]
        x = x + params["embed"]["pos"][:s].astype(dtype)
    return x


def _encoder(cfg, params, frames, mesh, impl):
    dtype = frames.dtype
    x = frames + sinusoid_pos(frames.shape[1], cfg.d_model, dtype)
    enc = params["enc"]

    def body(carry, p):
        h, _ = carry
        h, a = tfm.block_apply(cfg, p, h, jnp.arange(h.shape[1]), mesh,
                               causal=False, impl="masked")
        return (h, a), None

    (x, _), _ = jax.lax.scan(
        tfm._maybe_remat(cfg, body), (x, jnp.float32(0.0)), enc["blocks"]
    )
    return apply_norm(cfg, enc["ln_post"], x)


def forward_hidden(cfg: ModelConfig, params, batch, mesh=None, impl="triangle"):
    """Run the stack, return (final hidden states, aux loss) — the training
    loss computes logits chunk-by-chunk from these (never materializing the
    full (B, S, V) f32 tensor)."""
    dtype = jnp.dtype(cfg.dtype)
    x = _embed_in(cfg, params, batch, dtype)
    x = constrain(x, mesh, "batch", "seq", None)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    aux = jnp.float32(0.0)

    if cfg.family in ("dense", "moe", "vlm"):
        x, aux = tfm.dense_stack_apply(cfg, params["blocks"], x, positions,
                                       mesh, impl=impl)
    elif cfg.family == "encdec":
        enc_out = _encoder(cfg, params, batch["frames"].astype(dtype), mesh, impl)
        x, aux = tfm.dense_stack_apply(cfg, params["blocks"], x, positions,
                                       mesh, impl=impl, enc_out=enc_out)
    elif cfg.family == "ssm":
        x = _xlstm_stack(cfg, params, x, mesh)
    elif cfg.family == "hybrid":
        x = _zamba_stack(cfg, params, x, positions, mesh, impl)
    return x, aux


def forward_train(cfg: ModelConfig, params, batch, mesh=None, impl="triangle"):
    x, aux = forward_hidden(cfg, params, batch, mesh, impl)
    return tfm.logits_apply(cfg, params["embed"], x), aux


def _xlstm_stack(cfg, params, x, mesh):
    def group(carry, gp):
        h = carry

        def mbody(hh, mp):
            y = xl.mlstm_apply(cfg, mp["cell"], apply_norm(cfg, mp["ln"], hh))
            return hh + y, None

        if cfg.remat == "inner":
            mbody = jax.checkpoint(mbody, prevent_cse=False)
        h, _ = jax.lax.scan(mbody, h, gp["m"])
        sp = gp["s"]
        h = h + xl.slstm_apply(cfg, sp["cell"], apply_norm(cfg, sp["ln"], h))
        h = h + xl.slstm_ffn(cfg, sp["cell"], apply_norm(cfg, sp["ln2"], h))
        h = constrain(h, mesh, "batch", "seq", None)
        return h, None

    x, _ = jax.lax.scan(tfm._maybe_remat(cfg, group), x, params["groups"])
    return x


def _zamba_stack(cfg, params, x, positions, mesh, impl):
    e0 = x  # original embeddings, concatenated into every shared-block input
    shared = params["shared"]
    w_in = params["shared_in"]
    has_lora = cfg.lora_rank > 0

    def group(carry, gp):
        h = carry

        def mbody(hh, mp):
            y = ssm_mod.mamba_apply(cfg, mp["mix"], apply_norm(cfg, mp["ln"], hh))
            return hh + y, None

        if cfg.remat == "inner":
            mbody = jax.checkpoint(mbody, prevent_cse=False)
        h, _ = jax.lax.scan(mbody, h, gp["m"])
        p_blk = _merge_lora(shared, gp["lora"]) if has_lora else shared
        inp = jnp.einsum(
            "bsd,dt->bst", jnp.concatenate([h, e0], axis=-1),
            w_in.astype(h.dtype),
        )
        y, _ = tfm.block_apply(cfg, p_blk, inp, positions, mesh, causal=True,
                               impl=impl, lora=has_lora)
        h = h + y - inp  # block returns inp+delta; keep only the delta path
        h = constrain(h, mesh, "batch", "seq", None)
        return h, None

    xs = params["groups"] if not has_lora else (
        {"m": params["groups"]["m"], "lora": params["lora"]}
    )
    x, _ = jax.lax.scan(tfm._maybe_remat(cfg, group), x, xs)
    return x


# ---------------------------------------------------------------------------
# caches


class DecodeCache(NamedTuple):
    k: Any = None
    v: Any = None
    xk: Any = None   # enc-dec cross keys
    xv: Any = None
    ssm: Any = None  # mamba / xlstm states
    pos: Any = None


def _kv_shape(cfg, b, s_max):
    if cfg.local_global:
        return (cfg.n_layers // 2, 2, b, s_max, cfg.n_kv_heads, cfg.hd)
    return (cfg.n_layers, b, s_max, cfg.n_kv_heads, cfg.hd)


def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        sh = _kv_shape(cfg, batch, s_max)
        return DecodeCache(k=jnp.zeros(sh, dtype), v=jnp.zeros(sh, dtype),
                           pos=jnp.int32(0))
    if fam == "encdec":
        sh = (cfg.n_layers, batch, s_max, cfg.n_kv_heads, cfg.hd)
        xsh = (cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.hd)
        return DecodeCache(k=jnp.zeros(sh, dtype), v=jnp.zeros(sh, dtype),
                           xk=jnp.zeros(xsh, dtype), xv=jnp.zeros(xsh, dtype),
                           pos=jnp.int32(0))
    if fam == "ssm":
        per = cfg.ssm.mlstm_per_group
        g = cfg.n_layers // (per + 1)
        m_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (g, per) + x.shape),
            xl.init_mlstm_state(cfg, batch),
        )
        s_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (g,) + x.shape),
            xl.init_slstm_state(cfg, batch),
        )
        return DecodeCache(ssm={"m": m_state, "s": s_state}, pos=jnp.int32(0))
    if fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        m_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (g, cfg.attn_every) + x.shape),
            ssm_mod.init_mamba_state(cfg, batch),
        )
        sh = (g, batch, s_max, cfg.n_kv_heads, cfg.hd)
        return DecodeCache(k=jnp.zeros(sh, dtype), v=jnp.zeros(sh, dtype),
                           ssm=m_state, pos=jnp.int32(0))
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# prefill


def prefill(cfg: ModelConfig, params, batch, s_max: int, mesh=None,
            impl="triangle", cache_dtype=jnp.bfloat16):
    """Run the prompt, return (last-token logits, filled cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = _embed_in(cfg, params, batch, dtype)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    fam = cfg.family

    def pad_seq(arr):  # (..., s, h, d) -> (..., s_max, h, d)
        pad = s_max - arr.shape[-3]
        if pad == 0:
            return arr.astype(cache_dtype)
        cfgp = [(0, 0)] * arr.ndim
        cfgp[-3] = (0, pad)
        return jnp.pad(arr.astype(cache_dtype), cfgp)

    if fam in ("dense", "moe", "vlm"):
        def body(carry, p):
            h, aux = carry
            if cfg.local_global:
                ks, vs = [], []
                for nm, win in (("local", cfg.window), ("global", 0)):
                    hn = apply_norm(cfg, p[nm]["ln1"], h)
                    _, k, v = tfm.qkv(cfg, p[nm]["attn"], hn, hn, positions)
                    ks.append(k); vs.append(v)
                    h, a = tfm.block_apply(cfg, p[nm], h, positions, mesh,
                                           causal=True, window=win, impl=impl)
                    aux = aux + a
                return (h, aux), (jnp.stack(ks), jnp.stack(vs))
            hn = apply_norm(cfg, p["ln1"], h)
            _, k, v = tfm.qkv(cfg, p["attn"], hn, hn, positions)
            h, a = tfm.block_apply(cfg, p, h, positions, mesh, causal=True,
                                   window=cfg.window, impl=impl)
            return (h, aux + a), (k, v)

        (x, _), (ks, vs) = jax.lax.scan(
            body, (x, jnp.float32(0.0)), params["blocks"]
        )
        cache = DecodeCache(k=pad_seq(ks), v=pad_seq(vs), pos=jnp.int32(s))
    elif fam == "encdec":
        enc_out = _encoder(cfg, params, batch["frames"].astype(dtype), mesh, impl)

        def body(carry, p):
            h, aux = carry
            hn = apply_norm(cfg, p["ln1"], h)
            _, k, v = tfm.qkv(cfg, p["attn"], hn, hn, positions)
            xk, xv = tfm.cross_kv(cfg, p["xattn"], enc_out)
            h, a = tfm.block_apply(cfg, p, h, positions, mesh, causal=True,
                                   impl=impl, enc_out=enc_out)
            return (h, aux + a), (k, v, xk, xv)

        (x, _), (ks, vs, xks, xvs) = jax.lax.scan(
            body, (x, jnp.float32(0.0)), params["blocks"]
        )
        cache = DecodeCache(k=pad_seq(ks), v=pad_seq(vs),
                            xk=xks.astype(cache_dtype),
                            xv=xvs.astype(cache_dtype), pos=jnp.int32(s))
    elif fam == "ssm":
        # Chunk-parallel prompt processing, collecting the recurrent states.
        def group(carry, gp):
            h = carry

            def mbody(hh, mp):
                y, st = xl.mlstm_apply(cfg, mp["cell"],
                                       apply_norm(cfg, mp["ln"], hh),
                                       return_state=True)
                return hh + y, st

            h, mst = jax.lax.scan(mbody, h, gp["m"])
            sp = gp["s"]
            y, sst = xl.slstm_apply(cfg, sp["cell"],
                                    apply_norm(cfg, sp["ln"], h),
                                    return_state=True)
            h = h + y
            h = h + xl.slstm_ffn(cfg, sp["cell"], apply_norm(cfg, sp["ln2"], h))
            return h, (mst, sst)

        x, (m_state, s_state) = jax.lax.scan(group, x, params["groups"])
        cache = DecodeCache(ssm={"m": m_state, "s": s_state}, pos=jnp.int32(s))
    elif fam == "hybrid":
        e0 = x
        shared = params["shared"]
        w_in = params["shared_in"]
        has_lora = cfg.lora_rank > 0

        def group(carry, xs):
            h = carry
            gp = xs
            lora_site = None
            if has_lora:
                gp, lora_site = xs

            def mbody(hh, mp):
                y, st = ssm_mod.mamba_apply(cfg, mp["mix"],
                                            apply_norm(cfg, mp["ln"], hh),
                                            return_state=True)
                return hh + y, st

            h, mst = jax.lax.scan(mbody, h, gp["m"])
            p_blk = _merge_lora(shared, lora_site) if has_lora else shared
            inp = jnp.einsum("bsd,dt->bst", jnp.concatenate([h, e0], -1),
                             w_in.astype(h.dtype))
            hn = apply_norm(cfg, p_blk["ln1"], inp)
            _, k, v = tfm.qkv(cfg, p_blk["attn"], hn, hn, positions,
                              lora=has_lora)
            y, _ = tfm.block_apply(cfg, p_blk, inp, positions, mesh,
                                   causal=True, impl=impl, lora=has_lora)
            h = h + y - inp
            return h, (mst, k, v)

        xs = (params["groups"], params["lora"]) if has_lora else params["groups"]
        x, (m_state, ks, vs) = jax.lax.scan(group, x, xs)
        cache = DecodeCache(k=pad_seq(ks), v=pad_seq(vs), ssm=m_state,
                            pos=jnp.int32(s))
    else:
        raise ValueError(fam)

    logits = tfm.logits_apply(cfg, params["embed"], x[:, -1:])
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# decode


def decode_step(cfg: ModelConfig, params, token, pos, cache: DecodeCache,
                mesh=None, patches=None):
    """token: (B, 1) i32; pos: scalar i32 (position being generated).
    Returns (logits (B, vocab_padded) f32, new cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = tfm.embed_apply(cfg, params["embed"], token, dtype)
    if cfg.pos == "learned":
        x = x + params["embed"]["pos"][pos][None, None].astype(dtype)
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        if cfg.local_global:
            def body(h, xs):
                p, kc, vc = xs
                h, k1, v1 = tfm.block_decode(cfg, p["local"], h, pos, kc[0],
                                             vc[0], window=cfg.window)
                h, k2, v2 = tfm.block_decode(cfg, p["global"], h, pos, kc[1],
                                             vc[1], window=0)
                return h, (jnp.stack([k1, k2]), jnp.stack([v1, v2]))
        else:
            def body(h, xs):
                p, kc, vc = xs
                h, kc, vc = tfm.block_decode(cfg, p, h, pos, kc, vc,
                                             window=cfg.window)
                return h, (kc, vc)

        x, (k, v) = jax.lax.scan(body, x, (params["blocks"], cache.k, cache.v))
        cache = cache._replace(k=k, v=v, pos=pos + 1)
    elif fam == "encdec":
        def body(h, xs):
            p, kc, vc, xk, xv = xs
            h, kc, vc = tfm.block_decode(cfg, p, h, pos, kc, vc,
                                         enc_kv=(xk, xv))
            return h, (kc, vc)

        x, (k, v) = jax.lax.scan(
            body, x, (params["blocks"], cache.k, cache.v, cache.xk, cache.xv)
        )
        cache = cache._replace(k=k, v=v, pos=pos + 1)
    elif fam == "ssm":
        def group(h, xs):
            gp, mst, sst = xs

            def mbody(hh, xs2):
                mp, st = xs2
                y, st = xl.mlstm_decode(cfg, mp["cell"],
                                        apply_norm(cfg, mp["ln"], hh), st)
                return hh + y, st

            h, mst = jax.lax.scan(mbody, h, (gp["m"], mst))
            sp = gp["s"]
            y, sst = xl.slstm_decode(cfg, sp["cell"],
                                     apply_norm(cfg, sp["ln"], h), sst)
            h = h + y
            h = h + xl.slstm_ffn(cfg, sp["cell"], apply_norm(cfg, sp["ln2"], h))
            return h, (mst, sst)

        x, (m_new, s_new) = jax.lax.scan(
            group, x, (params["groups"], cache.ssm["m"], cache.ssm["s"])
        )
        cache = cache._replace(ssm={"m": m_new, "s": s_new}, pos=pos + 1)
    elif fam == "hybrid":
        e0 = x
        shared = params["shared"]
        w_in = params["shared_in"]
        has_lora = cfg.lora_rank > 0

        def group(h, xs):
            if has_lora:
                gp, mst, kc, vc, lora_site = xs
                p_blk = _merge_lora(shared, lora_site)
            else:
                gp, mst, kc, vc = xs
                p_blk = shared

            def mbody(hh, xs2):
                mp, st = xs2
                y, st = ssm_mod.mamba_decode(cfg, mp["mix"],
                                             apply_norm(cfg, mp["ln"], hh), st)
                return hh + y, st

            h, mst = jax.lax.scan(mbody, h, (gp["m"], mst))
            inp = jnp.einsum("bsd,dt->bst", jnp.concatenate([h, e0], -1),
                             w_in.astype(h.dtype))
            y, kc, vc = tfm.block_decode(cfg, p_blk, inp, pos, kc, vc,
                                         lora=has_lora)
            h = h + y - inp
            return h, (mst, kc, vc)

        xs = ((params["groups"], cache.ssm, cache.k, cache.v, params["lora"])
              if has_lora else (params["groups"], cache.ssm, cache.k, cache.v))
        x, (m_new, k, v) = jax.lax.scan(group, x, xs)
        cache = cache._replace(ssm=m_new, k=k, v=v, pos=pos + 1)
    else:
        raise ValueError(fam)

    logits = tfm.logits_apply(cfg, params["embed"], x)
    return logits[:, 0], cache
