"""Shared model infrastructure: parameter definitions with logical sharding
axes, initialization, norms, rotary embeddings, and dtype policy.

Parameters are plain nested dicts of arrays. Each model builds a parallel tree
of `ParamDef`s (shape + logical axes + init); `init_params` materializes it and
`param_specs` lowers logical axes to mesh `PartitionSpec`s with automatic
divisibility fallback (a dim that does not divide the assigned mesh axes is
left unsharded rather than relying on GSPMD padding).

Logical axes:
    fsdp    weight dim sharded over the data axis (ZeRO-3 storage)
    tensor  weight dim sharded over the model axis (TP)
    layers / None   unsharded (layer-stacked leading dims etc.)
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


class ParamDef(NamedTuple):
    shape: tuple
    logical: tuple          # per-dim logical axis name (or None)
    init: str = "normal"    # normal | zeros | ones | embed
    scale: float = 1.0      # stddev multiplier (normal) — fan-in handled here


def dense_def(d_in: int, d_out: int, *, axes=("fsdp", "tensor"),
              scale: float = 1.0) -> ParamDef:
    return ParamDef((d_in, d_out), axes, "normal", scale)


def stack(n: int, tree):
    """Prepend a stacked-layers dim to every ParamDef in the tree."""
    return jax.tree.map(
        lambda p: ParamDef((n,) + p.shape, (None,) + p.logical, p.init, p.scale),
        tree,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def init_params(key: jax.Array, defs, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, p in zip(keys, leaves):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        else:
            # fan-in scaled normal; for stacked defs the fan-in dim is shape[-2]
            fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
            std = p.scale / math.sqrt(max(fan_in, 1))
            if p.init == "embed":
                std = p.scale
            out.append(std * jax.random.normal(k, p.shape, dtype))
    return jax.tree.unflatten(treedef, out)


# Logical-axis -> mesh-axis assignment. The pod axis is pure data parallelism
# (batch only): FSDP weight shards stay within a pod so the per-layer weight
# all-gathers ride the intra-pod ICI, not the cross-pod links.
LOGICAL_RULES = {
    "fsdp": ("data",),
    "tensor": ("model",),
    "batch": ("pod", "data"),
    "seq": ("model",),
    "heads": ("model",),
}

_DEFAULT_RULES = dict(LOGICAL_RULES)


def set_sharding_profile(profile: str) -> None:
    """Switch the logical->mesh assignment (a §Perf lever, applied before
    tracing). Profiles:
      default   FSDP(data) x TP(model)
      dp_only   no tensor parallelism: "model" becomes a second FSDP/DP axis —
                right for small-d models where 16-way TP is all overhead.
    """
    LOGICAL_RULES.clear()
    LOGICAL_RULES.update(_DEFAULT_RULES)
    if profile == "dp_only":
        LOGICAL_RULES.update({
            "fsdp": ("data", "model"),
            "tensor": (),
            "batch": ("pod", "data", "model"),
            "seq": (),
            "heads": (),
        })
    elif profile != "default":
        raise ValueError(profile)


def _mesh_axes(mesh: Mesh, logical: str | None):
    if logical is None:
        return None
    axes = tuple(a for a in LOGICAL_RULES.get(logical, ()) if a in mesh.axis_names)
    return axes or None


def spec_for(p: ParamDef, mesh: Mesh) -> P:
    dims = []
    for size, logical in zip(p.shape, p.logical):
        axes = _mesh_axes(mesh, logical)
        if axes is None:
            dims.append(None)
            continue
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        dims.append(axes if size % total == 0 else None)
    return P(*dims)


def param_specs(defs, mesh: Mesh):
    return jax.tree.map(
        lambda p: spec_for(p, mesh),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def constrain(x: jax.Array, mesh: Mesh | None, *logical):
    """with_sharding_constraint via logical dims, with divisibility fallback."""
    if mesh is None:
        return x
    dims = []
    for size, l in zip(x.shape, logical):
        axes = _mesh_axes(mesh, l)
        if axes is None:
            dims.append(None)
            continue
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        dims.append(axes if size % total == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*dims))
    )


# ---------------------------------------------------------------------------
# numerics helpers


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_defs(cfg, d: int):
    if cfg.norm == "layernorm":
        return {"scale": ParamDef((d,), (None,), "ones"),
                "bias": ParamDef((d,), (None,), "zeros")}
    return {"scale": ParamDef((d,), (None,), "zeros")}  # rmsnorm: (1+scale)


def apply_norm(cfg, p, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoid_pos(seq: int, d: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return -(-vocab // multiple) * multiple
