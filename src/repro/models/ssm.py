"""Mamba-2 (SSD) mixer — chunked scan for train/prefill, O(1) state decode.

State-space dual form: per head h with state (P x N),
    h_t = exp(dt_t A) h_{t-1} + dt_t * (B_t ⊗ x_t),   y_t = C_t · h_t + D x_t.

Train evaluates chunks of Q tokens: a masked intra-chunk quadratic term plus an
inter-chunk state recurrence (lax.scan over chunks keeps the Q x Q decay matrix
transient at (B, H, Q, Q) instead of materializing all chunks at once).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, apply_norm, rmsnorm


def mamba_defs(cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    n = s.d_state
    h = d_in // s.head_dim
    d_conv = d_in + 2 * n
    total = 2 * d_in + 2 * n + h  # z, x, B, C, dt
    return {
        "in_proj": ParamDef((d, total), ("fsdp", "tensor")),
        "conv_w": ParamDef((s.conv_width, d_conv), (None, "tensor"), "normal", 0.5),
        "conv_b": ParamDef((d_conv,), ("tensor",), "zeros"),
        "a_log": ParamDef((h,), (None,), "ones"),
        "d_skip": ParamDef((h,), (None,), "ones"),
        "dt_bias": ParamDef((h,), (None,), "zeros"),
        "norm": {"scale": ParamDef((d_in,), (None,), "zeros")},
        "out_proj": ParamDef((d_in, d), ("tensor", "fsdp")),
    }


def _pick_chunk(sq: int, chunk: int) -> int:
    """Largest divisor of sq that is <= chunk (production shapes are aligned;
    odd smoke/prompt lengths fall back to smaller chunks, worst case 1)."""
    c = min(chunk, sq)
    while sq % c:
        c -= 1
    return c


class MambaState(NamedTuple):
    conv: jax.Array  # (B, W-1, d_conv) trailing conv inputs
    ssd: jax.Array   # (B, H, P, N) state


def init_mamba_state(cfg, batch: int, dtype=jnp.float32) -> MambaState:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n = s.d_state
    h = d_in // s.head_dim
    return MambaState(
        conv=jnp.zeros((batch, s.conv_width - 1, d_in + 2 * n), dtype),
        ssd=jnp.zeros((batch, h, s.head_dim, n), dtype),
    )


def _split(cfg, zxbcdt):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n = s.d_state
    h = d_in // s.head_dim
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * n]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def _conv(cfg, p, xbc, prepend=None):
    """Causal depthwise conv over time. xbc: (B, S, Dc)."""
    w = p["conv_w"].astype(xbc.dtype)          # (W, Dc)
    width = w.shape[0]
    if prepend is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = prepend.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i:i + xbc.shape[1]] * w[i] for i in range(width)
    ) + p["conv_b"].astype(xbc.dtype)
    return jax.nn.silu(out), xp[:, -(width - 1):]


def _heads(cfg, x_in, b_in, c_in, dt, p):
    s = cfg.ssm
    h = x_in.shape[-1] // s.head_dim
    bsz, sq = x_in.shape[0], x_in.shape[1]
    xh = x_in.reshape(bsz, sq, h, s.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))       # (H,) negative
    return xh, b_in, c_in, dt, a


def mamba_apply(cfg, p, x, return_state=False):
    """x: (B, S, d) -> (B, S, d) (or (y, MambaState) with return_state).
    S must be a multiple of ssm.chunk (or less)."""
    s = cfg.ssm
    bsz, sq, _ = x.shape
    q = _pick_chunk(sq, s.chunk)
    nc = sq // q

    zxbcdt = jnp.einsum("bsd,dt->bst", x, p["in_proj"].astype(x.dtype))
    z, xbc0, dt = _split(cfg, zxbcdt)
    xbc, conv_tail = _conv(cfg, p, xbc0)
    d_in = s.expand * cfg.d_model
    n = s.d_state
    x_in, b_in, c_in = (xbc[..., :d_in], xbc[..., d_in:d_in + n],
                        xbc[..., d_in + n:])
    xh, b_in, c_in, dt, a = _heads(cfg, x_in, b_in, c_in, dt, p)

    f32 = jnp.float32
    xh_c = xh.reshape(bsz, nc, q, -1, s.head_dim).astype(f32)
    b_c = b_in.reshape(bsz, nc, q, n).astype(f32)
    c_c = c_in.reshape(bsz, nc, q, n).astype(f32)
    dt_c = dt.reshape(bsz, nc, q, -1)
    da_c = dt_c * a  # (B, nc, Q, H)

    def chunk_step(h_state, inp):
        xq, bq, cq, dtq, daq = inp  # (B,Q,H,P), (B,Q,N), (B,Q,N), (B,Q,H)
        cum = jnp.cumsum(daq, axis=1)               # (B,Q,H)
        total = cum[:, -1]                          # (B,H)
        # intra-chunk: L_ij = exp(cum_i - cum_j) for i >= j
        diff = cum[:, :, None, :] - cum[:, None, :, :]     # (B,Q,Q,H)
        mask = jnp.tril(jnp.ones((q, q), bool))
        l_mat = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bin,bjn->bij", cq, bq)        # (B,Q,Q)
        w_ij = scores[..., None] * l_mat * dtq[:, None]    # (B,Q,Q,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w_ij, xq)
        # inter-chunk: y_i += C_i . (exp(cum_i) h_prev)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cq, h_state, jnp.exp(cum))
        # state update: h = exp(total) h + sum_j exp(total - cum_j) dt_j B_j x_j
        decay_j = jnp.exp(total[:, None] - cum) * dtq      # (B,Q,H)
        h_new = h_state * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bjh,bjn,bjhp->bhpn", decay_j, bq, xq
        )
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((bsz, xh.shape[2], s.head_dim, n), f32)
    inputs = (
        xh_c.transpose(1, 0, 2, 3, 4),
        b_c.transpose(1, 0, 2, 3),
        c_c.transpose(1, 0, 2, 3),
        dt_c.transpose(1, 0, 2, 3),
        da_c.transpose(1, 0, 2, 3),
    )
    h_fin, ys = jax.lax.scan(chunk_step, h0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, sq, -1, s.head_dim)
    y = y + xh.astype(f32) * p["d_skip"].astype(f32)[:, None]
    y = y.reshape(bsz, sq, d_in).astype(x.dtype)

    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"]["scale"], cfg.norm_eps)
    out = jnp.einsum("bst,td->bsd", y, p["out_proj"].astype(x.dtype))
    if return_state:
        return out, MambaState(conv=conv_tail, ssd=h_fin.astype(x.dtype))
    return out


def mamba_decode(cfg, p, x, state: MambaState):
    """x: (B, 1, d) -> (y, new_state). Exact single-step recurrence."""
    s = cfg.ssm
    bsz = x.shape[0]
    d_in = s.expand * cfg.d_model
    n = s.d_state

    zxbcdt = jnp.einsum("bsd,dt->bst", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt = _split(cfg, zxbcdt)
    xbc, conv_tail = _conv(cfg, p, xbc, prepend=state.conv)
    x_in, b_in, c_in = (xbc[..., :d_in], xbc[..., d_in:d_in + n],
                        xbc[..., d_in + n:])
    xh, b_in, c_in, dt, a = _heads(cfg, x_in, b_in, c_in, dt, p)

    f32 = jnp.float32
    xq = xh[:, 0].astype(f32)         # (B,H,P)
    bq = b_in[:, 0].astype(f32)       # (B,N)
    cq = c_in[:, 0].astype(f32)
    dtq = dt[:, 0]                    # (B,H)
    decay = jnp.exp(dtq * a)          # (B,H)
    h_new = state.ssd * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtq, bq, xq
    )
    y = jnp.einsum("bn,bhpn->bhp", cq, h_new)
    y = y + xq * p["d_skip"].astype(f32)[:, None]
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"]["scale"], cfg.norm_eps)
    out = jnp.einsum("bst,td->bsd", y, p["out_proj"].astype(x.dtype))
    return out, MambaState(conv=conv_tail, ssd=h_new)
