"""Attention: memory-efficient online-softmax ("flash") for train/prefill and
cache attention for decode. Pure JAX (lax control flow) so the multi-pod
dry-run lowers on any backend; GQA, sliding window, logit softcap, causal and
cross (bidirectional) variants.

Two train/prefill implementations:

  * "masked"   — one lax.scan over all KV chunks with a positional mask.
    Simple, but for causal attention executes ~2x the necessary matmul FLOPs
    (the masked upper triangle still burns MXU cycles).
  * "triangle" — q-chunk loop unrolled (static), each q chunk scanning only
    the KV chunks its causal/window footprint actually needs. This is the
    TPU analogue of flash-attention's block skipping and is the default;
    measured in EXPERIMENTS.md §Perf (compute-term reduction ~2x at 32k).

Both keep O(S * chunk) live memory and are exactly equal (tests assert
allclose against a naive softmax oracle).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models.common import softcap as _softcap

NEG_INF = -1e30


def _soft(s, cap):
    return _softcap(s, cap) if cap else s


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    cap: float = 0.0,
    q_offset: int = 0,
    impl: str = "triangle",
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D).

    q_offset: absolute position of q[0] relative to k[0] (chunked prefill)."""
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)

    def _fit(total, chunk):
        c = min(chunk, total)
        while total % c:
            c -= 1
        return c

    q_chunk = _fit(sq, q_chunk)
    kv_chunk = _fit(skv, kv_chunk)
    n_q, n_kv = sq // q_chunk, skv // kv_chunk

    # (B, Hkv, G, S, D) layout
    qh = (q * scale).reshape(b, sq, hkv, g, dh).transpose(0, 2, 3, 1, 4)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)

    kv_pos_c = jnp.arange(kv_chunk)

    def q_chunk_out(ci):
        q_i = jax.lax.dynamic_slice_in_dim(qh, ci * q_chunk, q_chunk, axis=3)
        q_pos = q_offset + ci * q_chunk + jnp.arange(q_chunk)
        if causal:
            hi = min(
                (q_offset + (ci + 1) * q_chunk + kv_chunk - 1) // kv_chunk, n_kv
            )
        else:
            hi = n_kv
        lo = 0
        if window:
            lo = max(0, (q_offset + ci * q_chunk - window) // kv_chunk)
        if impl == "masked":
            lo, hi = 0, n_kv

        def body(carry, j):
            m, l, acc = carry
            k_j = jax.lax.dynamic_slice_in_dim(kh, j * kv_chunk, kv_chunk, axis=2)
            v_j = jax.lax.dynamic_slice_in_dim(vh, j * kv_chunk, kv_chunk, axis=2)
            kv_pos = j * kv_chunk + kv_pos_c
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            if window:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            return _chunk_attn_step_cap(q_i, k_j, v_j, m, l, acc,
                                        mask[None, None, None], cap), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(lo, hi))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    outs = [q_chunk_out(ci) for ci in range(n_q)]
    o = jnp.concatenate(outs, axis=3) if n_q > 1 else outs[0]
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh).astype(q.dtype)


def _chunk_attn_step_cap(q, k, v, m, l, acc, mask, cap):
    s = jnp.einsum("bkgqd,bkcd->bkgqc", q, k, preferred_element_type=jnp.float32)
    s = _soft(s, cap)
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bkgqc,bkcd->bkgqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return m_new, l_new, acc_new


def attention_reference(q, k, v, *, causal=True, window=0, cap=0.0, q_offset=0):
    """Naive softmax oracle (tests only)."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    qh = (q * scale).reshape(b, sq, hkv, g, dh)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qh, k).astype(jnp.float32)
    s = _soft(s, cap)
    q_pos = q_offset + jnp.arange(sq)
    kv_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        mask &= kv_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(b, sq, hq, dh)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
    cap: float = 0.0,
) -> jax.Array:
    """Single-step cache attention.

    q: (B, 1, Hq, D); caches: (B, S_max, Hkv, D); pos: scalar index of the
    current token (its K/V already written). Softmax in f32; masked to
    [max(0, pos-window+1), pos].
    """
    b, _, hq, dh = q.shape
    s_max, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    qh = (q * scale).reshape(b, hkv, g, dh)
    s = jnp.einsum("bkgd,bckd->bkgc", qh, k_cache,
                   preferred_element_type=jnp.float32)
    s = _soft(s, cap)
    idx = jnp.arange(s_max)
    valid = idx <= pos
    if window:
        valid &= idx > pos - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, 1, hq, dh).astype(q.dtype)
