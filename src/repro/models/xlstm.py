"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-parallel
train, O(1)-state decode) and sLSTM (scalar memory with recurrent gate weights,
lax.scan train — inherently sequential, which is exactly why xLSTM pairs a few
of them with many mLSTM blocks).

mLSTM cell (per head, stabilized exponential gating):
    m_t = max(log f_t + m_{t-1}, log i_t)
    C_t = e^{log f + m_{t-1} - m_t} C_{t-1} + e^{log i - m_t} k_t v_t^T
    n_t = (same decays) n_{t-1} + e^{log i - m_t} k_t
    h_t = (C_t^T q_t) / max(|n_t . q_t|, 1)

The chunkwise form carries (C, n, m) across Q-token chunks and evaluates the
intra-chunk part as a masked quadratic with per-pair decays — validated against
the step recurrence (tests/test_models.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, apply_norm, dense_def, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM


def mlstm_defs(cfg):
    d = cfg.d_model
    d_in = 2 * d
    h = cfg.n_heads
    return {
        "up": dense_def(d, 2 * d_in),            # [x_m, z]
        "conv_w": ParamDef((4, d_in), (None, "tensor"), "normal", 0.5),
        "conv_b": ParamDef((d_in,), ("tensor",), "zeros"),
        "wq": dense_def(d_in, d_in),
        "wk": dense_def(d_in, d_in),
        "wv": dense_def(d_in, d_in),
        "w_if": ParamDef((d_in, 2 * h), ("fsdp", None), "normal"),
        "b_if": ParamDef((2 * h,), (None,), "zeros"),
        "norm": {"scale": ParamDef((d_in,), (None,), "zeros")},
        "down": ParamDef((d_in, d), ("tensor", "fsdp")),
    }


def _pick_chunk(sq: int, chunk: int) -> int:
    """Largest divisor of sq that is <= chunk (production shapes are aligned;
    odd smoke/prompt lengths fall back to smaller chunks, worst case 1)."""
    c = min(chunk, sq)
    while sq % c:
        c -= 1
    return c


class MLSTMState(NamedTuple):
    c: jax.Array     # (B, H, Dk, Dv)
    n: jax.Array     # (B, H, Dk)
    m: jax.Array     # (B, H)
    conv: jax.Array  # (B, 3, d_in)


def init_mlstm_state(cfg, batch, dtype=jnp.float32) -> MLSTMState:
    d_in = 2 * cfg.d_model
    h = cfg.n_heads
    hd = d_in // h
    return MLSTMState(
        c=jnp.zeros((batch, h, hd, hd), dtype),
        n=jnp.zeros((batch, h, hd), dtype),
        m=jnp.full((batch, h), -1e30, dtype),
        conv=jnp.zeros((batch, 3, d_in), dtype),
    )


def _mlstm_qkv_gates(cfg, p, x, conv_state=None):
    d_in = 2 * cfg.d_model
    h = cfg.n_heads
    up = jnp.einsum("bsd,dt->bst", x, p["up"].astype(x.dtype))
    x_m, z = up[..., :d_in], up[..., d_in:]
    w = p["conv_w"].astype(x.dtype)
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], width - 1, d_in), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x_m], axis=1)
    xc = sum(xp[:, i:i + x_m.shape[1]] * w[i] for i in range(width))
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))
    b, s, _ = x.shape
    hd = d_in // h
    q = jnp.einsum("bst,tu->bsu", xc, p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = jnp.einsum("bst,tu->bsu", xc, p["wk"].astype(x.dtype)).reshape(b, s, h, hd)
    v = jnp.einsum("bst,tu->bsu", x_m, p["wv"].astype(x.dtype)).reshape(b, s, h, hd)
    k = k / jnp.sqrt(jnp.asarray(hd, x.dtype))
    gates = jnp.einsum("bst,tg->bsg", xc, p["w_if"].astype(x.dtype)) + p[
        "b_if"
    ].astype(x.dtype)
    log_i = gates[..., :h].astype(jnp.float32)
    log_f = -jax.nn.softplus(-gates[..., h:].astype(jnp.float32))  # log sigmoid
    return q, k, v, z, log_i, log_f, xp[:, -(width - 1):]


def mlstm_apply(cfg, p, x, return_state=False):
    """Chunkwise-parallel mLSTM. x: (B, S, d)."""
    s_cfg = cfg.ssm
    b, sq, d = x.shape
    qun = _pick_chunk(sq, s_cfg.chunk)
    nc = sq // qun
    h = cfg.n_heads
    q, k, v, z, log_i, log_f, conv_tail = _mlstm_qkv_gates(cfg, p, x)
    hd = q.shape[-1]
    f32 = jnp.float32

    def resh(t):
        return t.reshape(b, nc, qun, h, -1).transpose(1, 0, 3, 2, 4).astype(f32)

    qc, kc, vc = resh(q), resh(k), resh(v)               # (nc, B, H, Q, hd)
    li = log_i.reshape(b, nc, qun, h).transpose(1, 0, 3, 2)   # (nc, B, H, Q)
    lf = log_f.reshape(b, nc, qun, h).transpose(1, 0, 3, 2)

    neg = jnp.float32(-1e30)
    tri = jnp.tril(jnp.ones((qun, qun), bool))

    def chunk(carry, inp):
        c_st, n_st, m_st = carry
        qq, kk, vv, lii, lff = inp
        fcum = jnp.cumsum(lff, axis=-1)                  # (B,H,Q)
        total = fcum[..., -1:]                           # (B,H,1)
        # log decay D_ij = fcum_i - fcum_j + li_j  (j <= i)
        dmat = fcum[..., :, None] - fcum[..., None, :] + lii[..., None, :]
        dmat = jnp.where(tri, dmat, neg)
        # inter path log scale: fcum_i + m_prev
        inter_log = fcum + m_st[..., None]               # (B,H,Q)
        m_i = jnp.maximum(jnp.max(dmat, axis=-1), inter_log)
        m_i = jnp.maximum(m_i, -m_i * 0 - 80.0)          # floor for stability
        w_intra = jnp.exp(dmat - m_i[..., None])          # (B,H,Q,Q)
        w_inter = jnp.exp(inter_log - m_i)               # (B,H,Q)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qq, kk) * w_intra
        num = jnp.einsum("bhqk,bhkd->bhqd", scores, vv) + jnp.einsum(
            "bhqd,bhde,bhq->bhqe", qq, c_st, w_inter
        )
        den = jnp.einsum("bhqk->bhq", scores) + jnp.einsum(
            "bhqd,bhd,bhq->bhq", qq, n_st, w_inter
        )
        hh = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # carry update
        m_new = jnp.maximum(m_st + total[..., 0],
                            jnp.max(total - fcum + lii, axis=-1))
        sc_prev = jnp.exp(m_st + total[..., 0] - m_new)   # (B,H)
        sc_j = jnp.exp(total - fcum + lii - m_new[..., None])  # (B,H,Q)
        c_new = c_st * sc_prev[..., None, None] + jnp.einsum(
            "bhq,bhqd,bhqe->bhde", sc_j, kk, vv
        )
        n_new = n_st * sc_prev[..., None] + jnp.einsum("bhq,bhqd->bhd", sc_j, kk)
        return (c_new, n_new, m_new), hh

    c0 = jnp.zeros((b, h, hd, hd), f32)
    n0 = jnp.zeros((b, h, hd), f32)
    m0 = jnp.full((b, h), -1e30, f32)
    (c_f, n_f, m_f), hs = jax.lax.scan(chunk, (c0, n0, m0), (qc, kc, vc, li, lf))
    y = hs.transpose(1, 0, 3, 2, 4).reshape(b, sq, -1).astype(x.dtype)
    y = rmsnorm(y, p["norm"]["scale"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bst,td->bsd", y, p["down"].astype(x.dtype))
    if return_state:
        return out, MLSTMState(c=c_f, n=n_f, m=m_f, conv=conv_tail)
    return out


def mlstm_decode(cfg, p, x, st: MLSTMState):
    """Single-token step. x: (B, 1, d)."""
    q, k, v, z, log_i, log_f, conv_tail = _mlstm_qkv_gates(
        cfg, p, x, conv_state=st.conv
    )
    f32 = jnp.float32
    qq = q[:, 0].astype(f32)   # (B,H,hd)
    kk = k[:, 0].astype(f32)
    vv = v[:, 0].astype(f32)
    li = log_i[:, 0]           # (B,H)
    lf = log_f[:, 0]
    m_new = jnp.maximum(lf + st.m, li)
    f_sc = jnp.exp(lf + st.m - m_new)
    i_sc = jnp.exp(li - m_new)
    c_new = st.c * f_sc[..., None, None] + i_sc[..., None, None] * (
        kk[..., :, None] * vv[..., None, :]
    )
    n_new = st.n * f_sc[..., None] + i_sc[..., None] * kk
    num = jnp.einsum("bhd,bhde->bhe", qq, c_new)
    den = jnp.einsum("bhd,bhd->bh", qq, n_new)
    hh = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    b = x.shape[0]
    y = hh.reshape(b, 1, -1).astype(x.dtype)
    y = rmsnorm(y, p["norm"]["scale"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bst,td->bsd", y, p["down"].astype(x.dtype))
    return out, MLSTMState(c=c_new, n=n_new, m=m_new, conv=conv_tail)


# ---------------------------------------------------------------------------
# sLSTM


def slstm_defs(cfg):
    d = cfg.d_model
    h = d // cfg.ssm.slstm_head_dim
    hd = cfg.ssm.slstm_head_dim
    ff = -(-4 * d // 3 // 128) * 128
    return {
        "w": dense_def(d, 4 * d),
        "r": ParamDef((h, hd, 4 * hd), (None, None, None), "normal"),
        "b": ParamDef((4 * d,), (None,), "zeros"),
        "gn": {"scale": ParamDef((d,), (None,), "zeros")},
        "out": dense_def(d, d),
        "ff_gate": dense_def(d, ff),
        "ff_up": dense_def(d, ff),
        "ff_down": ParamDef((ff, d), ("tensor", "fsdp")),
    }


class SLSTMState(NamedTuple):
    h: jax.Array  # (B, d)
    c: jax.Array  # (B, d)
    n: jax.Array  # (B, d)
    m: jax.Array  # (B, d)


def init_slstm_state(cfg, batch, dtype=jnp.float32) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), dtype)
    return SLSTMState(h=z, c=z, n=z, m=jnp.full((batch, d), -1e30, dtype))


def _slstm_cell(cfg, p, wx_t, st: SLSTMState):
    """One step. wx_t: (B, 4d) precomputed input projection."""
    d = cfg.d_model
    hd = cfg.ssm.slstm_head_dim
    nh = d // hd
    b = wx_t.shape[0]
    hh = st.h.reshape(b, nh, hd).astype(jnp.float32)
    rec = jnp.einsum("bnk,nkg->bng", hh, p["r"].astype(jnp.float32))
    rec = rec.reshape(b, nh, 4, hd).transpose(0, 2, 1, 3).reshape(b, 4 * d)
    g = wx_t.astype(jnp.float32) + rec + p["b"].astype(jnp.float32)
    zi, ii, ff, oo = jnp.split(g, 4, axis=-1)
    log_f = -jax.nn.softplus(-ff)
    m_new = jnp.maximum(log_f + st.m, ii)
    i_sc = jnp.exp(ii - m_new)
    f_sc = jnp.exp(log_f + st.m - m_new)
    c_new = f_sc * st.c + i_sc * jnp.tanh(zi)
    n_new = f_sc * st.n + i_sc
    h_new = jax.nn.sigmoid(oo) * c_new / jnp.maximum(n_new, 1.0)
    return SLSTMState(h=h_new, c=c_new, n=n_new, m=m_new)


def slstm_apply(cfg, p, x, return_state=False):
    """x: (B, S, d) -> (B, S, d) via lax.scan over time."""
    b, s, d = x.shape
    wx = jnp.einsum("bsd,dg->bsg", x, p["w"].astype(x.dtype))
    st0 = init_slstm_state(cfg, b)

    def step(st, wx_t):
        st = _slstm_cell(cfg, p, wx_t, st)
        return st, st.h

    st_f, hs = jax.lax.scan(step, st0, wx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    y = rmsnorm(y, p["gn"]["scale"], cfg.norm_eps)
    y = jnp.einsum("bsd,de->bse", y, p["out"].astype(x.dtype))
    if return_state:
        return y, st_f
    return y


def slstm_ffn(cfg, p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["ff_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["ff_up"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                      p["ff_down"].astype(x.dtype))


def slstm_decode(cfg, p, x, st: SLSTMState):
    wx = jnp.einsum("bsd,dg->bsg", x, p["w"].astype(x.dtype))
    st = _slstm_cell(cfg, p, wx[:, 0], st)
    y = st.h[:, None].astype(x.dtype)
    y = rmsnorm(y, p["gn"]["scale"], cfg.norm_eps)
    y = jnp.einsum("bsd,de->bse", y, p["out"].astype(x.dtype))
    return y, st
