"""Model zoo: composable JAX definitions for the assigned architectures."""
from repro.models import model
from repro.models.model import (
    DecodeCache,
    decode_step,
    forward_train,
    init,
    init_cache,
    param_defs,
    prefill,
    specs,
)

__all__ = [k for k in dir() if not k.startswith("_")]
