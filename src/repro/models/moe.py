"""Mixture-of-Experts FFN.

Train/prefill: GShard-style *grouped, sort-based capacity dispatch*. Each
sequence (batch row) is a dispatch group, so routing, sorting and the
scatter/gather stay local to the data shard that owns the row — no global
all-token sort and no all-to-all in the baseline layout (expert weights are
FSDP-stored over `data` and tensor-sharded over `model` on d_ff, gathered per
layer like every other weight). Tokens beyond an expert's capacity
C = ceil(S * top_k / E * capacity_factor) are dropped for the routed path
(shared experts still process them).

Decode (S = 1): capacity dispatch would compute every expert for every token;
instead gather the top-k experts' weights per token and do batched GEMVs —
FLOPs = B * k * 3 d f, the MoE ideal.

Aux outputs: GShard load-balance loss + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, constrain, dense_def


def moe_defs(cfg):
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_d_ff, m.n_experts
    out = {
        "router": ParamDef((d, e), ("fsdp", None), "normal"),
        "experts": {
            "w_gate": ParamDef((e, d, f), (None, "fsdp", "tensor")),
            "w_up": ParamDef((e, d, f), (None, "fsdp", "tensor")),
            "w_down": ParamDef((e, f, d), (None, "tensor", "fsdp")),
        },
    }
    if m.n_shared:
        fs = m.n_shared * f
        out["shared"] = {
            "w_gate": dense_def(d, fs),
            "w_up": dense_def(d, fs),
            "w_down": ParamDef((fs, d), ("tensor", "fsdp")),
        }
        if m.shared_gate:
            out["shared_gate"] = ParamDef((d, 1), ("fsdp", None), "normal")
    return out


def _route(cfg, p, x):
    """x: (..., d) -> (weights (..., k), experts (..., k), aux)."""
    m = cfg.moe
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, m.top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    # aux: load balance (fraction dispatched x mean prob x E) + z-loss
    e = m.n_experts
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(tope, e, dtype=jnp.float32), axis=tuple(range(tope.ndim - 1))
    ).sum(0)  # (E,) mean over tokens and k
    prob_frac = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = e * jnp.sum(dispatch_frac * prob_frac) * m.aux_coef
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_coef
    return topw, tope, aux + z


def _dispatch_group(x, tope, topw, e, cap):
    """One group (sequence). x: (S, d); tope/topw: (S, k).
    Returns (buf (E*C, d), slot (S*k,), token (S*k,), weight (S*k,))."""
    s, k = tope.shape
    flat_e = tope.reshape(-1)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e)                      # stable
    se = flat_e[order]
    st = order // k
    sw = flat_w[order]
    ones = jnp.ones_like(se, jnp.int32)
    counts = jax.ops.segment_sum(ones, se, num_segments=e)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(s * k, dtype=jnp.int32) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)  # OOB -> dropped
    buf = jnp.zeros((e * cap, x.shape[-1]), x.dtype)
    buf = buf.at[slot].set(x[st], mode="drop")
    return buf, slot, st, jnp.where(keep, sw, 0.0)


def moe_apply(cfg, p, x, mesh):
    """x: (B, S, d) -> (y, aux). S == 1 takes the decode fast path."""
    m = cfg.moe
    if x.shape[1] == 1:
        return _moe_decode(cfg, p, x), jnp.float32(0.0)
    b, s, d = x.shape
    e, k, f = m.n_experts, m.top_k, m.expert_d_ff
    cap = int(-(-s * k // e) * m.capacity_factor)
    x = constrain(x, mesh, "batch", None, None)
    topw, tope, aux = _route(cfg, p, x)
    tope = constrain(tope, mesh, "batch", None, None)
    topw = constrain(topw, mesh, "batch", None, None)

    # The whole dispatch -> expert GEMM -> combine pipeline is batch-sharded;
    # without these constraints GSPMD replicates the (B, E, C, d) buffers
    # (26 GB/chip for qwen2-moe at 4k x 256).
    buf, slot, st, sw = jax.vmap(
        lambda xr, er, wr: _dispatch_group(xr, er, wr, e, cap)
    )(x, tope, topw)
    buf = buf.reshape(b, e, cap, d)
    buf = constrain(buf, mesh, "batch", None, None, None)
    slot = constrain(slot, mesh, "batch", None)
    st = constrain(st, mesh, "batch", None)
    sw = constrain(sw, mesh, "batch", None)

    dt = x.dtype
    pe = p["experts"]
    g = jnp.einsum("becd,edf->becf", buf, pe["w_gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", buf, pe["w_up"].astype(dt))
    g = constrain(g, mesh, "batch", None, None, "tensor")
    u = constrain(u, mesh, "batch", None, None, "tensor")
    act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    h = jnp.einsum("becf,efd->becd", act * u, pe["w_down"].astype(dt))
    h = h.reshape(b, e * cap, d)
    h = constrain(h, mesh, "batch", None, None)

    def gather_group(hr, slot_r, st_r, sw_r):
        y = hr[jnp.minimum(slot_r, e * cap - 1)] * sw_r[:, None].astype(hr.dtype)
        y = jnp.where((slot_r < e * cap)[:, None], y, 0.0)
        return jnp.zeros((s, d), hr.dtype).at[st_r].add(y)

    y = jax.vmap(gather_group)(h, slot, st, sw)
    y = constrain(y, mesh, "batch", None, None)
    y = y + _shared_experts(cfg, p, x)
    return y, aux


def _shared_experts(cfg, p, x):
    m = cfg.moe
    if not m.n_shared:
        return jnp.zeros_like(x)
    dt = x.dtype
    ps = p["shared"]
    g = jnp.einsum("bsd,df->bsf", x, ps["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, ps["w_up"].astype(dt))
    act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    y = jnp.einsum("bsf,fd->bsd", act * u, ps["w_down"].astype(dt))
    if m.shared_gate:
        gate = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", x.astype(jnp.float32),
                       p["shared_gate"].astype(jnp.float32))
        )
        y = y * gate.astype(dt)
    return y


def _moe_decode(cfg, p, x):
    """x: (B, 1, d): gather the top-k experts' weights per token (no capacity
    machinery, no dropped tokens, FLOPs = B k 3 d f)."""
    m = cfg.moe
    b, _, d = x.shape
    xt = x[:, 0]
    topw, tope, _ = _route(cfg, p, xt)          # (B, k)
    dt = x.dtype
    pe = p["experts"]
    wg = jnp.take(pe["w_gate"], tope, axis=0).astype(dt)   # (B, k, d, f)
    wu = jnp.take(pe["w_up"], tope, axis=0).astype(dt)
    wd = jnp.take(pe["w_down"], tope, axis=0).astype(dt)   # (B, k, f, d)
    g = jnp.einsum("bd,bkdf->bkf", xt, wg)
    u = jnp.einsum("bd,bkdf->bkf", xt, wu)
    act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    y = jnp.einsum("bkf,bkfd,bk->bd", act * u, wd, topw.astype(dt))
    return (y + _shared_experts(cfg, p, x)[:, 0])[:, None]
