"""Transformer blocks and scanned stacks (dense / enc-dec / MoE / VLM-prefix).

Layer parameters are stacked along a leading dim and consumed by `lax.scan`
(small HLO, fast multi-hundred-layer compiles); activation checkpointing wraps
the scan body. The residual stream is sequence-sharded between blocks
("seq" -> model axis) so per-chip activation memory is S/|model| even at
global-batch 256 x 4k.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models.attention import decode_attention, flash_attention
from repro.models.common import (
    ParamDef,
    apply_norm,
    constrain,
    dense_def,
    norm_defs,
    pad_vocab,
    rope,
    sinusoid_pos,
    softcap,
    stack,
)


# ---------------------------------------------------------------------------
# attention sublayer


def attn_defs(cfg: ModelConfig, lora_rank: int = 0):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    out = {
        "wq": dense_def(d, hq * hd),
        "wk": dense_def(d, hkv * hd),
        "wv": dense_def(d, hkv * hd),
        "wo": ParamDef((hq * hd, d), ("tensor", "fsdp"), "normal", 1.0),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamDef((hq * hd,), ("tensor",), "zeros")
        out["bk"] = ParamDef((hkv * hd,), ("tensor",), "zeros")
        out["bv"] = ParamDef((hkv * hd,), ("tensor",), "zeros")
    if lora_rank:
        for nm, do in (("q", hq * hd), ("k", hkv * hd), ("v", hkv * hd)):
            out[f"lora_a_{nm}"] = ParamDef((d, lora_rank), ("fsdp", None))
            out[f"lora_b_{nm}"] = ParamDef((lora_rank, do), (None, "tensor"), "zeros")
    return out


def _proj(p, x, name, bias_name=None, lora=None):
    y = jnp.einsum("bsd,df->bsf", x, p[name].astype(x.dtype))
    if bias_name and bias_name in p:
        y = y + p[bias_name].astype(x.dtype)
    if lora is not None and f"lora_a_{lora}" in p:
        y = y + jnp.einsum(
            "bsd,dr,rf->bsf",
            x,
            p[f"lora_a_{lora}"].astype(x.dtype),
            p[f"lora_b_{lora}"].astype(x.dtype),
        )
    return y


def qkv(cfg, p, x, kv_x, positions, *, use_rope=True, lora=False, mesh=None):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    lq = "q" if lora else None
    q = _proj(p, x, "wq", "bq", lq).reshape(b, s, hq, hd)
    k = _proj(p, kv_x, "wk", "bk", "k" if lora else None)
    v = _proj(p, kv_x, "wv", "bv", "v" if lora else None)
    skv = kv_x.shape[1]
    k = k.reshape(b, skv, hkv, hd)
    v = v.reshape(b, skv, hkv, hd)
    if use_rope and cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta)
        kv_positions = positions if kv_x is x else jnp.arange(skv)
        k = rope(k, kv_positions, cfg.rope_theta)
    # Keep attention activations head-sharded (TP); without the constraint
    # GSPMD tends to replicate q/k/v after rope's transposes.
    q = constrain(q, mesh, "batch", None, "heads", None)
    k = constrain(k, mesh, "batch", None, "heads", None)
    v = constrain(v, mesh, "batch", None, "heads", None)
    return q, k, v


def self_attn(cfg, p, x, positions, mesh, *, causal=True, window=0,
              impl="triangle", q_offset=0, lora=False):
    q, k, v = qkv(cfg, p, x, x, positions, lora=lora, mesh=mesh)
    o = flash_attention(
        q, k, v, causal=causal, window=window, cap=cfg.attn_softcap,
        q_offset=q_offset, impl=impl,
    )
    b, s, _, _ = o.shape
    o = constrain(o, mesh, "batch", None, "heads", None)
    return jnp.einsum(
        "bsf,fd->bsd", o.reshape(b, s, -1), p["wo"].astype(x.dtype)
    )


def self_attn_decode(cfg, p, x, pos, k_cache, v_cache, *, window=0, lora=False):
    """x: (B, 1, d). Returns (out, k_cache, v_cache) with the new KV written."""
    b = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    lq = "q" if lora else None
    q = _proj(p, x, "wq", "bq", lq).reshape(b, 1, hq, hd)
    k = _proj(p, x, "wk", "bk", "k" if lora else None).reshape(b, 1, hkv, hd)
    v = _proj(p, x, "wv", "bv", "v" if lora else None).reshape(b, 1, hkv, hd)
    if cfg.pos == "rope":
        pos_arr = pos[None] if pos.ndim == 0 else pos
        q = rope(q, pos_arr, cfg.rope_theta)
        k = rope(k, pos_arr, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), pos, axis=1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), pos, axis=1
    )
    o = decode_attention(q, k_cache, v_cache, pos, window=window,
                         cap=cfg.attn_softcap)
    out = jnp.einsum("bsf,fd->bsd", o.reshape(b, 1, -1), p["wo"].astype(x.dtype))
    return out, k_cache, v_cache


def cross_attn(cfg, p, x, enc_kv, mesh):
    """enc_kv: precomputed (k, v) each (B, S_enc, Hkv, D) (cached at prefill)."""
    b, s, _ = x.shape
    hq, hd = cfg.n_heads, cfg.hd
    q = _proj(p, x, "wq", "bq").reshape(b, s, hq, hd)
    k, v = enc_kv
    o = flash_attention(q, k, v, causal=False, impl="masked",
                        cap=cfg.attn_softcap)
    return jnp.einsum("bsf,fd->bsd", o.reshape(b, s, -1), p["wo"].astype(x.dtype))


def cross_kv(cfg, p, enc_out):
    b, s, _ = enc_out.shape
    k = _proj(p, enc_out, "wk", "bk").reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = _proj(p, enc_out, "wv", "bv").reshape(b, s, cfg.n_kv_heads, cfg.hd)
    return k, v


# ---------------------------------------------------------------------------
# MLP sublayer


def mlp_defs(cfg: ModelConfig, d: int, f: int, lora_rank: int = 0):
    if cfg.act == "gelu_mlp":
        out = {"w_in": dense_def(d, f), "w_out": ParamDef((f, d), ("tensor", "fsdp"))}
        if cfg.qkv_bias:
            out["b_in"] = ParamDef((f,), ("tensor",), "zeros")
            out["b_out"] = ParamDef((d,), (None,), "zeros")
        return out
    out = {
        "w_gate": dense_def(d, f),
        "w_up": dense_def(d, f),
        "w_down": ParamDef((f, d), ("tensor", "fsdp")),
    }
    if lora_rank:
        out["lora_a_g"] = ParamDef((d, lora_rank), ("fsdp", None))
        out["lora_b_g"] = ParamDef((lora_rank, f), (None, "tensor"), "zeros")
    return out


def mlp_apply(cfg, p, x, lora=False):
    dt = x.dtype
    if cfg.act == "gelu_mlp":
        h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(dt))
        if "b_in" in p:
            h = h + p["b_in"].astype(dt)
        h = jax.nn.gelu(h)
        y = jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(dt))
        if "b_out" in p:
            y = y + p["b_out"].astype(dt)
        return y
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
    if lora and "lora_a_g" in p:
        g = g + jnp.einsum("bsd,dr,rf->bsf", x, p["lora_a_g"].astype(dt),
                           p["lora_b_g"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    return jnp.einsum("bsf,fd->bsd", act * u, p["w_down"].astype(dt))


# ---------------------------------------------------------------------------
# block


def block_defs(cfg: ModelConfig, *, cross: bool = False, lora_rank: int = 0):
    d = cfg.d_model
    out = {
        "ln1": norm_defs(cfg, d),
        "attn": attn_defs(cfg, lora_rank),
        "ln2": norm_defs(cfg, d),
    }
    if cfg.moe is not None:
        out["moe"] = moe_mod.moe_defs(cfg)
    else:
        out["mlp"] = mlp_defs(cfg, d, cfg.d_ff, lora_rank)
    if cfg.sandwich_norm:
        out["ln1_post"] = norm_defs(cfg, d)
        out["ln2_post"] = norm_defs(cfg, d)
    if cross:
        out["lnx"] = norm_defs(cfg, d)
        out["xattn"] = attn_defs(cfg)
    return out


def block_apply(cfg, p, x, positions, mesh, *, causal=True, window=0,
                impl="triangle", q_offset=0, enc_out=None, lora=False):
    """Returns (x, aux_loss). enc_out: encoder output for cross-attention
    (per-layer K/V projections are computed in-block, inside the layer scan)."""
    x = constrain(x, mesh, "batch", "seq", None)
    h = apply_norm(cfg, p["ln1"], x)
    a = self_attn(cfg, p["attn"], h, positions, mesh, causal=causal,
                  window=window, impl=impl, q_offset=q_offset, lora=lora)
    if cfg.sandwich_norm:
        a = apply_norm(cfg, p["ln1_post"], a)
    x = x + a
    if enc_out is not None:
        h = apply_norm(cfg, p["lnx"], x)
        kv = cross_kv(cfg, p["xattn"], enc_out)
        x = x + cross_attn(cfg, p["xattn"], h, kv, mesh)
    x = constrain(x, mesh, "batch", "seq", None)
    h = apply_norm(cfg, p["ln2"], x)
    aux = jnp.float32(0.0)
    if cfg.moe is not None:
        f, aux = moe_mod.moe_apply(cfg, p["moe"], h, mesh)
    else:
        f = mlp_apply(cfg, p["mlp"], h, lora=lora)
    if cfg.sandwich_norm:
        f = apply_norm(cfg, p["ln2_post"], f)
    return x + f, aux


def block_decode(cfg, p, x, pos, k_cache, v_cache, *, window=0, enc_kv=None,
                 lora=False):
    h = apply_norm(cfg, p["ln1"], x)
    a, k_cache, v_cache = self_attn_decode(
        cfg, p["attn"], h, pos, k_cache, v_cache, window=window, lora=lora
    )
    if cfg.sandwich_norm:
        a = apply_norm(cfg, p["ln1_post"], a)
    x = x + a
    if enc_kv is not None:
        h = apply_norm(cfg, p["lnx"], x)
        x = x + cross_attn(cfg, p["xattn"], h, enc_kv, None)
    h = apply_norm(cfg, p["ln2"], x)
    if cfg.moe is not None:
        f, _ = moe_mod.moe_apply(cfg, p["moe"], h, None)
    else:
        f = mlp_apply(cfg, p["mlp"], h, lora=lora)
    if cfg.sandwich_norm:
        f = apply_norm(cfg, p["ln2_post"], f)
    return x + f, k_cache, v_cache


# ---------------------------------------------------------------------------
# scanned stacks

def _maybe_remat(cfg, fn):
    if cfg.remat in ("block", "inner"):
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


def dense_stack_defs(cfg: ModelConfig, *, cross: bool = False):
    """Decoder stack. gemma2-style local/global alternation packs layer pairs."""
    if cfg.local_global:
        assert cfg.n_layers % 2 == 0
        pair = {"local": block_defs(cfg, cross=cross),
                "global": block_defs(cfg, cross=cross)}
        return stack(cfg.n_layers // 2, pair)
    return stack(cfg.n_layers, block_defs(cfg, cross=cross))


def dense_stack_apply(cfg, stacked, x, positions, mesh, *, causal=True,
                      impl="triangle", q_offset=0, enc_out=None):
    """Scan the stacked blocks; returns (x, total_aux)."""

    if cfg.local_global:
        def body(carry, p):
            h, aux = carry
            h, a1 = block_apply(cfg, p["local"], h, positions, mesh,
                                causal=causal, window=cfg.window, impl=impl,
                                q_offset=q_offset, enc_out=enc_out)
            h, a2 = block_apply(cfg, p["global"], h, positions, mesh,
                                causal=causal, window=0, impl=impl,
                                q_offset=q_offset, enc_out=enc_out)
            return (h, aux + a1 + a2), None
    else:
        def body(carry, p):
            h, aux = carry
            h, a = block_apply(cfg, p, h, positions, mesh, causal=causal,
                               window=cfg.window, impl=impl,
                               q_offset=q_offset, enc_out=enc_out)
            return (h, aux + a), None

    (x, aux), _ = jax.lax.scan(_maybe_remat(cfg, body), (x, jnp.float32(0.0)),
                               stacked)
    return x, aux


def dense_stack_decode(cfg, stacked, x, pos, cache_k, cache_v, *, enc_kv=None):
    """cache_k/v: (L, B, S_max, Hkv, D) (or (L/2, 2, ...) packed for gemma2 —
    handled by treating the pair dim as part of the scan xs)."""

    if cfg.local_global:
        def body(h, xs):
            p, kc, vc = xs
            h, k1, v1 = block_decode(cfg, p["local"], h, pos, kc[0], vc[0],
                                     window=cfg.window, enc_kv=enc_kv)
            h, k2, v2 = block_decode(cfg, p["global"], h, pos, kc[1], vc[1],
                                     window=0, enc_kv=enc_kv)
            return h, (jnp.stack([k1, k2]), jnp.stack([v1, v2]))
    else:
        def body(h, xs):
            p, kc, vc = xs
            h, kc, vc = block_decode(cfg, p, h, pos, kc, vc,
                                     window=cfg.window, enc_kv=enc_kv)
            return h, (kc, vc)

    x, (cache_k, cache_v) = jax.lax.scan(body, x, (stacked, cache_k, cache_v))
    return x, cache_k, cache_v


# ---------------------------------------------------------------------------
# embeddings / head


def embed_defs(cfg: ModelConfig, max_seq: int):
    vp = pad_vocab(cfg.vocab)
    out = {"tok": ParamDef((vp, cfg.d_model), ("tensor", "fsdp"), "embed", 0.02)}
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamDef((cfg.d_model, vp), ("fsdp", "tensor"), "normal")
    if cfg.pos == "learned":
        out["pos"] = ParamDef((max_seq, cfg.d_model), (None, "fsdp"), "embed", 0.02)
    out["ln_f"] = norm_defs(cfg, cfg.d_model)
    return out


def embed_apply(cfg, p, tokens, dtype):
    e = jnp.take(p["tok"].astype(dtype), tokens, axis=0)
    if cfg.name.startswith("gemma"):
        e = e * jnp.asarray(cfg.d_model ** 0.5, dtype)
    return e


def logits_apply(cfg, p, x):
    head = p["lm_head"] if "lm_head" in p else p["tok"].T
    x = apply_norm(cfg, p["ln_f"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)
