"""In-house optimizers (no optax dependency): AdamW and Adafactor.

Both are pytree->pytree transforms whose states inherit the parameter
shardings under pjit (elementwise states) — Adafactor's factored second moment
keeps optimizer memory O(rows+cols), which is what lets grok-1 (314B) train on
a 256-chip v5e pod with FSDPxTP sharding.
"""
from repro.optim.optimizers import (
    Optimizer,
    OptState,
    adafactor,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
)

__all__ = [k for k in dir() if not k.startswith("_")]
