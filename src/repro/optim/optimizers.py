"""AdamW + Adafactor, schedules, and global-norm clipping."""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

OptState = Any


class Optimizer(NamedTuple):
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any, jax.Array], tuple[Any, OptState, dict]]
    # update(grads, state, params, step) -> (new_params, new_state, metrics)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          clip_norm=1.0) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z)}

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state["v"], grads)

        def upd(p, m_, v_):
            u = (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v}, {"gnorm": gnorm, "lr": lr}

    return Optimizer(init=init, update=update)


def _factored_dims(shape):
    """Adafactor factors the two largest trailing dims of >=2-D params."""
    if len(shape) < 2:
        return None
    return len(shape) - 2, len(shape) - 1


def adafactor(lr_fn, decay=0.99, eps=1e-30, clip_norm=1.0,
              weight_decay=0.0) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern, 2018), beta1 = 0."""

    def init(params):
        def st(p):
            f = _factored_dims(p.shape)
            if f is None:
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            r, c = f
            # vr accumulates row means (reduce over c); vc column means
            # (reduce over r).
            vr = jnp.zeros(p.shape[:c] + p.shape[c + 1:], jnp.float32)
            vc = jnp.zeros(p.shape[:r] + p.shape[r + 1:], jnp.float32)
            return {"vr": vr, "vc": vc}

        return {"s": jax.tree.map(st, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray))}

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        lr = lr_fn(step)

        def upd(p, g, s):
            g2 = g * g + eps
            f = _factored_dims(p.shape)
            if f is None:
                v = decay * s["v"] + (1 - decay) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            else:
                r, c = f
                vr = decay * s["vr"] + (1 - decay) * jnp.mean(g2, axis=c)
                vc = decay * s["vc"] + (1 - decay) * jnp.mean(g2, axis=r)
                mean_r = jnp.mean(vr, axis=-1, keepdims=True)
                pre_r = jax.lax.rsqrt(
                    jnp.expand_dims(vr / jnp.maximum(mean_r, eps), c)
                )
                pre_c = jax.lax.rsqrt(jnp.expand_dims(vc, r))
                u = g * pre_r * pre_c
                new_s = {"vr": vr, "vc": vc}
            # update clipping (RMS <= 1) as in the paper
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

        flat_p, td = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_s = td.flatten_up_to(state["s"])
        new_p, new_s = [], []
        for p, g, s in zip(flat_p, flat_g, flat_s):
            np_, ns = upd(p, g, s)
            new_p.append(np_)
            new_s.append(ns)
        return (jax.tree.unflatten(td, new_p),
                {"s": jax.tree.unflatten(td, new_s)},
                {"gnorm": gnorm, "lr": lr})

    return Optimizer(init=init, update=update)


def make_optimizer(name: str, lr_fn=None, **kw) -> Optimizer:
    lr_fn = lr_fn or cosine_schedule(3e-4, 100, 10_000)
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    raise ValueError(name)
