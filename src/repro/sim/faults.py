"""Hostile signal ecosystems: fault generators for the simulator.

The clean-Poisson simulator assumes one always-on CIS channel per page.
Real change-indicating feeds are an ecosystem: sitemaps, CDN purge pings,
webhook notifications — each with its own recall, false-positive rate, and
delivery delay, each of which can go dark for hours. This module provides
the host-side (numpy) machinery to model that:

- `ChannelSpec` / `assign_channels` / `route_through_channels`: per-source
  signal channels mixed across the page population, with per-channel
  delivery delay and scheduled outages.
- `OutageSchedule`: per-channel on/off windows. Signals generated while a
  channel is out are *lost*, not queued — a dead sitemap never
  retro-delivers, which is exactly why silence is ambiguous.
- `hawkes_change_counts`: bursty self-exciting (discretized exponential
  kernel Hawkes) change processes.
- `flash_crowd_profile`: request-surge multipliers for mu / bandwidth.
- `FaultPlan` + `FeedFaultInjector` / `OutcomeFaultInjector`: drop, delay,
  duplicate, and reorder feed rows and outcome-echo batches on their way
  into `run_rounds`.

Everything here is deterministic given an explicit `numpy.random.Generator`
or a declarative plan, so property tests and the scenario-grid benchmark
replay identically.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Per-source signal channels
# ---------------------------------------------------------------------------


class ChannelSpec(NamedTuple):
    """One signal source. Scales are multipliers on the page's base (lam, nu);
    delay is delivery lag in scheduler rounds."""

    name: str
    lam_scale: float = 1.0
    nu_scale: float = 1.0
    delay_rounds: int = 0


#: A representative three-source ecosystem: sitemaps are high-recall and
#: clean but not instant to re-fetch; CDN purge events are prompt but
#: noisier; third-party pings are weak recall and false-positive heavy.
DEFAULT_CHANNELS: Tuple[ChannelSpec, ...] = (
    ChannelSpec("sitemap", lam_scale=1.0, nu_scale=0.3, delay_rounds=0),
    ChannelSpec("cdn", lam_scale=0.7, nu_scale=1.0, delay_rounds=1),
    ChannelSpec("ping", lam_scale=0.4, nu_scale=1.6, delay_rounds=2),
)


def assign_channels(
    m: int,
    n_channels: int,
    span: int = 1,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Assign each page a channel id in [0, n_channels).

    With `span > 1`, channels are contiguous runs of `span` pages — sites
    cluster on one feed technology, and aligning `span` to the selection
    block size makes outages block-coherent (the granularity the on-device
    watchdog detects). With `rng`, assignment is an i.i.d. shuffle instead.
    """
    if rng is not None:
        return rng.integers(0, n_channels, size=m).astype(np.int32)
    return ((np.arange(m) // max(span, 1)) % n_channels).astype(np.int32)


def channel_rates(
    lam: np.ndarray,
    nu: np.ndarray,
    channels: np.ndarray,
    specs: Sequence[ChannelSpec] = DEFAULT_CHANNELS,
) -> Tuple[np.ndarray, np.ndarray]:
    """Effective per-page (lam, nu) after channel quality scaling."""
    lam_s = np.asarray([s.lam_scale for s in specs], np.float64)
    nu_s = np.asarray([s.nu_scale for s in specs], np.float64)
    lam_eff = np.clip(np.asarray(lam, np.float64) * lam_s[channels], 0.0, 1.0)
    nu_eff = np.asarray(nu, np.float64) * nu_s[channels]
    return lam_eff, nu_eff


# ---------------------------------------------------------------------------
# Scheduled outages
# ---------------------------------------------------------------------------


class OutageWindow(NamedTuple):
    """Channel `channel` delivers nothing for rounds [start, stop)."""

    channel: int
    start: int
    stop: int


@dataclasses.dataclass(frozen=True)
class OutageSchedule:
    windows: Tuple[OutageWindow, ...] = ()
    n_channels: int = len(DEFAULT_CHANNELS)

    def delivery_mask(self, n_rounds: int) -> np.ndarray:
        """(n_rounds, n_channels) bool; True = channel delivering."""
        mask = np.ones((n_rounds, self.n_channels), dtype=bool)
        for w in self.windows:
            if not (0 <= w.channel < self.n_channels):
                raise ValueError(f"outage window channel {w.channel} out of range")
            lo = max(int(w.start), 0)
            hi = min(int(w.stop), n_rounds)
            if lo < hi:
                mask[lo:hi, w.channel] = False
        return mask

    def out_rounds(self, channel: int, n_rounds: int) -> np.ndarray:
        return np.nonzero(~self.delivery_mask(n_rounds)[:, channel])[0]


def route_through_channels(
    sig: np.ndarray,
    channels: np.ndarray,
    specs: Sequence[ChannelSpec] = DEFAULT_CHANNELS,
    schedule: Optional[OutageSchedule] = None,
) -> np.ndarray:
    """Route per-page generated signal counts through channel delivery.

    `sig` is (n_rounds, m) counts generated at the source. Each channel
    applies its delivery delay (counts generated at round g land at
    g + delay, truncated at the horizon) and its outage windows (counts
    generated while the channel is out are lost). Returns delivered
    (n_rounds, m) counts.
    """
    sig = np.asarray(sig)
    R, m = sig.shape
    out = np.zeros_like(sig)
    mask = (
        schedule.delivery_mask(R)
        if schedule is not None
        else np.ones((R, len(specs)), dtype=bool)
    )
    if mask.shape[1] != len(specs):
        raise ValueError("outage schedule n_channels != len(specs)")
    for c, spec in enumerate(specs):
        sel = np.asarray(channels) == c
        if not sel.any():
            continue
        rows = sig[:, sel] * mask[:, c : c + 1]
        d = int(spec.delay_rounds)
        if d == 0:
            out[:, sel] += rows
        elif d < R:
            out[d:, sel] += rows[: R - d]
    return out


# ---------------------------------------------------------------------------
# Bursty (self-exciting) change processes and flash crowds
# ---------------------------------------------------------------------------


def hawkes_change_counts(
    rng: np.random.Generator,
    base_rate_dt: np.ndarray,
    n_rounds: int,
    excite: float = 0.3,
    decay: float = 0.7,
    max_rate_dt: float = 16.0,
) -> np.ndarray:
    """Self-exciting change counts, discretized exponential-kernel Hawkes.

        intensity[t+1] = base + (intensity[t] - base) * exp(-decay)
                              + excite * counts[t]
        counts[t] ~ Poisson(intensity[t])

    `base_rate_dt` is the per-page stationary rate already multiplied by dt;
    `excite` is the intensity jump per observed change and `decay` the
    per-round kernel decay. `excite / (exp(decay) - 1) < 1` keeps the
    process subcritical; `max_rate_dt` hard-caps intensity so a property
    test can never draw an unbounded burst. Returns (n_rounds, m) int64.
    """
    base = np.asarray(base_rate_dt, np.float64)
    if excite / max(np.expm1(decay), 1e-9) >= 1.0:
        raise ValueError("supercritical hawkes: excite/(e^decay - 1) >= 1")
    lam_t = base.copy()
    k = float(np.exp(-decay))
    counts = np.zeros((n_rounds,) + base.shape, np.int64)
    for t in range(n_rounds):
        lam_t = np.minimum(lam_t, max_rate_dt)
        counts[t] = rng.poisson(lam_t)
        lam_t = base + (lam_t - base) * k + excite * counts[t]
    return counts


def flash_crowd_profile(
    n_rounds: int,
    surges: Sequence[Tuple[int, int, float]],
    base: float = 1.0,
) -> np.ndarray:
    """(n_rounds,) request-intensity multiplier: `base` everywhere, `gain`
    inside each (start, stop, gain) surge window. Multiply into mu for
    importance surges or into a bandwidth schedule for crawl-budget dips."""
    prof = np.full(n_rounds, float(base))
    for start, stop, gain in surges:
        lo, hi = max(int(start), 0), min(int(stop), n_rounds)
        if lo < hi:
            prof[lo:hi] = float(gain)
    return prof


# ---------------------------------------------------------------------------
# Feed / outcome fault injection
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative per-round feed/outcome faults, keyed by the *global*
    round index (feeds) or outcome-batch sequence number (outcomes).

    - `drop`: feed rows lost entirely.
    - `delay`: (round, lag) — the row lands `lag` rounds late instead.
    - `duplicate`: (round, lag) — the row lands on time AND again lag later.
    - `out_drop` / `out_dup`: outcome batches lost / delivered twice.
    - `out_hold`: outcome batches held back one delivery slot, so they
      arrive after the next batch (reordering).
    """

    drop: Tuple[int, ...] = ()
    delay: Tuple[Tuple[int, int], ...] = ()
    duplicate: Tuple[Tuple[int, int], ...] = ()
    out_drop: Tuple[int, ...] = ()
    out_dup: Tuple[int, ...] = ()
    out_hold: Tuple[int, ...] = ()


def random_fault_plan(
    rng: np.random.Generator,
    n_rounds: int,
    p_drop: float = 0.05,
    p_delay: float = 0.05,
    p_dup: float = 0.05,
    max_lag: int = 3,
    n_batches: int = 0,
    p_out_fault: float = 0.2,
) -> FaultPlan:
    """Sample a FaultPlan. Shared with `tests/strategies.py` so hypothesis
    shrinks over (seed, rates) while the plan itself stays replayable."""
    drop, delay, dup = [], [], []
    for r in range(n_rounds):
        u = rng.random()
        if u < p_drop:
            drop.append(r)
        elif u < p_drop + p_delay:
            delay.append((r, int(rng.integers(1, max_lag + 1))))
        elif u < p_drop + p_delay + p_dup:
            dup.append((r, int(rng.integers(1, max_lag + 1))))
    out_drop, out_dup, out_hold = [], [], []
    for b in range(n_batches):
        if rng.random() < p_out_fault:
            kind = int(rng.integers(0, 3))
            (out_drop, out_dup, out_hold)[kind].append(b)
    return FaultPlan(
        drop=tuple(drop),
        delay=tuple(delay),
        duplicate=tuple(dup),
        out_drop=tuple(out_drop),
        out_dup=tuple(out_dup),
        out_hold=tuple(out_hold),
    )


class FeedFaultInjector:
    """Apply a FaultPlan to (R, m) per-round CIS count rows on their way
    into `run_rounds`, carrying delayed rows across batch boundaries.

    Counts are conserved except for `drop` rounds and rows delayed past the
    final call: `pending_total()` reports the still-buffered remainder so
    tests can assert conservation exactly.
    """

    def __init__(self, plan: FaultPlan):
        self._drop = frozenset(int(r) for r in plan.drop)
        self._delay = {int(r): int(lag) for r, lag in plan.delay}
        self._dup = {int(r): int(lag) for r, lag in plan.duplicate}
        self._pending: dict = {}  # absolute round -> (m,) counts to add
        self._round0 = 0

    def apply(self, feeds: np.ndarray) -> np.ndarray:
        feeds = np.asarray(feeds)
        R = feeds.shape[0]
        out = np.zeros_like(feeds)
        for r in range(R):
            g = self._round0 + r
            row = feeds[r]
            if g in self._drop:
                continue
            lag = self._delay.get(g)
            if lag is not None:
                self._stash(g + lag, row)
                continue
            out[r] = out[r] + row
            lag = self._dup.get(g)
            if lag is not None:
                self._stash(g + lag, row)
        for g in sorted(self._pending):
            r = g - self._round0
            if 0 <= r < R:
                out[r] = out[r] + self._pending.pop(g)
        self._round0 += R
        return out

    def _stash(self, g: int, row: np.ndarray) -> None:
        prev = self._pending.get(g)
        self._pending[g] = row.copy() if prev is None else prev + row

    def pending_total(self) -> int:
        return int(sum(int(v.sum()) for v in self._pending.values()))


class OutcomeFaultInjector:
    """Turn a clean stream of (seq, batch) outcome echoes into a faulted
    delivery stream: drops, duplicates, and holds (reordering). `batch` is
    opaque — typically the `(ids, changed, tau, n_cis)` tuple."""

    def __init__(self, plan: FaultPlan):
        self._drop = frozenset(int(b) for b in plan.out_drop)
        self._dup = frozenset(int(b) for b in plan.out_dup)
        self._hold = frozenset(int(b) for b in plan.out_hold)
        self._held: list = []

    def deliveries(self, seq: int, batch):
        out = []
        if seq in self._drop:
            pass
        elif seq in self._hold:
            self._held.append((seq, batch))
        else:
            out.append((seq, batch))
            if seq in self._dup:
                out.append((seq, batch))
        if out and self._held:
            out.extend(self._held)  # held batches land late = out of order
            self._held = []
        return out

    def flush(self):
        out, self._held = self._held, []
        return out
