"""Closed-loop scheduler-in-the-loop simulation (the estimation bench).

`sim.simulator` replays a *policy function* against the environment one tick
at a time inside one jitted scan — ideal for policy-value experiments, but it
cannot exercise the production scheduler stack (`sched.CrawlScheduler`), whose
unit of work is a macro-round batch. This driver closes that gap: it drives a
live `CrawlScheduler` against the same Section 3 event model at macro-round
granularity, feeding CIS as dense per-round feed batches and — when the
scheduler runs `FusedBackend(online_est=True)` — crawl outcomes as the
`run_rounds(feeds, outcomes=...)` batches of the streaming-estimation loop.

The loop is batch-synchronous: outcomes of macro-batch B's crawls are
delivered during batch B+1 (a fixed R-round crawl latency — conservative for
the estimator, realistic for a crawler whose fetch pipeline lags its
scheduler). Within a batch the driver replays the scheduler's own selections
on a host shadow of (tau, n_cis, staleness) to log per-crawl observations and
the per-tick expected-freshness integral, with exactly `sim.simulator`'s
event ordering (crawl outcome = staleness at tick start; a tick's freshness
counts a page fresh for E[min of N uniforms] = 1/(N+1) of the tick).

Modes:
  * "fixed"     — no learning; the scheduler keeps its construction-time env.
    Construct the scheduler from ground truth to get the oracle baseline,
    from a corrupted env to get the no-learning floor.
  * "streaming" — the on-device estimation loop (`online_est=True` +
    outcomes batches); zero per-round host transfers.
  * "mle"       — the batch reference loop: accumulate crawl logs on the
    host, refit `estimation.fit_mle_pages` every `mle_every` batches through
    `CrawlScheduler.ingest_crawl_results`.

`freshness_regret` of a run vs the oracle run is the bench's headline metric
(ISSUE: streaming within 5% of batch-MLE at <= 15% throughput overhead).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from repro.core.values import Env
from repro.sched.errors import FeedValidationError


class LoopConfig(NamedTuple):
    n_batches: int               # macro-batches to run
    rounds_per_batch: int        # R: rounds per run_rounds call
    mode: str = "fixed"          # "fixed" | "streaming" | "mle"
    mle_every: int = 4           # (mle) batches between host refits
    mle_window: int = 8192       # (mle) most recent observations refit on
    seed: int = 0
    # Elastic bandwidth: optional per-batch crawl rate (crawls per unit
    # time, like CrawlScheduler's bandwidth). The driver turns each batch's
    # rate into a per-round budget vector through a host token bucket whose
    # residue carries across batches — realized crawls track rate * time
    # within +-1 over any window even at fractional per-round rates — and
    # feeds it to run_rounds(budgets=...), so a mid-flight rate change is
    # pure data to the already-compiled scheduler (construct it with k_max
    # >= ceil(max_rate * round_period)). None keeps the scheduler's own
    # fixed bandwidth.
    bandwidth_schedule: Optional[tuple] = None
    # Hostile-ecosystem knobs (`sim.faults`), all optional:
    #   fault_plan — a `faults.FaultPlan`: feed rows are dropped / delayed /
    #     duplicated on their way into run_rounds (`FeedFaultInjector`) and
    #     outcome-echo batches are dropped / held / duplicated
    #     (`OutcomeFaultInjector`), with duplicates deduped through a
    #     `sched.degraded.OutcomeGate` before ingestion.
    #   cis_mask — (n_batches * R, m) bool: False = the CIS fired but was
    #     never delivered (a channel outage; build it from
    #     `faults.OutageSchedule.delivery_mask` + per-page channel ids).
    #     Changes still happen — only the signal is lost.
    #   rate_gain — (n_batches * R,) or (n_batches * R, m) float multiplier
    #     on the per-round CHANGE rates (flash crowds /
    #     `faults.flash_crowd_profile`, bursty Hawkes-style regimes via a
    #     precomputed rate trace). False-signal rates are not scaled.
    fault_plan: Optional[object] = None
    cis_mask: Optional[object] = None
    rate_gain: Optional[object] = None
    #   cis_delay — per-page CIS delivery latency in rounds: scalar or (m,)
    #     int. When set, the loop routes signals through a delay line (a
    #     signal generated at round g lands at round g + delay[page] —
    #     `faults.route_through_channels` semantics, here at closed-loop
    #     granularity), and it CHANGES cis_mask semantics from drop to
    #     re-bucket: signals landing on a masked (outage) round are held
    #     and delivered at the page's first later unmasked round, instead
    #     of lost. Total delivered CIS counts are conserved modulo horizon
    #     truncation (signals still in flight when the loop ends). Pass
    #     cis_delay=0 for pure outage re-bucketing with no added latency;
    #     leave it None to keep the legacy lossy-mask behavior.
    cis_delay: Optional[object] = None
    # Request-driven importance (sched.importance / serve.requests):
    #   request_trace — (n_batches, m) per-batch user-request counts. The
    #     loop records `LoopResult.request_freshness`, the per-tick
    #     freshness integral weighted by the CURRENT batch's realized
    #     traffic distribution (the paper's freshness-at-request-time
    #     objective) — always, learning or not, so static-mu baselines are
    #     comparable arm-for-arm.
    #   importance_source — an `importance.ImportanceSource`: after each
    #     batch the trace row is logged into the scheduler's request-EWMA
    #     plane, and every `fold_every` batches it folds into MU_T (the
    #     scheduler must be constructed with importance=True). None = no
    #     learning (static mu), the ablation baseline.
    request_trace: Optional[object] = None
    importance_source: Optional[object] = None
    fold_every: int = 4


class LoopResult(NamedTuple):
    freshness: np.ndarray        # (n_batches * R,) per-tick weighted freshness
    crawls: np.ndarray           # (m,) crawls per page
    obs: tuple                   # flat (ids, tau, n_cis, fresh) crawl log
    dropped_batches: int = 0     # outcome batches dropped as invalid/dup
    group_freshness: Optional[np.ndarray] = None  # (ticks, n_groups)
    request_freshness: Optional[np.ndarray] = None  # (ticks,) traffic-weighted


def route_cis_batch(gen_cis: np.ndarray, mask_rows, delay_buf: np.ndarray,
                    mask_carry: np.ndarray, delay_cols: dict):
    """One batch of the delayed-CIS routing (`LoopConfig.cis_delay`).

    Two stages, both count-conserving:
      1. channel latency — a signal generated at (local) round g lands at
         g + delay[page]; `delay_buf` ((maxd, m), row i = signals generated
         maxd - i rounds before this batch, still in flight) carries the
         tail across batches;
      2. outage re-bucketing — with `mask_rows` ((R, m) bool, False =
         channel down), signals landing on a masked round queue in
         `mask_carry` and deliver at the page's first later unmasked round
         (the closed-loop analogue of `faults.route_through_channels`'s
         delay semantics — late, never lost).

    Returns (delivered (R, m), delay_buf, mask_carry). Invariant
    (property-tested): sum(gen_cis) + sum(in-flight before) ==
    sum(delivered) + sum(in-flight after) — nothing is dropped, only
    shifted; the horizon truncates whatever is still in flight when the
    loop ends."""
    R, m = gen_cis.shape
    maxd = delay_buf.shape[0]
    ext = np.concatenate([delay_buf, gen_cis], axis=0)
    delivered = np.zeros((R, m), np.int64)
    for d, cols in delay_cols.items():
        if cols.size:
            delivered[:, cols] = ext[maxd + np.arange(R) - d][:, cols]
    delay_buf = ext[R:].copy()
    # The carried tail still holds rows already delivered for pages with
    # small delays (tail row i = signals generated R - maxd + i rounds
    # into this batch; a page with delay d consumed it iff i < maxd - d).
    # Zero those so the buffer holds in-flight signals ONLY — next batch
    # reads past them anyway, and the conservation invariant stays exact.
    for d, cols in delay_cols.items():
        if cols.size and maxd - d > 0:
            delay_buf[:maxd - d][:, cols] = 0
    if mask_rows is not None:
        for r in range(R):
            avail = delivered[r] + mask_carry
            delivered[r] = np.where(mask_rows[r], avail, 0)
            mask_carry = np.where(mask_rows[r], 0, avail)
    return delivered, delay_buf, mask_carry


def run_closed_loop(sched, env_true: Env, cfg: LoopConfig,
                    mu_t: Optional[np.ndarray] = None,
                    groups: Optional[np.ndarray] = None) -> LoopResult:
    """Drive `sched` (a live CrawlScheduler) against `env_true` events.

    The scheduler's *belief* is whatever it was constructed with (plus
    whatever its mode learns); events and the freshness integral always
    follow `env_true`. mu_t overrides the normalized importance weights of
    the freshness integral (defaults to env_true.mu / sum(mu)). groups is
    an optional (m,) int page partition (e.g. signal-quality tiers): when
    set, `LoopResult.group_freshness` additionally records each group's
    share of the per-tick integral, so fairness-across-tiers metrics need
    no extra replay."""
    rng = np.random.default_rng(cfg.seed)
    m = sched.m
    R = int(cfg.rounds_per_batch)
    dt = float(sched.round_period)
    delta = np.asarray(env_true.delta, np.float64)
    lam = np.broadcast_to(np.asarray(env_true.lam, np.float64), (m,))
    nu = np.broadcast_to(np.asarray(env_true.nu, np.float64), (m,))
    mu = np.asarray(env_true.mu, np.float64)
    mu_t = np.asarray(mu_t, np.float64) if mu_t is not None else (
        mu / max(mu.sum(), 1e-12))
    rate_sig = lam * delta * dt
    rate_uns = (1.0 - lam) * delta * dt
    rate_fls = nu * dt

    streaming = cfg.mode == "streaming"
    mle = cfg.mode == "mle"
    if cfg.mode not in ("fixed", "streaming", "mle"):
        raise ValueError(f"unknown mode {cfg.mode!r}")

    bw_sched = cfg.bandwidth_schedule
    if bw_sched is not None:
        bw_sched = np.asarray(bw_sched, np.float64)
        if bw_sched.shape != (cfg.n_batches,):
            raise ValueError(
                f"bandwidth_schedule must have one rate per batch "
                f"({cfg.n_batches}), got shape {bw_sched.shape}")
        if (bw_sched < 0).any():
            raise ValueError("bandwidth_schedule rates must be >= 0")
        if int(np.ceil(float(bw_sched.max()) * dt)) > sched.k_cap:
            raise ValueError(
                f"bandwidth_schedule peaks at {float(bw_sched.max()):g} "
                f"crawls/time = {float(bw_sched.max()) * dt:g}/round, over "
                f"the scheduler's k_max contract ({sched.k_cap}); construct "
                "it with a larger k_max")
    bucket = 0.0  # token-bucket residue, carried across batches

    n_total = cfg.n_batches * R
    cis_mask = None
    if cfg.cis_mask is not None:
        cis_mask = np.asarray(cfg.cis_mask, bool)
        if cis_mask.shape != (n_total, m):
            raise ValueError(
                f"cis_mask must be ({n_total}, {m}) (one bool per round per "
                f"page), got shape {cis_mask.shape}")
    rate_gain = None
    if cfg.rate_gain is not None:
        rate_gain = np.asarray(cfg.rate_gain, np.float64)
        if rate_gain.ndim == 1:
            rate_gain = rate_gain[:, None]
        if rate_gain.shape not in ((n_total, 1), (n_total, m)):
            raise ValueError(
                f"rate_gain must be ({n_total},) or ({n_total}, {m}), got "
                f"shape {cfg.rate_gain.shape if hasattr(cfg.rate_gain, 'shape') else np.shape(cfg.rate_gain)}")
        if (rate_gain < 0).any():
            raise ValueError("rate_gain must be >= 0")
    cis_delay = None
    delay_buf = None
    delay_cols = None
    mask_carry = None
    if cfg.cis_delay is not None:
        cis_delay = np.broadcast_to(
            np.asarray(cfg.cis_delay, np.int64), (m,))
        if (cis_delay < 0).any():
            raise ValueError("cis_delay must be >= 0 rounds")
        maxd = int(cis_delay.max())
        # Delay line across batches: row i holds the signals generated at
        # global round (b * R) - maxd + i, still in flight.
        delay_buf = np.zeros((maxd, m), np.int64)
        delay_cols = {int(d): np.nonzero(cis_delay == d)[0]
                      for d in np.unique(cis_delay)}
        # Signals that landed on a masked (outage) round, awaiting the
        # page's channel to come back up.
        mask_carry = np.zeros((m,), np.int64)

    request_trace = None
    req_fresh_trace = None
    if cfg.request_trace is not None:
        request_trace = np.asarray(cfg.request_trace, np.float64)
        if request_trace.shape != (cfg.n_batches, m):
            raise ValueError(
                f"request_trace must be ({cfg.n_batches}, {m}) (one count "
                f"per batch per page), got shape {request_trace.shape}")
        if (request_trace < 0).any():
            raise ValueError("request_trace counts must be >= 0")
        req_fresh_trace = []
    if cfg.importance_source is not None and request_trace is None:
        raise ValueError(
            "importance_source needs a request_trace to learn from")

    feed_inj = out_inj = out_gate = None
    if cfg.fault_plan is not None:
        from repro.sched.degraded import OutcomeGate
        from repro.sim import faults as _faults

        feed_inj = _faults.FeedFaultInjector(cfg.fault_plan)
        out_inj = _faults.OutcomeFaultInjector(cfg.fault_plan)
        out_gate = OutcomeGate()
    dropped_batches = 0
    out_seq = 0

    groups_np = None
    group_trace = None
    if groups is not None:
        groups_np = np.asarray(groups, np.int64)
        if groups_np.shape != (m,):
            raise ValueError(
                f"groups must be ({m},) page group ids, got shape "
                f"{groups_np.shape}")
        n_groups = int(groups_np.max()) + 1
        group_trace = []

    stale = np.zeros((m,), bool)
    tau_sh = np.zeros((m,), np.float64)   # host shadow of scheduler state
    n_sh = np.zeros((m,), np.int64)
    crawls = np.zeros((m,), np.int64)
    pending_cis = np.zeros((m,), np.int64)  # tick-r CIS ingest at round r+1
    prev_out: tuple | None = None         # batch B outcomes -> batch B+1
    fresh_trace = []
    log_ids, log_tau, log_n, log_z = [], [], [], []

    for b in range(cfg.n_batches):
        if rate_gain is None:
            sig = rng.poisson(rate_sig, size=(R, m))
            uns = rng.poisson(rate_uns, size=(R, m))
        else:
            g = rate_gain[b * R:(b + 1) * R]
            sig = rng.poisson(np.broadcast_to(rate_sig * g, (R, m)))
            uns = rng.poisson(np.broadcast_to(rate_uns * g, (R, m)))
        fls = rng.poisson(rate_fls, size=(R, m))
        gen_cis = sig + fls
        rows = (cis_mask[b * R:(b + 1) * R]
                if cis_mask is not None else None)
        if cis_delay is not None:
            delivered, delay_buf, mask_carry = route_cis_batch(
                gen_cis, rows, delay_buf, mask_carry, delay_cols)
        elif rows is not None:
            # Legacy lossy outage (no cis_delay): the change happened
            # (sig/uns already drawn) but the signal never reached the
            # feed — exactly the censoring the degraded-mode watchdog
            # exists to detect.
            delivered = gen_cis * rows
        else:
            delivered = gen_cis
        feeds = np.empty((R, m), np.int32)
        feeds[0] = pending_cis
        feeds[1:] = delivered[:-1]
        pending_cis = delivered[-1]
        if feed_inj is not None:
            feeds = feed_inj.apply(feeds).astype(np.int32, copy=False)

        budgets = None
        if bw_sched is not None:
            rate = float(bw_sched[b]) * dt
            budgets = np.empty(R, np.int64)
            for r in range(R):
                bucket += rate
                budgets[r] = int(bucket)  # floor; <= k_cap by the check
                bucket -= budgets[r]

        outcomes_in = prev_out if streaming else None
        if streaming and out_inj is not None:
            # Faulty echo path: the injector may drop this batch, hold it
            # for a later delivery, or deliver it twice; everything that
            # does arrive is deduped by sequence number through the
            # OutcomeGate, and the survivors (the current batch plus any
            # released held batches — all (R, w) with one row per round)
            # merge along the width axis into one ingest batch.
            merged = []
            if prev_out is not None:
                for s, batch in out_inj.deliveries(out_seq, prev_out):
                    got = out_gate.offer(s, batch)
                    if got is not None:
                        merged.append(got)
                    else:
                        dropped_batches += 1
                out_seq += 1
            outcomes_in = tuple(
                np.concatenate([mb[i] for mb in merged], axis=1)
                for i in range(4)) if merged else None
        try:
            ids = sched.run_rounds(feeds, outcomes=outcomes_in,
                                   budgets=budgets)
        except FeedValidationError:
            # A malformed outcome batch must not take the scheduler down:
            # outcomes are an optional enrichment of the round, the round
            # itself is not. Drop the batch host-locally and run without.
            if outcomes_in is None:
                raise
            dropped_batches += 1
            ids = sched.run_rounds(feeds, outcomes=None, budgets=budgets)
        ids_np = np.asarray(ids[0])       # the one host read per batch

        changed = np.zeros_like(ids_np)
        out_tau = np.zeros(ids_np.shape, np.float32)
        out_n = np.zeros(ids_np.shape, np.int32)
        for r in range(R):
            n_sh += feeds[r]
            row = ids_np[r]
            # Under a budget vector, slots past round r's budget are -1
            # (the masked tail of the k_cap-wide selection) — padding both
            # for the shadow replay and for the echoed outcomes batch.
            valid = row >= 0
            sel = row[valid]
            changed[r, valid] = stale[sel]
            out_tau[r, valid] = tau_sh[sel]
            out_n[r, valid] = n_sh[sel]
            log_ids.append(sel.copy())
            log_tau.append(tau_sh[sel].astype(np.float32))
            log_n.append(n_sh[sel].astype(np.int32))
            log_z.append((~stale[sel]).astype(np.int32))
            crawls[sel] += 1
            stale[sel] = False
            n_changes = sig[r] + uns[r]
            frac = np.where(~stale, 1.0 / (n_changes + 1.0), 0.0)
            fresh_trace.append(float(np.sum(mu_t * frac)))
            if req_fresh_trace is not None:
                # Freshness at request time: the same per-tick integral,
                # weighted by the batch's realized traffic distribution
                # (zero-traffic batches contribute zero — nobody asked).
                row = request_trace[b]
                tot = row.sum()
                req_fresh_trace.append(
                    float(np.sum(row * frac) / tot) if tot > 0 else 0.0)
            if group_trace is not None:
                group_trace.append(np.bincount(
                    groups_np, weights=mu_t * frac, minlength=n_groups))
            stale |= n_changes > 0
            tau_sh[sel] = 0.0
            n_sh[sel] = 0
            tau_sh += dt
        # Echo each crawl's covariates with its outcome — the shadow replay
        # knows them exactly, as any real crawl pipeline does (it issued
        # the crawl orders and owns the feed stream), making every outcome
        # a self-contained observation (`online_est.SparseOutcomes`).
        prev_out = (ids_np, changed, out_tau, out_n)

        if cfg.importance_source is not None:
            # The batch's traffic teaches the scheduler what matters: log
            # the realized request counts into the EWMA plane, and fold
            # them into MU_T every fold_every batches — from batch b+1 the
            # crawler optimizes freshness weighted by observed demand.
            row = request_trace[b]
            req_ids = np.nonzero(row)[0]
            if req_ids.size:
                sched.log_requests(req_ids, row[req_ids])
            if cfg.fold_every and (b + 1) % cfg.fold_every == 0:
                sched.fold_importance(cfg.importance_source)

        if mle:
            done = len(fresh_trace) // R
            if done % cfg.mle_every == 0:
                _refit_mle(sched, log_ids, log_tau, log_n, log_z,
                           cfg.mle_window)

    obs = tuple(np.concatenate(x) for x in (log_ids, log_tau, log_n, log_z))
    return LoopResult(freshness=np.asarray(fresh_trace), crawls=crawls,
                      obs=obs, dropped_batches=dropped_batches,
                      group_freshness=(np.asarray(group_trace)
                                       if group_trace is not None else None),
                      request_freshness=(np.asarray(req_fresh_trace)
                                         if req_fresh_trace is not None
                                         else None))


def _refit_mle(sched, log_ids, log_tau, log_n, log_z, window: int) -> None:
    """Batch-reference refit: group the most recent `window` flat crawl
    observations per page and push them through
    `CrawlScheduler.ingest_crawl_results`. Short pages are padded with
    (tau=0, n=0, fresh=1) rows, which contribute zero NLL gradient and
    nothing to gamma_hat — the padding is estimation-invisible."""
    ids = np.concatenate(log_ids)[-window:]
    tau = np.concatenate(log_tau)[-window:]
    n = np.concatenate(log_n)[-window:]
    z = np.concatenate(log_z)[-window:]
    if not ids.size:
        return
    uniq, inv = np.unique(ids, return_inverse=True)
    counts = np.bincount(inv)
    order = np.argsort(inv, kind="stable")
    col = np.concatenate([np.arange(c) for c in counts])
    width = int(counts.max())
    tau_m = np.zeros((uniq.size, width), np.float32)
    n_m = np.zeros((uniq.size, width), np.int32)
    z_m = np.ones((uniq.size, width), np.int32)
    tau_m[inv[order], col] = tau[order]
    n_m[inv[order], col] = n[order]
    z_m[inv[order], col] = z[order]
    sched.ingest_crawl_results(uniq, tau_m, n_m, z_m)


def run_importance_ablation(sched_factory, env_true: Env, cfg: LoopConfig,
                            sources: dict | None = None,
                            mu_t: Optional[np.ndarray] = None) -> dict:
    """A/B the importance sources over ONE realized trace.

    Every arm replays the identical event/traffic realization (the loop's
    rng is seeded from cfg.seed and the request trace is part of cfg), so
    per-arm `request_freshness` traces differ only by what the scheduler
    learned to crawl — the paper's freshness-at-request-time objective,
    compared like-for-like. `sched_factory()` must build a FRESH scheduler
    per arm (state is donated; arms cannot share one). `sources` maps arm
    name -> `importance.ImportanceSource`, or None for the static-mu
    baseline (no logging, no folds); default arms: static uniform vs
    learned request-EWMA. Returns {name: LoopResult}."""
    from repro.sched import importance as imp

    if sources is None:
        sources = {"static": None, "request_ewma": imp.REQUEST_EWMA}
    if cfg.request_trace is None:
        raise ValueError("run_importance_ablation needs cfg.request_trace")
    out = {}
    for name, src in sources.items():
        out[name] = run_closed_loop(
            sched_factory(), env_true,
            cfg._replace(importance_source=src), mu_t=mu_t)
    return out


def freshness_regret(result: LoopResult, oracle: LoopResult,
                     skip_frac: float = 0.25) -> float:
    """Mean per-tick freshness shortfall vs an oracle run, after dropping
    the first `skip_frac` of ticks (the learning transient — regret here
    measures the steady state the estimator converges to, not the price of
    the burn-in both learning modes pay)."""
    s = int(len(result.freshness) * skip_frac)
    return float(np.mean(oracle.freshness[s:]) - np.mean(result.freshness[s:]))
