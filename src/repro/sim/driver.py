"""Closed-loop scheduler-in-the-loop simulation (the estimation bench).

`sim.simulator` replays a *policy function* against the environment one tick
at a time inside one jitted scan — ideal for policy-value experiments, but it
cannot exercise the production scheduler stack (`sched.CrawlScheduler`), whose
unit of work is a macro-round batch. This driver closes that gap: it drives a
live `CrawlScheduler` against the same Section 3 event model at macro-round
granularity, feeding CIS as dense per-round feed batches and — when the
scheduler runs `FusedBackend(online_est=True)` — crawl outcomes as the
`run_rounds(feeds, outcomes=...)` batches of the streaming-estimation loop.

The loop is batch-synchronous: outcomes of macro-batch B's crawls are
delivered during batch B+1 (a fixed R-round crawl latency — conservative for
the estimator, realistic for a crawler whose fetch pipeline lags its
scheduler). Within a batch the driver replays the scheduler's own selections
on a host shadow of (tau, n_cis, staleness) to log per-crawl observations and
the per-tick expected-freshness integral, with exactly `sim.simulator`'s
event ordering (crawl outcome = staleness at tick start; a tick's freshness
counts a page fresh for E[min of N uniforms] = 1/(N+1) of the tick).

Modes:
  * "fixed"     — no learning; the scheduler keeps its construction-time env.
    Construct the scheduler from ground truth to get the oracle baseline,
    from a corrupted env to get the no-learning floor.
  * "streaming" — the on-device estimation loop (`online_est=True` +
    outcomes batches); zero per-round host transfers.
  * "mle"       — the batch reference loop: accumulate crawl logs on the
    host, refit `estimation.fit_mle_pages` every `mle_every` batches through
    `CrawlScheduler.ingest_crawl_results`.

`freshness_regret` of a run vs the oracle run is the bench's headline metric
(ISSUE: streaming within 5% of batch-MLE at <= 15% throughput overhead).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from repro.core.values import Env


class LoopConfig(NamedTuple):
    n_batches: int               # macro-batches to run
    rounds_per_batch: int        # R: rounds per run_rounds call
    mode: str = "fixed"          # "fixed" | "streaming" | "mle"
    mle_every: int = 4           # (mle) batches between host refits
    mle_window: int = 8192       # (mle) most recent observations refit on
    seed: int = 0
    # Elastic bandwidth: optional per-batch crawl rate (crawls per unit
    # time, like CrawlScheduler's bandwidth). The driver turns each batch's
    # rate into a per-round budget vector through a host token bucket whose
    # residue carries across batches — realized crawls track rate * time
    # within +-1 over any window even at fractional per-round rates — and
    # feeds it to run_rounds(budgets=...), so a mid-flight rate change is
    # pure data to the already-compiled scheduler (construct it with k_max
    # >= ceil(max_rate * round_period)). None keeps the scheduler's own
    # fixed bandwidth.
    bandwidth_schedule: Optional[tuple] = None


class LoopResult(NamedTuple):
    freshness: np.ndarray        # (n_batches * R,) per-tick weighted freshness
    crawls: np.ndarray           # (m,) crawls per page
    obs: tuple                   # flat (ids, tau, n_cis, fresh) crawl log


def run_closed_loop(sched, env_true: Env, cfg: LoopConfig,
                    mu_t: Optional[np.ndarray] = None) -> LoopResult:
    """Drive `sched` (a live CrawlScheduler) against `env_true` events.

    The scheduler's *belief* is whatever it was constructed with (plus
    whatever its mode learns); events and the freshness integral always
    follow `env_true`. mu_t overrides the normalized importance weights of
    the freshness integral (defaults to env_true.mu / sum(mu))."""
    rng = np.random.default_rng(cfg.seed)
    m = sched.m
    R = int(cfg.rounds_per_batch)
    dt = float(sched.round_period)
    delta = np.asarray(env_true.delta, np.float64)
    lam = np.broadcast_to(np.asarray(env_true.lam, np.float64), (m,))
    nu = np.broadcast_to(np.asarray(env_true.nu, np.float64), (m,))
    mu = np.asarray(env_true.mu, np.float64)
    mu_t = np.asarray(mu_t, np.float64) if mu_t is not None else (
        mu / max(mu.sum(), 1e-12))
    rate_sig = lam * delta * dt
    rate_uns = (1.0 - lam) * delta * dt
    rate_fls = nu * dt

    streaming = cfg.mode == "streaming"
    mle = cfg.mode == "mle"
    if cfg.mode not in ("fixed", "streaming", "mle"):
        raise ValueError(f"unknown mode {cfg.mode!r}")

    bw_sched = cfg.bandwidth_schedule
    if bw_sched is not None:
        bw_sched = np.asarray(bw_sched, np.float64)
        if bw_sched.shape != (cfg.n_batches,):
            raise ValueError(
                f"bandwidth_schedule must have one rate per batch "
                f"({cfg.n_batches}), got shape {bw_sched.shape}")
        if (bw_sched < 0).any():
            raise ValueError("bandwidth_schedule rates must be >= 0")
        if int(np.ceil(float(bw_sched.max()) * dt)) > sched.k_cap:
            raise ValueError(
                f"bandwidth_schedule peaks at {float(bw_sched.max()):g} "
                f"crawls/time = {float(bw_sched.max()) * dt:g}/round, over "
                f"the scheduler's k_max contract ({sched.k_cap}); construct "
                "it with a larger k_max")
    bucket = 0.0  # token-bucket residue, carried across batches

    stale = np.zeros((m,), bool)
    tau_sh = np.zeros((m,), np.float64)   # host shadow of scheduler state
    n_sh = np.zeros((m,), np.int64)
    crawls = np.zeros((m,), np.int64)
    pending_cis = np.zeros((m,), np.int64)  # tick-r CIS ingest at round r+1
    prev_out: tuple | None = None         # batch B outcomes -> batch B+1
    fresh_trace = []
    log_ids, log_tau, log_n, log_z = [], [], [], []

    for b in range(cfg.n_batches):
        sig = rng.poisson(rate_sig, size=(R, m))
        uns = rng.poisson(rate_uns, size=(R, m))
        fls = rng.poisson(rate_fls, size=(R, m))
        gen_cis = sig + fls
        feeds = np.empty((R, m), np.int32)
        feeds[0] = pending_cis
        feeds[1:] = gen_cis[:-1]
        pending_cis = gen_cis[-1]

        budgets = None
        if bw_sched is not None:
            rate = float(bw_sched[b]) * dt
            budgets = np.empty(R, np.int64)
            for r in range(R):
                bucket += rate
                budgets[r] = int(bucket)  # floor; <= k_cap by the check
                bucket -= budgets[r]

        ids = sched.run_rounds(
            feeds, outcomes=prev_out if streaming else None, budgets=budgets)
        ids_np = np.asarray(ids[0])       # the one host read per batch

        changed = np.zeros_like(ids_np)
        out_tau = np.zeros(ids_np.shape, np.float32)
        out_n = np.zeros(ids_np.shape, np.int32)
        for r in range(R):
            n_sh += feeds[r]
            row = ids_np[r]
            # Under a budget vector, slots past round r's budget are -1
            # (the masked tail of the k_cap-wide selection) — padding both
            # for the shadow replay and for the echoed outcomes batch.
            valid = row >= 0
            sel = row[valid]
            changed[r, valid] = stale[sel]
            out_tau[r, valid] = tau_sh[sel]
            out_n[r, valid] = n_sh[sel]
            log_ids.append(sel.copy())
            log_tau.append(tau_sh[sel].astype(np.float32))
            log_n.append(n_sh[sel].astype(np.int32))
            log_z.append((~stale[sel]).astype(np.int32))
            crawls[sel] += 1
            stale[sel] = False
            n_changes = sig[r] + uns[r]
            frac = np.where(~stale, 1.0 / (n_changes + 1.0), 0.0)
            fresh_trace.append(float(np.sum(mu_t * frac)))
            stale |= n_changes > 0
            tau_sh[sel] = 0.0
            n_sh[sel] = 0
            tau_sh += dt
        # Echo each crawl's covariates with its outcome — the shadow replay
        # knows them exactly, as any real crawl pipeline does (it issued
        # the crawl orders and owns the feed stream), making every outcome
        # a self-contained observation (`online_est.SparseOutcomes`).
        prev_out = (ids_np, changed, out_tau, out_n)

        if mle:
            done = len(fresh_trace) // R
            if done % cfg.mle_every == 0:
                _refit_mle(sched, log_ids, log_tau, log_n, log_z,
                           cfg.mle_window)

    obs = tuple(np.concatenate(x) for x in (log_ids, log_tau, log_n, log_z))
    return LoopResult(freshness=np.asarray(fresh_trace), crawls=crawls,
                      obs=obs)


def _refit_mle(sched, log_ids, log_tau, log_n, log_z, window: int) -> None:
    """Batch-reference refit: group the most recent `window` flat crawl
    observations per page and push them through
    `CrawlScheduler.ingest_crawl_results`. Short pages are padded with
    (tau=0, n=0, fresh=1) rows, which contribute zero NLL gradient and
    nothing to gamma_hat — the padding is estimation-invisible."""
    ids = np.concatenate(log_ids)[-window:]
    tau = np.concatenate(log_tau)[-window:]
    n = np.concatenate(log_n)[-window:]
    z = np.concatenate(log_z)[-window:]
    if not ids.size:
        return
    uniq, inv = np.unique(ids, return_inverse=True)
    counts = np.bincount(inv)
    order = np.argsort(inv, kind="stable")
    col = np.concatenate([np.arange(c) for c in counts])
    width = int(counts.max())
    tau_m = np.zeros((uniq.size, width), np.float32)
    n_m = np.zeros((uniq.size, width), np.int32)
    z_m = np.ones((uniq.size, width), np.int32)
    tau_m[inv[order], col] = tau[order]
    n_m[inv[order], col] = n[order]
    z_m[inv[order], col] = z[order]
    sched.ingest_crawl_results(uniq, tau_m, n_m, z_m)


def freshness_regret(result: LoopResult, oracle: LoopResult,
                     skip_frac: float = 0.25) -> float:
    """Mean per-tick freshness shortfall vs an oracle run, after dropping
    the first `skip_frac` of ticks (the learning transient — regret here
    measures the steady state the estimator converges to, not the price of
    the burn-in both learning modes pay)."""
    s = int(len(result.freshness) * skip_frac)
    return float(np.mean(oracle.freshness[s:]) - np.mean(result.freshness[s:]))
