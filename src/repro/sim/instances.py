"""Problem-instance generators (paper Section 6.1 / 6.7).

All generators return `core.Env` arrays plus any auxiliary ground truth needed
by the benchmarks. Randomness is explicit via PRNG keys; instances are plain
arrays so they vmap over repetitions.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.values import Env


def uniform_instance(
    key: jax.Array,
    m: int,
    delta_range=(0.0, 1.0),
    mu_range=(0.0, 1.0),
    lam_beta=(0.25, 0.25),
    nu_range=(0.1, 0.6),
    with_cis: bool = True,
) -> Env:
    """Section 6.1: Delta, mu ~ Unif; lam ~ Beta(a, b) (bimodal for 0.25/0.25);
    nu ~ Unif. with_cis=False zeroes the CIS channel (Section 6.4 setting)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    delta = jax.random.uniform(k1, (m,), minval=delta_range[0], maxval=delta_range[1])
    mu = jax.random.uniform(k2, (m,), minval=mu_range[0], maxval=mu_range[1])
    if with_cis:
        lam = jax.random.beta(k3, lam_beta[0], lam_beta[1], (m,))
        nu = jax.random.uniform(k4, (m,), minval=nu_range[0], maxval=nu_range[1])
    else:
        lam = jnp.zeros((m,))
        nu = jnp.zeros((m,))
    # Degenerate delta = 0 pages never change; keep a tiny floor so V and the
    # freshness integral stay well-conditioned (matches 'close to m/2' note).
    delta = jnp.maximum(delta, 1e-3)
    mu = jnp.maximum(mu, 1e-3)
    return Env(delta=delta, mu=mu, lam=lam, nu=nu)


class TieredCISInstance(NamedTuple):
    env: Env
    tier: jax.Array  # (m,) int32 tier id, len(TIER_NAMES) tiers


TIER_NAMES = ("reliable", "noisy", "silent")


def tiered_cis_instance(
    key: jax.Array,
    m: int,
    fracs=(0.3, 0.5, 0.2),
    delta_range=(0.05, 1.0),
    mu_range=(0.1, 1.0),
) -> TieredCISInstance:
    """Per-page heterogeneous CIS-quality regimes (the estimation-fairness
    instance): pages fall into signal-quality tiers with very different
    (lam, nu) — "reliable" (high recall, few false signals), "noisy" (weak
    recall, false-positive-heavy), "silent" (no CIS channel at all) — while
    Delta and mu vary independently of tier. An estimator bench on this
    instance exercises convergence across quality tiers at once: a scheduler
    that learns only the easy tier shows up as per-tier regret skew, not
    just an aggregate number. Tier ids index `TIER_NAMES`."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    delta = jax.random.uniform(k1, (m,), minval=delta_range[0],
                               maxval=delta_range[1])
    mu = jax.random.uniform(k2, (m,), minval=mu_range[0], maxval=mu_range[1])
    edges = jnp.cumsum(jnp.asarray(fracs[:-1], jnp.float32))
    tier = jnp.searchsorted(edges, jax.random.uniform(k3, (m,)))
    lam_t = jnp.stack([
        jax.random.uniform(k4, (m,), minval=0.8, maxval=1.0),   # reliable
        jax.random.uniform(k4, (m,), minval=0.2, maxval=0.6),   # noisy
        jnp.zeros((m,)),                                        # silent
    ])
    nu_t = jnp.stack([
        jax.random.uniform(k5, (m,), minval=0.0, maxval=0.05),
        jax.random.uniform(k5, (m,), minval=0.3, maxval=0.8),
        jnp.zeros((m,)),
    ])
    rows = jnp.arange(m)
    env = Env(delta=delta, mu=mu, lam=lam_t[tier, rows], nu=nu_t[tier, rows])
    return TieredCISInstance(env=env, tier=tier.astype(jnp.int32))


class MultiChannelInstance(NamedTuple):
    env: Env                 # effective env after channel quality scaling
    tier: jax.Array          # (m,) int32 tier id into TIER_NAMES
    channels: jax.Array      # (m,) int32 channel id into specs
    specs: tuple             # ChannelSpec per channel (sim.faults)


def multichannel_instance(
    key: jax.Array,
    m: int,
    specs=None,
    span: int | None = None,
    fracs=(0.3, 0.5, 0.2),
) -> MultiChannelInstance:
    """Tiered instance whose pages are additionally spread across per-source
    signal channels (sitemap vs CDN vs ping — `sim.faults.ChannelSpec`), so
    each page's effective (lam, nu) is its tier draw scaled by its channel's
    quality, and its CIS delivery inherits the channel's delay and outage
    windows. Channels are contiguous runs of `span` pages (sites cluster on
    one feed technology); align `span` to the selection block size to make
    outages block-coherent — the granularity the degraded-mode watchdog
    detects."""
    from repro.sim import faults

    specs = tuple(specs) if specs is not None else faults.DEFAULT_CHANNELS
    if span is None:
        span = max(min(32768, m // len(specs)), 1)
    base = tiered_cis_instance(key, m, fracs=fracs)
    channels = faults.assign_channels(m, len(specs), span=span)
    lam_eff, nu_eff = faults.channel_rates(
        base.env.lam, base.env.nu, channels, specs)
    env = Env(
        delta=base.env.delta,
        mu=base.env.mu,
        lam=jnp.asarray(lam_eff, base.env.lam.dtype),
        nu=jnp.asarray(nu_eff, base.env.nu.dtype),
    )
    return MultiChannelInstance(
        env=env,
        tier=base.tier,
        channels=jnp.asarray(channels, jnp.int32),
        specs=specs,
    )


def env_from_precision_recall(
    delta: jax.Array, mu: jax.Array, precision: jax.Array, recall: jax.Array
) -> Env:
    """Invert (precision, recall) to model parameters:
        lam = recall;   gamma = lam*delta/precision;   nu = gamma - lam*delta.
    Pages with recall = 0 get nu = 0 (no signal channel at all) — matching the
    paper's treatment of URLs without side information."""
    lam = jnp.clip(recall, 0.0, 1.0)
    prec = jnp.clip(precision, 1e-3, 1.0)
    signaled = lam * delta
    gamma = jnp.where(lam > 0, signaled / prec, 0.0)
    nu = jnp.maximum(gamma - signaled, 0.0)
    return Env(delta=delta, mu=mu, lam=lam, nu=nu)


class RealWorldInstance(NamedTuple):
    env: Env
    precision: jax.Array
    recall: jax.Array
    top_mask: jax.Array  # the ~5% of URLs labelled "perfect CIS" by Kolobov'19


def realworld_instance(
    key: jax.Array,
    m: int = 100_000,
    top_frac: float = 0.05,
) -> RealWorldInstance:
    """Section 6.7 semi-synthetic protocol.

    The Kolobov'19 dataset is not redistributable; we reproduce the *published
    statistics*: importance and change rates with heavy-tailed distributions
    (importance from PageRank-like power law, change rate in changes/day over a
    2-week crawl), ~5% of URLs labelled as having side information. Precision /
    recall are drawn from the Section 2 shaped histograms: the labelled top 5%
    from the upper tail (>0.8 mode), the rest from the lower 95% (precision
    mode < 0.2, recall mode < 0.5).
    """
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # Importance: power-law (PageRank-like). Change rate: log-uniform-ish.
    mu = jax.random.pareto(k1, 1.5, (m,)) + 1.0
    delta = jnp.exp(jax.random.uniform(k2, (m,), minval=jnp.log(0.02), maxval=jnp.log(5.0)))
    top = jax.random.uniform(k3, (m,)) < top_frac
    # Lower 95%: Beta(1.2, 5) precision (mass < 0.2), Beta(2, 2.5) recall.
    prec_lo = jax.random.beta(k4, 1.2, 5.0, (m,))
    rec_lo = jax.random.beta(k5, 2.0, 2.5, (m,))
    # Upper 5% tail: Beta(8, 1.5) — mode near 0.9 for both.
    prec_hi = jax.random.beta(k6, 8.0, 1.5, (m,))
    rec_hi = jax.random.beta(jax.random.fold_in(k6, 1), 8.0, 1.5, (m,))
    precision = jnp.where(top, prec_hi, prec_lo)
    recall = jnp.where(top, rec_hi, rec_lo)
    env = env_from_precision_recall(delta, mu, precision, recall)
    return RealWorldInstance(env=env, precision=precision, recall=recall, top_mask=top)


def corrupt_precision_recall(
    key: jax.Array, precision: jax.Array, recall: jax.Array, p: float
):
    """Section 6.7 corruption: mix uniform noise xi ~ U(0,1) into the estimates
    with weight p: est = (1-p) * est + p * xi."""
    k1, k2 = jax.random.split(key)
    xi1 = jax.random.uniform(k1, precision.shape)
    xi2 = jax.random.uniform(k2, recall.shape)
    return (1 - p) * precision + p * xi1, (1 - p) * recall + p * xi2
