"""Semi-synthetic crawling experiment substrate (paper Section 6)."""
from repro.sim.driver import (
    LoopConfig,
    LoopResult,
    freshness_regret,
    run_closed_loop,
)
from repro.sim.instances import (
    TIER_NAMES,
    TieredCISInstance,
    corrupt_precision_recall,
    env_from_precision_recall,
    realworld_instance,
    tiered_cis_instance,
    uniform_instance,
)
from repro.sim.simulator import DelayConfig, SimConfig, SimResult, simulate

__all__ = [k for k in dir() if not k.startswith("_")]
