"""Semi-synthetic crawling experiment substrate (paper Section 6)."""
from repro.sim.driver import (
    LoopConfig,
    LoopResult,
    freshness_regret,
    route_cis_batch,
    run_closed_loop,
    run_importance_ablation,
)
from repro.sim.faults import (
    DEFAULT_CHANNELS,
    ChannelSpec,
    FaultPlan,
    FeedFaultInjector,
    OutageSchedule,
    OutageWindow,
    OutcomeFaultInjector,
    assign_channels,
    channel_rates,
    flash_crowd_profile,
    hawkes_change_counts,
    random_fault_plan,
    route_through_channels,
)
from repro.sim.instances import (
    TIER_NAMES,
    MultiChannelInstance,
    TieredCISInstance,
    corrupt_precision_recall,
    env_from_precision_recall,
    multichannel_instance,
    realworld_instance,
    tiered_cis_instance,
    uniform_instance,
)
from repro.sim.simulator import (
    DelayConfig,
    Modulation,
    SimConfig,
    SimResult,
    simulate,
)

__all__ = [k for k in dir() if not k.startswith("_")]
