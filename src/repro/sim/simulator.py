"""Tick-driven crawl simulator (discrete policy class, paper Section 3).

Time advances in ticks of length dt; k pages are crawled per tick (k/dt = R).
Within a tick:

  1. the policy scores all pages from scheduler state (tau^ELAP, n_CIS) as of
     the tick start and crawls the arg-top-k (crawl lands at the tick start);
  2. the environment samples change / signalled-change / false-CIS events for
     the tick from the three independent Poisson processes of Section 3;
  3. the exact *expected* freshness of the tick given the realized event counts
     is accumulated: a page fresh at the start of the tick with N changes in
     the tick is fresh for a fraction E[min of N uniforms] = 1/(N+1) of it.

Accuracy = importance-weighted time-average freshness, which by PASTA equals
the paper's request-hit objective in expectation but with lower variance than
sampling request events.

Event sampling uses the exact split of Section 3 (signalled changes at rate
lam*Delta, unsignalled at (1-lam)*Delta, false CIS at nu) — all Poisson; when
max(rate*dt) is small a Bernoulli approximation is used for speed (error
O((rate*dt)^2), documented and tested).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import policies as pol
from repro.core import tables
from repro.core.state import PageState
from repro.core.values import BIG, DerivedEnv, Env, derive

_BERNOULLI_THRESH = 0.05


class DelayConfig(NamedTuple):
    """CIS delivery delay in ticks ~ Poisson(mean_ticks) (paper App. C uses a
    Poisson(6) delay); max_ticks bounds the circular arrival buffer."""

    mean_ticks: float = 6.0
    max_ticks: int = 32


class Modulation(NamedTuple):
    """Per-tick hostile-environment modulation (built by `sim.faults`).

    change_gain: (n_steps, m) multiplier on the page *change* rates (both
        the signalled and unsignalled rows, not false CIS) — e.g. a Hawkes
        burst intensity normalized by the base rate, or a flash-crowd
        profile broadcast over pages.
    cis_gain: (n_steps, m) multiplier applied to generated CIS counts
        post-sampling; a 0/1 row encodes a per-tick channel outage —
        changes still happen, the signals just never arrive.

    Either field may be None. Passing `modulation=None` (the default)
    leaves the clean path bit-identical: no extra operands are traced.
    """

    change_gain: Optional[jax.Array] = None
    cis_gain: Optional[jax.Array] = None


class SimConfig(NamedTuple):
    dt: float                    # tick length (= k_per_tick / bandwidth R)
    n_steps: int                 # number of ticks
    k_per_tick: int = 1          # crawls per tick
    n_terms: int = 8             # K for GREEDY_NCIS
    value_impl: str = "table"    # "table" | "exact" (series)
    table_grid: int = 128
    count_mode: str = "auto"     # "auto" | "bernoulli" | "poisson"
    t_delay_filter: float = 0.0  # App. C discard window (0 = off)
    record_trace: bool = True
    record_obs: bool = False     # per-crawl (page, tau, n_cis, fresh) log


class SimResult(NamedTuple):
    accuracy: jax.Array          # scalar: importance-weighted freshness
    trace: jax.Array             # (n_steps,) per-tick weighted freshness
    crawl_counts: jax.Array      # (m,) crawls per page
    obs: Optional[tuple] = None  # (page, tau, n_cis, fresh) each (n_steps, k)


def _sample_counts(key, rates_dt, mode):
    """Counts of the 3 stacked Poisson processes for one tick. rates_dt: (3, m)."""
    if mode == "bernoulli":
        u = jax.random.uniform(key, rates_dt.shape)
        return (u < -jnp.expm1(-rates_dt)).astype(jnp.int32)
    return jax.random.poisson(key, rates_dt, rates_dt.shape).astype(jnp.int32)


def _resolve_count_mode(cfg: SimConfig, env: Env) -> str:
    if cfg.count_mode != "auto":
        return cfg.count_mode
    import numpy as np

    max_rate = float(np.max(np.asarray(env.delta) + np.asarray(env.nu)))
    return "bernoulli" if max_rate * cfg.dt < _BERNOULLI_THRESH else "poisson"


def simulate(
    key: jax.Array,
    env: Env,
    policy: str,
    cfg: SimConfig,
    belief: Env | None = None,
    lds_rates: jax.Array | None = None,
    quality_mask: jax.Array | None = None,
    k_schedule: jax.Array | None = None,
    modulation: Modulation | None = None,
) -> SimResult:
    """Run one simulation. `belief` is what the policy *thinks* the environment
    is (e.g. corrupted precision/recall estimates); events always follow `env`.

    k_schedule: optional (n_steps,) integer per-tick crawl budgets (elastic
    bandwidth). `cfg.k_per_tick` becomes the static cap: each tick crawls the
    arg-top-`k_schedule[t]` pages (0 = pure observation tick), and the vector
    is a traced operand — sweeping budget values reuses one compiled
    executable. In `obs`, selection slots past a tick's budget carry page -1
    (filter on it; their covariate columns are padding)."""
    d_true = derive(env)
    d_bel = derive(belief) if belief is not None else d_true
    mode = _resolve_count_mode(cfg, env)
    if k_schedule is not None:
        k_schedule = jnp.clip(
            jnp.asarray(k_schedule, jnp.int32), 0, cfg.k_per_tick)
        if k_schedule.shape != (cfg.n_steps,):
            raise ValueError(
                f"k_schedule must have shape ({cfg.n_steps},), got "
                f"{k_schedule.shape}")
    modulation = _check_modulation(modulation, cfg, env)
    return _simulate_impl(key, env, d_true, d_bel, policy, cfg, mode,
                          lds_rates, quality_mask, k_schedule, modulation,
                          delay=None)


def simulate_delayed(
    key: jax.Array,
    env: Env,
    policy: str,
    cfg: SimConfig,
    delay: DelayConfig,
    belief: Env | None = None,
    quality_mask: jax.Array | None = None,
    modulation: Modulation | None = None,
) -> SimResult:
    """Simulation with CIS delivery delays (paper App. C)."""
    d_true = derive(env)
    d_bel = derive(belief) if belief is not None else d_true
    mode = _resolve_count_mode(cfg, env)
    modulation = _check_modulation(modulation, cfg, env)
    return _simulate_impl(key, env, d_true, d_bel, policy, cfg, mode,
                          None, quality_mask, None, modulation, delay=delay)


def _check_modulation(
    modulation: Modulation | None, cfg: SimConfig, env: Env
) -> Modulation | None:
    if modulation is None:
        return None
    if modulation.change_gain is None and modulation.cis_gain is None:
        return None
    m = env.delta.shape[0]
    out = {}
    for name, arr in zip(
        ("change_gain", "cis_gain"), (modulation.change_gain, modulation.cis_gain)
    ):
        if arr is None:
            out[name] = None
            continue
        arr = jnp.asarray(arr, jnp.float32)
        if arr.shape != (cfg.n_steps, m):
            raise ValueError(
                f"modulation.{name} must have shape ({cfg.n_steps}, {m}), "
                f"got {arr.shape}")
        out[name] = arr
    return Modulation(**out)


@functools.partial(
    jax.jit,
    static_argnames=("policy", "cfg", "mode", "delay"),
)
def _simulate_impl(
    key,
    env: Env,
    d_true: DerivedEnv,
    d_bel: DerivedEnv,
    policy: str,
    cfg: SimConfig,
    mode: str,
    lds_rates,
    quality_mask,
    k_schedule,
    modulation,
    delay: DelayConfig | None,
) -> SimResult:
    m = env.delta.shape[0]
    dt = jnp.float32(cfg.dt)
    rates_dt = jnp.stack(
        [d_true.lam * d_true.delta, d_true.alpha, d_true.nu], axis=0
    ) * dt  # signalled changes, unsignalled changes, false CIS

    # Value evaluation (under the policy's *beliefs*).
    table = None
    if policy == pol.GREEDY_NCIS and cfg.value_impl == "table":
        table = tables.build_ncis_table(
            d_bel, n_terms=cfg.n_terms, n_grid=cfg.table_grid
        )

    def values_fn(state: PageState) -> jax.Array:
        if policy == pol.LDS:
            raise AssertionError("LDS handled by deadline path")
        if table is not None:
            return tables.lookup_state(table, d_bel, state.tau_elap, state.n_cis)
        return pol.crawl_values(
            policy, state, d_bel, n_terms=cfg.n_terms, quality_mask=quality_mask
        )

    is_lds = policy == pol.LDS
    if is_lds:
        if lds_rates is None:
            raise ValueError("LDS policy requires lds_rates")
        period = jnp.where(lds_rates > 1e-9, 1.0 / jnp.maximum(lds_rates, 1e-9), BIG)
        phase = jax.random.uniform(jax.random.fold_in(key, 7), (m,))
        deadlines0 = phase * period
    else:
        period = jnp.zeros((m,))
        deadlines0 = jnp.zeros((m,))

    d_max = delay.max_ticks if delay is not None else 1
    buf0 = jnp.zeros((d_max, m), jnp.int32)

    state0 = PageState(tau_elap=jnp.zeros((m,)), n_cis=jnp.zeros((m,), jnp.int32))
    stale0 = jnp.zeros((m,), bool)
    counts0 = jnp.zeros((m,), jnp.int32)

    def step(carry, step_idx):
        state, stale, deadlines, buf, counts = carry
        k_ev = jax.random.fold_in(key, step_idx)

        # --- 1. policy decision at tick start ---
        if is_lds:
            scores = -deadlines
        else:
            scores = values_fn(state)
        if cfg.k_per_tick == 1 and k_schedule is None:
            sel = jnp.argmax(scores)
            crawled = jax.nn.one_hot(sel, m, dtype=bool)
            sel_pages = sel[None]
        else:
            _, sel_pages = jax.lax.top_k(scores, cfg.k_per_tick)
            if k_schedule is not None:
                # Elastic budget: top_k stays at the static cap; slots past
                # this tick's budget point at the out-of-range sentinel m,
                # which mode="drop" discards — so the budget is pure data.
                live = jnp.arange(cfg.k_per_tick) < k_schedule[step_idx]
                sel_pages = jnp.where(live, sel_pages, m)
            crawled = jnp.zeros((m,), bool).at[sel_pages].set(
                True, mode="drop")
            if k_schedule is not None:
                sel_pages = jnp.where(live, sel_pages, -1)

        # Crawl observations (what a production crawler would log).
        obs = None
        if cfg.record_obs:
            obs = (
                sel_pages,
                state.tau_elap[sel_pages],
                state.n_cis[sel_pages],
                (~stale[sel_pages]).astype(jnp.int32),
            )

        fresh_after_crawl = (~stale) | crawled
        if is_lds:
            deadlines = jnp.where(crawled, deadlines + period, deadlines)

        # --- 2. environment events during the tick ---
        tick_rates = rates_dt
        if modulation is not None and modulation.change_gain is not None:
            g = modulation.change_gain[step_idx]
            tick_rates = rates_dt * jnp.stack([g, g, jnp.ones_like(g)])
        cnt = _sample_counts(k_ev, tick_rates, mode)
        sig_changes, unsig_changes, false_cis = cnt[0], cnt[1], cnt[2]
        n_changes = sig_changes + unsig_changes
        gen_cis = sig_changes + false_cis
        if modulation is not None and modulation.cis_gain is not None:
            # Outage / thinning at the source: the change happened, the
            # signal never left the channel.
            gen_cis = jnp.round(
                gen_cis.astype(jnp.float32) * modulation.cis_gain[step_idx]
            ).astype(jnp.int32)

        # --- CIS delivery (possibly delayed) ---
        if delay is not None:
            arrivals = buf[step_idx % d_max]
            buf = buf.at[step_idx % d_max].set(0)
            lag = jnp.clip(
                jax.random.poisson(
                    jax.random.fold_in(k_ev, 1), delay.mean_ticks, (m,)
                ),
                1,
                d_max - 1,
            )
            buf = buf.at[((step_idx + lag) % d_max, jnp.arange(m))].add(gen_cis)
        else:
            arrivals = gen_cis

        # --- 3. freshness integral for this tick ---
        frac = jnp.where(
            fresh_after_crawl, 1.0 / (n_changes.astype(jnp.float32) + 1.0), 0.0
        )
        tick_fresh = jnp.sum(d_true.mu_t * frac)

        # --- state updates ---
        stale = (stale & ~crawled) | (n_changes > 0)
        tau0 = jnp.where(crawled, 0.0, state.tau_elap)
        n0 = jnp.where(crawled, 0, state.n_cis)
        if cfg.t_delay_filter > 0.0:
            keep = tau0 >= cfg.t_delay_filter
            arrivals = jnp.where(keep, arrivals, 0)
        state = PageState(tau_elap=tau0 + dt, n_cis=n0 + arrivals)
        counts = counts + crawled.astype(jnp.int32)

        out = (tick_fresh, obs) if cfg.record_obs else (tick_fresh, None)
        return (state, stale, deadlines, buf, counts), out

    carry0 = (state0, stale0, deadlines0, buf0, counts0)
    (state, stale, deadlines, buf, counts), (trace, obs) = jax.lax.scan(
        step, carry0, jnp.arange(cfg.n_steps)
    )
    return SimResult(
        accuracy=jnp.mean(trace),
        trace=trace,
        crawl_counts=counts,
        obs=obs,
    )
