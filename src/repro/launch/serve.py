"""Serving launcher: batched generation with the KV/state-cache engine.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-2.7b \
        --batch 4 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.configs.base import reduced
from repro.models import model as M
from repro.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    key = jax.random.PRNGKey(0)
    s_max = args.prompt_len + args.max_new
    params = M.init(key, cfg, max_seq=s_max)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.n_prefix, cfg.d_model))
    t0 = time.perf_counter()
    res = generate(cfg, params, batch, max_new=args.max_new,
                   temperature=args.temperature, top_k=50, key=key,
                   s_max=s_max)
    jax.block_until_ready(res.tokens)
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: {args.batch}x{args.max_new} tokens "
          f"in {dt:.2f}s ({args.batch*args.max_new/dt:.1f} tok/s)")
    print("[serve] sample:", res.tokens[0, args.prompt_len:].tolist())


if __name__ == "__main__":
    main()
