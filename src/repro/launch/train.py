"""Training launcher.

Local (CPU/1-host) runs execute reduced or full configs on whatever devices
exist; on a real fleet the same entrypoint builds the production mesh and
shards per DESIGN.md §4. Auto-resumes from the newest checkpoint (fault
tolerance: preempt/restart-safe).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --reduced --mesh local
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro import configs
from repro.configs.base import reduced
from repro.data import CrawlRefreshedCorpus, SyntheticLMData
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import model as M
from repro.optim import cosine_schedule, make_optimizer
from repro.train.step import TrainState, train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--data", choices=["synthetic", "crawl"], default="crawl")
    ap.add_argument("--mesh", choices=["local", "single", "multi"],
                    default="local")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = (make_local_mesh() if args.mesh == "local"
            else make_production_mesh(multi_pod=args.mesh == "multi"))

    if args.data == "crawl":
        data = CrawlRefreshedCorpus(m=2048, vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch)
        get_batch = lambda i: data.batch_at(i)[0]
    else:
        data = SyntheticLMData(cfg.vocab, args.seq, args.batch)
        get_batch = data.batch_at

    params = M.init(jax.random.PRNGKey(0), cfg, max_seq=args.seq)
    opt = make_optimizer(cfg.optimizer,
                         cosine_schedule(args.lr, 20, args.steps))
    state = TrainState(params=params, opt_state=opt.init(params),
                       step=jnp.int32(0))
    if args.ckpt_dir:
        restored, step0, _ = ckpt.restore_latest(args.ckpt_dir, state)
        if restored is not None:
            state = restored
            print(f"[train] resumed from step {step0}")

    step_fn = jax.jit(functools.partial(train_step, cfg, opt, mesh=mesh))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params on {mesh.size} device(s)")
    t0 = time.perf_counter()
    for i in range(int(state.step), args.steps):
        state, metrics = step_fn(state, get_batch(i))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"[train] step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['gnorm']):.2f}")
        if args.ckpt_dir and i and i % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i, state)
    print(f"[train] {args.steps} steps in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
