import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
cell with ShapeDtypeStruct inputs (no allocation), record memory analysis,
cost analysis, and the HLO-derived roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh single --out results/dryrun.json

The two os lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes need 512 host devices.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, get_shape
from repro.launch import specs as S
from repro.launch.hlo_cost import parse_hlo_cost
from repro.launch.mesh import make_production_mesh

# TPU v5e roofline constants (per chip).
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link (brief)


def mesh_for(name: str):
    return make_production_mesh(multi_pod=(name == "multi"))


def should_skip(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "full-attention arch: long_500k requires sub-quadratic decode"
    return None


def analyze(compiled, n_devices: int, seconds: float) -> dict:
    rec = {"compile_s": round(seconds, 1), "n_devices": n_devices}
    try:
        ca = compiled.cost_analysis()
        rec["cost_raw"] = {
            "flops": ca.get("flops"),
            "bytes": ca.get("bytes accessed"),
        }
    except Exception as e:  # pragma: no cover
        rec["cost_raw"] = {"error": str(e)}
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(ma, k)
        }
        rec["memory"]["per_device_total"] = (
            rec["memory"].get("argument_size_in_bytes", 0)
            + rec["memory"].get("temp_size_in_bytes", 0)
            + rec["memory"].get("output_size_in_bytes", 0)
            - rec["memory"].get("alias_size_in_bytes", 0)
        )
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}
    hlo = parse_hlo_cost(compiled.as_text(), n_devices)
    rec["hlo"] = {
        "flops": hlo["flops"],
        "bytes": hlo["bytes"],
        "collective_bytes": hlo["collective_bytes"],
        "collective_by_type": hlo["collective_by_type"],
    }
    rec["roofline_s"] = {
        "compute": hlo["flops"] / PEAK_FLOPS,
        "memory": hlo["bytes"] / HBM_BW,
        "collective": hlo["collective_bytes"] / ICI_BW,
    }
    dom = max(rec["roofline_s"], key=rec["roofline_s"].get)
    rec["bottleneck"] = dom
    return rec


def apply_variant(cfg, variant: str):
    """§Perf experiment variants (beyond-paper optimizations)."""
    import dataclasses
    from repro.models.common import set_sharding_profile

    set_sharding_profile("default")
    if not variant:
        return cfg
    for v in variant.split("+"):
        if v == "tp0":
            set_sharding_profile("dp_only")
        elif v.startswith("chunk"):
            cfg = dataclasses.replace(
                cfg, ssm=dataclasses.replace(cfg.ssm, chunk=int(v[5:])))
        elif v == "rmi":
            cfg = dataclasses.replace(cfg, remat="inner")
        elif v.startswith("micro"):
            cfg = dataclasses.replace(cfg, train_n_micro=int(v[5:]))
        elif v in ("sched-lean", "sched-series", "sched-lean-series"):
            pass  # handled in run_sched_cell
        else:
            raise ValueError(f"unknown variant {v!r}")
    return cfg


def run_cell(arch: str, shape_name: str, mesh_name: str, impl: str = "triangle",
             n_micro: int = 1, variant: str = "") -> dict:
    mesh = mesh_for(mesh_name)
    if arch == "paper-crawl":
        return run_sched_cell(mesh, mesh_name, variant)
    cfg = configs.get(arch)
    cfg = apply_variant(cfg, variant)
    shape = get_shape(shape_name)
    skip = should_skip(cfg, shape)
    if skip:
        return {"skipped": skip}
    t0 = time.time()
    if shape.kind == "train":
        n_micro = max(n_micro, cfg.train_n_micro)
        kw = {"impl": impl, "n_micro": n_micro}
    elif shape.kind == "prefill":
        kw = {"impl": impl}
    else:
        kw = {}
    fn, args = S.make_cell(cfg, shape, mesh, **kw)
    lowered = fn.lower(*args)
    compiled = lowered.compile()
    rec = analyze(compiled, mesh.size, time.time() - t0)
    rec["mesh"] = dict(zip(mesh.axis_names, mesh.devices.shape))
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
          f"compile {rec['compile_s']}s, bottleneck {rec['bottleneck']}, "
          f"terms {rec['roofline_s']}")
    mem = rec.get("memory", {})
    print(f"  memory_analysis: {mem}")
    print(f"  cost_analysis:   {rec['cost_raw']}")
    return rec


def run_sched_cell(mesh, mesh_name: str, variant: str = "") -> dict:
    """The paper's own production workload: billion-page scheduler round."""
    from repro.configs import paper_crawl as pc
    from repro.sched.distributed import sched_input_specs, sharded_crawl_step

    lean = "lean" in variant
    series = "series" in variant
    table_grid = None if series else pc.TABLE_GRID
    k_local = (8 * max(1, pc.SCHED_K // mesh.size)) if lean else None
    m = pc.PAGES_PER_CHIP * mesh.size
    state, new_cis, d, table = sched_input_specs(m, mesh, table_grid)
    t0 = time.time()
    fn = lambda st, nc, dd, tb: sharded_crawl_step(
        st, nc, dd, tb, mesh, pc.SCHED_K, 1.0, k_local=k_local
    )
    lowered = jax.jit(fn).lower(state, new_cis, d, table)
    compiled = lowered.compile()
    rec = analyze(compiled, mesh.size, time.time() - t0)
    rec["mesh"] = dict(zip(mesh.axis_names, mesh.devices.shape))
    rec["pages"] = m
    print(f"[dryrun] paper-crawl ({m/1e6:.0f}M pages) x {mesh_name}: "
          f"compile {rec['compile_s']}s, bottleneck {rec['bottleneck']}, "
          f"terms {rec['roofline_s']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, 'paper-crawl', or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--impl", default="triangle", choices=["triangle", "masked"])
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--variant", default="",
                    help="perf variant: tp0|chunkN|microN|sched-lean[-series]")
    args = ap.parse_args()

    archs = list(configs.ARCH_NAMES) + ["paper-crawl"] \
        if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape in (["sched"] if arch == "paper-crawl" else shapes):
            for mesh_name in meshes:
                key = f"{arch}|{shape}|{mesh_name}"
                if args.impl != "triangle" or args.n_micro != 1:
                    key += f"|{args.impl}|m{args.n_micro}"
                if args.variant:
                    key += f"|{args.variant}"
                try:
                    rec = run_cell(arch, shape, mesh_name, args.impl,
                                   args.n_micro, args.variant)
                except Exception as e:  # record failures, keep going
                    rec = {"error": f"{type(e).__name__}: {e}"}
                    print(f"[dryrun] {key} FAILED: {rec['error']}")
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, sort_keys=True)
    print(f"[dryrun] wrote {args.out}")


if __name__ == "__main__":
    main()
