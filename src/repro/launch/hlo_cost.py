"""Mini HLO cost model over `compiled.as_text()`.

Why: `compiled.cost_analysis()` visits each while-loop body once, so any
scan-over-layers program undercounts FLOPs/bytes/collectives by the trip
count (verified: an 8-step lax.scan of matmuls reports 1/8 of the unrolled
FLOPs). This walker parses the optimized HLO, accumulates per-computation
costs bottom-up, and multiplies while bodies by their
`backend_config known_trip_count`.

Cost model (per executed instruction):
  dot            flops = 2 * |result| * |contracted dims|;  bytes = operands + result
  fusion/most    bytes = operands + result (XLA's own fusion traffic model);
                 flops = |result| (elementwise estimate; dots dominate)
  gather/slice   bytes = result only (operand-bytes would massively overcount
                 embedding lookups)
  collectives    wire bytes per chip with ring formulas:
                 all-gather (n-1)/n * |result|; all-reduce 2(n-1)/n * |result|;
                 reduce-scatter (n-1) * |result|; all-to-all (n-1)/n * |result|;
                 collective-permute |result|.

All values are per device (the compiled module is the SPMD-partitioned,
per-device program).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?([%\w.\-]+)\s*=\s*(\([^)]*\)|\w+\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\("
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?([%\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "all-gather-start",
    "all-reduce-start", "collective-permute-start",
}
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "reshape",
    "broadcast", "transpose",  # usually layout no-ops or fused on TPU
}
_RESULT_ONLY_OPS = {"gather", "dynamic-slice", "slice", "pad", "concatenate",
                    "copy", "dynamic-update-slice"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_type: dict = field(default_factory=lambda: defaultdict(float))
    # sub-calls: (computation_name, multiplier)
    calls: list = field(default_factory=list)


def _operand_names(line: str, start: int) -> list[str]:
    # operands of the top-level op call: text within (...) opening at `start`
    depth = 0
    buf = ""
    for ch in line[start:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        if ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            buf += ch
    return [t.lstrip("%") for t in re.findall(r"[%\w.\-]+", buf)]


def parse_hlo_cost(text: str, n_partitions: int) -> dict:
    comps: dict[str, CompCost] = {}
    shapes: dict[str, str] = {}
    cur = None
    entry = None

    for line in text.splitlines():
        # Computation headers sit at column 0 (instructions are indented);
        # the header's type tuple may contain /*index=N*/ comments, so no
        # '='-based filtering.
        mc = _COMP_RE.match(line) if not line.startswith(" ") else None
        if mc:
            cur = mc.group(1).lstrip("%")
            comps[cur] = CompCost()
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.startswith("}"):
            continue
        mi = _INSTR_RE.match(line)
        if not mi or cur is None:
            continue
        name, rtype, op = mi.group(1).lstrip("%"), mi.group(2), mi.group(3)
        shapes[name] = rtype
        c = comps[cur]
        opstart = mi.end() - 1

        if op == "while":
            m = _TRIP_RE.search(line)
            trips = int(m.group(1)) if m else 1
            mb = re.search(r"body=([%\w.\-]+)", line)
            mcond = re.search(r"condition=([%\w.\-]+)", line)
            if mb:
                c.calls.append((mb.group(1).lstrip("%"), trips))
            if mcond:
                c.calls.append((mcond.group(1).lstrip("%"), trips + 1))
            continue
        if op in ("call", "conditional", "async-start"):
            for m in re.finditer(r"(?:to_apply|calls)=([%\w.\-]+)", line):
                c.calls.append((m.group(1).lstrip("%"), 1))
            continue
        if op == "fusion":
            m = re.search(r"calls=([%\w.\-]+)", line)
            if m:
                c.calls.append((m.group(1).lstrip("%"), 1))
            ops_b = sum(_shape_bytes(shapes.get(o, "")) for o in
                        _operand_names(line, opstart))
            c.bytes += _shape_bytes(rtype) + ops_b
            continue
        if op in _FREE_OPS:
            continue
        if op in _COLLECTIVES:
            n = _group_size(line, n_partitions)
            sz = _shape_bytes(rtype)
            kind = op.replace("-start", "")
            if kind == "all-gather":
                wire = sz * (n - 1) / max(n, 1)
            elif kind == "all-reduce":
                wire = 2 * sz * (n - 1) / max(n, 1)
            elif kind == "reduce-scatter":
                wire = sz * (n - 1)
            elif kind == "all-to-all":
                wire = sz * (n - 1) / max(n, 1)
            else:
                wire = sz
            c.coll_bytes += wire
            c.coll_by_type[kind] += wire
            c.bytes += sz
            continue
        if op == "dot":
            operands = _operand_names(line, opstart)
            lhs = shapes.get(operands[0], "") if operands else ""
            mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            contract = 1
            if mdims and lhs:
                dims_str = _SHAPE_RE.search(lhs)
                if dims_str:
                    lhs_dims = [int(d) for d in dims_str.group(2).split(",") if d]
                    for di in mdims.group(1).split(","):
                        if di:
                            contract *= lhs_dims[int(di)]
            c.flops += 2.0 * _shape_elems(rtype) * contract
            c.bytes += _shape_bytes(rtype) + sum(
                _shape_bytes(shapes.get(o, "")) for o in operands[:2]
            )
            continue
        if op in _RESULT_ONLY_OPS:
            c.bytes += _shape_bytes(rtype)
            c.flops += 0.0
            continue
        if op == "scatter":
            operands = _operand_names(line, opstart)
            c.bytes += _shape_bytes(rtype) + sum(
                _shape_bytes(shapes.get(o, "")) for o in operands[1:]
            )
            continue
        # default: elementwise-ish
        c.flops += _shape_elems(rtype)
        ops_b = sum(_shape_bytes(shapes.get(o, "")) for o in
                    _operand_names(line, opstart))
        c.bytes += _shape_bytes(rtype) + ops_b

    # bottom-up accumulation with memoization (call graph is a DAG)
    memo: dict[str, tuple] = {}

    def total(name: str):
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return (0.0, 0.0, 0.0, {})
        f, b, cb = c.flops, c.bytes, c.coll_bytes
        ct = dict(c.coll_by_type)
        for callee, mult in c.calls:
            sf, sb, scb, sct = total(callee)
            f += mult * sf
            b += mult * sb
            cb += mult * scb
            for k, v in sct.items():
                ct[k] = ct.get(k, 0.0) + mult * v
        memo[name] = (f, b, cb, ct)
        return memo[name]

    f, b, cb, ct = total(entry)
    return {
        "flops": f,
        "bytes": b,
        "collective_bytes": cb,
        "collective_by_type": ct,
        "entry": entry,
        "n_computations": len(comps),
    }
