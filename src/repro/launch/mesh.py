"""Production meshes.

Single pod: 16x16 = 256 chips (data=FSDP+batch, model=tensor).
Multi-pod:  2x16x16 = 512 chips; the extra leading "pod" axis is pure data
parallelism (batch + gradient all-reduce) so the only traffic that crosses the
pod boundary is one gradient reduction per step — weight/optimizer FSDP shards
stay inside a pod (see models.common.LOGICAL_RULES).

Defined as functions so importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever this host has — used by tests and CPU examples."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
