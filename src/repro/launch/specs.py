"""Abstract inputs (ShapeDtypeStruct + NamedSharding) and step callables for
every (architecture x input-shape x mesh) dry-run cell.

Sharding policy (see DESIGN.md §4):
  batch dims   -> ("pod", "data")                 (when divisible)
  KV caches    -> kv-heads over "model" when divisible, else KV sequence over
                  "model" (flash-decoding-style partial softmax via SPMD);
                  long_500k (batch=1) shards KV seq over ("data", "model").
  SSM states   -> batch over data; the widest inner dim over "model".
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCfg
from repro.models import model as M
from repro.models.common import pad_vocab
from repro.optim import make_optimizer
from repro.train.step import TrainState, train_step


def _axes(mesh: Mesh, *names):
    out = tuple(a for a in names if a in mesh.axis_names)
    return out or None


def _size(mesh: Mesh, axes) -> int:
    if not axes:
        return 1
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_spec_dim(mesh: Mesh, b: int):
    ax = _axes(mesh, "pod", "data")
    return ax if (ax and b % _size(mesh, ax) == 0) else None


def abstract_batch(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh,
                   with_labels: bool):
    b, s = shape.global_batch, shape.seq_len
    bs = batch_spec_dim(mesh, b)
    out = {"tokens": sds((b, s), jnp.int32, mesh, P(bs, None))}
    if with_labels:
        out["labels"] = sds((b, s), jnp.int32, mesh, P(bs, None))
    if cfg.frontend == "audio_frames":
        out["frames"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16, mesh,
                            P(bs, None, None))
    if cfg.frontend == "vision_patches":
        out["patches"] = sds((b, cfg.n_prefix, cfg.d_model), jnp.bfloat16,
                             mesh, P(bs, None, None))
    return out


def abstract_params(cfg: ModelConfig, max_seq: int, mesh: Mesh):
    pspecs = M.specs(cfg, max_seq, mesh)
    shapes = jax.eval_shape(lambda k: M.init(k, cfg, max_seq),
                            jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda sd, sp: jax.ShapeDtypeStruct(
            sd.shape, sd.dtype, sharding=NamedSharding(mesh, sp)
        ),
        shapes, pspecs,
    ), pspecs


def _drop_dim(spec: P, dim: int, ndim: int) -> P:
    t = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
    return P(*(t[:dim] + t[dim + 1:]))


def abstract_opt_state(optimizer_name: str, params_abs, pspecs, mesh: Mesh):
    opt = make_optimizer(optimizer_name)
    shapes = jax.eval_shape(opt.init, params_abs)
    if optimizer_name == "adamw":
        sspecs = {"m": pspecs, "v": pspecs}
    else:  # adafactor: factored states drop one of the two trailing dims
        def st_spec(sd, sp):
            if sd.ndim < 2:
                return {"v": sp}
            return {"vr": _drop_dim(sp, sd.ndim - 1, sd.ndim),
                    "vc": _drop_dim(sp, sd.ndim - 2, sd.ndim)}

        sspecs = {"s": jax.tree.map(st_spec, params_abs, pspecs)}
    return jax.tree.map(
        lambda sd, sp: jax.ShapeDtypeStruct(
            sd.shape, sd.dtype, sharding=NamedSharding(mesh, sp)
        ),
        shapes, sspecs,
    )


# ---------------------------------------------------------------------------
# decode cache shardings


def _kv_spec(cfg: ModelConfig, mesh: Mesh, b: int, s: int, lead: int):
    """Spec for (lead..., B, S, Hkv, hd)."""
    model = mesh.shape.get("model", 1)
    bs = batch_spec_dim(mesh, b)
    lead_dims = (None,) * lead
    if cfg.n_kv_heads % model == 0 and cfg.n_kv_heads >= model:
        return P(*lead_dims, bs, None, _axes(mesh, "model"), None)
    if bs is None:  # batch=1 long-context: shard seq over everything
        both = _axes(mesh, "data", "model")
        if both and s % _size(mesh, both) == 0:
            return P(*lead_dims, None, both, None, None)
    if s % model == 0:
        return P(*lead_dims, bs, _axes(mesh, "model"), None, None)
    return P(*lead_dims, bs, None, None, None)


def abstract_cache(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh):
    b, s_max = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        functools.partial(M.init_cache, cfg, b, s_max)
    )
    model = mesh.shape.get("model", 1)
    bs = batch_spec_dim(mesh, b)

    def spec_of(path, sd):
        name = path[0].name if hasattr(path[0], "name") else str(path[0])
        if name in ("k", "v", "xk", "xv"):
            lead = sd.ndim - 4
            return _kv_spec(cfg, mesh, b, sd.shape[-3], lead)
        if name == "pos":
            return P()
        # ssm states: (lead..., B, inner...) — batch over data, widest inner
        # dim over model when divisible.
        dims = [None] * sd.ndim
        for i, n in enumerate(sd.shape):
            if n == b and bs is not None:
                dims[i] = bs
                break
        best, best_i = 0, None
        for i in range(sd.ndim - 1, -1, -1):
            if dims[i] is None and sd.shape[i] % model == 0 and sd.shape[i] >= model:
                if sd.shape[i] > best:
                    best, best_i = sd.shape[i], i
        if best_i is not None:
            dims[best_i] = _axes(mesh, "model")
        return P(*dims)

    flat, treedef = jax.tree.flatten_with_path(cache)
    out = [
        jax.ShapeDtypeStruct(
            sd.shape, sd.dtype,
            sharding=NamedSharding(mesh, spec_of(path, sd)),
        )
        for path, sd in flat
    ]
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# dry-run cells


def make_train_cell(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh,
                    impl: str = "triangle", n_micro: int = 1):
    params_abs, pspecs = abstract_params(cfg, shape.seq_len, mesh)
    opt_abs = abstract_opt_state(cfg.optimizer, params_abs, pspecs, mesh)
    state_abs = TrainState(params=params_abs, opt_state=opt_abs,
                           step=jax.ShapeDtypeStruct(
                               (), jnp.int32,
                               sharding=NamedSharding(mesh, P())))
    batch_abs = abstract_batch(cfg, shape, mesh, with_labels=True)
    opt = make_optimizer(cfg.optimizer)

    def fn(state, batch):
        return train_step(cfg, opt, state, batch, mesh=mesh, impl=impl,
                          n_micro=n_micro)

    return jax.jit(fn, donate_argnums=(0,)), (state_abs, batch_abs)


def make_prefill_cell(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh,
                      impl: str = "triangle"):
    params_abs, _ = abstract_params(cfg, shape.seq_len, mesh)
    batch_abs = abstract_batch(cfg, shape, mesh, with_labels=False)

    def fn(params, batch):
        return M.prefill(cfg, params, batch, s_max=shape.seq_len, mesh=mesh,
                         impl=impl)

    return jax.jit(fn), (params_abs, batch_abs)


def make_decode_cell(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh):
    b = shape.global_batch
    params_abs, _ = abstract_params(cfg, shape.seq_len, mesh)
    cache_abs = abstract_cache(cfg, shape, mesh)
    bs = batch_spec_dim(mesh, b)
    token_abs = sds((b, 1), jnp.int32, mesh, P(bs, None))
    pos_abs = sds((), jnp.int32, mesh, P())

    def fn(params, token, pos, cache):
        return M.decode_step(cfg, params, token, pos, cache, mesh=mesh)

    return jax.jit(fn, donate_argnums=(3,)), (params_abs, token_abs, pos_abs,
                                              cache_abs)


def make_cell(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh, **kw):
    if shape.kind == "train":
        return make_train_cell(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return make_prefill_cell(cfg, shape, mesh, **kw)
    return make_decode_cell(cfg, shape, mesh)
