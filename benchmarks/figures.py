"""Paper-figure benchmarks (one function per paper table/figure).

Each reproduces the corresponding experimental protocol of Section 6 /
appendices; `REPRO_BENCH_PROFILE=paper` runs the full published sizes, the
default `quick` profile shrinks horizons/reps (same distributions) for CI.
Rows: name,us_per_call,derived (derived = accuracies etc.).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policies as pol
from repro.core import solver
from repro.core.values import Env, derive
from repro.sim import (
    DelayConfig,
    SimConfig,
    corrupt_precision_recall,
    env_from_precision_recall,
    realworld_instance,
    simulate,
    uniform_instance,
)
from repro.sim.simulator import simulate_delayed
from benchmarks.common import emit, mean_sem, prof


def _run_policy(key, env, policy, cfg, **kw):
    t0 = time.perf_counter()
    res = simulate(key, env, policy, cfg, **kw)
    acc = float(res.accuracy)
    return acc, (time.perf_counter() - t0) * 1e6


def fig2_greedy_vs_lds():
    """Fig. 2: discrete policies without CIS vs the continuous optimum."""
    R = 100
    T = prof(100, 1000)
    reps = prof(5, 20)
    for m in prof([100, 300], [100, 200, 300, 500, 1000]):
        cfg = SimConfig(dt=1.0 / R, n_steps=R * T)
        accs = {"greedy": [], "lds": [], "baseline": []}
        us = 0.0
        for r in range(reps):
            key = jax.random.PRNGKey(1000 + r)
            env = uniform_instance(key, m, with_cis=False)
            sol = solver.solve_continuous_nocis(env, R)
            accs["baseline"].append(float(sol.objective))
            a, t = _run_policy(jax.random.fold_in(key, 1), env, pol.GREEDY, cfg)
            accs["greedy"].append(a)
            us += t
            a, _ = _run_policy(jax.random.fold_in(key, 2), env, pol.LDS, cfg,
                               lds_rates=sol.rate)
            accs["lds"].append(a)
        d = ";".join(f"{k}={mean_sem(v)[0]:.4f}+-{mean_sem(v)[1]:.4f}"
                     for k, v in accs.items())
        emit(f"fig2/m{m}", us / reps, d)


def fig3_partial_cis():
    """Fig. 3: GREEDY vs GREEDY-CIS under partially observable changes."""
    R = 100
    T = prof(100, 1000)
    reps = prof(5, 20)
    for m in prof([100, 300], [100, 200, 300, 500, 1000]):
        cfg = SimConfig(dt=1.0 / R, n_steps=R * T)
        accs = {"greedy": [], "greedy_cis": [], "baseline_cis": []}
        us = 0.0
        for r in range(reps):
            key = jax.random.PRNGKey(2000 + r)
            env = uniform_instance(key, m, with_cis=True,
                                   nu_range=(0.0, 0.0))  # no false positives
            sol = solver.solve_continuous(env, R)
            accs["baseline_cis"].append(float(sol.objective))
            a, t = _run_policy(jax.random.fold_in(key, 1), env, pol.GREEDY, cfg)
            accs["greedy"].append(a)
            a, t2 = _run_policy(jax.random.fold_in(key, 2), env,
                                pol.GREEDY_CIS, cfg)
            accs["greedy_cis"].append(a)
            us += t2
        d = ";".join(f"{k}={mean_sem(v)[0]:.4f}+-{mean_sem(v)[1]:.4f}"
                     for k, v in accs.items())
        emit(f"fig3/m{m}", us / reps, d)


def fig4_noisy_cis():
    """Fig. 4: noisy CIS (false positives) — all policies, m sweep."""
    R = 100
    T = prof(50, 1000)
    reps = prof(3, 20)
    policies = [pol.GREEDY, pol.GREEDY_CIS, pol.GREEDY_NCIS,
                pol.G_NCIS_APPROX_1, pol.G_NCIS_APPROX_2]
    for m in prof([100, 300, 1000], [100, 200, 500, 750, 1000, 10000]):
        cfg = SimConfig(dt=1.0 / R, n_steps=R * T)
        accs = {p: [] for p in policies}
        accs["baseline"] = []
        us = 0.0
        for r in range(reps):
            key = jax.random.PRNGKey(3000 + r)
            env = uniform_instance(key, m)
            sol = solver.solve_continuous(env, R)
            accs["baseline"].append(float(sol.objective))
            for i, p in enumerate(policies):
                a, t = _run_policy(jax.random.fold_in(key, i), env, p, cfg)
                accs[p].append(a)
                if p == pol.GREEDY_NCIS:
                    us += t
        d = ";".join(f"{k}={mean_sem(v)[0]:.4f}+-{mean_sem(v)[1]:.4f}"
                     for k, v in accs.items())
        emit(f"fig4/m{m}", us / reps, d)


def fig5_realworld():
    """Fig. 5 (Section 6.7): semi-synthetic real-world instance with
    heavy-tailed precision/recall and corrupted estimates."""
    m = prof(20_000, 100_000)
    budget = prof(1000, 5000)
    steps = 200
    reps = prof(2, 10)
    for p_corrupt in [0.0, 0.1, 0.2]:
        accs = {"greedy": [], "greedy_ncis": [], "greedy_cis_plus": []}
        us = 0.0
        for r in range(reps):
            key = jax.random.PRNGKey(4000 + r)
            inst = realworld_instance(key, m)
            cfg = SimConfig(dt=1.0, n_steps=steps, k_per_tick=budget,
                            count_mode="poisson")
            # corrupted estimates -> the policy's beliefs
            cp, cr = corrupt_precision_recall(
                jax.random.fold_in(key, 9), inst.precision, inst.recall,
                p_corrupt,
            )
            belief = env_from_precision_recall(
                inst.env.delta, inst.env.mu, cp, cr
            )
            qmask = (cp > 0.7) & (cr > 0.6)
            a, _ = _run_policy(jax.random.fold_in(key, 1), inst.env,
                               pol.GREEDY, cfg)
            accs["greedy"].append(a)
            t0 = time.perf_counter()
            res = simulate(jax.random.fold_in(key, 2), inst.env,
                           pol.GREEDY_NCIS, cfg, belief=belief)
            us += (time.perf_counter() - t0) * 1e6
            accs["greedy_ncis"].append(float(res.accuracy))
            res = simulate(jax.random.fold_in(key, 3), inst.env,
                           pol.GREEDY_CIS_PLUS, cfg, belief=belief,
                           quality_mask=qmask)
            accs["greedy_cis_plus"].append(float(res.accuracy))
        d = ";".join(f"{k}={mean_sem(v)[0]:.4f}+-{mean_sem(v)[1]:.4f}"
                     for k, v in accs.items())
        emit(f"fig5/corrupt{p_corrupt}", us / reps, d)


def fig8_delayed_cis():
    """App. C / Fig. 8: delayed CIS and the discard heuristic."""
    R = 100
    T = prof(50, 1000)
    reps = prof(3, 20)
    delay = DelayConfig(mean_ticks=6.0, max_ticks=32)
    for m in prof([100, 300], [100, 200, 500, 1000]):
        cfg = SimConfig(dt=1.0 / R, n_steps=R * T)
        cfg_d = cfg._replace(t_delay_filter=5.0 / R)
        accs = {"ncis_nodelay": [], "ncis_delayed": [], "ncis_d_filter": []}
        us = 0.0
        for r in range(reps):
            key = jax.random.PRNGKey(5000 + r)
            env = uniform_instance(key, m)
            a, _ = _run_policy(jax.random.fold_in(key, 1), env,
                               pol.GREEDY_NCIS, cfg)
            accs["ncis_nodelay"].append(a)
            t0 = time.perf_counter()
            res = simulate_delayed(jax.random.fold_in(key, 2), env,
                                   pol.GREEDY_NCIS, cfg, delay)
            us += (time.perf_counter() - t0) * 1e6
            accs["ncis_delayed"].append(float(res.accuracy))
            res = simulate_delayed(jax.random.fold_in(key, 3), env,
                                   pol.GREEDY_NCIS, cfg_d, delay)
            accs["ncis_d_filter"].append(float(res.accuracy))
        d = ";".join(f"{k}={mean_sem(v)[0]:.4f}+-{mean_sem(v)[1]:.4f}"
                     for k, v in accs.items())
        emit(f"fig8/m{m}", us / reps, d)


def fig9_elastic_bandwidth():
    """App. D / Fig. 9: bandwidth 100 -> 150 -> 100 with zero recomputation."""
    m = prof(300, 1000)
    R1, R2 = 100, 150
    T_seg = prof(40, 133)
    key = jax.random.PRNGKey(6000)
    env = uniform_instance(key, m)
    segs = []
    t0 = time.perf_counter()
    from repro.core.state import PageState
    # run three segments, carrying state (the policy itself has no state
    # beyond (tau, n_cis) — that is the point of App. D)
    accs = []
    for i, R in enumerate([R1, R2, R1]):
        cfg = SimConfig(dt=1.0 / R, n_steps=R * T_seg)
        res = simulate(jax.random.fold_in(key, i), env, pol.GREEDY, cfg)
        accs.append(float(jnp.mean(res.trace[res.trace.shape[0] // 2:])))
    us = (time.perf_counter() - t0) * 1e6
    # steady-state references
    ref1 = simulate(jax.random.fold_in(key, 10), env, pol.GREEDY,
                    SimConfig(dt=1.0 / R1, n_steps=R1 * T_seg))
    ref2 = simulate(jax.random.fold_in(key, 11), env, pol.GREEDY,
                    SimConfig(dt=1.0 / R2, n_steps=R2 * T_seg))
    d = (f"seg100={accs[0]:.4f};seg150={accs[1]:.4f};segback={accs[2]:.4f};"
         f"ref100={float(ref1.accuracy):.4f};ref150={float(ref2.accuracy):.4f}")
    emit("fig9/elastic", us, d)


def appe_estimation():
    """App. E: naive vs MLE estimation of CIS precision/recall."""
    from repro.core.estimation import fit_mle, naive_precision_recall

    reps = prof(20, 200)
    horizon = prof(20_000, 100_000)
    rng = np.random.default_rng(0)
    errs_naive, errs_mle = [], []
    t0 = time.perf_counter()
    for r in range(reps):
        precision = rng.uniform(0.2, 0.95)
        recall = rng.uniform(0.2, 0.95)
        delta = 1.0 / rng.uniform(2, 20)
        crawl_rate = delta * rng.uniform(0.25, 4.0)
        lam = recall
        gamma = lam * delta / precision
        nu = gamma - lam * delta
        # simulate intervals between crawls ~ Exp(crawl_rate)
        n_int = max(50, int(horizon * crawl_rate / 10))
        tau = rng.exponential(1.0 / crawl_rate, n_int)
        changes = rng.poisson(delta * tau)
        signaled = rng.binomial(changes, lam)
        false = rng.poisson(nu * tau)
        n_cis = signaled + false
        fresh = (changes == 0).astype(np.int32)
        p_n, r_n = naive_precision_recall(
            jnp.asarray(n_cis)[None], jnp.asarray(changes)[None]
        )
        errs_naive.append(abs(float(p_n[0]) - precision)
                          + abs(float(r_n[0]) - recall))
        q = fit_mle(jnp.asarray(tau, jnp.float32), jnp.asarray(n_cis),
                    jnp.asarray(fresh), jnp.float32(gamma), steps=300)
        errs_mle.append(abs(float(q.precision) - precision)
                        + abs(float(q.recall) - recall))
    us = (time.perf_counter() - t0) / reps * 1e6
    emit("appe/estimation", us,
         f"naive_l1={np.mean(errs_naive):.4f};mle_l1={np.mean(errs_mle):.4f}")


ALL = [fig2_greedy_vs_lds, fig3_partial_cis, fig4_noisy_cis, fig5_realworld,
       fig8_delayed_cis, fig9_elastic_bandwidth, appe_estimation]
