"""Roofline table from the dry-run JSON (deliverable (g)).

Per (arch x shape x mesh): the three per-chip roofline terms in seconds,
the dominant bottleneck, MODEL_FLOPS (6ND / 2ND), the useful-compute ratio
MODEL/HLO, and the roofline fraction = model-compute-time / dominant-term
(this is the §Perf score). Writes results/roofline.md and prints CSV rows.
"""
from __future__ import annotations

import json
import os

from repro import configs
from repro.configs.base import SHAPES, get_shape
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS
from benchmarks.analytic import model_flops
from benchmarks.common import emit


def build_table(path="results/dryrun.json", out_md="results/roofline.md",
                variants_path="results/variants.json"):
    if not os.path.exists(path):
        emit("roofline/missing", 0.0, f"no {path}; run the dry-run sweep first")
        return []
    with open(path) as f:
        res = json.load(f)
    if os.path.exists(variants_path):
        with open(variants_path) as f:
            res.update(json.load(f))
    rows = []
    for key, rec in sorted(res.items()):
        parts = key.split("|")
        if len(parts) < 3:
            continue
        arch, shape_name, mesh = parts[0], parts[1], parts[2]
        if "skipped" in rec:
            rows.append({"key": key, "skipped": rec["skipped"]})
            continue
        if "error" in rec or "roofline_s" not in rec:
            rows.append({"key": key, "error": rec.get("error", "?")})
            continue
        terms = rec["roofline_s"]
        n_dev = rec.get("n_devices", 256)
        dom = rec["bottleneck"]
        dom_t = terms[dom]
        row = {"key": key, "arch": arch, "shape": shape_name, "mesh": mesh,
               "terms": terms, "bottleneck": dom, "n_devices": n_dev,
               "memory_gb": rec.get("memory", {}).get("per_device_total", 0)
               / 1e9}
        if arch != "paper-crawl":
            mf = model_flops(configs.get(arch), get_shape(shape_name))
            mf_dev = mf / n_dev
            row["model_flops_dev"] = mf_dev
            row["useful_ratio"] = (mf_dev / rec["hlo"]["flops"]
                                   if rec["hlo"]["flops"] else 0.0)
            row["roofline_frac"] = (mf_dev / PEAK_FLOPS) / dom_t if dom_t else 0.0
        else:
            row["roofline_frac"] = terms["compute"] / dom_t if dom_t else 0.0
            row["useful_ratio"] = 1.0
        rows.append(row)

    lines = [
        "| cell | bottleneck | compute s | memory s | collective s | "
        "mem GB/chip | MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "terms" not in r:
            note = r.get("skipped", r.get("error", ""))
            lines.append(f"| {r['key']} | — | | | | | | {note} |")
            continue
        t = r["terms"]
        lines.append(
            f"| {r['key']} | {r['bottleneck']} | {t['compute']:.4f} | "
            f"{t['memory']:.4f} | {t['collective']:.4f} | "
            f"{r['memory_gb']:.2f} | {r.get('useful_ratio', 0):.3f} | "
            f"{r.get('roofline_frac', 0):.4f} |"
        )
    os.makedirs(os.path.dirname(out_md) or ".", exist_ok=True)
    with open(out_md, "w") as f:
        f.write("\n".join(lines) + "\n")
    for r in rows:
        if "terms" in r:
            emit(f"roofline/{r['key']}", 0.0,
                 f"bottleneck={r['bottleneck']};frac={r.get('roofline_frac', 0):.4f};"
                 f"useful={r.get('useful_ratio', 0):.3f}")
    return rows


if __name__ == "__main__":
    build_table()
