"""Adversarial scenario-grid benchmark: degraded-mode scheduling under
hostile signal ecosystems.

Grid: {clean, outage, bursty, flash_crowd, mixed_channel} scenarios x the
three CIS-quality tiers of `sim.tiered_cis_instance` ({reliable, noisy,
silent}), driven through the closed loop (`sim.run_closed_loop`) on a
`sim.multichannel_instance` whose channels are block-aligned — the
granularity the degraded-mode watchdog detects. Each cell runs TWICE, with
and without `FusedBackend(degraded=True)`, and reports per-tier normalized
freshness plus the fairness ratio (worst-tier / best-tier freshness).

Hard gates (AssertionError fails the bench run / CI):

  (1) clean scenario: degraded mode is BIT-IDENTICAL to today's path when
      every channel is healthy — same crawls page-for-page, same freshness
      trace, to the last bit.
  (2) outage + mixed_channel scenarios: degraded mode STRICTLY improves
      the worst-tier freshness of the pages the outage actually censors —
      the CIS-dependent tiers (reliable, noisy) on the dark channel,
      scored during its dark window. (The silent tier never receives
      signals, so it is definitionally outside an outage's blast radius;
      and losing signals fleet-wide accidentally *flattens* allocation,
      so the global worst tier is not the mitigation's target.) Aggregate
      freshness must stay within 10% of no-mitigation.
  (3) the staleness-watchdog plane costs <= 5% round overhead at
      m = 2^18 (quick) on healthy feeds — interleaved per-round medians,
      selections verified bit-identical first — and the degraded macro
      scan runs under a poisoned `jax.device_get` (zero host syncs).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, prof
from repro.sched import backends as be
from repro.sched.service import CrawlScheduler
from repro.sim import (
    LoopConfig,
    TIER_NAMES,
    faults,
    multichannel_instance,
    run_closed_loop,
    uniform_instance,
)

SKIP_FRAC = 0.25   # transient ticks dropped from per-tier means
N_TIERS = len(TIER_NAMES)
N_CH = 3           # channels of `multichannel_instance` (DEFAULT_CHANNELS)


def _cell_freshness(res, mass, lo, hi):
    """(N_CH * N_TIERS,) normalized freshness per (channel, tier) cell over
    ticks [lo, hi) — cell c*N_TIERS+t is tier t's pages on channel c."""
    got = res.group_freshness[lo:hi].mean(axis=0)
    return got / np.maximum(mass, 1e-12)


def _tier_freshness(cells, mass):
    """Collapse (channel, tier) cells to per-tier normalized freshness."""
    w = (cells * mass).reshape(N_CH, N_TIERS).sum(axis=0)
    return w / np.maximum(mass.reshape(N_CH, N_TIERS).sum(axis=0), 1e-12)


def _scenarios(n_total, m, channels):
    """The scenario grid: name -> (cis_mask, rate_gain, outage_windows)."""
    ch = np.asarray(channels)
    mid = (n_total // 4, 3 * n_total // 4)

    def mask_from(windows):
        sched = faults.OutageSchedule(
            windows=tuple(faults.OutageWindow(c, a, b)
                          for c, a, b in windows))
        deliv = sched.delivery_mask(n_total)          # (rounds, channels)
        return deliv[:, ch]                           # (rounds, m)

    rng = np.random.default_rng(7)
    burst = faults.hawkes_change_counts(
        rng, np.array([1.0]), n_total, excite=0.5, decay=0.6)[:, 0]
    burst = np.maximum(burst.astype(np.float64), 0.0)
    burst = burst / max(burst.mean(), 1e-9)           # bursty, mean ~ 1

    third = n_total // 3
    staggered = [(0, 0, third), (1, third, 2 * third),
                 (2, 2 * third, n_total)]
    return {
        "clean": (None, None, []),
        "outage": (mask_from([(0, *mid)]), None, [(0, *mid)]),
        "bursty": (None, burst, []),
        "flash_crowd": (None, faults.flash_crowd_profile(
            n_total, [(third, third + max(2, n_total // 8), 4.0)]), []),
        "mixed_channel": (mask_from(staggered), None, staggered),
    }


def _worst_censored(res, mass, windows):
    """Worst normalized freshness over the pages an outage actually
    censors: the CIS-dependent tiers (all but `silent`) on each dark
    channel, scored during that channel's dark window."""
    worst = np.inf
    for c, a, b in windows:
        cells = _cell_freshness(res, mass, a, b)
        worst = min(worst, float(
            cells[c * N_TIERS:c * N_TIERS + N_TIERS - 1].min()))
    return worst


def scenario_bench():
    m = prof(2048, 8192)
    k, R, dt = 32, 8, 0.5
    NB = prof(12, 40)
    n_total = NB * R
    mesh = jax.make_mesh((1,), ("data",))
    # Channels in contiguous 256-page runs = exactly one selection block
    # each at block_rows=2, so outages are block-coherent.
    inst = multichannel_instance(jax.random.PRNGKey(1), m, span=256)
    tier = np.asarray(inst.tier)
    chan = np.asarray(inst.channels)
    groups = (chan * N_TIERS + tier).astype(np.int64)
    mu = np.asarray(inst.env.mu, np.float64)
    mu_t = mu / max(mu.sum(), 1e-12)
    mass = np.bincount(groups, weights=mu_t, minlength=N_CH * N_TIERS)

    def build(degraded):
        return CrawlScheduler(
            inst.env, mesh, bandwidth=float(k) / dt, round_period=dt,
            backend=be.FusedBackend(block_rows=2, adaptive_bounds=True,
                                    degraded=degraded, stale_limit=3))

    t0 = time.time()
    grid = {}
    scen = _scenarios(n_total, m, inst.channels)
    for name, (mask, gain, _wins) in scen.items():
        cfg = LoopConfig(n_batches=NB, rounds_per_batch=R, seed=5,
                         cis_mask=mask, rate_gain=gain)
        runs = {}
        for mode in ("off", "on"):
            res = run_closed_loop(build(mode == "on"), inst.env, cfg,
                                  groups=groups)
            skip = int(n_total * SKIP_FRAC)
            cells = _cell_freshness(res, mass, skip, n_total)
            runs[mode] = (res, _tier_freshness(cells, mass))
        grid[name] = runs

    # --- Gate (1): healthy channels -> bit-identical scheduling ---------
    off, on = grid["clean"]["off"][0], grid["clean"]["on"][0]
    assert np.array_equal(off.crawls, on.crawls), (
        "degraded mode changed crawl selections on healthy channels")
    assert np.array_equal(off.freshness, on.freshness), (
        "degraded mode changed the freshness trace on healthy channels")

    # --- Gate (2): outage scenarios -> strict worst-tier improvement for
    # the censored pages (CIS-dependent tiers on the dark channel, scored
    # during its dark window), without tanking the aggregate. -----------
    for name in ("outage", "mixed_channel"):
        wins = scen[name][2]
        worst_off = _worst_censored(grid[name]["off"][0], mass, wins)
        worst_on = _worst_censored(grid[name]["on"][0], mass, wins)
        assert worst_on > worst_off, (
            f"{name}: degraded mode did not improve the censored pages' "
            f"worst-tier freshness ({worst_on:.4f} vs {worst_off:.4f} "
            "without mitigation)")
        agg_off = grid[name]["off"][0].freshness[n_total // 4:].mean()
        agg_on = grid[name]["on"][0].freshness[n_total // 4:].mean()
        assert agg_on >= 0.9 * agg_off, (
            f"{name}: degraded mode cost {1 - agg_on / agg_off:.1%} "
            "aggregate freshness, over the 10% budget")

    loop_us = (time.time() - t0) * 1e6 / (10 * n_total)
    for name, runs in grid.items():
        tf_off, tf_on = runs["off"][1], runs["on"][1]
        fair_off = float(tf_off.min() / max(tf_off.max(), 1e-12))
        fair_on = float(tf_on.min() / max(tf_on.max(), 1e-12))
        tiers = ";".join(
            f"{t}_on={tf_on[i]:.4f};{t}_off={tf_off[i]:.4f}"
            for i, t in enumerate(TIER_NAMES))
        extra = ""
        if scen[name][2]:
            extra = (f";censored_worst_on="
                     f"{_worst_censored(runs['on'][0], mass, scen[name][2]):.4f}"
                     f";censored_worst_off="
                     f"{_worst_censored(runs['off'][0], mass, scen[name][2]):.4f}")
        emit(f"sched/scenario_{name}", loop_us,
             f"m={m};R={R};batches={NB};{tiers};"
             f"fairness_on={fair_on:.3f};fairness_off={fair_off:.3f};"
             f"worst_tier_on={tf_on.min():.4f};"
             f"worst_tier_off={tf_off.min():.4f}{extra}")

    _overhead_gate()


def _overhead_gate():
    """Gate (3): the staleness plane rides the donated scan for <= 5% round
    overhead on healthy feeds, bit-identically, with zero host syncs."""
    m = prof(1 << 18, 1 << 20)
    k, R, dt = 256, 32, 1.0
    mesh = jax.make_mesh((1,), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), m)
    order = jnp.argsort(-(env.mu / env.delta))
    env = jax.tree.map(lambda x: x[order], env)
    tau0 = jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=2.0)

    def build(degraded):
        s = CrawlScheduler(env, mesh, bandwidth=float(k) / dt,
                           round_period=dt,
                           backend=be.FusedBackend(adaptive_bounds=True,
                                                   degraded=degraded,
                                                   stale_limit=8),
                           feed_cap=4096)
        s.round = dataclasses.replace(s.round, tau_elap=jnp.copy(tau0))
        return s

    # Healthy feeds: every block signalled every round (no block within
    # stale_limit of silence), so degraded mode must match bit for bit.
    bp = 8 * 128
    rng = np.random.default_rng(0)
    feeds_np = np.zeros((R, m), np.int32)
    feeds_np[:, ::bp] = 1
    for r in range(R):
        idx = rng.choice(m, 64, replace=False)
        feeds_np[r, idx] = rng.poisson(2.0, 64).astype(np.int32) + 1

    off, on = build(False), build(True)
    ids_f, vals_f = off.run_rounds(np.copy(feeds_np))
    ids_d, vals_d = on.run_rounds(np.copy(feeds_np))
    assert np.array_equal(np.asarray(ids_f), np.asarray(ids_d)), (
        "degraded selections diverged from the healthy path")
    assert np.array_equal(np.asarray(vals_f), np.asarray(vals_d))

    # Zero host syncs inside the degraded macro scan.
    real = jax.device_get

    def die(*a, **kw):  # pragma: no cover - only on regression
        raise AssertionError("host sync inside the degraded macro-round")

    jax.device_get = die
    try:
        _, v = on.run_rounds(np.copy(feeds_np))
        jax.block_until_ready(v)
    finally:
        jax.device_get = real
    off.run_rounds(np.copy(feeds_np))    # donated-state signature warmup

    reps = prof(5, 7)
    t_off, t_on = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        _, v = on.run_rounds(np.copy(feeds_np))
        jax.block_until_ready(v)
        t_on.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _, v = off.run_rounds(np.copy(feeds_np))
        jax.block_until_ready(v)
        t_off.append(time.perf_counter() - t0)
    us_on = float(np.median(t_on)) / R * 1e6
    us_off = float(np.median(t_off)) / R * 1e6
    overhead = us_on / us_off - 1.0
    assert overhead <= 0.05, (
        f"staleness watchdog costs {overhead:.1%} round overhead, over "
        "the 5% budget")
    emit("sched/degraded_overhead", us_on,
         f"m={m};k={k};R={R};pages_per_s={m / (us_on / 1e6):.3e};"
         f"overhead_vs_off={overhead:.3f};healthy_bit_identical=1;"
         f"host_syncs_per_round=0")


if __name__ == "__main__":
    scenario_bench()
