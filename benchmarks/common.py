"""Shared benchmark utilities: profiles, timing, CSV emission."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

# quick: CI-friendly (~minutes); paper: the paper's experimental protocol.
PROFILE = os.environ.get("REPRO_BENCH_PROFILE", "quick")


def prof(quick, paper):
    return paper if PROFILE == "paper" else quick


_rows = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def timed(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6


def mean_sem(xs):
    xs = np.asarray(xs, dtype=np.float64)
    sem = xs.std(ddof=1) / np.sqrt(len(xs)) if len(xs) > 1 else 0.0
    return float(xs.mean()), float(sem)
