"""Shared benchmark utilities: profiles, timing, CSV emission + JSON persist."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

# quick: CI-friendly (~minutes); paper: the paper's experimental protocol.
PROFILE = os.environ.get("REPRO_BENCH_PROFILE", "quick")

# Machine-readable perf trajectory, kept across PRs (committed after bench
# runs; CI uploads it as an artifact). One row per emit() of the last run.
BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_kernel.json")


def prof(quick, paper):
    return paper if PROFILE == "paper" else quick


_rows = None  # lazily seeded from the existing file so partial runs
              # (e.g. --only kernel) update their rows without clobbering
              # the rest of the committed trajectory


def _load_rows():
    global _rows
    if _rows is None:
        _rows = []
        try:
            with open(BENCH_JSON) as f:
                _rows = json.load(f).get("rows", [])
        except (OSError, ValueError):
            pass
    return _rows


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    rows = _load_rows()
    row = {"name": name, "us_per_call": round(us_per_call, 1),
           "derived": derived}
    for i, r in enumerate(rows):
        if r["name"] == name:
            rows[i] = row
            break
    else:
        rows.append(row)
    try:
        with open(BENCH_JSON, "w") as f:
            json.dump({"profile": PROFILE, "rows": rows}, f, indent=1)
    except OSError:
        pass  # read-only checkouts still get the CSV on stdout


def timed(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6


def mean_sem(xs):
    xs = np.asarray(xs, dtype=np.float64)
    sem = xs.std(ddof=1) / np.sqrt(len(xs)) if len(xs) > 1 else 0.0
    return float(xs.mean()), float(sem)
