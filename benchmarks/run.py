"""Benchmark harness: one entry per paper table/figure + kernel/scheduler
microbenchmarks + the roofline table (reads the dry-run JSON).

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig4] \
        [REPRO_BENCH_PROFILE=quick|paper]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated substring filters")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]

    from benchmarks import figures, kernel_bench, roofline, scenario_bench

    jobs = [(f.__name__, f) for f in figures.ALL]
    jobs += [("kernel_bench", kernel_bench.kernel_bench),
             ("sched_bench", kernel_bench.sched_bench),
             ("scenario_bench", scenario_bench.scenario_bench),
             ("roofline", roofline.build_table)]

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in jobs:
        if only and not any(o in name for o in only):
            continue
        try:
            fn()
        except AssertionError:  # correctness gates must fail the run
            raise
        except Exception as e:  # keep the harness going
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
