"""Analytic MODEL_FLOPS (the 6ND / 2ND convention) per (arch x shape).

N = parameters that participate in matmuls: total params minus embedding
tables/positions, plus the LM-head matrix (once — tied or not), with routed
MoE expert weights scaled by top_k/n_experts (active experts only).
Attention score/value FLOPs and remat recompute are intentionally excluded —
the MODEL_FLOPS/HLO_FLOPS ratio in the roofline table surfaces exactly that
overhead (brief: "how much of compiled compute is useful").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ModelConfig, ShapeCfg
from repro.models import model as M
from repro.models.common import pad_vocab


def _sizes_by_path(cfg: ModelConfig, max_seq: int):
    shapes = jax.eval_shape(lambda k: M.init(k, cfg, max_seq),
                            jax.random.PRNGKey(0))
    flat, _ = jax.tree.flatten_with_path(shapes)
    out = []
    for path, sd in flat:
        keys = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append(("/".join(keys), sd.size))
    return out


def param_counts(cfg: ModelConfig, max_seq: int = 4096):
    total = emb = routed = 0
    for path, size in _sizes_by_path(cfg, max_seq):
        total += size
        if path.startswith("embed/tok") or path.startswith("embed/pos"):
            emb += size
        if "/moe/experts/" in path:
            routed += size
    head = pad_vocab(cfg.vocab) * cfg.d_model  # logits matmul params
    mm_total = total - emb + head
    active = mm_total
    if cfg.moe is not None and routed:
        active = mm_total - routed + routed * cfg.moe.top_k / cfg.moe.n_experts
    return {"total": total, "matmul": mm_total, "active": active,
            "routed": routed, "embed": emb}


def model_flops(cfg: ModelConfig, shape: ShapeCfg) -> float:
    """Global useful FLOPs for one step of this cell."""
    n = param_counts(cfg, max_seq=shape.seq_len)["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
