"""Crawl-value evaluation microbenchmarks: the paper's per-tick hot path.

Compares the four evaluation strategies at production shard sizes:
  gammainc  exact igamma special function (solver-grade)
  series    K-term Taylor ladder (the Pallas kernel's algorithm, jnp)
  table     exposure-grid interpolation (App. G tiering, our TPU adaptation)
  pallas    the actual kernel body in interpret mode (correctness-grade only
            on CPU; compiled Mosaic on TPU)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import derive, tables
from repro.core.values import tau_eff, value_ncis
from repro.sim import uniform_instance
from benchmarks.common import emit, prof, timed


def kernel_bench():
    m = prof(1 << 18, 1 << 22)
    env = uniform_instance(jax.random.PRNGKey(0), m)
    d = derive(env)
    tau = jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=30.0)
    n = jax.random.poisson(jax.random.PRNGKey(2), 2.0, (m,)).astype(jnp.int32)

    gam = jax.jit(lambda t, nn: value_ncis(tau_eff(t, nn, d), d, 8, "gamma"))
    ser = jax.jit(lambda t, nn: value_ncis(tau_eff(t, nn, d), d, 8, "series"))
    table = tables.build_ncis_table(d, n_terms=8)
    tab = jax.jit(lambda t, nn: tables.lookup_state(table, d, t, nn))

    ref, us_g = timed(gam, tau, n, reps=1)
    v_s, us_s = timed(ser, tau, n, reps=3)
    v_t, us_t = timed(tab, tau, n, reps=3)
    err_s = float(jnp.max(jnp.abs(v_s - ref)))
    err_t = float(jnp.max(jnp.abs(v_t - ref)))
    emit("kernel/gammainc", us_g, f"m={m};exact")
    emit("kernel/series", us_s,
         f"m={m};speedup={us_g/us_s:.1f}x;max_err={err_s:.2e}")
    emit("kernel/table", us_t,
         f"m={m};speedup={us_g/us_t:.1f}x;max_err={err_t:.2e}")

    from repro.kernels import ops
    mk = prof(1 << 16, 1 << 18)
    dk = jax.tree.map(lambda x: x[:mk], d)
    vk, us_k = timed(
        lambda t, nn: ops.crawl_value(t, nn, dk, n_terms=8), tau[:mk], n[:mk],
        reps=1,
    )
    err_k = float(jnp.max(jnp.abs(vk - ref[:mk])))
    emit("kernel/pallas_interpret", us_k, f"m={mk};max_err={err_k:.2e}")


def sched_bench():
    """Sharded scheduler round + tiered-selection quality."""
    import numpy as np
    from repro.core.state import PageState
    from repro.sched.distributed import ShardedSchedState, sharded_crawl_step
    from repro.sched.tiered import init_tiers, tiered_select

    m = prof(1 << 18, 1 << 21)
    k = 256
    mesh = jax.make_mesh((1,), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), m)
    d = derive(env)
    table = tables.build_ncis_table(d, n_grid=64)
    state = ShardedSchedState(
        tau_elap=jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=10.0),
        n_cis=jnp.zeros((m,), jnp.int32),
        crawl_clock=jnp.int32(0),
    )
    zero = jnp.zeros((m,), jnp.int32)
    step = lambda st: sharded_crawl_step(st, zero, d, table, mesh, k, 0.01)[0]
    _, us = timed(step, state, reps=3)
    emit("sched/round", us, f"m={m};k={k};pages_per_s={m/(us/1e6):.3e}")

    # tiered selection: agreement + compute saved over a rolling horizon
    # (pages grouped into value tiers, as the paper's production system does)
    order = jnp.argsort(-(env.mu / env.delta))
    env_t = jax.tree.map(lambda x: x[order], env)
    d = derive(env_t)
    table = tables.build_ncis_table(d, n_grid=64)
    state = state._replace(tau_elap=state.tau_elap[order])
    tiers = init_tiers(d, block=4096)
    tau = state.tau_elap
    n = state.n_cis
    agree, saved = [], []
    for rnd in range(1, prof(20, 100)):
        exact_v, exact_i = jax.lax.top_k(
            tables.lookup_state(table, d, tau, n), k)
        tv, ti, tiers, frac = tiered_select(
            tau, n, d, table, tiers, jnp.int32(rnd), 0.01, k)
        inter = len(set(np.asarray(ti).tolist())
                    & set(np.asarray(exact_i).tolist()))
        agree.append(inter / k)
        saved.append(1.0 - float(frac))
        # crawl the tiered selection, advance time
        tau = tau.at[ti].set(0.0) + 0.01
    emit("sched/tiered", 0.0,
         f"overlap@k={np.mean(agree):.3f};eval_saved={np.mean(saved):.3f}")
