"""Crawl-value evaluation microbenchmarks: the paper's per-tick hot path.

`kernel_bench` compares the four value-evaluation strategies at production
shard sizes:
  gammainc  exact igamma special function (solver-grade)
  series    K-term Taylor ladder (the Pallas kernel's algorithm, jnp)
  table     exposure-grid interpolation (App. G tiering, our TPU adaptation)
  pallas    the actual kernel body in interpret mode (correctness-grade only
            on CPU; compiled Mosaic on TPU)

`sched_bench` measures full scheduling rounds, including the headline
fused-select comparison at m = 2^20 (quick) / 2^22 (paper):
  sched/round_seed   the seed pipeline — dense per-page values + full-m top_k
                     (the m-element value vector round-trips HBM)
  sched/round_fused  packed PageShard + fused single-pass select with static
                     asymptote block bounds and a warm-started threshold;
                     derived column reports pages/s, speedup, the analytic
                     HBM bytes/page, the active-block fraction, and the
                     number of exact-recovery fallbacks observed.
Selections are verified identical between the two paths before timing.

`fused_adaptive_bench` (also run by the CI bench-smoke via `kernel_bench`)
adds sched/round_fused_adaptive: the closed skip-control loop
(`FusedBackend(adaptive_bounds=True)` — refreshing BlockBounds folded back
in-jit + adaptive per-shard hysteresis) against an identically-seeded
static-asymptote scheduler, reporting the extra skip rate
(frac_active vs frac_active_static), fallback counts, and the state-plane
donation alias — with both selections gated identical to dense top-k first.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import derive, tables
from repro.core.values import tau_eff, value_ncis
from repro.sim import uniform_instance
from benchmarks.common import emit, prof, timed


def kernel_bench():
    m = prof(1 << 18, 1 << 22)
    env = uniform_instance(jax.random.PRNGKey(0), m)
    d = derive(env)
    tau = jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=30.0)
    n = jax.random.poisson(jax.random.PRNGKey(2), 2.0, (m,)).astype(jnp.int32)

    gam = jax.jit(lambda t, nn: value_ncis(tau_eff(t, nn, d), d, 8, "gamma"))
    ser = jax.jit(lambda t, nn: value_ncis(tau_eff(t, nn, d), d, 8, "series"))
    table = tables.build_ncis_table(d, n_terms=8)
    tab = jax.jit(lambda t, nn: tables.lookup_state(table, d, t, nn))

    ref, us_g = timed(gam, tau, n, reps=1)
    v_s, us_s = timed(ser, tau, n, reps=3)
    v_t, us_t = timed(tab, tau, n, reps=3)
    err_s = float(jnp.max(jnp.abs(v_s - ref)))
    err_t = float(jnp.max(jnp.abs(v_t - ref)))
    emit("kernel/gammainc", us_g, f"m={m};exact")
    emit("kernel/series", us_s,
         f"m={m};speedup={us_g/us_s:.1f}x;max_err={err_s:.2e}")
    emit("kernel/table", us_t,
         f"m={m};speedup={us_g/us_t:.1f}x;max_err={err_t:.2e}")

    from repro.kernels import ops
    mk = prof(1 << 16, 1 << 18)
    dk = jax.tree.map(lambda x: x[:mk], d)
    vk, us_k = timed(
        lambda t, nn: ops.crawl_value(t, nn, dk, n_terms=8), tau[:mk], n[:mk],
        reps=1,
    )
    err_k = float(jnp.max(jnp.abs(vk - ref[:mk])))
    emit("kernel/pallas_interpret", us_k, f"m={mk};max_err={err_k:.2e}")

    refresh_repack_bench()
    fused_adaptive_bench()
    macro_round_bench()
    ckpt_roundtrip_bench()
    online_est_bench()
    elastic_bandwidth_bench()
    request_path_bench()


def refresh_repack_bench():
    """Block-granular parameter refresh (`CrawlScheduler.update_pages`) vs a
    full `pack_shard`: scatter the touched plane columns + refresh only the
    touched blocks' bounds, with the packed tensor donated (in-place)."""
    import numpy as np
    from repro.kernels import layout

    m = prof(1 << 20, 1 << 22)
    env = uniform_instance(jax.random.PRNGKey(0), m)
    d = derive(env)
    shard = layout.pack_shard(d)
    n_upd = m // 100
    ids = jnp.asarray(
        np.sort(np.random.default_rng(0).choice(m, n_upd, replace=False)),
        jnp.int32,
    )
    d_rows = jax.tree.map(lambda x: x[ids], d)
    blk = jnp.asarray(np.unique(np.asarray(ids) // shard.block_pages),
                      jnp.int32)
    bounds = layout.asym_block_bounds(shard.env)

    # Full repack baseline: d passed as a real argument (a closed-over d
    # would constant-fold the entire pack at trace time).
    pack = jax.jit(lambda dd: layout.pack_shard(dd).env)
    _, us_full = timed(pack, d, reps=prof(2, 3))

    repack = jax.jit(
        lambda e, b, i, dr, bl: (
            lambda e2: (e2, layout.refresh_block_bounds(e2, b, bl))
        )(layout.repack_pages(e, i, dr)),
        donate_argnums=(0, 1),
    )
    e, b = jnp.copy(shard.env), jnp.copy(bounds)
    e, b = repack(e, b, ids, d_rows, blk)  # compile
    p0 = e.unsafe_buffer_pointer()
    jax.block_until_ready(e)
    import time as _time
    reps = prof(10, 20)
    t0 = _time.perf_counter()
    for _ in range(reps):
        e, b = repack(e, b, ids, d_rows, blk)
    jax.block_until_ready(e)
    us_part = (_time.perf_counter() - t0) / reps * 1e6
    # No-copy accounting: the donated packed tensor must alias through.
    aliased = e.unsafe_buffer_pointer() == p0
    assert aliased, "repack copied the donated env planes"
    emit(
        "sched/refresh_repack", us_part,
        f"m={m};upd_frac=0.01;blocks_touched={blk.shape[0]}/{shard.n_blocks};"
        f"speedup_vs_full_pack={us_full / us_part:.1f}x;"
        f"bytes_per_update={layout.bytes_per_update(shard.n_terms)};"
        f"donated_alias={int(aliased)}",
    )


def _fused_round_loop(sched, zero, n_rounds, warm_rounds=2):
    """Run donated backend rounds (the warm-start threshold is carried inside
    the RoundState); returns seconds_per_round. warm_rounds covers compile +
    threshold seeding; the adaptive-bounds loop needs a few more rounds for
    the block anchors to populate before steady-state timing."""
    for _ in range(warm_rounds):
        _, v = sched.ingest_and_schedule(zero)
    jax.block_until_ready(v)
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        _, v = sched.ingest_and_schedule(zero)
    jax.block_until_ready(v)
    return (time.perf_counter() - t0) / n_rounds


def fused_adaptive_bench():
    """The closed skip-control loop (`sched/round_fused_adaptive`): adaptive
    BlockBounds + per-shard hysteresis vs the static asymptote bound, on the
    same value-tiered instance and warm state trajectory. Rounds run at a
    short period (the production regime where values regrow over many rounds
    — with dt ~ 1 the slope bound saturates at the asymptote and the
    refreshing bound degenerates to the static one). Reports the extra skip
    rate, fallback frequency, and asserts both selection exactness vs dense
    top-k and the state-plane donation aliasing."""
    import dataclasses

    import numpy as np

    from repro.sched import backends as be
    from repro.sched.service import CrawlScheduler
    from repro.kernels import layout

    m = prof(1 << 20, 1 << 22)
    k = 256
    dt = 0.05
    mesh = jax.make_mesh((1,), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), m)
    # Value-correlated blocks (the paper's production tiers).
    order = jnp.argsort(-(env.mu / env.delta))
    env = jax.tree.map(lambda x: x[order], env)
    tau0 = jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=2.0)
    zero = jnp.zeros((m,), jnp.int32)

    def build(backend):
        s = CrawlScheduler(env, mesh, bandwidth=float(k), round_period=dt,
                           backend=backend)
        s.round = dataclasses.replace(s.round, tau_elap=jnp.copy(tau0))
        return s

    adaptive = build(be.FusedBackend(adaptive_bounds=True))
    static = build(be.FusedBackend())
    dense = build(be.DenseBackend())

    # Correctness gate: adaptive == static == dense selection, round by round
    # (including the rounds that warm the thresholds and bound anchors).
    for r in range(4):
        ids_a, _ = adaptive.ingest_and_schedule(zero)
        ids_s, _ = static.ingest_and_schedule(zero)
        ids_d, _ = dense.ingest_and_schedule(zero)
        assert (set(np.asarray(ids_a).tolist())
                == set(np.asarray(ids_d).tolist())), \
            f"adaptive selection diverged from dense top-k (round {r})"
        assert (set(np.asarray(ids_s).tolist())
                == set(np.asarray(ids_d).tolist())), \
            f"static selection diverged from dense top-k (round {r})"

    p_env = adaptive.round.backend.env_planes.unsafe_buffer_pointer()
    n_rounds = prof(12, 20)
    # Warm both (compile + populate thresholds/anchors), then time the two
    # loops INTERLEAVED round by round and take per-round MEDIANS so
    # host-load drift and spikes cancel out of the adaptive-vs-static
    # comparison.
    for s in (adaptive, static):
        for _ in range(10):
            _, v = s.ingest_and_schedule(zero)
        jax.block_until_ready(v)
    times = ([], [])
    fell = [0, 0]
    for _ in range(n_rounds):
        for i, s in enumerate((adaptive, static)):
            t0 = time.perf_counter()
            _, v = s.ingest_and_schedule(zero)
            jax.block_until_ready(v)
            times[i].append((time.perf_counter() - t0) * 1e6)
            # fallback FREQUENCY across the timed rounds (a last-round
            # snapshot could hide fallback churn inflating the medians)
            fell[i] += int(np.asarray(s.round.backend.fell_back).any())
    us = [float(np.median(t)) for t in times]
    frac = [float(s.round.backend.frac_active.mean())
            for s in (adaptive, static)]
    aliased = (adaptive.round.backend.env_planes.unsafe_buffer_pointer()
               == p_env)
    assert aliased, "adaptive crawl_round copied the donated env planes"
    assert frac[0] < frac[1], (
        f"adaptive bounds did not increase the skip rate: "
        f"frac_active={frac[0]:.3f} vs static {frac[1]:.3f}"
    )
    bpp = layout.bytes_per_page(adaptive.backend.n_terms)
    emit("sched/round_fused_adaptive", us[0],
         f"m={m};k={k};dt={dt};pages_per_s={m/(us[0]/1e6):.3e};"
         f"frac_active={frac[0]:.3f};frac_active_static={frac[1]:.3f};"
         f"extra_skip={frac[1]-frac[0]:.3f};"
         f"hbm_bytes_per_page={bpp*frac[0]:.1f};"
         f"fallback_rounds={fell[0]}/{n_rounds};"
         f"hyst={float(adaptive.round.backend.hyst[0]):.2f};"
         f"speedup_vs_static_bound={us[1]/us[0]:.2f}x;"
         f"state_planes_donated_alias={int(aliased)}")


def ckpt_roundtrip_bench():
    """Per-host shard checkpoint round-trip (`sched/ckpt_roundtrip`):
    `state_dict` -> sharded-v1 `save` -> `restore_latest` ->
    `load_state_dict` on a warm fused scheduler.

    Guards: (1) no-global-gather — `jax.device_get` is poisoned for the
    whole round trip, so neither save nor restore may assemble a global
    array through the public gather path (per-host shard files only);
    (2) restore-equivalence — the restored scheduler's next macro-round
    selection must be bit-identical to the original's."""
    import tempfile

    import numpy as np

    from repro.checkpoint import store as ckpt_store
    from repro.sched import backends as be
    from repro.sched.service import CrawlScheduler

    m = prof(1 << 18, 1 << 20)
    k, dt, R = 256, 1.0, 8
    mesh = jax.make_mesh((1,), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), m)

    def build():
        return CrawlScheduler(env, mesh, bandwidth=float(k) / dt,
                              round_period=dt,
                              backend=be.FusedBackend(adaptive_bounds=True),
                              feed_cap=4096)

    s = build()
    rng = np.random.default_rng(0)
    feeds_np = np.zeros((R, m), np.int32)
    for r in range(R):
        feeds_np[r, rng.choice(m, 64, replace=False)] = 1
    s.run_rounds(np.copy(feeds_np))

    tmp = tempfile.mkdtemp(prefix="ckpt_bench_")
    s2 = build()

    def die(*_a, **_kw):
        raise AssertionError(
            "checkpoint round-trip called jax.device_get (global gather)")

    real, jax.device_get = jax.device_get, die
    try:
        _, us_save = timed(
            lambda: ckpt_store.save(tmp, 1, s.state_dict(), sharded=True),
            reps=prof(3, 5))
        (tree, step, _), us_rest = timed(
            lambda: ckpt_store.restore_latest(tmp, s2.state_dict()),
            reps=prof(3, 5))
        assert step == 1
        s2.load_state_dict(tree)
    finally:
        jax.device_get = real

    nxt = np.zeros((R, m), np.int32)
    for r in range(R):
        nxt[r, rng.choice(m, 64, replace=False)] = 1
    ia, va = s.run_rounds(np.copy(nxt))
    ib, vb = s2.run_rounds(np.copy(nxt))
    equiv = int(np.array_equal(np.asarray(ia), np.asarray(ib))
                and np.array_equal(np.asarray(va), np.asarray(vb)))
    assert equiv, "restored scheduler diverged from the original"

    n_leaves = len(jax.tree.leaves(s.state_dict()))
    emit("sched/ckpt_roundtrip", us_save + us_rest,
         f"m={m};k={k};leaves={n_leaves};save_us={us_save:.1f};"
         f"restore_us={us_rest:.1f};restore_equivalent={equiv};"
         f"no_global_gather=1")


def macro_round_bench():
    """The macro-round scan pipeline (`sched/macro_round`): R rounds under
    one jitted donated `lax.scan` (`CrawlScheduler.run_rounds`) vs R
    sequential `ingest_and_schedule` calls at identical seeds/feeds.

    Guards, in order: (1) the stacked macro selection must be BIT-IDENTICAL
    to the sequential loop round by round; (2) the feed batch must enter the
    jitted macro-round as runtime parameters — a closed-over batch would be
    constant-folded at trace time and the scan timing would be meaningless;
    (3) the donated packed env planes must alias through the whole
    macro-round (no state-plane copy). Also emits
    `sched/round_fused_adaptive_sparse`: the CIS-mass re-evaluation rule vs
    the PR-3 blanket re-mark on the same sparse feed, both gated exact."""
    import dataclasses

    import numpy as np

    from repro.sched import backends as be
    from repro.sched.service import CrawlScheduler

    m = prof(1 << 20, 1 << 22)
    k = 256
    R = 32
    dt = 1.0
    mesh = jax.make_mesh((1,), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), m)
    # Value-correlated blocks (the paper's production tiers).
    order = jnp.argsort(-(env.mu / env.delta))
    env = jax.tree.map(lambda x: x[order], env)
    tau0 = jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=2.0)

    def build(feed_cap=None, **kw):
        s = CrawlScheduler(env, mesh, bandwidth=float(k) / dt,
                           round_period=dt,
                           backend=be.FusedBackend(adaptive_bounds=True,
                                                   **kw),
                           feed_cap=feed_cap)
        s.round = dataclasses.replace(s.round, tau_elap=jnp.copy(tau0))
        return s

    seq, mac = build(), build()
    # Sparse CIS feed batch, identical for both paths: ~64 signalled pages
    # per round (the production regime the sparse macro ingest targets).
    rng = np.random.default_rng(0)
    nnz = 64
    feeds_np = np.zeros((R, m), np.int32)
    for r in range(R):
        idx = rng.choice(m, nnz, replace=False)
        feeds_np[r, idx] = rng.poisson(2.0, nnz).astype(np.int32) + 1
    feeds = jnp.asarray(feeds_np)

    # Guard (2): the feed batch reaches the compiled macro-round as runtime
    # parameters (REPRO memory: closed-over inputs constant-fold and the
    # "timed" call is a memcpy). The sparse (ids, counts) arrays must both
    # appear in the entry computation's signature.
    sf = mac._sparse_feed_batch(feeds)
    n_sh, cap = sf.ids.shape[1], sf.ids.shape[2]
    lowered = be.crawl_rounds.lower(
        mac.backend, mac.round, sf, mesh=mesh, k=mac.k_per_round, dt=dt)
    import re

    txt = lowered.as_text()
    n_feed_params = len(re.findall(
        rf"%arg\d+: tensor<{R}x{n_sh}x{cap}xi32>", txt))
    assert n_feed_params >= 2, (
        "feed batch is not a jit argument of the macro-round — timings "
        "would be constant-folded fiction")

    # Guard (1): stacked macro selection == R sequential rounds, bit-exact
    # (this also compiles + warms both paths on the same trajectory).
    p_env = mac.round.backend.env_planes.unsafe_buffer_pointer()
    ids_m, vals_m = mac.run_rounds(feeds)
    ids_m, vals_m = np.asarray(ids_m), np.asarray(vals_m)
    for r in range(R):
        ids_s, vals_s = seq.ingest_and_schedule(feeds[r])
        assert np.array_equal(ids_m[r], np.asarray(ids_s)), (
            f"macro selection diverged from sequential at round {r}")
        assert np.array_equal(vals_m[r], np.asarray(vals_s)), r

    # Timing: interleaved reps, per-round medians.
    reps = prof(5, 7)
    ts, tm = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        for r in range(R):
            _, v = seq.ingest_and_schedule(feeds[r])
        jax.block_until_ready(v)
        ts.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _, v = mac.run_rounds(feeds)
        jax.block_until_ready(v)
        tm.append(time.perf_counter() - t0)
    us_seq = float(np.median(ts)) / R * 1e6
    us_mac = float(np.median(tm)) / R * 1e6
    # Guard (3): no state-plane copy across the whole run.
    aliased = mac.round.backend.env_planes.unsafe_buffer_pointer() == p_env
    assert aliased, "macro-round copied the donated env planes"

    # Guard (4): the per-host capacity contract — with feed_cap pinned, a
    # hot-shard feed batch (32x the steady nnz) must reuse the compiled
    # macro-round bit for bit: zero recompiles across hot-shard feed
    # rounds. (Without the contract the pow2 capacity bucket grows and
    # re-jits — on a multi-process mesh, on every host.)
    capd = build(feed_cap=4096)
    capd.run_rounds(np.copy(feeds_np))
    capd.run_rounds(np.copy(feeds_np))  # donated state now committed
    c0 = be.crawl_rounds._cache_size()
    hot_np = np.zeros((R, m), np.int32)
    hot_np[:, :2048] = 1
    capd.run_rounds(hot_np)
    no_rejit = int(be.crawl_rounds._cache_size() == c0)
    assert no_rejit, (
        "hot-shard feed batch re-jitted the macro-round despite the "
        "feed_cap contract")

    frac = float(np.asarray(mac.macro_diagnostics.frac_active).mean())
    emit("sched/macro_round", us_mac,
         f"m={m};k={k};R={R};dt={dt};pages_per_s={m/(us_mac/1e6):.3e};"
         f"speedup_vs_sequential={us_seq/us_mac:.2f}x;"
         f"seq_us_per_round={us_seq:.1f};frac_active={frac:.3f};"
         f"feed_nnz_per_round={nnz};feeds_as_jit_args=1;exact_equal=1;"
         f"state_planes_donated_alias={int(aliased)};"
         f"feed_cap_no_rejit_hot_shard={no_rejit}")

    # --- CIS-mass rule vs blanket re-mark on the same sparse feed --------
    mass_s = build()
    remark_s = build(cis_rule="remark")
    dense_s = CrawlScheduler(env, mesh, bandwidth=float(k) / dt,
                             round_period=dt, backend=be.DenseBackend())
    dense_s.round = dataclasses.replace(dense_s.round,
                                        tau_elap=jnp.copy(tau0))
    n_rounds = prof(24, 40)
    rng = np.random.default_rng(1)
    fr = {"mass": [], "remark": []}
    for r in range(n_rounds):
        feed = np.zeros((m,), np.int32)
        idx = rng.choice(m, 8, replace=False)  # a few weak signals/round
        feed[idx] = 1
        feed = jnp.asarray(feed)
        ids_a, _ = mass_s.ingest_and_schedule(feed)
        ids_b, _ = remark_s.ingest_and_schedule(feed)
        if r < 4:  # exactness gate on the warming rounds
            ids_d, _ = dense_s.ingest_and_schedule(feed)
            assert set(np.asarray(ids_a).tolist()) \
                == set(np.asarray(ids_d).tolist()), r
            assert set(np.asarray(ids_b).tolist()) \
                == set(np.asarray(ids_d).tolist()), r
        fr["mass"].append(float(mass_s.round.backend.frac_active.mean()))
        fr["remark"].append(float(remark_s.round.backend.frac_active.mean()))
    f_mass = float(np.mean(fr["mass"][-n_rounds // 2:]))
    f_remark = float(np.mean(fr["remark"][-n_rounds // 2:]))
    assert f_mass < f_remark, (
        f"CIS-mass rule did not out-skip the blanket re-mark: "
        f"{f_mass:.3f} vs {f_remark:.3f}")
    emit("sched/round_fused_adaptive_sparse", 0.0,
         f"m={m};k={k};dt={dt};feed_nnz_per_round=8;"
         f"frac_active_mass={f_mass:.3f};frac_active_remark={f_remark:.3f};"
         f"extra_skip={f_remark - f_mass:.3f};selection_exact=1")


def elastic_bandwidth_bench():
    """Elastic bandwidth (`sched/elastic_bandwidth`): the k_max cap
    contract end to end — per-round budgets and the token-bucket rate as
    traced operands of the compiled macro-round.

    Three hard gates:
      (1) no_rejit_on_bandwidth_change=1 — after warm-up, a 4-point
          `set_bandwidth` sweep (and a budget-vector sweep) leaves the
          `crawl_rounds` jit cache flat: rate changes are pure data;
      (2) window_spike_free=1 — under emission="smooth" at a fractional
          rate, realized crawls over EVERY window of W rounds stay within
          +-1 of rate * W, for all W in {4, 16, 64};
      (3) overhead: dynamic-k rounds (budgets pinned at k, selection
          bit-identical to fixed-k) cost <= 5% over the fixed-k scan on
          identical feeds — the masking is where-ops on k-element
          vectors, invisible next to the O(m) value pass."""
    import dataclasses

    import numpy as np

    from repro.sched import backends as be
    from repro.sched.service import CrawlScheduler

    m = prof(1 << 18, 1 << 20)
    k, R, dt = 256, 32, 1.0
    mesh = jax.make_mesh((1,), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), m)
    order = jnp.argsort(-(env.mu / env.delta))
    env = jax.tree.map(lambda x: x[order], env)
    tau0 = jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=2.0)

    def build(**kw):
        s = CrawlScheduler(env, mesh, bandwidth=float(k) / dt,
                           round_period=dt,
                           backend=be.FusedBackend(adaptive_bounds=True),
                           feed_cap=4096, **kw)
        s.round = dataclasses.replace(s.round, tau_elap=jnp.copy(tau0))
        return s

    rng = np.random.default_rng(0)
    feeds_np = np.zeros((R, m), np.int32)
    for r in range(R):
        idx = rng.choice(m, 64, replace=False)
        feeds_np[r, idx] = rng.poisson(2.0, 64).astype(np.int32) + 1

    # --- Gate (3) setup: fixed-k vs budgets-at-k, identical feeds --------
    fixed, elastic = build(), build(k_max=k)
    buds = np.full(R, k)
    ids_f, vals_f = fixed.run_rounds(np.copy(feeds_np))
    ids_e, vals_e = elastic.run_rounds(np.copy(feeds_np), budgets=buds)
    # Correctness gate first: constant budgets == fixed-k, bit for bit.
    assert np.array_equal(np.asarray(ids_f), np.asarray(ids_e)), \
        "budgets pinned at k diverged from the fixed-k selection"
    assert np.array_equal(np.asarray(vals_f), np.asarray(vals_e))
    fixed.run_rounds(np.copy(feeds_np))          # donated-state signatures
    elastic.run_rounds(np.copy(feeds_np), budgets=buds)
    reps = prof(5, 7)
    t_f, t_e = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        _, v = elastic.run_rounds(np.copy(feeds_np), budgets=buds)
        jax.block_until_ready(v)
        t_e.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _, v = fixed.run_rounds(np.copy(feeds_np))
        jax.block_until_ready(v)
        t_f.append(time.perf_counter() - t0)
    us_e = float(np.median(t_e)) / R * 1e6
    us_f = float(np.median(t_f)) / R * 1e6
    overhead = us_e / us_f - 1.0
    assert overhead <= 0.05, (
        f"dynamic-k budgets cost {overhead:.1%} over the fixed-k scan, "
        "over the 5% budget")

    # --- Gates (1) + (2): smooth emission, swept mid-flight --------------
    smooth = build(k_max=k, emission="smooth")
    rate0 = 0.4 * k + 0.5                        # fractional crawls/round
    smooth.set_bandwidth(rate0 / dt)
    smooth.run_rounds(np.copy(feeds_np))
    smooth.run_rounds(np.copy(feeds_np))         # warm both signatures
    c0 = be.crawl_rounds._cache_size()
    counts = []
    sweep = (rate0 / 2, rate0, rate0 * 2, float(k))
    for bw in sweep:
        smooth.set_bandwidth(bw / dt)
        ids, _ = smooth.run_rounds(np.copy(feeds_np))
        counts.append(np.asarray((np.asarray(ids) >= 0).sum(axis=1)))
    no_rejit = int(be.crawl_rounds._cache_size() == c0)
    assert no_rejit, (
        "a set_bandwidth sweep re-jitted the macro-round despite the "
        "k_max contract")
    # Budget vectors are the same compiled entry: still no growth.
    elastic.run_rounds(np.copy(feeds_np),
                       budgets=rng.integers(0, k + 1, R))
    assert be.crawl_rounds._cache_size() == c0, \
        "a budget-vector batch re-jitted the macro-round"
    max_dev = 0.0
    for arr, bw in zip(counts, sweep):
        for W in (4, 16, 64):
            if arr.size < W:
                continue
            win = np.convolve(arr, np.ones(W, int), mode="valid")
            max_dev = max(max_dev, float(np.abs(win - bw * W).max()))
    spike_free = int(max_dev <= 1.0)
    assert spike_free, (
        f"token-bucket emission deviated by {max_dev} crawls over a "
        "window (spike-free bound is 1)")

    emit("sched/elastic_bandwidth", us_e,
         f"m={m};k_max={k};R={R};pages_per_s={m/(us_e/1e6):.3e};"
         f"overhead_vs_fixed_k={overhead:.3f};"
         f"const_budget_bit_identical=1;"
         f"no_rejit_on_bandwidth_change={no_rejit};"
         f"window_spike_free={spike_free};max_window_dev={max_dev:.1f};"
         f"sweep_rates={','.join(f'{b:g}' for b in sweep)}")


def online_est_bench():
    """Streaming on-device estimation (`sched/online_est`): the cost and
    the payoff of closing the learning loop inside the macro-round scan.

    Part 1 (cost): estimating macro-rounds (`FusedBackend(online_est=True)`
    + a full `outcomes` batch every round) vs the non-estimating scan on
    identical feeds — interleaved reps, per-batch medians. Guards:
    (1) with an empty outcome batch the estimating selection is
    BIT-IDENTICAL to online_est=False; (2) the entire estimating run
    executes under a poisoned `jax.device_get` (host_syncs_per_round = 0 —
    the learning loop never leaves the device); (3) machine-calibrated
    throughput: estimating rounds must not exceed off-path rounds plus the
    ISOLATED estimation subgraph (timed on this machine, same shapes) by
    more than 25% — a gate on regressions in the integrated path, not on
    the container's clock (the old absolute 15% gate tripped on slow
    2-core boxes from drift alone).

    Part 2 (payoff): the closed-loop driver (`sim.run_closed_loop`) on the
    tiered-CIS instance from a WRONG (Delta, lambda, nu) belief —
    steady-state freshness regret of streaming vs the batch-MLE reference
    loop vs the no-learning floor, gated at the ISSUE's 5% parity."""
    import dataclasses

    import numpy as np

    from repro.core.values import Env
    from repro.sched import backends as be
    from repro.sched.service import CrawlScheduler
    from repro.sim import (LoopConfig, freshness_regret, run_closed_loop,
                           tiered_cis_instance)

    m = prof(1 << 18, 1 << 20)
    k, R, dt = 256, 32, 1.0
    mesh = jax.make_mesh((1,), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), m)
    order = jnp.argsort(-(env.mu / env.delta))
    env = jax.tree.map(lambda x: x[order], env)
    tau0 = jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=2.0)

    def build(online_est):
        s = CrawlScheduler(env, mesh, bandwidth=float(k) / dt,
                           round_period=dt,
                           backend=be.FusedBackend(adaptive_bounds=True,
                                                   online_est=online_est),
                           feed_cap=4096, outcome_cap=k)
        s.round = dataclasses.replace(s.round, tau_elap=jnp.copy(tau0))
        return s

    off, on = build(False), build(True)
    rng = np.random.default_rng(0)
    feeds_np = np.zeros((R, m), np.int32)
    for r in range(R):
        idx = rng.choice(m, 64, replace=False)
        feeds_np[r, idx] = rng.poisson(2.0, 64).astype(np.int32) + 1

    # Guard (1): empty-outcome estimating rounds == non-estimating rounds.
    ids_off, vals_off = off.run_rounds(np.copy(feeds_np))
    ids_on, vals_on = on.run_rounds(np.copy(feeds_np))
    assert np.array_equal(np.asarray(ids_off), np.asarray(ids_on)), \
        "online_est=True with no outcomes diverged from online_est=False"
    assert np.array_equal(np.asarray(vals_off), np.asarray(vals_on))

    # A full outcome batch every round from here on: the previous batch's
    # own selections with echoed covariates (the production echo contract).
    ids_np = np.asarray(ids_on)
    out = (ids_np, (ids_np % 3 == 0).astype(np.int32),
           np.full(ids_np.shape, dt * R, np.float32),
           np.zeros(ids_np.shape, np.int64))

    def die(*_a, **_kw):
        raise AssertionError(
            "estimating macro-round called jax.device_get (host sync)")

    # Warm the outcome-carrying signature, then time interleaved. Guard
    # (2): the whole estimating loop runs with jax.device_get poisoned.
    real, jax.device_get = jax.device_get, die
    try:
        on.run_rounds(np.copy(feeds_np), outcomes=out)
        off.run_rounds(np.copy(feeds_np))
        reps = prof(5, 7)
        t_on, t_off = [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            _, v = on.run_rounds(np.copy(feeds_np), outcomes=out)
            jax.block_until_ready(v)
            t_on.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            _, v = off.run_rounds(np.copy(feeds_np))
            jax.block_until_ready(v)
            t_off.append(time.perf_counter() - t0)
    finally:
        jax.device_get = real
    us_on = float(np.median(t_on)) / R * 1e6
    us_off = float(np.median(t_off)) / R * 1e6
    overhead = us_on / us_off - 1.0

    # Guard (3), machine-calibrated: the old absolute `overhead <= 0.15`
    # gate encoded one container's timing into the assert and failed at
    # ~22% on 2-core boxes from environment drift alone. Instead, time the
    # estimation subgraph ISOLATED on the same shard-local shapes (R
    # ingest_outcomes folds + one apply_estimates — exactly the extra work
    # the estimating scan carries) and gate the integrated path against
    # off-path + isolated-estimation: that bound moves with the machine,
    # so it fails on real regressions (the integrated path doing MORE work
    # than its parts, e.g. an accidental extra repack or a host sync
    # serializing the scan), not on slow hardware.
    from repro.sched import online_est as oest
    from repro.sched import tiered

    bst = on.round.backend
    cap = ids_np.shape[1]
    oidx_cal = jnp.asarray(ids_np % on.m_state, jnp.int32)  # (R, cap) local
    och_cal = jnp.asarray(out[1], jnp.int32)
    otau_cal = jnp.asarray(out[2], jnp.float32)
    on_cal = jnp.asarray(out[3], jnp.int32)
    ebk = on.backend

    @jax.jit
    def est_subgraph(stats, oids, och, otau, ons, env_planes, bounds,
                     slope, blk_max, last_eval, beta_max, cis_mass):
        def body(st, xs):
            i, ch, tau, n = xs
            return oest.ingest_outcomes(st, i, ch, tau, n), 0
        stats, _ = jax.lax.scan(body, stats, (oids, och, otau, ons))
        bb = tiered.BlockBounds(asym=bounds, slope=slope, blk_max=blk_max,
                                last_eval=last_eval)
        return stats, oest.apply_estimates(
            stats, env_planes, oids[-1], bb, beta_max, cis_mass,
            min_obs=float(ebk.est_min_obs), prior_a=ebk.est_prior_a,
            prior_b=ebk.est_prior_b, prior_w=ebk.est_prior_w)

    cal_args = (bst.est, oidx_cal, och_cal, otau_cal, on_cal,
                bst.env_planes, bst.bounds, bst.slope, bst.blk_max,
                bst.last_eval, bst.beta_max, bst.cis_mass)
    jax.block_until_ready(est_subgraph(*cal_args))  # warm
    t_cal = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(est_subgraph(*cal_args))
        t_cal.append(time.perf_counter() - t0)
    us_cal = float(np.median(t_cal)) / R * 1e6
    budget = (us_off + us_cal) * 1.25
    assert us_on <= budget, (
        f"estimating rounds cost {us_on:.1f}us/round but off-path + "
        f"isolated estimation is only {us_off:.1f} + {us_cal:.1f}us — the "
        f"integrated path exceeds its parts by more than 25% "
        f"({us_on / (us_off + us_cal):.2f}x): a regression, not drift")

    # ---- Part 2: closed-loop freshness regret vs the batch-MLE loop ----
    ml = 2048
    kl, Rl, NB = 32, 16, prof(40, 120)
    inst = tiered_cis_instance(jax.random.PRNGKey(1), ml)
    env_true = inst.env
    env_wrong = Env(delta=jnp.full((ml,), 0.5), mu=env_true.mu,
                    lam=jnp.zeros((ml,)), nu=jnp.zeros((ml,)))

    def build_loop(envb, online_est):
        return CrawlScheduler(
            envb, mesh, bandwidth=float(kl),
            backend=be.FusedBackend(block_rows=8, online_est=online_est),
            outcome_cap=kl)

    cfg = lambda mode: LoopConfig(n_batches=NB, rounds_per_batch=Rl,
                                  mode=mode, mle_every=4, seed=7)
    oracle = run_closed_loop(build_loop(env_true, False), env_true,
                             cfg("fixed"))
    fixed = run_closed_loop(build_loop(env_wrong, False), env_true,
                            cfg("fixed"))
    stream = run_closed_loop(build_loop(env_wrong, True), env_true,
                             cfg("streaming"))
    mle = run_closed_loop(build_loop(env_wrong, False), env_true,
                          cfg("mle"))
    r_fixed = freshness_regret(fixed, oracle)
    r_stream = freshness_regret(stream, oracle)
    r_mle = freshness_regret(mle, oracle)
    parity = r_stream / max(r_mle, 1e-9)
    assert r_stream < r_fixed, "streaming estimation did not learn at all"
    # The ISSUE's parity acceptance: streaming within 5% of the batch-MLE
    # reference (measured: streaming BEATS the windowed refit here).
    assert parity <= 1.05, (
        f"streaming regret {r_stream:.5f} is {parity:.3f}x the batch-MLE "
        "reference, over the 5% parity budget")

    emit("sched/online_est", us_on,
         f"m={m};k={k};R={R};pages_per_s={m/(us_on/1e6):.3e};"
         f"overhead_vs_off={overhead:.3f};us_cal={us_cal:.1f};"
         f"integrated_vs_parts={us_on/(us_off+us_cal):.3f};"
         f"host_syncs_per_round=0;"
         f"empty_outcomes_bit_identical=1;"
         f"regret_stream={r_stream:.5f};regret_mle={r_mle:.5f};"
         f"regret_no_learning={r_fixed:.5f};stream_vs_mle={parity:.3f};"
         f"loop_m={ml};loop_batches={NB}")


def request_path_bench():
    """The serving front (`serve.requests` / `sched.importance`):
    requests/s answered CONCURRENTLY with scheduling rounds, and the
    freshness-SLO payoff of learning `mu` from the traffic it serves.

    Part 1 (throughput): a RequestFront serving batched freshness queries
    (`serve_pages(sync=False)` — answers stay on device) interleaved with
    macro-round batches and periodic MU_T folds, the production cadence.
    Gates: (1) the ENTIRE serve+schedule+fold loop runs under a poisoned
    `jax.device_get` — zero host syncs; (2) the macro-round jit cache is
    flat from call 1 (construction commits the state, and every
    log/serve/fold re-commits, so serving never recompiles scheduling).

    Part 2 (freshness SLO): the closed-loop A/B
    (`sim.run_importance_ablation`) on a skewed (Zipf) traffic trace over
    one realized event stream: request-weighted freshness under learned
    request-EWMA `mu` must STRICTLY beat the static-uniform-`mu` baseline
    in steady state — the paper's freshness-at-request-time objective,
    demonstrated end to end. This part doubles as the CI ablation smoke
    (quick profile keeps it a few seconds)."""
    import numpy as np

    from repro.core.values import Env
    from repro.sched import backends as be
    from repro.sched.backends import crawl_rounds
    from repro.sched.service import CrawlScheduler
    from repro.serve import RequestFront
    from repro.sim import LoopConfig, run_importance_ablation

    m = prof(1 << 16, 1 << 18)
    k, R, dt = 256, 16, 1.0
    # One shard on the bench mesh: every routed row lands in shard 0, so
    # the cap contract must cover the whole batch.
    batch_requests = 8192
    req_cap = batch_requests
    mesh = jax.make_mesh((1,), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), m)
    sched = CrawlScheduler(
        env, mesh, bandwidth=float(k) / dt, round_period=dt,
        backend=be.FusedBackend(adaptive_bounds=True),
        importance=True, request_cap=req_cap, feed_cap=4096)
    front = RequestFront(sched, fold_every=0)

    rng = np.random.default_rng(0)
    # Zipf-skewed traffic: a head of hot pages dominates, like real serving.
    pop = 1.0 / (1.0 + np.arange(m)) ** 1.1
    pop /= pop.sum()
    req_batches = [rng.choice(m, size=batch_requests, p=pop)
                   for _ in range(4)]
    feeds = np.zeros((R, m), np.int32)
    for r in range(R):
        idx = rng.choice(m, 64, replace=False)
        feeds[r, idx] = 1

    def die(*_a, **_kw):
        raise AssertionError(
            "request path called jax.device_get (host sync)")

    # Warm every signature once (serve, round, fold), then pin the cache.
    p, _ = front.serve_pages(req_batches[0], sync=False)
    sched.run_rounds(np.copy(feeds))
    front.fold()
    cache0 = crawl_rounds._cache_size()

    reps = prof(4, 6)
    served = 0
    real, jax.device_get = jax.device_get, die
    try:
        t0 = time.perf_counter()
        for i in range(reps):
            for b in req_batches:
                p, _ = front.serve_pages(b, sync=False)
                served += b.size
            sched.run_rounds(np.copy(feeds))
            front.fold()
        jax.block_until_ready(p)
        elapsed = time.perf_counter() - t0
    finally:
        jax.device_get = real
    # Gate (2): serving + folding never recompiled the macro round.
    assert crawl_rounds._cache_size() == cache0, (
        "the request path recompiled the macro round: jit cache grew "
        f"{cache0} -> {crawl_rounds._cache_size()}")
    req_per_s = served / elapsed

    # ---- Part 2: freshness-at-request SLO, learned vs static uniform ----
    ml, Rl, NB = 1024, 8, prof(16, 48)
    kl = 24
    env_l = uniform_instance(jax.random.PRNGKey(2), ml)
    # Static-uniform baseline: every page equally important — what a
    # crawler believes with no traffic signal at all.
    env_l = Env(delta=env_l.delta, mu=jnp.ones((ml,)), lam=env_l.lam,
                nu=env_l.nu)
    pop_l = 1.0 / (1.0 + np.arange(ml)) ** 1.2
    pop_l = np.random.default_rng(3).permutation(pop_l)
    trace = np.random.default_rng(4).poisson(
        400 * pop_l / pop_l.sum(), size=(NB, ml)).astype(np.float64)
    cfg = LoopConfig(n_batches=NB, rounds_per_batch=Rl,
                     request_trace=trace, fold_every=2, seed=9)

    def factory():
        return CrawlScheduler(
            env_l, mesh, bandwidth=float(kl), round_period=1.0,
            backend=be.FusedBackend(block_rows=8),
            importance=True, request_cap=ml)

    arms = run_importance_ablation(factory, env_l, cfg)
    half = NB * Rl // 2
    slo_static = float(arms["static"].request_freshness[half:].mean())
    slo_learned = float(arms["request_ewma"].request_freshness[half:].mean())
    # Gate (3): learning from traffic must strictly pay on skewed traffic.
    assert slo_learned > slo_static, (
        f"request-EWMA mu ({slo_learned:.4f}) failed to beat the "
        f"static-uniform baseline ({slo_static:.4f}) on skewed traffic")

    us_batch = elapsed / (reps * len(req_batches)) * 1e6
    emit("serve/request_path", us_batch,
         f"m={m};req_cap={req_cap};batch={batch_requests};"
         f"requests_per_s={req_per_s:.3e};host_syncs=0;"
         f"jit_cache_flat=1;slo_learned={slo_learned:.4f};"
         f"slo_static={slo_static:.4f};"
         f"slo_gain={slo_learned / max(slo_static, 1e-9):.2f}x;"
         f"ablation_m={ml};ablation_batches={NB}")


def sched_bench():
    """Sharded scheduler rounds (seed vs fused select) + tiered-selection
    quality."""
    import numpy as np
    from repro.kernels import layout, select
    from repro.sched.distributed import ShardedSchedState, sharded_crawl_step
    from repro.sched.tiered import init_tiers, tiered_select

    m = prof(1 << 18, 1 << 21)
    k = 256
    mesh = jax.make_mesh((1,), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), m)
    d = derive(env)
    table = tables.build_ncis_table(d, n_grid=64)
    state = ShardedSchedState(
        tau_elap=jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=10.0),
        n_cis=jnp.zeros((m,), jnp.int32),
        crawl_clock=jnp.int32(0),
    )
    zero = jnp.zeros((m,), jnp.int32)
    step = lambda st: sharded_crawl_step(st, zero, d, table, mesh, k, 0.01)[0]
    _, us = timed(step, state, reps=3)
    emit("sched/round", us, f"m={m};k={k};pages_per_s={m/(us/1e6):.3e}")

    # ---- fused select vs the seed dense pipeline at production size ----
    mf = prof(1 << 20, 1 << 22)
    env = uniform_instance(jax.random.PRNGKey(0), mf)
    # Value-correlated blocks (the paper's production tiers).
    order = jnp.argsort(-(env.mu / env.delta))
    env = jax.tree.map(lambda x: x[order], env)
    d = derive(env)
    shard = layout.pack_shard(d)  # block-aligned at these sizes
    assert shard.m_pad == mf
    bounds = layout.asym_block_bounds(shard.env)
    zero = jnp.zeros((mf,), jnp.int32)
    state = ShardedSchedState(
        tau_elap=jax.random.uniform(jax.random.PRNGKey(1), (mf,), maxval=10.0),
        n_cis=jnp.zeros((mf,), jnp.int32),
        crawl_clock=jnp.int32(0),
    )

    # Correctness gate: fused == dense selection on the benchmark instance.
    tau_pad, n_pad = state.tau_elap, state.n_cis.astype(jnp.float32)
    sel = select.fused_select(tau_pad, n_pad, shard, k, bounds=bounds)
    dense_v = value_ncis(tau_eff(state.tau_elap, state.n_cis, d), d, 8,
                         "series")
    _, di = jax.lax.top_k(dense_v, k)
    assert set(np.asarray(sel.ids).tolist()) == set(np.asarray(di).tolist()), \
        "fused selection diverged from dense top-k"

    # Seed pipeline: dense values (series, = the dense kernel's math) written
    # out in full + jax.lax.top_k over all m as a second pass.
    seed_step = lambda st: sharded_crawl_step(st, zero, d, None, mesh, k, 0.01)[0]
    _, us_seed = timed(seed_step, state, reps=prof(2, 3))
    emit("sched/round_seed", us_seed,
         f"m={mf};k={k};pages_per_s={mf/(us_seed/1e6):.3e};"
         f"hbm_bytes_per_page={8*4 + 4 + 4}")

    # Fused pipeline via the backend API: donated RoundState, per-shard
    # threshold warm-start carried inside the state, static asym bounds.
    import dataclasses
    from repro.sched import backends as be
    from repro.sched.service import CrawlScheduler

    sched = CrawlScheduler(env, mesh, bandwidth=float(k), round_period=1.0,
                           backend=be.FusedBackend())
    sched.round = dataclasses.replace(
        sched.round,
        tau_elap=jnp.copy(state.tau_elap), n_cis=jnp.copy(state.n_cis),
    )
    p_env = sched.round.backend.env_planes.unsafe_buffer_pointer()
    n_rounds = prof(6, 10)
    us_fused = _fused_round_loop(sched, zero, n_rounds) * 1e6
    # No-copy accounting (state-plane donation): across all timed rounds the
    # packed env planes must alias the same donated buffer.
    aliased = sched.round.backend.env_planes.unsafe_buffer_pointer() == p_env
    assert aliased, "crawl_round copied the donated env planes"
    frac = float(sched.round.backend.frac_active.mean())
    fell = int(np.asarray(sched.round.backend.fell_back).any())
    bpp = layout.bytes_per_page(sched.backend.n_terms)
    emit("sched/round_fused", us_fused,
         f"m={mf};k={k};pages_per_s={mf/(us_fused/1e6):.3e};"
         f"speedup={us_seed/us_fused:.2f}x;frac_active={frac:.3f};"
         f"hbm_bytes_per_page={bpp*frac:.1f};fell_back={fell};"
         f"state_planes_donated_alias={int(aliased)}")

    # tiered selection: agreement + compute saved over a rolling horizon
    # (pages grouped into value tiers, as the paper's production system does)
    m = prof(1 << 18, 1 << 21)
    env = uniform_instance(jax.random.PRNGKey(0), m)
    order = jnp.argsort(-(env.mu / env.delta))
    env_t = jax.tree.map(lambda x: x[order], env)
    d = derive(env_t)
    table = tables.build_ncis_table(d, n_grid=64)
    tiers = init_tiers(d, block=4096)
    tau = jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=10.0)
    n = jnp.zeros((m,), jnp.int32)
    agree, saved = [], []
    for rnd in range(1, prof(20, 100)):
        exact_v, exact_i = jax.lax.top_k(
            tables.lookup_state(table, d, tau, n), k)
        tv, ti, tiers, frac = tiered_select(
            tau, n, d, table, tiers, jnp.int32(rnd), 0.01, k)
        inter = len(set(np.asarray(ti).tolist())
                    & set(np.asarray(exact_i).tolist()))
        agree.append(inter / k)
        saved.append(1.0 - float(frac))
        # crawl the tiered selection, advance time
        tau = tau.at[ti].set(0.0) + 0.01
    emit("sched/tiered", 0.0,
         f"overlap@k={np.mean(agree):.3f};eval_saved={np.mean(saved):.3f}")
