"""Request-driven importance: the EWMA plane, the MU_T fold, the serve
front, and the delayed-CIS re-bucketing.

The contracts under test (README "Request-driven importance & the serving
front"):

  * the logged EWMA plane holds the closed form
    sum_t decay^(T-1-t) * counts_t after T batches (property);
  * `fold_importance` equals a from-scratch scheduler construction at the
    blended mu — BITWISE, for the entire packed-plane tensor, every
    block-bound row, mu_total, and the selections that follow (the fold is
    a re-anchor, not an approximation);
  * importance OFF (`FusedState.req is None`) is byte-identical to the
    pre-feature scheduler: same state leaves, same selections, and logging
    without folding changes nothing the round consumes;
  * checkpoints roundtrip both ways across the optional plane (request
    snapshot -> plain scheduler attaches it; pre-plane snapshot ->
    importance scheduler keeps live delta/prior with a zeroed EWMA);
  * construction commits the state to the donated shardings, so the first
    call's compilation is the only one, and serve/log/fold interleave with
    rounds on a flat jit cache from call 1;
  * `sim.route_cis_batch` conserves CIS counts exactly (delay and outage
    re-bucketing shift signals, never drop them) and matches a sequential
    per-page queue reference.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import Env
from repro.core.values import BIG
from repro.kernels import layout
from repro.sched import backends as be
from repro.sched import importance as imp
from repro.sched.errors import CapacityExceeded, FeedValidationError
from repro.sched.service import CrawlScheduler
from repro.serve import RequestFront
from repro.sim import route_cis_batch, uniform_instance

M, DT = 512, 0.5


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _env(m=M, seed=0):
    return uniform_instance(jax.random.PRNGKey(seed), m)


def _feeds(n_rounds, m=M, seed=1, frac=0.05):
    rng = np.random.default_rng(seed)
    return (rng.random((n_rounds, m)) < frac).astype(np.int32)


def _sched(env, *, bandwidth=8.0, importance=True, **kw):
    return CrawlScheduler(env, _mesh1(), bandwidth=bandwidth,
                          round_period=DT,
                          backend=be.FusedBackend(block_rows=8),
                          importance=importance, **kw)


def _ewma(s):
    return np.asarray(s.round.backend.req.ewma)


# ---------------------------------------------------------------------------
# The EWMA plane: closed form, routing semantics, capacity contract.
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(decay=st.floats(min_value=0.05, max_value=1.0,
                       allow_nan=False, allow_infinity=False),
       n_batches=st.integers(min_value=1, max_value=5),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_property_ewma_closed_form(decay, n_batches, seed):
    """After T logged batches the plane holds exactly
    sum_t decay^(T-1-t) * counts_t — one decay step per batch, requested
    pages scatter-ADD their counts (duplicates are repeat traffic)."""
    m = 64
    rng = np.random.default_rng(seed)
    s = _sched(_env(m=m, seed=3), importance_decay=decay, request_cap=128)
    expect = np.zeros(m, np.float32)
    for _ in range(n_batches):
        n_req = int(rng.integers(0, 40))
        ids = rng.integers(0, m, n_req)          # duplicates welcome
        counts = rng.integers(1, 5, n_req).astype(np.float32)
        s.log_requests(ids, counts)
        batch = np.zeros(m, np.float32)
        np.add.at(batch, ids, counts)
        expect = np.float32(decay) * expect + batch
    np.testing.assert_allclose(_ewma(s)[:m], expect, rtol=1e-5, atol=1e-5)


def test_log_counts_default_to_one_and_serve_also_logs():
    m = 128
    s = _sched(_env(m=m, seed=4), importance_decay=1.0)
    s.log_requests([3, 3, 7])                    # counts=None -> 1 each
    np.testing.assert_array_equal(_ewma(s)[[3, 7]], [2.0, 1.0])
    s.serve_requests([3, 9])                     # serving IS a request
    np.testing.assert_array_equal(_ewma(s)[[3, 7, 9]], [3.0, 1.0, 1.0])
    s.serve_requests([9], log=False)             # ... unless log=False
    np.testing.assert_array_equal(_ewma(s)[[9]], [1.0])


def test_request_validation_and_capacity_contract():
    m = 256
    s = _sched(_env(m=m, seed=5), request_cap=8)
    with pytest.raises(FeedValidationError, match="integers"):
        s.log_requests(np.array([1.5, 2.5]))
    with pytest.raises(FeedValidationError, match="request ids"):
        s.log_requests([m])
    with pytest.raises(FeedValidationError, match="counts shape"):
        s.log_requests([1, 2], counts=[1.0])
    with pytest.raises(CapacityExceeded, match="request_cap"):
        s.log_requests(np.arange(9))             # 9 rows > cap 8 on 1 shard
    s.log_requests(np.arange(8))                 # at cap: fine


def test_serve_posterior_matches_model_belief():
    """p_fresh = exp(-alpha * min(tau + min(beta*n, BIG), BIG)) — the exact
    tau_eff expression the value kernel scores with, read from the live
    clocks."""
    m = 256
    env = _env(m=m, seed=6)
    s = _sched(env, importance_decay=0.9)
    feeds = _feeds(6, m=m, seed=2)
    s.run_rounds(feeds)
    ids = np.array([0, 17, 17, 255, 31])         # duplicates answer alike
    p = s.serve_requests(ids)
    d = np.asarray  # noqa: E731 - terse aliases for the reference math
    alpha, beta = (layout.gather_plane(
        s.round.backend.env_planes, jnp.asarray(ids), pl)
        for pl in (layout.ALPHA, layout.BETA))
    tau = d(s.round.tau_elap)[ids]
    n = d(s.round.n_cis)[ids].astype(np.float32)
    t_eff = np.minimum(tau + np.minimum(d(beta) * n, BIG), BIG)
    np.testing.assert_allclose(p, np.exp(-d(alpha) * t_eff), rtol=1e-6)
    assert np.isfinite(p).all() and (p >= 0).all() and (p <= 1).all()
    # The front's boolean view is the same numbers thresholded.
    front = RequestFront(s, fresh_threshold=0.5)
    np.testing.assert_array_equal(front.fresh(ids), p >= 0.5)


# ---------------------------------------------------------------------------
# The fold: bitwise-equal to a from-scratch construction at the blended mu.
# ---------------------------------------------------------------------------

def _fresh_at_blend(s, env, source):
    """The reference: a scheduler constructed from scratch with
    Env(mu = valid * blend) — what the fold claims to equal bitwise."""
    m = env.mu.shape[0]
    req = s.round.backend.req
    blend = (np.float32(source.w_request) * _ewma(s)
             + np.float32(source.w_prior) * np.asarray(req.prior)
             + np.float32(source.w_uniform) + np.float32(source.floor))
    mu = np.asarray(req.valid) * blend
    env2 = Env(delta=env.delta, mu=jnp.asarray(mu[:m]), lam=env.lam,
               nu=env.nu)
    return _sched(env2, importance=False)


@pytest.mark.parametrize("source", [imp.REQUEST_EWMA, imp.LINK_PRIOR,
                                    imp.UNIFORM])
def test_fold_bitwise_equals_fresh_construction(source):
    env = _env(seed=7)
    s = _sched(env, importance_decay=0.8, request_cap=256)
    rng = np.random.default_rng(11)
    for b in range(3):
        s.log_requests(rng.integers(0, M, 200),
                       rng.integers(1, 9, 200).astype(np.float32))
    ref = _fresh_at_blend(s, env, source)
    s.fold_importance(source)
    bf, br = s.round.backend, ref.round.backend
    np.testing.assert_array_equal(np.asarray(bf.env_planes),
                                  np.asarray(br.env_planes))
    for leaf in ("bounds", "slope", "blk_max", "last_eval", "beta_max",
                 "cis_mass"):
        np.testing.assert_array_equal(
            np.asarray(getattr(bf, leaf)), np.asarray(getattr(br, leaf)),
            err_msg=leaf)
    assert float(s.mu_total) == float(ref.mu_total)
    # ... and the selections that follow are the fresh scheduler's.
    feeds = _feeds(8, seed=13)
    ids_f, vals_f = s.run_rounds(feeds)
    ids_r, vals_r = ref.run_rounds(feeds)
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_r))
    np.testing.assert_array_equal(np.asarray(vals_f), np.asarray(vals_r))


def test_fold_keeps_padding_dark():
    """The additive floor must NOT leak onto state padding: the fold's mu
    uses `ReqState.valid` (real pages only), not the packed VALID plane
    (1.0 everywhere — the fused init packs a pre-padded env)."""
    m = 500                                      # forces m_state > m padding
    s = _sched(_env(m=m, seed=8))
    s.log_requests(np.arange(0, m, 7))
    s.fold_importance(imp.REQUEST_EWMA)
    planes = np.asarray(s.round.backend.env_planes)
    bp = planes.shape[2] * planes.shape[3]
    mu_t = planes[:, layout.MU_T].reshape(-1)[:s.m_state]
    assert (mu_t[m:] == 0.0).all(), "padding pages gained importance mass"
    assert (mu_t[:m] > 0.0).all()                # the floor: explore term


def test_fold_requires_the_plane():
    s = _sched(_env(seed=9), importance=False)
    with pytest.raises(RuntimeError, match="importance=True"):
        s.fold_importance()
    with pytest.raises(RuntimeError, match="importance=True"):
        s.serve_requests([0])


# ---------------------------------------------------------------------------
# Importance OFF: byte-identical to the pre-feature scheduler.
# ---------------------------------------------------------------------------

def test_off_path_state_and_selection_identity():
    """req=None rides every jit signature as an empty subtree: the OFF
    scheduler's state leaves and selections are bit-identical to an
    importance-capable scheduler that never folds — logging alone must not
    perturb the round."""
    env = _env(seed=10)
    feeds = _feeds(10, seed=17)
    off = _sched(env, importance=False)
    on = _sched(env, importance_decay=0.9)
    rng = np.random.default_rng(23)
    ids_off, vals_off = [], []
    ids_on, vals_on = [], []
    for half in range(2):
        f = feeds[half * 5:(half + 1) * 5]
        i, v = off.run_rounds(f)
        ids_off.append(np.asarray(i)); vals_off.append(np.asarray(v))
        on.log_requests(rng.integers(0, M, 64))  # traffic between batches
        i, v = on.run_rounds(f)
        ids_on.append(np.asarray(i)); vals_on.append(np.asarray(v))
    np.testing.assert_array_equal(np.concatenate(ids_off),
                                  np.concatenate(ids_on))
    np.testing.assert_array_equal(np.concatenate(vals_off),
                                  np.concatenate(vals_on))
    # Every non-req backend leaf matches bitwise after the interleaving.
    bo, bn = off.round.backend, on.round.backend
    assert bo.req is None and bn.req is not None
    for name in bo._fields:
        if name == "req":
            continue
        lo, ln = getattr(bo, name), getattr(bn, name)
        if lo is None or ln is None:
            assert lo is ln, name
            continue
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(ln),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# Checkpoints: roundtrip across the optional plane, both directions.
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_request_plane_into_plain_scheduler():
    env = _env(seed=11)
    s = _sched(env, importance_decay=0.7)
    s.run_rounds(_feeds(4, seed=19))
    s.log_requests(np.arange(0, M, 3))
    s.fold_importance()
    sd = jax.device_get(s.state_dict())
    plain = _sched(env, importance=False)
    plain.load_state_dict(sd)
    assert plain.round.backend.req is not None   # plane attached on restore
    np.testing.assert_array_equal(_ewma(plain), sd["backend"].req.ewma)
    for leaf in ("delta", "prior", "valid"):
        np.testing.assert_array_equal(
            np.asarray(getattr(plain.round.backend.req, leaf)),
            np.asarray(getattr(sd["backend"].req, leaf)), err_msg=leaf)
    # The restored scheduler serves, logs, and folds like the original.
    feeds = _feeds(6, seed=29)
    ids_a, _ = s.run_rounds(feeds)
    ids_b, _ = plain.run_rounds(feeds)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    plain.log_requests([1, 2, 3])
    plain.fold_importance()


def test_checkpoint_roundtrip_pre_plane_snapshot_into_importance_sched():
    """A snapshot that predates the feature restores into an importance
    scheduler with the EWMA zeroed (the snapshot has no traffic to claim)
    while the LIVE delta/prior/valid columns survive — they are
    construction-time env data, not snapshot state."""
    env = _env(seed=12)
    old = _sched(env, importance=False)
    old.run_rounds(_feeds(4, seed=31))
    sd = jax.device_get(old.state_dict())
    assert sd["backend"].req is None             # genuinely pre-plane
    live = _sched(env, importance_decay=0.9,
                  importance_prior=np.linspace(1.0, 2.0, M))
    live.log_requests(np.arange(64))             # pre-restore traffic ...
    prior_before = np.asarray(live.round.backend.req.prior).copy()
    live.load_state_dict(sd)
    req = live.round.backend.req
    assert req is not None
    np.testing.assert_array_equal(_ewma(live), 0.0)  # ... is wiped
    np.testing.assert_array_equal(np.asarray(req.prior), prior_before)
    np.testing.assert_array_equal(
        np.asarray(req.valid)[:M], np.ones(M, np.float32))
    # And the restored clocks drive identical rounds.
    feeds = _feeds(5, seed=37)
    ids_a, _ = old.run_rounds(feeds)
    ids_b, _ = live.run_rounds(feeds)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))


# ---------------------------------------------------------------------------
# Cold start and cache flatness: call 1 is the only compilation.
# ---------------------------------------------------------------------------

def test_first_call_is_the_only_compilation_and_interleaving_stays_flat():
    """Construction commits the state to the donated shardings
    (`backends.commit_state`), so run_rounds compiles exactly once — and
    serve/log/fold between rounds re-commit their outputs, keeping that
    one signature live through arbitrary interleavings."""
    env = _env(seed=13)
    s = _sched(env, importance_decay=0.9, request_cap=128, feed_cap=64)
    rng = np.random.default_rng(41)
    s.run_rounds(_feeds(4, seed=43))
    n0 = be.crawl_rounds._cache_size()           # pinned after call 1
    s.run_rounds(_feeds(4, seed=44))
    assert be.crawl_rounds._cache_size() == n0, "cold-state re-jit is back"
    for i in range(3):
        s.serve_requests(rng.integers(0, M, 64), sync=False)
        s.log_requests(rng.integers(0, M, 32))
        s.run_rounds(_feeds(4, seed=50 + i))
        s.fold_importance()
        s.run_rounds(_feeds(4, seed=60 + i))
    assert be.crawl_rounds._cache_size() == n0, (
        "serve/log/fold interleaving grew the macro-round jit cache")


def test_request_front_auto_fold_and_stats():
    env = _env(seed=14)
    s = _sched(env, importance_decay=0.9)
    front = RequestFront(s, fold_every=2)
    rng = np.random.default_rng(47)
    for _ in range(5):
        front.serve_pages(rng.integers(0, M, 16))
    front.log_requests(rng.integers(0, M, 8))
    st_ = front.stats
    assert (st_.batches, st_.requests, st_.folds) == (6, 5 * 16 + 8, 3)
    with pytest.raises(RuntimeError, match="importance=True"):
        RequestFront(_sched(env, importance=False))  # fail at build


# ---------------------------------------------------------------------------
# Delayed-CIS re-bucketing: conserve, never drop (sim.route_cis_batch).
# ---------------------------------------------------------------------------

def _route_reference(gen, mask, delay):
    """Sequential per-page queue: signal born at round g lands at
    g + delay[page], then waits for the first unmasked round >= that."""
    T, m = gen.shape
    out = np.zeros((T, m), np.int64)
    for p in range(m):
        queue = []                               # arrival rounds, in order
        for g in range(T):
            queue.extend([g + delay[p]] * int(gen[g, p]))
            keep = []
            for a in queue:
                if a <= g and (mask is None or mask[g, p]):
                    out[g, p] += 1
                else:
                    keep.append(a)
            queue = keep
    return out


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       maxd=st.integers(min_value=0, max_value=4),
       n_batches=st.integers(min_value=1, max_value=4),
       masked=st.booleans())
def test_property_route_cis_batch_conserves_and_matches_reference(
        seed, maxd, n_batches, masked):
    rng = np.random.default_rng(seed)
    m, R = 12, 5
    delay = rng.integers(0, maxd + 1, m)
    delay_cols = {d: np.nonzero(delay == d)[0] for d in range(maxd + 1)}
    gen = rng.poisson(0.7, (n_batches * R, m)).astype(np.int64)
    mask = (rng.random((n_batches * R, m)) < 0.7) if masked else None
    buf = np.zeros((maxd, m), np.int64)
    carry = np.zeros(m, np.int64)
    delivered = []
    for b in range(n_batches):
        g = gen[b * R:(b + 1) * R]
        rows = mask[b * R:(b + 1) * R] if masked else None
        before = buf.sum() + carry.sum()
        d, buf, carry = route_cis_batch(g, rows, buf, carry, delay_cols)
        # Per-batch conservation: generated + in-flight-before ==
        # delivered + in-flight-after. Nothing dropped, only shifted.
        assert g.sum() + before == d.sum() + buf.sum() + carry.sum()
        delivered.append(d)
    np.testing.assert_array_equal(
        np.concatenate(delivered),
        _route_reference(gen, mask, delay),
        err_msg="batched routing != sequential per-page queue")


def test_route_cis_zero_delay_with_mask_is_pure_outage_rebucketing():
    """cis_delay=0 + a mask: signals on a down round re-bucket to the
    page's next up round — late, never lost (the legacy cis_mask-only
    path DROPS them; the delta is the bug under test)."""
    m, R = 4, 6
    rng = np.random.default_rng(53)
    gen = rng.poisson(1.0, (R, m)).astype(np.int64)
    mask = np.ones((R, m), bool)
    mask[1:4, 2] = False                         # page 2: rounds 1-3 down
    cols = {0: np.arange(m)}
    d, buf, carry = route_cis_batch(gen, mask, np.zeros((0, m), np.int64),
                                    np.zeros(m, np.int64), cols)
    assert d.sum() + carry.sum() == gen.sum()
    np.testing.assert_array_equal(d[1:4, 2], 0)
    assert d[4, 2] == gen[1:5, 2].sum()          # the queued burst lands
