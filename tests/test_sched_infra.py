"""Distributed scheduler, tiering, checkpointing, data pipeline, optimizers."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import derive, tables
from repro.sched.distributed import ShardedSchedState, sharded_crawl_step
from repro.sched.tiered import init_tiers, tiered_select
from repro.sim import uniform_instance


def _state(key, m):
    return ShardedSchedState(
        tau_elap=jax.random.uniform(key, (m,), maxval=10.0),
        n_cis=jnp.zeros((m,), jnp.int32),
        crawl_clock=jnp.int32(0),
    )


def test_sharded_step_matches_topk():
    mesh = jax.make_mesh((1,), ("data",))
    m, k = 4096, 16
    env = uniform_instance(jax.random.PRNGKey(0), m)
    d = derive(env)
    table = tables.build_ncis_table(d)
    st = _state(jax.random.PRNGKey(1), m)
    ns, (gids, vals) = sharded_crawl_step(
        st, jnp.zeros((m,), jnp.int32), d, table, mesh, k, 0.1)
    direct = jax.lax.top_k(
        tables.lookup_state(table, d, st.tau_elap, st.n_cis), k)
    assert set(map(int, gids)) == set(map(int, direct[1]))
    # winners reset to dt, others advanced
    for g in map(int, gids):
        assert abs(float(ns.tau_elap[g]) - 0.1) < 1e-6


def test_sharded_step_multidevice_subprocess():
    """Run the sharded scheduler on 8 fake host devices in a subprocess (the
    main process must keep its single-device view)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.core import derive, tables
        from repro.sched.distributed import ShardedSchedState, sharded_crawl_step
        from repro.sim import uniform_instance
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        m, k = 8192, 16
        env = uniform_instance(jax.random.PRNGKey(0), m)
        d = derive(env)
        table = tables.build_ncis_table(d)
        st = ShardedSchedState(
            tau_elap=jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=10.0),
            n_cis=jnp.zeros((m,), jnp.int32), crawl_clock=jnp.int32(0))
        ns, (gids, vals) = sharded_crawl_step(
            st, jnp.zeros((m,), jnp.int32), d, table, mesh, k, 0.1)
        direct = jax.lax.top_k(tables.lookup_state(table, d, st.tau_elap, st.n_cis), k)
        assert set(map(int, gids)) == set(map(int, direct[1])), (gids, direct[1])
        print("MULTIDEV_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env, timeout=300)
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr


def test_tiered_selection_quality():
    m, k, block = 131072, 32, 1024
    env = uniform_instance(jax.random.PRNGKey(2), m)
    # The paper's tiers: group pages into blocks by value scale (asymptote).
    order = jnp.argsort(-(env.mu / env.delta))
    env = jax.tree.map(lambda x: x[order], env)
    d = derive(env)
    table = tables.build_ncis_table(d, n_grid=64)
    tiers = init_tiers(d, block)
    tau = jax.random.uniform(jax.random.PRNGKey(3), (m,), maxval=10.0)
    n = jnp.zeros((m,), jnp.int32)
    overlaps, fracs = [], []
    for rnd in range(1, 20):
        exact = set(np.asarray(
            jax.lax.top_k(tables.lookup_state(table, d, tau, n), k)[1]).tolist())
        tv, ti, tiers, frac = tiered_select(tau, n, d, table, tiers,
                                            jnp.int32(rnd), 0.02, k)
        overlaps.append(len(exact & set(np.asarray(ti).tolist())) / k)
        fracs.append(float(frac))
        tau = tau.at[ti].set(0.0) + 0.02
    assert np.mean(overlaps) > 0.9           # selection agreement
    assert min(fracs[5:]) < 1.0              # some blocks actually skipped


def test_checkpoint_roundtrip(tmp_path):
    from repro import checkpoint as ckpt

    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "step": jnp.int32(7)}
    ckpt.save(str(tmp_path), 7, tree, extra={"note": "x"})
    ckpt.save(str(tmp_path), 9, tree)
    assert ckpt.latest_step(str(tmp_path)) == 9
    got, step, extra = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 9
    np.testing.assert_array_equal(got["a"], tree["a"])
    assert got["b"]["c"].dtype == jnp.bfloat16
    # keep=3 gc
    for s in (11, 13, 15):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 15
    assert len([d for d in os.listdir(tmp_path) if d.startswith("step_")]) <= 2


def test_crawl_refreshed_corpus():
    from repro.data import CrawlRefreshedCorpus

    c = CrawlRefreshedCorpus(m=512, vocab=256, seq_len=32, global_batch=4,
                             refresh_per_step=16, dt=0.2)
    fresh = []
    for step in range(30):
        batch, stats = c.batch_at(step)
        assert batch["tokens"].shape == (4, 32)
        fresh.append(c.stats()["weighted_freshness"])
    # the scheduler keeps the cache mostly fresh under budget
    assert np.mean(fresh[10:]) > 0.5


def test_optimizers_reduce_quadratic():
    from repro.optim import make_optimizer

    for name in ("adamw", "adafactor"):
        opt = make_optimizer(name)
        # non-square + stacked shapes (adafactor vr/vc orientation regression)
        params = {"w": jnp.array([[2.0, -3.0, 1.0], [1.5, 0.5, -2.0]]),
                  "s": jnp.ones((2, 3, 5))}
        st = opt.init(params)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        p = params
        for i in range(200):
            g = jax.grad(loss)(p)
            p, st, _ = opt.update(g, st, p, jnp.int32(i))
        assert float(loss(p)) < float(loss(params))


def test_elastic_bandwidth_service():
    from repro.sched.service import CrawlScheduler

    mesh = jax.make_mesh((1,), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), 2048)
    sched = CrawlScheduler(env, mesh, bandwidth=32.0, table_grid=64)
    ids1, _ = sched.ingest_and_schedule(jnp.zeros((2048,), jnp.int32))
    assert ids1.shape == (32,)
    sched.set_bandwidth(64.0)  # App. D: no recomputation needed
    ids2, _ = sched.ingest_and_schedule(jnp.zeros((2048,), jnp.int32))
    assert ids2.shape == (64,)
    sd = sched.state_dict()
    sched.load_state_dict(jax.device_get(sd))
