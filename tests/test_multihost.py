"""Multi-host data path: per-host sparse feed ingest + local-range repack.

Single-process tests pin the `host_slice` view, the per-shard `SparseFeeds`
layout (ids land in their shard's range; densify == original), and the
`feed_cap` capacity contract (fixed static shapes — hot-shard feeds trigger
zero recompiles; feed overflow raises `CapacityExceeded`, while an
over-`update_cap` refresh batch is chunked host-side instead).

The `slow`-marked tests launch GENUINE 2-process `jax.distributed` meshes
(`mesh_harness.run_distributed`, gloo CPU collectives) and prove the
acceptance criteria end to end: the 2-process run — each host converting
only its local feed rows, applying only its local refresh jobs, estimating
only its local crawl logs — selects bit-identically to the single-host
4-shard run at the same seeds/feeds, for `run_rounds`, sequential rounds,
and `update_pages` / `ingest_crawl_results`-interleaved rounds; and a hot
shard on host 0 triggers zero recompiles on either host (per-process jit
caches asserted).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import strategies
from _hypothesis_compat import given, settings, st
from mesh_harness import run_distributed, run_forced_shards
from repro.sched import backends as be
from repro.sched.service import CrawlScheduler
from repro.sim import uniform_instance


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _densify(sf: be.SparseFeeds, m_state: int) -> np.ndarray:
    """Fold a per-shard COO batch back to a dense (R, m_state) batch."""
    ids = np.asarray(sf.ids)
    cnt = np.asarray(sf.counts)
    out = np.zeros((ids.shape[0], m_state), np.int64)
    r, s, c = np.nonzero(ids >= 0)
    np.add.at(out, (r, ids[r, s, c]), cnt[r, s, c])
    return out


# ---------------------------------------------------------------------------
# host_slice view + per-shard SparseFeeds layout (single process).
# ---------------------------------------------------------------------------

def test_host_slice_single_process():
    m = 3000
    env = uniform_instance(jax.random.PRNGKey(0), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                       backend=be.FusedBackend(block_rows=8))
    assert not s.is_multiprocess
    assert s.n_shards == 1
    assert s.host_slice == slice(0, s.m_state)
    assert s.m_shard == s.m_state


def test_sparse_feed_batch_roundtrip_and_layout():
    m = 5000
    env = uniform_instance(jax.random.PRNGKey(1), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                       backend=be.FusedBackend(block_rows=8))
    feeds = strategies.build_feed_batch(m, 4, "sparse", np.int32, seed=3)
    sf = s._sparse_feed_batch(feeds)
    assert sf.ids.shape[1] == s.n_shards  # per-shard layout
    dense = _densify(sf, s.m_state)
    np.testing.assert_array_equal(dense[:, :m], feeds)
    assert (dense[:, m:] == 0).all()


@settings(max_examples=10, deadline=None)
@given(feeds=strategies.feed_batches(m=5000, max_rounds=4))
def test_property_sparse_feed_conversion_lossless(feeds):
    """Property (shared strategies): for every feed shape/dtype the ingest
    contract accepts, the per-shard COO conversion is lossless and every id
    lands inside its shard's page range."""
    m = feeds.shape[1]
    env = uniform_instance(jax.random.PRNGKey(2), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                       backend=be.FusedBackend(block_rows=8))
    sf = s._sparse_feed_batch(feeds)
    np.testing.assert_array_equal(
        _densify(sf, s.m_state)[:, :m], feeds.astype(np.int64))
    ids = np.asarray(sf.ids)
    ms = s.m_shard
    for shard in range(s.n_shards):
        cell = ids[:, shard, :]
        real = cell[cell >= 0]
        assert ((real >= shard * ms) & (real < (shard + 1) * ms)).all()


def test_sparse_feed_shard_ranges_forced_4():
    """On a real 4-shard mesh, each SparseFeeds shard row holds only ids of
    that shard's page range, and macro selection still matches sequential
    (the conversion is what `run_rounds` actually consumes)."""
    run_forced_shards("""
        import numpy as np, jax.numpy as jnp
        import sys; sys.path.insert(0, "tests")
        import strategies
        from repro.sched import backends as be
        from repro.sched.service import CrawlScheduler
        from repro.sim import uniform_instance
        mesh = jax.make_mesh((4,), ("data",))
        m = 16384
        env = uniform_instance(jax.random.PRNGKey(1), m)
        s = CrawlScheduler(env, mesh, bandwidth=16.0 / 0.05,
                           round_period=0.05,
                           backend=be.FusedBackend(block_rows=8))
        feeds = strategies.build_feed_batch(m, 3, "hot_shard", np.int32, 9)
        sf = s._sparse_feed_batch(feeds)
        ids = np.asarray(sf.ids)
        assert ids.shape[1] == 4
        ms = s.m_shard
        for shard in range(4):
            real = ids[:, shard, :][ids[:, shard, :] >= 0]
            assert ((real >= shard * ms) & (real < (shard + 1) * ms)).all()
        dense = np.zeros((3, s.m_state), np.int64)
        r, sh, c = np.nonzero(ids >= 0)
        np.add.at(dense, (r, ids[r, sh, c]), np.asarray(sf.counts)[r, sh, c])
        np.testing.assert_array_equal(dense[:, :m], feeds)
        ids_m, _ = s.run_rounds(feeds)
        seq = CrawlScheduler(env, mesh, bandwidth=16.0 / 0.05,
                             round_period=0.05,
                             backend=be.FusedBackend(block_rows=8))
        for r in range(3):
            ids_s, _ = seq.ingest_and_schedule(jnp.asarray(feeds[r]))
            np.testing.assert_array_equal(np.asarray(ids_m)[r],
                                          np.asarray(ids_s), err_msg=str(r))
        print("SHARD_RANGES_OK")
    """, n_devices=4, token="SHARD_RANGES_OK")


# ---------------------------------------------------------------------------
# The feed_cap / update_cap capacity contracts.
# ---------------------------------------------------------------------------

def test_feed_cap_contract_no_rejit_on_hot_feed():
    """With the per-host capacity contract pinned, a hot-shard feed batch
    reuses the compiled macro-round (zero recompiles); without it, the
    pow2 bucket grows and re-jits — the exact failure mode the contract
    removes."""
    m, k, R = 12_000, 16, 4
    env = uniform_instance(jax.random.PRNGKey(3), m)
    cold = np.zeros((R, m), np.int32)
    cold[:, ::523] = 1          # ~23 signalled pages/round -> pow2 cap 32
    hot = np.zeros((R, m), np.int32)
    hot[:, :3000] = 1           # one hot range -> pow2 cap 4096

    s = CrawlScheduler(env, _mesh1(), bandwidth=float(k) / 0.05,
                       round_period=0.05,
                       backend=be.FusedBackend(block_rows=8),
                       feed_cap=4096)
    # Construction commits the state to the donated shardings, so the
    # first call's compilation is the steady state: pin the cache after
    # call 1, no warm-up batch needed.
    s.run_rounds(np.copy(cold))
    c0 = be.crawl_rounds._cache_size()
    s.run_rounds(np.copy(cold))
    assert be.crawl_rounds._cache_size() == c0, (
        "second cold batch re-jitted: construction no longer commits the "
        "state to the donated shardings")
    s.run_rounds(hot)
    assert be.crawl_rounds._cache_size() == c0, (
        "hot-shard feed re-jitted despite the feed_cap contract")

    s2 = CrawlScheduler(env, _mesh1(), bandwidth=float(k) / 0.05,
                        round_period=0.05,
                        backend=be.FusedBackend(block_rows=8))
    s2.run_rounds(np.copy(cold))
    c1 = be.crawl_rounds._cache_size()
    hot2 = np.zeros((R, m), np.int32)
    hot2[:, :5000] = 1          # pow2 cap 8192: a shape nobody compiled yet
    s2.run_rounds(hot2)
    assert be.crawl_rounds._cache_size() > c1, (
        "expected the uncapped pow2 bucketing to re-jit on the hot batch "
        "(did the bucketing change?)")


def test_feed_cap_overflow_raises():
    m = 6000
    env = uniform_instance(jax.random.PRNGKey(4), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                       backend=be.FusedBackend(block_rows=8), feed_cap=8)
    feeds = np.zeros((2, m), np.int32)
    feeds[1, :100] = 1  # 100 signalled pages on one shard > cap 8
    with pytest.raises(ValueError, match="feed_cap"):
        s.run_rounds(feeds)


def test_update_cap_overflow_chunks():
    """An over-`update_cap` refresh batch no longer raises (ROADMAP item
    iii): `update_pages` chunks it host-side in a donation-safe loop, and
    the chunked application is bit-identical to one under-cap application
    of the same batch."""
    from repro.core import Env

    m = 6000
    env = uniform_instance(jax.random.PRNGKey(5), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                       backend=be.FusedBackend(block_rows=8), update_cap=8)
    n = 40
    upd = Env(delta=jnp.full((n,), 1.0), mu=jnp.full((n,), 5.0),
              lam=jnp.full((n,), 0.5), nu=jnp.full((n,), 0.1))
    s.update_pages(np.arange(n), upd)  # 40 > cap 8: five chunks, no raise
    # One under-cap application is the reference; every backend-state leaf
    # must match bitwise.
    s2 = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                        backend=be.FusedBackend(block_rows=8), update_cap=64)
    s2.update_pages(np.arange(n), upd)
    for name, a, b in zip(be.FusedState._fields, s.round.backend,
                          s2.round.backend):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    ids, _ = s.ingest_and_schedule(jnp.zeros((m,), jnp.int32))
    ids2, _ = s2.ingest_and_schedule(jnp.zeros((m,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids2))
    assert int(np.asarray(ids).max()) < m


# ---------------------------------------------------------------------------
# Genuine 2-process jax.distributed meshes (slow).
# ---------------------------------------------------------------------------

# Shared by the single-host reference and the 2-process run: same mesh
# shape, same seeds, same feeds/jobs/logs, same capacity contracts. The rng
# draws happen in identical order, so every process sees identical inputs.
_DATA_PATH_SETUP = """
    import dataclasses
    import numpy as np
    import jax.numpy as jnp
    from repro.core import Env
    from repro.sched import backends as be
    from repro.sched.service import CrawlScheduler
    from repro.sim import uniform_instance

    mesh = jax.make_mesh((4,), ("data",))
    m, k, R, dt = 16384, 16, 6, 0.05
    env = uniform_instance(jax.random.PRNGKey(0), m)
    order = jnp.argsort(-(env.mu / env.delta))
    env = jax.tree.map(lambda x: x[order], env)
    s = CrawlScheduler(env, mesh, bandwidth=float(k) / dt, round_period=dt,
                       backend=be.FusedBackend(block_rows=8,
                                               adaptive_bounds=True),
                       feed_cap=64, update_cap=32)
    rng = np.random.default_rng(7)
    def sparse_batch():
        f = np.zeros((R, m), np.int32)
        for r in range(R):
            idx = rng.choice(m, 20, replace=False)
            f[r, idx] = rng.integers(1, 9, 20)
        return f
    feedsA = sparse_batch()
    feedsA2 = sparse_batch()
    feedB = np.zeros((m,), np.int32)
    feedB[rng.choice(m, 15, replace=False)] = 2
    upd_ids = np.sort(rng.choice(m, 40, replace=False))
    upd_env = Env(delta=jnp.full((40,), 1.5), mu=jnp.full((40,), 250.0),
                  lam=jnp.full((40,), 0.4), nu=jnp.full((40,), 0.2))
    log_ids = np.sort(rng.choice(m, 24, replace=False))
    log_tau = rng.uniform(0.5, 2.0, (24, 6)).astype(np.float32)
    log_n = rng.poisson(1.0, (24, 6)).astype(np.int32)
    log_fresh = (rng.random((24, 6)) < 0.6).astype(np.int32)
    # Hot-shard batch: every signal lands on shard 0 (host 0's range).
    feedsC = np.zeros((R, m), np.int32)
    ms = s.m_shard
    for r in range(R):
        idx = rng.choice(ms, 48, replace=False)
        feedsC[r, idx] = rng.integers(1, 9, 48)
"""


@pytest.mark.slow
def test_two_process_data_path_bit_identical_and_no_hot_recompile(tmp_path):
    """THE acceptance harness: a genuine 2-process mesh, per-host feed
    ingest, per-host refresh, per-host crawl-log estimation — selection
    bit-identical to the single-host 4-shard run phase by phase, and the
    hot-shard batch (all signals on host 0) compiles nothing new on either
    host."""
    tmpdir = str(tmp_path)
    run_forced_shards(_DATA_PATH_SETUP + """
    idsA, valsA = s.run_rounds(feedsA)
    idsB, valsB = s.ingest_and_schedule(feedB)
    s.update_pages(upd_ids, upd_env)
    idsA2, valsA2 = s.run_rounds(feedsA2)
    s.ingest_crawl_results(log_ids, log_tau, log_n, log_fresh)
    c0 = be.crawl_rounds._cache_size()
    idsC, valsC = s.run_rounds(feedsC)
    assert be.crawl_rounds._cache_size() == c0
    import os
    np.savez(os.path.join(tmpdir, "ref.npz"),
             **{n: np.asarray(v) for n, v in [
                 ("idsA", idsA), ("valsA", valsA), ("idsB", idsB),
                 ("valsB", valsB), ("idsA2", idsA2), ("valsA2", valsA2),
                 ("idsC", idsC), ("valsC", valsC)]})
    print("REF_OK")
    """, n_devices=4, timeout=900, token="REF_OK", tmpdir=tmpdir)

    run_distributed(_DATA_PATH_SETUP + """
    lo, hi = s.host_slice.start, s.host_slice.stop
    assert s.is_multiprocess
    assert (lo, hi) == (PROC_ID * m // 2, (PROC_ID + 1) * m // 2), (lo, hi)

    # Host-local data path: each host feeds ONLY its local rows, applies
    # the global job/log lists (the service filters to host_slice), and
    # the union across hosts reproduces the single-host run exactly.
    idsA, valsA = s.run_rounds(feedsA[:, lo:hi])
    idsB, valsB = s.ingest_and_schedule(feedB[lo:hi])
    s.update_pages(upd_ids, upd_env)
    idsA2, valsA2 = s.run_rounds(feedsA2[:, lo:hi])
    s.ingest_crawl_results(log_ids, log_tau, log_n, log_fresh)

    # Zero-recompile acceptance: the hot batch (all signals on host 0)
    # must not grow THIS host's jit cache — asserted on both hosts, so in
    # particular on the cold one.
    c0 = be.crawl_rounds._cache_size()
    idsC, valsC = s.run_rounds(feedsC[:, lo:hi])
    assert be.crawl_rounds._cache_size() == c0, (
        f"hot shard re-jitted process {PROC_ID}")

    import os
    ref = np.load(os.path.join(tmpdir, "ref.npz"))
    for name, got in [("idsA", idsA), ("valsA", valsA), ("idsB", idsB),
                      ("valsB", valsB), ("idsA2", idsA2),
                      ("valsA2", valsA2), ("idsC", idsC), ("valsC", valsC)]:
        np.testing.assert_array_equal(np.asarray(got), ref[name],
                                      err_msg=name)

    # The capacity contracts are mandatory on multi-process meshes: the
    # per-host conversion cannot invent a cap all hosts agree on.
    s.feed_cap = None
    try:
        s.run_rounds(np.zeros((R, hi - lo), np.int32))
        raise AssertionError("feed without feed_cap must raise")
    except ValueError:
        pass
    s.feed_cap = 64
    s.update_cap = None
    try:
        s.update_pages(upd_ids, upd_env)
        raise AssertionError("update without update_cap must raise")
    except ValueError:
        pass
    print("MULTIHOST_OK")
    """, n_procs=2, devices_per_proc=2, timeout=900, token="MULTIHOST_OK",
        tmpdir=tmpdir)


# ---------------------------------------------------------------------------
# Request-driven importance across hosts: host-local logging, joint fold.
# ---------------------------------------------------------------------------

# Same rng order on every process: identical request batches, feeds, probe.
_IMPORTANCE_SETUP = """
    import numpy as np
    import jax.numpy as jnp
    from repro.sched import backends as be
    from repro.sched.service import CrawlScheduler
    from repro.sim import uniform_instance

    mesh = jax.make_mesh((4,), ("data",))
    m, k, R, dt = 16384, 16, 6, 0.05
    env = uniform_instance(jax.random.PRNGKey(0), m)
    s = CrawlScheduler(env, mesh, bandwidth=float(k) / dt, round_period=dt,
                       backend=be.FusedBackend(block_rows=8), feed_cap=64,
                       importance=True, importance_decay=0.9,
                       request_cap=4096)
    rng = np.random.default_rng(13)
    pop = 1.0 / (1.0 + np.arange(m)) ** 1.1
    pop /= pop.sum()
    req_batches = [rng.choice(m, size=3000, p=pop) for _ in range(4)]
    feeds = np.zeros((R, m), np.int32)
    for r in range(R):
        idx = rng.choice(m, 20, replace=False)
        feeds[r, idx] = rng.integers(1, 9, 20)
    probe = np.sort(rng.choice(m, 64, replace=False))
"""


@pytest.mark.slow
def test_two_process_importance_fold_matches_single_host(tmp_path):
    """Request-driven importance on a genuine 2-process mesh: each host
    logs the SAME global request batches (its router keeps only local
    rows — the drop contract), the fold's one psum re-anchors a mu_total
    every host agrees on, the refolded MU_T plane matches the single-host
    4-shard reference per page, selections after the fold match, and the
    serve path answers local probes with the reference posteriors (NaN for
    remote rows). Values compare at 1e-6 like `from_local_env`: the
    per-shard partial sums may meet in a different order across process
    layouts."""
    tmpdir = str(tmp_path)
    run_forced_shards(_IMPORTANCE_SETUP + """
    for b in req_batches:
        s.log_requests(b)
    s.fold_importance()
    ids, vals = s.run_rounds(feeds)
    p = s.serve_requests(probe, log=False)
    mu_probe = np.asarray(s._gather_mu_t(jnp.asarray(probe)))
    import os
    np.savez(os.path.join(tmpdir, "ref.npz"), ids=np.asarray(ids),
             vals=np.asarray(vals), p=p, mu_probe=mu_probe,
             mu_total=float(s.mu_total))
    print("REF_OK")
    """, n_devices=4, timeout=900, token="REF_OK", tmpdir=tmpdir)

    run_distributed(_IMPORTANCE_SETUP + """
    lo, hi = s.host_slice.start, s.host_slice.stop
    assert s.is_multiprocess
    for b in req_batches:
        s.log_requests(b)          # full batch: remote rows drop host-side
    s.fold_importance()            # the collective: all hosts together
    ids, vals = s.run_rounds(feeds[:, lo:hi])
    c0 = be.crawl_rounds._cache_size()
    p = s.serve_requests(probe, log=False)

    import os
    ref = np.load(os.path.join(tmpdir, "ref.npz"))
    np.testing.assert_allclose(float(s.mu_total), float(ref["mu_total"]),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ids), ref["ids"])
    np.testing.assert_allclose(np.asarray(vals), ref["vals"], rtol=1e-6)
    here = (probe >= lo) & (probe < hi)
    assert np.isnan(p[~here]).all(), "remote probe rows must answer NaN"
    np.testing.assert_allclose(p[here], ref["p"][here], rtol=1e-6)
    mu_loc = np.asarray(s._gather_mu_t(jnp.asarray(probe[here])))
    np.testing.assert_allclose(mu_loc, ref["mu_probe"][here], rtol=1e-6)

    # Logging, folding, and serving between rounds keep THIS host's jit
    # cache flat — asserted on both hosts.
    s.log_requests(req_batches[0])
    s.fold_importance()
    s.run_rounds(feeds[:, lo:hi])
    assert be.crawl_rounds._cache_size() == c0, (
        f"importance path re-jitted process {PROC_ID}")
    print("IMPORTANCE_OK")
    """, n_procs=2, devices_per_proc=2, timeout=900, token="IMPORTANCE_OK",
        tmpdir=tmpdir)
