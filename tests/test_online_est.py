"""Streaming on-device change-rate estimation (`sched.online_est` +
`FusedBackend(online_est=True)`): the in-scan learning loop.

Estimator-level tests pin `estimation.stream_update`/`stream_quality` in
isolation: a whole-trace fold equals the sequential fold (pure
accumulation), a hypothesis property drives traces drawn from the paper's
freshness model through the closed-form conditional-moment estimator and
checks convergence to the ground truth AND to `fit_mle` on the same trace,
and the degenerate pages of the ISSUE are regression-pinned (a
never-changing page under false-positive-only CIS stays finite with
precision -> 0; a never-crawled page holds its prior exactly).

Scheduler-level tests close the loop: with `online_est=True` and no
outcomes the macro-round is BIT-IDENTICAL to the non-estimating path; a
full `run_rounds(feeds, outcomes=...)` batch completes under a poisoned
`jax.device_get` (zero per-round host transfers — the tentpole's
no-host-sync guarantee); the closed-loop driver (`sim.driver`) started
from a WRONG (Delta, lambda, nu) belief converges (regret well under the
no-learning floor); the streaming steady state matches the batch-MLE
reference (`fit_mle_pages`) on the same realized trace; and the estimator
planes survive the sharded checkpoint round-trip, with pre-estimation
snapshots still restoring under `strict=False` (estimation starts from
scratch — exactly the documented compat contract).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import strategies
from _hypothesis_compat import given, settings, st
from repro.checkpoint import store as ckpt
from repro.core import Env, estimation
from repro.sched import backends as be
from repro.sched import errors
from repro.sched import online_est as oest
from repro.sched.service import CrawlScheduler
from repro.sim import (LoopConfig, freshness_regret, run_closed_loop,
                       tiered_cis_instance, uniform_instance)
from repro.sim.instances import TIER_NAMES


def _mesh1():
    return jax.make_mesh((1,), ("data",))


# ---------------------------------------------------------------------------
# Estimator level: stream_update / stream_quality on single-page traces.
# ---------------------------------------------------------------------------

def _sim_trace(rng, alpha, b, gamma, n_obs, tau_hi=4.0):
    """Observations from the paper's freshness model: tau ~ U(0.2, tau_hi),
    n ~ Poisson(gamma tau), z ~ Ber(exp(-(alpha tau + b n)))."""
    tau = rng.uniform(0.2, tau_hi, n_obs).astype(np.float32)
    n = rng.poisson(gamma * tau).astype(np.int32)
    p = np.exp(-(alpha * tau + b * n))
    z = (rng.random(n_obs) < p).astype(np.int32)
    return tau, n, z


def _fold(tau, n, z) -> estimation.StreamStats:
    """Fold a whole single-page trace at once: `stream_update` is pure
    accumulation from zero, so an (n_obs,)-shaped elementwise update summed
    field-wise equals the sequential per-observation fold."""
    per = estimation.stream_update(estimation.stream_init(tau.shape),
                                   jnp.asarray(tau), jnp.asarray(n),
                                   jnp.asarray(z))
    return estimation.StreamStats(*(p.sum() for p in per))


def test_stream_fold_matches_sequential():
    rng = np.random.default_rng(0)
    tau, n, z = _sim_trace(rng, 0.3, 1.0, 0.8, 32)
    s = estimation.stream_init(())
    for t, nn, zz in zip(tau, n, z):
        s = estimation.stream_update(s, jnp.float32(t), jnp.float32(nn),
                                     jnp.float32(zz))
    batch = _fold(tau, n, z)
    for name, a, b_ in zip(estimation.StreamStats._fields, s, batch):
        np.testing.assert_allclose(float(a), float(b_), rtol=1e-5,
                                   err_msg=name)


def _converges_case(alpha, b, gamma, seed):
    """Convergence gates shared by the hypothesis property and its
    deterministic twin: on a long trace from the model, the closed-form
    streaming estimator lands near the ground truth AND near `fit_mle` run
    on the exact same trace (both are consistent for the same
    (alpha, b, gamma); tolerances are calibrated to the estimators'
    sampling noise at 6000 observations over these parameter ranges —
    loose, but far tighter than the >100% errors of a broken group split
    or Jensen term)."""
    rng = np.random.default_rng(seed)
    tau, n, z = _sim_trace(rng, alpha, b, gamma, 6000)
    q = estimation.stream_quality(_fold(tau, n, z))
    for f in q:
        assert np.isfinite(float(f))
    prec_t = -np.expm1(-b)
    delta_t = alpha + gamma * prec_t
    assert abs(float(q.alpha) - alpha) <= 0.45 * max(alpha, 0.05)
    assert abs(float(q.b) - b) <= 0.7 * max(b, 0.2)
    assert abs(float(q.delta) - delta_t) <= 0.35 * delta_t
    qm = estimation.fit_mle_pages(tau[None], n[None], z[None])
    assert abs(float(q.delta - qm.delta[0])) <= 0.30 * delta_t
    assert abs(float(q.recall - qm.recall[0])) <= 0.20
    nu_s = float(q.gamma * (1.0 - q.precision))
    nu_m = float(qm.gamma[0] * (1.0 - qm.precision[0]))
    assert abs(nu_s - nu_m) <= 0.30


@settings(max_examples=10, deadline=None)
@given(alpha=st.floats(0.05, 0.6), b=st.floats(0.2, 2.0),
       gamma=st.floats(0.2, 1.5), seed=st.integers(0, 2**16))
def test_property_stream_converges_to_mle_and_truth(alpha, b, gamma, seed):
    _converges_case(alpha, b, gamma, seed)


@pytest.mark.parametrize("alpha,b,gamma,seed", [
    (0.1, 0.5, 0.4, 11), (0.4, 1.5, 1.0, 12), (0.25, 0.8, 1.4, 13),
])
def test_stream_converges_fixed_params(alpha, b, gamma, seed):
    """Deterministic twin of the hypothesis property — the convergence
    gates still run where hypothesis is not installed."""
    _converges_case(alpha, b, gamma, seed)


def test_degenerate_never_changing_page_false_positive_cis():
    """A page that NEVER changes but receives false-positive CIS: every
    crawl finds it fresh (z = 1 always), n ~ Poisson(nu tau). The estimator
    must stay finite, drive precision (and b) to ~0, and report a small
    delta — not divide by an empty group or produce a negative rate."""
    rng = np.random.default_rng(3)
    tau = rng.uniform(0.2, 4.0, 800).astype(np.float32)
    n = rng.poisson(0.6 * tau).astype(np.int32)
    z = np.ones_like(n)
    q = estimation.stream_quality(_fold(tau, n, z))
    for name, f in zip(estimation.CISQuality._fields, q):
        assert np.isfinite(float(f)), name
    assert float(q.alpha) < 0.05
    assert float(q.b) < 0.05
    assert float(q.precision) < 0.05
    assert float(q.delta) < 0.05
    assert float(q.recall) >= 0.0
    # gamma still tracks the (false) signal rate, so nu ~ gamma survives
    # as the false-positive explanation of the observed CIS.
    np.testing.assert_allclose(float(q.gamma), 0.6, atol=0.1)


def test_degenerate_never_crawled_page_holds_prior():
    """Zero statistics + a prior weight reproduce the prior EXACTLY, with
    no NaNs: the never-crawled page's packed parameters come only from
    (prior_a, prior_b) under shrinkage, and gamma = 0 (prior_w acts as
    pseudo-exposure-time, so an empty exposure never divides by zero)."""
    q = estimation.stream_quality(estimation.stream_init((4,)),
                                  prior_a=0.5, prior_b=1.0, prior_w=8.0)
    for name, f in zip(estimation.CISQuality._fields, q):
        assert np.all(np.isfinite(np.asarray(f))), name
    np.testing.assert_array_equal(np.asarray(q.alpha), 0.5)
    np.testing.assert_array_equal(np.asarray(q.b), 1.0)
    np.testing.assert_array_equal(np.asarray(q.gamma), 0.0)
    np.testing.assert_array_equal(np.asarray(q.delta), 0.5)
    # ... and without any prior the all-zero state still reads finite.
    q0 = estimation.stream_quality(estimation.stream_init((4,)))
    for name, f in zip(estimation.CISQuality._fields, q0):
        assert np.all(np.isfinite(np.asarray(f))), name


# ---------------------------------------------------------------------------
# Scheduler level: the in-scan loop.
# ---------------------------------------------------------------------------

def _sched(env, online_est, k=32, feed_cap=256, **kw):
    backend = be.FusedBackend(block_rows=8, online_est=online_est, **kw)
    return CrawlScheduler(env, _mesh1(), bandwidth=float(k), backend=backend,
                          feed_cap=feed_cap, outcome_cap=k)


def test_online_est_off_bit_identity():
    """With online_est=True and no outcomes the macro-round selection is
    bit-identical to the non-estimating scheduler — the estimator planes
    ride along without touching the selection until estimates apply."""
    m = 3000
    env = uniform_instance(jax.random.PRNGKey(0), m)
    s_off = _sched(env, False, k=32)
    s_on = _sched(env, True, k=32)
    est0 = s_on.round.backend.est
    assert isinstance(est0, estimation.StreamStats)
    assert s_off.round.backend.est is None
    for b in range(3):
        feeds = strategies.build_feed_batch(m, 4, "sparse", np.int32,
                                            seed=20 + b)
        ia, va = s_off.run_rounds(feeds)
        ib, vb = s_on.run_rounds(feeds)
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    # No outcomes ever arrived: the estimator planes are still all zero.
    for name, p in zip(estimation.StreamStats._fields,
                       s_on.round.backend.est):
        assert float(jnp.abs(p).max()) == 0.0, name


def test_outcome_batch_validation():
    m = 2000
    env = uniform_instance(jax.random.PRNGKey(1), m)
    feeds = strategies.build_feed_batch(m, 2, "sparse", np.int32, seed=1)
    ids = np.full((2, 8), -1, np.int64)
    chg = np.zeros((2, 8), np.int64)
    tau = np.zeros((2, 8), np.float32)
    ncis = np.zeros((2, 8), np.int64)
    # outcomes against a non-estimating backend: rejected up front.
    s_off = _sched(env, False)
    with pytest.raises(errors.FeedValidationError, match="online_est"):
        s_off.run_rounds(feeds, outcomes=(ids, chg, tau, ncis))
    s = _sched(env, True)
    with pytest.raises(errors.FeedValidationError, match="n_cis"):
        s.run_rounds(feeds, outcomes=(ids, chg, tau))
    with pytest.raises(errors.FeedDtypeError, match="integer"):
        s.run_rounds(feeds, outcomes=(ids, chg, tau,
                                      ncis.astype(np.float32)))
    with pytest.raises(errors.FeedValidationError, match="rounds"):
        s.run_rounds(feeds, outcomes=(ids[:1], chg[:1], tau[:1], ncis[:1]))
    with pytest.raises(errors.FeedValidationError, match="page ids"):
        bad = ids.copy()
        bad[0, 0] = m + 7
        s.run_rounds(feeds, outcomes=(bad, chg, tau, ncis))


def test_macro_round_zero_host_transfers():
    """THE tentpole guarantee: a full estimating macro-round — outcome
    ingest, in-scan estimator updates, and the macro-boundary estimate ->
    policy repack — completes with `jax.device_get` poisoned. The learning
    loop never leaves the device."""
    m = 3000
    env = uniform_instance(jax.random.PRNGKey(2), m)
    s = _sched(env, True, k=32, est_min_obs=1.0)
    feeds = strategies.build_feed_batch(m, 4, "sparse", np.int32, seed=3)
    ids0, _ = s.run_rounds(feeds)  # compile + get real crawled page ids
    ids_np = np.asarray(ids0)
    out = (ids_np, np.ones_like(ids_np), np.full(ids_np.shape, 1.5,
                                                 np.float32),
           np.zeros(ids_np.shape, np.int64))

    def die(*_a, **_kw):
        raise AssertionError("estimating macro-round called jax.device_get")

    real, jax.device_get = jax.device_get, die
    try:
        ids1, vals1 = s.run_rounds(feeds, outcomes=out)
    finally:
        jax.device_get = real
    assert np.asarray(ids1).shape == ids_np.shape
    assert np.all(np.isfinite(np.asarray(vals1)))
    # The outcomes actually landed: estimator planes are no longer zero.
    assert float(jnp.max(s.round.backend.est.n_obs)) >= 1.0


# ---------------------------------------------------------------------------
# Closed loop: wrong belief -> convergence; streaming vs batch-MLE parity.
# ---------------------------------------------------------------------------

_LOOP = dict(m=1024, k=32, R=16, NB=30)


@functools.lru_cache(maxsize=1)
def _closed_loop_runs():
    """One oracle / no-learning / streaming trio on the tiered-CIS
    instance, shared by the convergence and parity tests (the streaming
    run is the expensive part)."""
    m, k, R, NB = (_LOOP[x] for x in ("m", "k", "R", "NB"))
    inst = tiered_cis_instance(jax.random.PRNGKey(1), m)
    env_true = inst.env
    env_wrong = Env(delta=jnp.full((m,), 0.5), mu=env_true.mu,
                    lam=jnp.zeros((m,)), nu=jnp.zeros((m,)))
    cfg = lambda mode: LoopConfig(n_batches=NB, rounds_per_batch=R,
                                  mode=mode, seed=7)
    # feed_cap=None: the simulated CIS feeds are dense at these rates (a
    # large fraction of the 1024 pages signals every round), so the COO
    # cap derives from the batch instead of a production contract.
    oracle = run_closed_loop(_sched(env_true, False, k=k, feed_cap=None),
                             env_true, cfg("fixed"))
    fixed = run_closed_loop(_sched(env_wrong, False, k=k, feed_cap=None),
                            env_true, cfg("fixed"))
    stream = run_closed_loop(_sched(env_wrong, True, k=k, feed_cap=None),
                             env_true, cfg("streaming"))
    return inst, env_true, oracle, fixed, stream


def test_closed_loop_streaming_converges_from_wrong_belief():
    """A scheduler constructed with a WRONG (Delta, lambda, nu) belief and
    driven with `run_rounds(feeds, outcomes=...)` must learn: its
    steady-state freshness regret vs the oracle lands well under the
    no-learning floor (calibrated: ~0.52x at these sizes; 0.75x is the
    regression gate)."""
    _, _, oracle, fixed, stream = _closed_loop_runs()
    r_fixed = freshness_regret(fixed, oracle)
    r_stream = freshness_regret(stream, oracle)
    assert r_fixed > 0.02  # the wrong belief really does cost freshness
    assert r_stream < 0.75 * r_fixed, (r_stream, r_fixed)


def test_streaming_steady_state_matches_batch_mle():
    """Batch-MLE parity (the reference the ISSUE pins): fold the closed
    loop's realized crawl log through the streaming statistics and compare
    against `fit_mle_pages` on the SAME grouped trace. Medians over the
    well-observed pages gate the parity — per-page tails are sampling
    noise in BOTH estimators (calibrated: median delta rel err ~0.12)."""
    _, env_true, _, _, stream = _closed_loop_runs()
    m = _LOOP["m"]
    ids, tau, n, z = stream.obs
    no = (n == 0)
    one = (n == 1)

    def acc(v, w):
        return np.bincount(ids, weights=np.asarray(v, np.float64) * w,
                           minlength=m)

    stats = estimation.StreamStats(
        n0=acc(no, 1.0), f0=acc(no & (z > 0), 1.0), t0=acc(tau, no),
        q0=acc(tau * tau, no), n1=acc(one, 1.0), f1=acc(one & (z > 0), 1.0),
        t1=acc(tau, one), n_obs=acc(np.ones_like(tau), 1.0),
        t_obs=acc(tau, 1.0), c_obs=acc(n, 1.0))
    stats = estimation.StreamStats(*(jnp.asarray(p, jnp.float32)
                                     for p in stats))
    q_s = estimation.stream_quality(stats)

    uniq, inv = np.unique(ids, return_inverse=True)
    counts = np.bincount(inv)
    order = np.argsort(inv, kind="stable")
    col = np.concatenate([np.arange(c) for c in counts])
    width = int(counts.max())
    tau_m = np.zeros((uniq.size, width), np.float32)
    n_m = np.zeros((uniq.size, width), np.int32)
    z_m = np.ones((uniq.size, width), np.int32)
    tau_m[inv[order], col] = tau[order]
    n_m[inv[order], col] = n[order]
    z_m[inv[order], col] = z[order]
    q_m = estimation.fit_mle_pages(tau_m, n_m, z_m)

    well = counts >= 25
    assert well.sum() >= 50  # the loop crawled enough pages to compare
    pid = uniq[well]
    d_s, d_m = np.asarray(q_s.delta)[pid], np.asarray(q_m.delta)[well]
    l_s = np.clip(np.asarray(q_s.recall)[pid], 0, 1)
    l_m = np.clip(np.asarray(q_m.recall)[well], 0, 1)
    nu_s = np.asarray(q_s.gamma * (1 - q_s.precision))[pid]
    nu_m = np.asarray(q_m.gamma * (1 - q_m.precision))[well]
    assert np.median(np.abs(d_s - d_m) / np.maximum(d_m, 0.05)) <= 0.30
    assert np.median(np.abs(l_s - l_m)) <= 0.15
    assert np.median(np.abs(nu_s - nu_m)) <= 0.10
    # ... and both estimators track the TRUE delta of the realized trace.
    d_t = np.asarray(env_true.delta)[pid]
    assert np.median(np.abs(d_s - d_t) / d_t) <= 0.35
    assert np.median(np.abs(d_m - d_t) / d_t) <= 0.35


# ---------------------------------------------------------------------------
# Checkpointing: estimator planes round-trip; old snapshots restore.
# ---------------------------------------------------------------------------

def test_est_planes_survive_checkpoint_roundtrip(tmp_path):
    m = 3000
    env = uniform_instance(jax.random.PRNGKey(4), m)
    s = _sched(env, True, est_min_obs=1.0)
    feeds = strategies.build_feed_batch(m, 3, "sparse", np.int32, seed=5)
    ids0, _ = s.run_rounds(feeds)
    ids_np = np.asarray(ids0)
    out = (ids_np, np.zeros_like(ids_np),
           np.full(ids_np.shape, 2.0, np.float32),
           np.zeros(ids_np.shape, np.int64))
    s.run_rounds(feeds, outcomes=out)  # non-trivial estimator state

    d = str(tmp_path / "ck")
    ckpt.save(d, 1, s.state_dict(), sharded=True)
    s2 = _sched(env, True, est_min_obs=1.0)
    restored, step, _ = ckpt.restore_latest(d, s2.state_dict())
    assert step == 1
    s2.load_state_dict(restored)
    for name, a, b_ in zip(estimation.StreamStats._fields,
                           s.round.backend.est, s2.round.backend.est):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_),
                                      err_msg=name)
    assert s2.rounds_completed == s.rounds_completed
    # Continued estimating rounds are bit-identical too.
    nxt = strategies.build_feed_batch(m, 3, "sparse", np.int32, seed=6)
    ia, va = s.run_rounds(nxt, outcomes=out)
    ib, vb = s2.run_rounds(nxt, outcomes=out)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))


def test_pre_estimation_snapshot_restores_with_est_off(tmp_path):
    """Compat contract: a snapshot saved BEFORE estimation existed (an
    online_est=False state has no `est` leaves — None is an empty subtree)
    restores into an estimating scheduler with strict=False: every live
    plane restores, the estimator starts from scratch (all-zero planes),
    and the continued selection matches the non-estimating continuation
    bit for bit."""
    m = 3000
    env = uniform_instance(jax.random.PRNGKey(5), m)
    s_old = _sched(env, False)
    feeds = strategies.build_feed_batch(m, 3, "sparse", np.int32, seed=7)
    s_old.run_rounds(feeds)
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, s_old.state_dict(), sharded=True)

    s_new = _sched(env, True)
    restored, _ = ckpt.restore(d, 1, s_new.state_dict(), strict=False)
    s_new.load_state_dict(restored)
    for name, p in zip(estimation.StreamStats._fields,
                       s_new.round.backend.est):
        assert float(jnp.abs(p).max()) == 0.0, name
    nxt = strategies.build_feed_batch(m, 2, "sparse", np.int32, seed=8)
    ia, va = s_old.run_rounds(nxt)
    ib, vb = s_new.run_rounds(nxt)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))


# ---------------------------------------------------------------------------
# The tiered-CIS instance (the estimation-fairness substrate).
# ---------------------------------------------------------------------------

def test_tiered_cis_instance_regimes():
    m = 4096
    inst = tiered_cis_instance(jax.random.PRNGKey(9), m)
    tier = np.asarray(inst.tier)
    lam = np.asarray(inst.env.lam)
    nu = np.asarray(inst.env.nu)
    assert tier.min() >= 0 and tier.max() < len(TIER_NAMES)
    frac = np.bincount(tier, minlength=3) / m
    np.testing.assert_allclose(frac, (0.3, 0.5, 0.2), atol=0.05)
    rel, noisy, silent = (tier == 0), (tier == 1), (tier == 2)
    assert lam[rel].min() >= 0.8 and nu[rel].max() <= 0.05
    assert lam[noisy].min() >= 0.2 and lam[noisy].max() <= 0.6
    assert nu[noisy].min() >= 0.3 and nu[noisy].max() <= 0.8
    np.testing.assert_array_equal(lam[silent], 0.0)
    np.testing.assert_array_equal(nu[silent], 0.0)
    assert np.asarray(inst.env.delta).min() >= 0.05
    # Deterministic in the key; tier independent of (delta, mu).
    inst2 = tiered_cis_instance(jax.random.PRNGKey(9), m)
    np.testing.assert_array_equal(tier, np.asarray(inst2.tier))
    np.testing.assert_array_equal(np.asarray(inst.env.delta),
                                  np.asarray(inst2.env.delta))
