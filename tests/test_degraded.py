"""Degraded-mode scheduling (`FusedBackend(degraded=True)`): healthy-path
bit-identity, the per-block staleness watchdog, outage compensation,
estimator quarantine, checkpoint compatibility, and the host-side
outcome-echo gate (`sched.degraded`)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sched import backends as be
from repro.sched import online_est
from repro.sched.degraded import OutcomeGate, retry_with_backoff
from repro.sched.errors import FeedValidationError
from repro.sched.service import CrawlScheduler
from repro.sim import tiered_cis_instance


def _mesh1():
    return jax.make_mesh((1,), ("data",))


M = 1024
K = 8
DT = 0.5
BP = 2 * 128  # block_rows=2 -> pages per block


def _mk(env, degraded, stale_limit=3, **kw):
    backend = be.FusedBackend(block_rows=2, adaptive_bounds=True,
                              degraded=degraded, stale_limit=stale_limit,
                              **kw)
    return CrawlScheduler(env, _mesh1(), bandwidth=K / DT, round_period=DT,
                          backend=backend)


def _env():
    return tiered_cis_instance(jax.random.PRNGKey(1), M).env


def _healthy_feeds(rng, n_rounds):
    """Every block sees CIS every round — no block ever goes silent."""
    feeds = rng.poisson(0.05, (n_rounds, M)).astype(np.int32)
    feeds[:, ::BP] += 1
    return feeds


def _outage_feeds(rng, n_rounds):
    """Blocks 0-1 dark for the whole batch; blocks 2-3 healthy."""
    feeds = _healthy_feeds(rng, n_rounds)
    feeds[:, :2 * BP] = 0
    return feeds


# -- healthy-path bit-identity ----------------------------------------------

def test_healthy_bit_identity_sequential():
    env = _env()
    rng = np.random.default_rng(0)
    feeds = _healthy_feeds(rng, 12)
    s_off, s_on = _mk(env, False), _mk(env, True)
    for r in range(12):
        ids0, vals0 = s_off.ingest_and_schedule(feeds[r])
        ids1, vals1 = s_on.ingest_and_schedule(feeds[r])
        np.testing.assert_array_equal(np.asarray(ids0), np.asarray(ids1))
        np.testing.assert_array_equal(np.asarray(vals0), np.asarray(vals1))
    np.testing.assert_array_equal(np.asarray(s_off.round.tau_elap),
                                  np.asarray(s_on.round.tau_elap))
    # Watchdog saw CIS every round on every block.
    assert int(np.asarray(s_on.round.backend.stale).max()) == 0


def test_healthy_bit_identity_macro():
    env = _env()
    feeds = _healthy_feeds(np.random.default_rng(1), 10)
    s_off, s_on = _mk(env, False), _mk(env, True)
    ids0, vals0 = s_off.run_rounds(feeds)
    ids1, vals1 = s_on.run_rounds(feeds)
    np.testing.assert_array_equal(np.asarray(ids0), np.asarray(ids1))
    np.testing.assert_array_equal(np.asarray(vals0), np.asarray(vals1))
    np.testing.assert_array_equal(np.asarray(s_off.round.tau_elap),
                                  np.asarray(s_on.round.tau_elap))


# -- the watchdog + compensation under outage --------------------------------

def test_stale_counts_and_resets():
    env = _env()
    s = _mk(env, True, stale_limit=100)
    feeds = _outage_feeds(np.random.default_rng(2), 6)
    s.run_rounds(feeds)
    stale = np.asarray(s.round.backend.stale)
    np.testing.assert_array_equal(stale, [6, 6, 0, 0])
    # One healthy round resets the dark blocks' counters.
    s.ingest_and_schedule(_healthy_feeds(np.random.default_rng(3), 1)[0])
    np.testing.assert_array_equal(np.asarray(s.round.backend.stale),
                                  [0, 0, 0, 0])


def test_outage_compensation_changes_selection():
    env = _env()
    rng = np.random.default_rng(4)
    feeds = _outage_feeds(rng, 16)
    s_off, s_on = _mk(env, False), _mk(env, True)
    ids0, vals0 = s_off.run_rounds(feeds)
    ids1, vals1 = s_on.run_rounds(feeds)
    assert not np.array_equal(np.asarray(ids0), np.asarray(ids1)), (
        "degraded mode must re-evaluate silent blocks under an outage")
    assert np.isfinite(np.asarray(vals1)[np.asarray(ids1) >= 0]).all()


def test_outage_macro_matches_sequential_bitwise():
    env = _env()
    feeds = _outage_feeds(np.random.default_rng(5), 10)
    s_seq, s_mac = _mk(env, True), _mk(env, True)
    seq_ids, seq_vals = [], []
    for r in range(10):
        i, v = s_seq.ingest_and_schedule(feeds[r])
        seq_ids.append(np.asarray(i))
        seq_vals.append(np.asarray(v))
    mac_ids, mac_vals = s_mac.run_rounds(feeds)
    np.testing.assert_array_equal(np.stack(seq_ids), np.asarray(mac_ids))
    np.testing.assert_array_equal(np.stack(seq_vals), np.asarray(mac_vals))
    np.testing.assert_array_equal(np.asarray(s_seq.round.backend.stale),
                                  np.asarray(s_mac.round.backend.stale))


def test_no_host_sync_in_degraded_macro_scan():
    env = _env()
    s = _mk(env, True, online_est=True)
    feeds = _outage_feeds(np.random.default_rng(6), 6)
    s.run_rounds(feeds)  # compile outside the poisoned window
    real = jax.device_get

    def die(*a, **k):  # pragma: no cover - only on regression
        raise AssertionError("host sync inside the degraded macro-round")

    jax.device_get = die
    try:
        s.run_rounds(_outage_feeds(np.random.default_rng(7), 6))
    finally:
        jax.device_get = real


# -- checkpointing -----------------------------------------------------------

def test_stale_plane_checkpoint_roundtrip():
    env = _env()
    s = _mk(env, True)
    s.run_rounds(_outage_feeds(np.random.default_rng(8), 5))
    sd = jax.device_get(s.state_dict())
    assert int(np.asarray(sd["backend"].stale).max()) == 5
    s2 = _mk(env, True)
    s2.load_state_dict(sd)
    np.testing.assert_array_equal(np.asarray(s2.round.backend.stale),
                                  np.asarray(sd["backend"].stale))
    # The restored scheduler keeps counting from the snapshot.
    s2.run_rounds(_outage_feeds(np.random.default_rng(9), 2))
    assert int(np.asarray(s2.round.backend.stale).max()) == 7


def test_pre_degraded_snapshot_restores_into_degraded():
    env = _env()
    sd = jax.device_get(_mk(env, False).state_dict())
    assert sd["backend"].stale is None
    s = _mk(env, True)
    s.load_state_dict(sd)
    st = s.round.backend.stale
    assert st is not None
    assert int(np.asarray(st).sum()) == 0


def test_degraded_snapshot_restores_into_healthy():
    env = _env()
    s = _mk(env, True)
    s.run_rounds(_outage_feeds(np.random.default_rng(10), 3))
    sd = jax.device_get(s.state_dict())
    s2 = _mk(env, False)
    s2.load_state_dict(sd)
    np.testing.assert_array_equal(np.asarray(s2.round.backend.stale),
                                  np.asarray(sd["backend"].stale))


# -- estimator quarantine ----------------------------------------------------

def test_quarantine_freezes_stream_stats():
    stats = online_est.init_est(64)
    oidx = jnp.array([3, 9], jnp.int32)
    chg = jnp.array([1, 1], jnp.int32)
    tau = jnp.array([1.0, 1.0], jnp.float32)
    ncis = jnp.array([2, 2], jnp.int32)
    quar = jnp.array([False, True])
    out = online_est.ingest_outcomes(stats, oidx, chg, tau, ncis,
                                     quarantine=quar)
    assert float(out.n_obs[3]) == 1.0
    assert float(out.n_obs[9]) == 0.0          # quarantined: untouched
    # quarantine=None is the exact legacy path.
    out2 = online_est.ingest_outcomes(stats, oidx, chg, tau, ncis)
    assert float(out2.n_obs[9]) == 1.0


def test_quarantine_protects_outage_page_estimates():
    """Outcomes of pages in silent blocks must not drag the streaming
    estimates: with degraded=True the macro round discards them, so the
    (alpha, b, gamma)-bearing statistics of outage pages stay at their
    pre-outage values."""
    env = _env()
    s = _mk(env, True, stale_limit=2, online_est=True)
    feeds = _outage_feeds(np.random.default_rng(11), 8)
    ids, _ = s.run_rounds(feeds)
    before = jax.device_get(s.round.backend.est)
    # Echo every crawl as an outcome while blocks 0-1 are still dark.
    ids_np = np.asarray(ids)
    out = (ids_np, np.zeros_like(ids_np),
           np.full(ids_np.shape, 1.0, np.float32),
           np.zeros(ids_np.shape, np.int32))
    s.run_rounds(_outage_feeds(np.random.default_rng(12), 8), outcomes=out)
    after = jax.device_get(s.round.backend.est)
    dark = slice(0, 2 * BP)
    np.testing.assert_array_equal(before.n_obs[dark], after.n_obs[dark])


# -- outcome-batch dedupe (the scatter double-count bugfix) ------------------

def test_outcome_batch_duplicate_ids_keep_last():
    env = _env()
    s = _mk(env, False, online_est=True)
    ids = np.full((2, 5), -1, np.int32)
    ids[0, :3] = [7, 7, 9]
    chg = np.zeros_like(ids)
    chg[0, 0] = 1                       # the stale early duplicate
    tau = np.full(ids.shape, -1.0, np.float32)
    tau[0, :3] = [1.0, 2.0, 3.0]
    ncis = np.zeros_like(ids)
    so = s._sparse_outcome_batch(ids, chg, tau, ncis, 2)
    cell = np.asarray(so.ids)[0, 0]
    live = cell[cell >= 0]
    assert sorted(live.tolist()) == [7, 9]            # id-unique
    got_tau = np.asarray(so.tau)[0, 0][cell == 7]
    assert got_tau.tolist() == [2.0]                  # the LAST entry won
    assert np.asarray(so.changed)[0, 0][cell == 7].tolist() == [0]


def test_outcome_batch_duplicate_ids_single_count():
    env = _env()
    s = _mk(env, False, online_est=True)
    feeds = np.zeros((2, M), np.int32)
    ids = np.full((2, 4), -1, np.int32)
    ids[0, :2] = [5, 5]                 # same page twice in one round
    chg = np.zeros_like(ids)
    tau = np.full(ids.shape, -1.0, np.float32)
    tau[0, :2] = [1.0, 1.0]
    ncis = np.zeros_like(ids)
    s.run_rounds(feeds, outcomes=(ids, chg, tau, ncis))
    assert float(np.asarray(s.round.backend.est.n_obs)[5]) == 1.0


# -- host-side echo gate + retry --------------------------------------------

def test_outcome_gate_dedupes_and_ages_out():
    g = OutcomeGate(window=4)
    assert g.offer(0, "a") == "a"
    assert g.offer(0, "a") is None                    # duplicate
    assert g.offer(2, "b") == "b"
    assert g.offer(1, "c") == "c"                     # out of order: fine
    assert g.offer(10, "d") == "d"
    assert g.offer(5, "e") is None                    # below the window
    assert (g.accepted, g.dropped_dup, g.dropped_stale) == (4, 1, 1)
    with pytest.raises(ValueError):
        g.offer(-1, "x")
    g2 = OutcomeGate.from_state_dict(g.state_dict())
    assert g2.offer(10, "a") is None                  # memory survived


def test_retry_with_backoff_sequence():
    delays = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 4:
            raise OSError("transient")
        return "ok"

    out = retry_with_backoff(flaky, max_attempts=5, base_delay=0.1,
                             max_delay=0.25, sleep=delays.append)
    assert out == "ok"
    assert delays == [0.1, 0.2, 0.25]

    def always():
        raise TimeoutError("down")

    with pytest.raises(TimeoutError):
        retry_with_backoff(always, max_attempts=2, sleep=delays.append)

    def fatal():
        raise FeedValidationError("not transient")

    with pytest.raises(FeedValidationError):
        retry_with_backoff(fatal, sleep=delays.append)  # no retry


def test_run_rounds_outcome_seq_gates_duplicates():
    env = _env()
    s = _mk(env, False, online_est=True)
    feeds = np.zeros((2, M), np.int32)
    ids = np.full((2, 4), -1, np.int32)
    ids[0, 0] = 11
    chg = np.zeros_like(ids)
    tau = np.full(ids.shape, -1.0, np.float32)
    tau[0, 0] = 1.0
    ncis = np.zeros_like(ids)
    out = (ids, chg, tau, ncis)
    s.run_rounds(feeds, outcomes=out, outcome_seq=0)
    s.run_rounds(feeds, outcomes=out, outcome_seq=0)  # replayed batch
    assert float(np.asarray(s.round.backend.est.n_obs)[11]) == 1.0
    assert s.outcome_gate.dropped_dup == 1
    with pytest.raises(FeedValidationError):
        s.run_rounds(feeds, outcome_seq=2)            # seq without batch
