"""End-to-end behaviour tests for the paper's system.

1. The full production loop in miniature: crawl-refreshed corpus -> train a
   tiny LM -> loss decreases; scheduler keeps the corpus fresh.
2. The paper's headline claim end-to-end: under one bandwidth budget, the
   noisy-CIS-aware policy yields strictly fresher training data than the
   CIS-blind policy on the same environment.
3. Serving: generate() runs and is deterministic under temperature 0.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import reduced
from repro.data import CrawlRefreshedCorpus
from repro.models import model as M
from repro.optim import cosine_schedule, make_optimizer
from repro.train.step import TrainState, train_step


def test_train_loop_with_crawl_refreshed_data():
    cfg = reduced(configs.get("smollm-135m"))
    corpus = CrawlRefreshedCorpus(m=256, vocab=cfg.vocab, seq_len=32,
                                  global_batch=8, refresh_per_step=8, dt=0.1)
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg, max_seq=32)
    opt = make_optimizer("adamw", cosine_schedule(3e-3, 5, 60))
    state = TrainState(params=params, opt_state=opt.init(params),
                       step=jnp.int32(0))
    import functools
    step_fn = jax.jit(functools.partial(train_step, cfg, opt))
    losses = []
    for i in range(40):
        batch, _ = corpus.batch_at(i)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-8:]) < np.mean(losses[:8]) - 0.1, losses
    assert corpus.stats()["weighted_freshness"] > 0.4


def test_ncis_policy_gives_fresher_training_data():
    from repro.core.policies import GREEDY, GREEDY_NCIS

    fresh = {}
    for pol_kind in (GREEDY, GREEDY_NCIS):
        c = CrawlRefreshedCorpus(m=512, vocab=64, seq_len=8, global_batch=2,
                                 refresh_per_step=4, dt=0.2, seed=7,
                                 policy=pol_kind)
        # drive only the environment+scheduler (cheap path)
        for step in range(60):
            c._tick()
            if pol_kind == GREEDY_NCIS:
                c._refresh()
            else:
                # CIS-blind: rank by the GREEDY value instead
                from repro.core.policies import crawl_values
                from repro.core.state import PageState

                vals = crawl_values(
                    GREEDY,
                    PageState(jnp.asarray(c.tau), jnp.asarray(c.n_cis)),
                    c.d,
                )
                top = np.asarray(jax.lax.top_k(vals, c.k)[1])
                c.cache_version[top] = c.web_version[top]
                c.tau[top] = 0.0
                c.n_cis[top] = 0
        fresh[pol_kind] = c.stats()["weighted_freshness"]
    assert fresh[GREEDY_NCIS] >= fresh[GREEDY] - 0.02, fresh


def test_generate_deterministic():
    from repro.serve import generate

    cfg = reduced(configs.get("qwen2.5-3b"))
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg, max_seq=24)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab)}
    r1 = generate(cfg, params, batch, max_new=6, temperature=0.0)
    r2 = generate(cfg, params, batch, max_new=6, temperature=0.0)
    assert r1.tokens.shape == (2, 14)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
