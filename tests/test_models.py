"""Model zoo tests: per-arch reduced smoke (deliverable f), train/prefill/
decode consistency, attention oracle, chunked-vs-recurrent equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import reduced
from repro.models import model as M
from repro.models.attention import attention_reference, flash_attention

ARCHS = list(configs.ARCH_NAMES)


def _batch(cfg, key, b=2, s=32):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = 0.1 * jax.random.normal(key, (b, cfg.n_prefix, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    """Deliverable (f): reduced same-family config, one forward, shapes+finite."""
    cfg = reduced(configs.get(arch))
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg, max_seq=32)
    batch = _batch(cfg, key)
    logits, aux = M.forward_train(cfg, params, batch)
    from repro.models.common import pad_vocab

    assert logits.shape == (2, 32, pad_vocab(cfg.vocab))
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One reduced train step on CPU: loss finite, params update."""
    from repro.optim import make_optimizer
    from repro.train.step import TrainState, train_step

    cfg = reduced(configs.get(arch))
    key = jax.random.PRNGKey(1)
    params = M.init(key, cfg, max_seq=32)
    opt = make_optimizer(cfg.optimizer)
    state = TrainState(params=params, opt_state=opt.init(params),
                       step=jnp.int32(0))
    batch = _batch(cfg, key)
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    state2, metrics = train_step(cfg, opt, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    moved = jax.tree.leaves(
        jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     state.params, state2.params)
    )
    assert max(moved) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS])
def test_decode_consistency(arch):
    """prefill(S-1) + decode(1) logits == forward_train logits (f32 cache)."""
    cfg = reduced(configs.get(arch))
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    s = 24
    key = jax.random.PRNGKey(2)
    params = M.init(key, cfg, max_seq=s)
    batch = _batch(cfg, key, s=s)
    full, _ = M.forward_train(cfg, params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : s - 1]
    lg_pre, cache = M.prefill(cfg, params, pre, s_max=s,
                              cache_dtype=jnp.float32)
    lg_dec, _ = M.decode_step(cfg, params, batch["tokens"][:, s - 1:],
                              jnp.int32(s - 1), cache)
    tol = 5e-5 * max(float(jnp.max(jnp.abs(full))), 1.0)
    assert float(jnp.max(jnp.abs(lg_pre - full[:, s - 2]))) < tol
    assert float(jnp.max(jnp.abs(lg_dec - full[:, s - 1]))) < tol


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("cap", [0.0, 20.0])
@pytest.mark.parametrize("impl", ["triangle", "masked"])
def test_attention_oracle(causal, window, cap, impl):
    key = jax.random.PRNGKey(0)
    b, s, hq, hkv, dh = 2, 128, 4, 2, 16
    q = jax.random.normal(key, (b, s, hq, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, dh))
    ref = attention_reference(q, k, v, causal=causal, window=window, cap=cap)
    out = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                          impl=impl, q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(out, ref, atol=3e-5)


def test_mamba_chunk_equals_step():
    """Chunked SSD scan == token-by-token recurrence."""
    from repro.models import ssm

    cfg = reduced(configs.get("zamba2-2.7b"))
    from repro.models.common import init_params

    p = init_params(jax.random.PRNGKey(0), ssm.mamba_defs(cfg))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_chunk = ssm.mamba_apply(cfg, p, x)
    st = ssm.init_mamba_state(cfg, 2)
    ys = []
    for t in range(32):
        y, st = ssm.mamba_decode(cfg, p, x[:, t:t + 1], st)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_step, atol=2e-4)


def test_mlstm_chunk_equals_step():
    from repro.models import xlstm as xl
    from repro.models.common import init_params

    cfg = reduced(configs.get("xlstm-350m"))
    p = init_params(jax.random.PRNGKey(0), xl.mlstm_defs(cfg))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_chunk = xl.mlstm_apply(cfg, p, x)
    st = xl.init_mlstm_state(cfg, 2)
    ys = []
    for t in range(32):
        y, st = xl.mlstm_decode(cfg, p, x[:, t:t + 1], st)
        ys.append(y)
    np.testing.assert_allclose(y_chunk, jnp.concatenate(ys, 1), atol=2e-4)


def test_moe_dropless_matches_dense_gating():
    """With huge capacity, sorted dispatch == explicit per-expert sum."""
    from repro.models import moe as moe_mod
    from repro.models.common import init_params

    cfg = reduced(configs.get("qwen2-moe-a2.7b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0, n_shared=0)
    )
    p = init_params(jax.random.PRNGKey(0), moe_mod.moe_defs(cfg))
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = moe_mod.moe_apply(cfg, p, x, None)
    # dense-gating oracle
    topw, tope, _ = moe_mod._route(cfg, p, x)
    e = cfg.moe.n_experts
    y_ref = jnp.zeros_like(x)
    for ei in range(e):
        g = jnp.einsum("bsd,df->bsf", x, p["experts"]["w_gate"][ei])
        u = jnp.einsum("bsd,df->bsf", x, p["experts"]["w_up"][ei])
        o = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                       p["experts"]["w_down"][ei])
        wsel = jnp.sum(jnp.where(tope == ei, topw, 0.0), axis=-1)
        y_ref = y_ref + o * wsel[..., None].astype(o.dtype)
    np.testing.assert_allclose(y, y_ref, atol=3e-5)
