import os
import sys

# src/ layout import path (tests run as PYTHONPATH=src pytest tests/)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single-device host; only launch/dryrun.py forces 512 devices.
