"""End-to-end fidelity: CrawlScheduler vs sim.simulator on ONE shared
realized event trace.

The simulator (paper Section 3) is the ground-truth harness: per tick it
scores pages, crawls the top-k, samples Poisson change / signalled-change /
false-CIS counts, and integrates importance-weighted freshness exactly
(E[min of N uniforms] = 1/(N+1)). The production scheduler consumes the
same information as a CIS feed stream. This test pre-realizes the
simulator's event trace (same keys, same `_sample_counts`), drives the
scheduler round-by-round with the realized CIS arrivals, integrates its
freshness with the simulator's exact formula, and asserts the two
importance-weighted freshness numbers agree within tolerance — pinning the
whole service data path (feed validation, sparse ingest, fused selection)
to the paper's discrete-policy baseline, not just to internal
self-consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policies as pol
from repro.core.values import derive
from repro.sched import backends as be
from repro.sched.service import CrawlScheduler
from repro.sim import uniform_instance
from repro.sim.simulator import (
    SimConfig,
    _resolve_count_mode,
    _sample_counts,
    simulate,
)

M, K, DT, STEPS = 600, 3, 0.2, 80


def _realized_trace(key, env, cfg):
    """The exact per-tick event counts the simulator will sample: same key
    folding, same `_sample_counts`, same count mode. Returns
    (n_changes, cis_arrivals) as (n_steps, m) int arrays."""
    d = derive(env)
    rates_dt = np.asarray(
        jnp.stack([d.lam * d.delta, d.alpha, d.nu], axis=0) * cfg.dt)
    mode = _resolve_count_mode(cfg, env)
    changes, arrivals = [], []
    for t in range(cfg.n_steps):
        k_ev = jax.random.fold_in(key, t)
        cnt = np.asarray(_sample_counts(k_ev, jnp.asarray(rates_dt), mode))
        changes.append(cnt[0] + cnt[1])          # signalled + unsignalled
        arrivals.append(cnt[0] + cnt[2])         # signalled + false CIS
    return np.stack(changes), np.stack(arrivals)


def _freshness(mu_t, crawls, changes):
    """The simulator's exact freshness integral applied to an arbitrary
    crawl schedule: page fresh entering the tick (or crawled at its start)
    with N changes during the tick is fresh for 1/(N+1) of it."""
    m = mu_t.shape[0]
    stale = np.zeros((m,), bool)
    trace = []
    for t in range(changes.shape[0]):
        crawled = np.zeros((m,), bool)
        sel = np.asarray(crawls[t]).reshape(-1)
        # Elastic rounds pad slots past the round's budget with id -1.
        crawled[sel[sel >= 0]] = True
        fresh_after_crawl = (~stale) | crawled
        frac = np.where(fresh_after_crawl, 1.0 / (changes[t] + 1.0), 0.0)
        trace.append(float(np.sum(mu_t * frac)))
        stale = (stale & ~crawled) | (changes[t] > 0)
    return float(np.mean(trace))


def test_scheduler_freshness_matches_simulator_baseline():
    key = jax.random.PRNGKey(42)
    env = uniform_instance(jax.random.fold_in(key, 1), M)
    cfg = SimConfig(dt=DT, n_steps=STEPS, k_per_tick=K, value_impl="exact")
    changes, arrivals = _realized_trace(key, env, cfg)
    mu_t = np.asarray(derive(env).mu_t)

    # The paper's discrete-policy baseline on this very trace.
    sim = simulate(key, env, pol.GREEDY_NCIS, cfg)
    acc_sim = float(sim.accuracy)

    # The production scheduler, fed the identical realized CIS arrivals.
    mesh = jax.make_mesh((1,), ("data",))
    dense = CrawlScheduler(env, mesh, bandwidth=K / DT, round_period=DT,
                           backend=be.DenseBackend())
    assert dense.k_per_round == K
    crawls = []
    for t in range(STEPS):
        ids, _ = dense.ingest_and_schedule(jnp.asarray(arrivals[t]))
        crawls.append(np.asarray(ids))
    acc_dense = _freshness(mu_t, crawls, changes)

    # Same greedy policy, same trace, same freshness integral: the two
    # must agree to high precision (the only daylight is value-method
    # numerics — igamma vs series — flipping near-exact ties).
    np.testing.assert_allclose(acc_dense, acc_sim, rtol=0.02)

    # And the full production data path — fused backend, macro-rounds over
    # per-shard SparseFeeds with a pinned feed_cap contract — lands on the
    # same freshness (fused selection is provably dense top-k, so any drift
    # here is a data-path bug, not policy noise).
    fused = CrawlScheduler(env, mesh, bandwidth=K / DT, round_period=DT,
                           backend=be.FusedBackend(block_rows=8,
                                                   adaptive_bounds=True),
                           feed_cap=int(arrivals.sum(axis=1).max()) + 1)
    crawls_f = []
    R = 16
    for t0 in range(0, STEPS, R):
        ids, _ = fused.run_rounds(arrivals[t0:t0 + R])
        crawls_f.extend(np.asarray(ids))
    acc_fused = _freshness(mu_t, crawls_f, changes)
    np.testing.assert_allclose(acc_fused, acc_sim, rtol=0.02)
    np.testing.assert_allclose(acc_fused, acc_dense, rtol=5e-3)
