"""Unit + property tests for the paper's value functions (Theorem 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    BIG,
    Env,
    G,
    derive,
    freq,
    psi,
    residual,
    residual_derivative,
    residual_naive,
    tau_eff,
    value_asymptote,
    value_cis,
    value_greedy,
    value_ncis,
    w,
)
from repro.core.residuals import residual_ladder
from repro.core import tables


def _env(key, m=64, nu_range=(0.1, 0.6)):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return Env(
        delta=jax.random.uniform(k1, (m,), minval=0.05, maxval=1.0),
        mu=jax.random.uniform(k2, (m,), minval=0.05, maxval=1.0),
        lam=jax.random.beta(k3, 0.25, 0.25, (m,)),
        nu=jax.random.uniform(k4, (m,), minval=nu_range[0], maxval=nu_range[1]),
    )


class TestResiduals:
    def test_r0_closed_form(self):
        x = jnp.linspace(0.01, 30, 100)
        np.testing.assert_allclose(residual(0, x), 1 - np.exp(-x), atol=1e-6)

    @pytest.mark.parametrize("i", [1, 2, 3, 5, 7])
    def test_matches_naive(self, i):
        x = jnp.linspace(0.01, 20, 200)
        np.testing.assert_allclose(
            residual(i, x), residual_naive(i, x), atol=1e-5
        )

    def test_ladder_matches_gammainc(self):
        k = 8
        x = jax.random.uniform(jax.random.PRNGKey(0), (256, k), maxval=50.0)
        lad = residual_ladder(x)
        ref = residual(jnp.arange(k, dtype=jnp.float32), x)
        np.testing.assert_allclose(lad, ref, atol=2e-5)

    def test_ladder_no_overflow(self):
        x = jnp.full((4, 8), 1e30)
        assert bool(jnp.isfinite(residual_ladder(x)).all())

    def test_derivative_identity(self):
        # Eq. (3): dR^i/dx = R^{i-1} - R^i
        x = jnp.linspace(0.1, 10, 50)
        for i in [1, 2, 4]:
            lhs = residual_derivative(i, x)
            rhs = residual(i - 1, x) - residual(i, x)
            np.testing.assert_allclose(lhs, rhs, atol=1e-5)


class TestValues:
    def test_monotone(self):
        # Lemma 2: V increasing, f decreasing in iota.
        d = derive(_env(jax.random.PRNGKey(0)))
        iotas = jnp.linspace(0.05, 60, 300)
        V = jax.vmap(lambda i: value_ncis(jnp.full((64,), i), d, 8))(iotas)
        F = jax.vmap(lambda i: freq(jnp.full((64,), i), d, 8))(iotas)
        assert float(jnp.min(jnp.diff(V, axis=0))) >= -1e-7
        assert float(jnp.max(jnp.diff(F, axis=0))) <= 1e-7

    def test_asymptote(self):
        d = derive(_env(jax.random.PRNGKey(1)))
        v = value_ncis(jnp.full((64,), BIG), d, 8)
        np.testing.assert_allclose(v, value_asymptote(d), rtol=1e-6)

    def test_greedy_limit(self):
        # gamma -> 0 recovers V_GREEDY exactly.
        env = _env(jax.random.PRNGKey(2))
        env0 = Env(env.delta, env.mu, jnp.zeros(64), jnp.zeros(64))
        d0 = derive(env0)
        t = jnp.linspace(0.1, 20, 100)[:, None] * jnp.ones((1, 64))
        np.testing.assert_allclose(
            value_greedy(t, d0),
            jax.vmap(lambda tt: value_ncis(tt, d0, 8))(t),
            atol=1e-6,
        )

    def test_cis_limit(self):
        # nu -> 0 with no signal recovers V_GREEDY_CIS.
        env = _env(jax.random.PRNGKey(3), nu_range=(0.0, 0.0))
        d = derive(env)
        t = jnp.linspace(0.1, 20, 50)[:, None] * jnp.ones((1, 64))
        np.testing.assert_allclose(
            value_cis(t, jnp.zeros((50, 64), jnp.int32), d),
            jax.vmap(lambda tt: value_ncis(tt, d, 8))(t),
            atol=1e-6,
        )

    def test_never_change_page_worthless(self):
        # delta -> 0: always fresh, V = 0 for any iota.
        env = Env(delta=jnp.array([1e-9]), mu=jnp.array([1.0]),
                  lam=jnp.array([0.0]), nu=jnp.array([0.3]))
        d = derive(env)
        v = value_ncis(jnp.array([5.0]), d, 8)
        assert abs(float(v[0])) < 1e-4

    @settings(max_examples=30, deadline=None)
    @given(
        delta=st.floats(0.01, 2.0),
        mu=st.floats(0.01, 2.0),
        lam=st.floats(0.0, 0.999),
        nu=st.floats(0.0, 2.0),
        t1=st.floats(0.01, 50.0),
        scale=st.floats(1.01, 4.0),
    )
    def test_property_monotone_and_bounded(self, delta, mu, lam, nu, t1, scale):
        env = Env(*[jnp.array([v]) for v in (delta, mu, lam, nu)])
        d = derive(env)
        v1 = float(value_ncis(jnp.array([t1]), d, 8)[0])
        v2 = float(value_ncis(jnp.array([t1 * scale]), d, 8)[0])
        vmax = float(value_asymptote(d)[0])
        assert v1 <= v2 + 1e-6          # monotone
        assert -1e-6 <= v1 <= vmax + 1e-5  # bounded by asymptote
        assert np.isfinite(v1) and np.isfinite(v2)

    def test_table_accuracy(self):
        env = _env(jax.random.PRNGKey(4), m=512)
        d = derive(env)
        table = tables.build_ncis_table(d, n_terms=8)
        tau = jax.random.uniform(jax.random.PRNGKey(5), (512,), maxval=40.0)
        n = jax.random.poisson(jax.random.PRNGKey(6), 2.0, (512,)).astype(jnp.int32)
        v_tab = tables.lookup_state(table, d, tau, n)
        v_ref = value_ncis(tau_eff(tau, n, d), d, 8)
        scale = float(jnp.max(v_ref))
        # Measured f32 lerp error on the default quadratic 128-grid is ~3e-3
        # relative (halves per grid doubling); the seed's 2e-3 tolerance was
        # never exercised (suite failed at collection) and fails on the seed.
        assert float(jnp.max(jnp.abs(v_tab - v_ref))) < 5e-3 * scale

    def test_g_objective(self):
        mu_t = jnp.array([0.5])
        delta = jnp.array([0.8])
        xi = jnp.array([2.0])
        expected = 0.5 / 0.8 * 2.0 * (1 - np.exp(-0.8 / 2.0))
        np.testing.assert_allclose(G(xi, mu_t, delta), [expected], rtol=1e-6)
