"""Fused select pipeline: exactness vs dense top-k, tiered skip semantics,
oracle accuracy, and the candidate-overflow fallback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Env, derive
from repro.kernels import layout, ops, ref, select
from repro.kernels.layout import LANES
from repro.sim import uniform_instance


def _packed(key, m, block_rows=8, n_terms=8, tau_max=20.0):
    env = uniform_instance(key, m)
    d = derive(env)
    shard = layout.pack_shard(d, n_terms=n_terms, block_rows=block_rows)
    tau = jax.random.uniform(jax.random.fold_in(key, 1), (m,), maxval=tau_max)
    n = jax.random.poisson(jax.random.fold_in(key, 2), 2.0, (m,)).astype(jnp.int32)
    tau_pad, n_pad = layout.pad_state(tau, n, shard.m_pad)
    return d, shard, tau_pad, n_pad


def _dense_topk(tau_pad, n_pad, shard, k):
    vals, _ = ops.crawl_value_packed(tau_pad, n_pad, shard.env,
                                     n_terms=shard.n_terms)
    return jax.lax.top_k(vals, k)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("m,k", [(5000, 16), (40_000, 128)])
def test_fused_matches_dense_topk(impl, m, k):
    d, shard, tau_pad, n_pad = _packed(jax.random.PRNGKey(m + k), m)
    dv, di = _dense_topk(tau_pad, n_pad, shard, k)
    sel = select.fused_select(tau_pad, n_pad, shard, k, impl=impl)
    np.testing.assert_array_equal(np.asarray(sel.ids), np.asarray(di))
    np.testing.assert_array_equal(np.asarray(sel.values), np.asarray(dv))


def test_fused_exact_across_warm_rounds():
    """Threshold warm-start + static asymptote bounds: selection stays
    bit-identical to dense top-k every round while blocks get skipped."""
    m, k = 30_000, 32
    env = uniform_instance(jax.random.PRNGKey(7), m)
    # Value-correlated blocks (the paper's tiers): sort by asymptote.
    order = jnp.argsort(-(env.mu / env.delta))
    d = derive(jax.tree.map(lambda x: x[order], env))
    shard = layout.pack_shard(d, n_terms=8, block_rows=8)
    bounds = layout.asym_block_bounds(shard.env)
    tau = jax.random.uniform(jax.random.PRNGKey(8), (m,), maxval=10.0)
    tau_pad, n_pad = layout.pad_state(tau, jnp.zeros((m,), jnp.int32),
                                      shard.m_pad)
    thresh = -jnp.inf
    fracs = []
    for _ in range(10):
        dv, di = _dense_topk(tau_pad, n_pad, shard, k)
        sel = select.fused_select(tau_pad, n_pad, shard, k, thresh=thresh,
                                  bounds=bounds, impl="jnp")
        np.testing.assert_array_equal(np.asarray(sel.ids), np.asarray(di))
        fracs.append(float(sel.frac_active))
        thresh = sel.values[-1] * 0.9
        tau_pad = tau_pad.at[sel.ids].set(0.0) + 0.05
    assert min(fracs[2:]) < 1.0  # tiering actually skipped blocks


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_skipped_blocks_emit_neg_inf_and_never_win(impl):
    block_rows = 8
    bp = block_rows * LANES
    m = 8 * bp
    k = 16
    d, shard, tau_pad, n_pad = _packed(jax.random.PRNGKey(3), m,
                                       block_rows=block_rows)
    # Force-skip odd blocks; candidates from them must be -inf and selection
    # must come from even blocks only.
    bounds = jnp.where(jnp.arange(8) % 2 == 0, jnp.inf, -jnp.inf)
    thresh = jnp.float32(0.0)
    if impl == "pallas":
        cand_v, cand_i = select._candidates_pallas(
            tau_pad, n_pad, shard.env, bounds, thresh, 8,
            select.DEFAULT_CAND_PER_LANE, interpret=True)
    else:
        cand_v, cand_i = select._candidates_jnp(
            tau_pad, n_pad, shard.env, bounds, thresh, 8,
            select.DEFAULT_CAND_PER_LANE)
    assert bool(jnp.all(jnp.isneginf(cand_v[1::2])))
    assert bool(jnp.all(jnp.isfinite(cand_v[0::2])))

    sel = select.fused_select(tau_pad, n_pad, shard, k, thresh=thresh,
                              bounds=bounds, impl=impl)
    blocks = np.asarray(sel.ids) // bp
    assert (blocks % 2 == 0).all()
    assert bool(jnp.all(jnp.isneginf(sel.blk_max[1::2])))


def test_active_blocks_match_gamma_oracle():
    m = 20_000
    d, shard, tau_pad, n_pad = _packed(jax.random.PRNGKey(11), m)
    vals, _ = ops.crawl_value_packed(tau_pad, n_pad, shard.env)
    v_ref = ref.crawl_value_ref(tau_pad[:m], n_pad[:m].astype(jnp.int32), d,
                                method="gamma")
    scale = float(jnp.max(jnp.abs(v_ref))) + 1e-12
    np.testing.assert_allclose(np.asarray(vals[:m]), np.asarray(v_ref),
                               atol=1e-5 * scale)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_candidate_overflow_falls_back_to_exact_dense(impl):
    """Pile the global top-k into a single lane column so the per-lane
    candidate buffer must overflow; the fused path must detect it and return
    the exact dense selection."""
    block_rows = 8
    bp = block_rows * LANES
    m = 4 * bp
    k = 16
    cand_per_lane = 2
    # One lane column (lane 0 of block 0) holds 3*cand_per_lane winners.
    mu = jnp.ones((m,)) * 1e-3
    hot = jnp.arange(3 * cand_per_lane) * LANES  # lane-0 rows
    mu = mu.at[hot].set(100.0)
    env = Env(delta=jnp.full((m,), 0.5), mu=mu, lam=jnp.full((m,), 0.5),
              nu=jnp.full((m,), 0.3))
    d = derive(env)
    shard = layout.pack_shard(d, n_terms=8, block_rows=block_rows)
    tau = jnp.full((m,), 5.0)
    tau_pad, n_pad = layout.pad_state(tau, jnp.zeros((m,), jnp.int32),
                                      shard.m_pad)
    dv, di = _dense_topk(tau_pad, n_pad, shard, k)
    sel = select.fused_select(tau_pad, n_pad, shard, k, impl=impl,
                              cand_per_lane=cand_per_lane)
    assert bool(sel.fell_back)
    np.testing.assert_array_equal(np.asarray(sel.ids), np.asarray(di))
    np.testing.assert_array_equal(np.asarray(sel.values), np.asarray(dv))


def test_pallas_and_jnp_candidates_agree():
    m = 10_000
    d, shard, tau_pad, n_pad = _packed(jax.random.PRNGKey(5), m)
    nb = shard.n_blocks
    bounds = jnp.full((nb,), jnp.inf, jnp.float32)
    thresh = jnp.float32(-jnp.inf)
    cv_j, ci_j = select._candidates_jnp(tau_pad, n_pad, shard.env, bounds,
                                        thresh, 8, 3)
    cv_p, ci_p = select._candidates_pallas(tau_pad, n_pad, shard.env, bounds,
                                           thresh, 8, 3, interpret=True)
    np.testing.assert_array_equal(np.asarray(cv_j), np.asarray(cv_p))
    np.testing.assert_array_equal(np.asarray(ci_j), np.asarray(ci_p))


def test_sharded_fused_step_matches_dense():
    from repro.sched.distributed import ShardedSchedState, sharded_crawl_step

    mesh = jax.make_mesh((1,), ("data",))
    block_rows = 8
    m = 16 * block_rows * LANES
    k = 16
    env = uniform_instance(jax.random.PRNGKey(0), m)
    d = derive(env)
    shard = layout.pack_shard(d, n_terms=8, block_rows=block_rows)
    bounds = layout.asym_block_bounds(shard.env)
    st = ShardedSchedState(
        tau_elap=jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=10.0),
        n_cis=jnp.zeros((m,), jnp.int32),
        crawl_clock=jnp.int32(0),
    )
    zero = jnp.zeros((m,), jnp.int32)
    thresh = jnp.float32(-jnp.inf)
    stf = std = st
    for _ in range(4):
        stf, (gf, vf) = sharded_crawl_step(
            stf, zero, None, None, mesh, k, 0.05,
            env_planes=shard.env, thresh=thresh, bounds=bounds)
        std, (gd, vd) = sharded_crawl_step(std, zero, d, None, mesh, k, 0.05)
        assert set(map(int, gf)) == set(map(int, gd))
        thresh = vf[k - 1] * 0.9


def test_fused_service_multidevice_subprocess():
    """Fused service on 4 fake host devices with a non-aligned page count:
    padding must round the block count up to a shard multiple (regression:
    the fused shard_map asserts n_blocks % n_shards == 0)."""
    import os
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from repro.sched.service import CrawlScheduler
        from repro.sim import uniform_instance
        mesh = jax.make_mesh((4,), ("data",))
        m = 3000  # pads to 3 blocks of 1024 -> must round up to 4
        env = uniform_instance(jax.random.PRNGKey(0), m)
        s = CrawlScheduler(env, mesh, bandwidth=16.0, use_fused=True,
                           block_rows=8)
        ids, vals = s.ingest_and_schedule(jnp.zeros((m,), jnp.int32))
        assert ids.shape == (16,) and int(ids.max()) < m, ids
        print("FUSED_MULTIDEV_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env, timeout=300)
    assert "FUSED_MULTIDEV_OK" in r.stdout, r.stdout + r.stderr


def test_fused_service_roundtrip():
    from repro.sched.service import CrawlScheduler

    mesh = jax.make_mesh((1,), ("data",))
    m = 20_000  # not block-aligned: service pads internally
    env = uniform_instance(jax.random.PRNGKey(3), m)
    s = CrawlScheduler(env, mesh, bandwidth=32.0, use_fused=True, block_rows=8)
    s_tab = CrawlScheduler(env, mesh, bandwidth=32.0, table_grid=None)
    for _ in range(3):
        ids_f, _ = s.ingest_and_schedule(jnp.zeros((m,), jnp.int32))
        ids_t, _ = s_tab.ingest_and_schedule(jnp.zeros((m,), jnp.int32))
        assert ids_f.shape == (32,)
        assert int(jnp.max(ids_f)) < m  # padding never selected
        assert set(map(int, ids_f)) == set(map(int, ids_t))
    sd = s.state_dict()
    s.load_state_dict(jax.device_get(sd))
