"""Macro-round scan pipeline (`backends.crawl_rounds` /
`CrawlScheduler.run_rounds`): stacked selection equal to sequential rounds
page-id-for-page-id, device-resident diagnostics, the CIS-mass re-evaluation
rule, feed-batch validation, and adaptation-counter persistence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import strategies
from _hypothesis_compat import given, settings, st
from mesh_harness import run_forced_shards
from repro.sched import backends as be
from repro.sched import tiered
from repro.sched.service import CrawlScheduler
from repro.sim import uniform_instance


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _sorted_env(key, m):
    env = uniform_instance(key, m)
    order = jnp.argsort(-(env.mu / env.delta))
    return jax.tree.map(lambda x: x[order], env)


def _pair(env, k, backend, dt=0.05, tau_max=2.0, seed=99):
    """Two identically-seeded schedulers on the same warm trajectory,
    crawling exactly k pages per round (bandwidth = k / dt)."""
    out = []
    for _ in range(2):
        s = CrawlScheduler(env, _mesh1(), bandwidth=float(k) / dt,
                           round_period=dt, backend=backend)
        tau = jax.random.uniform(jax.random.PRNGKey(seed), (env.m,),
                                 maxval=tau_max)
        s.round = dataclasses.replace(
            s.round,
            tau_elap=jnp.zeros((s.m_state,)).at[:env.m].set(tau))
        out.append(s)
    return out


def _cis_feeds(rng, n_rounds, m, rounds, n_pages=200, jump=40):
    feeds = np.zeros((n_rounds, m), np.int32)
    for r in rounds:
        idx = rng.choice(m, n_pages, replace=False)
        feeds[r, idx] = rng.integers(1, jump, n_pages)
    return feeds


# ---------------------------------------------------------------------------
# Tentpole: run_rounds == R sequential rounds, page-id-for-page-id.
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**16), period=st.integers(2, 4))
def test_property_run_rounds_equals_sequential(seed, period):
    """Property: with adaptive bounds on and CIS jumps mid-batch, the macro
    scan's stacked (ids, values) are bit-identical to sequential
    ingest_and_schedule calls on an identically-seeded scheduler — not just
    set-equal: every float expression in the scan matches the per-round
    path."""
    m, k, R = 12_000, 16, 8
    env = _sorted_env(jax.random.PRNGKey(seed), m)
    seq, mac = _pair(env, k, be.FusedBackend(block_rows=8,
                                             adaptive_bounds=True))
    rng = np.random.default_rng(seed)
    feeds = _cis_feeds(rng, R, m, rounds=range(period - 1, R, period))
    ids_m, vals_m = mac.run_rounds(jnp.asarray(feeds))
    for r in range(R):
        ids_s, vals_s = seq.ingest_and_schedule(jnp.asarray(feeds[r]))
        np.testing.assert_array_equal(np.asarray(ids_m)[r],
                                      np.asarray(ids_s), err_msg=str(r))
        np.testing.assert_array_equal(np.asarray(vals_m)[r],
                                      np.asarray(vals_s), err_msg=str(r))
    assert int(mac.round.crawl_clock) == int(seq.round.crawl_clock) == R


def test_run_rounds_equals_sequential_all_adaptive():
    """The full production config (adaptive bounds + hysteresis + candidate
    depth): selection stays identical even though the sequential loop takes
    its host-side depth decisions mid-stream and the macro path at the
    boundary (exactness never depends on the depth)."""
    m, k, R = 20_000, 64, CrawlScheduler.CAND_ADAPT_INTERVAL + 4
    env = _sorted_env(jax.random.PRNGKey(3), m)
    backend = be.FusedBackend(block_rows=8, adaptive_bounds=True,
                              adaptive_cand=True)
    seq, mac = _pair(env, k, backend)
    feeds = _cis_feeds(np.random.default_rng(3), R, m, rounds=[5, 11])
    ids_m, _ = mac.run_rounds(jnp.asarray(feeds))
    for r in range(R):
        ids_s, _ = seq.ingest_and_schedule(jnp.asarray(feeds[r]))
        assert set(map(int, np.asarray(ids_m)[r])) == set(map(int, ids_s)), r
    # The macro boundary took a depth decision from the device-resident
    # watermark (window >= interval after one batch).
    assert mac.backend.cand_per_lane is not None


@settings(max_examples=6, deadline=None)
@given(feeds=strategies.feed_batches(m=9_000, max_rounds=4))
def test_property_macro_equals_sequential_on_shared_feed_shapes(feeds):
    """Property over the shared feed-shape strategies (empty / sparse /
    dense / hot-shard, int and bool dtypes): the macro scan's stacked
    selection is bit-identical to sequential rounds for EVERY feed shape
    the data path accepts — including the dense-ish batches that stress the
    COO capacity bucketing and hot-shard batches that concentrate all
    signals in one page range."""
    m = feeds.shape[1]
    env = _sorted_env(jax.random.PRNGKey(11), m)
    seq, mac = _pair(env, 16, be.FusedBackend(block_rows=8,
                                              adaptive_bounds=True))
    ids_m, vals_m = mac.run_rounds(feeds)
    for r in range(feeds.shape[0]):
        ids_s, vals_s = seq.ingest_and_schedule(jnp.asarray(feeds[r]))
        np.testing.assert_array_equal(np.asarray(ids_m)[r],
                                      np.asarray(ids_s), err_msg=str(r))
        np.testing.assert_array_equal(np.asarray(vals_m)[r],
                                      np.asarray(vals_s), err_msg=str(r))
    assert seq.round.n_cis.dtype == jnp.int32


def test_run_rounds_dense_backend_generic_scan():
    """Stateless backends ride the generic `_round_body` scan — bit-equal to
    the per-round path by construction."""
    m, k, R = 8_000, 16, 5
    env = _sorted_env(jax.random.PRNGKey(4), m)
    seq, mac = _pair(env, k, be.DenseBackend())
    feeds = _cis_feeds(np.random.default_rng(4), R, m, rounds=[2])
    ids_m, vals_m = mac.run_rounds(jnp.asarray(feeds))
    assert ids_m.shape == (R, k)
    for r in range(R):
        ids_s, vals_s = seq.ingest_and_schedule(jnp.asarray(feeds[r]))
        np.testing.assert_array_equal(np.asarray(ids_m)[r],
                                      np.asarray(ids_s), err_msg=str(r))
    # Placeholder diagnostics still stack to (R, n_shards).
    assert mac.macro_diagnostics.frac_active.shape == (R, 1)


def test_run_rounds_multishard_cis_subprocess():
    """Acceptance property on a 4-shard mesh: macro == sequential across
    rounds with CIS jumps, while blocks are actually skipped."""
    run_forced_shards("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.sched.service import CrawlScheduler
        from repro.sched import backends as be
        from repro.sim import uniform_instance
        mesh = jax.make_mesh((4,), ("data",))
        m, k, R = 30_000, 32, 10
        env = uniform_instance(jax.random.PRNGKey(0), m)
        order = jnp.argsort(-(env.mu / env.delta))
        env = jax.tree.map(lambda x: x[order], env)
        scheds = []
        for _ in range(2):
            s = CrawlScheduler(env, mesh, bandwidth=float(k),
                               round_period=0.05,
                               backend=be.FusedBackend(block_rows=8,
                                                       adaptive_bounds=True))
            tau = jax.random.uniform(jax.random.PRNGKey(9), (m,), maxval=2.0)
            s.round = dataclasses.replace(
                s.round, tau_elap=jnp.zeros((s.m_state,)).at[:m].set(tau))
            scheds.append(s)
        seq, mac = scheds
        rng = np.random.default_rng(0)
        feeds = np.zeros((R, m), np.int32)
        for r in (4, 7):
            idx = rng.choice(m, 300, replace=False)
            feeds[r, idx] = rng.integers(1, 40, 300)
        ids_m, vals_m = mac.run_rounds(jnp.asarray(feeds))
        for r in range(R):
            ids_s, _ = seq.ingest_and_schedule(jnp.asarray(feeds[r]))
            np.testing.assert_array_equal(np.asarray(ids_m)[r],
                                          np.asarray(ids_s), err_msg=str(r))
        frac = np.asarray(mac.macro_diagnostics.frac_active)
        assert frac.shape == (R, 4)
        assert frac.min() < 1.0, frac
        print("MACRO_MULTISHARD_OK")
    """, n_devices=4, timeout=900, token="MACRO_MULTISHARD_OK")


# ---------------------------------------------------------------------------
# Tentpole: device-resident diagnostics match the per-round values.
# ---------------------------------------------------------------------------

def test_macro_diagnostics_match_per_round():
    m, k, R = 20_000, 32, 8
    env = _sorted_env(jax.random.PRNGKey(5), m)
    seq, mac = _pair(env, k, be.FusedBackend(block_rows=8,
                                             adaptive_bounds=True))
    feeds = _cis_feeds(np.random.default_rng(5), R, m, rounds=[3, 6])
    mac.run_rounds(jnp.asarray(feeds))
    diag = mac.macro_diagnostics
    for r in range(R):
        seq.ingest_and_schedule(jnp.asarray(feeds[r]))
        b = seq.round.backend
        for got, want, name in (
            (diag.frac_active, b.frac_active, "frac_active"),
            (diag.fell_back, b.fell_back, "fell_back"),
            (diag.hyst, b.hyst, "hyst"),
            (diag.col_winners, b.col_winners, "col_winners"),
        ):
            np.testing.assert_array_equal(np.asarray(got)[r],
                                          np.asarray(want),
                                          err_msg=f"{name}@{r}")


def test_macro_keeps_donated_planes_aliased():
    m, k, R = 12_000, 16, 4
    env = _sorted_env(jax.random.PRNGKey(6), m)
    _, mac = _pair(env, k, be.FusedBackend(block_rows=8,
                                           adaptive_bounds=True))
    p0 = mac.round.backend.env_planes.unsafe_buffer_pointer()
    feeds = jnp.zeros((R, m), jnp.int32)
    mac.run_rounds(feeds)
    mac.run_rounds(feeds)
    assert mac.round.backend.env_planes.unsafe_buffer_pointer() == p0


# ---------------------------------------------------------------------------
# Tentpole: the CIS-mass re-evaluation rule (ROADMAP steady-state item).
# ---------------------------------------------------------------------------

def test_cis_mass_bound_math():
    """Unit: the accumulator resets on evaluation, accrues beta_max * n, and
    widens the bound by slope * mass."""
    bb = tiered.BlockBounds(
        asym=jnp.asarray([10.0, 10.0]), slope=jnp.asarray([1.0, 1.0]),
        blk_max=jnp.asarray([2.0, 2.0]), last_eval=jnp.asarray([0, 0]),
    )
    beta_max = jnp.asarray([0.5, 0.5])
    mass = tiered.accumulate_cis_mass(
        jnp.asarray([3.0, 3.0]), beta_max, jnp.asarray([4, 0]),
        evaluated=jnp.asarray([True, False]))
    # evaluated block: reset then accrue 4 * 0.5; skipped block: keep 3.0
    np.testing.assert_allclose(np.asarray(mass), [2.0, 3.0])
    b0 = tiered.current_block_bounds(bb, jnp.int32(2), 1.0)
    bm = tiered.current_block_bounds(bb, jnp.int32(2), 1.0, cis_mass=mass)
    np.testing.assert_allclose(np.asarray(bm - b0), [2.0, 3.0])


def test_cis_mass_skips_more_than_remark_on_sparse_feeds():
    """The resolved ROADMAP item: under sparse weak signals the mass rule
    must evaluate strictly fewer blocks than the blanket re-mark — while
    both stay exactly equal to dense top-k."""
    m, k, R = 30_000, 32, 24
    env = _sorted_env(jax.random.PRNGKey(7), m)
    mass_s, _ = _pair(env, k, be.FusedBackend(block_rows=8,
                                              adaptive_bounds=True))
    remark_s, _ = _pair(env, k, be.FusedBackend(block_rows=8,
                                                adaptive_bounds=True,
                                                cis_rule="remark"))
    dense_s, _ = _pair(env, k, be.DenseBackend())
    rng = np.random.default_rng(7)
    fr_mass, fr_remark = [], []
    for r in range(R):
        feed = np.zeros((m,), np.int32)
        # one weak signal somewhere every round — the blanket rule re-marks
        # (and so re-evaluates) that block; the mass rule only bumps its
        # bound by one beta-slope step
        feed[rng.integers(0, m)] = 1
        feed = jnp.asarray(feed)
        ids_a, _ = mass_s.ingest_and_schedule(feed)
        ids_b, _ = remark_s.ingest_and_schedule(feed)
        ids_d, _ = dense_s.ingest_and_schedule(feed)
        assert set(map(int, ids_a)) == set(map(int, ids_d)), r
        assert set(map(int, ids_b)) == set(map(int, ids_d)), r
        fr_mass.append(float(mass_s.round.backend.frac_active.mean()))
        fr_remark.append(float(remark_s.round.backend.frac_active.mean()))
    assert np.mean(fr_mass[-12:]) < np.mean(fr_remark[-12:]), (
        fr_mass, fr_remark)
    # Mass accrued on (at least) the fed, skipped blocks.
    assert float(mass_s.round.backend.cis_mass.max()) > 0.0


def test_cis_mass_resets_on_update_pages():
    from repro.core import Env

    m, k = 12_000, 16
    env = _sorted_env(jax.random.PRNGKey(8), m)
    s, _ = _pair(env, k, be.FusedBackend(block_rows=8, adaptive_bounds=True))
    feed = jnp.zeros((m,), jnp.int32).at[jnp.arange(32)].set(1)
    for _ in range(6):
        s.ingest_and_schedule(feed)
    bst = s.round.backend
    bp = bst.env_planes.shape[2] * bst.env_planes.shape[3]
    hot = np.arange(0, 64)
    upd = Env(delta=jnp.full((64,), 2.0), mu=jnp.full((64,), 300.0),
              lam=jnp.full((64,), 0.5), nu=jnp.full((64,), 0.1))
    s.update_pages(hot, upd)
    touched = np.unique(hot // bp)
    bst = s.round.backend
    assert (np.asarray(bst.cis_mass)[touched] == 0.0).all()
    # beta_max refreshed from the new planes for the touched blocks
    from repro.kernels import layout

    np.testing.assert_allclose(
        np.asarray(bst.beta_max),
        np.asarray(layout.block_beta_max(bst.env_planes)), rtol=1e-6)


# ---------------------------------------------------------------------------
# Satellite: macro depth cadence — one hot round must not pin the depth.
# ---------------------------------------------------------------------------

def test_macro_depth_cadence_one_hot_round_vs_saturated():
    """Regression for the ROADMAP macro depth-cadence item at large R: the
    candidate-depth watermark is a running max, so a single hot round in a
    32-round macro-round used to re-target the depth to the spike for the
    whole next window. The bounded in-scan saturation counter
    (`FusedState.depth_hot`) lets the boundary decision hold the
    steady-state depth for a lone spike — and still grow it when every
    round saturates."""
    from repro.core import Env
    from repro.kernels import select as ksel

    block_rows, lanes = 32, 128
    bp = block_rows * lanes
    m, k, R = 4 * bp, 16, 32
    # Ordinary pages everywhere; 32 "CIS bomb" pages down lane column 0 of
    # block 0: tiny delta (huge value asymptote, slow time-driven growth —
    # never winners on their own) and a huge beta, so a small CIS burst
    # jumps all 32 to the top of one lane column at once.
    delta = np.full((m,), 1.0, np.float32)
    mu = (1.0 + np.arange(m, dtype=np.float32) * 1e-4)
    hot = np.arange(block_rows) * lanes
    delta[hot] = 0.01
    env = Env(delta=jnp.asarray(delta), mu=jnp.asarray(mu),
              lam=jnp.full((m,), 0.5), nu=jnp.full((m,), 0.3))
    s = CrawlScheduler(env, _mesh1(), bandwidth=float(k),
                       backend=be.FusedBackend(block_rows=block_rows,
                                               adaptive_cand=True))
    auto = ksel.auto_cand_per_lane(k)
    zero = np.zeros((R, m), np.int32)

    # Steady state: winners are well-spread, the depth shrinks below auto.
    s.run_rounds(zero)
    d0 = s.backend.cand_per_lane
    assert d0 is not None and d0 < auto, (d0, auto)

    # One hot round mid-batch: the burst concentrates the whole top-k in
    # one lane column (realized depth ~k), the round falls back (exactness
    # kept), the watermark spikes — but the saturation counter reads "a
    # lone spike" and the boundary decision HOLDS the steady-state depth.
    one_hot = zero.copy()
    one_hot[10, hot] = 5
    s.run_rounds(one_hot)
    diag = s.macro_diagnostics
    assert int(np.asarray(diag.col_winners).max()) > d0  # watermark spiked
    assert 1 <= int(np.asarray(diag.depth_hot).max()) <= max(1, R // 8)
    assert s.backend.cand_per_lane == d0, (
        "a single hot round re-targeted the depth to the spike")
    # The observation window was reset for the next decision.
    assert int(np.asarray(s.round.backend.depth_hot).max()) == 0

    # Every round saturated: the counter reads persistent saturation and
    # the boundary decision grows the depth.
    every_hot = zero.copy()
    every_hot[:, hot] = 5
    s.run_rounds(every_hot)
    assert int(np.asarray(s.macro_diagnostics.depth_hot).max()) > R // 8
    assert s.backend.cand_per_lane > d0, (
        "persistent saturation failed to grow the depth")


# ---------------------------------------------------------------------------
# Satellite: feed-batch validation.
# ---------------------------------------------------------------------------

def test_run_rounds_feed_validation():
    m, k = 8_000, 16
    env = _sorted_env(jax.random.PRNGKey(10), m)
    s, _ = _pair(env, k, be.FusedBackend(block_rows=8))
    with pytest.raises(TypeError, match="integer"):
        s.run_rounds(jnp.zeros((3, m), jnp.float32))
    with pytest.raises(ValueError, match="feed batch"):
        s.run_rounds(jnp.zeros((m,), jnp.int32))  # missing round axis
    with pytest.raises(ValueError, match="entries"):
        s.run_rounds(jnp.zeros((3, m + 7), jnp.int32))
    # (R, m) rows are zero-padded to m_state; bool casts like _pad_feed.
    ids, vals = s.run_rounds(np.ones((2, m), bool))
    assert ids.shape == (2, k)
    assert s.round.n_cis.dtype == jnp.int32
    assert int(ids.max()) < m


# ---------------------------------------------------------------------------
# Satellite: adaptation counters survive a checkpoint round-trip.
# ---------------------------------------------------------------------------

def test_adapt_counters_persist_across_restore(tmp_path):
    from repro import checkpoint as ckpt

    m, k = 20_000, 128
    env = uniform_instance(jax.random.PRNGKey(11), m)  # well-mixed
    backend = be.FusedBackend(block_rows=8, adaptive_cand=True)
    s = CrawlScheduler(env, _mesh1(), bandwidth=float(k), backend=backend)
    zero = jnp.zeros((m,), jnp.int32)
    # Adapt, then advance partway into the next observation window.
    for _ in range(CrawlScheduler.CAND_ADAPT_INTERVAL + 3):
        s.ingest_and_schedule(zero)
    adapted = s.backend.cand_per_lane
    assert adapted is not None
    window = s._rounds_since_cand_adapt
    assert window == 3
    sd = jax.device_get(s.state_dict())
    ckpt.save(str(tmp_path), 1, sd)

    s2 = CrawlScheduler(env, _mesh1(), bandwidth=float(k), backend=backend)
    got, _ = ckpt.restore(str(tmp_path), 1,
                          jax.device_get(s2.state_dict()))
    s2.load_state_dict(got)
    # The restored service resumes with the adapted static buffer shape and
    # the partially-elapsed window — no auto-depth revert, no restart.
    assert s2.backend.cand_per_lane == adapted
    assert s2._rounds_since_cand_adapt == window
    ids, _ = s2.ingest_and_schedule(zero)
    assert ids.shape == (k,)
    # Old snapshots without the adapt key keep the configured depth.
    s3 = CrawlScheduler(env, _mesh1(), bandwidth=float(k), backend=backend)
    legacy = {kk: v for kk, v in sd.items() if kk != "adapt"}
    s3.load_state_dict(legacy)
    assert s3.backend.cand_per_lane is None
