"""Pallas kernel vs pure-jnp oracle: shape/param sweeps + tiering semantics
(interpret mode executes the kernel body on CPU; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Env, derive
from repro.kernels import ops, ref
from repro.kernels.crawl_value import LANES
from repro.sim import uniform_instance


@pytest.mark.parametrize("m", [1000, 32768, 100_000])
@pytest.mark.parametrize("n_terms", [1, 2, 8])
def test_crawl_value_allclose(m, n_terms):
    env = uniform_instance(jax.random.PRNGKey(m), m)
    d = derive(env)
    tau = jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=50.0)
    n = jax.random.poisson(jax.random.PRNGKey(2), 2.0, (m,)).astype(jnp.int32)
    v_k = ops.crawl_value(tau, n, d, n_terms=n_terms, block_rows=64)
    v_r = ref.crawl_value_ref(tau, n, d, n_terms=n_terms)
    # f32 series-vs-f32 gammainc: absolute cancellation floor ~1e-7 (same
    # floor as test_crawl_value_property; the seed's 1e-9 floor was unrunnable
    # at the time it was written and fails for the seed kernel too).
    scale = float(jnp.max(jnp.abs(v_r))) + 1e-12
    np.testing.assert_allclose(v_k, v_r, atol=2e-6 * scale + 1e-7)


@settings(max_examples=15, deadline=None)
@given(
    lam=st.floats(0.0, 1.0),
    nu=st.floats(0.0, 1.5),
    delta=st.floats(1e-3, 3.0),
    tau_max=st.floats(0.1, 500.0),
)
def test_crawl_value_property(lam, nu, delta, tau_max):
    m = 256
    env = Env(
        delta=jnp.full((m,), delta),
        mu=jnp.linspace(0.1, 1.0, m),
        lam=jnp.full((m,), lam),
        nu=jnp.full((m,), nu),
    )
    d = derive(env)
    tau = jnp.linspace(0.0, tau_max, m)
    n = (jnp.arange(m) % 5).astype(jnp.int32)
    v_k = ops.crawl_value(tau, n, d, block_rows=64)
    v_r = ref.crawl_value_ref(tau, n, d)
    assert bool(jnp.isfinite(v_k).all())
    # f32 series-vs-f32 gammainc: allow an absolute cancellation floor ~1e-7
    scale = float(jnp.max(jnp.abs(v_r))) + 1e-12
    np.testing.assert_allclose(v_k, v_r, atol=5e-6 * scale + 2e-7)


def test_tiered_skip():
    block_rows = 64
    bp = block_rows * LANES
    m = 8 * bp
    env = uniform_instance(jax.random.PRNGKey(0), m)
    d = derive(env)
    tau = jax.random.uniform(jax.random.PRNGKey(1), (m,), maxval=20.0)
    n = jnp.zeros((m,), jnp.int32)
    bounds = jnp.where(jnp.arange(8) % 2 == 0, 1.0, -1.0)
    thresh = jnp.zeros(())
    v_t, blkmax = ops.crawl_value_tiered(tau, n, d, bounds, thresh,
                                         block_rows=block_rows)
    v_ref = ref.tiered_crawl_value_ref(tau, n, d, bounds, thresh, bp)
    finite = np.isfinite(np.asarray(v_ref))
    assert (np.isfinite(np.asarray(v_t)) == finite).all()
    np.testing.assert_allclose(np.asarray(v_t)[finite],
                               np.asarray(v_ref)[finite], atol=1e-6)
    # block maxima of computed blocks match
    got = np.asarray(blkmax).reshape(8)[::2]
    want = np.asarray(v_t).reshape(8, bp).max(1)[::2]
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_padding_pages_never_selected():
    m = 1000  # forces padding to a block multiple
    env = uniform_instance(jax.random.PRNGKey(3), m)
    d = derive(env)
    tau = jnp.full((m,), 5.0)
    n = jnp.zeros((m,), jnp.int32)
    v = ops.crawl_value(tau, n, d, block_rows=64)
    assert v.shape == (m,)
    assert bool(jnp.isfinite(v).all())
