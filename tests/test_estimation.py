"""App. E estimation: MLE recovery of CIS quality from synthetic crawl logs,
the naive estimator's bias (paper Fig. 10), and the closed
crawl -> estimate -> refresh -> re-select loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimation
from repro.core.values import Env
from repro.sim import uniform_instance


def _synth_logs(rng, alpha, b, gamma, n_int):
    """Synthetic per-crawl-interval logs from the App. E model:
    tau_k ~ U, n_k ~ Poisson(gamma tau_k), z_k ~ Ber(e^{-(alpha tau + b n)})."""
    n_pages = alpha.shape[0]
    tau = rng.uniform(0.5, 2.0, (n_pages, n_int))
    n = rng.poisson(gamma[:, None] * tau)
    p_fresh = np.exp(-(alpha[:, None] * tau + b[:, None] * n))
    fresh = (rng.uniform(size=p_fresh.shape) < p_fresh).astype(np.float32)
    return jnp.asarray(tau), jnp.asarray(n), jnp.asarray(fresh)


def test_fit_mle_recovers_quality_vmapped():
    """fit_mle_pages (vmapped over pages) recovers (precision, recall, Delta)
    from Poisson/CIS logs within tolerance."""
    rng = np.random.default_rng(0)
    n_pages = 8
    alpha_t = rng.uniform(0.1, 1.0, n_pages)
    b_t = rng.uniform(0.3, 2.0, n_pages)
    gamma_t = rng.uniform(0.5, 2.0, n_pages)
    tau, n, fresh = _synth_logs(rng, alpha_t, b_t, gamma_t, 800)

    q = estimation.fit_mle_pages(tau, n, fresh, steps=800)
    prec_t = 1.0 - np.exp(-b_t)
    delta_t = alpha_t + gamma_t * prec_t
    recall_t = gamma_t * prec_t / delta_t
    np.testing.assert_allclose(np.asarray(q.precision), prec_t, atol=0.12)
    np.testing.assert_allclose(np.asarray(q.recall), recall_t, atol=0.15)
    np.testing.assert_allclose(np.asarray(q.delta), delta_t, rtol=0.2)
    # gamma_hat straight from the raw logs
    np.testing.assert_allclose(np.asarray(q.gamma), gamma_t, rtol=0.15)


def test_fit_mle_single_page_matches_batched():
    rng = np.random.default_rng(1)
    alpha_t, b_t, gamma_t = np.array([0.4]), np.array([1.0]), np.array([1.2])
    tau, n, fresh = _synth_logs(rng, alpha_t, b_t, gamma_t, 500)
    q1 = estimation.fit_mle(tau[0], n[0], fresh[0],
                            jnp.asarray(n[0].sum() / tau[0].sum()))
    qb = estimation.fit_mle_pages(tau, n, fresh)
    np.testing.assert_allclose(float(q1.alpha), float(qb.alpha[0]), rtol=1e-4)
    np.testing.assert_allclose(float(q1.b), float(qb.b[0]), rtol=1e-4)


def test_naive_estimator_bias_regression():
    """The interval-counting estimator stays biased (paper Fig. 10): with
    multi-event intervals its precision error must exceed the MLE's."""
    rng = np.random.default_rng(2)
    n_pages = 8
    alpha_t = rng.uniform(0.2, 0.8, n_pages)
    b_t = rng.uniform(0.4, 1.5, n_pages)
    gamma_t = rng.uniform(1.0, 2.0, n_pages)  # several signals per interval
    tau, n, fresh = _synth_logs(rng, alpha_t, b_t, gamma_t, 800)

    prec_t = 1.0 - np.exp(-b_t)
    naive_p, _ = estimation.naive_precision_recall(n, 1.0 - np.asarray(fresh))
    q = estimation.fit_mle_pages(tau, n, fresh, steps=800)
    err_naive = np.abs(np.asarray(naive_p) - prec_t)
    err_mle = np.abs(np.asarray(q.precision) - prec_t)
    assert err_naive.mean() > err_mle.mean(), (err_naive, err_mle)


def test_quality_to_env_roundtrip():
    """quality_to_env inverts the Env -> CISQuality mapping."""
    delta = jnp.asarray([0.5, 1.0]); lam = jnp.asarray([0.6, 0.9])
    nu = jnp.asarray([0.2, 0.05]); mu = jnp.asarray([1.0, 2.0])
    gamma = lam * delta + nu
    precision = lam * delta / gamma
    q = estimation.CISQuality(
        alpha=(1 - lam) * delta, b=-jnp.log(nu / gamma), gamma=gamma,
        precision=precision, recall=lam, delta=delta,
    )
    env = estimation.quality_to_env(q, mu)
    np.testing.assert_allclose(np.asarray(env.delta), np.asarray(delta),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(env.lam), np.asarray(lam),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(env.nu), np.asarray(nu), atol=1e-6)


@pytest.mark.parametrize("backend_name", ["fused", "dense"])
def test_ingest_crawl_results_closes_the_loop(backend_name):
    """End-to-end App. E: crawl logs showing a cohort is hot (stale on every
    crawl, reliable signals) must flow through fit_mle -> update_pages and
    change the subsequent selection toward that cohort."""
    from repro.sched import backends as be
    from repro.sched.service import CrawlScheduler

    m, k = 20_000, 32
    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.default_rng(3)
    env = uniform_instance(jax.random.PRNGKey(9), m)
    # Start the cohort cold: tiny change rate -> never selected.
    cohort = np.arange(200, 200 + k)
    env = Env(
        delta=jnp.asarray(env.delta).at[cohort].set(1e-3),
        mu=jnp.asarray(env.mu).at[cohort].set(5.0),
        lam=env.lam, nu=env.nu,
    )
    backend = (be.FusedBackend(block_rows=8) if backend_name == "fused"
               else be.DenseBackend())
    s = CrawlScheduler(env, mesh, bandwidth=float(k), backend=backend)
    zero = jnp.zeros((m,), jnp.int32)
    s.ingest_and_schedule(zero)
    before = set(map(int, s.ingest_and_schedule(zero)[0]))
    assert not (before & set(cohort.tolist()))

    # Crawl logs for the cohort: high true change rate, precise signals.
    alpha_t = np.full(k, 0.3)
    b_t = np.full(k, 2.0)
    gamma_t = np.full(k, 2.0)
    tau = rng.uniform(0.5, 2.0, (k, 600))
    n = rng.poisson(gamma_t[:, None] * tau)
    p_fresh = np.exp(-(alpha_t[:, None] * tau + b_t[:, None] * n))
    fresh = (rng.uniform(size=p_fresh.shape) < p_fresh).astype(np.float32)

    q = s.ingest_crawl_results(cohort, jnp.asarray(tau), jnp.asarray(n),
                               jnp.asarray(fresh))
    assert float(q.delta.min()) > 0.5  # the logs say: changes often
    after = set(map(int, s.ingest_and_schedule(zero)[0]))
    assert after != before
    assert len(after & set(cohort.tolist())) > k // 2
