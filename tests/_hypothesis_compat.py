"""Optional-hypothesis shim: property tests skip (not fail) when hypothesis
is not installed, so the rest of the suite still collects and runs.

Usage (instead of `from hypothesis import given, settings, strategies as st`):

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal environments
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(f):
            return pytest.mark.skip(reason="hypothesis not installed")(f)

        return deco

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _StrategyStub:
        """st.floats(...) etc. evaluate at decoration time; return inert
        placeholders so modules import cleanly."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
