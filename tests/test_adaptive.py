"""The adaptive skip-control loop: refreshing BlockBounds + CIS-seen
re-evaluation in the jitted round (exact vs dense top-k under signal jumps),
in-jit per-shard hysteresis tighten/relax, host-side candidate-depth
adaptation, fallback-round diagnostics, the round-0 sentinel, feed-dtype
validation, and the k ~ m budget edge."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import strategies
from _hypothesis_compat import given, settings, st
from mesh_harness import run_forced_shards
from repro.core import Env, derive
from repro.kernels import layout, select
from repro.sched import backends as be
from repro.sched import tiered
from repro.sched.service import CrawlScheduler
from repro.sim import uniform_instance


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _sorted_env(key, m):
    env = uniform_instance(key, m)
    order = jnp.argsort(-(env.mu / env.delta))
    return jax.tree.map(lambda x: x[order], env)


def _schedulers(env, k, dt=0.05, tau_max=2.0, **fused_kw):
    """Adaptive-bounds fused + dense oracle on the same warm trajectory."""
    mesh = _mesh1()
    m = env.m
    fused = CrawlScheduler(env, mesh, bandwidth=float(k), round_period=dt,
                           backend=be.FusedBackend(block_rows=8,
                                                   adaptive_bounds=True,
                                                   **fused_kw))
    dense = CrawlScheduler(env, mesh, bandwidth=float(k), round_period=dt,
                           backend=be.DenseBackend())
    tau = jax.random.uniform(jax.random.PRNGKey(99), (m,), maxval=tau_max)
    fused.round = dataclasses.replace(
        fused.round,
        tau_elap=jnp.zeros((fused.m_state,)).at[:m].set(tau))
    dense.round = dataclasses.replace(dense.round, tau_elap=jnp.copy(tau))
    return fused, dense


# ---------------------------------------------------------------------------
# Tentpole: adaptive bounds == dense top-k, including under CIS jumps.
# ---------------------------------------------------------------------------

def test_adaptive_bounds_exact_and_skips_more_than_static():
    """With adaptive_bounds the refreshing anchors must (a) keep selection
    bit-identical to dense top-k every round and (b) skip strictly more
    blocks than the static asymptote bound once warm."""
    m, k = 30_000, 32
    env = _sorted_env(jax.random.PRNGKey(0), m)
    fused, dense = _schedulers(env, k)
    static = CrawlScheduler(env, _mesh1(), bandwidth=float(k),
                            round_period=0.05,
                            backend=be.FusedBackend(block_rows=8))
    static.round = dataclasses.replace(
        static.round, tau_elap=jnp.copy(fused.round.tau_elap))
    zero = jnp.zeros((m,), jnp.int32)
    fr_a, fr_s = [], []
    for r in range(25):
        ids_f, _ = fused.ingest_and_schedule(zero)
        ids_s, _ = static.ingest_and_schedule(zero)
        ids_d, _ = dense.ingest_and_schedule(zero)
        assert set(map(int, ids_f)) == set(map(int, ids_d)), r
        assert set(map(int, ids_s)) == set(map(int, ids_d)), r
        fr_a.append(float(fused.round.backend.frac_active.mean()))
        fr_s.append(float(static.round.backend.frac_active.mean()))
    assert np.mean(fr_a[-10:]) < np.mean(fr_s[-10:]), (fr_a, fr_s)
    assert min(fr_a) < 1.0


def test_cis_seen_blocks_lose_their_anchor():
    """The blanket re-evaluation rule (cis_rule="remark"): any block whose
    pages received CIS this round is re-marked never-evaluated (+inf bound
    -> exact re-evaluation), so a skipped block can never hide a
    signal-jumped winner. The default CIS-mass rule refines this (see
    tests/test_macro.py); the blunt rule stays available and sound."""
    m, k = 30_000, 32
    env = _sorted_env(jax.random.PRNGKey(1), m)
    fused, dense = _schedulers(env, k, cis_rule="remark")
    zero = jnp.zeros((m,), jnp.int32)
    for _ in range(10):
        fused.ingest_and_schedule(zero)
        dense.ingest_and_schedule(zero)
    bst = fused.round.backend
    skipped = np.flatnonzero(np.asarray(bst.last_eval) <
                             int(fused.round.crawl_clock) - 1)
    assert skipped.size, "warm loop never skipped a block"
    # Inject CIS into pages of one currently-skipped (low-value) block.
    bp = bst.env_planes.shape[2] * bst.env_planes.shape[3]
    blk = int(skipped[-1])
    feed = np.zeros((m,), np.int32)
    lo, hi = blk * bp, min((blk + 1) * bp, m)
    feed[lo:hi] = 50
    ids_f, _ = fused.ingest_and_schedule(jnp.asarray(feed))
    ids_d, _ = dense.ingest_and_schedule(jnp.asarray(feed))
    assert set(map(int, ids_f)) == set(map(int, ids_d))
    # The fed block lost its anchor...
    assert int(fused.round.backend.last_eval[blk]) == -1
    # ...and therefore re-evaluates exactly next round, again == dense.
    ids_f, _ = fused.ingest_and_schedule(zero)
    ids_d, _ = dense.ingest_and_schedule(zero)
    assert set(map(int, ids_f)) == set(map(int, ids_d))
    assert int(fused.round.backend.last_eval[blk]) == \
        int(fused.round.crawl_clock) - 1


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), jump=st.integers(1, 60),
       period=st.integers(2, 4))
def test_property_adaptive_equals_dense_under_cis_jumps(seed, jump, period):
    """Property: across rounds with randomly-placed CIS jumps, adaptive-
    bounds fused selection is identical to dense top-k on every round."""
    m, k = 12_000, 16
    env = _sorted_env(jax.random.PRNGKey(seed), m)
    fused, dense = _schedulers(env, k)
    rng = np.random.default_rng(seed)
    for r in range(8):
        feed = np.zeros((m,), np.int32)
        if r % period == period - 1:
            idx = rng.choice(m, 200, replace=False)
            feed[idx] = rng.integers(1, jump + 1, 200)
        feed = jnp.asarray(feed)
        ids_f, vals_f = fused.ingest_and_schedule(feed)
        ids_d, vals_d = dense.ingest_and_schedule(feed)
        assert set(map(int, ids_f)) == set(map(int, ids_d)), (seed, r)
        np.testing.assert_allclose(np.sort(np.asarray(vals_f)),
                                   np.sort(np.asarray(vals_d)), rtol=1e-5)


@settings(max_examples=6, deadline=None)
@given(feed=strategies.feed_rows(m=9_000))
def test_property_adaptive_round_exact_on_shared_feed_shapes(feed):
    """Property over the shared single-round feed strategies: one adaptive
    fused round stays exactly equal to dense top-k for every feed shape and
    integer dtype the ingest contract accepts."""
    m = feed.shape[0]
    env = _sorted_env(jax.random.PRNGKey(21), m)
    fused, dense = _schedulers(env, 16)
    for _ in range(3):  # warm the skip loop, then hit it with the feed
        zero = jnp.zeros((m,), jnp.int32)
        fused.ingest_and_schedule(zero)
        dense.ingest_and_schedule(zero)
    ids_f, _ = fused.ingest_and_schedule(feed)
    ids_d, _ = dense.ingest_and_schedule(np.asarray(feed, np.int32))
    assert set(map(int, ids_f)) == set(map(int, ids_d))


def test_adaptive_multishard_cis_property_subprocess():
    """Acceptance property on a 4-shard mesh: adaptive-bounds selection
    equals dense top-k across rounds with CIS jumps, while blocks are
    actually skipped."""
    run_forced_shards("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.sched.service import CrawlScheduler
        from repro.sched import backends as be
        from repro.sim import uniform_instance
        mesh = jax.make_mesh((4,), ("data",))
        m, k = 30_000, 32
        for seed in range(3):
            env = uniform_instance(jax.random.PRNGKey(seed), m)
            order = jnp.argsort(-(env.mu / env.delta))
            env = jax.tree.map(lambda x: x[order], env)
            fused = CrawlScheduler(env, mesh, bandwidth=float(k),
                                   round_period=0.05,
                                   backend=be.FusedBackend(
                                       block_rows=8, adaptive_bounds=True))
            dense = CrawlScheduler(env, mesh, bandwidth=float(k),
                                   round_period=0.05,
                                   backend=be.DenseBackend())
            rng = np.random.default_rng(seed)
            fracs = []
            for r in range(10):
                feed = np.zeros((m,), np.int32)
                if r in (4, 7):  # CIS jumps once the skip loop is warm
                    idx = rng.choice(m, 300, replace=False)
                    feed[idx] = rng.integers(1, 40, 300)
                feed = jnp.asarray(feed)
                ids_f, _ = fused.ingest_and_schedule(feed)
                ids_d, _ = dense.ingest_and_schedule(feed)
                assert set(map(int, ids_f)) == set(map(int, ids_d)), (seed, r)
                fracs.append(float(fused.round.backend.frac_active.mean()))
            assert min(fracs) < 1.0, fracs
        print("ADAPTIVE_MULTISHARD_OK")
    """, n_devices=4, timeout=600, token="ADAPTIVE_MULTISHARD_OK")


# ---------------------------------------------------------------------------
# Tentpole: in-jit hysteresis tighten/relax.
# ---------------------------------------------------------------------------

def test_hysteresis_tightens_then_relaxes():
    m, k = 20_000, 16
    env = _sorted_env(jax.random.PRNGKey(2), m)
    mesh = _mesh1()
    s = CrawlScheduler(env, mesh, bandwidth=float(k),
                       backend=be.FusedBackend(block_rows=8))
    zero = jnp.zeros((m,), jnp.int32)
    h0 = float(s.round.backend.hyst[0])
    assert h0 == pytest.approx(be.DEFAULT_HYSTERESIS)
    clean, h = 0, h0
    for _ in range(12):
        s.ingest_and_schedule(zero)
        h_new = float(s.round.backend.hyst[0])
        if not bool(s.round.backend.fell_back.any()):
            assert h_new == pytest.approx(
                min(h + be.HYSTERESIS_TIGHTEN, be.HYSTERESIS_MAX), abs=1e-6)
            clean += 1
        else:
            assert h_new == pytest.approx(
                max(h - be.HYSTERESIS_RELAX, be.HYSTERESIS_MIN), abs=1e-6)
        h = h_new
    assert clean > 0 and h > h0  # the loop actually tightened

    # cand_per_lane=1 can never hold the winners: every round falls back,
    # so the hysteresis must walk down to the floor.
    s2 = CrawlScheduler(env, mesh, bandwidth=float(k),
                        backend=be.FusedBackend(block_rows=8,
                                                cand_per_lane=1))
    for _ in range(50):
        s2.ingest_and_schedule(zero)
    assert bool(s2.round.backend.fell_back.all())
    assert float(s2.round.backend.hyst[0]) == pytest.approx(
        be.HYSTERESIS_MIN, abs=1e-6)


def test_fixed_hysteresis_opt_out():
    m, k = 12_000, 16
    env = _sorted_env(jax.random.PRNGKey(3), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=float(k),
                       backend=be.FusedBackend(block_rows=8,
                                               adaptive_hysteresis=False,
                                               hysteresis=0.8))
    zero = jnp.zeros((m,), jnp.int32)
    for _ in range(5):
        s.ingest_and_schedule(zero)
    assert float(s.round.backend.hyst[0]) == pytest.approx(0.8)


# ---------------------------------------------------------------------------
# Tentpole: candidate-depth adaptation from realized winner concentration.
# ---------------------------------------------------------------------------

def test_adaptive_cand_depth_shrinks_and_stays_exact():
    m, k = 30_000, 128
    env = uniform_instance(jax.random.PRNGKey(4), m)  # well-mixed
    mesh = _mesh1()
    s = CrawlScheduler(env, mesh, bandwidth=float(k),
                       backend=be.FusedBackend(block_rows=8,
                                               adaptive_cand=True))
    dense = CrawlScheduler(env, mesh, bandwidth=float(k),
                           backend=be.DenseBackend())
    auto = select.auto_cand_per_lane(k)
    zero = jnp.zeros((m,), jnp.int32)
    for r in range(CrawlScheduler.CAND_ADAPT_INTERVAL + 4):
        ids_f, _ = s.ingest_and_schedule(zero)
        ids_d, _ = dense.ingest_and_schedule(zero)
        assert set(map(int, ids_f)) == set(map(int, ids_d)), r
    got = s.backend.cand_per_lane
    assert got is not None and got < auto, (got, auto)
    # the watermark window was reset for the next decision
    assert int(np.asarray(s.round.backend.col_winners).max()) <= got


def test_adaptive_cand_depth_respects_coverage_floor():
    """Regression: the depth adaptation must never shrink the buffer below
    the capacity that covers the shard-local budget — with k comparable to
    the per-shard capacity at small depths, a tie-degenerate observation
    window would otherwise shrink to a depth whose capacity clamp cuts
    k_loc under the global top-k (ValueError mid-run / silent shortfall)."""
    m, k = 2000, 512  # pads to 2 blocks of 1024: floor = ceil(512/256) = 2
    env = uniform_instance(jax.random.PRNGKey(15), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=float(k),
                       backend=be.FusedBackend(block_rows=8,
                                               adaptive_cand=True))
    dense = CrawlScheduler(env, _mesh1(), bandwidth=float(k),
                           backend=be.DenseBackend())
    zero = jnp.zeros((m,), jnp.int32)
    for r in range(2 * CrawlScheduler.CAND_ADAPT_INTERVAL + 2):
        ids_f, _ = s.ingest_and_schedule(zero)
        ids_d, _ = dense.ingest_and_schedule(zero)
        assert set(map(int, ids_f)) == set(map(int, ids_d)), r
        cand = s.backend.cand_per_lane
        if cand is not None:
            assert cand >= s._cand_floor(k), (r, cand)


def test_adapted_cand_depth_survives_bandwidth_raise():
    """A bandwidth raise between depth decisions must not leave the round
    with a buffer too small to cover the new budget."""
    m = 30_000
    env = uniform_instance(jax.random.PRNGKey(16), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=64.0,
                       backend=be.FusedBackend(block_rows=8,
                                               adaptive_cand=True))
    zero = jnp.zeros((m,), jnp.int32)
    for _ in range(CrawlScheduler.CAND_ADAPT_INTERVAL + 1):
        s.ingest_and_schedule(zero)
    assert s.backend.cand_per_lane is not None  # a decision was taken
    s.set_bandwidth(8192.0)  # k jumps 128x between decisions
    ids, _ = s.ingest_and_schedule(zero)  # must not raise
    assert ids.shape == (8192,)
    assert (s.backend.cand_per_lane or 0) >= s._cand_floor(s.k_per_round)


def test_adaptive_cand_depth_regrows_after_overflow():
    m, k = 20_000, 64
    env = uniform_instance(jax.random.PRNGKey(5), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=float(k),
                       backend=be.FusedBackend(block_rows=8,
                                               adaptive_cand=True,
                                               cand_per_lane=1))
    zero = jnp.zeros((m,), jnp.int32)
    for _ in range(CrawlScheduler.CAND_ADAPT_INTERVAL + 1):
        s.ingest_and_schedule(zero)
    # depth 1 forced dense fallbacks; the watermark grew the buffer back
    assert (s.backend.cand_per_lane or 0) > 1


# ---------------------------------------------------------------------------
# Satellite: round-0 sentinel (last_eval = -1, not 0).
# ---------------------------------------------------------------------------

def test_round0_evaluation_anchors_the_bound():
    """Regression: a block evaluated at round 0 must get a finite bound
    (previously `last_eval == 0` doubled as the never-evaluated sentinel, so
    first-round evaluations kept a +inf bound and re-evaluated forever)."""
    m = 4 * 8 * layout.LANES
    env = uniform_instance(jax.random.PRNGKey(6), m)
    shard = layout.pack_shard(derive(env), n_terms=8, block_rows=8)
    bb = tiered.init_block_bounds(shard.env)
    assert (np.asarray(bb.last_eval) == -1).all()
    assert np.isinf(np.asarray(
        tiered.current_block_bounds(bb, jnp.int32(0), 1.0))).all()

    evaluated = jnp.asarray([True, True, False, False])
    bb = tiered.update_block_bounds(bb, jnp.full((4,), 0.5), evaluated,
                                    jnp.int32(0))
    bound = np.asarray(tiered.current_block_bounds(bb, jnp.int32(1), 1.0))
    assert np.isfinite(bound[:2]).all(), bound  # anchored at round 0
    assert np.isinf(bound[2:]).all(), bound     # still never evaluated


def test_round0_sentinel_in_service_loop():
    """End-to-end: after the very first (clock 0) round, evaluated blocks
    must carry last_eval = 0 and finite bounds — not the sentinel."""
    m, k = 12_000, 16
    env = _sorted_env(jax.random.PRNGKey(7), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=float(k), round_period=0.05,
                       backend=be.FusedBackend(block_rows=8,
                                               adaptive_bounds=True))
    s.ingest_and_schedule(jnp.zeros((m,), jnp.int32))
    last = np.asarray(s.round.backend.last_eval)
    assert (last == 0).all(), last  # all evaluated on the cold first round
    assert np.isfinite(np.asarray(s.round.backend.blk_max)).all()


def test_init_tiers_round0_sentinel():
    from repro.core import tables

    m, block, k = 4096, 512, 16
    env = uniform_instance(jax.random.PRNGKey(8), m)
    d = derive(env)
    table = tables.build_ncis_table(d, n_grid=64)
    tiers = tiered.init_tiers(d, block)
    assert (np.asarray(tiers.last_eval) == -1).all()
    tau = jax.random.uniform(jax.random.PRNGKey(9), (m,), maxval=5.0)
    n = jnp.zeros((m,), jnp.int32)
    # Evaluate everything at round 0; afterwards blocks below threshold
    # must be skippable (previously last_eval == 0 forced them active).
    _, _, tiers, frac0 = tiered.tiered_select(
        tau, n, d, table, tiers, jnp.int32(0), 0.01, k)
    assert frac0 == 1.0
    assert (np.asarray(tiers.last_eval) == 0).all()


# ---------------------------------------------------------------------------
# Satellite: fallback-round diagnostics.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_fallback_round_diagnostics_are_sound(impl):
    """On a dense exact-recovery round, frac_active must report 1.0 (the
    dense pass evaluated everything) and blk_max must be the dense per-block
    maxima — a sound anchor — instead of -inf for skipped blocks."""
    block_rows = 8
    bp = block_rows * layout.LANES
    m, k, cand = 4 * bp, 16, 2
    mu = jnp.ones((m,)) * 1e-3
    mu = mu.at[jnp.arange(3 * cand) * layout.LANES].set(100.0)
    env = Env(delta=jnp.full((m,), 0.5), mu=mu, lam=jnp.full((m,), 0.5),
              nu=jnp.full((m,), 0.3))
    shard = layout.pack_shard(derive(env), n_terms=8, block_rows=block_rows)
    tau_pad, n_pad = layout.pad_state(jnp.full((m,), 5.0),
                                      jnp.zeros((m,), jnp.int32),
                                      shard.m_pad)
    # Force-skip blocks 2..3 via -inf bounds so the pre-fallback skip
    # fraction (0.5) differs from the sound fallback report (1.0).
    bounds = jnp.where(jnp.arange(4) < 2, jnp.inf, -jnp.inf)
    sel = select.fused_select(tau_pad, n_pad, shard, k, thresh=0.0,
                              bounds=bounds, impl=impl, cand_per_lane=cand)
    assert bool(sel.fell_back)
    assert float(sel.frac_active) == 1.0
    from repro.kernels import ops
    vals, _ = ops.crawl_value_packed(tau_pad, n_pad, shard.env,
                                     n_terms=shard.n_terms)
    dense_blk = np.asarray(vals.reshape(4, -1).max(axis=1))
    np.testing.assert_allclose(np.asarray(sel.blk_max), dense_blk, rtol=1e-6)
    assert np.isfinite(np.asarray(sel.blk_max)).all()


# ---------------------------------------------------------------------------
# Satellite: CIS feed dtype contract.
# ---------------------------------------------------------------------------

def test_float_feed_rejected_integer_feed_cast():
    m = 3000
    env = uniform_instance(jax.random.PRNGKey(10), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                       backend=be.FusedBackend(block_rows=8))
    with pytest.raises(TypeError, match="integer"):
        s.ingest_and_schedule(jnp.zeros((m,), jnp.float32))
    with pytest.raises(TypeError, match="integer"):
        s.ingest_and_schedule(np.ones((m,)))  # f64 numpy feed
    # integer and bool feeds are cast to the state dtype; the donated n_cis
    # plane must stay int32 across rounds (the dtype contract).
    for feed in (np.ones((m,), np.int16), np.ones((m,), bool),
                 jnp.ones((m,), jnp.int32)):
        s.ingest_and_schedule(feed)
        assert s.round.n_cis.dtype == jnp.int32


# ---------------------------------------------------------------------------
# Satellite: k ~ m budget edge on small shards.
# ---------------------------------------------------------------------------

def test_budget_near_corpus_single_shard():
    m = 3000  # pads to 3072: k above the real page count but under padded
    k = 2900
    env = uniform_instance(jax.random.PRNGKey(11), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=float(k),
                       backend=be.FusedBackend(block_rows=8))
    ids, vals = s.ingest_and_schedule(jnp.zeros((m,), jnp.int32))
    assert ids.shape == (k,)
    assert int(ids.max()) < m  # padding never selected
    assert len(set(map(int, ids))) == k


def test_budget_above_shard_size_subprocess():
    """Regression (k ~ m edge): a budget larger than one shard's page count
    used to fire the in-jit k <= n_cand assert / local top_k error; the
    shard-local k must clamp to the shard size and stay exact."""
    run_forced_shards("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.sched.service import CrawlScheduler
        from repro.sched import backends as be
        from repro.sim import uniform_instance
        m, k = 3000, 2000  # 4 shards of 1024 padded pages: k > m/shard
        mesh = jax.make_mesh((4,), ("data",))
        env = uniform_instance(jax.random.PRNGKey(0), m)
        s = CrawlScheduler(env, mesh, bandwidth=float(k),
                           backend=be.FusedBackend(block_rows=8))
        d = CrawlScheduler(env, mesh, bandwidth=float(k),
                           backend=be.DenseBackend())
        zero = jnp.zeros((m,), jnp.int32)
        for _ in range(2):
            ids_f, _ = s.ingest_and_schedule(zero)
            ids_d, _ = d.ingest_and_schedule(zero)
            assert ids_f.shape == (k,)
            assert int(ids_f.max()) < m
            assert set(map(int, ids_f)) == set(map(int, ids_d))
        print("BUDGET_EDGE_OK")
    """, n_devices=4, timeout=600, token="BUDGET_EDGE_OK")


# ---------------------------------------------------------------------------
# Satellite: checkpoint round-trip of the grown FusedState.
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_grown_fused_state(tmp_path):
    from repro import checkpoint as ckpt

    m, k = 20_000, 32
    env = _sorted_env(jax.random.PRNGKey(12), m)
    backend = be.FusedBackend(block_rows=8, adaptive_bounds=True)
    s = CrawlScheduler(env, _mesh1(), bandwidth=float(k), round_period=0.05,
                       backend=backend)
    zero = jnp.zeros((m,), jnp.int32)
    for _ in range(6):
        s.ingest_and_schedule(zero)
    sd = jax.device_get(s.state_dict())
    ckpt.save(str(tmp_path), 1, sd)

    s2 = CrawlScheduler(env, _mesh1(), bandwidth=float(k), round_period=0.05,
                        backend=backend)
    got, _, _ = ckpt.restore_latest(str(tmp_path), s2.state_dict())
    s2.load_state_dict(got)
    b1, b2 = sd["backend"], s2.round.backend
    for name in ("thresh", "blk_max", "last_eval", "hyst", "col_winners",
                 "slope", "bounds"):
        np.testing.assert_array_equal(np.asarray(getattr(b1, name)),
                                      np.asarray(getattr(b2, name)), name)
    # The restored service resumes warm AND exact.
    dense = CrawlScheduler(env, _mesh1(), bandwidth=float(k),
                           round_period=0.05, backend=be.DenseBackend())
    dense.load_state_dict({"tau_elap": sd["tau_elap"][:m],
                           "n_cis": sd["n_cis"][:m],
                           "crawl_clock": sd["crawl_clock"]})
    ids2, _ = s2.ingest_and_schedule(zero)
    ids_d, _ = dense.ingest_and_schedule(zero)
    assert set(map(int, ids2)) == set(map(int, ids_d))
    assert float(s2.round.backend.frac_active.mean()) < 1.0


def test_pre_adaptive_checkpoint_restores_into_grown_state(tmp_path):
    """A snapshot taken before the adaptive planes existed (backend = the
    original five FusedState slots) restores through the strict=False
    path-matched restore: old slots load, appended planes keep their init
    values, and the service keeps running exactly."""
    from repro import checkpoint as ckpt

    m, k = 12_000, 16
    env = _sorted_env(jax.random.PRNGKey(13), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=float(k),
                       backend=be.FusedBackend(block_rows=8))
    zero = jnp.zeros((m,), jnp.int32)
    for _ in range(3):
        s.ingest_and_schedule(zero)
    sd = jax.device_get(s.state_dict())
    # A pre-adaptive snapshot: only the first five FusedState fields existed
    # (checkpoint paths carry the *field names*, so restore matches by name).
    import collections
    LegacyFusedState = collections.namedtuple(
        "FusedState",
        ["env_planes", "thresh", "bounds", "frac_active", "fell_back"])
    legacy = dict(sd, backend=LegacyFusedState(*tuple(sd["backend"])[:5]))
    ckpt.save(str(tmp_path), 1, legacy)

    s2 = CrawlScheduler(env, _mesh1(), bandwidth=float(k),
                        backend=be.FusedBackend(block_rows=8))
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, s2.state_dict())
    got, _ = ckpt.restore(str(tmp_path), 1,
                          jax.device_get(s2.state_dict()), strict=False)
    s2.load_state_dict(got)
    b = s2.round.backend
    np.testing.assert_array_equal(np.asarray(b.thresh),
                                  np.asarray(sd["backend"].thresh))
    assert (np.asarray(b.last_eval) == -1).all()  # appended plane kept init
    dense = CrawlScheduler(env, _mesh1(), bandwidth=float(k),
                           backend=be.DenseBackend())
    dense.load_state_dict({"tau_elap": sd["tau_elap"][:m],
                           "n_cis": sd["n_cis"][:m],
                           "crawl_clock": sd["crawl_clock"]})
    ids2, _ = s2.ingest_and_schedule(zero)
    ids_d, _ = dense.ingest_and_schedule(zero)
    assert set(map(int, ids2)) == set(map(int, ids_d))


def test_update_pages_resets_adaptive_rows():
    """A parameter repack must drop the touched blocks' anchors (their
    recorded maxima describe the old parameters) and refresh the slope."""
    m, k = 12_000, 16
    env = _sorted_env(jax.random.PRNGKey(14), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=float(k), round_period=0.05,
                       backend=be.FusedBackend(block_rows=8,
                                               adaptive_bounds=True))
    zero = jnp.zeros((m,), jnp.int32)
    for _ in range(4):
        s.ingest_and_schedule(zero)
    assert (np.asarray(s.round.backend.last_eval) >= 0).all()
    hot = np.arange(0, 64)
    upd = Env(delta=jnp.full((64,), 2.0), mu=jnp.full((64,), 300.0),
              lam=jnp.full((64,), 0.5), nu=jnp.full((64,), 0.1))
    s.update_pages(hot, upd)
    bst = s.round.backend
    bp = bst.env_planes.shape[2] * bst.env_planes.shape[3]
    touched = np.unique(hot // bp)
    assert (np.asarray(bst.last_eval)[touched] == -1).all()
    assert (np.asarray(bst.blk_max)[touched] == 0.0).all()
    mu_blk = np.asarray(layout.block_mu_max(bst.env_planes))
    np.testing.assert_allclose(
        np.asarray(bst.slope),
        mu_blk * np.exp(-1.0) * 2.0, rtol=1e-6)
    # and the refreshed pages steer the next selection, exactly.
    env_full = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), env)
    env_full = Env(
        delta=env_full.delta.at[hot].set(upd.delta),
        mu=env_full.mu.at[hot].set(upd.mu),
        lam=env_full.lam.at[hot].set(upd.lam),
        nu=env_full.nu.at[hot].set(upd.nu),
    )
    ref = CrawlScheduler(env_full, _mesh1(), bandwidth=float(k),
                         round_period=0.05, backend=be.DenseBackend())
    ref.round = dataclasses.replace(
        ref.round, tau_elap=jnp.copy(s.round.tau_elap[:m]),
        n_cis=jnp.copy(s.round.n_cis[:m]))
    ids_f, _ = s.ingest_and_schedule(zero)
    ids_d, _ = ref.ingest_and_schedule(zero)
    assert set(map(int, ids_f)) == set(map(int, ids_d))
