"""SelectionBackend protocol / RoundState round: backend matrix agreement,
buffer donation (no state-plane copies), per-shard threshold warm-start on
multi-shard meshes, block-granular parameter refresh, and warm-start
persistence across checkpoint restore."""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Env, derive
from repro.kernels import layout
from repro.sched import backends as be
from repro.sched.service import CrawlScheduler
from repro.sim import uniform_instance


def _sorted_env(key, m):
    """Value-correlated blocks (the paper's tiers) so threshold skipping has
    something to skip."""
    env = uniform_instance(key, m)
    order = jnp.argsort(-(env.mu / env.delta))
    return jax.tree.map(lambda x: x[order], env)


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def test_backend_matrix_agreement():
    """Dense, Kernel, and Fused backends select identically; Table agrees up
    to interpolation error."""
    m, k = 20_000, 32
    env = uniform_instance(jax.random.PRNGKey(0), m)
    mesh = _mesh1()
    scheds = {
        "dense": CrawlScheduler(env, mesh, bandwidth=float(k),
                                backend=be.DenseBackend()),
        "kernel": CrawlScheduler(env, mesh, bandwidth=float(k),
                                 backend=be.KernelBackend()),
        "fused": CrawlScheduler(env, mesh, bandwidth=float(k),
                                backend=be.FusedBackend(block_rows=8)),
        "table": CrawlScheduler(env, mesh, bandwidth=float(k),
                                backend=be.TableBackend(table_grid=128)),
    }
    zero = jnp.zeros((m,), jnp.int32)
    for _ in range(3):
        picks = {name: set(map(int, s.ingest_and_schedule(zero)[0]))
                 for name, s in scheds.items()}
        assert picks["dense"] == picks["kernel"] == picks["fused"]
        overlap = len(picks["dense"] & picks["table"]) / k
        assert overlap > 0.9, overlap


def test_legacy_kwargs_map_to_backends():
    m = 5 * 8 * layout.LANES
    env = uniform_instance(jax.random.PRNGKey(1), m)
    mesh = _mesh1()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        s = CrawlScheduler(env, mesh, bandwidth=8.0, use_fused=True,
                           block_rows=8)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert isinstance(s.backend, be.FusedBackend)
    s2 = CrawlScheduler(env, mesh, bandwidth=8.0,
                        backend=be.FusedBackend(block_rows=8))
    zero = jnp.zeros((m,), jnp.int32)
    ids1, _ = s.ingest_and_schedule(zero)
    ids2, _ = s2.ingest_and_schedule(zero)
    assert set(map(int, ids1)) == set(map(int, ids2))
    # kernel / table shims
    assert isinstance(
        CrawlScheduler(env, mesh, bandwidth=8.0, use_kernel=True).backend,
        be.KernelBackend)
    assert isinstance(
        CrawlScheduler(env, mesh, bandwidth=8.0, table_grid=64).backend,
        be.TableBackend)
    assert isinstance(
        CrawlScheduler(env, mesh, bandwidth=8.0, table_grid=None).backend,
        be.DenseBackend)


def test_round_donates_state_planes():
    """The jitted round donates the RoundState: packed env planes alias
    through (zero copies) and the old state's buffers are released."""
    m = 20_000
    env = uniform_instance(jax.random.PRNGKey(2), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=16.0,
                       backend=be.FusedBackend(block_rows=8))
    zero = jnp.zeros((m,), jnp.int32)
    s.ingest_and_schedule(zero)  # compile round
    prev = s.round
    p_env = prev.backend.env_planes.unsafe_buffer_pointer()
    s.ingest_and_schedule(zero)
    # unchanged planes alias the donated input buffer: no copy
    assert s.round.backend.env_planes.unsafe_buffer_pointer() == p_env
    # the donated previous state is actually released
    assert prev.tau_elap.is_deleted()
    assert prev.backend.thresh.is_deleted()


def test_oversized_feed_rejected():
    m = 3000
    env = uniform_instance(jax.random.PRNGKey(3), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                       backend=be.FusedBackend(block_rows=8))
    with pytest.raises(ValueError, match="entries"):
        s.ingest_and_schedule(jnp.zeros((s.m_state + 1,), jnp.int32))
    # a feed between m and m_state would credit its tail to padding pages
    assert s.m < s.m_state
    with pytest.raises(ValueError, match="entries"):
        s.ingest_and_schedule(jnp.zeros((m + 1,), jnp.int32))
    with pytest.raises(ValueError, match="entries"):
        s.ingest_and_schedule(jnp.zeros((m - 1,), jnp.int32))
    # exactly-m and pre-padded feeds are fine
    s.ingest_and_schedule(jnp.zeros((s.m_state,), jnp.int32))
    s.ingest_and_schedule(jnp.zeros((m,), jnp.int32))


@pytest.mark.parametrize("backend", [
    be.DenseBackend(), be.TableBackend(table_grid=128),
    be.FusedBackend(block_rows=8),
])
def test_update_pages_changes_selection(backend):
    """The decentralized refresh must actually steer selection: promote a
    cold page cohort and they must be picked next round (and agree with a
    scheduler built directly on the updated env)."""
    m, k = 20_000, 32
    env = uniform_instance(jax.random.PRNGKey(4), m)
    mesh = _mesh1()
    s = CrawlScheduler(env, mesh, bandwidth=float(k), backend=backend)
    zero = jnp.zeros((m,), jnp.int32)
    s.ingest_and_schedule(zero)
    before = set(map(int, s.ingest_and_schedule(zero)[0]))

    hot = np.arange(100, 100 + k)
    env_upd = Env(
        delta=jnp.full((k,), 2.0), mu=jnp.full((k,), 200.0),
        lam=jnp.full((k,), 0.5), nu=jnp.full((k,), 0.1),
    )
    s.update_pages(hot, env_upd)
    after = set(map(int, s.ingest_and_schedule(zero)[0]))
    assert after != before
    assert len(after & set(hot.tolist())) > k // 2

    # cross-check vs a from-scratch scheduler on the updated env (same
    # normalizer: update_pages freezes mu_total at construction, and greedy
    # selection is invariant to the common scale, so selections agree).
    env_full = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), env)
    env_full = Env(
        delta=env_full.delta.at[hot].set(env_upd.delta),
        mu=env_full.mu.at[hot].set(env_upd.mu),
        lam=env_full.lam.at[hot].set(env_upd.lam),
        nu=env_full.nu.at[hot].set(env_upd.nu),
    )
    if isinstance(backend, be.TableBackend):
        return  # interpolation-grade; exact cross-check below is for exact backends
    s_ref = CrawlScheduler(env_full, mesh, bandwidth=float(k),
                           backend=be.DenseBackend())
    # replay the same state trajectory on the reference scheduler
    import dataclasses
    s_ref.round = dataclasses.replace(
        s_ref.round,
        tau_elap=jnp.copy(s.round.tau_elap[:m]),
        n_cis=jnp.copy(s.round.n_cis[:m]),
    )
    ref = set(map(int, s_ref.ingest_and_schedule(zero)[0]))
    got = set(map(int, s.ingest_and_schedule(zero)[0]))
    assert got == ref


def test_update_pages_validates_ids():
    m = 3000
    env = uniform_instance(jax.random.PRNGKey(5), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                       backend=be.FusedBackend(block_rows=8))
    upd = Env(delta=jnp.ones((1,)), mu=jnp.ones((1,)), lam=jnp.ones((1,)),
              nu=jnp.ones((1,)))
    with pytest.raises(ValueError, match="page ids"):
        s.update_pages(np.array([m]), upd)  # padding page: not updatable


def test_repack_pages_matches_full_pack():
    """Incremental repack must be bit-identical to a from-scratch pack of
    the updated environment, and leave untouched blocks untouched."""
    m = 16 * 8 * layout.LANES
    env = uniform_instance(jax.random.PRNGKey(6), m)
    d = derive(env)
    shard = layout.pack_shard(d, n_terms=8, block_rows=8)
    rng = np.random.default_rng(0)
    ids = np.sort(rng.choice(m, m // 50, replace=False))
    env_upd = jax.tree.map(lambda x: jnp.asarray(x)[ids] * 1.3 + 0.01, env)
    d_new = derive(env_upd, mu_total=jnp.sum(env.mu))

    repacked = layout.repack_pages(shard.env, jnp.asarray(ids, jnp.int32),
                                   d_new)
    d_full = derive(env, mu_total=jnp.sum(env.mu))
    d_full = jax.tree.map(
        lambda f, n: jnp.asarray(f).at[ids].set(n.astype(f.dtype)),
        d_full, d_new)
    full = layout.pack_shard(d_full, n_terms=8, block_rows=8).env
    np.testing.assert_array_equal(np.asarray(repacked), np.asarray(full))

    blk = np.unique(ids // shard.block_pages)
    bounds = layout.refresh_block_bounds(
        repacked, layout.asym_block_bounds(shard.env),
        jnp.asarray(blk, jnp.int32))
    np.testing.assert_allclose(np.asarray(bounds),
                               np.asarray(layout.asym_block_bounds(full)),
                               rtol=1e-6)
    untouched = np.setdiff1d(np.arange(shard.n_blocks), blk)
    if untouched.size:
        np.testing.assert_array_equal(np.asarray(repacked[untouched]),
                                      np.asarray(shard.env[untouched]))


def test_refresh_block_params_consistent_with_init():
    """After a repack, refresh_block_params must leave BlockBounds exactly as
    a from-scratch init on the touched blocks (modulo the reset anchors) and
    untouched elsewhere."""
    from repro.sched import tiered

    m = 8 * 8 * layout.LANES
    env = uniform_instance(jax.random.PRNGKey(10), m)
    d = derive(env)
    shard = layout.pack_shard(d, n_terms=8, block_rows=8)
    bb = tiered.init_block_bounds(shard.env)
    bb = tiered.update_block_bounds(
        bb, jnp.ones((shard.n_blocks,)), jnp.ones((shard.n_blocks,), bool),
        jnp.int32(5))

    ids = np.arange(0, 2 * shard.block_pages)  # touch blocks 0 and 1
    env_upd = jax.tree.map(lambda x: jnp.asarray(x)[ids] * 2.0 + 0.1, env)
    d_new = derive(env_upd, mu_total=jnp.sum(env.mu))
    env2 = layout.repack_pages(shard.env, jnp.asarray(ids, jnp.int32), d_new)
    blk = jnp.asarray([0, 1], jnp.int32)
    bb2 = tiered.refresh_block_params(bb, env2, blk)

    ref = tiered.init_block_bounds(env2)
    np.testing.assert_allclose(np.asarray(bb2.asym[:2]),
                               np.asarray(ref.asym[:2]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(bb2.slope[:2]),
                               np.asarray(ref.slope[:2]), rtol=1e-6)
    # touched blocks lose their stale anchor (the -1 never-evaluated
    # sentinel: +inf bound, re-evaluate next round; 0 would collide with
    # "evaluated at round 0")...
    assert (np.asarray(bb2.last_eval[:2]) == -1).all()
    assert (np.asarray(bb2.blk_max[:2]) == 0.0).all()
    # ...untouched blocks keep theirs.
    np.testing.assert_array_equal(np.asarray(bb2.asym[2:]),
                                  np.asarray(bb.asym[2:]))
    assert (np.asarray(bb2.last_eval[2:]) == 5).all()
    assert (np.asarray(bb2.blk_max[2:]) == 1.0).all()


def test_fused_multishard_warmstart_property_subprocess():
    """Acceptance property: on a multi-shard mesh with per-shard threshold
    warm-start ENABLED, fused selection is identical to dense top-k on every
    round, across random instances — while blocks actually get skipped and
    shards carry distinct local thresholds."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.sched.service import CrawlScheduler
        from repro.sched import backends as be
        from repro.sim import uniform_instance
        mesh = jax.make_mesh((4,), ("data",))
        m, k = 30_000, 32
        for seed in range(3):
            env = uniform_instance(jax.random.PRNGKey(seed), m)
            order = jnp.argsort(-(env.mu / env.delta))
            env = jax.tree.map(lambda x: x[order], env)
            fused = CrawlScheduler(env, mesh, bandwidth=float(k),
                                   backend=be.FusedBackend(block_rows=8))
            assert fused.backend.warm_start and mesh.size > 1
            dense = CrawlScheduler(env, mesh, bandwidth=float(k),
                                   backend=be.DenseBackend())
            zero = jnp.zeros((m,), jnp.int32)
            fracs = []
            for r in range(8):
                ids_f, _ = fused.ingest_and_schedule(zero)
                ids_d, _ = dense.ingest_and_schedule(zero)
                assert set(map(int, ids_f)) == set(map(int, ids_d)), (seed, r)
                fracs.append(float(fused.round.backend.frac_active.mean()))
            assert min(fracs) < 1.0, fracs  # warm-start skipped blocks
            th = np.asarray(fused.round.backend.thresh)
            assert np.unique(th).size > 1, th  # genuinely per-shard
        print("MULTISHARD_WARMSTART_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env, timeout=600)
    assert "MULTISHARD_WARMSTART_OK" in r.stdout, r.stdout + r.stderr


def test_state_dict_preserves_warm_start(tmp_path):
    """Restart resumes warm: state_dict round-trip carries the per-shard
    thresholds/bounds, so the first post-restore round skips blocks instead
    of paying a cold full pass."""
    from repro import checkpoint as ckpt

    m, k = 30_000, 32
    env = _sorted_env(jax.random.PRNGKey(7), m)
    mesh = _mesh1()
    s = CrawlScheduler(env, mesh, bandwidth=float(k),
                       backend=be.FusedBackend(block_rows=8))
    zero = jnp.zeros((m,), jnp.int32)
    for _ in range(4):
        s.ingest_and_schedule(zero)
    assert float(s.round.backend.frac_active.mean()) < 1.0
    sd = jax.device_get(s.state_dict())
    ckpt.save(str(tmp_path), 1, sd)

    # Fresh service: cold first round evaluates everything...
    s2 = CrawlScheduler(env, mesh, bandwidth=float(k),
                        backend=be.FusedBackend(block_rows=8))
    s2.ingest_and_schedule(zero)
    assert float(s2.round.backend.frac_active.mean()) == 1.0
    # ...restored service resumes warm and stays exact.
    s3 = CrawlScheduler(env, mesh, bandwidth=float(k),
                        backend=be.FusedBackend(block_rows=8))
    got, _, _ = ckpt.restore_latest(str(tmp_path), s3.state_dict())
    s3.load_state_dict(got)
    s_ref = CrawlScheduler(env, mesh, bandwidth=float(k),
                           backend=be.DenseBackend())
    s_ref.load_state_dict({"tau_elap": sd["tau_elap"][:m],
                           "n_cis": sd["n_cis"][:m],
                           "crawl_clock": sd["crawl_clock"]})
    ids3, _ = s3.ingest_and_schedule(zero)
    ids_r, _ = s_ref.ingest_and_schedule(zero)
    assert set(map(int, ids3)) == set(map(int, ids_r))
    assert float(s3.round.backend.frac_active.mean()) < 1.0  # skipped warm


def test_load_state_dict_accepts_legacy_checkpoints(tmp_path):
    """Old checkpoints (tau/n_cis/clock only) still restore — backend state
    keeps its cold init — including through checkpoint.restore(strict=False)
    path matching."""
    from repro import checkpoint as ckpt

    m = 3000
    env = uniform_instance(jax.random.PRNGKey(8), m)
    s = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                       backend=be.FusedBackend(block_rows=8))
    zero = jnp.zeros((m,), jnp.int32)
    s.ingest_and_schedule(zero)
    legacy = {k_: v for k_, v in jax.device_get(s.state_dict()).items()
              if k_ != "backend"}
    s.load_state_dict(legacy)  # no "backend" key: keeps live backend state
    s.ingest_and_schedule(zero)

    # strict=False restore of a legacy checkpoint into the grown state_dict
    ckpt.save(str(tmp_path), 1, legacy)
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, s.state_dict())
    got, _ = ckpt.restore(str(tmp_path), 1, jax.device_get(s.state_dict()),
                          strict=False)
    s.load_state_dict(got)
    s.ingest_and_schedule(zero)
