"""Shared hypothesis strategies for synthetic CIS feed batches.

One place for the feed shapes the scheduler must survive — used by the
macro-round properties (test_macro), the adaptive-round properties
(test_adaptive), and the multi-host data-path properties (test_multihost):

  * empty      — all-zero rounds (the steady-state common case)
  * sparse     — a few signalled pages per round (production regime)
  * dense      — most pages signalled (stress; also exercises the COO cap)
  * hot_shard  — all signals concentrated in one contiguous page range
                 (the per-host capacity-contract scenario: one shard's
                 feed must not re-shape anyone else's compiled rounds)

plus dtype variants (int32 / int16 / bool) covering the `_pad_feed`
integer-feed contract.

Degrades gracefully when hypothesis is not installed (`_hypothesis_compat`):
the builders return None and `given` skips the test.
"""
from __future__ import annotations

import numpy as np

from _hypothesis_compat import HAVE_HYPOTHESIS

FEED_KINDS = ("empty", "sparse", "dense", "hot_shard")
FEED_DTYPES = (np.int32, np.int16, np.bool_)

BUDGET_KINDS = ("constant", "mixed", "zero_runs", "ramp", "extremes")

OUTAGE_KINDS = ("none", "single", "staggered", "blackout")


def build_budget_vector(n_rounds: int, k_cap: int, kind: str,
                        seed: int) -> np.ndarray:
    """Deterministically build one (n_rounds,) per-round budget vector in
    [0, k_cap] of the given kind — the elastic-bandwidth counterpart of
    `build_feed_batch`:

      * constant  — every round the same budget (the fixed-k equivalence)
      * mixed     — uniform draws over the full [0, k_cap] range
      * zero_runs — bursts of crawling separated by runs of pure
                    observation (k=0) rounds
      * ramp      — 0 up to k_cap and back inside one batch (the
                    candidate-depth floor scenario)
      * extremes  — only 0 and k_cap, the two boundary budgets
    """
    rng = np.random.default_rng(seed)
    if kind == "constant":
        return np.full(n_rounds, int(rng.integers(0, k_cap + 1)), np.int64)
    if kind == "mixed":
        return rng.integers(0, k_cap + 1, n_rounds)
    if kind == "zero_runs":
        bud = rng.integers(1, k_cap + 1, n_rounds)
        r = 0
        while r < n_rounds:
            run = int(rng.integers(1, max(2, n_rounds // 3)))
            bud[r:r + run] = 0
            r += run + int(rng.integers(1, max(2, n_rounds // 3)))
        return bud
    if kind == "ramp":
        half = (n_rounds + 1) // 2
        up = np.linspace(0, k_cap, half).round().astype(np.int64)
        down = up[::-1][:n_rounds - half]
        return np.concatenate([up, down])
    if kind == "extremes":
        return rng.integers(0, 2, n_rounds) * k_cap
    raise ValueError(f"unknown budget kind {kind!r}")


def build_feed_batch(m: int, n_rounds: int, kind: str, dtype, seed: int,
                     max_count: int = 40) -> np.ndarray:
    """Deterministically build one (n_rounds, m) CIS feed batch of the given
    kind/dtype — shared by the hypothesis strategies and by deterministic
    tests that want the same shapes without hypothesis installed."""
    rng = np.random.default_rng(seed)
    feeds = np.zeros((n_rounds, m), np.int64)
    if kind == "sparse":
        for r in range(n_rounds):
            nnz = int(rng.integers(1, max(2, m // 100)))
            idx = rng.choice(m, nnz, replace=False)
            feeds[r, idx] = rng.integers(1, max_count, nnz)
    elif kind == "dense":
        mask = rng.random((n_rounds, m)) < 0.7
        feeds[mask] = rng.integers(1, max_count, int(mask.sum()))
    elif kind == "hot_shard":
        # Everything lands in one contiguous quarter of the page range —
        # on a sharded mesh, (at most) one shard's feed runs hot.
        lo = int(rng.integers(0, max(1, 3 * m // 4)))
        hi = min(m, lo + m // 4 + 1)
        for r in range(n_rounds):
            nnz = int(rng.integers(1, max(2, (hi - lo) // 2)))
            idx = lo + rng.choice(hi - lo, nnz, replace=False)
            feeds[r, idx] = rng.integers(1, max_count, nnz)
    elif kind != "empty":
        raise ValueError(f"unknown feed kind {kind!r}")
    if dtype == np.bool_:
        return feeds > 0
    info = np.iinfo(dtype)
    return np.clip(feeds, 0, info.max).astype(dtype)


def build_outage_windows(n_rounds: int, n_channels: int, kind: str,
                         seed: int) -> list[tuple[int, int, int]]:
    """Deterministically build one list of (channel, start, stop) outage
    windows of the given kind — the hostile-ecosystem counterpart of
    `build_feed_batch`, consumed by `sim.faults.OutageSchedule`:

      * none      — a healthy schedule (the degraded-mode no-op case)
      * single    — one channel dark for one contiguous window
      * staggered — every channel dark once, windows overlapping at random
      * blackout  — ALL channels dark over one shared window (total CIS
                    loss; the watchdog must flag every block)
    """
    rng = np.random.default_rng(seed)
    if kind == "none":
        return []
    def window():
        start = int(rng.integers(0, max(1, n_rounds - 1)))
        stop = int(rng.integers(start + 1, n_rounds + 1))
        return start, stop
    if kind == "single":
        ch = int(rng.integers(0, n_channels))
        return [(ch, *window())]
    if kind == "staggered":
        return [(ch, *window()) for ch in range(n_channels)]
    if kind == "blackout":
        start, stop = window()
        return [(ch, start, stop) for ch in range(n_channels)]
    raise ValueError(f"unknown outage kind {kind!r}")


def build_fault_plan(n_rounds: int, seed: int, n_batches: int = 0,
                     p_drop: float = 0.1, p_delay: float = 0.1,
                     p_dup: float = 0.1, max_lag: int = 3,
                     p_out_fault: float = 0.25):
    """Deterministically build one `sim.faults.FaultPlan` (feed-row drops /
    delays / duplicates plus outcome-batch drop / dup / hold patterns) from
    a seed — shared by the hypothesis strategies and by deterministic
    degraded-mode tests."""
    from repro.sim.faults import random_fault_plan

    return random_fault_plan(
        np.random.default_rng(seed), n_rounds, p_drop=p_drop,
        p_delay=p_delay, p_dup=p_dup, max_lag=max_lag,
        n_batches=n_batches, p_out_fault=p_out_fault)


if HAVE_HYPOTHESIS:
    from hypothesis import strategies as st

    @st.composite
    def feed_batches(draw, m: int, max_rounds: int = 6,
                     kinds=FEED_KINDS, dtypes=FEED_DTYPES,
                     max_count: int = 40):
        """A (n_rounds, m) synthetic CIS feed batch (numpy array)."""
        n_rounds = draw(st.integers(1, max_rounds))
        kind = draw(st.sampled_from(list(kinds)))
        dtype = draw(st.sampled_from(list(dtypes)))
        seed = draw(st.integers(0, 2**16))
        return build_feed_batch(m, n_rounds, kind, dtype, seed,
                                max_count=max_count)

    def feed_rows(m: int, kinds=FEED_KINDS, dtypes=FEED_DTYPES,
                  max_count: int = 40):
        """A single-round (m,) feed drawn from the same shapes."""
        return feed_batches(m, max_rounds=1, kinds=kinds, dtypes=dtypes,
                            max_count=max_count).map(lambda f: f[0])

    @st.composite
    def budget_vectors(draw, n_rounds: int, k_cap: int,
                       kinds=BUDGET_KINDS):
        """A (n_rounds,) bounded per-round budget vector in [0, k_cap]."""
        kind = draw(st.sampled_from(list(kinds)))
        seed = draw(st.integers(0, 2**16))
        return build_budget_vector(n_rounds, k_cap, kind, seed)

    @st.composite
    def outage_schedules(draw, n_rounds: int, n_channels: int = 3,
                         kinds=OUTAGE_KINDS):
        """A `sim.faults.OutageSchedule` over n_channels channels."""
        from repro.sim.faults import OutageSchedule, OutageWindow

        kind = draw(st.sampled_from(list(kinds)))
        seed = draw(st.integers(0, 2**16))
        wins = build_outage_windows(n_rounds, n_channels, kind, seed)
        return OutageSchedule(
            windows=tuple(OutageWindow(c, a, b) for c, a, b in wins),
            n_channels=n_channels)

    @st.composite
    def fault_plans(draw, n_rounds: int, n_batches: int = 0):
        """A `sim.faults.FaultPlan` (feed drop/delay/duplicate patterns +
        outcome-batch faults when n_batches > 0)."""
        seed = draw(st.integers(0, 2**16))
        p_drop = draw(st.sampled_from([0.0, 0.05, 0.2]))
        p_delay = draw(st.sampled_from([0.0, 0.05, 0.2]))
        p_dup = draw(st.sampled_from([0.0, 0.05, 0.2]))
        return build_fault_plan(n_rounds, seed, n_batches=n_batches,
                                p_drop=p_drop, p_delay=p_delay,
                                p_dup=p_dup)
else:  # pragma: no cover - exercised in minimal environments
    def feed_batches(*_a, **_k):
        return None

    def feed_rows(*_a, **_k):
        return None

    def budget_vectors(*_a, **_k):
        return None

    def outage_schedules(*_a, **_k):
        return None

    def fault_plans(*_a, **_k):
        return None
