"""Elastic bandwidth end to end: per-round dynamic budgets under the k_max
cap contract, spike-free token-bucket emission, recompile-free mid-flight
rate changes, and the candidate-depth floor at the cap.

The contract under test (README "Elastic bandwidth"): the compiled macro
round selects at the static width `k_cap` and masks down to each round's
budget, so budget values and bandwidth changes are pure data — one compiled
executable serves every budget sequence in [0, k_cap], a constant budget
vector equal to k is bit-identical to the fixed-k path, and realized crawls
under emission="smooth" track bandwidth * time within +-1 over any window.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import strategies
from _hypothesis_compat import given, settings, st
from repro.core import policies as pol
from repro.core.values import derive
from repro.sched import backends as be
from repro.sched.errors import CapacityExceeded, FeedValidationError
from repro.sched.service import CrawlScheduler
from repro.sim import uniform_instance
from repro.sim.simulator import SimConfig, simulate

M, DT = 512, 0.5


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _env(m=M, seed=0):
    return uniform_instance(jax.random.PRNGKey(seed), m)


def _feeds(n_rounds, m=M, seed=1, frac=0.05):
    rng = np.random.default_rng(seed)
    return (rng.random((n_rounds, m)) < frac).astype(np.int32)


def _sched(env, *, bandwidth, backend=None, **kw):
    backend = backend if backend is not None else be.FusedBackend(
        block_rows=8)
    return CrawlScheduler(env, _mesh1(), bandwidth=bandwidth,
                          round_period=DT, backend=backend, **kw)


def _counts(ids):
    return np.asarray((np.asarray(ids) >= 0).sum(axis=1))


# ---------------------------------------------------------------------------
# Tentpole: the k_max cap contract.
# ---------------------------------------------------------------------------

def test_constant_budget_bit_identical_to_fixed_k():
    """A budget vector pinned at k is the fixed-k path bit for bit: every
    dynamic-k mask is a value no-op when the budget equals the cap."""
    env, k = _env(), 6
    fixed = _sched(env, bandwidth=k / DT)
    elast = _sched(env, bandwidth=k / DT, k_max=k)
    feeds = _feeds(12)
    ids_f, val_f = fixed.run_rounds(feeds)
    ids_e, val_e = elast.run_rounds(feeds, budgets=np.full(12, k))
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_e))
    np.testing.assert_array_equal(np.asarray(val_f), np.asarray(val_e))
    np.testing.assert_array_equal(np.asarray(fixed.round.tau_elap),
                                  np.asarray(elast.round.tau_elap))
    np.testing.assert_array_equal(np.asarray(fixed.round.n_cis),
                                  np.asarray(elast.round.n_cis))


def test_budget_vector_realizes_exactly():
    env = _env()
    s = _sched(env, bandwidth=2.0, k_max=8)
    bud = np.array([0, 3, 0, 8, 1, 0, 5, 8, 0, 2, 7, 4])
    ids, _ = s.run_rounds(_feeds(12), budgets=bud)
    np.testing.assert_array_equal(_counts(ids), bud)
    # Masked tail rows are id -1; live rows are unique real pages.
    ids_np = np.asarray(ids)
    for r in range(12):
        live = ids_np[r][ids_np[r] >= 0]
        assert live.size == bud[r]
        assert np.unique(live).size == live.size


def test_zero_budget_rounds_observe_but_do_not_crawl():
    """k=0 rounds are pure observation: no winners, but tau still advances
    and the round's CIS feed still lands in the signal state."""
    env = _env()
    s = _sched(env, bandwidth=2.0, k_max=4)
    feeds = _feeds(8, seed=3)
    tau0 = np.asarray(s.round.tau_elap).copy()
    n0 = np.asarray(s.round.n_cis).copy()
    ids, _ = s.run_rounds(feeds, budgets=np.zeros(8, np.int64))
    assert (np.asarray(ids) == -1).all()
    np.testing.assert_allclose(np.asarray(s.round.tau_elap),
                               tau0 + 8 * DT, rtol=1e-6)
    dn = np.asarray(s.round.n_cis) - n0
    np.testing.assert_array_equal(dn[:M], feeds.sum(axis=0))


def test_budget_at_corpus_size_crawls_everything():
    """k_max past m clamps to m; a budget at the clamp crawls every page."""
    env = _env(m=64)
    s = CrawlScheduler(env, _mesh1(), bandwidth=4.0, round_period=DT,
                       backend=be.FusedBackend(block_rows=8), k_max=500)
    assert s.k_cap == 64
    ids, _ = s.run_rounds(_feeds(4, m=64), budgets=np.full(4, 64))
    ids_np = np.asarray(ids)
    np.testing.assert_array_equal(_counts(ids), np.full(4, 64))
    for r in range(4):
        np.testing.assert_array_equal(np.sort(ids_np[r]), np.arange(64))


def test_budget_validation():
    env = _env()
    s = _sched(env, bandwidth=2.0, k_max=4)
    feeds = _feeds(4)
    with pytest.raises(CapacityExceeded):
        s.run_rounds(feeds, budgets=np.array([1, 5, 0, 0]))
    with pytest.raises(FeedValidationError):
        s.run_rounds(feeds, budgets=np.array([0.5, 1, 1, 1]))
    with pytest.raises(FeedValidationError):
        s.run_rounds(feeds, budgets=np.array([-1, 1, 1, 1]))
    with pytest.raises(FeedValidationError):
        s.run_rounds(feeds, budgets=np.array([1, 1, 1]))
    dense = CrawlScheduler(env, _mesh1(), bandwidth=2.0, round_period=DT,
                           backend=be.DenseBackend())
    with pytest.raises(FeedValidationError):
        dense.run_rounds(feeds, budgets=np.array([1, 1, 1, 1]))


@settings(max_examples=4, deadline=None)
@given(bud=strategies.budget_vectors(n_rounds=8, k_cap=6))
def test_budget_vector_property(bud):
    """Any bounded budget vector realizes exactly, with unique live pages
    and -1 padding past each round's budget."""
    env = _env(m=256)
    s = CrawlScheduler(env, _mesh1(), bandwidth=2.0, round_period=DT,
                       backend=be.FusedBackend(block_rows=8), k_max=6)
    ids, vals = s.run_rounds(_feeds(8, m=256), budgets=bud)
    ids_np, vals_np = np.asarray(ids), np.asarray(vals)
    np.testing.assert_array_equal(_counts(ids), bud)
    for r in range(8):
        live = ids_np[r][ids_np[r] >= 0]
        assert np.unique(live).size == live.size
        assert (ids_np[r][int(bud[r]):] == -1).all()
        assert not np.isfinite(vals_np[r][int(bud[r]):]).any()


# ---------------------------------------------------------------------------
# Tentpole: spike-free token-bucket emission + satellite: rounding drift.
# ---------------------------------------------------------------------------

def test_token_bucket_window_bound():
    """emission="smooth" at a fractional rate: over ANY window of W rounds
    the realized crawl count is within +-1 of rate * W, and the fractional
    residue carries across macro-round boundaries (long-run rate exact)."""
    env = _env()
    rate = 2.5  # crawls per round
    s = _sched(env, bandwidth=rate / DT, k_max=4, emission="smooth")
    counts = np.concatenate([
        _counts(s.run_rounds(_feeds(64, seed=10 + i))[0]) for i in range(2)])
    assert counts.sum() == int(rate * 128)  # residue exact across batches
    for W in (4, 16, 64):
        win = np.convolve(counts, np.ones(W, int), mode="valid")
        dev = np.abs(win - rate * W).max()
        assert dev <= 1.0, (W, dev)


def test_fixed_k_rounding_drift_regression():
    """The satellite bug: fixed emission floors bandwidth * round_period
    once (int(round(2.5)) == 2) and crawls 2/round forever — a standing
    20% bandwidth shortfall at rate 2.5. emission="smooth" realizes the
    exact long-run rate instead."""
    env = _env()
    rate = 2.5
    fixed = _sched(env, bandwidth=rate / DT)
    assert fixed.k_per_round == 2  # the drift, documented
    ids_f, _ = fixed.run_rounds(_feeds(32))
    assert _counts(ids_f).sum() == 2 * 32  # 64 crawls where 80 were due
    smooth = _sched(env, bandwidth=rate / DT, k_max=3, emission="smooth")
    ids_s, _ = smooth.run_rounds(_feeds(32))
    assert abs(int(_counts(ids_s).sum()) - rate * 32) <= 1


# ---------------------------------------------------------------------------
# Tentpole: recompile-free mid-flight rate changes.
# ---------------------------------------------------------------------------

def test_set_bandwidth_and_budget_sweep_no_rejit():
    """With k_max pinned, bandwidth values and budget vectors are pure
    data: the first call's compilation is the only one — construction
    commits the state to the donated shardings, so there is no separate
    cold-state signature — and sweeping either never grows the cache."""
    env = _env()
    s = _sched(env, bandwidth=2.5 / DT, k_max=4, emission="smooth",
               feed_cap=64)
    s.run_rounds(_feeds(16, seed=20))
    n0 = be.crawl_rounds._cache_size()  # pinned after call 1: no warm-up
    s.run_rounds(_feeds(16, seed=21))
    assert be.crawl_rounds._cache_size() == n0
    totals = []
    for i, bw in enumerate((0.75 / DT, 1.25 / DT, 2.5 / DT, 4.0 / DT)):
        s.set_bandwidth(bw)
        ids, _ = s.run_rounds(_feeds(16, seed=30 + i))
        totals.append(int(_counts(ids).sum()))
    assert be.crawl_rounds._cache_size() == n0
    # ... and the swept rates actually realized (within the +-1 residue).
    for tot, bw in zip(totals, (0.75, 1.25, 2.5, 4.0)):
        assert abs(tot - bw * 16) <= 1, (tot, bw)

    s2 = _sched(env, bandwidth=2.0, k_max=6, feed_cap=64)
    bud = strategies.build_budget_vector(16, 6, "mixed", seed=5)
    s2.run_rounds(_feeds(16, seed=40), budgets=bud)
    n1 = be.crawl_rounds._cache_size()  # again: call 1 is the warm state
    for i, kind in enumerate(("zero_runs", "ramp", "extremes", "constant")):
        b = strategies.build_budget_vector(16, 6, kind, seed=i)
        ids, _ = s2.run_rounds(_feeds(16, seed=50 + i), budgets=b)
        np.testing.assert_array_equal(_counts(ids), b)
    assert be.crawl_rounds._cache_size() == n1


# ---------------------------------------------------------------------------
# Satellite: candidate-depth watermark floor at k_cap, not this round's k.
# ---------------------------------------------------------------------------

def test_cand_floor_holds_at_cap_under_budget_ramp():
    """A depth adapted down during a low-bandwidth stretch must re-grow to
    cover k_cap — not the current round's k — before a budget vector ramps
    to the cap inside one compiled batch. With the floor computed at the
    round's k (the bug), shard_budget's capacity clamp cuts k_loc under the
    global top-k requirement and the ramp batch dies mid-compile."""
    env = _env(m=1024, seed=2)
    k_max, R = 512, 32
    ramp = np.linspace(0, k_max, R).round().astype(np.int64)
    feeds = _feeds(R, m=1024, seed=7)
    # Depth adapted down to 1 (as a quiet stretch would), floor bug bait:
    # bandwidth 1/round keeps the old floor at 1, far under the cap's need.
    shrunk = CrawlScheduler(
        env, _mesh1(), bandwidth=1.0 / DT, round_period=DT,
        backend=be.FusedBackend(block_rows=8, adaptive_cand=True,
                                cand_per_lane=1),
        k_max=k_max)
    ids_s, _ = shrunk.run_rounds(feeds, budgets=ramp)
    assert shrunk.backend.cand_per_lane >= shrunk._cand_floor(k_max)
    # Reference: same rounds at the never-shrunk auto depth (established
    # dense-exact). Selection must match page-id-for-page-id.
    ref = CrawlScheduler(
        env, _mesh1(), bandwidth=1.0 / DT, round_period=DT,
        backend=be.FusedBackend(block_rows=8), k_max=k_max)
    ids_r, _ = ref.run_rounds(feeds, budgets=ramp)
    np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_r))
    np.testing.assert_array_equal(_counts(ids_s), ramp)


# ---------------------------------------------------------------------------
# Acceptance: mid-flight halve-then-double vs the simulator's re-solved
# discrete optimum, segment by segment, with a flat jit cache.
# ---------------------------------------------------------------------------

def test_halve_then_double_matches_resolved_simulator_optimum():
    from test_fidelity import _freshness, _realized_trace

    m, cap, steps, seg = 400, 4, 96, 32
    key = jax.random.PRNGKey(11)
    env = uniform_instance(jax.random.fold_in(key, 1), m)
    cfg = SimConfig(dt=DT, n_steps=steps, k_per_tick=cap,
                    value_impl="exact")
    changes, arrivals = _realized_trace(key, env, cfg)
    mu_t = np.asarray(derive(env).mu_t)
    k_sched = np.concatenate([np.full(seg, cap), np.full(seg, cap // 2),
                              np.full(seg, cap)])

    # The simulator re-solves the discrete policy under the same schedule:
    # per tick, arg-top-k_schedule[t] — the elastic discrete optimum.
    sim = simulate(key, env, pol.GREEDY_NCIS, cfg, k_schedule=k_sched)
    sim_trace = np.asarray(sim.trace)

    s = CrawlScheduler(env, _mesh1(), bandwidth=cap / DT, round_period=DT,
                       backend=be.FusedBackend(block_rows=8,
                                               adaptive_bounds=True),
                       k_max=cap,
                       feed_cap=int(arrivals.sum(axis=1).max()) + 1)
    # Construction commits the state to donated shardings, so segment 1's
    # compilation is the only one: pin the cache after call 1 and the rate
    # changes (halve, double) must stay flat — no warm-up twin needed.
    crawls = []
    n0 = None
    for t0 in range(0, steps, seg):
        ids, _ = s.run_rounds(arrivals[t0:t0 + seg],
                              budgets=k_sched[t0:t0 + seg])
        crawls.extend(np.asarray(ids))
        if n0 is None:
            n0 = be.crawl_rounds._cache_size()
    assert be.crawl_rounds._cache_size() == n0  # halve/double: pure data

    # Per-round realized counts follow the schedule exactly.
    np.testing.assert_array_equal(
        np.asarray([(c >= 0).sum() for c in crawls]), k_sched)

    # Importance-weighted freshness per segment within 2% of the re-solved
    # optimum (same realized trace, same exact freshness integral).
    stale = np.zeros((m,), bool)
    trace = []
    for t in range(steps):
        sel = crawls[t][crawls[t] >= 0]
        crawled = np.zeros((m,), bool)
        crawled[sel] = True
        frac = np.where((~stale) | crawled, 1.0 / (changes[t] + 1.0), 0.0)
        trace.append(float(np.sum(mu_t * frac)))
        stale = (stale & ~crawled) | (changes[t] > 0)
    trace = np.asarray(trace)
    for t0 in range(0, steps, seg):
        np.testing.assert_allclose(trace[t0:t0 + seg].mean(),
                                   sim_trace[t0:t0 + seg].mean(), rtol=0.02)
    # The halved middle segment really crawled half as much.
    assert sum((c >= 0).sum() for c in crawls[seg:2 * seg]) == (cap // 2) * seg


# ---------------------------------------------------------------------------
# Smooth emission state rides checkpoints.
# ---------------------------------------------------------------------------

def test_emit_residue_survives_checkpoint():
    env = _env()
    rate = 2.5
    s = _sched(env, bandwidth=rate / DT, k_max=4, emission="smooth")
    c1 = _counts(s.run_rounds(_feeds(7, seed=60))[0])
    sd = jax.device_get(s.state_dict())
    # Continue live vs restore-and-continue: identical emission pattern
    # only if the fractional residue survived the round trip.
    c2 = _counts(s.run_rounds(_feeds(9, seed=61))[0])
    r = _sched(env, bandwidth=rate / DT, k_max=4, emission="smooth")
    r.load_state_dict(sd)
    c3 = _counts(r.run_rounds(_feeds(9, seed=61))[0])
    np.testing.assert_array_equal(c2, c3)
    assert c1.sum() + c2.sum() == int(rate * 16)
