"""Reusable mesh test harness.

Two launchers factor the subprocess pattern every sharded/multi-host test
needs (previously duplicated across test_adaptive.py / test_macro.py):

  * `run_forced_shards(body, n_devices)` — one process whose host platform
    is forced to expose `n_devices` CPU devices (`XLA_FLAGS=
    --xla_force_host_platform_device_count`), the classic single-host
    multi-shard setup. A fresh process is required because the flag must be
    set before jax initializes.

  * `run_distributed(body, n_procs, devices_per_proc)` — a GENUINE
    multi-process `jax.distributed` mesh: n_procs separate processes, each
    owning devices_per_proc forced CPU devices, coordinated over localhost
    with the gloo CPU collectives backend. This is a real SPMD deployment —
    per-process jit caches, per-process addressable shards, cross-host
    collectives — not an emulation, so it can prove host-locality claims
    (e.g. "a hot shard on one host triggers zero recompiles on the other
    host") that a forced-device-count mesh cannot.

  * `run_distributed_kill(body, victim=...)` — the fault-injection
    variant: the same genuine multi-process mesh, but the body is expected
    to SIGKILL the `victim` process partway through (after printing the
    token). The launcher asserts the victim actually died by signal, then
    reaps the survivors — which, having lost their peer, are hanging in a
    collective — after a short grace period. Pair it with a follow-up
    `run_distributed` on the same tmpdir to prove kill-and-restore
    recovery from per-host shard checkpoints.

All launchers run under the fleet watchdog (`reap_fleet`): one GLOBAL
deadline per launch, reap-on-hang (the fleet is killed the moment any
process overstays, and every process's captured output lands in the
assertion), instead of per-process timeouts that could stack to
n_procs * timeout on a wedged collective.

Bodies are plain Python source (dedented automatically) run with
`PYTHONPATH=src` from the repo root. They must print `token` on success —
`run_distributed` requires the token from EVERY process. Distributed bodies
see `PROC_ID`, `N_PROCS`, `N_DEVICES` (global device count) predefined and
jax already initialized; use `tmpdir` (also predefined, shared across the
processes) to exchange reference data with the parent.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
import tempfile
import textwrap
import time

ROOT = os.path.join(os.path.dirname(__file__), "..")

_FORCED_PRELUDE = """\
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count={n} "
    + os.environ.get("XLA_FLAGS", ""))
tmpdir = {tmpdir!r}
import jax
"""

_DIST_PRELUDE = """\
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count={d} "
    + os.environ.get("XLA_FLAGS", ""))
PROC_ID = {pid}
N_PROCS = {n}
N_DEVICES = {d} * {n}
tmpdir = {tmpdir!r}
import jax
try:
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
except Exception:
    pass  # newer jax enables CPU collectives without the flag
jax.distributed.initialize(coordinator_address="127.0.0.1:{port}",
                           num_processes={n}, process_id={pid})
"""


def _env(extra_env=None):
    env = dict(os.environ, PYTHONPATH="src")
    env.update(extra_env or {})
    return env


def _format_fleet(outs) -> str:
    return "\n".join(f"--- proc {i} ---\n{o}" for i, o in enumerate(outs))


def reap_fleet(procs, timeout: float, *, require_all: bool = True):
    """THE fleet watchdog: collect every subprocess in `procs` under ONE
    global deadline, killing the whole fleet the moment any process
    overstays it — a peer blocked in a collective can never finish once one
    process is gone, so a single hang must take the fleet down instead of
    serializing per-process timeouts (the pre-watchdog launchers gave each
    process the full timeout in turn, so a pathological fleet could burn
    n_procs * timeout before failing).

    require_all=True (the healthy-fleet contract) raises AssertionError
    naming the hung processes, with every process's captured output
    attached so the failure is diagnosable. require_all=False (the
    fault-injection contract: survivors of a killed peer are EXPECTED to
    hang in their collectives) kills and reaps the stragglers silently.

    Returns the list of stdouts in process order."""
    start = time.monotonic()
    outs: list[str | None] = [None] * len(procs)
    hung = []
    for i, p in enumerate(procs):
        left = timeout - (time.monotonic() - start)
        try:
            outs[i], _ = p.communicate(timeout=max(0.0, left))
        except subprocess.TimeoutExpired:
            hung.append(i)
            for q in procs:
                if q.poll() is None:
                    q.kill()
    for i, p in enumerate(procs):
        if outs[i] is None:
            outs[i], _ = p.communicate()
    if require_all:
        assert not hung, (
            f"process(es) {hung} hung past the {timeout}s fleet deadline "
            f"(killed):\n{_format_fleet(outs)}")
    return outs


def run_forced_shards(body: str, n_devices: int = 4, timeout: int = 900,
                      token: str = "OK", extra_env: dict | None = None,
                      tmpdir: str | None = None) -> str:
    """Run `body` in one fresh process with `n_devices` forced CPU devices.
    Asserts `token` appears on its stdout; returns the stdout."""
    tmpdir = tmpdir or tempfile.mkdtemp(prefix="mesh_harness_")
    code = (_FORCED_PRELUDE.format(n=n_devices, tmpdir=tmpdir)
            + textwrap.dedent(body))
    try:
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, cwd=ROOT,
                           env=_env(extra_env), timeout=timeout)
    except subprocess.TimeoutExpired as e:
        # Watchdog parity with `reap_fleet`: a hang becomes a diagnosable
        # assertion carrying whatever the body printed, not a bare
        # TimeoutExpired traceback.
        raise AssertionError(
            f"forced-shard body hung past {timeout}s (killed):\n"
            f"--- stdout ---\n{e.stdout or ''}\n"
            f"--- stderr ---\n{e.stderr or ''}") from None
    assert token in r.stdout, (
        f"forced-shard body did not print {token!r}:\n"
        f"--- stdout ---\n{r.stdout}\n--- stderr ---\n{r.stderr}")
    return r.stdout


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_distributed(body: str, n_procs: int = 2, devices_per_proc: int = 2,
                    timeout: int = 900, token: str = "OK",
                    extra_env: dict | None = None,
                    tmpdir: str | None = None) -> list[str]:
    """Run `body` as a genuine `jax.distributed` mesh of `n_procs`
    processes x `devices_per_proc` CPU devices each (gloo collectives).
    Asserts `token` appears on EVERY process's stdout; returns the stdouts
    in process order."""
    tmpdir = tmpdir or tempfile.mkdtemp(prefix="mesh_harness_")
    port = _free_port()
    body = textwrap.dedent(body)
    procs = []
    for pid in range(n_procs):
        code = _DIST_PRELUDE.format(d=devices_per_proc, n=n_procs, pid=pid,
                                    port=port, tmpdir=tmpdir) + body
        procs.append(subprocess.Popen(
            [sys.executable, "-c", code], stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True, cwd=ROOT,
            env=_env(extra_env)))
    outs = reap_fleet(procs, timeout)
    joined = _format_fleet(outs)
    for i, out in enumerate(outs):
        assert token in out, (
            f"process {i} did not print {token!r}:\n{joined}")
    return outs


def run_distributed_kill(body: str, n_procs: int = 2,
                         devices_per_proc: int = 2, victim: int = 1,
                         timeout: int = 900, token: str = "OK",
                         extra_env: dict | None = None,
                         tmpdir: str | None = None,
                         grace: int = 30) -> list[str]:
    """Fault-injection launcher: run `body` as a genuine `jax.distributed`
    mesh in which process `victim` is expected to SIGKILL ITSELF partway
    through (`os.kill(os.getpid(), signal.SIGKILL)`), after printing
    `token` (print with flush=True — SIGKILL gives no chance to flush).

    Asserts the victim printed the token and died by signal (negative
    returncode). The survivors lose their peer mid-collective and can
    never finish; they get `grace` seconds (in case they exit on a gloo
    connection error by themselves), then are killed and reaped. Returns
    the stdouts in process order — survivor output is whatever they
    printed before losing the victim, for checkpoint/reference
    assertions."""
    tmpdir = tmpdir or tempfile.mkdtemp(prefix="mesh_harness_")
    port = _free_port()
    body = textwrap.dedent(body)
    procs = []
    for pid in range(n_procs):
        code = _DIST_PRELUDE.format(d=devices_per_proc, n=n_procs, pid=pid,
                                    port=port, tmpdir=tmpdir) + body
        procs.append(subprocess.Popen(
            [sys.executable, "-c", code], stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True, cwd=ROOT,
            env=_env(extra_env)))
    outs: list[str | None] = [None] * n_procs
    try:
        outs[victim], _ = procs[victim].communicate(timeout=timeout)
    except subprocess.TimeoutExpired:
        for q in procs:
            if q.poll() is None:
                q.kill()
        outs[victim], _ = procs[victim].communicate()
        rest = _format_fleet(
            [p.communicate()[0] if i != victim else "(victim above)"
             for i, p in enumerate(procs)])
        raise AssertionError(
            f"victim process {victim} did not die within {timeout}s "
            f"(killed the fleet):\n--- victim ---\n{outs[victim]}\n{rest}")
    # Survivors lost their peer mid-collective and are EXPECTED to hang:
    # reap-on-hang without asserting (the fault-injection watchdog
    # contract), after `grace` seconds for a clean gloo-error exit.
    survivors = [p for i, p in enumerate(procs) if i != victim]
    surv_outs = reap_fleet(survivors, grace, require_all=False)
    for i, p in enumerate(procs):
        if i != victim:
            outs[i] = surv_outs.pop(0)
    joined = _format_fleet(outs)
    assert token in outs[victim], (
        f"victim process {victim} did not print {token!r} before dying:\n"
        f"{joined}")
    assert procs[victim].returncode < 0, (
        f"victim process {victim} exited with {procs[victim].returncode}, "
        f"expected death by signal:\n{joined}")
    return outs
