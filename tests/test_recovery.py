"""Elastic multi-host lifecycle: host-local construction, per-host shard
checkpointing, and kill-and-restore crash recovery.

Fast single-process tests pin each lifecycle piece in isolation:
`from_local_env` construction is state- and selection-identical to the
global `__init__` path (with no dense `.d` oracle — it raises); the
sharded-v1 checkpoint format round-trips `state_dict` bitwise through
per-host shard files; damaged checkpoints (truncated npz, flipped bytes,
partially-renamed step dirs) raise `CheckpointCorruptError` and
`restore_latest` falls back to the previous intact step; the typed
exception hierarchy distinguishes host-local from fleet-fatal errors; and
a hypothesis property round-trips save/restore across all four selection
backends.

The `slow`-marked test is THE fault-injection acceptance run: a genuine
2-process `jax.distributed` fleet runs macro-rounds, checkpoints to
per-host shards (under a poisoned `jax.device_get` — no global gather),
one process SIGKILLs itself mid-run, and a fresh fleet restores from the
shards and continues BIT-IDENTICALLY to an uninterrupted reference run at
the same seeds/feeds: selections, values, diagnostics, final state shards,
adaptation counters, and per-batch jit-cache growth all match.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import strategies
from _hypothesis_compat import given, settings, st
from mesh_harness import run_distributed, run_distributed_kill
from repro.checkpoint import store as ckpt
from repro.checkpoint.store import CheckpointCorruptError
from repro.core import Env
from repro.sched import backends as be
from repro.sched import errors
from repro.sched.service import CrawlScheduler
from repro.sim import uniform_instance


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _fused(m=3000, seed=0, **kw):
    env = uniform_instance(jax.random.PRNGKey(seed), m)
    kw.setdefault("backend", be.FusedBackend(block_rows=8))
    kw.setdefault("feed_cap", 256)
    return env, CrawlScheduler(env, _mesh1(), bandwidth=8.0, **kw)


# ---------------------------------------------------------------------------
# Host-local construction (single process: local slice == whole corpus).
# ---------------------------------------------------------------------------

def test_from_local_env_matches_global_init():
    m = 3000
    env, s_ref = _fused(m)
    s_loc = CrawlScheduler.from_local_env(
        env, _mesh1(), 8.0, m=m, backend=be.FusedBackend(block_rows=8),
        feed_cap=256)
    assert s_loc.m_state == s_ref.m_state
    # mu_total may differ from the global summation order in the last ulp
    # (per-shard partial sums); selection is scale-invariant in it.
    np.testing.assert_allclose(float(s_loc.mu_total), float(s_ref.mu_total),
                               rtol=1e-6)
    for name, a, b in zip(be.FusedState._fields, s_loc.round.backend,
                          s_ref.round.backend):
        np.testing.assert_allclose(
            np.asarray(a, np.float64), np.asarray(b, np.float64),
            rtol=1e-6, atol=1e-7, err_msg=name)
    feeds = strategies.build_feed_batch(m, 4, "sparse", np.int32, seed=11)
    ia, va = s_ref.run_rounds(feeds)
    ib, vb = s_loc.run_rounds(feeds)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_allclose(np.asarray(va), np.asarray(vb), rtol=1e-6)


def test_from_local_env_has_no_dense_oracle():
    m = 2000
    env = uniform_instance(jax.random.PRNGKey(1), m)
    s = CrawlScheduler.from_local_env(
        env, _mesh1(), 8.0, m=m, backend=be.FusedBackend(block_rows=8))
    with pytest.raises(RuntimeError, match="oracle"):
        s.d
    # ... but refresh still works without it (planes are written eagerly).
    upd = Env(delta=jnp.full((5,), 1.5), mu=jnp.full((5,), 9.0),
              lam=jnp.full((5,), 0.4), nu=jnp.full((5,), 0.2))
    s.update_pages(np.arange(5), upd)


def test_from_local_env_validation():
    m = 2000
    env = uniform_instance(jax.random.PRNGKey(2), m)
    with pytest.raises(ValueError, match="raw page range"):
        CrawlScheduler.from_local_env(
            jax.tree.map(lambda x: x[:-7], env), _mesh1(), 8.0, m=m,
            backend=be.FusedBackend(block_rows=8))
    with pytest.raises(ValueError, match="FusedBackend"):
        CrawlScheduler.from_local_env(env, _mesh1(), 8.0, m=m,
                                      backend=be.DenseBackend())


# ---------------------------------------------------------------------------
# Sharded-v1 checkpoint round-trip + integrity hardening.
# ---------------------------------------------------------------------------

def _roundtrip(tmp_path, s, make_fresh, feeds, sharded=True):
    """save(state_dict) -> fresh scheduler -> restore -> load_state_dict;
    assert the continued run and every state leaf match bitwise."""
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, s.state_dict(), sharded=sharded)
    s2 = make_fresh()
    restored, step, _ = ckpt.restore_latest(d, s2.state_dict())
    assert step == 1
    s2.load_state_dict(restored)
    for p, (a, b) in enumerate(zip(jax.tree.flatten(s.round)[0],
                                   jax.tree.flatten(s2.round)[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"round leaf {p}")
    ia, va = s.run_rounds(feeds)
    ib, vb = s2.run_rounds(feeds)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))


def test_sharded_roundtrip_bitwise(tmp_path):
    m = 3000
    env, s = _fused(m)
    feeds = strategies.build_feed_batch(m, 3, "sparse", np.int32, seed=5)
    s.run_rounds(feeds)
    make_fresh = lambda: CrawlScheduler(
        env, _mesh1(), bandwidth=8.0,
        backend=be.FusedBackend(block_rows=8), feed_cap=256)
    nxt = strategies.build_feed_batch(m, 3, "sparse", np.int32, seed=6)
    _roundtrip(tmp_path, s, make_fresh, nxt, sharded=True)


def test_adapt_counter_sentinel_roundtrip(tmp_path):
    """The sentinel-encoded host adaptation counters survive the sharded
    round-trip: cand_per_lane None <-> -1, an adapted depth comes back as
    itself, and the observation window resumes."""
    m = 3000
    for cand in (None, 3):
        env, s = _fused(m, backend=be.FusedBackend(
            block_rows=8, adaptive_cand=True, cand_per_lane=cand))
        s._rounds_since_cand_adapt = 5
        d = str(tmp_path / f"ck_{cand}")
        ckpt.save(d, 1, s.state_dict(), sharded=True)
        s2 = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                            backend=be.FusedBackend(block_rows=8,
                                                    adaptive_cand=True),
                            feed_cap=256)
        restored, _, _ = ckpt.restore_latest(d, s2.state_dict())
        s2.load_state_dict(restored)
        assert s2.backend.cand_per_lane == cand
        assert s2._rounds_since_cand_adapt == 5


def test_restore_detects_truncated_npz(tmp_path):
    _, s = _fused()
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, s.state_dict(), sharded=True)
    npz = os.path.join(d, "step_000000001", "shard_0.npz")
    with open(npz, "rb") as f:
        blob = f.read()
    with open(npz, "wb") as f:
        f.write(blob[: len(blob) // 2])
    with pytest.raises(CheckpointCorruptError):
        ckpt.restore(d, 1, s.state_dict())


def test_restore_detects_checksum_mismatch(tmp_path):
    _, s = _fused()
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, s.state_dict(), sharded=True)
    npz = os.path.join(d, "step_000000001", "shard_0.npz")
    data = dict(np.load(npz).items())
    data["a0"] = np.ascontiguousarray(data["a0"])
    flat = data["a0"].reshape(-1).view(np.uint8)
    flat[0] ^= 0xFF  # one flipped byte, still a valid zip
    np.savez(npz, **data)
    with pytest.raises(CheckpointCorruptError, match="checksum"):
        ckpt.restore(d, 1, s.state_dict())


def test_restore_detects_partially_renamed_step(tmp_path):
    _, s = _fused()
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, s.state_dict(), sharded=True)
    os.remove(os.path.join(d, "step_000000001", "manifest.json"))
    with pytest.raises(CheckpointCorruptError, match="manifest"):
        ckpt.restore(d, 1, s.state_dict())


def test_restore_latest_falls_back_past_damaged_step(tmp_path):
    """A damaged newest step degrades to the previous one (warning, not a
    crash) — across formats: the older intact step here is a legacy
    single-file snapshot."""
    m = 3000
    env, s = _fused(m)
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, s.state_dict(), sharded=False)  # legacy format
    feeds = strategies.build_feed_batch(m, 2, "sparse", np.int32, seed=7)
    s.run_rounds(feeds)
    ckpt.save(d, 2, s.state_dict(), sharded=True)
    os.remove(os.path.join(d, "step_000000002", "manifest.json"))
    s2 = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                        backend=be.FusedBackend(block_rows=8), feed_cap=256)
    with pytest.warns(UserWarning, match="damaged"):
        restored, step, _ = ckpt.restore_latest(d, s2.state_dict())
    assert step == 1
    s2.load_state_dict(restored)
    assert int(np.asarray(s2.round.crawl_clock)) == 0  # step-1 state


def test_old_snapshot_compat(tmp_path):
    """Regression: a pre-PR-6 snapshot — legacy single-file layout, no
    checksums, no `adapt` key — still restores with strict=False."""
    m = 3000
    env, s = _fused(m)
    feeds = strategies.build_feed_batch(m, 3, "sparse", np.int32, seed=8)
    s.run_rounds(feeds)
    sd = s.state_dict()
    old_sd = {k: v for k, v in sd.items() if k != "adapt"}
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, old_sd, sharded=False)
    mpath = os.path.join(d, "step_000000001", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["crcs"]  # old snapshots predate the checksums
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    s2 = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                        backend=be.FusedBackend(block_rows=8), feed_cap=256)
    restored, _ = ckpt.restore(d, 1, s2.state_dict(), strict=False)
    s2.load_state_dict(restored)
    nxt = strategies.build_feed_batch(m, 2, "sparse", np.int32, seed=9)
    ia, _ = s.run_rounds(nxt)
    ib, _ = s2.run_rounds(nxt)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))


# ---------------------------------------------------------------------------
# Typed exception hierarchy (host-local vs fleet-fatal).
# ---------------------------------------------------------------------------

def test_typed_exception_hierarchy():
    m = 2000
    env, s = _fused(m, feed_cap=4)
    # Host-local, recoverable: raised before any device work.
    with pytest.raises(errors.FeedDtypeError) as ei:
        s.ingest_and_schedule(np.zeros((m,), np.float32))
    assert isinstance(ei.value, TypeError) and not ei.value.fleet_fatal
    with pytest.raises(errors.FeedValidationError) as ei:
        s.run_rounds(np.zeros((2, m + 13), np.int32))
    assert isinstance(ei.value, ValueError) and not ei.value.fleet_fatal
    upd = Env(delta=jnp.ones((2,)), mu=jnp.ones((2,)),
              lam=jnp.ones((2,)), nu=jnp.ones((2,)))
    with pytest.raises(errors.FeedValidationError):
        s.update_pages(np.array([0, m + 5]), upd)
    # Fleet-fatal: the capacity contract is a cross-host compiled shape.
    feeds = np.zeros((2, m), np.int32)
    feeds[0, :64] = 1
    with pytest.raises(errors.CapacityExceeded) as ei:
        s.run_rounds(feeds)
    assert isinstance(ei.value, ValueError) and ei.value.fleet_fatal
    assert issubclass(errors.CapacityExceeded, errors.SchedulerError)


# ---------------------------------------------------------------------------
# Property: per-host save/restore round-trip across every backend.
# ---------------------------------------------------------------------------

_BACKENDS = {
    "dense": lambda: be.DenseBackend(),
    "table": lambda: be.TableBackend(),
    "kernel": lambda: be.KernelBackend(),
    "fused": lambda: be.FusedBackend(block_rows=8, adaptive_bounds=True,
                                     adaptive_cand=True),
}


@settings(max_examples=8, deadline=None)
@given(kind=st.sampled_from(sorted(_BACKENDS)),
       feeds=strategies.feed_batches(m=512, max_rounds=3),
       seed=st.integers(0, 2**8))
def test_property_state_roundtrip_all_backends(kind, feeds, seed):
    """state_dict -> per-host sharded save -> restore -> load_state_dict is
    an identity for every backend: all round-state leaves (including the
    grown FusedState planes) bitwise, and the continued selection too."""
    import pathlib
    import tempfile
    m = feeds.shape[1]
    env = uniform_instance(jax.random.PRNGKey(seed), m)
    mk = lambda: CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                                backend=_BACKENDS[kind](), feed_cap=512)
    s = mk()
    s.run_rounds(feeds)
    nxt = strategies.build_feed_batch(m, 2, "sparse", np.int32,
                                      seed=seed + 1)
    _roundtrip(pathlib.Path(tempfile.mkdtemp(prefix="ckpt_prop_")), s, mk,
               nxt, sharded=True)


# ---------------------------------------------------------------------------
# THE fault-injection acceptance run (slow, genuine 2-process fleet).
# ---------------------------------------------------------------------------

# Shared by the reference fleet, the to-be-killed fleet, and the restored
# fleet: deterministic env/feeds from integer hashes of the GLOBAL page
# index, built over each host's local range only — no process ever holds a
# global env or feed row. k is large enough that the candidate-depth
# adaptation takes a real decision inside the replayed window (so the
# checkpointed counters provably matter).
_RECOVERY_SETUP = """
import os, signal
import numpy as np
import jax.numpy as jnp
from repro.core import Env
from repro.sched import backends as be
from repro.sched.service import CrawlScheduler
from repro.checkpoint import store as ckpt

mesh = jax.make_mesh((4,), ("data",))
m, k, R, dt = 16384, 1024, 6, 0.05

def local_env(lo, hi):
    idx = np.arange(lo, hi, dtype=np.int64)
    return Env(
        delta=jnp.asarray(0.5 + ((idx * 2654435761) % 1000)
                          .astype(np.float32) / 500.0),
        mu=jnp.asarray(1.0 + ((idx * 40503) % 997)
                       .astype(np.float32) / 10.0),
        lam=jnp.asarray(0.1 + ((idx * 69069) % 91)
                        .astype(np.float32) / 100.0),
        nu=jnp.asarray(0.05 + ((idx * 12345) % 37)
                       .astype(np.float32) / 200.0),
    )

def feed(b, lo, hi):
    idx = np.arange(lo, hi, dtype=np.int64)
    f = np.zeros((R, hi - lo), np.int32)
    for r in range(R):
        h = (idx * 2654435761 + 97 * r + 131 * b) % 701
        sel = h < 2
        f[r, sel] = (1 + (idx[sel] % 7)).astype(np.int32)
    return f

# 40 pages, ALL on shard 0: over update_cap=32, so host 0 applies two
# chunks while host 1 applies one empty batch — hosts legitimately
# disagree on chunk count (the collective-free repack).
upd_ids = np.arange(0, 400, 10)
upd_env = Env(delta=jnp.full((40,), 1.5), mu=jnp.full((40,), 250.0),
              lam=jnp.full((40,), 0.4), nu=jnp.full((40,), 0.2))

def make_sched():
    lo, hi = PROC_ID * m // N_PROCS, (PROC_ID + 1) * m // N_PROCS
    return CrawlScheduler.from_local_env(
        local_env(lo, hi), mesh, float(k) / dt, m=m, round_period=dt,
        backend=be.FusedBackend(block_rows=8, adaptive_bounds=True,
                                adaptive_cand=True),
        feed_cap=64, update_cap=32)

def state_slabs(s):
    out = {}
    for name, v in zip(be.FusedState._fields, s.round.backend):
        if v is None:   # lazy planes (est/emit_res/stale) absent here;
            continue    # np.asarray(None) is an unloadable object array
        out["st_" + name] = ckpt._local_slab(v)[0]
    out["tau"] = ckpt._local_slab(s.round.tau_elap)[0]
    out["ncis"] = ckpt._local_slab(s.round.n_cis)[0]
    out["clock"] = np.asarray(s.round.crawl_clock)
    for name, v in zip(be.RoundDiagnostics._fields, s.macro_diagnostics):
        out["dg_" + name] = ckpt._local_slab(v)[0]
    return out

def poison_device_get(msg):
    def die(*a, **kw):
        raise AssertionError(msg)
    real, jax.device_get = jax.device_get, die
    return real
"""

_RECOVERY_PHASE_A = _RECOVERY_SETUP + """
# Host-local construction really is host-local: the assembled state is NOT
# addressable from one process (so neither init nor save can be secretly
# gathering globals).
s_ref = make_sched()
was_addressable = True
try:
    np.asarray(s_ref.round.tau_elap)
except Exception:
    was_addressable = False
assert not was_addressable, "2-process state was fully addressable"

# Uninterrupted reference run: B1 .. B4, over-cap refresh after B2.
lo, hi = s_ref.host_slice.start, s_ref.host_slice.stop
s_ref.run_rounds(feed(1, lo, hi))
ids2, vals2 = s_ref.run_rounds(feed(2, lo, hi))
c2 = be.crawl_rounds._cache_size()
s_ref.update_pages(upd_ids, upd_env)
ids3, vals3 = s_ref.run_rounds(feed(3, lo, hi))
c3 = be.crawl_rounds._cache_size()
ids4, vals4 = s_ref.run_rounds(feed(4, lo, hi))
c4 = be.crawl_rounds._cache_size()
# The depth decision must have fired inside the replayed window (18 rounds
# >= the 16-round interval at the B3 boundary) — otherwise this test would
# not prove the adaptation counters survive the crash.
assert s_ref.backend.cand_per_lane is not None, "no depth decision fired"
np.savez(os.path.join(tmpdir, "ref_%d.npz" % PROC_ID),
         ids2=np.asarray(ids2), vals2=np.asarray(vals2),
         ids3=np.asarray(ids3), vals3=np.asarray(vals3),
         ids4=np.asarray(ids4), vals4=np.asarray(vals4),
         cgrow3=c3 - c2, cgrow4=c4 - c3,
         cand=s_ref.backend.cand_per_lane,
         window=getattr(s_ref, "_rounds_since_cand_adapt", 0),
         **state_slabs(s_ref))

# The fleet that will crash: checkpoint after B1 (per-host shards, with
# jax.device_get poisoned — the sharded save path must never gather).
s = make_sched()
lo, hi = s.host_slice.start, s.host_slice.stop
s.run_rounds(feed(1, lo, hi))
real = poison_device_get("sharded save called jax.device_get")
ckpt.save(os.path.join(tmpdir, "ck"), 1, s.state_dict())
jax.device_get = real
print("CKPT_READY", flush=True)
s.run_rounds(feed(2, lo, hi))   # post-checkpoint work, lost in the crash
if PROC_ID == 1:
    os.kill(os.getpid(), signal.SIGKILL)
s.run_rounds(feed(3, lo, hi))   # survivor hangs here (reaped by harness)
print("SURVIVOR_PASSED_B3", flush=True)
"""

_RECOVERY_PHASE_B = _RECOVERY_SETUP + """
s = make_sched()
lo, hi = s.host_slice.start, s.host_slice.stop
real = poison_device_get("sharded restore called jax.device_get")
restored, step, extra = ckpt.restore_latest(os.path.join(tmpdir, "ck"),
                                            s.state_dict())
assert step == 1, step
s.load_state_dict(restored)
jax.device_get = real

ref = np.load(os.path.join(tmpdir, "ref_%d.npz" % PROC_ID))
ids2, vals2 = s.run_rounds(feed(2, lo, hi))
c2 = be.crawl_rounds._cache_size()
s.update_pages(upd_ids, upd_env)
ids3, vals3 = s.run_rounds(feed(3, lo, hi))
c3 = be.crawl_rounds._cache_size()
ids4, vals4 = s.run_rounds(feed(4, lo, hi))
c4 = be.crawl_rounds._cache_size()

# Selections + values of every replayed batch: bit-identical.
for name, got in [("ids2", ids2), ("vals2", vals2), ("ids3", ids3),
                  ("vals3", vals3), ("ids4", ids4), ("vals4", vals4)]:
    np.testing.assert_array_equal(np.asarray(got), ref[name], err_msg=name)
# Post-restore recompile cadence identical to the uninterrupted run: no
# extra jit-cache growth batch over batch (in particular the depth
# decision at the B3 boundary re-jits exactly once in both runs).
assert c3 - c2 == int(ref["cgrow3"]), (c3 - c2, int(ref["cgrow3"]))
assert c4 - c3 == int(ref["cgrow4"]), (c4 - c3, int(ref["cgrow4"]))
# Adaptation counters: the restored fleet took the same depth decision in
# the same round.
assert s.backend.cand_per_lane == int(ref["cand"]), (
    s.backend.cand_per_lane, int(ref["cand"]))
assert getattr(s, "_rounds_since_cand_adapt", 0) == int(ref["window"])
# Final state (packed planes, bounds, thresholds, page state) and the last
# macro-round's diagnostics: bit-identical shard by shard.
for name, slab in state_slabs(s).items():
    np.testing.assert_array_equal(slab, ref[name], err_msg=name)
print("RESTORE_OK", flush=True)
"""


@pytest.mark.slow
def test_kill_and_restore_two_process(tmp_path):
    """Run a 2-process fleet, checkpoint to per-host shards, SIGKILL one
    process mid-run, restart a fresh fleet from the shards, and prove the
    continued run is bit-identical to an uninterrupted one."""
    tmpdir = str(tmp_path)
    outs = run_distributed_kill(_RECOVERY_PHASE_A, n_procs=2,
                                devices_per_proc=2, victim=1, timeout=900,
                                token="CKPT_READY", tmpdir=tmpdir)
    # The survivor must NOT have completed the post-crash batch: its peer
    # is gone, the collective can never finish.
    assert "SURVIVOR_PASSED_B3" not in outs[0], outs[0]
    # Both reference files and the checkpoint were durable before the kill.
    for p in (0, 1):
        assert os.path.exists(os.path.join(tmpdir, f"ref_{p}.npz"))
    assert os.path.exists(
        os.path.join(tmpdir, "ck", "step_000000001", "manifest.json"))
    run_distributed(_RECOVERY_PHASE_B, n_procs=2, devices_per_proc=2,
                    timeout=900, token="RESTORE_OK", tmpdir=tmpdir)


# ---------------------------------------------------------------------------
# Topology resharding: restore an N-proc sharded-v1 checkpoint elsewhere.
# ---------------------------------------------------------------------------

def _fabricate_n_proc_step(src_step: str, dst_step: str, n_procs: int):
    """Rewrite a single-process sharded-v1 step as if saved by `n_procs`
    processes: every leaf whose leading axis divides evenly is split into
    contiguous slabs with recorded global offsets (exactly what
    `ckpt._local_slab` records on a real fleet); everything else is
    carried replicated (offsets None) in every shard file. Bit-identical
    data, different recorded topology — the pure resharding stimulus."""
    import zlib

    with open(os.path.join(src_step, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == "sharded-v1"
    assert manifest["topology"]["n_procs"] == 1
    arrays = np.load(os.path.join(src_step, "shard_0.npz"))
    arrays = [arrays[f"a{i}"] for i in range(len(manifest["paths"]))]
    os.makedirs(dst_step, exist_ok=True)
    shards_meta = {}
    n_split = 0
    for p in range(n_procs):
        stored, crcs, offsets, shapes = [], [], [], []
        for a in arrays:
            if a.ndim >= 1 and a.shape[0] >= n_procs \
                    and a.shape[0] % n_procs == 0:
                h = a.shape[0] // n_procs
                piece = np.ascontiguousarray(a[p * h:(p + 1) * h])
                off = [p * h] + [0] * (a.ndim - 1)
                n_split += 1
            else:
                piece, off = a, None
            stored.append(piece)
            crcs.append(zlib.crc32(piece.tobytes()))
            offsets.append(off)
            shapes.append(list(piece.shape))
        np.savez(os.path.join(dst_step, f"shard_{p}.npz"),
                 **{f"a{i}": a for i, a in enumerate(stored)})
        shards_meta[str(p)] = {"proc": p, "crcs": crcs, "offsets": offsets,
                               "local_shapes": shapes}
    assert n_split > 0, "fabricated checkpoint split no leaf (no stimulus)"
    manifest["topology"] = {"n_procs": n_procs, "n_devices": n_procs}
    manifest["shards"] = shards_meta
    with open(os.path.join(dst_step, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def test_reshard_restore_two_proc_checkpoint_on_one_proc(tmp_path):
    """A sharded-v1 checkpoint recorded by a 2-process fleet restores on a
    single process by re-slicing the shard files along their recorded
    global offsets — and the continued selection is bit-identical to a
    same-topology restore of the same state."""
    m = 3000
    env, s = _fused(m)
    feeds = strategies.build_feed_batch(m, 3, "sparse", np.int32, seed=21)
    s.run_rounds(feeds)
    d1 = str(tmp_path / "ck1")
    ckpt.save(d1, 1, s.state_dict(), sharded=True)
    step1 = os.path.join(d1, "step_000000001")
    d2 = str(tmp_path / "ck2")
    _fabricate_n_proc_step(step1, os.path.join(d2, "step_000000001"), 2)

    def mk():
        return CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                              backend=be.FusedBackend(block_rows=8),
                              feed_cap=256)

    s2 = mk()
    restored, step, _ = ckpt.restore_latest(d2, s2.state_dict())
    assert step == 1
    s2.load_state_dict(restored)
    for p, (a, b) in enumerate(zip(jax.tree.flatten(s.round)[0],
                                   jax.tree.flatten(s2.round)[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"round leaf {p}")
    nxt = strategies.build_feed_batch(m, 3, "sparse", np.int32, seed=22)
    ia, va = s.run_rounds(nxt)
    ib, vb = s2.run_rounds(nxt)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))


def test_reshard_detects_slab_coverage_gap(tmp_path):
    """Resharding is offsets-driven, so damaged offsets must fail LOUDLY:
    a recorded slab layout that no longer tiles the global shape raises
    `CheckpointCorruptError` (never a silently half-initialized leaf)."""
    m = 3000
    env, s = _fused(m)
    d1 = str(tmp_path / "ck1")
    ckpt.save(d1, 1, s.state_dict(), sharded=True)
    d2 = str(tmp_path / "ck2")
    step2 = os.path.join(d2, "step_000000001")
    _fabricate_n_proc_step(os.path.join(d1, "step_000000001"), step2, 2)
    with open(os.path.join(step2, "manifest.json")) as f:
        manifest = json.load(f)
    # Shift every split slab of shard 1 past its true start: a coverage
    # gap opens between the halves of each split leaf.
    smeta = manifest["shards"]["1"]
    bad = False
    for i, off in enumerate(smeta["offsets"]):
        if off is not None and off[0] > 0:
            off[0] += 1
            bad = True
    assert bad
    with open(os.path.join(step2, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    s2 = CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                        backend=be.FusedBackend(block_rows=8), feed_cap=256)
    with pytest.raises(CheckpointCorruptError, match="tile"):
        ckpt.restore(d2, 1, s2.state_dict())


# Genuine cross-topology acceptance: a real 2-process fleet writes the
# checkpoint + the reference continuation, then ONE process with the same
# 4-device mesh restores it through the resharding path and must continue
# bit-identically. Env/feeds derive from integer hashes of the global page
# index over each host's local range (no process holds global data).
_RESHARD_SETUP = """
import os
import numpy as np
import jax.numpy as jnp
from repro.core import Env
from repro.sched import backends as be
from repro.sched.service import CrawlScheduler
from repro.checkpoint import store as ckpt

mesh = jax.make_mesh((4,), ("data",))
m, k, R, dt = 16384, 256, 4, 0.05
n_procs = jax.process_count()
lo = jax.process_index() * m // n_procs
hi = (jax.process_index() + 1) * m // n_procs

def local_env(lo, hi):
    idx = np.arange(lo, hi, dtype=np.int64)
    return Env(
        delta=jnp.asarray(0.5 + ((idx * 2654435761) % 1000)
                          .astype(np.float32) / 500.0),
        mu=jnp.asarray(1.0 + ((idx * 40503) % 997)
                       .astype(np.float32) / 10.0),
        lam=jnp.asarray(0.1 + ((idx * 69069) % 91)
                        .astype(np.float32) / 100.0),
        nu=jnp.asarray(0.05 + ((idx * 12345) % 37)
                       .astype(np.float32) / 200.0),
    )

def feed(b):
    idx = np.arange(lo, hi, dtype=np.int64)
    f = np.zeros((R, hi - lo), np.int32)
    for r in range(R):
        h = (idx * 2654435761 + 97 * r + 131 * b) % 701
        sel = h < 2
        f[r, sel] = (1 + (idx[sel] % 7)).astype(np.int32)
    return f

def make_sched():
    return CrawlScheduler.from_local_env(
        local_env(lo, hi), mesh, float(k) / dt, m=m, round_period=dt,
        backend=be.FusedBackend(block_rows=8, adaptive_bounds=True),
        feed_cap=64)
"""

_RESHARD_SAVE = _RESHARD_SETUP + """
s = make_sched()
s.run_rounds(feed(1))
ckpt.save(os.path.join(tmpdir, "ck"), 1, s.state_dict())
ids2, vals2 = s.run_rounds(feed(2))
if jax.process_index() == 0:
    np.savez(os.path.join(tmpdir, "reshard_ref.npz"),
             ids2=np.asarray(ids2), vals2=np.asarray(vals2))
print("SAVED_2PROC", flush=True)
"""

_RESHARD_RESTORE = _RESHARD_SETUP + """
assert jax.process_count() == 1 and len(jax.devices()) == 4
s = make_sched()
restored, step, extra = ckpt.restore_latest(os.path.join(tmpdir, "ck"),
                                            s.state_dict())
assert step == 1, step
s.load_state_dict(restored)
ref = np.load(os.path.join(tmpdir, "reshard_ref.npz"))
ids2, vals2 = s.run_rounds(feed(2))
np.testing.assert_array_equal(np.asarray(ids2), ref["ids2"])
np.testing.assert_array_equal(np.asarray(vals2), ref["vals2"])
print("RESHARD_OK", flush=True)
"""


@pytest.mark.slow
def test_reshard_genuine_two_proc_to_one_proc(tmp_path):
    """Save on a genuine 2-process `jax.distributed` fleet, restore on ONE
    process with the same 4-shard mesh (elastic shrink / post-mortem), and
    prove the continued macro-round selection is bit-identical to the
    uninterrupted fleet's."""
    from mesh_harness import run_forced_shards

    tmpdir = str(tmp_path)
    run_distributed(_RESHARD_SAVE, n_procs=2, devices_per_proc=2,
                    timeout=900, token="SAVED_2PROC", tmpdir=tmpdir)
    mpath = os.path.join(tmpdir, "ck", "step_000000001", "manifest.json")
    with open(mpath) as f:
        assert json.load(f)["topology"]["n_procs"] == 2
    run_forced_shards(_RESHARD_RESTORE, n_devices=4, timeout=900,
                      token="RESHARD_OK", tmpdir=tmpdir)
