"""Typed scheduler errors (`sched.errors`): the fleet_fatal contract, legacy
builtin subclassing, and the closed-loop driver's host-local drop of invalid
outcome batches."""
import jax
import numpy as np
import pytest

from repro.sched import backends as be
from repro.sched.errors import (
    CapacityExceeded,
    FeedDtypeError,
    FeedValidationError,
    SchedulerError,
)
from repro.sched.service import CrawlScheduler
from repro.sim import LoopConfig, run_closed_loop, tiered_cis_instance


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _sched(m=512, **kw):
    env = tiered_cis_instance(jax.random.PRNGKey(0), m).env
    return CrawlScheduler(env, _mesh1(), bandwidth=8.0,
                          backend=be.FusedBackend(block_rows=2, **kw))


# -- hierarchy + flags -------------------------------------------------------

def test_fleet_fatal_flags():
    assert SchedulerError.fleet_fatal is False
    assert FeedValidationError.fleet_fatal is False
    assert FeedDtypeError.fleet_fatal is False
    assert CapacityExceeded.fleet_fatal is True


def test_legacy_builtin_subclassing():
    assert issubclass(FeedValidationError, SchedulerError)
    assert issubclass(FeedValidationError, ValueError)
    assert issubclass(FeedDtypeError, FeedValidationError)
    assert issubclass(FeedDtypeError, TypeError)
    assert issubclass(CapacityExceeded, SchedulerError)
    assert issubclass(CapacityExceeded, ValueError)
    # Instances carry the class flag.
    assert FeedValidationError("x").fleet_fatal is False
    assert CapacityExceeded("x").fleet_fatal is True


def test_legacy_handlers_still_catch():
    s = _sched()
    with pytest.raises(ValueError):          # pre-hierarchy handler style
        s.ingest_and_schedule(np.zeros(7, np.int32))
    with pytest.raises(TypeError):
        s.ingest_and_schedule(np.zeros(s.m, np.float32))
    # And the typed forms are what actually flies.
    with pytest.raises(FeedValidationError):
        s.ingest_and_schedule(np.zeros(7, np.int32))
    with pytest.raises(FeedDtypeError):
        s.ingest_and_schedule(np.zeros(s.m, np.float32))


def test_capacity_exceeded_is_fleet_fatal():
    s = _sched(m=512)
    s.feed_cap = 1
    feeds = np.ones((2, 512), np.int32)
    with pytest.raises(CapacityExceeded) as ei:
        s.run_rounds(feeds)
    assert ei.value.fleet_fatal is True


# -- the driver's host-local drop path ---------------------------------------

def test_driver_drops_invalid_outcome_batches():
    """A malformed outcome batch is a host-local FeedValidationError: the
    closed-loop driver must drop the batch and keep the loop running, not
    crash — outcomes are optional enrichment, the round is not."""
    m = 256
    inst = tiered_cis_instance(jax.random.PRNGKey(1), m)
    s = CrawlScheduler(inst.env, _mesh1(), bandwidth=8.0,
                       backend=be.FusedBackend(block_rows=2,
                                               online_est=True))
    orig = s.run_rounds
    state = {"poisoned": 0}

    def flaky(feeds, outcomes=None, budgets=None, outcome_seq=None):
        if outcomes is not None and state["poisoned"] == 0:
            state["poisoned"] += 1
            raise FeedValidationError("corrupted echo batch")
        return orig(feeds, outcomes=outcomes, budgets=budgets,
                    outcome_seq=outcome_seq)

    s.run_rounds = flaky
    cfg = LoopConfig(n_batches=3, rounds_per_batch=4, mode="streaming",
                     seed=0)
    res = run_closed_loop(s, inst.env, cfg)
    assert state["poisoned"] == 1
    assert res.dropped_batches == 1
    assert len(res.freshness) == 12          # the loop ran to completion


def test_driver_does_not_swallow_fleet_fatal():
    """CapacityExceeded is fleet-fatal by contract — the driver must let it
    propagate, never retry around it."""
    m = 256
    inst = tiered_cis_instance(jax.random.PRNGKey(2), m)
    s = CrawlScheduler(inst.env, _mesh1(), bandwidth=8.0,
                       backend=be.FusedBackend(block_rows=2), feed_cap=1)
    cfg = LoopConfig(n_batches=2, rounds_per_batch=4, seed=0)
    with pytest.raises(CapacityExceeded):
        run_closed_loop(s, inst.env, cfg)
