"""Hostile signal ecosystems (`sim.faults`): channel routing, outage
schedules, bursty/flash-crowd processes, feed/outcome fault injectors, and
their closed-loop driver integration (`sim.driver` fault knobs)."""
import numpy as np
import pytest

import strategies
from _hypothesis_compat import given, settings, st
from repro.sim import faults


# -- channels & routing ------------------------------------------------------

def test_assign_channels_contiguous_runs():
    ch = faults.assign_channels(12, 3, span=2)
    assert ch.dtype == np.int32
    np.testing.assert_array_equal(
        ch, [0, 0, 1, 1, 2, 2, 0, 0, 1, 1, 2, 2])


def test_channel_rates_scale_and_clip():
    lam = np.array([0.9, 0.9, 0.5])
    nu = np.array([0.2, 0.2, 0.5])
    specs = (faults.ChannelSpec("a", 1.5, 2.0, 0),
             faults.ChannelSpec("b", 0.5, 0.5, 1))
    le, ne = faults.channel_rates(lam, nu, np.array([0, 1, 0]), specs)
    assert le[0] == 1.0                     # 0.9 * 1.5 clipped to [0, 1]
    assert le[1] == pytest.approx(0.45)
    assert ne[0] == pytest.approx(0.4)
    assert ne[1] == pytest.approx(0.1)


def test_route_conserves_counts_without_outage():
    rng = np.random.default_rng(0)
    R, m = 10, 30
    sig = rng.poisson(1.0, (R, m))
    ch = faults.assign_channels(m, 3, span=10)
    # Zero-delay specs: routing is the identity.
    specs = tuple(faults.ChannelSpec(s.name, 1.0, 1.0, 0)
                  for s in faults.DEFAULT_CHANNELS)
    np.testing.assert_array_equal(
        faults.route_through_channels(sig, ch, specs), sig)
    # With delays, counts are conserved modulo horizon truncation.
    out = faults.route_through_channels(sig, ch, faults.DEFAULT_CHANNELS)
    for c, spec in enumerate(faults.DEFAULT_CHANNELS):
        sel = ch == c
        d = spec.delay_rounds
        kept = sig[:R - d, sel].sum() if d < R else 0
        assert out[:, sel].sum() == kept


def test_outage_windows_lose_counts():
    R, m = 8, 9
    sig = np.ones((R, m), np.int64)
    ch = faults.assign_channels(m, 3, span=3)
    specs = tuple(faults.ChannelSpec(s.name, 1.0, 1.0, 0)
                  for s in faults.DEFAULT_CHANNELS)
    sched = faults.OutageSchedule(
        windows=(faults.OutageWindow(channel=1, start=2, stop=5),))
    out = faults.route_through_channels(sig, ch, specs, schedule=sched)
    assert out[:, ch == 1][2:5].sum() == 0          # dark window
    assert out[:, ch == 1][:2].sum() == 2 * 3       # delivering before
    np.testing.assert_array_equal(out[:, ch != 1], sig[:, ch != 1])
    np.testing.assert_array_equal(sched.out_rounds(1, R), [2, 3, 4])


def test_outage_bad_channel_raises():
    sched = faults.OutageSchedule(
        windows=(faults.OutageWindow(channel=7, start=0, stop=1),))
    with pytest.raises(ValueError):
        sched.delivery_mask(4)


# -- bursty / flash crowd ----------------------------------------------------

def test_hawkes_supercritical_guard():
    with pytest.raises(ValueError):
        faults.hawkes_change_counts(np.random.default_rng(0),
                                    np.full(4, 0.1), 8,
                                    excite=5.0, decay=0.1)


def test_hawkes_bursts_exceed_poisson_variance():
    rng = np.random.default_rng(1)
    base = np.full(256, 0.5)
    counts = faults.hawkes_change_counts(rng, base, 200, excite=0.5,
                                         decay=0.6)
    assert counts.shape == (200, 256)
    # Self-excitation makes the count process overdispersed vs its mean.
    per_round = counts.sum(axis=1).astype(np.float64)
    assert per_round.var() > 1.5 * per_round.mean()


def test_flash_crowd_profile():
    prof = faults.flash_crowd_profile(10, [(2, 4, 3.0), (8, 99, 0.5)])
    np.testing.assert_array_equal(
        prof, [1, 1, 3, 3, 1, 1, 1, 1, 0.5, 0.5])


# -- feed fault injector -----------------------------------------------------

def test_feed_injector_semantics():
    m = 4
    feeds = np.tile(np.arange(1, 6, dtype=np.int64)[:, None], (1, m))
    plan = faults.FaultPlan(drop=(0,), delay=((1, 2),), duplicate=((2, 1),))
    out = feeds.copy()
    inj = faults.FeedFaultInjector(plan)
    out = inj.apply(feeds)
    np.testing.assert_array_equal(out[0], 0)            # dropped
    np.testing.assert_array_equal(out[1], 0)            # delayed away
    np.testing.assert_array_equal(out[2], feeds[2])     # dup lands on time
    np.testing.assert_array_equal(out[3], feeds[1] + feeds[2] + feeds[3])
    np.testing.assert_array_equal(out[4], feeds[4])
    assert inj.pending_total() == 0


def test_feed_injector_carries_pending_across_batches():
    m = 3
    feeds = np.ones((2, m), np.int64)
    plan = faults.FaultPlan(delay=((1, 2),))
    inj = faults.FeedFaultInjector(plan)
    out1 = inj.apply(feeds)
    assert out1.sum() == m                   # row 1 delayed past the batch
    assert inj.pending_total() == m
    out2 = inj.apply(np.zeros((2, m), np.int64))
    np.testing.assert_array_equal(out2[1], 1)  # lands at global round 3
    assert inj.pending_total() == 0


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_feed_injector_conserves_counts(data):
    """Drop-free plans conserve every count (delays/dups only move/add)."""
    plan = data.draw(strategies.fault_plans(n_rounds=8))
    plan = faults.FaultPlan(drop=(), delay=plan.delay,
                            duplicate=plan.duplicate)
    rng = np.random.default_rng(0)
    feeds = rng.poisson(1.0, (8, 5)).astype(np.int64)
    inj = faults.FeedFaultInjector(plan)
    out = inj.apply(feeds)
    dup_extra = sum(feeds[r].sum() for r, lag in plan.duplicate
                    if r + lag < 8)
    assert out.sum() + inj.pending_total() == feeds.sum() + dup_extra


# -- outcome fault injector --------------------------------------------------

def test_outcome_injector_drop_dup_hold():
    inj = faults.OutcomeFaultInjector(
        faults.FaultPlan(out_drop=(0,), out_dup=(1,), out_hold=(2,)))
    assert inj.deliveries(0, "b0") == []
    assert inj.deliveries(1, "b1") == [(1, "b1"), (1, "b1")]
    assert inj.deliveries(2, "b2") == []            # held
    # Held batch is released AFTER the next delivery — true reordering.
    assert inj.deliveries(3, "b3") == [(3, "b3"), (2, "b2")]
    assert inj.flush() == []


def test_outcome_injector_flush_releases_held():
    inj = faults.OutcomeFaultInjector(faults.FaultPlan(out_hold=(0,)))
    assert inj.deliveries(0, "b0") == []
    assert inj.flush() == [(0, "b0")]


# -- deterministic plan builders (shared with hypothesis) --------------------

def test_random_fault_plan_deterministic():
    p1 = strategies.build_fault_plan(16, seed=7, n_batches=4)
    p2 = strategies.build_fault_plan(16, seed=7, n_batches=4)
    assert p1 == p2
    rounds = set(p1.drop) | {r for r, _ in p1.delay} | {
        r for r, _ in p1.duplicate}
    assert all(0 <= r < 16 for r in rounds)


def test_build_outage_windows_kinds():
    assert strategies.build_outage_windows(10, 3, "none", 0) == []
    wins = strategies.build_outage_windows(10, 3, "blackout", 3)
    assert len(wins) == 3
    assert len({(a, b) for _, a, b in wins}) == 1    # one shared window
    chans = {c for c, _, _ in strategies.build_outage_windows(
        10, 3, "staggered", 5)}
    assert chans == {0, 1, 2}
