"""Solver (Theorem 1) and simulator integration tests — the paper's central
empirical claims at reduced scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BIG,
    G,
    derive,
    freq,
    policies as pol,
    solver,
    value_ncis,
)
from repro.core.estimation import fit_mle, naive_precision_recall
from repro.sim import DelayConfig, SimConfig, simulate, uniform_instance
from repro.sim.simulator import simulate_delayed

R = 100


def test_solver_meets_budget_and_kkt():
    env = uniform_instance(jax.random.PRNGKey(0), 64)
    sol = solver.solve_continuous(env, R)
    np.testing.assert_allclose(float(jnp.sum(sol.rate)), R, rtol=1e-3)
    # KKT: V(iota*) == Lambda for crawled pages.
    d = derive(env)
    crawled = sol.iota < BIG
    v = value_ncis(sol.iota, d, 8)
    lam = float(sol.lam_mult)
    assert float(jnp.max(jnp.abs(jnp.where(crawled, v - lam, 0.0)))) < 1e-4


def test_solver_cis_beats_nocis():
    env = uniform_instance(jax.random.PRNGKey(1), 64)
    with_cis = solver.solve_continuous(env, R)
    without = solver.solve_continuous_nocis(env, R)
    assert float(with_cis.objective) >= float(without.objective) - 1e-6


def test_nocis_matches_G():
    env = uniform_instance(jax.random.PRNGKey(2), 64, with_cis=False)
    sol = solver.solve_continuous(env, R)
    d = derive(env)
    obj_g = float(jnp.sum(G(sol.rate, d.mu_t, d.delta)))
    np.testing.assert_allclose(float(sol.objective), obj_g, rtol=1e-4)


class TestSimulator:
    def _cfg(self, T=60):
        return SimConfig(dt=1.0 / R, n_steps=R * T)

    def test_greedy_near_continuous_optimum(self):
        # Fig. 2 claim: GREEDY ~ LDS ~ continuous optimum (no CIS).
        env = uniform_instance(jax.random.PRNGKey(3), 100, with_cis=False)
        sol = solver.solve_continuous_nocis(env, R)
        res = simulate(jax.random.PRNGKey(4), env, pol.GREEDY, self._cfg())
        lds = simulate(jax.random.PRNGKey(4), env, pol.LDS, self._cfg(),
                       lds_rates=sol.rate)
        base = float(sol.objective)
        assert abs(float(res.accuracy) - base) < 0.03
        assert abs(float(lds.accuracy) - base) < 0.03

    def test_budget_exact(self):
        env = uniform_instance(jax.random.PRNGKey(5), 50)
        cfg = self._cfg(T=10)
        res = simulate(jax.random.PRNGKey(6), env, pol.GREEDY, cfg)
        assert int(res.crawl_counts.sum()) == cfg.n_steps  # k=1 per tick

    def test_cis_helps(self):
        # Fig. 3/4 claim: NCIS >= GREEDY when signals exist.
        env = uniform_instance(jax.random.PRNGKey(7), 100)
        g = simulate(jax.random.PRNGKey(8), env, pol.GREEDY, self._cfg())
        n = simulate(jax.random.PRNGKey(8), env, pol.GREEDY_NCIS, self._cfg())
        assert float(n.accuracy) > float(g.accuracy) + 0.01

    def test_ncis_beats_cis_under_noise(self):
        # Fig. 4 claim: with false positives, NCIS >= CIS.
        accs = {"cis": [], "ncis": []}
        for r in range(3):
            env = uniform_instance(jax.random.PRNGKey(100 + r), 300,
                                   nu_range=(0.3, 0.6))
            c = simulate(jax.random.PRNGKey(r), env, pol.GREEDY_CIS,
                         self._cfg())
            n = simulate(jax.random.PRNGKey(r), env, pol.GREEDY_NCIS,
                         self._cfg())
            accs["cis"].append(float(c.accuracy))
            accs["ncis"].append(float(n.accuracy))
        assert np.mean(accs["ncis"]) > np.mean(accs["cis"]) - 1e-3

    def test_approx_close_to_exact(self):
        env = uniform_instance(jax.random.PRNGKey(9), 100)
        a1 = simulate(jax.random.PRNGKey(10), env, pol.G_NCIS_APPROX_1,
                      self._cfg())
        ex = simulate(jax.random.PRNGKey(10), env, pol.GREEDY_NCIS,
                      self._cfg())
        assert abs(float(a1.accuracy) - float(ex.accuracy)) < 0.03

    def test_delay_filter_recovers(self):
        env = uniform_instance(jax.random.PRNGKey(11), 100)
        cfg = self._cfg(T=40)
        delay = DelayConfig(mean_ticks=6.0, max_ticks=32)
        plain = simulate_delayed(jax.random.PRNGKey(12), env, pol.GREEDY_NCIS,
                                 cfg, delay)
        filt = simulate_delayed(jax.random.PRNGKey(12), env, pol.GREEDY_NCIS,
                                cfg._replace(t_delay_filter=5.0 / R), delay)
        assert float(filt.accuracy) > float(plain.accuracy) - 0.02

    def test_table_impl_matches_exact(self):
        env = uniform_instance(jax.random.PRNGKey(13), 100)
        cfg = self._cfg(T=30)
        t = simulate(jax.random.PRNGKey(14), env, pol.GREEDY_NCIS, cfg)
        e = simulate(jax.random.PRNGKey(14), env, pol.GREEDY_NCIS,
                     cfg._replace(value_impl="exact"))
        assert abs(float(t.accuracy) - float(e.accuracy)) < 0.01


def test_estimation_mle_beats_naive():
    rng = np.random.default_rng(0)
    errs_n, errs_m = [], []
    for _ in range(5):
        precision, recall = rng.uniform(0.3, 0.9, 2)
        delta = 1.0 / rng.uniform(2, 10)
        lam = recall
        gamma = lam * delta / precision
        nu = gamma - lam * delta
        tau = rng.exponential(2.0 / delta, 4000)
        changes = rng.poisson(delta * tau)
        signaled = rng.binomial(changes, lam)
        n_cis = signaled + rng.poisson(nu * tau)
        fresh = (changes == 0).astype(np.int32)
        p_n, r_n = naive_precision_recall(jnp.asarray(n_cis)[None],
                                          jnp.asarray(changes)[None])
        errs_n.append(abs(float(p_n[0]) - precision) + abs(float(r_n[0]) - recall))
        q = fit_mle(jnp.asarray(tau, jnp.float32), jnp.asarray(n_cis),
                    jnp.asarray(fresh), jnp.float32(gamma), steps=300)
        errs_m.append(abs(float(q.precision) - precision)
                      + abs(float(q.recall) - recall))
    assert np.mean(errs_m) < np.mean(errs_n)
